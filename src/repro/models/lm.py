"""Decoder-LM assembly: init / forward / cached decode for every family.

Layers are stacked along a leading L axis and traversed with ``lax.scan``
(compact HLO, essential for 512-device CPU dry-run compiles).  MoE models
split their leading dense layers (deepseek/kimi style) into a separate
stack.  Remat wraps the scanned body when ``cfg.remat``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.api import constrain

from . import blocks
from .blocks import HUGE_WINDOW
from .layers import dtype_of, init_dense, rms_norm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_windows(cfg: ModelConfig, n: int, offset: int = 0):
    """Per-layer attention window (HUGE_WINDOW = global).

    Returns a plain numpy array: static for the unrolled path, and scan
    accepts numpy xs directly for the stacked path."""
    import numpy as np

    w = np.full(n, HUGE_WINDOW, dtype=np.int32)
    if cfg.local_window:
        if cfg.layer_pattern == "lg":       # gemma2: local, global alternating
            for i in range(n):
                if (i + offset) % 2 == 0:
                    w[i] = cfg.local_window
        else:                                # hymba-style: all local but a few
            for i in range(n):
                if (i + offset) not in (0, n // 2, n - 1):
                    w[i] = cfg.local_window
    return w


def _init_attn(key, cfg: ModelConfig, L: int, dt) -> dict:
    d, Hq, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 8)
    p = {
        "ln1": jnp.zeros((L, d), dt),
        "wq": init_dense(ks[0], (L, d, Hq * D), dt),
        "wk": init_dense(ks[1], (L, d, Hkv * D), dt),
        "wv": init_dense(ks[2], (L, d, Hkv * D), dt),
        "wo": init_dense(ks[3], (L, Hq * D, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((L, Hq * D), dt)
        p["bk"] = jnp.zeros((L, Hkv * D), dt)
        p["bv"] = jnp.zeros((L, Hkv * D), dt)
    if cfg.name.startswith("gemma2"):
        p["post_ln"] = jnp.zeros((L, d), dt)
    return p


def _init_ffn(key, cfg: ModelConfig, L: int, dt) -> dict:
    d, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    p = {"ln2": jnp.zeros((L, d), dt)}
    if cfg.act == "gelu_mlp":
        p["wi"] = init_dense(ks[0], (L, d, F), dt)
    else:
        p["wi"] = init_dense(ks[0], (L, d, 2 * F), dt)
    p["wo_ff"] = init_dense(ks[1], (L, F, d), dt)
    if cfg.name.startswith("gemma2"):
        p["post_ln2"] = jnp.zeros((L, d), dt)
    return p


def _init_moe_ffn(key, cfg: ModelConfig, L: int, dt) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 4)
    p = {
        "ln2": jnp.zeros((L, d), dt),
        "router": init_dense(ks[0], (L, d, E), dt),
        "we_i": init_dense(ks[1], (L, E, d, 2 * f), dt),
        "we_o": init_dense(ks[2], (L, E, f, d), dt),
    }
    if cfg.n_shared_experts > 0:
        fs = f * cfg.n_shared_experts
        k1, k2 = jax.random.split(ks[3])
        p["ws_i"] = init_dense(k1, (L, d, 2 * fs), dt)
        p["ws_o"] = init_dense(k2, (L, fs, d), dt)
    return p


def _init_ssd(key, cfg: ModelConfig, L: int, dt) -> dict:
    d, H, P, N = cfg.d_model, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    proj_out = 2 * H * P + 2 * N + H
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((L, d), dt),
        "in_proj": init_dense(ks[0], (L, d, proj_out), dt),
        "conv_w": init_dense(ks[1], (L, cfg.conv_kernel, H * P), dt, scale=0.5),
        "dt_bias": jnp.zeros((L, H), jnp.float32),
        "a_log": jnp.zeros((L, H), jnp.float32),
        "d_skip": jnp.ones((L, H), jnp.float32) * 0.0,
        "out_ln": jnp.zeros((L, H * P), dt),
        "out_proj": init_dense(ks[2], (L, H * P, d), dt),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg.param_dtype)
    d, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": init_dense(keys[0], (V, d), dt, scale=1.0),
        "ln_f": jnp.zeros((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(keys[1], (d, V), dt)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["blocks"] = {**_init_attn(keys[2], cfg, L, dt),
                            **_init_ffn(keys[3], cfg, L, dt)}
    elif fam == "moe":
        nd = cfg.n_dense_layers
        nm = L - nd
        if nd:
            params["dense_blocks"] = {**_init_attn(keys[2], cfg, nd, dt),
                                      **_init_ffn(keys[3], cfg, nd, dt)}
        params["blocks"] = {**_init_attn(keys[4], cfg, nm, dt),
                            **_init_moe_ffn(keys[5], cfg, nm, dt)}
    elif fam == "ssm":
        params["blocks"] = _init_ssd(keys[2], cfg, L, dt)
    elif fam == "hybrid":
        p = {**_init_attn(keys[2], cfg, L, dt),
             **_init_ssd(keys[3], cfg, L, dt),
             **_init_ffn(keys[4], cfg, L, dt)}
        p["fuse_ln_a"] = jnp.zeros((L, d), dt)
        p["fuse_ln_s"] = jnp.zeros((L, d), dt)
        params["blocks"] = p
    elif fam == "encdec":
        Le = cfg.n_encoder_layers
        params["enc_blocks"] = {**_init_attn(keys[2], cfg, Le, dt),
                                **_init_ffn(keys[3], cfg, Le, dt)}
        dec = {**_init_attn(keys[4], cfg, L, dt),
               **_init_ffn(keys[5], cfg, L, dt)}
        # cross attention
        ks = jax.random.split(keys[6], 5)
        D = cfg.hd
        dec.update({
            "x_ln": jnp.zeros((L, d), dt),
            "x_wq": init_dense(ks[0], (L, d, cfg.n_heads * D), dt),
            "x_wk": init_dense(ks[1], (L, d, cfg.n_kv_heads * D), dt),
            "x_wv": init_dense(ks[2], (L, d, cfg.n_kv_heads * D), dt),
            "x_wo": init_dense(ks[3], (L, cfg.n_heads * D, d), dt),
        })
        params["blocks"] = dec
        params["enc_ln_f"] = jnp.zeros((d,), dt)
    else:
        raise ValueError(fam)
    if fam == "vlm":
        # stub anyres frontend: a single projection for precomputed patches
        params["patch_proj"] = init_dense(keys[7], (d, d), dt)
    return params


# ---------------------------------------------------------------------------
# forward (teacher-forced; used by train and prefill)
# ---------------------------------------------------------------------------


def _scan_blocks(cfg: ModelConfig, body, x, stacked, extra=None, length=None):
    """Apply ``body(carry_x, layer_params[, per_layer_extra])`` over layers.

    Default: ``lax.scan`` over stacked params (compact HLO).  With
    ``cfg.unroll_layers`` the layers run as a python loop so per-layer
    attributes (the attention window) are *static* — the prerequisite for
    the chunked sliding-window path (§Perf)."""
    if cfg.unroll_layers:
        import numpy as np

        ex = None if extra is None else [int(v) for v in np.asarray(extra)]
        b = body
        if cfg.remat:
            b = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable,
                static_argnums=(2,) if extra is not None else ())
        L = jax.tree.leaves(stacked)[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        for i in range(L):
            sl = jax.tree.map(lambda a: a[i], stacked)
            args = (sl,) if ex is None else (sl, ex[i])
            x, a = b(x, *args)
            aux = aux + a
        return x, aux[None]
    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (stacked, extra) if extra is not None else (stacked,)
    out, aux = jax.lax.scan(lambda c, s: body(c, *s), x, xs, length=length)
    return out, aux


def _dense_body(cfg: ModelConfig, positions):
    def body(x, p, window):
        a, _ = blocks.attn_block(cfg, p, x, positions, window=window)
        x = x + a
        x = x + blocks.ffn_block(cfg, p, x)
        x = constrain(x, "activation")
        return x, jnp.zeros((), jnp.float32)

    return body


def _moe_body(cfg: ModelConfig, positions, train: bool = False):
    # Training uses the capacity-dropped GShard dispatch (static shapes,
    # shardable einsums); evaluation routes droplessly so a token's expert
    # treatment never depends on which other tokens share its dispatch
    # group — the invariant that lets cached decode match forward().
    moe = blocks.moe_block if train else blocks.moe_block_dropless

    def body(x, p, window):
        a, _ = blocks.attn_block(cfg, p, x, positions, window=window)
        x = x + a
        m, aux = moe(cfg, p, x)
        x = x + m
        x = constrain(x, "activation")
        return x, aux

    return body


def _ssm_body(cfg: ModelConfig):
    def body(x, p):
        s, _ = blocks.ssd_block(cfg, p, x)
        x = x + s
        x = constrain(x, "activation")
        return x, jnp.zeros((), jnp.float32)

    return body


def _hybrid_body(cfg: ModelConfig, positions):
    def body(x, p, window):
        f, _ = blocks.hybrid_block(cfg, p, x, positions, window)
        x = x + f
        x = x + blocks.ffn_block(cfg, p, x)
        x = constrain(x, "activation")
        return x, jnp.zeros((), jnp.float32)

    return body


def embed_tokens(cfg: ModelConfig, params, tokens):
    emb = params["embed"][tokens]
    if cfg.name.startswith("gemma"):
        emb = emb * (cfg.d_model ** 0.5)
    return emb.astype(dtype_of(cfg.compute_dtype))


def unembed(cfg: ModelConfig, params, x):
    x = rms_norm(x, params["ln_f"], cfg.rms_eps)
    w = params.get("lm_head")
    if w is None:
        w = params["embed"].T
    logits = x @ w.astype(x.dtype)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.logit_softcap).astype(x.dtype)
    return logits


def forward(cfg: ModelConfig, params, tokens, *, patch_embeds=None,
            encoder_feats=None, return_hidden=False, train=False):
    """Teacher-forced forward pass -> hidden states [B, S, d] (pre-unembed).

    ``patch_embeds`` [B, P, d] (vlm): prepended to the token embeddings.
    ``encoder_feats`` [B, T, d] (encdec): precomputed frame embeddings fed
    through the encoder stack; the decoder cross-attends to the result.
    ``train`` selects the training-time MoE implementation (capacity-dropped
    GShard dispatch); the default is exact dropless evaluation, matching the
    cached serve path.
    """
    x = embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm" and patch_embeds is not None:
        pe = patch_embeds.astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = constrain(x, "activation")

    enc_out = None
    if cfg.family == "encdec":
        assert encoder_feats is not None
        enc = encoder_feats.astype(x.dtype)
        Be, Te, _ = enc.shape
        enc_pos = jnp.broadcast_to(jnp.arange(Te)[None, :], (Be, Te))

        def enc_body(h, p):
            a, _ = blocks.attn_block(cfg, p, h, enc_pos, causal=False)
            h = h + a
            h = h + blocks.ffn_block(cfg, p, h)
            return h, jnp.zeros((), jnp.float32)

        enc, _ = _scan_blocks(cfg, enc_body, enc, params["enc_blocks"])
        enc_out = rms_norm(enc, params["enc_ln_f"], cfg.rms_eps)

        def dec_body(h, p):
            a, _ = blocks.attn_block(cfg, p, h, positions)
            h = h + a
            h = h + _cross_attn(cfg, p, h, enc_out)
            h = h + blocks.ffn_block(cfg, p, h)
            return h, jnp.zeros((), jnp.float32)

        x, _ = _scan_blocks(cfg, dec_body, x, params["blocks"])
        aux = jnp.zeros((), jnp.float32)
    elif cfg.family == "ssm":
        x, auxs = _scan_blocks(cfg, _ssm_body(cfg), x, params["blocks"])
        aux = auxs.sum()
    else:
        windows = _layer_windows(
            cfg, cfg.n_layers - (cfg.n_dense_layers if cfg.family == "moe"
                                 else 0),
            offset=cfg.n_dense_layers if cfg.family == "moe" else 0)
        if cfg.family == "moe" and cfg.n_dense_layers:
            wd = _layer_windows(cfg, cfg.n_dense_layers)
            x, _ = _scan_blocks(cfg, _dense_body(cfg, positions), x,
                                params["dense_blocks"], extra=wd)
        if cfg.family == "moe":
            body_fn = _moe_body(cfg, positions, train=train)
        else:
            body_fn = {"dense": _dense_body, "vlm": _dense_body,
                       "hybrid": _hybrid_body}[cfg.family](cfg, positions)
        x, auxs = _scan_blocks(cfg, body_fn, x, params["blocks"],
                               extra=windows)
        aux = auxs.sum()
    if return_hidden:
        return x, aux
    return unembed(cfg, params, x), aux


def _cross_attn(cfg: ModelConfig, p, x, enc):
    from .layers import attention_ref

    B, S, d = x.shape
    Hq, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["x_ln"], cfg.rms_eps)
    q = (h @ p["x_wq"]).reshape(B, S, Hq, D)
    k = (enc @ p["x_wk"]).reshape(B, -1, Hkv, D)
    v = (enc @ p["x_wv"]).reshape(B, -1, Hkv, D)
    out = attention_ref(q, k, v, causal=False)
    return out.reshape(B, S, Hq * D) @ p["x_wo"]
