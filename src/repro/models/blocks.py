"""Per-family layer bodies (attention / FFN / MoE / SSD / hybrid).

All blocks operate on one layer's parameter slice (no leading L dim) so the
LM assembly can ``lax.scan`` over stacked layers.  Caches are pytrees with
the same convention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import attention, glu_ffn, rms_norm, rope

HUGE_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# attention block (dense/moe/vlm/encdec self-attention)
# ---------------------------------------------------------------------------


def attn_block(cfg: ModelConfig, p, x, positions, window=None, cache=None,
               cache_index=None, causal=True):
    """x: [B, S, d].  With ``cache`` (dict k/v [B, T, Hkv, D]) performs
    cached decode: writes new kv at ``cache_index`` and attends over the
    prefix.  Returns (out, new_cache)."""
    B, S, d = x.shape
    Hq, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, Hq, D)
    k = k.reshape(B, S, Hkv, D)
    v = v.reshape(B, S, Hkv, D)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        if "k_scale" in cache:
            # int8 KV cache: per-(token, head) abs-max quantization; the
            # cache stores 1 byte/elem + one f32 scale per (token, head)
            def q8(x):
                scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1
                                ) / 127.0
                scale = jnp.maximum(scale, 1e-8)
                xq = jnp.round(x.astype(jnp.float32) / scale[..., None]
                               ).astype(jnp.int8)
                return xq, scale

            kq, ks = q8(k)
            vq, vs = q8(v)
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], kq, cache_index, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vq, cache_index, axis=1)
            cks = jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks, cache_index, axis=1)
            cvs = jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs, cache_index, axis=1)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            kd = ck.astype(x.dtype) * cks[..., None].astype(x.dtype)
            vd = cv.astype(x.dtype) * cvs[..., None].astype(x.dtype)
        else:
            kd = ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
            vd = cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
            new_cache = {"k": ck, "v": cv}
        kv_len = jnp.full((B,), cache_index + S, dtype=jnp.int32)
        out = attention(cfg, q, kd, vd, causal=causal, window=window,
                        softcap=cfg.attn_softcap, kv_len=kv_len,
                        q_positions=positions)
    else:
        out = attention(cfg, q, k, v, causal=causal, window=window,
                        softcap=cfg.attn_softcap)
    out = out.reshape(B, S, Hq * D) @ p["wo"]
    if "post_ln" in p:  # gemma2 post-attention norm
        out = rms_norm(out, p["post_ln"], cfg.rms_eps)
    return out, new_cache


# ---------------------------------------------------------------------------
# FFN blocks
# ---------------------------------------------------------------------------


def ffn_block(cfg: ModelConfig, p, x):
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    if cfg.act == "gelu_mlp":
        out = jax.nn.gelu(h @ p["wi"], approximate=True) @ p["wo_ff"]
    else:
        out = glu_ffn(h, p["wi"], p["wo_ff"], cfg.act)
    if "post_ln2" in p:
        out = rms_norm(out, p["post_ln2"], cfg.rms_eps)
    return out


def _moe_route(cfg: ModelConfig, p, ht):
    """Shared router math: softmax over experts, top-k, gate renorm.

    ``ht``'s leading axes are arbitrary (grouped [n, g, d] for the capacity
    dispatch, flat [T, d] for dropless).  Keeping this single implementation
    is what guarantees the two MoE paths route identically — the invariant
    behind prefill/decode matching ``forward()``.
    """
    E, K = cfg.n_experts, cfg.top_k
    logits = ht.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # [..., K, E]
    return probs, gate_vals, onehot


def _moe_expert_weights(p, cdt):
    """Expert weights, fp8-dequantized when quantized.  The ``constrain``
    pins force the FSDP reshard on the *f8* tensor, then dequantize
    locally — otherwise XLA gathers post-dequant at 2 B/elem (§Perf)."""
    from repro.parallel.api import constrain

    we_i, we_o = p["we_i"], p["we_o"]
    if "we_i_scale" in p:
        we_i = constrain(we_i, "moe_expert_w8")
        we_i = we_i.astype(cdt) * p["we_i_scale"].astype(cdt)
    if "we_o_scale" in p:
        we_o = constrain(we_o, "moe_expert_w8")
        we_o = we_o.astype(cdt) * p["we_o_scale"].astype(cdt)
    return we_i, we_o


def _moe_aux_loss(cfg: ModelConfig, probs, onehot):
    """Switch-style load-balance loss over all token/k axes."""
    E = cfg.n_experts
    me = probs.reshape(-1, E).mean(axis=0)
    ce = onehot.reshape(-1, E).mean(axis=0)
    return E * jnp.sum(me * ce)


def moe_block(cfg: ModelConfig, p, x):
    """Token-choice top-k routing with per-group capacity (GShard-style
    einsum dispatch; static shapes).

    The dispatch mask is [groups, g, E, C] with C = g*K*cf/E, so its global
    footprint is tokens * K * cf * g / g = tokens-linear once sharded over
    (groups -> data, E -> model); ``constrain`` pins those shardings.
    """
    from repro.parallel.api import constrain

    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    cdt = x.dtype
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    T = B * S
    g = min(cfg.moe_group_size, T)
    n_groups = T // g
    ht = h.reshape(n_groups, g, d)
    C = max(1, int(g * K / E * cfg.capacity_factor))

    probs, gate_vals, onehot = _moe_route(cfg, p, ht)        # [n, g, K, E]
    # position of each (token, k) within its expert queue
    pos_in_expert = jnp.cumsum(onehot.reshape(n_groups, g * K, E), axis=1)
    pos_in_expert = pos_in_expert.reshape(n_groups, g, K, E) * onehot - 1.0
    slot = (pos_in_expert * onehot).sum(-1)                  # [n, g, K]
    keep = (slot >= 0) & (slot < C)
    slot = jnp.clip(slot, 0, C - 1).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(slot, C, dtype=jnp.float32) * keep[..., None]
    # dispatch[n, g, E, C] (bf16, sharded data x model)
    disp = jnp.einsum("ngke,ngkc->ngec", onehot, slot_oh).astype(cdt)
    disp = constrain(disp, "moe_dispatch")
    xe = jnp.einsum("ngd,ngec->necd", ht, disp)
    xe = constrain(xe, "moe_expert_in")
    # expert FFN: we_i [E, d, 2f], we_o [E, f, d]; fp8 expert gathers
    # dequantize post-gather inside _moe_expert_weights
    we_i, we_o = _moe_expert_weights(p, cdt)
    he = jnp.einsum("necd,edf->necf", xe, we_i)
    gate, up = jnp.split(he, 2, axis=-1)
    he = (jax.nn.silu(gate.astype(jnp.float32)).astype(cdt) * up)
    ye = jnp.einsum("necf,efd->necd", he, we_o)
    ye = constrain(ye, "moe_expert_in")
    # combine back with gate values
    comb = jnp.einsum("ngke,ngkc,ngk->ngec", onehot, slot_oh,
                      gate_vals).astype(cdt)
    comb = constrain(comb, "moe_dispatch")
    yt = jnp.einsum("necd,ngec->ngd", ye, comb)
    out = yt.reshape(B, S, d).astype(x.dtype)
    # shared experts (always-on)
    if cfg.n_shared_experts > 0:
        out = out + glu_ffn(h, p["ws_i"], p["ws_o"], "swiglu")
    return out, _moe_aux_loss(cfg, probs, onehot)


def moe_block_dropless(cfg: ModelConfig, p, x):
    """Per-token dropless top-k routing — the cached-inference MoE path.

    ``moe_block`` sizes its expert capacity from the *current batch group*
    (``C = g*K*cf/E``), so whether a token is dropped depends on which other
    tokens share its dispatch group.  That is fine for training, but cached
    decode runs the same layer on 1-token groups: capacity collapses to 1,
    colliding tokens get dropped, and decode logits diverge from the
    teacher-forced ``forward()`` (observed as ~0.65 max-logit error on
    deepseek-moe-16b smoke).  Here every token always reaches all K chosen
    experts — mathematically identical to ``moe_block`` whenever no token
    overflows capacity, and independent of batch composition, so
    prefill/decode match ``forward`` regardless of grouping.

    Computes all E experts densely and combines with routing weights
    (fine for the smoke/eval shapes this path serves; a production decode
    would gather the K expert slices instead).
    """
    B, S, d = x.shape
    cdt = x.dtype
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    ht = h.reshape(B * S, d)

    probs, gate_vals, onehot = _moe_route(cfg, p, ht)        # [T, K, E]
    weight = (onehot * gate_vals[..., None]).sum(1)          # [T, E]

    we_i, we_o = _moe_expert_weights(p, cdt)
    he = jnp.einsum("td,edf->tef", ht, we_i)
    gate, up = jnp.split(he, 2, axis=-1)
    he = jax.nn.silu(gate.astype(jnp.float32)).astype(cdt) * up
    ye = jnp.einsum("tef,efd->ted", he, we_o)                # [T, E, d]
    yt = jnp.einsum("ted,te->td", ye, weight.astype(cdt))
    out = yt.reshape(B, S, d).astype(x.dtype)
    if cfg.n_shared_experts > 0:
        out = out + glu_ffn(h, p["ws_i"], p["ws_o"], "swiglu")
    return out, _moe_aux_loss(cfg, probs, onehot)


# ---------------------------------------------------------------------------
# SSD (Mamba-2) block
# ---------------------------------------------------------------------------


def _causal_conv(x, w, state=None):
    """Depthwise causal conv along time.  x: [B, S, C]; w: [Kc, C].
    With ``state`` [B, Kc-1, C] performs streaming conv; returns new state."""
    Kc = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], Kc - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(Kc))
    new_state = xp[:, -(Kc - 1):, :] if Kc > 1 else None
    return out, new_state


def ssd_block(cfg: ModelConfig, p, x, cache=None):
    """Mamba-2 SSD mixer.  cache (decode): {"conv": [B,Kc-1,HP], "ssm":
    [B,H,P,N]}."""
    B, S, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    proj = h @ p["in_proj"]     # [B,S, HP + HP + N + N + H]
    zx, xin, Bm, Cm, dt = jnp.split(
        proj, [H * P, 2 * H * P, 2 * H * P + N, 2 * H * P + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                  # [H]
    new_cache = {}
    conv_state = cache.get("conv") if cache else None
    xin, new_conv = _causal_conv(xin, p["conv_w"], conv_state)
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)
    xh = xin.reshape(B, S, H, P)
    if cache is not None:
        # recurrent decode: S small (usually 1)
        hst = cache["ssm"].astype(jnp.float32)   # [B,H,P,N]

        def step(hst, t):
            decay = jnp.exp(A[None, :] * dt[:, t])          # [B,H]
            upd = (dt[:, t, :, None] * xh[:, t].astype(jnp.float32)
                   )[..., None] * Bm[:, t, None, None, :].astype(jnp.float32)
            hst = hst * decay[..., None, None] + upd
            y = jnp.einsum("bhpn,bn->bhp", hst, Cm[:, t].astype(jnp.float32))
            return hst, y

        hst, ys = jax.lax.scan(step, hst, jnp.arange(S))
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, H * P)
        new_cache = {"conv": new_conv, "ssm": hst}
    else:
        if cfg.use_kernels:
            from repro.kernels import ops

            y = ops.ssd_scan(xh, dt.transpose(0, 1, 2), A, Bm, Cm)
        elif cfg.ssd_chunk and S % cfg.ssd_chunk == 0 and S > cfg.ssd_chunk:
            from repro.kernels import ref

            # chunked dual form: L/chunk scan steps of dense matmuls
            # instead of L serial recurrences (§Perf cell 3)
            y = ref.ssd_scan_chunked_ref(xh, dt, A, Bm, Cm,
                                         chunk=cfg.ssd_chunk)
        else:
            from repro.kernels import ref

            y = ref.ssd_scan_ref(xh, dt, A, Bm, Cm)
        y = y.reshape(B, S, H * P)
        new_cache = None
    y = y + xh.reshape(B, S, H * P) * p["d_skip"].astype(x.dtype).repeat(P)
    y = y.astype(x.dtype) * jax.nn.silu(zx.astype(jnp.float32)).astype(x.dtype)
    out = rms_norm(y, p["out_ln"], cfg.rms_eps) @ p["out_proj"]
    return out, new_cache


# ---------------------------------------------------------------------------
# hybrid (Hymba): parallel attention + SSD heads
# ---------------------------------------------------------------------------


def hybrid_block(cfg: ModelConfig, p, x, positions, window, cache=None,
                 cache_index=None):
    attn_out, new_kv = attn_block(cfg, p, x, positions, window=window,
                                  cache=cache.get("kv") if cache else None,
                                  cache_index=cache_index)
    ssd_out, new_ssm = ssd_block(cfg, p, x,
                                 cache=cache.get("ssd") if cache else None)
    # Hymba: per-branch normalization then mean fusion
    fused = 0.5 * (rms_norm(attn_out, p["fuse_ln_a"], cfg.rms_eps)
                   + rms_norm(ssd_out, p["fuse_ln_s"], cfg.rms_eps))
    new_cache = None
    if cache is not None:
        new_cache = {"kv": new_kv, "ssd": new_ssm}
    return fused, new_cache
