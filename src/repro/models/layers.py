"""Model primitives (pure JAX, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def rms_norm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(ang)[..., :, None, :]   # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  scale=None, kv_len=None, q_positions=None):
    """Masked multi-head attention on [B, S, H, D] layout with GQA.

    ``kv_len``: optional [B] active cache lengths (decode).  ``q_positions``:
    optional [B, Sq] absolute positions of queries (decode).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    T = k.shape[1]
    if scale is None:
        scale = D ** -0.5
    qh = q.reshape(B, Sq, Hkv, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    if q_positions is None:
        qpos = jnp.arange(Sq)[None, :] + (T - Sq)
        qpos = jnp.broadcast_to(qpos, (B, Sq))
    else:
        qpos = q_positions
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((B, Sq, T), dtype=bool)
    if causal:
        mask = mask & (kpos[:, None, :] <= qpos[:, :, None])
    if window is not None:
        mask = mask & (kpos[:, None, :] > qpos[:, :, None] - window)
    if kv_len is not None:
        mask = mask & (kpos[:, None, :] < kv_len[:, None, None])
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def local_chunked_attention(q, k, v, window: int, *, softcap=None,
                            scale=None):
    """Exact sliding-window causal attention, computed block-locally.

    Scores are only formed for (query block, same + previous key block):
    O(S * 2w) instead of O(S^2) — flops and peak memory drop by S/(2w).
    Requires S % window == 0 (train/prefill path with static window).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    w = window
    nb = S // w
    if scale is None:
        scale = D ** -0.5
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    qb = q.reshape(B, nb, w, Hq, D)
    kb = kk.reshape(B, nb, w, Hq, D)
    vb = vv.reshape(B, nb, w, Hq, D)
    # previous block (zeros before block 0)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)       # [B, nb, 2w, Hq, D]
    v2 = jnp.concatenate([vprev, vb], axis=2)
    logits = jnp.einsum("bnqhd,bnkhd->bnhqk", qb.astype(jnp.float32),
                        k2.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(w)[:, None] + w                # within-pair position
    kpos = jnp.arange(2 * w)[None, :]
    blk = jnp.arange(nb)
    valid = (kpos <= qpos) & (kpos > qpos - w)
    # block 0 has no previous block
    first = (kpos >= w) & (kpos <= qpos) & (kpos > qpos - w)
    mask = jnp.where(blk[:, None, None] == 0, first[None], valid[None])
    logits = jnp.where(mask[None, :, None, :, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", p, v2.astype(jnp.float32))
    return out.reshape(B, S, Hq, D).astype(q.dtype)


def attention(cfg: ModelConfig, q, k, v, **kw):
    window = kw.get("window")
    if (cfg.chunked_local_attn and isinstance(window, int)
            and kw.get("kv_len") is None and q.shape[1] == k.shape[1]
            and window * 2 <= q.shape[1] and q.shape[1] % window == 0
            and kw.get("causal", True)):
        return local_chunked_attention(q, k, v, window,
                                       softcap=kw.get("softcap"),
                                       scale=kw.get("scale"))
    if cfg.use_kernels:
        from repro.kernels import ops

        # kernels use [B, H, S, D] layout
        out = ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=kw.get("causal", True),
            window=kw.get("window"), softcap=kw.get("softcap"),
            scale=kw.get("scale"))
        return out.transpose(0, 2, 1, 3)
    return attention_ref(q, k, v, **kw)


def glu_ffn(x, wi, wo, act: str):
    """wi: [d, 2F] fused gate+up; wo: [F, d]."""
    h = x @ wi
    gate, up = jnp.split(h, 2, axis=-1)
    if act == "swiglu":
        g = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    elif act == "geglu":
        g = jax.nn.gelu(gate.astype(jnp.float32), approximate=True
                        ).astype(x.dtype)
    else:
        raise ValueError(act)
    return (g * up) @ wo


def init_dense(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale
            ).astype(dtype)
