"""Batched serving driver: prefill a batch of prompts, decode greedily."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.lm import init_params
from repro.serve.decode import decode_step, prefill
from repro.serve.kvcache import init_cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab, size=(B, S)), dtype=jnp.int32)
    enc = None
    if cfg.family == "encdec":
        enc = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.02)
    patches = None
    if cfg.family == "vlm":
        patches = jnp.asarray(rng.standard_normal(
            (B, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02)
    extra = (patches.shape[1] if patches is not None else 0)
    total = S + extra + args.max_new
    cache = init_cache(cfg, B, total,
                       encoder_len=enc.shape[1] if enc is not None else None)

    pf = jax.jit(lambda p, c, t: prefill(cfg, p, c, t, encoder_feats=enc,
                                         patch_embeds=patches))
    dc = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))

    t0 = time.perf_counter()
    logits, cache = pf(params, cache, prompts)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    out = [tok]
    pos = S + extra
    for i in range(args.max_new - 1):
        logits, cache = dc(params, cache, tok, pos + i)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    dt = time.perf_counter() - t0
    print(f"decoded {B}x{args.max_new} tokens in {dt:.2f}s "
          f"({B*args.max_new/dt:.1f} tok/s)")
    print("first row:", np.asarray(toks[0]))
    return toks


if __name__ == "__main__":
    main()
