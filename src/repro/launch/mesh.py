"""Production mesh builders (functions, never module-level constants — the
dry-run must set XLA_FLAGS before anything touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever devices exist right now (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
