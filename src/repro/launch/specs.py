"""ShapeDtypeStruct stand-ins for every model input — shardable, weak-type
correct, zero device allocation (the shannon/kernels dry-run pattern)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.layers import dtype_of


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Batch structs for a train/prefill step."""
    B, S = shape.global_batch, shape.seq_len
    out = {
        "tokens": sds((B, S), jnp.int32),
        "labels": sds((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        out["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model),
                                  dtype_of(cfg.compute_dtype))
    if cfg.family == "encdec":
        out["encoder_feats"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                   dtype_of(cfg.compute_dtype))
    if shape.kind in ("prefill", "decode"):
        out.pop("labels")
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Structs for one serve_step: single new token + a filled cache."""
    from repro.serve.kvcache import init_cache

    B, S = shape.global_batch, shape.seq_len
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, B, S,
                           encoder_len=cfg.encoder_seq or None))
    return {
        "tokens": sds((B, 1), jnp.int32),
        "pos": sds((), jnp.int32),
        "cache": cache_shape,
    }


def params_shape(cfg: ModelConfig):
    from repro.models.lm import init_params

    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def count_params(shapes) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
