"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces ``experiments/dryrun/<arch>__<shape>__<mesh>.json``
containing compile success, ``memory_analysis`` / ``cost_analysis`` numbers,
and a collective-traffic breakdown parsed from the partitioned HLO — the
inputs to the §Roofline analysis.

The two ``os.environ`` lines below MUST stay the first statements: jax locks
the device count on first initialization (before ANY repro/jax import).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig, get_config,
                                list_configs, shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (count_params, decode_specs, input_specs,
                                params_shape)
from repro.parallel.api import sharding_rules
from repro.parallel.sharding import (activation_rules, batch_specs,
                                     cache_specs, named, opt_specs,
                                     param_specs)
from repro.serve.decode import decode_step, prefill
from repro.train.optimizer import OptConfig
from repro.train.step import TrainConfig, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective traffic by op kind (result-shape bytes)."""
    stats: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "fusion" in line[:40]:
            continue
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(
            m.group(0))[0]
        b = _shape_bytes(lhs)
        s = stats.setdefault(kind, {"count": 0, "bytes": 0})
        s["count"] += 1
        s["bytes"] += b
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


def _active_params(cfg: ModelConfig, pshape) -> int:
    """6*N*D convention: activated parameters only (MoE discount)."""
    total = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(pshape)
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        n = int(np.prod(leaf.shape))
        if cfg.is_moe and ("we_i" in key or "we_o" in key):
            n = int(n * cfg.top_k / cfg.n_experts)
        total += n
    return total


def _opt_config(cfg: ModelConfig) -> OptConfig:
    # AdamW states for a 1T-param model cannot fit 512 v5e chips; kimi uses
    # factored second moments (see EXPERIMENTS.md §Dry-run)
    if cfg.name.startswith("kimi"):
        return OptConfig(name="adafactor")
    return OptConfig(name="adamw")


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg: ModelConfig | None = None, tcfg: TrainConfig | None = None):
    if cfg is None:
        cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    pshape = params_shape(cfg)
    pspecs = param_specs(cfg, mesh, pshape)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    n_params = count_params(pshape)
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": mesh.size,
        "n_params": n_params,
        "n_active_params": _active_params(cfg, pshape),
    }
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            if tcfg is None:
                tcfg = TrainConfig(opt=_opt_config(cfg))
            step_fn, opt_init = make_train_step(cfg, tcfg)
            oshape = jax.eval_shape(opt_init, pshape)
            ospecs = opt_specs(cfg, mesh, pspecs, oshape)
            batch = input_specs(cfg, shape)
            bspecs = batch_specs(cfg, mesh, batch)
            T = shape.global_batch * shape.seq_len
            g = min(cfg.moe_group_size, T)
            rules = activation_rules(cfg, mesh, n_moe_groups=T // g)
            with sharding_rules(rules):
                jitted = jax.jit(step_fn,
                                 in_shardings=(ns(pspecs), ns(ospecs),
                                               ns(bspecs)),
                                 out_shardings=(ns(pspecs), ns(ospecs), None),
                                 donate_argnums=(0, 1))
                lowered = jitted.lower(pshape, oshape, batch)
            record["model_flops"] = 6 * record["n_active_params"] * T
        elif shape.kind == "prefill":
            batch = input_specs(cfg, shape)
            bspecs = batch_specs(cfg, mesh, batch)
            from repro.serve.kvcache import init_cache

            extra_len = cfg.n_patches if cfg.family == "vlm" else 0
            cshape = jax.eval_shape(
                lambda: init_cache(cfg, shape.global_batch,
                                   shape.seq_len + extra_len,
                                   encoder_len=cfg.encoder_seq or None))
            cspecs = cache_specs(cfg, mesh, cshape)
            T = shape.global_batch * shape.seq_len
            g = min(cfg.moe_group_size, T)
            rules = activation_rules(cfg, mesh, n_moe_groups=T // g)

            def prefill_fn(params, cache, tokens, extras):
                return prefill(cfg, params, cache, tokens, **extras)

            extras = {}
            espec = {}
            if cfg.family == "encdec":
                extras["encoder_feats"] = batch.pop("encoder_feats")
                espec["encoder_feats"] = P(("pod", "data") if multi_pod
                                           else "data", None, None)
            if cfg.family == "vlm":
                extras["patch_embeds"] = batch.pop("patch_embeds")
                espec["patch_embeds"] = P(("pod", "data") if multi_pod
                                          else "data", None, None)
            tokens = batch["tokens"]
            with sharding_rules(rules):
                jitted = jax.jit(
                    prefill_fn,
                    in_shardings=(ns(pspecs), ns(cspecs),
                                  ns(batch_specs(cfg, mesh,
                                                 {"t": tokens})["t"]),
                                  ns(espec)),
                    donate_argnums=(1,))
                lowered = jitted.lower(pshape, cshape, tokens, extras)
            record["model_flops"] = 2 * record["n_active_params"] * T
        else:  # decode
            dspec = decode_specs(cfg, shape)
            cshape = dspec["cache"]
            cspecs = cache_specs(cfg, mesh, cshape)
            B = shape.global_batch
            g = min(cfg.moe_group_size, B)
            rules = activation_rules(cfg, mesh, n_moe_groups=B // g)

            def decode_fn(params, cache, tokens, pos):
                return decode_step(cfg, params, cache, tokens, pos)

            tok_spec = batch_specs(cfg, mesh, {"t": dspec["tokens"]})["t"]
            with sharding_rules(rules):
                jitted = jax.jit(
                    decode_fn,
                    in_shardings=(ns(pspecs), ns(cspecs), ns(tok_spec), None),
                    donate_argnums=(1,))
                lowered = jitted.lower(pshape, cshape, dspec["tokens"],
                                       dspec["pos"])
            record["model_flops"] = 2 * record["n_active_params"] * B
    record["lower_s"] = round(time.time() - t0, 2)
    return record, lowered


def compile_cell(record: dict, lowered) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t0, 2)
    try:
        mem = compiled.memory_analysis()
        record["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover
        record["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        record["cost"] = {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float)) and (
                              k in ("flops", "transcendentals")
                              or k.startswith("bytes accessed"))}
    except Exception as e:  # pragma: no cover
        record["cost"] = {"error": str(e)}
    try:
        record["collectives"] = collective_stats(compiled.as_text())
    except Exception:
        record["collectives"] = collective_stats(lowered.as_text())
    record["status"] = "ok"
    return record


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False) -> dict:
    mesh_tag = "multi" if multi_pod else "single"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    try:
        out = lower_cell(arch, shape_name, multi_pod)
        if isinstance(out, dict):   # skipped
            record = out
        else:
            record, lowered = out
            record = compile_cell(record, lowered)
    except Exception as e:
        record = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                  "status": "error", "error": f"{type(e).__name__}: {e}"}
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = list_configs() if args.all or not args.arch else [args.arch]
    archs = [a for a in archs if a != "kratos-dd"]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                r = run_cell(arch, shape, mp, args.out, force=args.force)
                status = r.get("status")
                extra = ""
                if status == "ok":
                    mem = r.get("memory", {})
                    per_dev = (mem.get("argument_size_in_bytes", 0)
                               + mem.get("temp_size_in_bytes", 0))
                    extra = (f"flops={r.get('cost', {}).get('flops', 0):.3g} "
                             f"mem/dev={per_dev/2**30:.2f}GiB "
                             f"coll={r.get('collectives', {}).get('total_bytes', 0)/2**20:.1f}MiB "
                             f"compile={r.get('compile_s')}s")
                elif status == "error":
                    extra = r.get("error", "")[:160]
                else:
                    extra = r.get("reason", "")
                print(f"[{r['arch']:18s} {r['shape']:12s} "
                      f"{r['mesh']:6s}] {status}: {extra}", flush=True)


if __name__ == "__main__":
    main()
