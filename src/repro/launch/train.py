"""End-to-end training driver.

Single-host entry point: builds the mesh over whatever devices exist,
shards params/optimizer with the production rules, and runs the
fault-tolerant loop.  ``--arch <id> --smoke`` trains the reduced config on
CPU; on a real pod the same flags train the full config.
"""
from __future__ import annotations

import argparse
import logging

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.data.pipeline import batch_for_step, to_device
from repro.launch.mesh import make_host_mesh
from repro.models.lm import init_params
from repro.parallel.api import sharding_rules
from repro.parallel.sharding import (activation_rules, batch_specs,
                                     opt_specs, param_specs)
from repro.train.loop import FitConfig, fit
from repro.train.optimizer import OptConfig
from repro.train.step import TrainConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh(args.model_parallel)
    params = init_params(jax.random.key(0), cfg)
    pspecs = param_specs(cfg, mesh, jax.eval_shape(lambda: params))
    params = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params,
        pspecs, is_leaf=lambda x: isinstance(x, jax.Array))

    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=5,
                      decay_steps=max(args.steps, 10)),
        grad_accum=args.grad_accum)
    fitc = FitConfig(steps=args.steps, seq_len=args.seq_len,
                     global_batch=args.batch, ckpt_dir=args.ckpt_dir)
    with mesh, sharding_rules(activation_rules(cfg, mesh)):
        result = fit(cfg, params, fitc, tcfg,
                     hooks=[lambda s, m: print(
                         f"step {s:5d} loss {float(m['loss']):.4f} "
                         f"gnorm {float(m['grad_norm']):.3f}", flush=True)
                         if s % 10 == 0 else None])
    print(f"final loss: {result['losses'][-1]:.4f} "
          f"(from {result['losses'][0]:.4f})")
    return result


if __name__ == "__main__":
    main()
