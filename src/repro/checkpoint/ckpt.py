"""Checkpointing: sharded-logical save/restore with atomic commits.

Layout::

    <dir>/step_<N>/
        manifest.json      # step, leaf paths, shapes, dtypes, mesh note
        arrays.npz         # one entry per flattened pytree leaf

Leaves are gathered to host (single-process container) and written via a
``tmp+rename`` commit so a crash mid-write never corrupts the latest
checkpoint.  ``restore`` rebuilds the pytree and ``jax.device_put``s each
leaf with the *target* sharding — so a checkpoint taken on one mesh restores
onto any other mesh (elastic re-scale) as long as logical shapes match.
Multi-host note: on a real pod each process writes its addressable shards
under ``arrays.<proc>.npz``; the manifest format already carries everything
needed to reassemble (kept single-file here because this container is
single-process).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import numpy as np

import jax


SEP = "/"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(directory: str, step: int, tree, keep_last: int = 3,
         extra: dict | None = None) -> str:
    flat, _ = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        def to_np(v):
            a = np.asarray(v)
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                a = a.astype(np.float32)  # lossless upcast for npz
            return a

        arrays = {k: to_np(v) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                       for k, a in arrays.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep_last)
    return final


def _gc(directory: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    if not steps:
        return None
    return int(steps[-1].split("_")[1])


def restore(directory: str, template, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree of Sharding matching template — leaves are
    placed directly onto the (possibly different) target mesh.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    flat_t, treedef = _flatten(template)
    shard_flat = None
    if shardings is not None:
        shard_flat, _ = _flatten(shardings)
    leaves = []
    for key, tmpl in flat_t.items():
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        a = arrays[key]
        if list(a.shape) != list(tmpl.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{a.shape} vs {tmpl.shape}")
        a = jax.numpy.asarray(a).astype(tmpl.dtype)
        if shard_flat is not None:
            leaves.append(jax.device_put(a, shard_flat[key]))
        else:
            leaves.append(a)
    # rebuild in treedef order
    ordered = jax.tree_util.tree_unflatten(
        treedef, [leaves[list(flat_t).index(k)] for k in flat_t])
    return ordered, step
