"""Double-Duty bitplane quantization: the paper's unrolled constant-weight
multiplication as a TPU feature.

``quantize_bitplanes`` decomposes a weight matrix into b binary planes +
per-column scale (two's-complement, top plane weighted -2^(b-1)) — exactly
the selector-bit decomposition of §IV, with the compressor-tree reduction
replaced by the MXU+VPU double-duty kernel
(:mod:`repro.kernels.bitplane_matmul`).

``sparsity()`` reports the fraction of zero selector bits — the quantity the
paper's row-skip optimization exploits; on TPU it predicts achievable
skipping when planes are all-zero (plane-level sparsity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_bitplanes(w: jax.Array, bits: int = 4):
    """w [K, N] float -> (planes [bits, K, N] in {0,1}, scale [N])."""
    maxq = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.abs(w).max(axis=0), 1e-8) / maxq
    q = jnp.clip(jnp.round(w / scale[None, :]), -(maxq + 1), maxq)
    q_uint = (q.astype(jnp.int32) % (1 << bits)).astype(jnp.uint32)
    planes = jnp.stack([(q_uint >> b) & 1 for b in range(bits)]
                       ).astype(jnp.float32)
    return planes, scale.astype(jnp.float32)


def dequantize(planes: jax.Array, scale: jax.Array) -> jax.Array:
    B = planes.shape[0]
    w = jnp.zeros(planes.shape[1:], jnp.float32)
    for b in range(B):
        coeff = -(2.0 ** (B - 1)) if b == B - 1 else 2.0 ** b
        w = w + coeff * planes[b]
    return w * scale[None, :]


def bitplane_linear(x: jax.Array, planes: jax.Array, scale: jax.Array,
                    use_pallas: bool = True) -> jax.Array:
    """y = x @ W_quant via the double-duty kernel."""
    from repro.kernels import ops

    shp = x.shape
    x2 = x.reshape(-1, shp[-1]).astype(jnp.float32)
    y = ops.bitplane_matmul(x2, planes, scale, use_pallas=use_pallas)
    return y.reshape(shp[:-1] + (planes.shape[-1],))


def plane_sparsity(planes: jax.Array) -> jax.Array:
    """Fraction of zero selector bits (the paper's row-skip opportunity)."""
    return 1.0 - planes.mean()


def quantize_tree(params, bits: int = 4, min_size: int = 1 << 16):
    """Quantize every large 2-D weight in a pytree; returns
    (quantized pytree of {"planes","scale"}, skeleton with passthroughs)."""
    def q(p):
        if p.ndim == 2 and p.size >= min_size:
            planes, scale = quantize_bitplanes(p.astype(jnp.float32), bits)
            return {"planes": planes, "scale": scale}
        return p

    return jax.tree.map(q, params)
