"""KV / SSM cache containers for cached decode."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dtype_of


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               encoder_len: int | None = None) -> dict:
    """Allocate the decode cache pytree (leading L axis, scan-friendly)."""
    dt = dtype_of(cfg.compute_dtype)
    kv_int8 = cfg.kv_cache_dtype == "int8"
    kdt = jnp.int8 if kv_int8 else dt
    L, Hkv, D = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Kc = cfg.conv_kernel
    cache: dict = {}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec", "hybrid"):
        cache["k"] = jnp.zeros((L, batch, max_len, Hkv, D), kdt)
        cache["v"] = jnp.zeros((L, batch, max_len, Hkv, D), kdt)
        if kv_int8:
            # per (token, head) abs-max scales
            cache["k_scale"] = jnp.zeros((L, batch, max_len, Hkv),
                                         jnp.float32)
            cache["v_scale"] = jnp.zeros((L, batch, max_len, Hkv),
                                         jnp.float32)
    if fam in ("ssm", "hybrid"):
        cache["conv"] = jnp.zeros((L, batch, Kc - 1, H * P), dt)
        cache["ssm"] = jnp.zeros((L, batch, H, P, N), jnp.float32)
    if fam == "encdec":
        Te = encoder_len or cfg.encoder_seq
        cache["xk"] = jnp.zeros((L, batch, Te, Hkv, D), dt)
        cache["xv"] = jnp.zeros((L, batch, Te, Hkv, D), dt)
    return cache
