"""Prefill / decode steps (cached autoregressive inference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.lm import (_layer_windows, embed_tokens, unembed)
from repro.models.layers import rms_norm
from repro.parallel.api import constrain


def _split_cache(cache: dict, nd: int):
    head = {k: v[:nd] for k, v in cache.items()}
    tail = {k: v[nd:] for k, v in cache.items()}
    return head, tail


def _merge_cache(head: dict, tail: dict):
    return {k: jnp.concatenate([head[k], tail[k]], axis=0) for k in tail}


def _layer_step(cfg: ModelConfig, x, p, cache_l, window, positions,
                cache_index, enc_out=None):
    """One decoder layer with cache; returns (x, new_cache_l)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec"):
        kv = {key: cache_l[key] for key in
              ("k", "v", "k_scale", "v_scale") if key in cache_l}
        a, new_kv = blocks.attn_block(cfg, p, x, positions, window=window,
                                      cache=kv, cache_index=cache_index)
        x = x + a
        new_cache = dict(cache_l)
        new_cache.update(new_kv)
        if fam == "encdec":
            x = x + _cross_attn_cached(cfg, p, x, cache_l)
        if fam == "moe" and "router" in p:
            # dropless routing: the capacity-dropped moe_block makes keep
            # decisions group-relative, so a cached decode step (1-token
            # groups) would drop tokens forward() kept — see
            # blocks.moe_block_dropless
            m, _ = blocks.moe_block_dropless(cfg, p, x)
            x = x + m
        else:
            x = x + blocks.ffn_block(cfg, p, x)
        return x, new_cache
    if fam == "ssm":
        s, new_ssd = blocks.ssd_block(cfg, p, x, cache=cache_l)
        x = x + s
        return x, new_ssd
    if fam == "hybrid":
        kv = {key: cache_l[key] for key in
              ("k", "v", "k_scale", "v_scale") if key in cache_l}
        c = {"kv": kv,
             "ssd": {"conv": cache_l["conv"], "ssm": cache_l["ssm"]}}
        f, nc = blocks.hybrid_block(cfg, p, x, positions, window,
                                    cache=c, cache_index=cache_index)
        x = x + f
        x = x + blocks.ffn_block(cfg, p, x)
        out_cache = dict(nc["kv"])
        out_cache.update({"conv": nc["ssd"]["conv"], "ssm": nc["ssd"]["ssm"]})
        return x, out_cache
    raise ValueError(fam)


def _cross_attn_cached(cfg: ModelConfig, p, x, cache_l):
    from repro.models.layers import attention_ref

    B, S, d = x.shape
    Hq, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["x_ln"], cfg.rms_eps)
    q = (h @ p["x_wq"]).reshape(B, S, Hq, D)
    out = attention_ref(q, cache_l["xk"], cache_l["xv"], causal=False)
    return out.reshape(B, S, Hq * D) @ p["x_wo"]


def _run_layers(cfg: ModelConfig, params, cache, x, positions, cache_index):
    nd = cfg.n_dense_layers if cfg.family == "moe" else 0
    windows_moe = _layer_windows(cfg, cfg.n_layers - nd, offset=nd)

    def mk_body(moe: bool):
        def body(carry, sl):
            p, cache_l, window = sl
            return _layer_step(cfg, carry, p, cache_l, window, positions,
                               cache_index)

        if cfg.remat:
            return jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        return body

    if nd:
        cache_d, cache_m = _split_cache(cache, nd)
        wd = _layer_windows(cfg, nd)
        x, new_d = jax.lax.scan(mk_body(False), x,
                                (params["dense_blocks"], cache_d, wd))
        x, new_m = jax.lax.scan(mk_body(True), x,
                                (params["blocks"], cache_m, windows_moe))
        new_cache = _merge_cache(new_d, new_m)
    else:
        x, new_cache = jax.lax.scan(mk_body(cfg.family == "moe"), x,
                                    (params["blocks"], cache, windows_moe))
    return x, new_cache


def prefill(cfg: ModelConfig, params, cache, tokens, *, encoder_feats=None,
            patch_embeds=None):
    """Fill the cache from a prompt; returns (logits_last, cache)."""
    x = embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm" and patch_embeds is not None:
        pe = patch_embeds.astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = constrain(x, "activation")
    if cfg.family == "encdec":
        cache = _encode_to_cache(cfg, params, cache, encoder_feats)
    x, cache = _run_layers(cfg, params, cache, x, positions, 0)
    logits = unembed(cfg, params, x[:, -1:, :])
    return logits, cache


def _encode_to_cache(cfg: ModelConfig, params, cache, encoder_feats):
    from repro.models.lm import forward

    enc = encoder_feats
    # run encoder stack (reuse forward's encoder path via hidden call)
    from repro.models import lm as _lm

    dtc = enc.dtype
    Be, Te, _ = enc.shape
    enc_pos = jnp.broadcast_to(jnp.arange(Te)[None, :], (Be, Te))

    def enc_body(h, p):
        a, _ = blocks.attn_block(cfg, p, h, enc_pos, causal=False)
        h = h + a
        h = h + blocks.ffn_block(cfg, p, h)
        return h, jnp.zeros((), jnp.float32)

    enc_h, _ = jax.lax.scan(lambda c, p: enc_body(c, p), enc,
                            params["enc_blocks"])
    enc_h = rms_norm(enc_h, params["enc_ln_f"], cfg.rms_eps)
    Hkv, D = cfg.n_kv_heads, cfg.hd

    def xkv(p):
        xk = (enc_h @ p["x_wk"]).reshape(Be, Te, Hkv, D)
        xv = (enc_h @ p["x_wv"]).reshape(Be, Te, Hkv, D)
        return xk.astype(cache["xk"].dtype), xv.astype(cache["xv"].dtype)

    xks, xvs = jax.vmap(xkv)(
        {"x_wk": params["blocks"]["x_wk"], "x_wv": params["blocks"]["x_wv"]})
    cache = dict(cache)
    cache["xk"] = xks
    cache["xv"] = xvs
    return cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step.  tokens [B, 1]; pos: scalar int32 (cache fill).
    Returns (logits [B, 1, V], new_cache)."""
    x = embed_tokens(cfg, params, tokens)
    B = x.shape[0]
    positions = jnp.broadcast_to(jnp.asarray(pos)[None, None], (B, 1))
    x = constrain(x, "activation")
    x, cache = _run_layers(cfg, params, cache, x, positions, pos)
    return unembed(cfg, params, x), cache


def greedy_generate(cfg: ModelConfig, params, prompt, max_new: int,
                    max_len: int | None = None, encoder_feats=None,
                    patch_embeds=None):
    """Simple greedy loop (example/testing path)."""
    from .kvcache import init_cache

    B, S = prompt.shape
    extra = patch_embeds.shape[1] if patch_embeds is not None else 0
    total = (max_len or (S + extra + max_new))
    cache = init_cache(cfg, B, total,
                       encoder_len=(encoder_feats.shape[1]
                                    if encoder_feats is not None else None))
    logits, cache = prefill(cfg, params, cache, prompt,
                            encoder_feats=encoder_feats,
                            patch_embeds=patch_embeds)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    out = [tok]
    pos = S + extra
    for i in range(max_new - 1):
        logits, cache = decode_step(cfg, params, cache, tok, pos + i)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
