"""Optimizers (pure pytree transforms — no external deps).

* ``adamw`` — fp32 moments + decoupled weight decay + global-norm clipping.
* ``adafactor`` — factored second moments (rank-1 row/col statistics) for
  trillion-parameter configs where AdamW's optimizer state cannot fit the
  mesh (kimi-k2 on 512 chips; see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"               # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptConfig, grads, state, params):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                      state["nu"], grads)
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(p, m, v):
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}, gnorm


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; beta1=0 variant)
# ---------------------------------------------------------------------------


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def init_one(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(init_one, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, grads, state, params):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)
    decay = 1.0 - (count.astype(jnp.float32) + 1.0) ** -0.8

    def upd(p, g, v):
        g2 = g * g + 1e-30
        if _factored(p.shape):
            vr = decay * v["vr"] + (1 - decay) * g2.mean(axis=-1)
            vc = decay * v["vc"] + (1 - decay) * g2.mean(axis=-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None],
                                   1e-30))
            step = g / (jnp.sqrt(denom) + cfg.eps)
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": decay * v["v"] + (1 - decay) * g2}
            step = g / (jnp.sqrt(nv["v"]) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), nv

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_v = tdef.flatten_up_to(state["v"])
    outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    new_params = tdef.unflatten([o[0] for o in outs])
    new_v = tdef.unflatten([o[1] for o in outs])
    return new_params, {"v": new_v, "count": count}, gnorm


def make_optimizer(cfg: OptConfig):
    if cfg.name == "adamw":
        return adamw_init, functools.partial(adamw_update, cfg)
    if cfg.name == "adafactor":
        return adafactor_init, functools.partial(adafactor_update, cfg)
    raise ValueError(cfg.name)
