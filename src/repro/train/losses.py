"""Losses: sequence-chunked cross entropy (keeps the [B,S,V] logits tensor
from ever materializing for 150k-256k vocabularies)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import unembed

PAD_ID = 0


def chunked_xent(cfg: ModelConfig, params, hidden, labels,
                 mask=None) -> jax.Array:
    """hidden [B, S, d] -> mean CE against labels [B, S] in seq chunks."""
    B, S, _ = hidden.shape
    chunk = min(cfg.loss_chunk, S)
    n = S // chunk
    if mask is None:
        mask = (labels != PAD_ID).astype(jnp.float32)

    hs = hidden[:, :n * chunk].reshape(B, n, chunk, -1).swapaxes(0, 1)
    ls = labels[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, sl):
        h, l, m = sl
        logits = unembed(cfg, params, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        loss = (lse - gold) * m
        return (carry[0] + loss.sum(), carry[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
