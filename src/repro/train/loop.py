"""Fault-tolerant training loop.

Production behaviours, exercised at laptop scale by the integration tests:

* periodic atomic checkpointing (params + optimizer + step),
* automatic restart-from-latest on entry (crash -> relaunch -> resume),
* non-finite-loss quarantine: restore last good checkpoint, skip the
  offending data window, continue (classic bad-batch recovery),
* straggler watch: per-step wall-time EMA; steps slower than
  ``straggler_factor`` x EMA are logged (on a real pod this feeds the
  coordinator's replace-node decision),
* deterministic data: the pipeline is a pure function of step, so recovery
  replays or skips exactly.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import batch_for_step, to_device
from repro.train.step import TrainConfig, make_train_step

log = logging.getLogger("repro.train")


@dataclass
class FitConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    straggler_factor: float = 3.0
    max_bad_restarts: int = 3


def fit(cfg: ModelConfig, params, fitc: FitConfig,
        tcfg: TrainConfig | None = None, hooks=None) -> dict:
    tcfg = tcfg or TrainConfig()
    train_step, opt_init = make_train_step(cfg, tcfg)
    train_step = jax.jit(train_step, donate_argnums=(0, 1))
    opt_state = opt_init(params)

    start = 0
    resumed = ckpt.latest_step(fitc.ckpt_dir)
    if resumed is not None:
        (params, opt_state), start = ckpt.restore(
            fitc.ckpt_dir, (params, opt_state))
        log.info("resumed from step %d", start)

    ema = None
    bad_restarts = 0
    losses = []
    step = start
    while step < fitc.steps:
        t0 = time.perf_counter()
        batch = to_device(batch_for_step(cfg, fitc.seq_len, fitc.global_batch,
                                         step, seed=fitc.seed))
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        if not np.isfinite(loss):
            bad_restarts += 1
            log.warning("non-finite loss at step %d (restart %d)", step,
                        bad_restarts)
            if bad_restarts > fitc.max_bad_restarts:
                raise RuntimeError("too many non-finite-loss restarts")
            if ckpt.latest_step(fitc.ckpt_dir) is not None:
                (params, opt_state), good = ckpt.restore(
                    fitc.ckpt_dir, (params, opt_state))
                step = good + 1  # skip the bad window
                continue
            step += 1
            continue
        losses.append(loss)
        ema = dt if ema is None else 0.9 * ema + 0.1 * dt
        if dt > fitc.straggler_factor * ema:
            log.warning("straggler step %d: %.3fs vs ema %.3fs", step, dt,
                        ema)
        if hooks:
            for h in hooks:
                h(step, metrics)
        step += 1
        if step % fitc.ckpt_every == 0 or step == fitc.steps:
            ckpt.save(fitc.ckpt_dir, step, (params, opt_state),
                      keep_last=fitc.keep_last)
    return {"params": params, "opt_state": opt_state,
            "losses": losses, "final_step": step}
