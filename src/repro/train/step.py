"""train_step factory: loss -> grad -> (accumulated) -> optimizer update.

Features: sequence-chunked CE, microbatch gradient accumulation (scan),
optional int8 gradient compression between accumulation steps (models
bandwidth-compressed gradient reduction), MoE aux-loss folding, donated
state.  The returned function is pjit-ready: all inputs/outputs are pytrees
of arrays.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import forward
from repro.train.losses import chunked_xent
from repro.train.optimizer import OptConfig, make_optimizer


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    grad_accum: int = 1
    aux_loss_weight: float = 0.01
    grad_compress: str | None = None   # None | "int8" | "bf16"
    fp8_expert_gather: bool = False    # §Perf: fp8 FSDP gathers for experts


def _fp8_expert_params(params):
    """Re-express MoE expert weights as f8e4m3 + per-out-channel scale.

    The f8 tensors inherit the original FSDP sharding, so the per-layer
    all-gather inside the scan moves 1 byte/elem instead of 2; dequant
    happens post-gather inside :func:`moe_block`.  The f32->f8 cast is
    linear for AD, so gradients flow to the master weights unchanged
    (standard fp8-FSDP training semantics)."""
    if "blocks" not in params or "we_i" not in params["blocks"]:
        return params
    out = dict(params)
    b = dict(params["blocks"])
    F8_MAX = 448.0
    for name in ("we_i", "we_o"):
        w = b[name]
        scale = (jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2,
                         keepdims=True) / F8_MAX + 1e-12)
        w8 = (w.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
        b[name] = w8
        b[name + "_scale"] = scale.astype(jnp.float32)
    out["blocks"] = b
    return out


def _compress(grads, how: str | None):
    if how is None:
        return grads
    if how == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(
            jnp.float32), grads)
    if how == "int8":
        def q(g):
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
            qg = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            return qg.astype(jnp.float32) * scale

        return jax.tree.map(q, grads)
    raise ValueError(how)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig):
    def loss_fn(params, batch):
        if tcfg.fp8_expert_gather:
            params = _fp8_expert_params(params)
        kw = {}
        if cfg.family == "vlm":
            kw["patch_embeds"] = batch["patch_embeds"]
        if cfg.family == "encdec":
            kw["encoder_feats"] = batch["encoder_feats"]
        hidden, aux = forward(cfg, params, batch["tokens"],
                              return_hidden=True, train=True, **kw)
        labels = batch["labels"]
        if cfg.family == "vlm":
            # patch positions carry no next-token loss
            P = batch["patch_embeds"].shape[1]
            pad = jnp.zeros((labels.shape[0], P), labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        loss = chunked_xent(cfg, params, hidden, labels)
        return loss + tcfg.aux_loss_weight * aux, (loss, aux)

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    opt_init, opt_update = make_optimizer(tcfg.opt)
    loss_fn = make_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if tcfg.grad_accum > 1:
            def split(x):
                return x.reshape((tcfg.grad_accum,
                                  x.shape[0] // tcfg.grad_accum) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                (l_acc, a_acc, g_acc) = carry
                (tot, (loss, aux)), grads = grad_fn(params, mb)
                grads = _compress(grads, tcfg.grad_compress)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (l_acc + loss, a_acc + aux, g_acc), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
            (loss, aux, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros(()), jnp.zeros(()), zero_g), micro)
            loss = loss / tcfg.grad_accum
            aux = aux / tcfg.grad_accum
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
        else:
            (tot, (loss, aux)), grads = grad_fn(params, batch)
            grads = _compress(grads, tcfg.grad_compress)
        new_params, new_opt, gnorm = opt_update(grads, opt_state, params)
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step, opt_init
