"""Deterministic synthetic token pipeline (shardable, restart-exact).

Every batch is a pure function of (seed, step, shard) — so a restarted or
re-sharded job regenerates the identical global batch with no data-loader
state to checkpoint.  Tokens follow a Zipf-ish distribution with a learnable
structure (repeated n-grams) so small models can overfit measurably —
enough signal for loss-goes-down integration tests and example drivers.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def batch_for_step(cfg: ModelConfig, seq_len: int, global_batch: int,
                   step: int, seed: int = 0, shard: int = 0,
                   n_shards: int = 1) -> dict:
    """Host-side numpy batch for one (possibly sharded) train step."""
    assert global_batch % n_shards == 0
    b = global_batch // n_shards
    rng = np.random.default_rng(
        np.uint64(seed) * np.uint64(1_000_003)
        + np.uint64(step) * np.uint64(9973) + np.uint64(shard))
    V = cfg.vocab
    # zipf-ish marginal + planted bigram structure: token[t+1] usually
    # (token[t] * 31 + 7) % V_small
    v_small = min(V - 2, 512)
    base = (rng.zipf(1.3, size=(b, seq_len)) % v_small) + 1
    planted = (base * 31 + 7) % v_small + 1
    use_planted = rng.random((b, seq_len)) < 0.7
    toks = np.where(use_planted, np.roll(planted, 1, axis=1), base)
    toks = toks.astype(np.int32)
    labels = np.roll(toks, -1, axis=1)
    labels[:, -1] = 0  # PAD: ignored by the loss
    out = {"tokens": toks, "labels": labels}
    if cfg.family == "vlm":
        P = max(cfg.n_patches, 1)
        out["patch_embeds"] = rng.standard_normal(
            (b, P, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.family == "encdec":
        T = max(cfg.encoder_seq, 1)
        out["encoder_feats"] = rng.standard_normal(
            (b, T, cfg.d_model)).astype(np.float32) * 0.02
    return out


def to_device(batch: dict) -> dict:
    return {k: jnp.asarray(v) for k, v in batch.items()}
