"""Pipeline parallelism: GPipe-style microbatch pipeline over a mesh axis.

``pipeline_apply`` runs a layer-stack split into S stages over the ``stage``
mesh axis: microbatches enter stage 0, activations hop stage→stage via
``lax.ppermute``, and results drain from the last stage.  The schedule is
the classic (n_mb + S − 1)-tick wavefront; bubble fraction (S−1)/(n_mb+S−1).

This is the building block for mapping the ``pod`` axis of the production
mesh to pipeline stages (inter-pod DCI links carry only microbatch
activations instead of FSDP parameter traffic — the right trade when the
cross-pod bandwidth is the binding term).  Used by
``examples``/``tests/parallel`` on a host mesh; forward (inference /
activation-recompute) schedule.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import shard_map


def pipeline_apply(stage_fn: Callable, params_stacked, x_microbatches,
                   mesh: Mesh, axis: str = "stage"):
    """Run ``y = stage_{S-1}(...stage_0(x))`` as a GPipe wavefront.

    stage_fn(stage_params, h) -> h            (same shape in/out)
    params_stacked: pytree with leading dim S, sharded over ``axis``
    x_microbatches: [n_mb, mb, ...] (replicated)
    returns: [n_mb, mb, ...] outputs (replicated; produced by last stage)
    """
    S = mesh.shape[axis]
    n_mb = x_microbatches.shape[0]
    n_ticks = n_mb + S - 1
    perm = [(i, (i + 1) % S) for i in range(S)]

    def per_stage(params_local, xs_local):
        # params_local: leading dim 1 (this stage's slice)
        p = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        h = jnp.zeros_like(xs_local[0])
        outs = jnp.zeros_like(xs_local)

        def tick(carry, t):
            h, outs = carry
            # stage 0 injects microbatch t (if any remain)
            mb_idx = jnp.clip(t, 0, n_mb - 1)
            inject = xs_local[mb_idx]
            h_in = jnp.where(sid == 0, inject, h)
            h_out = stage_fn(p, h_in)
            # valid computation at stage s during ticks [s, s + n_mb)
            valid = (t >= sid) & (t < sid + n_mb)
            h_out = jnp.where(valid, h_out, h)
            # last stage drains: store output for microbatch (t - (S-1))
            out_idx = jnp.clip(t - (S - 1), 0, n_mb - 1)
            take = (sid == S - 1) & (t >= S - 1)
            outs = jax.lax.cond(
                take,
                lambda o: o.at[out_idx].set(h_out),
                lambda o: o,
                outs)
            # hand activation to the next stage
            h_next = jax.lax.ppermute(h_out, axis, perm)
            return (h_next, outs), None

        (h, outs), _ = jax.lax.scan(tick, (h, outs), jnp.arange(n_ticks))
        # broadcast final outputs from the last stage (all others hold
        # zeros, so a psum is an exact broadcast)
        outs = jax.lax.psum(outs, axis)
        return outs

    spec_p = jax.tree.map(lambda _: P(axis), params_stacked)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_p, P()), out_specs=P(),
                   check=False)
    return fn(params_stacked, x_microbatches)


def bubble_fraction(n_microbatches: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
