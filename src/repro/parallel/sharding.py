"""Sharding rules: parameter / batch / cache PartitionSpecs per (cfg, mesh).

Strategy (MaxText-style 2-D + optional pod axis):

* ``model`` axis — tensor parallelism: attention heads, FFN hidden, expert
  axis (EP), vocab (when divisible).
* ``data`` axis (x ``pod`` when present) — batch data parallelism *and*
  FSDP-style parameter sharding on the d_model dimension: XLA inserts the
  per-layer all-gathers (scan keeps them one-layer-sized).
* dims that do not divide the axis size stay replicated — the rule table is
  computed, not hand-written per arch (hymba's 3257-wide SSD projection,
  51865-token Whisper vocab, batch-1 long-context decode all fall out).

``decode`` caches shard heads over ``model`` when divisible, else the time
axis; batch goes to ``data`` when divisible, else time.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _div(dim: int, mesh: Mesh, axes):
    """axes if dim divides the axis product, else None (replicate)."""
    return axes if dim % max(1, axis_size(mesh, axes)) == 0 else None


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape) -> Any:
    """Build a PartitionSpec tree matching ``jax.eval_shape(init_params)``."""
    DP = dp_axes(mesh)
    M = "model"

    def spec_for(path: str, shp) -> P:
        dims = list(shp.shape)
        nd = len(dims)
        leaf = path.split("/")[-1]
        L = (None,) if nd >= 1 else ()

        def last2(a, b):
            """spec with the last two dims sharded (a, b), L-prefixed."""
            pre = [None] * (nd - 2)
            return P(*pre, a, b)

        if leaf == "embed":
            return P(_div(dims[0], mesh, M), _div(dims[1], mesh, DP))
        if leaf in ("lm_head", "patch_proj"):
            return P(_div(dims[0], mesh, DP), _div(dims[1], mesh, M))
        if nd <= 2:   # norms, scalars, per-layer vectors
            return P(*([None] * nd))
        if leaf in ("wq", "wk", "wv", "x_wq", "x_wk", "x_wv", "wi", "ws_i",
                    "in_proj"):
            return last2(_div(dims[-2], mesh, DP), _div(dims[-1], mesh, M))
        if leaf in ("wo", "x_wo", "wo_ff", "ws_o", "out_proj"):
            return last2(_div(dims[-2], mesh, M), _div(dims[-1], mesh, DP))
        if leaf == "router":
            return last2(_div(dims[-2], mesh, DP), _div(dims[-1], mesh, M))
        if leaf == "we_i":   # [L, E, d, 2f]
            return P(None, _div(dims[1], mesh, M), _div(dims[2], mesh, DP),
                     None)
        if leaf == "we_o":   # [L, E, f, d]
            return P(None, _div(dims[1], mesh, M), None,
                     _div(dims[3], mesh, DP))
        if leaf == "conv_w":  # [L, Kc, HP]
            return P(None, None, _div(dims[-1], mesh, M))
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        specs.append(spec_for(key, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(cfg: ModelConfig, mesh: Mesh, pspecs, opt_shape) -> Any:
    """Optimizer-state specs: moments mirror their parameter's spec;
    factored adafactor stats drop the corresponding dim."""
    is_spec = lambda x: isinstance(x, P)
    flat_p, _ = jax.tree_util.tree_flatten_with_path(pspecs, is_leaf=is_spec)
    by_key = {}
    for path, spec in flat_p:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        by_key[key] = spec

    def lookup(key: str) -> P | None:
        return by_key.get(key)

    flat_o, treedef = jax.tree_util.tree_flatten_with_path(opt_shape)
    out = []
    for path, leaf in flat_o:
        keys = [str(getattr(p, "key", p)) for p in path]
        if keys and keys[0] in ("mu", "nu", "v"):
            rest = keys[1:]
            tail = None
            if rest and rest[-1] in ("vr", "vc", "v"):
                tail = rest[-1]
                rest = rest[:-1]
            pk = "/".join(rest)
            base = lookup(pk)
            if base is None:
                out.append(P(*([None] * leaf.ndim)))
            elif tail == "vr":      # param dims minus last
                out.append(P(*list(base)[:-1]))
            elif tail == "vc":      # param dims minus second-to-last
                out.append(P(*(list(base)[:-2] + list(base)[-1:])))
            else:
                out.append(base)
        else:
            out.append(P(*([None] * leaf.ndim)))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# batch / cache / activation specs
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_shape) -> Any:
    DP = dp_axes(mesh)

    def spec_for(leaf):
        dims = leaf.shape
        b = _div(dims[0], mesh, DP)
        return P(b, *([None] * (len(dims) - 1)))

    return jax.tree_util.tree_map(spec_for, batch_shape)


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape) -> Any:
    DP = dp_axes(mesh)
    M = "model"

    def spec_for(path, leaf):
        key = str(getattr(path[-1], "key", path[-1]))
        dims = list(leaf.shape)
        if key in ("k", "v", "xk", "xv"):       # [L, B, T, Hkv, D]
            b = _div(dims[1], mesh, DP)
            h = _div(dims[3], mesh, M)
            # the time axis picks up whatever axes remain unused and divide
            # it (sequence-parallel KV: batch-1 long-context, odd head counts)
            t_axes: list = []
            if b is None and dims[2] % axis_size(mesh, DP) == 0:
                t_axes += list(DP)
            if h is None and dims[2] % (
                    axis_size(mesh, tuple(t_axes)) * mesh.shape[M]) == 0:
                t_axes.append(M)
            t = tuple(t_axes) if t_axes else None
            return P(None, b, t, h, None)
        if key in ("k_scale", "v_scale"):         # [L, B, T, Hkv]
            b = _div(dims[1], mesh, DP)
            h = _div(dims[3], mesh, M)
            t_axes: list = []
            if b is None and dims[2] % axis_size(mesh, DP) == 0:
                t_axes += list(DP)
            if h is None and dims[2] % (
                    axis_size(mesh, tuple(t_axes)) * mesh.shape[M]) == 0:
                t_axes.append(M)
            t = tuple(t_axes) if t_axes else None
            return P(None, b, t, h)
        if key == "ssm":                          # [L, B, H, P, N]
            return P(None, _div(dims[1], mesh, DP),
                     _div(dims[2], mesh, M), None, None)
        if key == "conv":                         # [L, B, Kc-1, HP]
            return P(None, _div(dims[1], mesh, DP), None,
                     _div(dims[3], mesh, M))
        return P(*([None] * len(dims)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def activation_rules(cfg: ModelConfig, mesh: Mesh, *, n_moe_groups: int = 0):
    """Rule table for :func:`repro.parallel.api.constrain`."""
    DP = dp_axes(mesh)
    M = "model"
    rules = {
        "activation": named(mesh, P(DP, None, None)),
    }
    if cfg.is_moe:
        n_ax = DP if (n_moe_groups and
                      n_moe_groups % axis_size(mesh, DP) == 0) else None
        rules["moe_dispatch"] = named(mesh, P(n_ax, None, M, None))
        rules["moe_expert_in"] = named(mesh, P(n_ax, M, None, None))
        # fp8 expert gather (§Perf): gather the f8 tensor over the data
        # axis (E stays on model), dequant locally afterwards
        rules["moe_expert_w8"] = named(mesh, P(M, None, None))
    return rules
