"""Sharding-constraint side-channel.

Model code calls ``constrain(x, "moe_dispatch")`` on distribution-critical
intermediates; the launcher installs a rule table mapping those names to
``PartitionSpec``s for the active mesh.  Outside any mesh context the calls
are no-ops, so model code runs unchanged in single-device tests.
"""
from __future__ import annotations

import contextlib
import threading

import jax

_state = threading.local()


def current_rules() -> dict | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(rules: dict):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def constrain(x, name: str):
    rules = current_rules()
    if not rules or name not in rules:
        return x
    return jax.lax.with_sharding_constraint(x, rules[name])
