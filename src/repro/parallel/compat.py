"""JAX version-compat shims for the parallel layer.

The sharding API surface moved between JAX releases and the container pins
an older wheel, so nothing in ``repro.parallel`` may touch the new names
unconditionally:

* ``jax.sharding.AxisType`` (explicit/auto axis types) — absent before 0.5;
  :func:`make_mesh` accepts ``axis_types`` and silently drops it when the
  installed JAX cannot express it (meshes are fully ``Auto`` there anyway).
* ``jax.shard_map`` with ``check_vma=`` — older releases spell it
  ``jax.experimental.shard_map.shard_map(..., check_rep=)``;
  :func:`shard_map` hides the rename.

Keep every new-API access in this module so version drift breaks exactly
one file.
"""
from __future__ import annotations

import jax

__all__ = ["AXIS_TYPE_AUTO", "make_mesh", "shard_map"]

#: ``jax.sharding.AxisType.Auto`` when the installed JAX has axis types,
#: else ``None`` (meaning: meshes are implicitly fully automatic).
AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)


def make_mesh(axis_shapes, axis_names, axis_types=None, **kwargs):
    """``jax.make_mesh`` that tolerates JAX versions without ``axis_types``.

    ``axis_types`` may be a tuple of ``AxisType`` values (new JAX), a tuple
    of ``None`` / :data:`AXIS_TYPE_AUTO` placeholders, or ``None``.  On old
    JAX every mesh axis is Auto, which is what the placeholders request, so
    dropping the argument is semantics-preserving.
    """
    if axis_types is not None and AXIS_TYPE_AUTO is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=axis_types, **kwargs)
        except TypeError:
            pass  # make_mesh exists but predates the axis_types kwarg
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def shard_map(f, mesh, in_specs, out_specs, check: bool = True):
    """Version-stable ``shard_map``.

    ``check`` maps to ``check_vma`` (new JAX) / ``check_rep`` (old JAX) —
    both toggle the replication/varying-manual-axes verifier.
    """
    new_sm = getattr(jax, "shard_map", None)
    if new_sm is not None:
        try:
            return new_sm(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check)
        except TypeError:
            return new_sm(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as old_sm

    return old_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)
