"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute under ``interpret=True`` — the
kernel body runs as traced jnp ops, which validates BlockSpec indexing and
kernel logic exactly.  On a TPU backend the same call sites compile to
Mosaic.  ``use_pallas=False`` falls back to the jnp references (used by the
dry-run lowering path, where interpret-mode pallas would bloat the HLO).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .bitplane_matmul import bitplane_matmul as _bitplane_pallas
from .flash_attention import flash_attention as _flash_pallas
from .lut_eval import lut_eval as _lut_pallas
from .lut_eval import lut_eval6 as _lut6_pallas
from .popcount_matmul import popcount_matmul as _popcount_pallas
from .ssd_scan import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("mode", "k_bits", "use_pallas"))
def popcount_matmul(x_packed, w_packed, mode="and", k_bits=None,
                    use_pallas=True):
    if use_pallas:
        return _popcount_pallas(x_packed, w_packed, mode=mode, k_bits=k_bits,
                                interpret=not _on_tpu())
    return ref.popcount_matmul_ref(x_packed, w_packed, mode=mode,
                                   k_bits=k_bits)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def lut_eval(inputs, tts, use_pallas=True):
    if use_pallas:
        return _lut_pallas(inputs, tts, interpret=not _on_tpu())
    return ref.lut_eval_ref(inputs, tts)


def lut_eval6(inputs, tt_lo, tt_hi, use_pallas=True):
    """Fused-layout 6-pin LUT kernel (un-jitted: always called from inside
    the fused evaluator's own jit — once per width bucket of the
    multi-scan plan, so ``M`` is the bucket's own envelope, not the
    circuit-wide worst case)."""
    if use_pallas:
        return _lut6_pallas(inputs, tt_lo, tt_hi, interpret=not _on_tpu())
    return ref.lut_eval6_ref(inputs, tt_lo, tt_hi)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def bitplane_matmul(x, planes, scale, use_pallas=True):
    if use_pallas:
        return _bitplane_pallas(x, planes, scale, interpret=not _on_tpu())
    return ref.bitplane_matmul_ref(x, planes, scale)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "use_pallas"))
def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    scale=None, use_pallas=True):
    if use_pallas:
        return _flash_pallas(q, k, v, causal, window, softcap, scale,
                             not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def ssd_scan(x, dt, A, B, C, use_pallas=True):
    if use_pallas:
        return _ssd_pallas(x, dt, A, B, C, interpret=not _on_tpu())
    return ref.ssd_scan_ref(x, dt, A, B, C)
