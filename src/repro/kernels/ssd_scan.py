"""Pallas kernel: Mamba-2 SSD (state-space duality) chunked scan.

Grid: (batch, heads).  Each program walks the sequence in chunks of Q
timesteps, holding the (P x N) SSM state in VMEM.  Within a chunk the dual
quadratic form runs on the MXU (intra-chunk attention-like matmuls); the
recurrent state hand-off between chunks is a cheap VPU update — the
"double duty" split again (DESIGN.md §3).

Shapes follow arXiv:2405.21060 §6 with scalar A per head and shared B/C
(G = 1): x [B, L, H, P], dt [B, L, H], A [H], B/C [B, L, N].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 128


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *,
            n_chunks: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)       # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)     # [Q]
    a = a_ref[0].astype(jnp.float32)       # scalar A (per head), a < 0
    bmat = b_ref[0].astype(jnp.float32)    # [Q, N]
    cmat = c_ref[0].astype(jnp.float32)    # [Q, N]

    # cumulative log-decay within the chunk: L[t] = sum_{u<=t} a*dt[u]
    adt = a * dt                                    # [Q]
    cum = jnp.cumsum(adt)                           # [Q]
    # 1) contribution of the carried-in state: y_state[t] = C[t] . h_in decayed
    decay_in = jnp.exp(cum)[:, None]                # [Q, 1]
    h_in = state_ref[...]                           # [P, N]
    y_state = (cmat @ h_in.T) * decay_in            # [Q, P]
    # 2) intra-chunk (dual form): y[t] += sum_{u<=t} exp(cum[t]-cum[u]) *
    #    dt[u] * (C[t].B[u]) * x[u]
    scores = cmat @ bmat.T                          # [Q, Q]
    seg = cum[:, None] - cum[None, :]               # [Q, Q]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = t_idx >= u_idx
    w = jnp.where(causal, jnp.exp(seg) * scores, 0.0) * dt[None, :]
    y = y_state + jnp.dot(w, x, preferred_element_type=jnp.float32)
    # 3) update carried state: h_out = decay_total * h_in +
    #    sum_u exp(cum[-1]-cum[u]) * dt[u] * x[u] B[u]^T
    decay_tot = jnp.exp(cum[-1])
    wu = jnp.exp(cum[-1] - cum) * dt                # [Q]
    h_new = decay_tot * h_in + jnp.einsum("qp,qn->pn", x * wu[:, None], bmat)
    state_ref[...] = h_new
    o_ref[0] = y.astype(o_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, interpret: bool = True) -> jax.Array:
    """See :func:`repro.kernels.ref.ssd_scan_ref`."""
    Bb, L, H, P = x.shape
    N = B.shape[-1]
    chunk = min(CHUNK, L)
    assert L % chunk == 0, "sequence length must be a chunk multiple"
    n_chunks = L // chunk
    grid = (Bb, H, n_chunks)

    xt = x.transpose(0, 2, 1, 3).reshape(Bb * H, L, P)
    dtt = dt.transpose(0, 2, 1).reshape(Bb * H, L)
    out = pl.pallas_call(
        functools.partial(_kernel, n_chunks=n_chunks, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, h, c: (_flat2(b, h, H), c, 0)),
            pl.BlockSpec((1, chunk), lambda b, h, c: (_flat2(b, h, H), c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P),
                               lambda b, h, c: (_flat2(b, h, H), c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb * H, L, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A, B, C)
    return out.reshape(Bb, H, L, P).transpose(0, 2, 1, 3)


def _flat2(b, h, H):
    return b * H + h
