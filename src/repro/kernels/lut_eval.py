"""Pallas kernel: bit-parallel k-LUT level evaluation.

The functional simulator (``core/eval_jax.py``) evaluates one topological
level of LUTs at a time over packed test-vector lanes.  Per LUT the output is
a sum-of-minterms over its (<=5) input lanes — identical bitwise work for all
LUTs in a level, so it vectorizes across (LUT, lane) tiles.  The truth tables
ride along as a scalar-prefetch-style operand (one uint32 per LUT).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 256   # LUTs per tile
BLOCK_N = 128   # lane words per tile


def _kernel(tt_ref, in_ref, o_ref, *, k: int):
    # tt_ref: [BM] uint32; in_ref: [BM, k, BN] uint32; o_ref: [BM, BN]
    tts = tt_ref[...]
    ins = in_ref[...]
    BM, _, BN = ins.shape
    out = jnp.zeros((BM, BN), dtype=jnp.uint32)
    full = jnp.uint32(0xFFFFFFFF)
    for m in range(1 << k):  # unrolled: 2^k <= 32 minterms
        bit = (tts >> jnp.uint32(m)) & jnp.uint32(1)
        term = jnp.full((BM, BN), full, dtype=jnp.uint32)
        for j in range(k):
            lane = ins[:, j, :]
            term = term & (lane if (m >> j) & 1 else ~lane)
        out = out | (jnp.where(bit == 1, full, jnp.uint32(0))[:, None] & term)
    o_ref[...] = out


def lut_eval(inputs: jax.Array, tts: jax.Array,
             interpret: bool = True) -> jax.Array:
    """``inputs[M, K, N]`` uint32 lanes + ``tts[M]`` -> ``out[M, N]``."""
    M, K, N = inputs.shape
    assert K <= 5
    bm = min(BLOCK_M, M)
    bn = min(BLOCK_N, N)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn))
    return pl.pallas_call(
        functools.partial(_kernel, k=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm, K, bn), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.uint32),
        interpret=interpret,
    )(tts.astype(jnp.uint32), inputs.astype(jnp.uint32))
