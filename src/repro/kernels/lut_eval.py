"""Pallas kernels: bit-parallel k-LUT level evaluation.

The functional simulator (``core/eval_jax.py``) evaluates topological levels
of LUTs over packed test-vector lanes.  Per LUT the output is a
sum-of-minterms over its input lanes — identical bitwise work for all LUTs
in a level, so it vectorizes across (LUT, lane) tiles.  The truth tables
ride along as scalar-prefetch-style operands (uint32 words per LUT).

Two entry points:

* :func:`lut_eval` — the legacy per-level kernel, K <= 5, one uint32 table
  per LUT (kept for the per-level dispatcher and the kernel sweep tests).
* :func:`lut_eval6` — the fused-evaluator kernel.  Levels are padded to a
  uniform ``[M, 6, N]`` layout; the 64-entry table arrives as two uint32
  words and pin 5 Shannon-selects between them, so the inner loop is the
  same 32-minterm unroll as the 5-input case with one extra select.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 256   # LUTs per tile
BLOCK_N = 128   # lane words per tile


def _kernel(tt_ref, in_ref, o_ref, *, k: int):
    # tt_ref: [BM] uint32; in_ref: [BM, k, BN] uint32; o_ref: [BM, BN]
    tts = tt_ref[...]
    ins = in_ref[...]
    BM, _, BN = ins.shape
    out = jnp.zeros((BM, BN), dtype=jnp.uint32)
    full = jnp.uint32(0xFFFFFFFF)
    for m in range(1 << k):  # unrolled: 2^k <= 32 minterms
        bit = (tts >> jnp.uint32(m)) & jnp.uint32(1)
        term = jnp.full((BM, BN), full, dtype=jnp.uint32)
        for j in range(k):
            lane = ins[:, j, :]
            term = term & (lane if (m >> j) & 1 else ~lane)
        out = out | (jnp.where(bit == 1, full, jnp.uint32(0))[:, None] & term)
    o_ref[...] = out


def lut_eval(inputs: jax.Array, tts: jax.Array,
             interpret: bool = True) -> jax.Array:
    """``inputs[M, K, N]`` uint32 lanes + ``tts[M]`` -> ``out[M, N]``."""
    M, K, N = inputs.shape
    assert K <= 5
    bm = min(BLOCK_M, M)
    bn = min(BLOCK_N, N)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn))
    return pl.pallas_call(
        functools.partial(_kernel, k=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm, K, bn), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.uint32),
        interpret=interpret,
    )(tts.astype(jnp.uint32), inputs.astype(jnp.uint32))


def _kernel6(tt_lo_ref, tt_hi_ref, in_ref, o_ref):
    # tt_lo/hi_ref: [BM] uint32; in_ref: [BM, 6, BN]; o_ref: [BM, BN]
    lo_t = tt_lo_ref[...]
    hi_t = tt_hi_ref[...]
    ins = in_ref[...]
    BM, _, BN = ins.shape
    lo = jnp.zeros((BM, BN), dtype=jnp.uint32)
    hi = jnp.zeros((BM, BN), dtype=jnp.uint32)
    full = jnp.uint32(0xFFFFFFFF)
    for m in range(32):  # unrolled minterms over pins 0..4
        term = jnp.full((BM, BN), full, dtype=jnp.uint32)
        for j in range(5):
            lane = ins[:, j, :]
            term = term & (lane if (m >> j) & 1 else ~lane)
        lo_bit = (lo_t >> jnp.uint32(m)) & jnp.uint32(1)
        hi_bit = (hi_t >> jnp.uint32(m)) & jnp.uint32(1)
        lo = lo | (jnp.where(lo_bit == 1, full, jnp.uint32(0))[:, None] & term)
        hi = hi | (jnp.where(hi_bit == 1, full, jnp.uint32(0))[:, None] & term)
    sel = ins[:, 5, :]
    o_ref[...] = (sel & hi) | (~sel & lo)


def lut_eval6(inputs: jax.Array, tt_lo: jax.Array, tt_hi: jax.Array,
              interpret: bool = True) -> jax.Array:
    """``inputs[M, 6, N]`` uint32 lanes + split 64-bit tables -> ``out[M, N]``.

    Pin 5 Shannon-decomposes the 6-input table: ``tt_lo`` covers pin5=0
    minterms, ``tt_hi`` pin5=1.  LUTs narrower than 6 inputs are expressed
    by replicating their table into both words and padding unused pins
    with constant-0 lanes.
    """
    M, K, N = inputs.shape
    assert K == 6
    bm = min(BLOCK_M, M)
    bn = min(BLOCK_N, N)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn))
    return pl.pallas_call(
        _kernel6,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm, 6, bn), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.uint32),
        interpret=interpret,
    )(tt_lo.astype(jnp.uint32), tt_hi.astype(jnp.uint32),
      inputs.astype(jnp.uint32))
