"""Pallas kernel: binary GEMM via bitwise AND/XNOR + popcount.

Paper tie-in: on the FPGA, unrolled-DNN dot products are AND-gated partial
products reduced by compressor trees + adder chains (§II-C/§IV).  On TPU the
same reduction is a VPU bit-operation pipeline: 32 weight bits live in one
uint32 lane, the compressor tree becomes the SWAR popcount, and the adder
chain becomes the integer accumulate.  Tiled HBM->VMEM with BlockSpecs;
the M x N product grid maps to the Pallas grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128
BLOCK_N = 128


def _popc(v):
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _kernel_and(x_ref, w_ref, o_ref):
    # x_ref: [BM, W] uint32, w_ref: [BN, W] uint32, o_ref: [BM, BN] int32
    x = x_ref[...]
    w = w_ref[...]
    W = x.shape[-1]

    def body(i, acc):
        xi = jax.lax.dynamic_slice_in_dim(x, i, 1, axis=1)  # [BM, 1]
        wi = jax.lax.dynamic_slice_in_dim(w, i, 1, axis=1)  # [BN, 1]
        return acc + _popc(xi & wi.T)                        # [BM, BN]

    acc = jnp.zeros((x.shape[0], w.shape[0]), dtype=jnp.int32)
    acc = jax.lax.fori_loop(0, W, body, acc)
    o_ref[...] = acc


def _kernel_xnor(x_ref, w_ref, o_ref, *, k_bits: int):
    x = x_ref[...]
    w = w_ref[...]
    W = x.shape[-1]

    def body(i, acc):
        xi = jax.lax.dynamic_slice_in_dim(x, i, 1, axis=1)
        wi = jax.lax.dynamic_slice_in_dim(w, i, 1, axis=1)
        return acc + _popc(xi ^ wi.T)

    acc = jnp.zeros((x.shape[0], w.shape[0]), dtype=jnp.int32)
    acc = jax.lax.fori_loop(0, W, body, acc)
    o_ref[...] = k_bits - 2 * acc


def popcount_matmul(x_packed: jax.Array, w_packed: jax.Array,
                    mode: str = "and", k_bits: int | None = None,
                    interpret: bool = True) -> jax.Array:
    """See :func:`repro.kernels.ref.popcount_matmul_ref`."""
    M, W = x_packed.shape
    N, W2 = w_packed.shape
    assert W == W2
    bm = min(BLOCK_M, M)
    bn = min(BLOCK_N, N)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn))
    if mode == "and":
        kern = _kernel_and
    elif mode == "xnor":
        assert k_bits is not None
        kern = functools.partial(_kernel_xnor, k_bits=k_bits)
    else:
        raise ValueError(mode)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, W), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(x_packed.astype(jnp.uint32), w_packed.astype(jnp.uint32))
