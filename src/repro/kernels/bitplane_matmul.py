"""Pallas kernel: constant-weight matmul via weight bit-planes.

The Double-Duty adaptation for the MXU: a b-bit quantized weight matrix is
stored as b binary planes; the kernel streams each plane through the MXU
(dense {0,1} matmul at full systolic throughput) while the VPU concurrently
performs the shift-add plane accumulation and dequant epilogue — both compute
units do duty in the same pass, the TPU analogue of the paper's concurrent
adder-chain + LUT usage (DESIGN.md §3).

Tiling: classic (M, N, K) block grid with a VMEM accumulator carried across
the K-contraction; planes are unrolled inside the kernel body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _kernel(x_ref, p_ref, s_ref, o_ref, acc_ref, *, n_planes: int,
            n_k_blocks: int):
    # x_ref: [BM, BK] f32; p_ref: [B, BK, BN] (0/1); s_ref: [BN] f32
    # o_ref: [BM, BN] f32; acc_ref: VMEM accumulator [BM, BN] f32
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    acc = acc_ref[...]
    for b in range(n_planes):  # unrolled plane loop: MXU matmul + VPU shift-add
        w = p_ref[b, :, :].astype(jnp.float32)
        part = jnp.dot(x, w, preferred_element_type=jnp.float32)
        coeff = -(2.0 ** (n_planes - 1)) if b == n_planes - 1 else 2.0 ** b
        acc = acc + coeff * part
    acc_ref[...] = acc

    @pl.when(pl.program_id(2) == n_k_blocks - 1)
    def _done():
        o_ref[...] = acc_ref[...] * s_ref[...][None, :]


def bitplane_matmul(x: jax.Array, planes: jax.Array, scale: jax.Array,
                    interpret: bool = True) -> jax.Array:
    """``x[M, K]``, ``planes[B, K, N]`` in {0,1}, ``scale[N]`` -> ``y[M, N]``.

    W = (sum_b 2^b planes[b]  with top plane weighted -2^(B-1)) * scale.
    """
    M, K = x.shape
    Bp, K2, N = planes.shape
    assert K == K2
    bm = min(BLOCK_M, M)
    bn = min(BLOCK_N, N)
    bk = min(BLOCK_K, K)
    # zero-pad the contraction to a block multiple: padded K contributes 0,
    # and the kernel never reads uninitialized block tails.
    Kp = pl.cdiv(K, bk) * bk
    if Kp != K:
        x = jnp.pad(x, ((0, 0), (0, Kp - K)))
        planes = jnp.pad(planes, ((0, 0), (0, Kp - K), (0, 0)))
        K = Kp
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk))
    return pl.pallas_call(
        functools.partial(_kernel, n_planes=Bp, n_k_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((Bp, bk, bn), lambda i, j, k: (0, k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, planes, scale)
