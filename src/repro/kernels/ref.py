"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the per-kernel shape/dtype sweep tests and
the fallback implementation on backends without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# popcount_matmul — binary GEMM via AND/XNOR + popcount
# (the compressor tree's job — summing AND-gated partial products — executed
#  bit-parallel on the TPU VPU; the FPGA adder chain becomes a popcount)
# ---------------------------------------------------------------------------


def popcount_matmul_ref(x_packed: jax.Array, w_packed: jax.Array,
                        mode: str = "and", k_bits: int | None = None) -> jax.Array:
    """``x_packed[M, W]`` and ``w_packed[N, W]`` hold K bits packed into W =
    ceil(K/32) uint32 words.

    mode "and":  y[m, n] = sum_k x[m, k] & w[n, k]          (0/1 weights)
    mode "xnor": y[m, n] = K - 2 * popcount(x ^ w)          (+/-1 weights,
                 the classic binary-net dot product)
    """
    x = x_packed.astype(jnp.uint32)
    w = w_packed.astype(jnp.uint32)

    def popc(v):
        v = v - ((v >> 1) & jnp.uint32(0x55555555))
        v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
        v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
        return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)

    xw = x[:, None, :]
    ww = w[None, :, :]
    if mode == "and":
        return popc(xw & ww).sum(-1)
    if mode == "xnor":
        assert k_bits is not None
        return k_bits - 2 * popc(xw ^ ww).sum(-1)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# lut_eval — bit-parallel k-LUT evaluation over packed lanes
# ---------------------------------------------------------------------------


def lut_eval_ref(inputs: jax.Array, tts: jax.Array) -> jax.Array:
    """``inputs[M, K, N]`` uint32 lanes, ``tts[M]`` uint32 truth tables
    (K <= 5) -> ``out[M, N]`` uint32: out bit = tt[idx] where idx is the
    K-bit assignment read from the input lanes."""
    M, K, N = inputs.shape
    inputs = inputs.astype(jnp.uint32)
    tts = tts.astype(jnp.uint32)
    out = jnp.zeros((M, N), dtype=jnp.uint32)
    full = jnp.uint32(0xFFFFFFFF)
    for m in range(1 << K):
        bit = (tts >> jnp.uint32(m)) & 1  # (M,)
        term = jnp.full((M, N), full, dtype=jnp.uint32)
        for j in range(K):
            lane = inputs[:, j, :]
            term = term & jnp.where((m >> j) & 1, lane, ~lane)
        out = out | jnp.where(bit[:, None] == 1, term, jnp.uint32(0))
    return out


def lut_eval6_ref(inputs: jax.Array, tt_lo: jax.Array,
                  tt_hi: jax.Array) -> jax.Array:
    """Fused-layout 6-input LUT evaluation: ``inputs[M, 6, N]`` with the
    64-entry table split into pin5=0 (``tt_lo``) / pin5=1 (``tt_hi``)
    uint32 words."""
    g5 = inputs[:, :5, :]
    sel = inputs[:, 5, :].astype(jnp.uint32)
    lo = lut_eval_ref(g5, tt_lo)
    hi = lut_eval_ref(g5, tt_hi)
    return (sel & hi) | (~sel & lo)


# ---------------------------------------------------------------------------
# bitplane_matmul — constant-weight matmul via weight bit-planes
# (the paper's unrolled multiplication, adapted to MXU+VPU double duty)
# ---------------------------------------------------------------------------


def bitplane_matmul_ref(x: jax.Array, planes: jax.Array,
                        scale: jax.Array | None = None) -> jax.Array:
    """``x[M, K] @ W[K, N]`` where ``W = sum_b 2^b * planes[b]`` with the top
    plane carrying two's-complement weight ``-2^(B-1)``.

    planes: [B, K, N] in {0, 1}.  scale: optional [N] dequant scale.
    """
    B = planes.shape[0]
    w = jnp.zeros(planes.shape[1:], dtype=jnp.float32)
    for b in range(B):
        weight = -(2.0 ** (B - 1)) if b == B - 1 else 2.0 ** b
        w = w + weight * planes[b].astype(jnp.float32)
    y = jnp.dot(x.astype(jnp.float32), w, precision=jax.lax.Precision.HIGHEST)
    if scale is not None:
        y = y * scale[None, :]
    return y


# ---------------------------------------------------------------------------
# flash_attention — causal/local GQA attention with optional logit softcap
# ---------------------------------------------------------------------------


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int | None = None,
                        softcap: float | None = None,
                        scale: float | None = None) -> jax.Array:
    """q: [B, Hq, S, D], k/v: [B, Hkv, T, D] with Hq % Hkv == 0."""
    Bq, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    T = k.shape[2]
    qpos = jnp.arange(S)[:, None] + (T - S)  # decode: queries at the end
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)


# ---------------------------------------------------------------------------
# ssd_scan — Mamba-2 state-space duality (chunked scan)
# ---------------------------------------------------------------------------


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                 C: jax.Array) -> jax.Array:
    """Sequential reference for SSD.

    x:  [Bb, L, H, P]    inputs (already multiplied by dt outside if desired)
    dt: [Bb, L, H]       positive step sizes
    A:  [H]              negative-definite scalar per head (A < 0)
    B:  [Bb, L, N]       input projection (shared across heads, G=1)
    C:  [Bb, L, N]       output projection
    returns y: [Bb, L, H, P]
    """
    Bb, L, H, P = x.shape
    N = B.shape[-1]

    def step(h, inputs):
        xt, dtt, Bt, Ct = inputs  # [H,P], [H], [N], [N]
        decay = jnp.exp(A * dtt)  # [H]
        h = h * decay[:, None, None] + (dtt[:, None] * xt)[:, :, None] \
            * Bt[None, None, :]
        y = jnp.einsum("hpn,n->hp", h, Ct)
        return h, y

    def batch_one(xb, dtb, Bb_, Cb):
        h0 = jnp.zeros((H, P, N), dtype=jnp.float32)
        _, ys = jax.lax.scan(step, h0,
                             (xb.astype(jnp.float32), dtb.astype(jnp.float32),
                              Bb_.astype(jnp.float32), Cb.astype(jnp.float32)))
        return ys

    return jax.vmap(batch_one)(x, dt, B, C).astype(x.dtype)


def ssd_scan_chunked_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                         B: jax.Array, C: jax.Array,
                         chunk: int = 128) -> jax.Array:
    """Chunked (state-space *dual*) form of :func:`ssd_scan_ref` in pure jnp.

    Same math as the Pallas kernel: L serial steps become L/chunk steps of
    dense intra-chunk matmuls (arithmetic intensity ~chunk/2 instead of ~1)
    plus a cheap inter-chunk state hand-off — this is the paper-faithful
    SSD algorithm (arXiv:2405.21060 §6) and the training fast path.
    """
    Bb, L, H, P = x.shape
    N = B.shape[-1]
    if L % chunk:
        return ssd_scan_ref(x, dt, A, B, C)
    nc = L // chunk
    xf = x.astype(jnp.float32).reshape(Bb, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, chunk, H)
    Bf = B.astype(jnp.float32).reshape(Bb, nc, chunk, N)
    Cf = C.astype(jnp.float32).reshape(Bb, nc, chunk, N)
    Af = A.astype(jnp.float32)

    t_idx = jnp.arange(chunk)
    causal = t_idx[:, None] >= t_idx[None, :]

    def chunk_step(h_in, sl):
        xc, dtc, Bc, Cc = sl                      # [Bb,Q,H,P] [Bb,Q,H] [Bb,Q,N]
        cum = jnp.cumsum(Af[None, None, :] * dtc, axis=1)   # [Bb,Q,H]
        # carried-state contribution
        y_state = jnp.einsum("bqn,bhpn->bqhp", Cc, h_in) \
            * jnp.exp(cum)[..., None]
        # intra-chunk dual (attention-like) form
        scores = jnp.einsum("btn,bun->btu", Cc, Bc)          # [Bb,Q,Q]
        seg = cum[:, :, None, :] - cum[:, None, :, :]        # [Bb,Q,Q,H]
        w = jnp.where(causal[None, :, :, None],
                      jnp.exp(seg) * scores[..., None], 0.0) \
            * dtc[:, None, :, :]
        y = y_state + jnp.einsum("btuh,buhp->bthp", w, xc)
        # inter-chunk state update
        wu = jnp.exp(cum[:, -1:, :] - cum) * dtc             # [Bb,Q,H]
        h_out = jnp.exp(cum[:, -1])[..., None, None] * h_in \
            + jnp.einsum("buhp,bun->bhpn", xc * wu[..., None], Bc)
        return h_out, y

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0,
                         (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
                          Bf.swapaxes(0, 1), Cf.swapaxes(0, 1)))
    # ys: [nc, Bb, Q, H, P] -> [Bb, L, H, P]
    return ys.swapaxes(0, 1).reshape(Bb, L, H, P).astype(x.dtype)
