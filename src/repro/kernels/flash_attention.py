"""Pallas kernel: FlashAttention-style fused attention (fwd).

Online-softmax tiling: the query block and f32 accumulators live in VMEM;
key/value blocks stream through.  Supports causal masking, GQA (grouped KV
heads), sliding-window masking (gemma2/hymba local layers) and logit softcap
(gemma2).  The backward pass recomputes through the jnp reference under
``jax.custom_vjp`` (memory-optimal remat, standard for TPU training).

Grid: (batch*q_heads, q_blocks, kv_blocks) with the kv dimension innermost so
the VMEM accumulator carries across kv steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref as _ref

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int | None,
            softcap: float | None, n_kv_blocks: int, t_offset: int,
            block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # [BQ, D]
    k = k_ref[0].astype(jnp.float32)          # [BK, D]
    v = v_ref[0].astype(jnp.float32)          # [BK, D]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + t_offset
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)[:, None]
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def _flash_fwd(q, k, v, *, causal, window, softcap, scale, interpret):
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    G = Hq // Hkv
    bq = min(BLOCK_Q, S)
    bk = min(BLOCK_K, T)
    grid = (B * Hq, pl.cdiv(S, bq), pl.cdiv(T, bk))
    t_offset = T - S  # decode-style: queries sit at the sequence tail

    def qmap(h, i, j):
        return (h, i, 0)

    def kvmap(h, i, j):
        return (h // G, j, 0)

    q4 = q.reshape(B * Hq, S, D)
    k4 = k.reshape(B * Hkv, T, D)
    v4 = v.reshape(B * Hkv, T, D)
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, n_kv_blocks=grid[2], t_offset=t_offset,
            block_q=bq, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), qmap),
            pl.BlockSpec((1, bk, D), kvmap),
            pl.BlockSpec((1, bk, D), kvmap),
        ],
        out_specs=pl.BlockSpec((1, bq, D), qmap),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k4, v4)
    return out.reshape(B, Hq, S, D)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    scale=None, interpret=True):
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return _flash_fwd(q, k, v, causal=causal, window=window, softcap=softcap,
                      scale=scale, interpret=interpret)


def _fwd(q, k, v, causal, window, softcap, scale, interpret):
    out = flash_attention(q, k, v, causal, window, softcap, scale, interpret)
    return out, (q, k, v)


def _bwd(causal, window, softcap, scale, interpret, res, g):
    q, k, v = res
    # recompute-through-reference backward (IO-optimal remat strategy)
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window, softcap=softcap,
            scale=scale), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
