"""ABC-lite technology mapping: collapse LUT logic into larger LUTs.

The paper emits compressor boolean equations as fine-grained gates and lets
ABC pack them into LUTs (§IV, *Compressor Tree Synthesis*).  We model the two
dominant ABC behaviours:

1. substitute a fan-out-1 LUT into its single consumer while the merged
   support stays within ``max_k`` inputs (topological order lets whole cones
   collapse bottom-up);
2. *duplicate* a small LUT into **all** of its consumers when each can absorb
   it — the classic compressor-tree case: an AND partial product feeding both
   the XOR3 (sum) and MAJ3 (carry) of a full adder merges into both, turning
   FA+ANDs into two 5-LUTs and retiring the AND.
"""
from __future__ import annotations

from collections import defaultdict

from .netlist import CONST1, MAX_LUT_K, Netlist, tt_compose, tt_reduce


def techmap(net: Netlist, max_k: int = MAX_LUT_K) -> Netlist:
    # fanout over LUT outputs (consumers: luts, chains, POs)
    fanout = defaultdict(int)
    for ins in net.lut_inputs:
        for s in ins:
            fanout[s] += 1
    for ch in net.chains:
        for s in list(ch.a) + list(ch.b):
            fanout[s] += 1
        if ch.cin > CONST1:
            fanout[ch.cin] += 1
    for bus in net.pos.values():
        for s in bus:
            fanout[s] += 1

    drv_lut: dict[int, int] = {net.lut_out[i]: i for i in range(net.n_luts)}

    # working defs, mutated as we collapse
    defs: dict[int, tuple[tuple[int, ...], int]] = {
        i: (net.lut_inputs[i], net.lut_tt[i]) for i in range(net.n_luts)
    }
    dead: set[int] = set()

    # topo order over LUT nodes only
    order = [idx for kind, idx in net.topo_order() if kind == "lut"]

    for vi in order:
        if vi in dead:
            continue
        changed = True
        while changed:
            changed = False
            ins, tt = defs[vi]
            best = None
            for pin, s in enumerate(ins):
                ui = drv_lut.get(s)
                if ui is None or ui in dead or fanout[s] != 1:
                    continue
                u_ins, _ = defs[ui]
                merged = set(ins) - {s} | set(u_ins)
                if len(merged) <= max_k:
                    if best is None or len(merged) < best[0]:
                        best = (len(merged), pin, s, ui)
            if best is not None:
                _, pin, s, ui = best
                u_ins, u_tt = defs[ui]
                new_ins, new_tt = tt_compose(tt, ins, pin, u_tt, u_ins)
                new_ins, new_tt = tt_reduce(new_ins, new_tt)
                defs[vi] = (tuple(new_ins), new_tt)
                dead.add(ui)
                # support may have changed; update fanouts conservatively
                for q in u_ins:
                    pass  # counts retained; merges are guarded by fanout==1
                changed = True

    # --- pass 2: duplication into all consumers -----------------------------
    # (only LUT consumers; nodes feeding chains/POs stay put)
    chain_or_po_sigs: set[int] = set()
    for ch in net.chains:
        chain_or_po_sigs.update(ch.a)
        chain_or_po_sigs.update(ch.b)
        chain_or_po_sigs.add(ch.cin)
    for bus in net.pos.values():
        chain_or_po_sigs.update(bus)

    for _round in range(4):
        # consumer index over live defs
        consumers: dict[int, list[int]] = {}
        for vi in order:
            if vi in dead:
                continue
            for s in defs[vi][0]:
                consumers.setdefault(s, []).append(vi)
        changed_any = False
        for ui in order:
            if ui in dead:
                continue
            u_out = net.lut_out[ui]
            u_ins, u_tt = defs[ui]
            if len(u_ins) > 3 or u_out in chain_or_po_sigs:
                continue
            cons = consumers.get(u_out, [])
            if not cons or len(cons) > 4:
                continue
            # all consumers must absorb u
            plans = []
            ok = True
            for vi in cons:
                if vi in dead or vi == ui:
                    ok = False
                    break
                v_ins, v_tt = defs[vi]
                merged = set(v_ins) - {u_out} | set(u_ins)
                if len(merged) > max_k:
                    ok = False
                    break
                plans.append(vi)
            if not ok or not plans:
                continue
            for vi in plans:
                v_ins, v_tt = defs[vi]
                while u_out in v_ins:
                    pin = v_ins.index(u_out)
                    n_ins, n_tt = tt_compose(v_tt, v_ins, pin, u_tt, u_ins)
                    n_ins, n_tt = tt_reduce(n_ins, n_tt)
                    v_ins, v_tt = tuple(n_ins), n_tt
                defs[vi] = (v_ins, v_tt)
            dead.add(ui)
            changed_any = True
        if not changed_any:
            break

    # rebuild netlist
    out = Netlist(net.name)
    out.n_signals = net.n_signals
    out.pis = list(net.pis)
    out.pi_buses = dict(net.pi_buses)
    for s in net.pis:
        out.driver[s] = net.driver[s]
    for vi in order:
        if vi in dead:
            continue
        ins, tt = defs[vi]
        idx = len(out.lut_out)
        out.lut_inputs.append(tuple(ins))
        out.lut_tt.append(tt)
        out.lut_out.append(net.lut_out[vi])
        out.driver[net.lut_out[vi]] = ("lut", idx)
        out._lut_cache[(tuple(ins), tt)] = idx
    for ci, ch in enumerate(net.chains):
        out.chains.append(ch)
        out._chain_cache[(tuple(ch.a), tuple(ch.b), ch.cin)] = ci
        for bi, s in enumerate(ch.sums):
            out.driver[s] = ("chain", ci, bi)
        if ch.cout is not None:
            out.driver[ch.cout] = ("cout", ci)
    out.pos = {k: list(v) for k, v in net.pos.items()}
    return out
