"""Binary adder-tree reduction with the strength-heuristic DP (Algorithm 1).

At each reduction stage, ``n`` rows are paired into ``floor(n/2)`` carry
chains (an odd row passes through).  The paper's *strength* heuristic scores a
stage pairing by ``H = I / O`` where

* ``I`` — input signals **counted by position** (a signal feeding two chains
  counts twice), and
* ``O`` — output signals of **unique** chains (a chain identical to one that
  already exists — in this stage or anywhere in the netlist — contributes no
  new outputs).

Maximizing ``H`` rewards pairings that expose shifted-duplicate chains, which
the structural chain cache then builds only once (§IV, Fig. 4).

For ``n <= DP_LIMIT`` we run the exact memoized DP of Algorithm 1; above that
(dot-product reductions with dozens of rows) a duplicate-aware greedy pairing
is used — the paper only exercises the DP inside a single multiplier, where
``n`` is the operand width.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Sequence

from .netlist import Netlist
from .synth import Row, add_rows, add_rows_naive, chain_key_for

DP_LIMIT = 12


def reduce_binary(net: Netlist, rows: list[Row], width_cap: int | None = None,
                  use_dp: bool = True, share: bool = True) -> Row:
    if share:
        rows = [r for r in rows if not r.is_zero()]
    while len(rows) > 1:
        if not use_dp:
            pairs = [(i, i + 1) for i in range(0, len(rows) - 1, 2)]
            passthrough = [len(rows) - 1] if len(rows) % 2 else []
        elif len(rows) <= DP_LIMIT:
            pairs, passthrough = _best_placement(net, rows, width_cap)
        else:
            pairs, passthrough = _greedy_placement(rows)
        if share:
            nxt = [add_rows(net, rows[i], rows[j], width_cap=width_cap, share=True)
                   for i, j in pairs]
        else:
            nxt = [add_rows_naive(net, rows[i], rows[j], width_cap=width_cap)
                   for i, j in pairs]
        nxt.extend(rows[k] for k in passthrough)
        if share:
            nxt = [r for r in nxt if not r.is_zero()]
        rows = nxt
        if not rows:
            return Row(0, ())
    return rows[0]


# ---------------------------------------------------------------------------
# Algorithm 1 — exact memoized DP over row subsets
# ---------------------------------------------------------------------------


def _best_placement(net: Netlist, rows: list[Row], width_cap):
    """Return (pairs, passthrough) maximizing the stage strength H = I/O."""
    n = len(rows)
    keys = {}

    def pair_key(i: int, j: int):
        if (i, j) not in keys:
            keys[(i, j)] = chain_key_for(rows[i], rows[j], width_cap)
        return keys[(i, j)]

    existing = net._chain_cache

    memo: dict[int, tuple[float, int, int, tuple]] = {}

    def best(mask: int):
        """Best solution for the row subset ``mask``.

        Returns ``(H, I, O, pairs)`` where pairs is a tuple of (i, j).
        """
        cnt = bin(mask).count("1")
        if cnt < 2:
            return (0.0, 0, 0, ())
        if mask in memo:
            return memo[mask]
        idxs = [i for i in range(n) if (mask >> i) & 1]
        best_sol = None
        if cnt % 2 == 0:
            for ai in range(len(idxs)):
                for bi in range(ai + 1, len(idxs)):
                    i, j = idxs[ai], idxs[bi]
                    rest = mask & ~(1 << i) & ~(1 << j)
                    _, I_s, O_s, pairs_s = best(rest)
                    key = pair_key(i, j)
                    a, b = key
                    I_p = sum(1 for s in a + b if s != 0)
                    I_tot = I_s + I_p
                    seen = {pair_key(x, y) for x, y in pairs_s}
                    O_p = 0
                    if key not in seen and (a, b, 0) not in existing:
                        O_p = len(a) + 1  # sums + cout
                    O_tot = O_s + O_p
                    H = I_tot / max(O_tot, 1)
                    if best_sol is None or H > best_sol[0]:
                        best_sol = (H, I_tot, O_tot, pairs_s + ((i, j),))
        else:
            for drop in idxs:
                rest = mask & ~(1 << drop)
                H, I_s, O_s, pairs_s = best(rest)
                if best_sol is None or H > best_sol[0]:
                    best_sol = (H, I_s, O_s, pairs_s)
        memo[mask] = best_sol
        return best_sol

    full = (1 << n) - 1
    _, _, _, pairs = best(full)
    used = set()
    for i, j in pairs:
        used.add(i)
        used.add(j)
    passthrough = [k for k in range(n) if k not in used]
    return list(pairs), passthrough


# ---------------------------------------------------------------------------
# duplicate-aware greedy pairing for large row counts
# ---------------------------------------------------------------------------


def _greedy_placement(rows: list[Row]):
    """Pair rows so that shifted duplicates land in the same chain.

    Rows with identical bit patterns are grouped; within a group rows are
    sorted by shift and paired consecutively, which yields runs of equal
    shift-deltas (→ identical chain keys).  Leftovers are paired by
    proximity of their bit positions to minimize chain length.
    """
    n = len(rows)
    groups: dict[tuple[int, ...], list[int]] = {}
    for idx, r in enumerate(rows):
        groups.setdefault(r.bits, []).append(idx)
    pairs: list[tuple[int, int]] = []
    leftovers: list[int] = []
    for bits, idxs in groups.items():
        idxs.sort(key=lambda i: rows[i].shift)
        k = 0
        while k + 1 < len(idxs):
            pairs.append((idxs[k], idxs[k + 1]))
            k += 2
        if k < len(idxs):
            leftovers.append(idxs[k])
    leftovers.sort(key=lambda i: rows[i].shift)
    k = 0
    while k + 1 < len(leftovers):
        pairs.append((leftovers[k], leftovers[k + 1]))
        k += 2
    passthrough = leftovers[k:]
    return pairs, passthrough


def count_stage_strength(net: Netlist, rows: list[Row], pairs, width_cap=None):
    """Diagnostic: the H value of a given stage pairing (used in tests)."""
    I_tot = 0
    O_tot = 0
    seen = set()
    for i, j in pairs:
        a, b = chain_key_for(rows[i], rows[j], width_cap)
        I_tot += sum(1 for s in a + b if s != 0)
        key = (a, b)
        if key not in seen and (a, b, 0) not in net._chain_cache:
            seen.add(key)
            O_tot += len(a) + 1
    return I_tot / max(O_tot, 1)
