"""Vectorized static timing over the unified columnar CircuitIR.

The Python oracle (:func:`repro.core.timing.analyze_oracle`) walks dicts
signal-by-signal; this module executes the same levelized longest-path
recurrence as array programs over :class:`~repro.core.circuit_ir.CircuitIR`
(the same lowering the fused evaluator and the equivalence lanes read):

* **numpy backend** — one gather/max per level, ragged (unpadded) level
  tables, zero compile cost.  This is what ``timing.analyze`` uses for
  one-off pack-and-analyze calls (every figure driver).
* **jax backend** — levels are bucketed into contiguous width segments
  (the evaluator's padded-volume DP), each bucket runs as one
  ``lax.scan``, and the whole suite is batched with a nested ``vmap``:
  outer over circuits (stacked, sink-padded tensors), inner over
  architectures (delay-table rows).  One jit program re-times a whole
  benchmark suite across an N-point arch grid — the engine behind
  :mod:`repro.core.sweep`.

Value identity
--------------
Both backends are **bit-identical** to the oracle (not merely close): all
arithmetic is float64, additions compose in exactly the oracle's
association order — ``(((arrival + route) + wire) + pin) + path`` per
edge, ``((t_in + lut_delay) + t_alm_out) + t_out_mux_extra`` per node —
and ``max`` is exact in any order.  Padding exploits the model invariant
that delays are non-negative: padded slots gather signal 0 (CONST0,
arrival 0.0) through the all-zero null edge class and wire tier 0,
reproducing the oracle's ``default=0.0`` reductions exactly.

The *wire* term is the placement-derived inter-LB hop delay: each edge
carries a wire tier (0..3, see ``TIER_*`` in :mod:`repro.core.circuit_ir`)
gathered from a per-arch 4-entry component table.  Unplaced IRs carry
tier 0 everywhere and tier 0's delay is identically 0.0, so — because
``x + 0.0 == x`` exactly for every finite ``x >= 0`` — the placed path
at zero wire-tier delay reproduces the placement-free timing bit for
bit (the Fig-5/Table-III pins stay regression gates).

Delay tables are data, not structure: an edge stores a *class* (0..26,
see :mod:`repro.core.circuit_ir`), the per-arch component table is built
here by :func:`delay_components` from ``ArchParams.delay_table()`` rows.
Batching across architectures is therefore just a leading axis on the
component tables — no retrace, no repack.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from .alm import ArchParams, DELAY_FIELDS
from .circuit_ir import (N_EDGE_CLASSES, N_NODE_CLASSES, NDC_ABSORBED,
                         NDC_LUT4, NDC_LUT5, NDC_LUT6, CircuitIR)
from .plan import bucket_envelopes, combined_profile, segment_levels

_IDX = {f: i for i, f in enumerate(DELAY_FIELDS)}

#: jit executables shared across :class:`SuiteTimingProgram` instances,
#: keyed by the full shape signature (signal count, stacked member count,
#: per-bucket flags + tensor shapes, PO width).  The compiled function
#: reads every member-specific value from its *arguments*, so any two
#: programs with equal signatures can share one executable — and with
#: ``pad_shapes=True`` (below) signatures are quantized so that nearby
#: batch compositions actually collide.  Unbounded on purpose: entries
#: are a few compiled closures, not data.
_JIT_CACHE: dict[tuple, object] = {}

#: how many programs were built, how many jit executables that actually
#: compiled vs reused — the serving benchmark records the delta to prove
#: shape padding converts compiles into reuses.
_COMPILE_COUNTS = {"programs": 0, "jit_built": 0, "jit_reused": 0}


def read_compile_counts() -> dict:
    """Snapshot of program-build vs jit-compile/reuse counters."""
    return dict(_COMPILE_COUNTS)


def _pad_dim(n: int, floor: int = 4) -> int:
    """Round ``n`` up to the next power of two, at least ``floor`` —
    the shape quantizer behind ``pad_shapes``."""
    n = max(int(n), floor)
    return 1 << (n - 1).bit_length()


def delay_components(tables: np.ndarray) -> dict[str, np.ndarray]:
    """Expand delay-table rows ``[..., len(DELAY_FIELDS)]`` into the three
    component tables the executors gather from (leading axes preserved):

    * ``edge [..., 27, 3]`` — (route, pin, path) components per edge class;
    * ``wire [..., 4]``     — inter-LB delay per wire tier (null/tile-local,
      1-hop, 2-hop, long); tier 0 is identically 0.0 so unplaced edges
      (and padding) add nothing;
    * ``lut  [..., 4, 3]``  — (lut_delay, t_alm_out, t_out_mux_extra) per
      node delay class (all-zero for absorbed LUTs);
    * ``chain [..., 3]``    — (t_sum_out, t_out_mux_extra, t_carry).
    """
    t = np.asarray(tables, dtype=np.float64)
    lead = t.shape[:-1]
    z = np.zeros(lead, dtype=np.float64)

    def g(name):
        return t[..., _IDX[name]]

    route = np.stack([z, g("t_route_local"), g("t_route_global")], axis=-1)
    pin = np.stack([z, g("t_lbin_to_ah"), g("t_lbin_to_z")], axis=-1)
    path = np.stack([z, g("t_ah_to_adder"), g("t_z_to_adder")], axis=-1)
    edge = np.zeros(lead + (N_EDGE_CLASSES, 3), dtype=np.float64)
    for c in range(N_EDGE_CLASSES):
        edge[..., c, 0] = route[..., c // 9]
        edge[..., c, 1] = pin[..., (c // 3) % 3]
        edge[..., c, 2] = path[..., c % 3]

    lut = np.zeros(lead + (N_NODE_CLASSES, 3), dtype=np.float64)
    for ndc, d in ((NDC_LUT4, g("t_lut4")), (NDC_LUT5, g("t_lut5")),
                   (NDC_LUT6, g("t_lut6"))):
        lut[..., ndc, 0] = d
        lut[..., ndc, 1] = g("t_alm_out")
        lut[..., ndc, 2] = g("t_out_mux_extra")
    assert NDC_ABSORBED == 0  # row 0 stays all-zero: absorption adds nothing

    chain = np.stack([g("t_sum_out"), g("t_out_mux_extra"), g("t_carry")],
                     axis=-1)
    wire = np.stack([z, g("t_wire_hop1"), g("t_wire_hop2"),
                     g("t_wire_long")], axis=-1)
    return {"edge": edge, "wire": wire, "lut": lut, "chain": chain}


# ---------------------------------------------------------------------------
# numpy backend (per-circuit, compile-free)
# ---------------------------------------------------------------------------


def arrival_times_numpy(ir: CircuitIR, comps: dict[str, np.ndarray]
                        ) -> np.ndarray:
    """Arrival time per signal, float64, oracle-identical."""
    edge, wire, lutc = comps["edge"], comps["wire"], comps["lut"]
    t_sum, t_extra, t_carry = (float(comps["chain"][0]),
                               float(comps["chain"][1]),
                               float(comps["chain"][2]))
    arr = np.zeros(ir.n_signals, dtype=np.float64)
    for ll, cl in zip(ir.lut_levels, ir.chain_levels):
        if ll.out.shape[0]:
            ec = edge[ll.cls]                          # [M, 6, 3]
            t = (((arr[ll.ins] + ec[..., 0]) + wire[ll.hop])
                 + ec[..., 1]) + ec[..., 2]
            tin = t.max(axis=1)
            nc = lutc[ll.ndc]                          # [M, 3]
            arr[ll.out] = ((tin + nc[:, 0]) + nc[:, 1]) + nc[:, 2]
        C = cl.cout.shape[0]
        if C:
            ea, eb = edge[cl.a_cls], edge[cl.b_cls]
            a_t = (((arr[cl.a_sig] + ea[..., 0]) + wire[cl.a_hop])
                   + ea[..., 1]) + ea[..., 2]
            b_t = (((arr[cl.b_sig] + eb[..., 0]) + wire[cl.b_hop])
                   + eb[..., 1]) + eb[..., 2]
            ecin = edge[cl.cin_cls]
            c = (((arr[cl.cin_sig] + ecin[:, 0]) + wire[cl.cin_hop])
                 + ecin[:, 1]) + ecin[:, 2]
            B = cl.a_sig.shape[1]
            carries = np.zeros((C, B), dtype=np.float64)
            for bi in range(B):
                th = np.maximum(np.maximum(a_t[:, bi], b_t[:, bi]), c)
                valid = cl.sums[:, bi] >= 0
                if valid.any():
                    arr[cl.sums[valid, bi]] = (th[valid] + t_sum) + t_extra
                c = th + t_carry
                carries[:, bi] = c
            has = cl.cout >= 0
            if has.any():
                cy = carries[np.flatnonzero(has), cl.last[has]]
                arr[cl.cout[has]] = (cy + t_sum) + t_extra
    return arr


def critical_path_numpy(ir: CircuitIR, comps: dict[str, np.ndarray]) -> float:
    arr = arrival_times_numpy(ir, comps)
    cp = float(arr[ir.po_sig].max()) if ir.po_sig.size else 0.0
    return max(cp, 1.0)


def metrics_from_cp(ir: CircuitIR, arch: ArchParams, cp: float) -> dict:
    """The :func:`repro.core.timing.analyze` record for one (IR, arch, cp).

    ``n_alms``/``n_lbs``/``concurrent_luts`` come from the IR (structure);
    area comes from the arch row — within a structural class only the
    area constant and the delays differ, which is why one IR serves every
    grid row of its class."""
    area = ir.n_alms * arch.alm_area_mwta
    return {
        "arch": arch.name,
        "critical_path_ps": cp,
        "fmax_mhz": 1e6 / cp,
        "alms": ir.n_alms,
        "lbs": ir.n_lbs,
        "area_mwta": area,
        "adp": area * cp,
        "adders": ir.n_adders,
        "luts": ir.n_luts,
        "concurrent_luts": ir.concurrent_luts,
    }


def analyze_ir(ir: CircuitIR, arch: ArchParams, backend: str = "numpy") -> dict:
    """Vectorized :func:`repro.core.timing.analyze` over a lowered pack."""
    if backend == "numpy":
        comps = delay_components(arch.delay_table())
        cp = critical_path_numpy(ir, comps)
    elif backend == "jax":
        prog = build_suite_timing_program([ir])
        cp = float(prog.run(arch.delay_table()[None, :])[0, 0])
    else:
        raise ValueError(f"unknown timing backend {backend!r}")
    return metrics_from_cp(ir, arch, cp)


# ---------------------------------------------------------------------------
# jax backend (suite x arch-grid batched program)
# ---------------------------------------------------------------------------


def _alloc_bucket(l: int, M1: int, C1: int, B1: int, sink: int):
    """One bucket's all-pad (null) 17-tuple: every gather reads signal 0
    (CONST0, arrival 0.0) through edge class 0 / wire tier 0, every
    scatter lands on ``sink`` — a no-op level row."""
    return (np.zeros((l, M1, 6), dtype=np.int32),       # l_ins
            np.zeros((l, M1, 6), dtype=np.int32),       # l_cls
            np.zeros((l, M1), dtype=np.int32),          # l_ndc
            np.full((l, M1), sink, dtype=np.int32),     # l_out
            np.zeros((l, C1, B1), dtype=np.int32),      # a_sig
            np.zeros((l, C1, B1), dtype=np.int32),      # a_cls
            np.zeros((l, C1, B1), dtype=np.int32),      # b_sig
            np.zeros((l, C1, B1), dtype=np.int32),      # b_cls
            np.zeros((l, C1), dtype=np.int32),          # cin_sig
            np.zeros((l, C1), dtype=np.int32),          # cin_cls
            np.full((l, C1, B1), sink, dtype=np.int32),  # sums
            np.full((l, C1), sink, dtype=np.int32),     # cout
            np.zeros((l, C1), dtype=np.int32),          # last
            np.zeros((l, M1, 6), dtype=np.int32),       # l_hop
            np.zeros((l, C1, B1), dtype=np.int32),      # a_hop
            np.zeros((l, C1, B1), dtype=np.int32),      # b_hop
            np.zeros((l, C1), dtype=np.int32))          # cin_hop


def _pad_levels(ir: CircuitIR, bounds, shapes, sink: int):
    """Pad one member's ragged level tables to the bucketed group envelope
    (``shapes[bi] = (l, M1, C1, B1)``, possibly quantized upward);
    returns per-bucket 17-tuples of [l, ...] arrays (the scan xs).  The
    wire-tier (hop) arrays ride at indices 13..16 so the flag probes on
    indices 3/10/11 stay valid; padded slots keep tier 0 (zero delay)."""
    out = []
    for (i, j), (l, M1, C1, B1) in zip(bounds, shapes):
        (l_ins, l_cls, l_ndc, l_out, a_sig, a_cls, b_sig, b_cls,
         cin_sig, cin_cls, sums, cout, last,
         l_hop, a_hop, b_hop, cin_hop) = _alloc_bucket(l, M1, C1, B1, sink)
        for t in range(i, min(j, ir.n_levels)):
            r = t - i
            ll, cl = ir.lut_levels[t], ir.chain_levels[t]
            m = ll.out.shape[0]
            if m:
                l_ins[r, :m] = ll.ins
                l_cls[r, :m] = ll.cls
                l_hop[r, :m] = ll.hop
                l_ndc[r, :m] = ll.ndc
                l_out[r, :m] = ll.out
            c = cl.cout.shape[0]
            if c:
                bb = cl.a_sig.shape[1]
                a_sig[r, :c, :bb] = cl.a_sig
                a_cls[r, :c, :bb] = cl.a_cls
                a_hop[r, :c, :bb] = cl.a_hop
                b_sig[r, :c, :bb] = cl.b_sig
                b_cls[r, :c, :bb] = cl.b_cls
                b_hop[r, :c, :bb] = cl.b_hop
                cin_sig[r, :c] = cl.cin_sig
                cin_cls[r, :c] = cl.cin_cls
                cin_hop[r, :c] = cl.cin_hop
                s = cl.sums.copy()
                s[s < 0] = sink
                sums[r, :c, :bb] = s
                co = cl.cout.copy()
                co[co < 0] = sink
                cout[r, :c] = co
                last[r, :c] = cl.last
        out.append((l_ins, l_cls, l_ndc, l_out, a_sig, a_cls, b_sig, b_cls,
                    cin_sig, cin_cls, sums, cout, last,
                    l_hop, a_hop, b_hop, cin_hop))
    return out


@dataclass
class SuiteTimingProgram:
    """One batched timing program: G stacked circuits x K delay rows.

    ``run(delay_tables[K, len(DELAY_FIELDS)])`` returns critical paths
    ``[G, K]`` (float64), bit-identical to the oracle per (circuit, arch).
    The program is jit-compiled once per (shape, K); re-running with new
    delay rows of the same K reuses the compile — an arch-grid sweep is
    pure data motion after the first call.
    """

    n_sig: int
    n_members: int
    flags: tuple[tuple[bool, bool], ...]
    bucket_shapes: tuple[tuple[int, int, int, int], ...]
    _tensors: tuple = field(repr=False)
    _po: object = field(repr=False)
    _jit: object = field(default=None, repr=False)
    #: full compiled-shape signature; programs with equal signatures
    #: share one jit executable through the module ``_JIT_CACHE``
    shape_key: tuple | None = None

    def _build_jit(self):
        import functools

        import jax
        import jax.numpy as jnp

        flags = self.flags
        n_sig = self.n_sig

        def body(arr, xs, *, hl, hc, edge, wire, lutc, chainc):
            (l_ins, l_cls, l_ndc, l_out, a_sig, a_cls, b_sig, b_cls,
             cin_sig, cin_cls, sums, cout, last,
             l_hop, a_hop, b_hop, cin_hop) = xs
            if hl:
                ec = edge[l_cls]
                t = (((arr[l_ins] + ec[..., 0]) + wire[l_hop])
                     + ec[..., 1]) + ec[..., 2]
                tin = jnp.max(t, axis=1)
                nc = lutc[l_ndc]
                arr = arr.at[l_out].set(
                    ((tin + nc[:, 0]) + nc[:, 1]) + nc[:, 2])
            if hc:
                ea, eb = edge[a_cls], edge[b_cls]
                a_t = (((arr[a_sig] + ea[..., 0]) + wire[a_hop])
                       + ea[..., 1]) + ea[..., 2]
                b_t = (((arr[b_sig] + eb[..., 0]) + wire[b_hop])
                       + eb[..., 1]) + eb[..., 2]
                ecin = edge[cin_cls]
                c0 = (((arr[cin_sig] + ecin[:, 0]) + wire[cin_hop])
                      + ecin[:, 1]) + ecin[:, 2]
                t_sum, t_extra, t_carry = chainc[0], chainc[1], chainc[2]

                def ripple(c, ab):
                    at, bt = ab
                    th = jnp.maximum(jnp.maximum(at, bt), c)
                    cy = th + t_carry
                    return cy, (th, cy)

                _, (ths, cys) = jax.lax.scan(
                    ripple, c0, (a_t.swapaxes(0, 1), b_t.swapaxes(0, 1)))
                arr = arr.at[sums].set((ths.swapaxes(0, 1) + t_sum) + t_extra)
                cy_last = jnp.take_along_axis(
                    cys.swapaxes(0, 1), last[:, None], axis=1)[:, 0]
                arr = arr.at[cout].set((cy_last + t_sum) + t_extra)
            return arr, None

        def one(member_xs, po, edge, wire, lutc, chainc):
            arr = jnp.zeros(n_sig + 1, dtype=jnp.float64)
            for (hl, hc), xs in zip(flags, member_xs):
                bk = functools.partial(body, hl=hl, hc=hc, edge=edge,
                                       wire=wire, lutc=lutc, chainc=chainc)
                arr, _ = jax.lax.scan(bk, arr, xs)
            return jnp.maximum(jnp.max(arr[po]), 1.0)

        inner = jax.vmap(one, in_axes=(None, None, 0, 0, 0, 0))  # arch axis
        outer = jax.vmap(inner, in_axes=(0, 0, None, None, None, None))
        return jax.jit(outer)

    def run(self, delay_tables: np.ndarray) -> np.ndarray:
        """Critical paths ``[G, K]`` for delay rows ``[K, |DELAY_FIELDS|]``."""
        from jax.experimental import enable_x64

        comps = delay_components(np.asarray(delay_tables, dtype=np.float64))
        with enable_x64():
            if self._jit is None:
                jit = (_JIT_CACHE.get(self.shape_key)
                       if self.shape_key is not None else None)
                if jit is None:
                    jit = self._build_jit()
                    _COMPILE_COUNTS["jit_built"] += 1
                    if self.shape_key is not None:
                        _JIT_CACHE[self.shape_key] = jit
                else:
                    _COMPILE_COUNTS["jit_reused"] += 1
                self._jit = jit
            cps = self._jit(self._tensors, self._po, comps["edge"],
                            comps["wire"], comps["lut"], comps["chain"])
            # rows past n_members are pad members (cp 1.0), sliced away
            return np.asarray(cps, dtype=np.float64)[:self.n_members]


def build_suite_timing_program(irs: Sequence[CircuitIR],
                               max_buckets: int = 3,
                               pad_shapes: bool = False
                               ) -> SuiteTimingProgram:
    """Stack many circuits' CircuitIRs into one width-bucketed timing program.

    Levels are aligned to the longest member, the combined width profile
    is segmented by the evaluator's padded-volume DP, and every member is
    padded to the bucket envelopes with null rows (sink-scattering,
    zero-gathering).  One program serves the whole suite.

    ``pad_shapes=True`` additionally quantizes every compiled dimension
    (signal space, member count, PO width, per-bucket level count and
    envelope) up to the next power of two, so *different* batch
    compositions land on the same shape signature and share one jit
    executable through the module ``_JIT_CACHE`` — the flow server's
    edit streams and rotating tenant batches stop recompiling per batch.
    Padding is value-neutral by the model invariant documented in the
    module docstring: pad slots gather CONST0 through the all-zero null
    edge class, pad members scatter only to the sink and are sliced off
    by :meth:`SuiteTimingProgram.run`."""
    import jax.numpy as jnp

    if not irs:
        raise ValueError("empty IR list")
    L = max(ir.n_levels for ir in irs)
    m, c, b = combined_profile([ir.level_profile() for ir in irs], L)
    L = max(L, 1)
    bounds = segment_levels(m, c, b, max_buckets)
    envelopes = bucket_envelopes(m, c, b, bounds)
    n_sig = max(ir.n_signals for ir in irs)
    G = len(irs)
    G_alloc = G
    P = max(max((ir.po_sig.size for ir in irs), default=1), 1)
    shapes = [(max(j - i, 1), max(M, 1), max(C, 1), max(B, 1))
              for (i, j), (M, C, B) in zip(bounds, envelopes)]
    if pad_shapes:
        n_sig = _pad_dim(n_sig, floor=64)
        G_alloc = _pad_dim(G, floor=1)
        P = _pad_dim(P, floor=4)
        shapes = [(_pad_dim(l), _pad_dim(M1), _pad_dim(C1, floor=1),
                   _pad_dim(B1, floor=1)) for l, M1, C1, B1 in shapes]
    sink = n_sig
    members = [_pad_levels(ir, bounds, shapes, sink) for ir in irs]
    members += [[_alloc_bucket(*s, sink) for s in shapes]
                ] * (G_alloc - G)                           # pad members
    tensors = tuple(
        tuple(jnp.asarray(np.stack([mb[bi][ai] for mb in members]))
              for ai in range(17))
        for bi in range(len(bounds)))
    po = np.zeros((G_alloc, P), dtype=np.int32)    # pad -> CONST0 (arr 0.0)
    for g, ir in enumerate(irs):
        po[g, :ir.po_sig.size] = ir.po_sig
    flags = tuple(
        (any(mb[bi][3].min() < sink for mb in members[:G]),  # any real lut out
         any(mb[bi][11].min() < sink or (mb[bi][10] < sink).any()
             for mb in members[:G]))                         # any real chain
        for bi in range(len(bounds)))
    _COMPILE_COUNTS["programs"] += 1
    return SuiteTimingProgram(
        n_sig=n_sig, n_members=G, flags=flags, bucket_shapes=tuple(shapes),
        _tensors=tensors, _po=jnp.asarray(po),
        shape_key=(n_sig, G_alloc, P, flags, tuple(shapes)))
