"""Netlist intermediate representation for the Double-Duty CAD stack.

The IR models exactly the primitives that matter for the paper's experiments:

* **k-LUT nodes** — arbitrary boolean functions of up to ``MAX_LUT_K`` inputs,
  stored as truth-table integers (bit ``i`` of ``tt`` is the output for input
  assignment ``i``, where input ``j`` contributes bit ``j`` of ``i``).
* **carry chains** — runs of 1-bit full adders with a ripple carry, the
  hard-adder resource of a Stratix-like ALM (2 FA bits per ALM).
* **primary inputs / outputs** — grouped into named buses.

Signals are dense integer ids.  Signal 0 is constant-0 and signal 1 is
constant-1.  Structural hashing deduplicates identical LUTs and identical
carry chains — the mechanism behind the paper's "duplicate adder chain"
optimization (§IV, *Unrolled Multiplication*).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence

CONST0 = 0
CONST1 = 1

MAX_LUT_K = 6

# ---------------------------------------------------------------------------
# truth-table helpers
# ---------------------------------------------------------------------------


def tt_const(value: bool, k: int = 0) -> int:
    mask = (1 << (1 << k)) - 1
    return mask if value else 0


def tt_var(j: int, k: int) -> int:
    """Truth table (over k inputs) of input variable ``j``."""
    out = 0
    for m in range(1 << k):
        if (m >> j) & 1:
            out |= 1 << m
    return out


def tt_eval(tt: int, assignment: int) -> int:
    return (tt >> assignment) & 1


def tt_words64(tt: int, k: int) -> tuple[int, int]:
    """Replicate a ``k``-input table into a 64-entry mask, split into
    (lo, hi) uint32 words — the evaluator's per-row LUT payload.  The
    replication makes every pin beyond ``k`` a don't-care, so padded pin
    slots may hold any signal (the lowering pads with CONST0)."""
    full = 0
    for r in range(1 << (6 - k)):
        full |= tt << (r * (1 << k))
    full &= (1 << 64) - 1
    return full & 0xFFFFFFFF, full >> 32


def tt_from_fn(fn, k: int) -> int:
    out = 0
    for m in range(1 << k):
        bits = [(m >> j) & 1 for j in range(k)]
        if fn(*bits):
            out |= 1 << m
    return out


# common tables (indexed little-endian: input0 is bit0 of the assignment)
TT_BUF = 0b10                                 # 1 input
TT_NOT = 0b01
TT_AND2 = tt_from_fn(lambda a, b: a & b, 2)
TT_XOR2 = tt_from_fn(lambda a, b: a ^ b, 2)
TT_OR2 = tt_from_fn(lambda a, b: a | b, 2)
TT_XOR3 = tt_from_fn(lambda a, b, c: a ^ b ^ c, 3)
TT_MAJ3 = tt_from_fn(lambda a, b, c: (a & b) | (c & (a | b)), 3)
TT_MUX = tt_from_fn(lambda s, a, b: b if s else a, 3)  # s ? b : a


def tt_compose(outer_tt: int, outer_inputs: Sequence[int], pin: int,
               inner_tt: int, inner_inputs: Sequence[int]):
    """Substitute ``inner`` into pin ``pin`` of ``outer``.

    Returns ``(new_inputs, new_tt)`` over the merged support.  Used by the
    ABC-lite technology mapper to collapse single-fanout logic.
    """
    merged: list[int] = [s for i, s in enumerate(outer_inputs) if i != pin]
    for s in inner_inputs:
        if s not in merged:
            merged.append(s)
    k = len(merged)
    if k > MAX_LUT_K:
        raise ValueError("composition exceeds MAX_LUT_K")
    pos = {s: j for j, s in enumerate(merged)}
    new_tt = 0
    for m in range(1 << k):
        inner_asgn = 0
        for j, s in enumerate(inner_inputs):
            if (m >> pos[s]) & 1:
                inner_asgn |= 1 << j
        inner_val = tt_eval(inner_tt, inner_asgn)
        outer_asgn = 0
        oj = 0
        for i, s in enumerate(outer_inputs):
            if i == pin:
                bit = inner_val
            else:
                bit = (m >> pos[s]) & 1
            if bit:
                outer_asgn |= 1 << i
        if tt_eval(outer_tt, outer_asgn):
            new_tt |= 1 << m
    return tuple(merged), new_tt


def tt_reduce(inputs: Sequence[int], tt: int):
    """Drop constant / duplicate / don't-care inputs.

    Returns a canonicalized ``(inputs, tt)`` pair (possibly 0 inputs →
    constant).  Keeps the mapper honest about LUT sizes.
    """
    inputs = list(inputs)
    # substitute constants
    changed = True
    while changed:
        changed = False
        k = len(inputs)
        for j, s in enumerate(inputs):
            if s in (CONST0, CONST1):
                bit = 1 if s == CONST1 else 0
                new_tt = 0
                nk = k - 1
                for m in range(1 << nk):
                    full = _insert_bit(m, j, bit)
                    if tt_eval(tt, full):
                        new_tt |= 1 << m
                tt = new_tt
                inputs.pop(j)
                changed = True
                break
        if changed:
            continue
        k = len(inputs)
        # duplicate inputs
        seen: dict[int, int] = {}
        for j, s in enumerate(inputs):
            if s in seen:
                jj = seen[s]
                new_tt = 0
                nk = k - 1
                for m in range(1 << nk):
                    full = _insert_bit(m, j, (m >> (jj if jj < j else jj - 1)) & 1)
                    if tt_eval(tt, full):
                        new_tt |= 1 << m
                tt = new_tt
                inputs.pop(j)
                changed = True
                break
            seen[s] = j
        if changed:
            continue
        # don't-care inputs
        k = len(inputs)
        for j in range(k):
            lo = 0
            hi = 0
            nk = k - 1
            care = False
            for m in range(1 << nk):
                b0 = tt_eval(tt, _insert_bit(m, j, 0))
                b1 = tt_eval(tt, _insert_bit(m, j, 1))
                if b0 != b1:
                    care = True
                    break
                if b0:
                    lo |= 1 << m
            if not care:
                tt = lo
                inputs.pop(j)
                changed = True
                break
    return tuple(inputs), tt


def _insert_bit(m: int, j: int, bit: int) -> int:
    low = m & ((1 << j) - 1)
    high = m >> j
    return low | (bit << j) | (high << (j + 1))


# ---------------------------------------------------------------------------
# netlist
# ---------------------------------------------------------------------------


@dataclass
class Chain:
    """A ripple-carry chain of 1-bit full adders.

    Bit ``i`` computes ``sums[i] = a[i] ^ b[i] ^ c_i`` with
    ``c_{i+1} = MAJ(a[i], b[i], c_i)`` and ``c_0 = cin``.
    """

    a: list[int]
    b: list[int]
    sums: list[int]
    cin: int = CONST0
    cout: int | None = None

    def n_adders(self) -> int:
        return len(self.sums)


class Netlist:
    def __init__(self, name: str = "") -> None:
        self.name = name
        self.n_signals = 2  # const0, const1
        self.pis: list[int] = []
        self.pi_buses: dict[str, list[int]] = {}
        self.pos: dict[str, list[int]] = {}
        self.lut_inputs: list[tuple[int, ...]] = []
        self.lut_tt: list[int] = []
        self.lut_out: list[int] = []
        self.chains: list[Chain] = []
        # structural hashing
        self._lut_cache: dict[tuple, int] = {}
        self._chain_cache: dict[tuple, int] = {}
        # signal -> driver: ("pi",idx) ("lut",idx) ("chain",ci,bi) ("cout",ci)
        self.driver: dict[int, tuple] = {}

    # -- construction -------------------------------------------------------
    def new_sig(self) -> int:
        s = self.n_signals
        self.n_signals += 1
        return s

    def add_pi_bus(self, name: str, width: int) -> list[int]:
        bus = []
        for i in range(width):
            s = self.new_sig()
            self.pis.append(s)
            self.driver[s] = ("pi", len(self.pis) - 1)
            bus.append(s)
        self.pi_buses[name] = bus
        return bus

    def set_po_bus(self, name: str, bus: Sequence[int]) -> None:
        self.pos[name] = list(bus)

    def add_lut(self, inputs: Sequence[int], tt: int) -> int:
        inputs, tt = tt_reduce(inputs, tt)
        if len(inputs) == 0:
            return CONST1 if tt & 1 else CONST0
        if len(inputs) == 1 and tt == TT_BUF:
            return inputs[0]
        if len(inputs) > MAX_LUT_K:
            raise ValueError(f"LUT with {len(inputs)} inputs > {MAX_LUT_K}")
        key = (inputs, tt)
        hit = self._lut_cache.get(key)
        if hit is not None:
            return self.lut_out[hit]
        out = self.new_sig()
        idx = len(self.lut_out)
        self.lut_inputs.append(inputs)
        self.lut_tt.append(tt)
        self.lut_out.append(out)
        self._lut_cache[key] = idx
        self.driver[out] = ("lut", idx)
        return out

    def add_chain(self, a: Sequence[int], b: Sequence[int], cin: int = CONST0,
                  want_cout: bool = False) -> tuple[list[int], int | None]:
        """Add (or reuse) a full-adder chain summing two aligned bit vectors.

        ``a`` and ``b`` must have equal length; pad with CONST0 first.
        Returns ``(sum_bits, cout_signal_or_None)``.  Chains are structurally
        hashed: an identical (a, b, cin) chain is emitted once and fanned out,
        implementing the paper's duplicate-adder-chain optimization.
        """
        a = list(a)
        b = list(b)
        assert len(a) == len(b) and len(a) > 0
        key = (tuple(a), tuple(b), cin)
        hit = self._chain_cache.get(key)
        if hit is not None:
            ch = self.chains[hit]
            if want_cout and ch.cout is None:
                ch.cout = self.new_sig()
                self.driver[ch.cout] = ("cout", hit)
            return list(ch.sums), ch.cout
        sums = [self.new_sig() for _ in a]
        ci = len(self.chains)
        cout = None
        if want_cout:
            cout = self.new_sig()
        ch = Chain(a=a, b=b, sums=sums, cin=cin, cout=cout)
        self.chains.append(ch)
        self._chain_cache[key] = ci
        for bi, s in enumerate(sums):
            self.driver[s] = ("chain", ci, bi)
        if cout is not None:
            self.driver[cout] = ("cout", ci)
        return sums, cout

    def content_digest(self) -> str:
        """Digest of the netlist's *structure* (signals, LUTs, chains,
        POs — not the name).  This is the cache key every caller-owned
        pack/plan/program cache must use: keys derived from a circuit's
        position in a list silently serve wrong entries when the same
        cache is passed with a different list (see
        :func:`repro.core.sweep.sweep_suite`).  Deliberately uncached:
        callers may mutate netlist attributes directly, so a stale
        digest would defeat the content keying this exists for."""
        import hashlib

        h = hashlib.blake2b(digest_size=16)
        h.update(repr((self.n_signals, tuple(self.pis),
                       tuple(self.lut_inputs), tuple(self.lut_tt),
                       tuple(self.lut_out),
                       tuple((tuple(c.a), tuple(c.b), tuple(c.sums),
                              c.cin, c.cout) for c in self.chains),
                       tuple(sorted((k, tuple(v))
                                    for k, v in self.pos.items()))
                       )).encode())
        return h.hexdigest()

    def pack_digest(self) -> str:
        """Digest of the *pack-and-timing-relevant* structure — everything
        :meth:`content_digest` covers **except the LUT truth tables**.
        Neither the packer (absorption / chain slotting / pairing /
        clustering read only connectivity) nor static timing (delays are
        per-edge-class, never per-function) ever reads ``lut_tt``, so two
        netlists with equal pack digests produce byte-identical
        ``pack()`` results and identical timing/area records under every
        (arch, seed).  This is the key behind the flow server's
        netlist-delta fast path (:mod:`repro.core.serve_flow`): a
        truth-table-only edit — the shape of an incremental-synthesis
        weight/constant update — reuses the base request's pack and
        timing outright and re-runs only functional evaluation."""
        import hashlib

        h = hashlib.blake2b(digest_size=16)
        h.update(repr((self.n_signals, tuple(self.pis),
                       tuple(self.lut_inputs),
                       tuple(self.lut_out),
                       tuple((tuple(c.a), tuple(c.b), tuple(c.sums),
                              c.cin, c.cout) for c in self.chains),
                       tuple(sorted((k, tuple(v))
                                    for k, v in self.pos.items()))
                       )).encode())
        return h.hexdigest()

    def lower_ir(self):
        """The functional columnar :class:`~repro.core.circuit_ir.CircuitIR`
        of this netlist (levelized node rows with truth-table words, signal
        kind/level columns, fanin CSR topology — no placement columns).
        Content-cached in the shared registry: this is the single
        levelization that the fused evaluator, the equivalence lanes and
        every packed lowering of this circuit consume."""
        from .circuit_ir import lower_netlist_ir

        return lower_netlist_ir(self)

    # -- stats --------------------------------------------------------------
    @property
    def n_luts(self) -> int:
        return len(self.lut_out)

    @property
    def n_adders(self) -> int:
        return sum(c.n_adders() for c in self.chains)

    def lut_size_histogram(self) -> dict[int, int]:
        hist: dict[int, int] = {}
        for ins in self.lut_inputs:
            hist[len(ins)] = hist.get(len(ins), 0) + 1
        return hist

    def stats(self) -> dict:
        return {
            "name": self.name,
            "pis": len(self.pis),
            "pos": sum(len(v) for v in self.pos.values()),
            "luts": self.n_luts,
            "adders": self.n_adders,
            "chains": len(self.chains),
            "lut_hist": self.lut_size_histogram(),
        }

    # -- topology ------------------------------------------------------------
    def node_list(self) -> list[tuple]:
        """All nodes: ("lut", i) and ("chain", i)."""
        return [("lut", i) for i in range(self.n_luts)] + [
            ("chain", i) for i in range(len(self.chains))
        ]

    def node_inputs(self, node: tuple) -> list[int]:
        kind, idx = node
        if kind == "lut":
            return list(self.lut_inputs[idx])
        ch = self.chains[idx]
        ins = list(ch.a) + list(ch.b)
        if ch.cin not in (CONST0, CONST1):
            ins.append(ch.cin)
        return ins

    def node_outputs(self, node: tuple) -> list[int]:
        kind, idx = node
        if kind == "lut":
            return [self.lut_out[idx]]
        ch = self.chains[idx]
        outs = list(ch.sums)
        if ch.cout is not None:
            outs.append(ch.cout)
        return outs

    def topo_order(self) -> list[tuple]:
        """Kahn topological order over LUT/chain nodes."""
        nodes = self.node_list()
        produced_by: dict[int, tuple] = {}
        for nd in nodes:
            for s in self.node_outputs(nd):
                produced_by[s] = nd
        indeg: dict[tuple, int] = {nd: 0 for nd in nodes}
        consumers: dict[tuple, list[tuple]] = {nd: [] for nd in nodes}
        for nd in nodes:
            deps = set()
            for s in self.node_inputs(nd):
                p = produced_by.get(s)
                if p is not None and p != nd:
                    deps.add(p)
            indeg[nd] = len(deps)
            for p in deps:
                consumers[p].append(nd)
        from collections import deque

        q = deque([nd for nd in nodes if indeg[nd] == 0])
        order = []
        while q:
            nd = q.popleft()
            order.append(nd)
            for c in consumers[nd]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    q.append(c)
        if len(order) != len(nodes):
            raise RuntimeError("combinational cycle in netlist")
        return order

    def sweep(self) -> "Netlist":
        """Dead-code elimination: keep only logic reachable from the POs."""
        live: set[int] = set()
        for bus in self.pos.values():
            live.update(bus)
        produced_by: dict[int, tuple] = {}
        for nd in self.node_list():
            for s in self.node_outputs(nd):
                produced_by[s] = nd
        stack = list(live)
        live_nodes: set[tuple] = set()
        seen_sigs = set(stack)
        while stack:
            s = stack.pop()
            nd = produced_by.get(s)
            if nd is None or nd in live_nodes:
                continue
            live_nodes.add(nd)
            for t in self.node_inputs(nd):
                if t not in seen_sigs:
                    seen_sigs.add(t)
                    stack.append(t)
        out = Netlist(self.name)
        out.n_signals = self.n_signals
        out.pis = list(self.pis)
        out.pi_buses = dict(self.pi_buses)
        for s in self.pis:
            out.driver[s] = self.driver[s]
        for i in range(self.n_luts):
            if ("lut", i) in live_nodes:
                idx = len(out.lut_out)
                out.lut_inputs.append(self.lut_inputs[i])
                out.lut_tt.append(self.lut_tt[i])
                out.lut_out.append(self.lut_out[i])
                out.driver[self.lut_out[i]] = ("lut", idx)
                out._lut_cache[(self.lut_inputs[i], self.lut_tt[i])] = idx
        for i, ch in enumerate(self.chains):
            if ("chain", i) in live_nodes:
                ci = len(out.chains)
                out.chains.append(ch)
                out._chain_cache[(tuple(ch.a), tuple(ch.b), ch.cin)] = ci
                for bi, s in enumerate(ch.sums):
                    out.driver[s] = ("chain", ci, bi)
                if ch.cout is not None:
                    out.driver[ch.cout] = ("cout", ci)
        out.pos = {k: list(v) for k, v in self.pos.items()}
        return out


# ---------------------------------------------------------------------------
# pure-python functional evaluation (reference oracle for tests)
# ---------------------------------------------------------------------------


def eval_netlist(net: Netlist, pi_values: dict[int, int], n_vectors: int = 1):
    """Evaluate bit-parallel over arbitrary-width python ints.

    ``pi_values[signal] = int`` whose bit ``v`` is the signal's value in test
    vector ``v``.  Returns ``dict signal -> int`` for every signal.
    """
    mask = (1 << n_vectors) - 1
    val: dict[int, int] = {CONST0: 0, CONST1: mask}
    val.update({s: v & mask for s, v in pi_values.items()})
    for nd in net.topo_order():
        kind, idx = nd
        if kind == "lut":
            ins = net.lut_inputs[idx]
            tt = net.lut_tt[idx]
            out = 0
            # sum-of-minterms, bit-parallel
            for m in range(1 << len(ins)):
                if not tt_eval(tt, m):
                    continue
                term = mask
                for j, s in enumerate(ins):
                    sv = val[s]
                    term &= sv if (m >> j) & 1 else (~sv & mask)
                    if term == 0:
                        break
                out |= term
            val[net.lut_out[idx]] = out
        else:
            ch = net.chains[idx]
            c = val[ch.cin]
            for i in range(len(ch.sums)):
                av, bv = val[ch.a[i]], val[ch.b[i]]
                val[ch.sums[i]] = av ^ bv ^ c
                c = (av & bv) | (c & (av ^ bv))
            if ch.cout is not None:
                val[ch.cout] = c
    return val


def bus_to_ints(val: dict[int, int], bus: Sequence[int], n_vectors: int) -> list[int]:
    """Decode a bus (LSB-first signal list) into per-vector integers."""
    out = []
    for v in range(n_vectors):
        x = 0
        for j, s in enumerate(bus):
            if (val[s] >> v) & 1:
                x |= 1 << j
        out.append(x)
    return out
