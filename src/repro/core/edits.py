"""Structural netlist edits with stable indices.

The incremental-repack contract (``core/repack.py``) is defined over
*index-stable* edits: the edited netlist must keep every signal id, LUT
index and chain index of its base so that a dirty set is meaningful.
:class:`repro.core.netlist.Netlist` can't express that through its
public builders — ``add_lut`` canonicalizes through ``tt_reduce`` and
structural hashing, so re-building an edited circuit renumbers
everything.  This module provides the sanctioned mutators instead:
:func:`clone_netlist` copies a netlist field-by-field (bypassing the
hash-consing caches' rebuild), and the ``edit_*`` operators patch one
node while keeping the driver map and hash caches coherent.

Edit classes map onto repack paths as follows:

=====================  ==============================================
edit                   repack path
=====================  ==============================================
``edit_lut_tt``        tt-only: prefix shared, advised replay all-skip
``edit_rewire_fanin``  incremental: dirty-set replay (non-absorbed LUT)
``edit_add_lut``       full fallback (signal/LUT count changed)
``edit_remove_lut``    full fallback (LUT indices shifted)
``edit_extend_chain``  full fallback (chain shape changed)
=====================  ==============================================

Every operator returns the signal/index it touched so callers (fuzz
stream, serve benchmark) can chain edits; all of them keep the netlist
valid for ``pack()`` — acyclic, driver-complete, hash caches in sync.
"""
from __future__ import annotations

from .circuit_ir import levelize
from .netlist import CONST1, Chain, Netlist


def clone_netlist(net: Netlist) -> Netlist:
    """Deep-copy a netlist preserving every index — the base for an
    in-place structural edit.  Field-level copy, not re-construction:
    ``add_lut`` would canonicalize and hash-cons, renumbering nodes."""
    c = Netlist.__new__(Netlist)
    c.name = net.name
    c.n_signals = net.n_signals
    c.pis = list(net.pis)
    c.pi_buses = {k: list(v) for k, v in net.pi_buses.items()}
    c.pos = {k: list(v) for k, v in net.pos.items()}
    c.lut_inputs = list(net.lut_inputs)
    c.lut_tt = list(net.lut_tt)
    c.lut_out = list(net.lut_out)
    c.chains = [Chain(list(ch.a), list(ch.b), list(ch.sums), ch.cin,
                      ch.cout) for ch in net.chains]
    c._lut_cache = dict(net._lut_cache)
    c._chain_cache = dict(net._chain_cache)
    c.driver = dict(net.driver)
    return c


def _uncache_lut(net: Netlist, li: int) -> None:
    key = (net.lut_inputs[li], net.lut_tt[li])
    if net._lut_cache.get(key) == li:
        del net._lut_cache[key]


def _recache_lut(net: Netlist, li: int) -> None:
    key = (net.lut_inputs[li], net.lut_tt[li])
    net._lut_cache.setdefault(key, li)


def safe_rewire_sources(net: Netlist, li: int) -> list[int]:
    """Signals LUT ``li`` may legally take as an input: anything whose
    topological level is strictly below the LUT's output level (hence
    provably not in its transitive fanout) and not a constant."""
    _, _, sig_level = levelize(net)
    lv = sig_level.get(net.lut_out[li], 0)
    return [s for s in range(2, net.n_signals)
            if sig_level.get(s, 0) < lv and s in net.driver]


def edit_rewire_fanin(net: Netlist, li: int, pin: int,
                      new_sig: int) -> int:
    """Repoint pin ``pin`` of LUT ``li`` at ``new_sig`` in place.  The
    caller guarantees acyclicity (see :func:`safe_rewire_sources`)."""
    ins = net.lut_inputs[li]
    if not 0 <= pin < len(ins):
        raise IndexError(f"lut {li} has no pin {pin}")
    if new_sig >= net.n_signals or new_sig <= CONST1:
        raise ValueError(f"bad rewire target {new_sig}")
    _uncache_lut(net, li)
    net.lut_inputs[li] = ins[:pin] + (new_sig,) + ins[pin + 1:]
    _recache_lut(net, li)
    return net.lut_out[li]


def edit_lut_tt(net: Netlist, li: int, new_tt: int) -> int:
    """Replace LUT ``li``'s truth table in place (same support shape).
    Pack-irrelevant: ``pack_digest`` is unchanged."""
    k = len(net.lut_inputs[li])
    new_tt &= (1 << (1 << k)) - 1
    _uncache_lut(net, li)
    net.lut_tt[li] = new_tt
    _recache_lut(net, li)
    return net.lut_out[li]


def edit_add_lut(net: Netlist, inputs, tt: int,
                 po_bus: str = "__edit_taps") -> int:
    """Append a fresh LUT node (no canonicalization, no hash-cons hit)
    and tap it onto ``po_bus`` so it stays live through equivalence."""
    inputs = tuple(inputs)
    if not inputs or any(s >= net.n_signals for s in inputs):
        raise ValueError("bad LUT inputs")
    out = net.new_sig()
    li = len(net.lut_out)
    net.lut_inputs.append(inputs)
    net.lut_tt.append(tt & ((1 << (1 << len(inputs))) - 1))
    net.lut_out.append(out)
    net.driver[out] = ("lut", li)
    _recache_lut(net, li)
    net.pos.setdefault(po_bus, []).append(out)
    return li


def edit_remove_lut(net: Netlist, li: int) -> int:
    """Delete LUT ``li``; it must be dead (no consumer, no PO).  Shifts
    every higher LUT index down by one and remaps the driver table; the
    orphaned output signal keeps its id but loses its driver."""
    out = net.lut_out[li]
    for ins in net.lut_inputs:
        if out in ins:
            raise ValueError(f"lut {li} has LUT fanout")
    for ch in net.chains:
        if out in ch.a or out in ch.b or out == ch.cin:
            raise ValueError(f"lut {li} feeds a chain")
    if any(out in bus for bus in net.pos.values()):
        raise ValueError(f"lut {li} is a primary output")
    _uncache_lut(net, li)
    del net.lut_inputs[li], net.lut_tt[li], net.lut_out[li]
    del net.driver[out]
    net._lut_cache = {k: (v - 1 if v > li else v)
                      for k, v in net._lut_cache.items() if v != li}
    for s, drv in list(net.driver.items()):
        if drv[0] == "lut" and drv[1] > li:
            net.driver[s] = ("lut", drv[1] - 1)
    return out


def edit_extend_chain(net: Netlist, ci: int, a_sig: int, b_sig: int,
                      po_bus: str = "__edit_taps") -> int:
    """Grow chain ``ci`` by one full-adder bit fed by ``a_sig``/``b_sig``
    (which must not depend on the chain — callers pick PIs or upstream
    signals) and tap the new sum bit as a PO."""
    ch = net.chains[ci]
    old_key = (tuple(ch.a), tuple(ch.b), ch.cin)
    if net._chain_cache.get(old_key) == ci:
        del net._chain_cache[old_key]
    s = net.new_sig()
    ch.a.append(a_sig)
    ch.b.append(b_sig)
    ch.sums.append(s)
    net.driver[s] = ("chain", ci, len(ch.sums) - 1)
    net._chain_cache.setdefault((tuple(ch.a), tuple(ch.b), ch.cin), ci)
    net.pos.setdefault(po_bus, []).append(s)
    return s
