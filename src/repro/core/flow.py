"""The unified CAD flow pipeline: synth → techmap → pack → equiv → eval.

Every benchmark driver and test drives the paper's flow through this
module instead of hand-rolling its own pack/analyze/verify/evaluate loop.
The stages:

* **synthesis + techmap** happen inside the circuit generators
  (``core.circuits``); the flow consumes finished :class:`Netlist`\\ s.
* **pack + analyze** — :func:`pack_and_analyze` packs under an
  architecture across placement seeds and averages the
  :func:`~repro.core.timing.analyze` metrics (the paper averages three
  seeds); :func:`pack_and_analyze_one` keeps the packed circuit for
  callers that need structural access (stress capacity sweeps).  Timing
  runs on the columnar :class:`~repro.core.pack_ir.PackIR` through the
  vectorized analyzer (bit-identical to the Python oracle) — figure
  drivers never re-walk the packed object graph.
* **design-space sweeps** — :func:`sweep_architectures` /
  :func:`sweep_frontier` drive :mod:`repro.core.sweep`: pack once per
  structural class, re-time the whole suite across an arch grid
  (:func:`repro.core.alm.arch_grid`) as one batched jit program per
  class, and reduce to geomean ADP-frontier rows.
* **equivalence gate** — :func:`run_circuit` optionally proves pack
  equivalence per arch through :mod:`repro.core.equiv` (symbolic fast
  path first, lane simulation as fallback), so any figure can be gated on
  "the comparison is apples-to-apples".
* **evaluation** — :func:`evaluate_netlist` / :func:`evaluate_suite` run
  the width-bucketed fused engine (:mod:`repro.core.eval_jax`).
  :func:`evaluate_suite` clusters a whole benchmark suite into a few
  compatible-envelope groups, so Kratos + Koios + VTR evaluate per arch
  as a handful of vmapped jit programs; plans and grouped tensors are
  content-cached, so repeated figures reuse compiles.
* :func:`oracle_check` closes the loop: any JAX-side result can be
  proven bit-identical to the pure-Python ``eval_netlist`` oracle.

Ratios against a baseline arch (the shape of Figs. 5-7) come from
:func:`ratios_vs_baseline`; :func:`run_suites` maps the whole pipeline
over named suites.
"""
from __future__ import annotations

import random
from typing import Callable, Sequence

import numpy as np

from .alm import ARCHS, ArchParams
from .equiv import check_pack_equivalence
from .eval_jax import (DEFAULT_MAX_BUCKETS, DEFAULT_MAX_GROUPS, FusedPlan,
                       SuiteProgram, eval_netlist_jax,
                       eval_netlists_batched_jax, plan_netlist,
                       prepare_suite_program)
from .netlist import Netlist, eval_netlist
from .packing import PackedCircuit, pack
from .timing import analyze

#: the paper averages three placement seeds per figure
DEFAULT_SEEDS = (0, 1, 2)

#: metrics whose per-seed mean makes up a flow record
_METRIC_KEYS = ("alms", "area_mwta", "critical_path_ps", "adp",
                "concurrent_luts", "lbs")


def _arch(arch: str | ArchParams) -> ArchParams:
    return ARCHS[arch] if isinstance(arch, str) else arch


# ---------------------------------------------------------------------------
# pack + analyze
# ---------------------------------------------------------------------------


def pack_and_analyze_one(net: Netlist, arch: str | ArchParams,
                         seed: int = 0) -> tuple[PackedCircuit, dict]:
    """One pack at one seed, returning both the packed circuit and its
    analysis — for flows that need structural access (capacity sweeps)."""
    packed = pack(net, _arch(arch), seed=seed)
    return packed, analyze(packed)


def pack_and_analyze(net: Netlist, arch: str | ArchParams,
                     seeds: Sequence[int] = DEFAULT_SEEDS) -> dict:
    """Average :func:`analyze` metrics over placement seeds."""
    acc: dict[str, float] = {}
    for s in seeds:
        r = analyze(pack(net, _arch(arch), seed=s))
        for k in _METRIC_KEYS:
            acc[k] = acc.get(k, 0.0) + r[k] / len(seeds)
    acc["adders"] = net.n_adders
    acc["luts"] = net.n_luts
    return acc


def run_circuit(net: Netlist, archs: Sequence[str | ArchParams],
                seeds: Sequence[int] = DEFAULT_SEEDS,
                check_equiv: bool = False, n_vectors: int = 64,
                equiv_method: str = "auto") -> dict[str, dict]:
    """Pack + analyze one circuit under several archs, optionally gated on
    pack equivalence.  Returns ``{arch_name: metrics}``; with
    ``check_equiv`` each record carries ``equivalent`` / ``equiv_method``
    and a non-equivalent pack raises ``AssertionError`` — a figure must
    not silently average a corrupted pack.
    """
    out: dict[str, dict] = {}
    for arch in archs:
        ap = _arch(arch)
        rec = pack_and_analyze(net, ap, seeds=seeds)
        if check_equiv:
            rep = check_pack_equivalence(net, ap, seed=seeds[0],
                                         n_vectors=n_vectors,
                                         method=equiv_method)
            if not rep["equivalent"]:
                if equiv_method == "symbolic" and not rep["mismatches"]:
                    # incomplete proof, not a disproof — name it as such
                    raise AssertionError(
                        f"{net.name}@{ap.name}: symbolic proof incomplete "
                        f"({len(rep.get('fallback', []))} unclosed cones); "
                        f"use equiv_method='auto' to simulate the residue")
                raise AssertionError(
                    f"{net.name}@{ap.name}: pack is NOT equivalent "
                    f"({rep['mismatches'][:1]})")
            rec["equivalent"] = True
            rec["equiv_method"] = rep.get("method", "simulate")
        out[ap.name] = rec
    return out


def sweep_architectures(suites_or_nets, archs=None, seed: int = 0,
                        backend: str = "jax", max_buckets: int = 3,
                        max_groups: int = 4,
                        packs: dict | None = None,
                        programs: dict | None = None,
                        prefixes: dict | None = None,
                        grid_axes: dict | None = None):
    """Design-space sweep over an architecture grid (see
    :func:`repro.core.sweep.sweep_suite`).  ``archs`` defaults to the
    full bypass-width x crossbar-population grid; pass any list of
    :class:`~repro.core.alm.ArchParams` rows (e.g. the canonical
    baseline/DD5/DD6 triple plus ablations), or ``grid_axes`` — keyword
    arguments for :func:`repro.core.alm.arch_grid` (e.g.
    ``{"alms_per_lb": (8, 10), "lb_inputs": (48, 60)}``) — to grow the
    grid along the structural cluster-geometry axes.

    ``max_groups`` (the timing-program envelope-grouping knob) is
    forwarded verbatim: a flow caller can now both match a direct
    ``sweep_suite`` configuration and hit a ``programs`` cache warmed
    with a non-default grouping.  ``packs``/``programs``/``prefixes``
    are the caller-owned content-keyed caches of ``sweep_suite``."""
    from .alm import arch_grid
    from .sweep import sweep_suite

    if archs is None:
        archs = arch_grid(**(grid_axes or {}))
    elif grid_axes is not None:
        raise ValueError("pass either archs or grid_axes, not both")
    return sweep_suite(suites_or_nets, archs, seed=seed, backend=backend,
                       max_buckets=max_buckets, max_groups=max_groups,
                       packs=packs, programs=programs, prefixes=prefixes)


def sweep_frontier(result, baseline: str | None = None):
    """Geomean area/cpd/ADP ratio rows vs a baseline grid point."""
    from .sweep import adp_frontier

    return adp_frontier(result, baseline=baseline)


def ratios_vs_baseline(per_arch: dict[str, dict], baseline: str = "baseline",
                       keys: Sequence[str] = ("area_mwta",
                                              "critical_path_ps", "adp")
                       ) -> dict[str, dict[str, float]]:
    """Per-arch metric ratios against ``per_arch[baseline]`` (Figs. 5-7)."""
    base = per_arch[baseline]
    return {name: {k: rec[k] / base[k] for k in keys}
            for name, rec in per_arch.items() if name != baseline}


def run_suites(suites: dict[str, list[Netlist]],
               archs: Sequence[str | ArchParams],
               seeds: Sequence[int] = DEFAULT_SEEDS,
               check_equiv: bool = False,
               per_circuit: Callable[[str, Netlist, dict], None]
               | None = None) -> dict[str, list[dict]]:
    """Map :func:`run_circuit` over named suites.

    Returns ``{suite: [{"net": name, "per_arch": {...}}, ...]}``;
    ``per_circuit(suite, net, per_arch)`` is an optional progress hook
    (benchmark drivers use it to emit CSV rows as results arrive).
    """
    out: dict[str, list[dict]] = {}
    for suite_name, nets in suites.items():
        rows = []
        for net in nets:
            per_arch = run_circuit(net, archs, seeds=seeds,
                                   check_equiv=check_equiv)
            rows.append({"net": net.name, "per_arch": per_arch})
            if per_circuit is not None:
                per_circuit(suite_name, net, per_arch)
        out[suite_name] = rows
    return out


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------


def random_lanes(net: Netlist, n_lane_words: int,
                 seed: int = 0) -> dict[int, np.ndarray]:
    """Random packed test vectors for every PI of ``net``."""
    rng = random.Random(seed)
    return {s: np.array([rng.getrandbits(32) for _ in range(n_lane_words)],
                        dtype=np.uint32) for s in net.pis}


def evaluate_netlist(net: Netlist, pi_lanes: dict[int, np.ndarray],
                     n_lane_words: int, use_pallas: bool = True,
                     max_buckets: int = DEFAULT_MAX_BUCKETS,
                     plan: FusedPlan | None = None) -> np.ndarray:
    """Single-circuit fused evaluation through the cached bucketed plan.

    Pass a precomputed ``plan`` in timing loops — it skips even the
    content-digest cache lookup.
    """
    if plan is None:
        plan = plan_netlist(net, max_buckets=max_buckets)
    return np.asarray(eval_netlist_jax(net, pi_lanes, n_lane_words,
                                       use_pallas=use_pallas, plan=plan))


def prepare_suite(nets: list[Netlist],
                  max_groups: int = DEFAULT_MAX_GROUPS,
                  max_buckets: int = DEFAULT_MAX_BUCKETS) -> SuiteProgram:
    """One-time suite preparation (clustering + stacked device tensors);
    reuse the returned program across :func:`evaluate_suite` calls."""
    return prepare_suite_program(nets, max_groups=max_groups,
                                 max_buckets=max_buckets)


def evaluate_suite(nets: list[Netlist],
                   pi_lanes_list: list[dict[int, np.ndarray]],
                   n_lane_words: int, use_pallas: bool = True,
                   max_groups: int = DEFAULT_MAX_GROUPS,
                   max_buckets: int = DEFAULT_MAX_BUCKETS,
                   program: SuiteProgram | None = None
                   ) -> tuple[list[np.ndarray], dict]:
    """Whole-suite evaluation as <= ``max_groups`` vmapped jit programs.

    Returns ``(per-circuit vals arrays, stats)`` where stats records the
    envelope groups, their bucket shapes, and padded-row counts.
    """
    return eval_netlists_batched_jax(
        nets, pi_lanes_list, n_lane_words, use_pallas=use_pallas,
        max_groups=max_groups, max_buckets=max_buckets, return_stats=True,
        program=program)


def oracle_check(net: Netlist, pi_lanes: dict[int, np.ndarray],
                 vals: np.ndarray, n_lane_words: int) -> bool:
    """Prove a JAX-side result bit-identical to the Python oracle on every
    primary output (all lane words)."""
    ok = True
    for w in range(n_lane_words):
        pi_vals = {s: int(pi_lanes[s][w]) for s in net.pis}
        ref = eval_netlist(net, pi_vals, 32)
        for bus in net.pos.values():
            for s in bus:
                if int(vals[s, w]) != (ref[s] & 0xFFFFFFFF):
                    return False
    return ok
