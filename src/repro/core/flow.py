"""The unified CAD flow pipeline: synth → techmap → pack → equiv → eval.

Every benchmark driver and test drives the paper's flow through this
module instead of hand-rolling its own pack/analyze/verify/evaluate loop.
The stages:

* **synthesis + techmap** happen inside the circuit generators
  (``core.circuits``); the flow consumes finished :class:`Netlist`\\ s.
* **pack + analyze** — :func:`pack_and_analyze` packs under an
  architecture across placement seeds and averages the
  :func:`~repro.core.timing.analyze` metrics (the paper averages three
  seeds); :func:`pack_and_analyze_one` keeps the packed circuit for
  callers that need structural access (stress capacity sweeps).  Timing
  runs on the columnar :class:`~repro.core.pack_ir.PackIR` through the
  vectorized analyzer (bit-identical to the Python oracle) — figure
  drivers never re-walk the packed object graph.
* **design-space sweeps** — :func:`sweep_architectures` /
  :func:`sweep_frontier` drive :mod:`repro.core.sweep`: pack once per
  structural class, re-time the whole suite across an arch grid
  (:func:`repro.core.alm.arch_grid`) as one batched jit program per
  class, and reduce to geomean ADP-frontier rows.
* **equivalence gate** — :func:`run_circuit` optionally proves pack
  equivalence per arch through :mod:`repro.core.equiv` (symbolic fast
  path first, lane simulation as fallback), so any figure can be gated on
  "the comparison is apples-to-apples".
* **evaluation** — :func:`evaluate_netlist` / :func:`evaluate_suite` run
  the width-bucketed fused engine (:mod:`repro.core.eval_jax`).
  :func:`evaluate_suite` clusters a whole benchmark suite into a few
  compatible-envelope groups, so Kratos + Koios + VTR evaluate per arch
  as a handful of vmapped jit programs; plans and grouped tensors are
  content-cached, so repeated figures reuse compiles.
* :func:`oracle_check` closes the loop: any JAX-side result can be
  proven bit-identical to the pure-Python ``eval_netlist`` oracle.

Ratios against a baseline arch (the shape of Figs. 5-7) come from
:func:`ratios_vs_baseline`; :func:`run_suites` maps the whole pipeline
over named suites.
"""
from __future__ import annotations

import random
from typing import Callable, Sequence

import numpy as np

from .alm import ARCHS, ArchParams
from .equiv import check_pack_equivalence
from .eval_jax import (DEFAULT_MAX_BUCKETS, DEFAULT_MAX_GROUPS, FusedPlan,
                       SuiteProgram, eval_netlist_jax,
                       eval_netlists_batched_jax, plan_netlist,
                       prepare_suite_program)
from .netlist import Netlist, eval_netlist
from .packing import PackedCircuit, pack
from .timing import analyze

#: the paper averages three placement seeds per figure
DEFAULT_SEEDS = (0, 1, 2)

#: metrics whose per-seed mean makes up a flow record
_METRIC_KEYS = ("alms", "area_mwta", "critical_path_ps", "adp",
                "concurrent_luts", "lbs")


def _arch(arch: str | ArchParams) -> ArchParams:
    return ARCHS[arch] if isinstance(arch, str) else arch


# ---------------------------------------------------------------------------
# pack + analyze
# ---------------------------------------------------------------------------


def pack_and_analyze_one(net: Netlist, arch: str | ArchParams,
                         seed: int = 0) -> tuple[PackedCircuit, dict]:
    """One pack at one seed, returning both the packed circuit and its
    analysis — for flows that need structural access (capacity sweeps)."""
    packed = pack(net, _arch(arch), seed=seed)
    return packed, analyze(packed)


def pack_and_analyze(net: Netlist, arch: str | ArchParams,
                     seeds: Sequence[int] = DEFAULT_SEEDS) -> dict:
    """Average :func:`analyze` metrics over placement seeds."""
    acc: dict[str, float] = {}
    for s in seeds:
        r = analyze(pack(net, _arch(arch), seed=s))
        for k in _METRIC_KEYS:
            acc[k] = acc.get(k, 0.0) + r[k] / len(seeds)
    acc["adders"] = net.n_adders
    acc["luts"] = net.n_luts
    return acc


def run_circuit(net: Netlist, archs: Sequence[str | ArchParams],
                seeds: Sequence[int] = DEFAULT_SEEDS,
                check_equiv: bool = False, n_vectors: int = 64,
                equiv_method: str = "auto") -> dict[str, dict]:
    """Pack + analyze one circuit under several archs, optionally gated on
    pack equivalence.  Returns ``{arch_name: metrics}``; with
    ``check_equiv`` each record carries ``equivalent`` / ``equiv_method``
    and a non-equivalent pack raises ``AssertionError`` — a figure must
    not silently average a corrupted pack.
    """
    out: dict[str, dict] = {}
    for arch in archs:
        ap = _arch(arch)
        rec = pack_and_analyze(net, ap, seeds=seeds)
        if check_equiv:
            rep = check_pack_equivalence(net, ap, seed=seeds[0],
                                         n_vectors=n_vectors,
                                         method=equiv_method)
            if not rep["equivalent"]:
                if equiv_method == "symbolic" and not rep["mismatches"]:
                    # incomplete proof, not a disproof — name it as such
                    raise AssertionError(
                        f"{net.name}@{ap.name}: symbolic proof incomplete "
                        f"({len(rep.get('fallback', []))} unclosed cones); "
                        f"use equiv_method='auto' to simulate the residue")
                raise AssertionError(
                    f"{net.name}@{ap.name}: pack is NOT equivalent "
                    f"({rep['mismatches'][:1]})")
            rec["equivalent"] = True
            rec["equiv_method"] = rep.get("method", "simulate")
        out[ap.name] = rec
    return out


def sweep_architectures(suites_or_nets, archs=None, seed: int = 0,
                        backend: str = "jax", max_buckets: int = 3,
                        max_groups: int = 4,
                        packs: dict | None = None,
                        programs: dict | None = None,
                        prefixes: dict | None = None,
                        grid_axes: dict | None = None,
                        place: bool = False,
                        refine: str | None = "anneal"):
    """Design-space sweep over an architecture grid (see
    :func:`repro.core.sweep.sweep_suite`).  ``archs`` defaults to the
    full bypass-width x crossbar-population grid; pass any list of
    :class:`~repro.core.alm.ArchParams` rows (e.g. the canonical
    baseline/DD5/DD6 triple plus ablations), or ``grid_axes`` — keyword
    arguments for :func:`repro.core.alm.arch_grid` (e.g.
    ``{"alms_per_lb": (8, 10), "lb_inputs": (48, 60)}``) — to grow the
    grid along the structural cluster-geometry axes.

    ``max_groups`` (the timing-program envelope-grouping knob) is
    forwarded verbatim: a flow caller can now both match a direct
    ``sweep_suite`` configuration and hit a ``programs`` cache warmed
    with a non-default grouping.  ``packs``/``programs``/``prefixes``
    are the caller-owned content-keyed caches of ``sweep_suite``.
    ``place=True`` grid-places every circuit and includes the wire-tier
    delay term (placements registry-cached per placement key, anneal-
    refined by default — ``refine`` forwards to
    :func:`repro.core.sweep.sweep_suite`; see :mod:`repro.core.place`
    and :mod:`repro.core.anneal`)."""
    from .alm import arch_grid
    from .sweep import sweep_suite

    if archs is None:
        archs = arch_grid(**(grid_axes or {}))
    elif grid_axes is not None:
        raise ValueError("pass either archs or grid_axes, not both")
    return sweep_suite(suites_or_nets, archs, seed=seed, backend=backend,
                       max_buckets=max_buckets, max_groups=max_groups,
                       packs=packs, programs=programs, prefixes=prefixes,
                       place=place, refine=refine)


def sweep_frontier(result, baseline: str | None = None):
    """Geomean area/cpd/ADP ratio rows vs a baseline grid point."""
    from .sweep import adp_frontier

    return adp_frontier(result, baseline=baseline)


def search_design_space(suites_or_nets, archs=None, seed: int = 0,
                        eta: int = 4, min_survivors: int = 8,
                        allocation: str = "halving",
                        budget: int | None = None,
                        baseline: str | None = None,
                        backend: str = "numpy", verify: bool = False,
                        **search_kwargs):
    """Pareto-aware successive-halving search over an arch grid (see
    :func:`repro.core.search.search_archs`).  ``archs`` defaults to the
    *full* design-space cross-product
    (:func:`repro.core.alm.full_arch_grid`, ~2000 points) and
    ``baseline`` to the grid's ``b0`` row when present.  ``verify=True``
    additionally proves every Pareto winner oracle-bit-identical and
    equivalence-gated (:func:`repro.core.search.verify_winners`) and
    attaches the report as ``result.verify``."""
    from .alm import full_arch_grid
    from .search import search_archs, verify_winners
    from .sweep import _flatten

    if archs is None:
        archs = full_arch_grid()
    if baseline is None and any(a.name == "b0" for a in archs):
        baseline = "b0"
    _, nets = _flatten(suites_or_nets)
    result = search_archs(nets, archs, seed=seed, eta=eta,
                          min_survivors=min_survivors,
                          allocation=allocation, budget=budget,
                          baseline=baseline, backend=backend,
                          **search_kwargs)
    if verify:
        result.verify = verify_winners(result, nets, archs, seed=seed)
    return result


def ratios_vs_baseline(per_arch: dict[str, dict], baseline: str = "baseline",
                       keys: Sequence[str] = ("area_mwta",
                                              "critical_path_ps", "adp")
                       ) -> dict[str, dict[str, float]]:
    """Per-arch metric ratios against ``per_arch[baseline]`` (Figs. 5-7)."""
    base = per_arch[baseline]
    return {name: {k: rec[k] / base[k] for k in keys}
            for name, rec in per_arch.items() if name != baseline}


def run_suites(suites: dict[str, list[Netlist]],
               archs: Sequence[str | ArchParams],
               seeds: Sequence[int] = DEFAULT_SEEDS,
               check_equiv: bool = False,
               per_circuit: Callable[[str, Netlist, dict], None]
               | None = None) -> dict[str, list[dict]]:
    """Map :func:`run_circuit` over named suites.

    Returns ``{suite: [{"net": name, "per_arch": {...}}, ...]}``;
    ``per_circuit(suite, net, per_arch)`` is an optional progress hook
    (benchmark drivers use it to emit CSV rows as results arrive).
    """
    out: dict[str, list[dict]] = {}
    for suite_name, nets in suites.items():
        rows = []
        for net in nets:
            per_arch = run_circuit(net, archs, seeds=seeds,
                                   check_equiv=check_equiv)
            rows.append({"net": net.name, "per_arch": per_arch})
            if per_circuit is not None:
                per_circuit(suite_name, net, per_arch)
        out[suite_name] = rows
    return out


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------


def random_lanes(net: Netlist, n_lane_words: int,
                 seed: int = 0) -> dict[int, np.ndarray]:
    """Random packed test vectors for every PI of ``net``."""
    rng = random.Random(seed)
    return {s: np.array([rng.getrandbits(32) for _ in range(n_lane_words)],
                        dtype=np.uint32) for s in net.pis}


def evaluate_netlist(net: Netlist, pi_lanes: dict[int, np.ndarray],
                     n_lane_words: int, use_pallas: bool = True,
                     max_buckets: int = DEFAULT_MAX_BUCKETS,
                     plan: FusedPlan | None = None) -> np.ndarray:
    """Single-circuit fused evaluation through the cached bucketed plan.

    Pass a precomputed ``plan`` in timing loops — it skips even the
    content-digest cache lookup.
    """
    if plan is None:
        plan = plan_netlist(net, max_buckets=max_buckets)
    return np.asarray(eval_netlist_jax(net, pi_lanes, n_lane_words,
                                       use_pallas=use_pallas, plan=plan))


def prepare_suite(nets: list[Netlist],
                  max_groups: int = DEFAULT_MAX_GROUPS,
                  max_buckets: int = DEFAULT_MAX_BUCKETS) -> SuiteProgram:
    """One-time suite preparation (clustering + stacked device tensors);
    reuse the returned program across :func:`evaluate_suite` calls."""
    return prepare_suite_program(nets, max_groups=max_groups,
                                 max_buckets=max_buckets)


#: padded-row-equivalents charged per program dispatch in the warm-path
#: cost model below — a program launch (value-buffer init + PI fill,
#: argument pytree flattening, dispatch, blocking result sync) costs
#: roughly what streaming this many padded rows through a scan step does.
#: Calibration: back-solving the measured warm walls of the 17-circuit
#: suite (``experiments/perf/suite_eval_grouped.json``) across two
#: recordings gives an implied dispatch cost anywhere from ~3k to ~20k
#: rows — the two paths sit within the host's run-to-run noise band and
#: the measured winner flips between recordings.  The constant is set at
#: the low end of that bracket deliberately: when the margin is inside
#: noise, a serial host should prefer the padding-free per-circuit
#: layout, and grouped should win only when envelope compatibility makes
#: the padding small relative to the saved dispatches.
EVAL_DISPATCH_ROW_COST = 4096

#: padded-row-equivalents charged per program COMPILE when the program's
#: shape signature has not run yet (``warm="auto"``, the default — see
#: :func:`repro.core.eval_jax.program_seen`) or the caller forces
#: ``warm=False``: the
#: recorded cold suite walls (``suite_eval_grouped.json``:
#: ``t_suite_per_circuit_s`` - ``t_suite_grouped_s`` over the compile-
#: count delta) imply ~3-4 s per program compile, ~10^7 rows at the
#: measured ~0.25 us/row.  This is what makes one-shot cold callers pick
#: grouped (few compiles) exactly as the pre-cost-model default did.
EVAL_COMPILE_ROW_COST = 1 << 24


def eval_mode_cost_model(nets: list[Netlist], plans=None, groups=None,
                         max_groups: int = DEFAULT_MAX_GROUPS,
                         max_buckets: int = DEFAULT_MAX_BUCKETS,
                         backend: str | None = None,
                         warm: bool | str = "auto",
                         n_lane_words: int | None = None,
                         use_pallas: bool = True) -> dict:
    """Backend-aware cost model: grouped vs per-circuit eval.

    Grouped evaluation trades program count (one compile + one dispatch
    per envelope group instead of one per circuit) for padded volume
    (every member pads to the group envelope).  On a serial host backend
    (``cpu``) the vmapped group axis executes sequentially, so the model
    charges the full ``rows_per_member * len(group)``; on parallel
    backends (``gpu``/``tpu``) the group axis maps to real parallelism
    and a group costs one member's padded rows.  Both sides are charged
    :data:`EVAL_DISPATCH_ROW_COST` rows per program, plus
    :data:`EVAL_COMPILE_ROW_COST` per program that is not yet compiled.

    Warmness is no longer caller-asserted: the default ``warm="auto"``
    derives it *per program* from the registry's record of programs that
    have actually run (:func:`repro.core.eval_jax.program_seen`, shape
    signatures matching jax's own jit keying) — on a mixed batch two
    circuits already served stay cheap while a new envelope is charged
    its compile, which the old all-or-nothing flag got wrong in both
    directions.  ``warm=True`` / ``False`` remain as forced overrides
    (benchmark loops that just cleared the jax cache, tests).
    ``n_lane_words`` sharpens the auto derivation (compiles are
    per-lane-shape); when unknown, a program compiled at any lane count
    counts as warm.  All row terms come from the unified
    :class:`~repro.core.circuit_ir.CircuitIR` profiles — no device
    tensors are built.  (ROADMAP "warm-path grouped eval" item.)
    """
    from .circuit_ir import lower_netlist_ir
    from .eval_jax import (group_layout, group_plans_by_envelope,
                           layout_program_signature, program_seen,
                           program_signature)

    if warm not in (True, False, "auto"):
        raise ValueError(f"warm must be True, False or 'auto': {warm!r}")
    if plans is None:
        plans = [plan_netlist(n, max_buckets=max_buckets) for n in nets]
    if groups is None:
        groups = group_plans_by_envelope(plans, max_groups=max_groups)
    if backend is None:
        import jax

        backend = jax.default_backend()
    parallel = backend in ("gpu", "tpu")

    def compile_cost(sig) -> int:
        if warm is True:
            return 0
        if warm is False:
            return EVAL_COMPILE_ROW_COST
        return 0 if program_seen(sig) else EVAL_COMPILE_ROW_COST

    irs = [lower_netlist_ir(n) for n in nets]
    single_rows = sum(p.padded_lut_rows + p.padded_chain_bits for p in plans)
    compile_single = sum(
        compile_cost(program_signature(p, n_lane_words, use_pallas))
        for p in plans)
    grouped_rows = 0
    compile_grouped = 0
    for g in groups:
        layout = group_layout([irs[i] for i in g], max_buckets=max_buckets)
        grouped_rows += layout["rows_per_member"] * (1 if parallel
                                                     else len(g))
        compile_grouped += compile_cost(layout_program_signature(
            layout, max(irs[i].n_signals for i in g), n_lane_words,
            use_pallas, len(g)))
    dispatch = EVAL_DISPATCH_ROW_COST
    cost_grouped = grouped_rows + dispatch * len(groups) + compile_grouped
    cost_single = single_rows + dispatch * len(nets) + compile_single
    return {
        "backend": backend,
        "parallel": parallel,
        "warm": warm,
        "n_programs_grouped": len(groups),
        "n_programs_per_circuit": len(nets),
        "n_cold_programs_grouped": compile_grouped // EVAL_COMPILE_ROW_COST,
        "n_cold_programs_per_circuit": (compile_single
                                        // EVAL_COMPILE_ROW_COST),
        "padded_rows_grouped": int(grouped_rows),
        "padded_rows_per_circuit": int(single_rows),
        "dispatch_row_cost": EVAL_DISPATCH_ROW_COST,
        "compile_row_cost": EVAL_COMPILE_ROW_COST,
        "compile_rows_grouped": int(compile_grouped),
        "compile_rows_per_circuit": int(compile_single),
        "cost_grouped": int(cost_grouped),
        "cost_per_circuit": int(cost_single),
        "pick": "grouped" if cost_grouped <= cost_single else "per_circuit",
    }


def evaluate_suite(nets: list[Netlist],
                   pi_lanes_list: list[dict[int, np.ndarray]],
                   n_lane_words: int, use_pallas: bool = True,
                   max_groups: int = DEFAULT_MAX_GROUPS,
                   max_buckets: int = DEFAULT_MAX_BUCKETS,
                   program: SuiteProgram | None = None,
                   mode: str = "auto",
                   warm: bool | str = "auto") -> tuple[list[np.ndarray],
                                                       dict]:
    """Whole-suite evaluation as <= ``max_groups`` vmapped jit programs —
    or per-circuit fused programs, whichever the backend-aware cost model
    predicts cheaper (``mode="auto"``; force with ``"grouped"`` /
    ``"per_circuit"``; a prepared ``program`` implies grouped).
    ``warm="auto"`` (default) derives each candidate program's compile
    cost from whether its shape signature has actually run
    (:func:`eval_mode_cost_model`); ``True``/``False`` force the old
    all-warm / all-cold assumptions for benchmark loops that know
    better (e.g. right after ``jax.clear_caches()``).

    Returns ``(per-circuit vals arrays, stats)`` where stats records the
    envelope groups, their bucket shapes, padded-row counts, the chosen
    ``mode`` and (in auto) the ``cost_model`` record — both paths are
    bit-identical, so the choice is purely a throughput matter.
    """
    if program is not None:
        outs, stats = eval_netlists_batched_jax(
            nets, pi_lanes_list, n_lane_words, use_pallas=use_pallas,
            return_stats=True, program=program)
        stats = dict(stats, mode="grouped")
        return outs, stats
    if mode not in ("auto", "grouped", "per_circuit"):
        raise ValueError(f"unknown evaluate_suite mode {mode!r}")
    from .eval_jax import group_plans_by_envelope

    # plans are registry-cached; the O(n^2) agglomerative grouping runs
    # at most ONCE and only when a branch actually needs it (a forced
    # per-circuit call never pays for clustering)
    plans = [plan_netlist(n, max_buckets=max_buckets) for n in nets]
    model = None
    chosen = mode
    groups = None
    if mode == "auto":
        groups = group_plans_by_envelope(plans, max_groups=max_groups)
        model = eval_mode_cost_model(nets, plans=plans, groups=groups,
                                     max_buckets=max_buckets, warm=warm,
                                     n_lane_words=n_lane_words,
                                     use_pallas=use_pallas)
        chosen = model["pick"]
    if chosen == "grouped":
        if groups is None:
            groups = group_plans_by_envelope(plans, max_groups=max_groups)
        program = prepare_suite_program(nets, max_buckets=max_buckets,
                                        plans=plans, groups=groups)
        outs, stats = eval_netlists_batched_jax(
            nets, pi_lanes_list, n_lane_words, use_pallas=use_pallas,
            return_stats=True, program=program)
        stats = dict(stats)
    else:
        outs = [evaluate_netlist(n, ln, n_lane_words,
                                 use_pallas=use_pallas, plan=pl)
                for n, ln, pl in zip(nets, pi_lanes_list, plans)]
        # the per-circuit path runs one program per circuit — report
        # that as the group count regardless of how this branch was
        # reached (the cost model's candidate clustering, when auto
        # computed one, is in stats["cost_model"])
        stats = {"n_groups": len(nets), "groups": [],
                 "n_programs": len(nets)}
    stats["mode"] = chosen
    if model is not None:
        stats["cost_model"] = model
    return outs, stats


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def serve(requests, **server_kwargs):
    """Serve a list of :class:`~repro.core.serve_flow.FlowRequest`\\ s
    through one async batched :class:`~repro.core.serve_flow.FlowServer`
    (coalescing window, request dedup, batched timing/eval programs,
    bounded multi-tenant caches) and return
    :class:`~repro.core.serve_flow.FlowResult`\\ s in request order.
    Every record is bit-identical to ``pack_and_analyze(net, arch,
    seeds=(seed,))`` — see :mod:`repro.core.serve_flow`."""
    from .serve_flow import serve_requests

    return serve_requests(requests, **server_kwargs)


def oracle_check(net: Netlist, pi_lanes: dict[int, np.ndarray],
                 vals: np.ndarray, n_lane_words: int) -> bool:
    """Prove a JAX-side result bit-identical to the Python oracle on every
    primary output (all lane words)."""
    ok = True
    for w in range(n_lane_words):
        pi_vals = {s: int(pi_lanes[s][w]) for s in net.pis}
        ref = eval_netlist(net, pi_vals, 32)
        for bus in net.pos.values():
            for s in bus:
                if int(vals[s, w]) != (ref[s] & 0xFFFFFFFF):
                    return False
    return ok
