"""Bit-parallel netlist evaluation in JAX (the simulator's compute layer).

The netlist is levelized once (compile time); evaluation then runs one
vectorized `lut_eval` kernel call per LUT level and a `lax.scan` ripple per
chain group, all over uint32 test-vector lanes.  This is the performance
path for large-circuit functional validation — the Python `eval_netlist`
oracle in `netlist.py` stays the ground truth in tests.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from .netlist import CONST0, CONST1, Netlist


@dataclass
class EvalPlan:
    n_signals: int
    # per level: (lut_ids, input_sig array [M, K], tt array [M], out_sigs [M])
    lut_levels: list[tuple]
    # per level: list of chain descriptors (a [L], b [L], cin, sums [L], cout)
    chain_levels: list[list[tuple]]


def plan_netlist(net: Netlist) -> EvalPlan:
    order = net.topo_order()
    level: dict[tuple, int] = {}
    sig_level: dict[int, int] = {s: 0 for s in net.pis}
    sig_level[CONST0] = 0
    sig_level[CONST1] = 0
    for nd in order:
        lv = 0
        for s in net.node_inputs(nd):
            lv = max(lv, sig_level.get(s, 0))
        lv += 1
        level[nd] = lv
        for s in net.node_outputs(nd):
            sig_level[s] = lv

    by_level_luts: dict[int, list[int]] = defaultdict(list)
    by_level_chains: dict[int, list[int]] = defaultdict(list)
    for nd, lv in level.items():
        if nd[0] == "lut":
            by_level_luts[lv].append(nd[1])
        else:
            by_level_chains[lv].append(nd[1])

    lut_levels = []
    for lv in sorted(by_level_luts):
        ids = by_level_luts[lv]
        kmax = max(len(net.lut_inputs[i]) for i in ids)
        kmax = max(kmax, 1)
        M = len(ids)
        ins = np.zeros((M, kmax), dtype=np.int64)
        tts = np.zeros(M, dtype=np.uint64)
        outs = np.zeros(M, dtype=np.int64)
        for r, i in enumerate(ids):
            sig_ins = net.lut_inputs[i]
            k = len(sig_ins)
            ins[r, :k] = sig_ins
            # pad unused pins with CONST0 and replicate the tt accordingly
            tt = net.lut_tt[i]
            reps = 1 << (kmax - k)
            full = 0
            for rr in range(reps):
                full |= tt << (rr * (1 << k))
            tts[r] = full & ((1 << min(64, 1 << kmax)) - 1)
            outs[r] = net.lut_out[i]
        lut_levels.append((ids, ins, tts.astype(np.uint32) if kmax <= 5
                           else tts, outs))
    chain_levels = [
        [(np.array(net.chains[c].a), np.array(net.chains[c].b),
          net.chains[c].cin, np.array(net.chains[c].sums),
          net.chains[c].cout) for c in by_level_chains[lv]]
        for lv in sorted(by_level_chains)
    ]
    # interleave by level order
    merged_l: list[tuple] = []
    merged_c: list[list[tuple]] = []
    lvs = sorted(set(by_level_luts) | set(by_level_chains))
    li = ci = 0
    plan_l, plan_c = [], []
    for lv in lvs:
        if lv in by_level_luts:
            plan_l.append(lut_levels[li])
            li += 1
        else:
            plan_l.append(None)
        if lv in by_level_chains:
            plan_c.append(chain_levels[ci])
            ci += 1
        else:
            plan_c.append(None)
    return EvalPlan(net.n_signals, plan_l, plan_c)


def eval_netlist_jax(net: Netlist, pi_lanes: dict[int, np.ndarray],
                     n_lane_words: int, use_pallas: bool = True) -> jax.Array:
    """Evaluate; returns ``vals[n_signals, n_lane_words]`` uint32.

    ``pi_lanes[signal]`` is a uint32 vector of packed test vectors.
    """
    from repro.kernels import ops

    plan = plan_netlist(net)
    vals = jnp.zeros((plan.n_signals, n_lane_words), dtype=jnp.uint32)
    vals = vals.at[CONST1].set(jnp.uint32(0xFFFFFFFF))
    for s, v in pi_lanes.items():
        vals = vals.at[s].set(jnp.asarray(v, dtype=jnp.uint32))

    for lut_lv, chain_lv in zip(plan.lut_levels, plan.chain_levels):
        if lut_lv is not None:
            ids, ins, tts, outs = lut_lv
            gathered = vals[jnp.asarray(ins)]          # [M, K, N]
            if ins.shape[1] <= 5:
                out = ops.lut_eval(gathered, jnp.asarray(tts),
                                   use_pallas=use_pallas)
            else:
                # 6-input LUTs: Shannon-decompose on pin 5 into two 5-LUT
                # evaluations (keeps truth tables in uint32)
                tt64 = tts.astype(np.uint64)
                tt_lo = jnp.asarray((tt64 & np.uint64(0xFFFFFFFF))
                                    .astype(np.uint32))
                tt_hi = jnp.asarray((tt64 >> np.uint64(32)).astype(np.uint32))
                g5 = gathered[:, :5, :]
                sel = gathered[:, 5, :]
                lo = ops.lut_eval(g5, tt_lo, use_pallas=use_pallas)
                hi = ops.lut_eval(g5, tt_hi, use_pallas=use_pallas)
                out = (sel & hi) | (~sel & lo)
            vals = vals.at[jnp.asarray(outs)].set(out)
        if chain_lv is not None:
            for a, b, cin, sums, cout in chain_lv:
                av = vals[jnp.asarray(a)]
                bv = vals[jnp.asarray(b)]
                c0 = vals[cin]

                def step(c, ab):
                    aa, bb = ab
                    s = aa ^ bb ^ c
                    cy = (aa & bb) | (c & (aa ^ bb))
                    return cy, s

                clast, ss = jax.lax.scan(step, c0, (av, bv))
                vals = vals.at[jnp.asarray(sums)].set(ss)
                if cout is not None:
                    vals = vals.at[cout].set(clast)
    return vals
