"""Bit-parallel netlist evaluation in JAX (the simulator's compute layer).

Fused single-jit engine
-----------------------
The netlist is levelized once (compile time) into a :class:`FusedPlan`:
every LUT level is padded to a uniform ``[L, M_max, 6]`` tensor (tables
split into two uint32 words, pin 5 Shannon-selects), every chain level to
``[L, C_max, B_max]``.  One ``lax.scan`` over levels then evaluates the
whole circuit inside a single jit:

* level ``t`` gathers its LUT input lanes from the signal-value buffer,
  runs one fused ``lut_eval6`` kernel call, and scatters the outputs;
* the level's carry chains ripple inside the same scan step (a nested
  bit-scan over the stacked ``[C_max, B_max]`` layout — one scan for *all*
  chains of the level, not one dispatch per chain);
* padded rows read constant-0 lanes and write a reserved sink row, so the
  scan body is shape-uniform with zero per-level Python dispatch.

The value buffer is donated to the jit (``donate_argnums``), so evaluation
reuses it in place, and :func:`eval_netlists_batched_jax` stacks several
circuits' plans into one ``vmap``-ed call — the layout that lets functional
validation of baseline/DD5/DD6 re-elaborations run concurrently.

The seed per-level dispatcher (one kernel launch per level from a Python
loop) survives as :func:`eval_netlist_jax_levels` — it is the baseline the
perf trajectory measures the fused engine against — and the Python
``eval_netlist`` oracle in ``netlist.py`` stays the ground truth in tests.
"""
from __future__ import annotations

import functools
from collections import defaultdict
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from .netlist import CONST0, CONST1, Netlist


# ---------------------------------------------------------------------------
# levelization
# ---------------------------------------------------------------------------


def _levelize(net: Netlist):
    """Group nodes by topological level (inputs strictly below)."""
    order = net.topo_order()
    sig_level: dict[int, int] = {s: 0 for s in net.pis}
    sig_level[CONST0] = 0
    sig_level[CONST1] = 0
    by_level_luts: dict[int, list[int]] = defaultdict(list)
    by_level_chains: dict[int, list[int]] = defaultdict(list)
    for nd in order:
        lv = 0
        for s in net.node_inputs(nd):
            lv = max(lv, sig_level.get(s, 0))
        lv += 1
        for s in net.node_outputs(nd):
            sig_level[s] = lv
        if nd[0] == "lut":
            by_level_luts[lv].append(nd[1])
        else:
            by_level_chains[lv].append(nd[1])
    return by_level_luts, by_level_chains


def _tt_words(tt: int, k: int) -> tuple[int, int]:
    """Replicate a k-input table into a 64-entry mask, split lo/hi uint32."""
    full = 0
    for r in range(1 << (6 - k)):
        full |= tt << (r * (1 << k))
    full &= (1 << 64) - 1
    return full & 0xFFFFFFFF, full >> 32


@dataclass
class FusedPlan:
    """Shape-uniform level tensors; ``sink = n_signals`` swallows padding."""

    n_signals: int
    n_levels: int
    has_luts: bool
    has_chains: bool
    lut_ins: np.ndarray     # [L, M, 6] int32 (padded pins/rows -> CONST0)
    lut_tt_lo: np.ndarray   # [L, M] uint32
    lut_tt_hi: np.ndarray   # [L, M] uint32
    lut_out: np.ndarray     # [L, M] int32 (padded rows -> sink)
    ch_a: np.ndarray        # [L, C, B] int32
    ch_b: np.ndarray        # [L, C, B] int32
    ch_cin: np.ndarray      # [L, C] int32
    ch_sums: np.ndarray     # [L, C, B] int32 (padded -> sink)
    ch_cout: np.ndarray     # [L, C] int32 (chains without cout -> sink)
    ch_last: np.ndarray     # [L, C] int32 (index of the last real bit)
    _dev: tuple | None = None   # cached device-resident copies

    @property
    def sink(self) -> int:
        return self.n_signals

    def arrays(self):
        return (self.lut_ins, self.lut_tt_lo, self.lut_tt_hi, self.lut_out,
                self.ch_a, self.ch_b, self.ch_cin, self.ch_sums,
                self.ch_cout, self.ch_last)

    def device_arrays(self):
        """Plan tensors as device arrays, uploaded once per plan — reusing
        a plan across calls must not re-transfer megabytes of indices."""
        if self._dev is None:
            self._dev = tuple(jnp.asarray(a) for a in self.arrays())
        return self._dev


def plan_netlist(net: Netlist) -> FusedPlan:
    """Compile a netlist into the fused evaluator's padded level tensors."""
    by_luts, by_chains = _levelize(net)
    levels = sorted(set(by_luts) | set(by_chains))
    L = max(len(levels), 1)
    M = max((len(by_luts[lv]) for lv in by_luts), default=0)
    C = max((len(by_chains[lv]) for lv in by_chains), default=0)
    B = max((len(net.chains[c].sums) for lv in by_chains
             for c in by_chains[lv]), default=0)
    sink = net.n_signals

    lut_ins = np.full((L, max(M, 1), 6), CONST0, dtype=np.int32)
    lut_tt_lo = np.zeros((L, max(M, 1)), dtype=np.uint32)
    lut_tt_hi = np.zeros((L, max(M, 1)), dtype=np.uint32)
    lut_out = np.full((L, max(M, 1)), sink, dtype=np.int32)
    ch_a = np.full((L, max(C, 1), max(B, 1)), CONST0, dtype=np.int32)
    ch_b = np.full((L, max(C, 1), max(B, 1)), CONST0, dtype=np.int32)
    ch_cin = np.full((L, max(C, 1)), CONST0, dtype=np.int32)
    ch_sums = np.full((L, max(C, 1), max(B, 1)), sink, dtype=np.int32)
    ch_cout = np.full((L, max(C, 1)), sink, dtype=np.int32)
    ch_last = np.zeros((L, max(C, 1)), dtype=np.int32)

    for t, lv in enumerate(levels):
        for r, i in enumerate(by_luts.get(lv, ())):
            sig_ins = net.lut_inputs[i]
            k = len(sig_ins)
            lut_ins[t, r, :k] = sig_ins
            lo, hi = _tt_words(net.lut_tt[i], k)
            lut_tt_lo[t, r] = lo
            lut_tt_hi[t, r] = hi
            lut_out[t, r] = net.lut_out[i]
        for r, c in enumerate(by_chains.get(lv, ())):
            ch = net.chains[c]
            n = len(ch.sums)
            ch_a[t, r, :n] = ch.a
            ch_b[t, r, :n] = ch.b
            ch_cin[t, r] = ch.cin
            ch_sums[t, r, :n] = ch.sums
            ch_last[t, r] = n - 1
            if ch.cout is not None:
                ch_cout[t, r] = ch.cout

    return FusedPlan(
        n_signals=net.n_signals, n_levels=L,
        has_luts=M > 0, has_chains=C > 0,
        lut_ins=lut_ins, lut_tt_lo=lut_tt_lo, lut_tt_hi=lut_tt_hi,
        lut_out=lut_out, ch_a=ch_a, ch_b=ch_b, ch_cin=ch_cin,
        ch_sums=ch_sums, ch_cout=ch_cout, ch_last=ch_last,
    )


# ---------------------------------------------------------------------------
# fused single-jit evaluation
# ---------------------------------------------------------------------------


def _fused_body(vals, xs, *, has_luts: bool, has_chains: bool,
                use_pallas: bool):
    """One level: fused LUT kernel + stacked chain ripple.  ``vals`` is the
    ``[n_signals + 1, N]`` value buffer (last row = padding sink)."""
    from repro.kernels import ops

    (ins, tt_lo, tt_hi, out_idx, a_idx, b_idx, cin_idx, sums_idx, cout_idx,
     last_idx) = xs
    if has_luts:
        gathered = vals[ins]                         # [M, 6, N]
        out = ops.lut_eval6(gathered, tt_lo, tt_hi, use_pallas=use_pallas)
        vals = vals.at[out_idx].set(out)
    if has_chains:
        av = vals[a_idx]                             # [C, B, N]
        bv = vals[b_idx]
        c0 = vals[cin_idx]                           # [C, N]

        def ripple(c, ab):
            aa, bb = ab
            s = aa ^ bb ^ c
            cy = (aa & bb) | (c & (aa ^ bb))
            return cy, (s, cy)

        _, (ss, cys) = jax.lax.scan(
            ripple, c0, (av.swapaxes(0, 1), bv.swapaxes(0, 1)))
        vals = vals.at[sums_idx].set(ss.swapaxes(0, 1))
        # cout is the carry *after the chain's last real bit* — padded tail
        # bits add 0+0 and would zero the carry, so index, don't take last
        cout_v = jnp.take_along_axis(
            cys.swapaxes(0, 1), last_idx[:, None, None], axis=1)[:, 0]
        vals = vals.at[cout_idx].set(cout_v)
    return vals, None


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("has_luts", "has_chains", "use_pallas"))
def _run_fused(vals, plan_arrays, *, has_luts, has_chains, use_pallas):
    body = functools.partial(_fused_body, has_luts=has_luts,
                             has_chains=has_chains, use_pallas=use_pallas)
    vals, _ = jax.lax.scan(body, vals, plan_arrays)
    return vals


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("has_luts", "has_chains", "use_pallas"))
def _run_fused_batch(vals, plan_arrays, *, has_luts, has_chains, use_pallas):
    body = functools.partial(_fused_body, has_luts=has_luts,
                             has_chains=has_chains, use_pallas=use_pallas)

    def one(v, arrs):
        out, _ = jax.lax.scan(body, v, arrs)
        return out

    return jax.vmap(one)(vals, plan_arrays)


def _init_vals(plan: FusedPlan, pi_lanes: dict[int, np.ndarray],
               n_lane_words: int) -> jax.Array:
    vals = np.zeros((plan.n_signals + 1, n_lane_words), dtype=np.uint32)
    vals[CONST1] = 0xFFFFFFFF
    for s, v in pi_lanes.items():
        vals[s] = np.asarray(v, dtype=np.uint32)
    return jnp.asarray(vals)


def eval_netlist_jax(net: Netlist, pi_lanes: dict[int, np.ndarray],
                     n_lane_words: int, use_pallas: bool = True,
                     plan: FusedPlan | None = None) -> jax.Array:
    """Fused evaluation; returns ``vals[n_signals, n_lane_words]`` uint32.

    ``pi_lanes[signal]`` is a uint32 vector of packed test vectors.  Pass a
    precompiled ``plan`` to amortize levelization across calls (the jit
    cache already amortizes compilation by shape).
    """
    if plan is None:
        plan = plan_netlist(net)
    vals = _init_vals(plan, pi_lanes, n_lane_words)
    out = _run_fused(vals, plan.device_arrays(),
                     has_luts=plan.has_luts, has_chains=plan.has_chains,
                     use_pallas=use_pallas)
    return out[:plan.n_signals]


def _pad_to(a: np.ndarray, shape, fill) -> np.ndarray:
    out = np.full(shape, fill, dtype=a.dtype)
    out[tuple(slice(0, d) for d in a.shape)] = a
    return out


def eval_netlists_batched_jax(nets: list[Netlist],
                              pi_lanes_list: list[dict[int, np.ndarray]],
                              n_lane_words: int,
                              use_pallas: bool = True) -> list[np.ndarray]:
    """Evaluate several circuits concurrently in one vmapped jit.

    Plans are padded to a common ``[L, M, 6]`` / ``[C, B]`` envelope and the
    per-circuit sink rows are re-pointed at the shared envelope's sink.
    Used to validate baseline/DD5/DD6 re-elaborations of the same source
    in a single device program.  Returns per-circuit ``vals`` arrays.
    """
    plans = [plan_netlist(net) for net in nets]
    n_sig = max(p.n_signals for p in plans)
    L = max(p.n_levels for p in plans)
    M = max(p.lut_out.shape[1] for p in plans)
    C = max(p.ch_cout.shape[1] for p in plans)
    B = max(p.ch_a.shape[2] for p in plans)

    stacked = []
    for p in plans:
        arrs = []
        for a, shape, fill in (
                (p.lut_ins, (L, M, 6), CONST0),
                (p.lut_tt_lo, (L, M), 0),
                (p.lut_tt_hi, (L, M), 0),
                (np.where(p.lut_out == p.sink, n_sig, p.lut_out),
                 (L, M), n_sig),
                (p.ch_a, (L, C, B), CONST0),
                (p.ch_b, (L, C, B), CONST0),
                (p.ch_cin, (L, C), CONST0),
                (np.where(p.ch_sums == p.sink, n_sig, p.ch_sums),
                 (L, C, B), n_sig),
                (np.where(p.ch_cout == p.sink, n_sig, p.ch_cout),
                 (L, C), n_sig),
                (p.ch_last, (L, C), 0)):
            arrs.append(_pad_to(np.asarray(a), shape, fill))
        stacked.append(arrs)
    plan_arrays = tuple(jnp.asarray(np.stack([s[i] for s in stacked]))
                        for i in range(10))

    vals = np.zeros((len(nets), n_sig + 1, n_lane_words), dtype=np.uint32)
    vals[:, CONST1] = 0xFFFFFFFF
    for bi, lanes in enumerate(pi_lanes_list):
        for s, v in lanes.items():
            vals[bi, s] = np.asarray(v, dtype=np.uint32)
    out = _run_fused_batch(jnp.asarray(vals), plan_arrays,
                           has_luts=any(p.has_luts for p in plans),
                           has_chains=any(p.has_chains for p in plans),
                           use_pallas=use_pallas)
    out = np.asarray(out)
    return [out[i, :p.n_signals] for i, p in enumerate(plans)]


# ---------------------------------------------------------------------------
# seed per-level dispatcher (perf baseline)
# ---------------------------------------------------------------------------


def eval_netlist_jax_levels(net: Netlist, pi_lanes: dict[int, np.ndarray],
                            n_lane_words: int,
                            use_pallas: bool = True) -> jax.Array:
    """The pre-fusion evaluator: one Python-dispatched kernel call per LUT
    level and one ``lax.scan`` per chain.  Kept as the measured baseline
    for the fused engine's speedup (see ``benchmarks/perf_iterations.py``).
    """
    from repro.kernels import ops

    by_luts, by_chains = _levelize(net)
    levels = sorted(set(by_luts) | set(by_chains))

    vals = jnp.zeros((net.n_signals, n_lane_words), dtype=jnp.uint32)
    vals = vals.at[CONST1].set(jnp.uint32(0xFFFFFFFF))
    for s, v in pi_lanes.items():
        vals = vals.at[s].set(jnp.asarray(v, dtype=jnp.uint32))

    for lv in levels:
        ids = by_luts.get(lv)
        if ids:
            kmax = max(1, max(len(net.lut_inputs[i]) for i in ids))
            ins = np.zeros((len(ids), kmax), dtype=np.int64)
            tts = np.zeros(len(ids), dtype=np.uint64)
            outs = np.zeros(len(ids), dtype=np.int64)
            for r, i in enumerate(ids):
                sig_ins = net.lut_inputs[i]
                k = len(sig_ins)
                ins[r, :k] = sig_ins
                tt = net.lut_tt[i]
                full = 0
                for rr in range(1 << (kmax - k)):
                    full |= tt << (rr * (1 << k))
                tts[r] = full & ((1 << min(64, 1 << kmax)) - 1)
                outs[r] = net.lut_out[i]
            gathered = vals[jnp.asarray(ins)]
            if kmax <= 5:
                out = ops.lut_eval(gathered, jnp.asarray(
                    tts.astype(np.uint32)), use_pallas=use_pallas)
            else:
                tt_lo = jnp.asarray((tts & np.uint64(0xFFFFFFFF))
                                    .astype(np.uint32))
                tt_hi = jnp.asarray((tts >> np.uint64(32)).astype(np.uint32))
                g5 = gathered[:, :5, :]
                sel = gathered[:, 5, :]
                lo = ops.lut_eval(g5, tt_lo, use_pallas=use_pallas)
                hi = ops.lut_eval(g5, tt_hi, use_pallas=use_pallas)
                out = (sel & hi) | (~sel & lo)
            vals = vals.at[jnp.asarray(outs)].set(out)
        for c in by_chains.get(lv, ()):
            ch = net.chains[c]
            av = vals[jnp.asarray(np.array(ch.a))]
            bv = vals[jnp.asarray(np.array(ch.b))]
            c0 = vals[ch.cin]

            def step(c_, ab):
                aa, bb = ab
                s = aa ^ bb ^ c_
                cy = (aa & bb) | (c_ & (aa ^ bb))
                return cy, s

            clast, ss = jax.lax.scan(step, c0, (av, bv))
            vals = vals.at[jnp.asarray(np.array(ch.sums))].set(ss)
            if ch.cout is not None:
                vals = vals.at[ch.cout].set(clast)
    return vals
