"""Bit-parallel netlist evaluation in JAX (the simulator's compute layer).

Width-bucketed multi-scan engine
--------------------------------
The netlist is lowered once (per content digest) to the unified columnar
:class:`~repro.core.circuit_ir.CircuitIR` — the same functional lowering
that feeds the timing stack and the equivalence lanes — and compiled here
into a :class:`FusedPlan`.  Instead of padding every level to one
worst-case ``[L, M_max, 6]`` envelope (a circuit with one wide level then
wastes rows on every other level), the level sequence is partitioned into
at most ``max_buckets`` *contiguous* segments — width buckets — by the
shared padded-volume DP (:func:`repro.core.plan.segment_levels`).  Each
bucket is padded only to its own envelope ``[l_b, M_b, 6]`` /
``[l_b, C_b, B_b]`` and evaluated by its own ``lax.scan``; the scans run
back-to-back inside a **single jit**, so the one-program property of the
fused engine is preserved while the padding waste drops to the per-bucket
optimum:

* a scan step gathers the level's LUT input lanes from the signal-value
  buffer, runs one fused ``lut_eval6`` kernel call, and scatters the
  outputs;
* the level's carry chains ripple inside the same scan step (a nested
  bit-scan over the stacked ``[C_b, B_b]`` layout — one scan for *all*
  chains of the level);
* padded rows read constant-0 lanes and write a reserved sink row, so each
  scan body is shape-uniform with zero per-level Python dispatch.

Suite-scale batched evaluation
------------------------------
:func:`eval_netlists_batched_jax` evaluates many circuits per device
program.  Plans are clustered by *compatible envelopes*
(:func:`repro.core.plan.group_by_envelope` — agglomerative merging on the
padded plan volume plus a signal-count term), capped at ``max_groups``
groups, so a whole benchmark suite compiles into a handful of vmapped jit
programs.  Within a group the bucket boundaries are recomputed on the
group's combined per-level width profile, members are padded to the group
envelope, and one ``vmap``-ed multi-scan evaluates the group.

Plans and grouped device tensors are cached by netlist content digest in
the shared registry (:mod:`repro.core.plan` — ``eval_plans`` /
``eval_groups``), alongside the functional IRs; one
:func:`repro.core.plan.clear_caches` invalidates everything.

The value buffer is donated to the jit (``donate_argnums``), so evaluation
reuses it in place.  The seed per-level dispatcher (one kernel launch per
level from a Python loop) survives as :func:`eval_netlist_jax_levels` — the
baseline the perf trajectory measures against — and the Python
``eval_netlist`` oracle in ``netlist.py`` stays the ground truth in tests.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from . import plan as _planner
from .circuit_ir import CircuitIR, levelize, lower_netlist_ir
from .netlist import CONST0, CONST1, Netlist
from .plan import segment_levels

DEFAULT_MAX_BUCKETS = 3
DEFAULT_MAX_GROUPS = 4

_PLAN_CACHE = _planner.register_cache("eval_plans", cap=64)
_GROUP_CACHE = _planner.register_cache("eval_groups", cap=16)

#: shape signatures of evaluation programs that have actually *run* (hence
#: compiled) in this process — the ground truth behind the cost model's
#: ``warm="auto"`` derivation (:func:`repro.core.flow.eval_mode_cost_model`).
#: jax keys its jit cache by argument shapes + static args, so the
#: signature is shape-based too (:func:`program_signature`): two circuits
#: whose plans pad to identical bucket envelopes share one compile, and
#: the marker honestly reports both warm.
_COMPILED_CACHE = _planner.register_cache("eval_compiled", cap=2048)


def program_signature(plan: FusedPlan, n_lane_words: int,
                      use_pallas: bool, batch: int | None = None) -> tuple:
    """The jit-cache identity of one evaluation program: per-bucket
    static flags + padded bucket shapes + value-buffer height + lane
    words (+ the vmap batch size for grouped programs, ``None`` for
    single-circuit ones).  Everything jax's compile cache keys on."""
    return (plan.flags, tuple(bk.shape for bk in plan.buckets),
            plan.n_signals,
            None if n_lane_words is None else int(n_lane_words),
            bool(use_pallas), batch)


def layout_program_signature(layout: dict, n_signals: int,
                             n_lane_words: int | None, use_pallas: bool,
                             batch: int | None) -> tuple:
    """:func:`program_signature` derived from a :func:`group_layout`
    record alone — no plan tensors built.  Mirrors ``_bucket_from_ir``'s
    padding floors (``max(dim, 1)``) and per-bucket flags, which a test
    pins against the signature an actual run records."""
    flags = tuple((M > 0, C > 0) for (M, C, B) in layout["envelopes"])
    shapes = tuple((max(j - i, 1), max(M, 1), max(C, 1), max(B, 1))
                   for (i, j), (M, C, B) in zip(layout["bounds"],
                                                layout["envelopes"]))
    return (flags, shapes, n_signals,
            None if n_lane_words is None else int(n_lane_words),
            bool(use_pallas), batch)


def mark_program_run(sig: tuple) -> None:
    """Record that the program with signature ``sig`` has executed (its
    compile is cached).  Called by both evaluation paths after a run."""
    _COMPILED_CACHE.put(sig, True)


def program_seen(sig: tuple) -> bool:
    """Has a program with this signature run in this process?  With
    ``n_lane_words`` (position 3) set to ``None`` the lane-word count is
    a wildcard — for cost-model callers that don't know the lane shape
    yet (a compile at any lane count proves the plan shapes were built
    and the program dispatched at least once)."""
    if sig[3] is not None:
        return sig in _COMPILED_CACHE
    probe = sig[:3] + sig[4:]
    return any(k[:3] + k[4:] == probe for k in _COMPILED_CACHE.keys())


def netlist_digest(net: Netlist) -> str:
    """Content digest of a netlist's structure (the plan-cache key) —
    alias of :meth:`Netlist.content_digest`, shared with the sweep
    engine's pack/program caches."""
    return net.content_digest()


def clear_plan_caches() -> None:
    """Deprecated alias of :func:`repro.core.plan.clear_caches` — the
    unified registry clears *every* lowering/planning cache (functional
    IRs, eval plans, grouped tensors, sweep IR templates), where this
    name historically left the sweep-side caches live."""
    _planner.clear_caches()


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------


@dataclass
class PlanBucket:
    """One contiguous run of levels padded to its own envelope."""

    n_levels: int
    has_luts: bool
    has_chains: bool
    lut_ins: np.ndarray     # [l, M, 6] int32 (padded pins/rows -> CONST0)
    lut_tt_lo: np.ndarray   # [l, M] uint32
    lut_tt_hi: np.ndarray   # [l, M] uint32
    lut_out: np.ndarray     # [l, M] int32 (padded rows -> sink)
    ch_a: np.ndarray        # [l, C, B] int32
    ch_b: np.ndarray        # [l, C, B] int32
    ch_cin: np.ndarray      # [l, C] int32
    ch_sums: np.ndarray     # [l, C, B] int32 (padded -> sink)
    ch_cout: np.ndarray     # [l, C] int32 (chains without cout -> sink)
    ch_last: np.ndarray     # [l, C] int32 (index of the last real bit)

    def arrays(self):
        return (self.lut_ins, self.lut_tt_lo, self.lut_tt_hi, self.lut_out,
                self.ch_a, self.ch_b, self.ch_cin, self.ch_sums,
                self.ch_cout, self.ch_last)

    @property
    def shape(self) -> tuple[int, int, int, int]:
        """(levels, M, C, B) envelope of this bucket."""
        return (self.n_levels, self.lut_out.shape[1],
                self.ch_cout.shape[1], self.ch_a.shape[2])

    @property
    def padded_lut_rows(self) -> int:
        l, M, _, _ = self.shape
        return l * (M if self.has_luts else 0)

    @property
    def padded_chain_bits(self) -> int:
        l, _, C, B = self.shape
        return l * (C * B if self.has_chains else 0)


@dataclass
class FusedPlan:
    """Width-bucketed level tensors; ``sink = n_signals`` swallows padding."""

    n_signals: int
    n_levels: int
    buckets: tuple[PlanBucket, ...]
    real_luts: int = 0
    real_chain_bits: int = 0
    _dev: tuple | None = field(default=None, repr=False)

    @property
    def sink(self) -> int:
        return self.n_signals

    @property
    def has_luts(self) -> bool:
        return any(bk.has_luts for bk in self.buckets)

    @property
    def has_chains(self) -> bool:
        return any(bk.has_chains for bk in self.buckets)

    @property
    def flags(self) -> tuple[tuple[bool, bool], ...]:
        """Static per-bucket (has_luts, has_chains) — part of the jit key."""
        return tuple((bk.has_luts, bk.has_chains) for bk in self.buckets)

    @property
    def envelope(self) -> tuple[int, int, int, int]:
        """The single worst-case (L, M, C, B) envelope (pre-bucketing).
        Dimensions whose side is absent are 0, not the array floor of 1 —
        a pure-LUT circuit must not be charged L phantom chain rows."""
        return (self.n_levels,
                max((bk.shape[1] if bk.has_luts else 0)
                    for bk in self.buckets),
                max((bk.shape[2] if bk.has_chains else 0)
                    for bk in self.buckets),
                max((bk.shape[3] if bk.has_chains else 0)
                    for bk in self.buckets))

    @property
    def padded_lut_rows(self) -> int:
        return sum(bk.padded_lut_rows for bk in self.buckets)

    @property
    def padded_chain_bits(self) -> int:
        return sum(bk.padded_chain_bits for bk in self.buckets)

    def arrays(self):
        return tuple(bk.arrays() for bk in self.buckets)

    def device_arrays(self):
        """Plan tensors as device arrays, uploaded once per plan — reusing
        a plan across calls must not re-transfer megabytes of indices."""
        if self._dev is None:
            self._dev = tuple(tuple(jnp.asarray(a) for a in bk)
                              for bk in self.arrays())
        return self._dev


def _bucket_from_ir(ir: CircuitIR, i: int, j: int, M: int, C: int, B: int,
                    sink: int) -> PlanBucket:
    """Pad IR levels ``[i, j)`` to the bucket envelope ``[l, M, C, B]``."""
    l = max(j - i, 1)
    has_luts = M > 0
    has_chains = C > 0
    lut_ins = np.full((l, max(M, 1), 6), CONST0, dtype=np.int32)
    lut_tt_lo = np.zeros((l, max(M, 1)), dtype=np.uint32)
    lut_tt_hi = np.zeros((l, max(M, 1)), dtype=np.uint32)
    lut_out = np.full((l, max(M, 1)), sink, dtype=np.int32)
    ch_a = np.full((l, max(C, 1), max(B, 1)), CONST0, dtype=np.int32)
    ch_b = np.full((l, max(C, 1), max(B, 1)), CONST0, dtype=np.int32)
    ch_cin = np.full((l, max(C, 1)), CONST0, dtype=np.int32)
    ch_sums = np.full((l, max(C, 1), max(B, 1)), sink, dtype=np.int32)
    ch_cout = np.full((l, max(C, 1)), sink, dtype=np.int32)
    ch_last = np.zeros((l, max(C, 1)), dtype=np.int32)
    for t in range(i, min(j, ir.n_levels)):
        r = t - i
        ll, cl = ir.lut_levels[t], ir.chain_levels[t]
        m = ll.out.shape[0]
        if m:
            lut_ins[r, :m] = ll.ins
            lut_tt_lo[r, :m] = ll.tt_lo
            lut_tt_hi[r, :m] = ll.tt_hi
            lut_out[r, :m] = ll.out
        c = cl.cout.shape[0]
        if c:
            bb = cl.a_sig.shape[1]
            ch_a[r, :c, :bb] = cl.a_sig
            ch_b[r, :c, :bb] = cl.b_sig
            ch_cin[r, :c] = cl.cin_sig
            s = cl.sums.copy()
            s[s < 0] = sink
            ch_sums[r, :c, :bb] = s
            co = cl.cout.copy()
            co[co < 0] = sink
            ch_cout[r, :c] = co
            ch_last[r, :c] = cl.last
    return PlanBucket(n_levels=l, has_luts=has_luts, has_chains=has_chains,
                      lut_ins=lut_ins, lut_tt_lo=lut_tt_lo,
                      lut_tt_hi=lut_tt_hi, lut_out=lut_out, ch_a=ch_a,
                      ch_b=ch_b, ch_cin=ch_cin, ch_sums=ch_sums,
                      ch_cout=ch_cout, ch_last=ch_last)


def plan_from_ir(ir: CircuitIR,
                 max_buckets: int = DEFAULT_MAX_BUCKETS,
                 n_signals: int | None = None,
                 bounds=None, envelopes=None) -> FusedPlan:
    """Compile a :class:`CircuitIR` (functional or packed — only the
    functional columns are read) into width-bucketed level tensors.

    This is the evaluator's half of the one-lowering contract: the same
    IR object that the vectorized timing analyzer consumes drives the
    fused evaluation plan, with no re-levelization.  Pass ``bounds`` /
    ``envelopes`` to pad to a shared group layout (suite batching).
    """
    m, c, b = ir.level_profile()
    if not m:
        m, c, b = [0], [0], [0]
    if bounds is None:
        bounds = segment_levels(m, c, b, max_buckets)
    if envelopes is None:
        envelopes = _planner.bucket_envelopes(m, c, b, bounds)
    if n_signals is None:
        n_signals = ir.n_signals
    sink = n_signals
    buckets = tuple(_bucket_from_ir(ir, i, j, M, C, B, sink)
                    for (i, j), (M, C, B) in zip(bounds, envelopes))
    n_levels = sum(max(j - i, 1) for i, j in bounds) if bounds else 1
    return FusedPlan(
        n_signals=n_signals, n_levels=n_levels, buckets=buckets,
        real_luts=int(sum(lv.out.shape[0] for lv in ir.lut_levels)),
        real_chain_bits=int(sum((lv.sums >= 0).sum()
                                for lv in ir.chain_levels)))


def plan_netlist(net: Netlist,
                 max_buckets: int = DEFAULT_MAX_BUCKETS) -> FusedPlan:
    """Compile a netlist into width-bucketed level tensors (content-cached,
    via the content-cached functional :class:`CircuitIR`)."""
    digest = netlist_digest(net)
    key = (digest, max_buckets)
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        return cached
    ir = lower_netlist_ir(net, digest=digest)
    plan = plan_from_ir(ir, max_buckets=max_buckets)
    _PLAN_CACHE.put(key, plan)
    return plan


# ---------------------------------------------------------------------------
# fused single-jit evaluation (multi-scan over buckets)
# ---------------------------------------------------------------------------


def _fused_body(vals, xs, *, has_luts: bool, has_chains: bool,
                use_pallas: bool):
    """One level: fused LUT kernel + stacked chain ripple.  ``vals`` is the
    ``[n_signals + 1, N]`` value buffer (last row = padding sink)."""
    from repro.kernels import ops

    (ins, tt_lo, tt_hi, out_idx, a_idx, b_idx, cin_idx, sums_idx, cout_idx,
     last_idx) = xs
    if has_luts:
        gathered = vals[ins]                         # [M, 6, N]
        out = ops.lut_eval6(gathered, tt_lo, tt_hi, use_pallas=use_pallas)
        vals = vals.at[out_idx].set(out)
    if has_chains:
        av = vals[a_idx]                             # [C, B, N]
        bv = vals[b_idx]
        c0 = vals[cin_idx]                           # [C, N]

        def ripple(c, ab):
            aa, bb = ab
            s = aa ^ bb ^ c
            cy = (aa & bb) | (c & (aa ^ bb))
            return cy, (s, cy)

        _, (ss, cys) = jax.lax.scan(
            ripple, c0, (av.swapaxes(0, 1), bv.swapaxes(0, 1)))
        vals = vals.at[sums_idx].set(ss.swapaxes(0, 1))
        # cout is the carry *after the chain's last real bit* — padded tail
        # bits add 0+0 and would zero the carry, so index, don't take last
        cout_v = jnp.take_along_axis(
            cys.swapaxes(0, 1), last_idx[:, None, None], axis=1)[:, 0]
        vals = vals.at[cout_idx].set(cout_v)
    return vals, None


def _multi_scan(vals, bucket_arrays, flags, use_pallas):
    """Back-to-back lax.scans, one per width bucket, in topological order."""
    for (hl, hc), xs in zip(flags, bucket_arrays):
        body = functools.partial(_fused_body, has_luts=hl, has_chains=hc,
                                 use_pallas=use_pallas)
        vals, _ = jax.lax.scan(body, vals, xs)
    return vals


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("flags", "use_pallas"))
def _run_fused(vals, bucket_arrays, *, flags, use_pallas):
    return _multi_scan(vals, bucket_arrays, flags, use_pallas)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("flags", "use_pallas"))
def _run_fused_batch(vals, bucket_arrays, *, flags, use_pallas):
    return jax.vmap(
        lambda v, arrs: _multi_scan(v, arrs, flags, use_pallas)
    )(vals, bucket_arrays)


def _init_vals(plan: FusedPlan, pi_lanes: dict[int, np.ndarray],
               n_lane_words: int) -> jax.Array:
    vals = np.zeros((plan.n_signals + 1, n_lane_words), dtype=np.uint32)
    vals[CONST1] = 0xFFFFFFFF
    for s, v in pi_lanes.items():
        vals[s] = np.asarray(v, dtype=np.uint32)
    return jnp.asarray(vals)


def eval_netlist_jax(net: Netlist, pi_lanes: dict[int, np.ndarray],
                     n_lane_words: int, use_pallas: bool = True,
                     plan: FusedPlan | None = None) -> jax.Array:
    """Fused evaluation; returns ``vals[n_signals, n_lane_words]`` uint32.

    ``pi_lanes[signal]`` is a uint32 vector of packed test vectors.  Pass a
    precompiled ``plan`` to skip the content-digest cache lookup (the jit
    cache amortizes compilation by shape either way).
    """
    if plan is None:
        plan = plan_netlist(net)
    vals = _init_vals(plan, pi_lanes, n_lane_words)
    out = _run_fused(vals, plan.device_arrays(), flags=plan.flags,
                     use_pallas=use_pallas)
    mark_program_run(program_signature(plan, n_lane_words, use_pallas))
    return out[:plan.n_signals]


# ---------------------------------------------------------------------------
# envelope-grouped suite evaluation
# ---------------------------------------------------------------------------


def group_plans_by_envelope(plans, max_groups: int = DEFAULT_MAX_GROUPS,
                            signal_weight: float = 1.0) -> list[list[int]]:
    """Cluster plans (or any ``.envelope`` / ``.n_signals`` carriers, e.g.
    :class:`CircuitIR`) into <= ``max_groups`` compatible-envelope groups
    — delegated to the shared planner
    (:func:`repro.core.plan.group_by_envelope`, which the timing sweep
    uses too)."""
    return _planner.group_by_envelope(plans, max_groups=max_groups,
                                      signal_weight=signal_weight)


def grouping_padded_value_rows(plans, groups: list[list[int]]) -> dict:
    """Value-buffer padding accounting for a grouping: every member is
    padded to its group's largest ``n_signals``."""
    real = sum(p.n_signals for p in plans)
    padded = sum(len(g) * max(plans[i].n_signals for i in g) for g in groups)
    return {"real_rows": real, "padded_rows": padded,
            "waste": 1.0 - real / max(padded, 1)}


def group_layout(irs, max_buckets: int = DEFAULT_MAX_BUCKETS):
    """Shared padded layout of one envelope group: combined width profile,
    bucket bounds, envelopes and the per-member padded row volume.  Used
    by the group builder below and by the flow-level grouped-vs-
    per-circuit cost model (:func:`repro.core.flow.eval_mode_cost_model`)
    without building any device tensors."""
    L = max((ir.n_levels for ir in irs), default=0)
    if L == 0:
        L = 1
    m, c, b = _planner.combined_profile([ir.level_profile() for ir in irs],
                                        L)
    bounds = segment_levels(m, c, b, max_buckets)
    envelopes = _planner.bucket_envelopes(m, c, b, bounds)
    return {"bounds": bounds, "envelopes": envelopes,
            "rows_per_member": _planner.padded_rows(bounds, envelopes)}


def _build_group(nets: list[Netlist], max_buckets: int):
    """Stack one envelope group's member plans into vmappable tensors.

    Bucket boundaries are recomputed on the group's combined width profile
    and every member is padded to the group envelope; each member's sink
    rows point at the shared ``n_sig`` row.
    """
    irs = [lower_netlist_ir(net) for net in nets]
    n_sig = max(net.n_signals for net in nets)
    layout = group_layout(irs, max_buckets=max_buckets)
    bounds, envelopes = layout["bounds"], layout["envelopes"]
    member_plans = [
        plan_from_ir(ir, n_signals=n_sig, bounds=bounds,
                     envelopes=envelopes)
        for ir in irs]
    flags = tuple(
        (any(p.buckets[bi].has_luts for p in member_plans),
         any(p.buckets[bi].has_chains for p in member_plans))
        for bi in range(len(bounds)))
    stacked = tuple(
        tuple(jnp.asarray(np.stack([np.asarray(p.buckets[bi].arrays()[ai])
                                    for p in member_plans]))
              for ai in range(10))
        for bi in range(len(bounds)))
    return n_sig, stacked, flags, member_plans


def get_group_program(nets: list[Netlist],
                      max_buckets: int = DEFAULT_MAX_BUCKETS):
    """Cached stacked device tensors for one envelope group of netlists."""
    key = (tuple(netlist_digest(net) for net in nets), max_buckets)
    cached = _GROUP_CACHE.get(key)
    if cached is None:
        cached = _build_group(nets, max_buckets)
        _GROUP_CACHE.put(key, cached)
    return cached


@dataclass
class SuiteProgram:
    """A suite's clustering + stacked device tensors, prepared once.

    ``run`` evaluates new lanes without re-digesting, re-clustering or
    re-uploading anything — the handle benchmark loops should reuse.
    """

    n_signals: list[int]          # per input circuit
    names: list[str]
    groups: list[list[int]]       # member indices per envelope group
    programs: list[tuple]         # (n_sig, stacked, flags, member_plans)
    stats: dict

    def run(self, pi_lanes_list: list[dict[int, np.ndarray]],
            n_lane_words: int, use_pallas: bool = True) -> list[np.ndarray]:
        outs: list = [None] * len(self.n_signals)
        for members, (n_sig, stacked, flags,
                      member_plans) in zip(self.groups, self.programs):
            vals = np.zeros((len(members), n_sig + 1, n_lane_words),
                            dtype=np.uint32)
            vals[:, CONST1] = 0xFFFFFFFF
            for row, i in enumerate(members):
                for s, v in pi_lanes_list[i].items():
                    vals[row, s] = np.asarray(v, dtype=np.uint32)
            out = _run_fused_batch(jnp.asarray(vals), stacked, flags=flags,
                                   use_pallas=use_pallas)
            # np.asarray blocks on the device result — timing loops over
            # run() measure execution, not dispatch
            out = np.asarray(out)
            # all members share the group layout, so member 0's plan IS
            # the group's program shape signature
            mark_program_run(program_signature(
                member_plans[0], n_lane_words, use_pallas,
                batch=len(members)))
            for row, i in enumerate(members):
                outs[i] = out[row, :self.n_signals[i]]
        return outs


def prepare_suite_program(nets: list[Netlist],
                          max_groups: int = DEFAULT_MAX_GROUPS,
                          max_buckets: int = DEFAULT_MAX_BUCKETS,
                          plans: list[FusedPlan] | None = None,
                          groups: list[list[int]] | None = None
                          ) -> SuiteProgram:
    """Cluster a suite into <= ``max_groups`` compatible-envelope groups and
    build (or fetch from the content cache) each group's stacked tensors.
    Pass precomputed ``plans``/``groups`` (e.g. from a cost-model pass) to
    skip re-planning and the O(n^2) agglomerative clustering."""
    if plans is None:
        plans = [plan_netlist(net, max_buckets=max_buckets) for net in nets]
    if groups is None:
        groups = group_plans_by_envelope(plans, max_groups=max_groups)
    programs = [get_group_program([nets[i] for i in members],
                                  max_buckets=max_buckets)
                for members in groups]
    stats = {"n_groups": len(groups), "groups": []}
    for members, (_, _, _, member_plans) in zip(groups, programs):
        gp = member_plans[0]
        stats["groups"].append({
            "members": [nets[i].name for i in members],
            "n_buckets": len(gp.buckets),
            "bucket_shapes": [bk.shape for bk in gp.buckets],
            "padded_lut_rows": gp.padded_lut_rows * len(members),
            "padded_chain_bits": gp.padded_chain_bits * len(members),
        })
    return SuiteProgram(n_signals=[p.n_signals for p in plans],
                        names=[net.name for net in nets],
                        groups=groups, programs=programs, stats=stats)


def eval_netlists_batched_jax(nets: list[Netlist],
                              pi_lanes_list: list[dict[int, np.ndarray]],
                              n_lane_words: int,
                              use_pallas: bool = True,
                              max_groups: int = DEFAULT_MAX_GROUPS,
                              max_buckets: int = DEFAULT_MAX_BUCKETS,
                              return_stats: bool = False,
                              program: SuiteProgram | None = None):
    """Evaluate a suite of circuits as a few vmapped jit programs.

    Plans are clustered into <= ``max_groups`` envelope groups (one compile
    per group) and each group's members are padded to the group's bucketed
    envelope.  ``max_groups=1, max_buckets=1`` reproduces the old
    single-worst-case-envelope path exactly.  Pass a prepared ``program``
    to skip clustering/digesting in hot loops.  Returns per-circuit
    ``vals`` arrays in input order (plus a stats record when
    ``return_stats``).
    """
    if program is None:
        program = prepare_suite_program(nets, max_groups=max_groups,
                                        max_buckets=max_buckets)
    outs = program.run(pi_lanes_list, n_lane_words, use_pallas=use_pallas)
    if return_stats:
        return outs, program.stats
    return outs


# ---------------------------------------------------------------------------
# seed per-level dispatcher (perf baseline)
# ---------------------------------------------------------------------------


def eval_netlist_jax_levels(net: Netlist, pi_lanes: dict[int, np.ndarray],
                            n_lane_words: int,
                            use_pallas: bool = True) -> jax.Array:
    """The pre-fusion evaluator: one Python-dispatched kernel call per LUT
    level and one ``lax.scan`` per chain.  Kept as the measured baseline
    for the fused engine's speedup (see ``benchmarks/perf_iterations.py``).
    """
    from repro.kernels import ops

    by_luts, by_chains, _ = levelize(net)
    levels = sorted(set(by_luts) | set(by_chains))

    vals = jnp.zeros((net.n_signals, n_lane_words), dtype=jnp.uint32)
    vals = vals.at[CONST1].set(jnp.uint32(0xFFFFFFFF))
    for s, v in pi_lanes.items():
        vals = vals.at[s].set(jnp.asarray(v, dtype=jnp.uint32))

    for lv in levels:
        ids = by_luts.get(lv)
        if ids:
            kmax = max(1, max(len(net.lut_inputs[i]) for i in ids))
            ins = np.zeros((len(ids), kmax), dtype=np.int64)
            tts = np.zeros(len(ids), dtype=np.uint64)
            outs = np.zeros(len(ids), dtype=np.int64)
            for r, i in enumerate(ids):
                sig_ins = net.lut_inputs[i]
                k = len(sig_ins)
                ins[r, :k] = sig_ins
                tt = net.lut_tt[i]
                full = 0
                for rr in range(1 << (kmax - k)):
                    full |= tt << (rr * (1 << k))
                tts[r] = full & ((1 << min(64, 1 << kmax)) - 1)
                outs[r] = net.lut_out[i]
            gathered = vals[jnp.asarray(ins)]
            if kmax <= 5:
                out = ops.lut_eval(gathered, jnp.asarray(
                    tts.astype(np.uint32)), use_pallas=use_pallas)
            else:
                tt_lo = jnp.asarray((tts & np.uint64(0xFFFFFFFF))
                                    .astype(np.uint32))
                tt_hi = jnp.asarray((tts >> np.uint64(32)).astype(np.uint32))
                g5 = gathered[:, :5, :]
                sel = gathered[:, 5, :]
                lo = ops.lut_eval(g5, tt_lo, use_pallas=use_pallas)
                hi = ops.lut_eval(g5, tt_hi, use_pallas=use_pallas)
                out = (sel & hi) | (~sel & lo)
            vals = vals.at[jnp.asarray(outs)].set(out)
        for c in by_chains.get(lv, ()):
            ch = net.chains[c]
            av = vals[jnp.asarray(np.array(ch.a))]
            bv = vals[jnp.asarray(np.array(ch.b))]
            c0 = vals[ch.cin]

            def step(c_, ab):
                aa, bb = ab
                s = aa ^ bb ^ c_
                cy = (aa & bb) | (c_ & (aa ^ bb))
                return cy, s

            clast, ss = jax.lax.scan(step, c0, (av, bv))
            vals = vals.at[jnp.asarray(np.array(ch.sums))].set(ss)
            if ch.cout is not None:
                vals = vals.at[ch.cout].set(clast)
    return vals
