"""Compressor-tree reduction (Wallace / Dadda / Proposed-Wallace).

Rows are exploded into a bit-matrix (one list of signals per column).  Each
reduction stage inserts full-adder (3:2) and half-adder (2:2) compressors as
*boolean logic* (XOR3 / MAJ3 / XOR2 / AND2 LUT nodes) — exactly the paper's
strategy of emitting the compressor's boolean equations and letting logic
synthesis pack them into LUTs (§IV, *Compressor Tree Synthesis*).  The final
two rows are summed on a single ripple carry chain.

Structural hashing in the netlist gives compressor CSE for free: two FAs over
the same three signals are built once.
"""
from __future__ import annotations

from .netlist import (CONST0, Netlist, TT_AND2, TT_MAJ3, TT_XOR2, TT_XOR3)
from .synth import Row, add_rows


def _full_adder(net: Netlist, a: int, b: int, c: int) -> tuple[int, int]:
    s = net.add_lut((a, b, c), TT_XOR3)
    cy = net.add_lut((a, b, c), TT_MAJ3)
    return s, cy


def _half_adder(net: Netlist, a: int, b: int) -> tuple[int, int]:
    s = net.add_lut((a, b), TT_XOR2)
    cy = net.add_lut((a, b), TT_AND2)
    return s, cy


def _dadda_targets(max_height: int) -> list[int]:
    ds = [2]
    while ds[-1] < max_height:
        ds.append(int(ds[-1] * 3 / 2))
    return ds


def rows_to_columns(rows: list[Row], width_cap: int | None):
    if not rows:
        return [], 0
    lo = min(r.start for r in rows)
    hi = max(r.end for r in rows)
    if width_cap is not None:
        hi = min(hi, width_cap)
    ncols = hi - lo
    cols: list[list[int]] = [[] for _ in range(ncols)]
    for r in rows:
        for j, s in enumerate(r.bits):
            p = r.shift + j
            if s != CONST0 and lo <= p < hi:
                cols[p - lo].append(s)
    return cols, lo


def columns_to_rows(cols: list[list[int]], lo: int) -> list[Row]:
    """Split height-<=2 columns back into (up to) two rows."""
    height = max((len(c) for c in cols), default=0)
    assert height <= 2, f"columns not fully compressed (h={height})"
    rows = []
    for lane in range(2):
        bits = [c[lane] if len(c) > lane else CONST0 for c in cols]
        r = Row(lo, tuple(bits)).trimmed()
        if not r.is_zero():
            rows.append(r)
    return rows


def compress_columns(net: Netlist, cols: list[list[int]], algo: str):
    """Run reduction stages until every column has height <= 2."""
    n_stages = 0
    while max((len(c) for c in cols), default=0) > 2:
        n_stages += 1
        if algo == "dadda":
            targets = _dadda_targets(max(len(c) for c in cols))
            # largest target strictly below current max height
            cur = max(len(c) for c in cols)
            tgt = max(t for t in targets if t < cur)
            cols = _dadda_stage(net, cols, tgt)
        elif algo == "wallace":
            cols = _wallace_stage(net, cols, use_ha=True)
        elif algo == "pw":
            cols = _wallace_stage(net, cols, use_ha=False)
        else:
            raise ValueError(algo)
        if n_stages > 64:
            raise RuntimeError("compressor tree failed to converge")
    return cols


def _wallace_stage(net: Netlist, cols, use_ha: bool):
    ncols = len(cols)
    out: list[list[int]] = [[] for _ in range(ncols + 1)]
    for p, col in enumerate(cols):
        i = 0
        h = len(col)
        while h - i >= 3:
            s, cy = _full_adder(net, col[i], col[i + 1], col[i + 2])
            out[p].append(s)
            out[p + 1].append(cy)
            i += 3
        if use_ha and h - i == 2:
            s, cy = _half_adder(net, col[i], col[i + 1])
            out[p].append(s)
            out[p + 1].append(cy)
            i += 2
        while i < h:
            out[p].append(col[i])
            i += 1
    while out and not out[-1]:
        out.pop()
    return out


def _dadda_stage(net: Netlist, cols, target: int):
    """Reduce so that no column exceeds ``target`` after carries."""
    ncols = len(cols)
    out: list[list[int]] = [[] for _ in range(ncols + 1)]
    for p in range(ncols):
        col = list(cols[p]) + out[p]
        out[p] = []
        i = 0
        # minimum compressors so len - 2*fa - ha + carries_in_future <= target;
        # classic Dadda: compress only while the column is too tall.
        while len(col) - i > target:
            excess = len(col) - i - target
            if excess >= 2:
                s, cy = _full_adder(net, col[i], col[i + 1], col[i + 2])
                out[p].append(s)
                out[p + 1].append(cy)
                i += 3
            else:
                s, cy = _half_adder(net, col[i], col[i + 1])
                out[p].append(s)
                out[p + 1].append(cy)
                i += 2
        out[p].extend(col[i:])
    while out and not out[-1]:
        out.pop()
    return out


def reduce_compressor(net: Netlist, rows: list[Row], algo: str,
                      width_cap: int | None = None) -> Row:
    cols, lo = rows_to_columns(rows, width_cap)
    if not cols:
        return Row(0, ())
    cols = compress_columns(net, cols, algo)
    final = columns_to_rows(cols, lo)
    if not final:
        return Row(0, ())
    if len(final) == 1:
        return final[0]
    return add_rows(net, final[0], final[1], width_cap=width_cap, share=True)
