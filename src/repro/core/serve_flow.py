"""CAD-as-a-service: an async batched flow server over the unified flow.

:func:`repro.core.flow.pack_and_analyze` answers one question for one
caller.  This module serves *many concurrent callers* — the shape of a
synthesis service where several tenants stream pack/timing/eval requests
against a shared arch library — by applying the continuous-batching idea
from inference serving to CAD flows:

* **coalescing window** — a :class:`FlowServer` collects requests that
  arrive within ``batch_window_s`` of each other (plus everything queued
  while the previous batch was computing) and processes them as ONE
  batch, highest :attr:`FlowRequest.priority` first;
* **request dedup** — within a batch, requests for the same (netlist
  content digest, arch, seed) collapse into one *job*: two tenants
  submitting the same circuit share one pack, one lowering and one
  timing row, and both futures resolve from the same record;
* **batched programs** — the batch's timing jobs are grouped by arch
  *structural class* (delays never steer the packer, see
  :mod:`repro.core.sweep`), circuits are envelope-clustered with the
  evaluator's shared planner (:func:`repro.core.plan.group_by_envelope`)
  and each group runs as one jit program over the class's stacked
  delay-table rows; eval jobs run through
  :func:`repro.core.flow.evaluate_suite` (``warm="auto"`` — compile
  costs derived from what has actually run, never caller-asserted);
* **bounded multi-tenant caches** — every store is a registry LRU
  (:mod:`repro.core.plan`): packs keyed by *pack digest* (structure
  minus truth tables), timing records by (pack digest, arch, seed),
  eval results by (content digest, lane config), compiled timing
  programs by member digests.  One :func:`repro.core.plan.cache_stats`
  call is the whole telemetry surface; a cache under eviction pressure
  recomputes correct results — it only stops amortizing.

Netlist-delta fast path
-----------------------
A request carrying ``base_digest`` (the content digest of a previously
served netlist) is an *incremental* edit.  Because neither packing nor
static timing ever reads LUT truth tables, pack results are keyed by
:meth:`~repro.core.netlist.Netlist.pack_digest`: a truth-table-only edit
— the shape of an incremental-synthesis constant/weight update — hits
the base's pack AND timing record outright and re-runs only functional
eval.

A *structural* edit takes the dirty-set path: the server diffs the
edited netlist against the served base
(:func:`~repro.core.repack.netlist_structural_diff`), patches the base's
ClusterPlan prefix instead of rebuilding it
(:func:`~repro.core.sweep.prefix_for_edit`, hosted in the shared prefix
store under ``(pack digest, base digest, seed)``), replays the base's
recorded greedy decisions over everything *outside* the dirty set
(:func:`~repro.core.repack.repack_delta` — surviving LBs are frozen as
placed obstacles, only dirty members and divergence-reached LBs re-run
real scans), patches only the touched rows of the cached CircuitIR
(:func:`~repro.core.circuit_ir.apply_pack_delta`), and proves the
touched clusters with a scoped symbolic equivalence pass
(:func:`~repro.core.equiv.verify_clusters`; the full-circuit proof runs
only on fallback modes, where the dirty set no longer bounds the
touched region).  Any eligibility failure (shape change, absorbed-LUT
edit, absorption/pairing flip, evicted base state, dirty-set growth
past the divergence bound) falls back to the full path — every mode is
byte-identical to a fresh ``pack()``.  :attr:`FlowResult.delta` carries
the per-cluster attribution (:func:`~repro.core.repack.cluster_delta`:
frozen / moved / re-clustered LB counts) plus the repack-path and
verify summaries.

Determinism contract
--------------------
Every served record is **bit-identical** to the single-request reference
``flow.pack_and_analyze(net, arch, seeds=(seed,))`` — batching, caching,
coalescing and eviction are throughput matters only.  That holds by
construction (``repack`` is byte-identical to ``pack``; the batched
timing program is bit-identical to the oracle) and is gated by
``tests/core/test_serve_flow.py`` and ``benchmarks/serve_latency.py``.

The server is a single-process asyncio design: ``submit()`` is awaited
from any number of client tasks in one event loop; the batch compute
itself is synchronous (CPU-bound jit dispatch), so concurrency buys
*coalescing*, not parallel compute.
"""
from __future__ import annotations

import asyncio
import itertools
import time
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from . import flow as _flow
from . import plan as _planner
from .alm import ARCHS, ArchParams
from .netlist import Netlist
from .repack import (cluster_delta, netlist_structural_diff, pack_prefix,
                     repack_delta, repack_with_log)
from .sweep import prefix_for_edit
from .timing import record_timing_wall
from .timing_vec import (build_suite_timing_program, critical_path_numpy,
                         delay_components, metrics_from_cp)

#: packs per (pack digest, structural key, seed).  Keyed by *pack*
#: digest, not content digest: a truth-table-only delta hits here with
#: zero bookkeeping — the key itself encodes "packing cannot differ".
_PACKS = _planner.register_cache("serve_packs", cap=256)

#: analyze-shaped records per (pack digest, arch name, seed) — the same
#: pack-digest keying makes tt-only deltas reuse timing verbatim.
_TIMING = _planner.register_cache("serve_timing", cap=2048)

#: per-PO eval lane results per (content digest, n_lane_words,
#: lanes_seed) — content digest here, truth tables obviously matter.
_EVAL = _planner.register_cache("serve_eval", cap=256)

#: compiled suite timing programs per (member pack digests, structural
#: key, seed, max_buckets) — a repeated batch shape reuses the compile.
_PROGRAMS = _planner.register_cache("serve_programs", cap=64)

#: content digest -> pack digest of every netlist ever served — how a
#: ``base_digest`` (content) resolves to the base pack (pack-keyed).
_DIGESTS = _planner.register_cache("serve_digests", cap=4096)

#: the prefix store shared with :mod:`repro.core.sweep` — delta
#: requests re-cluster from the same ClusterPlan prefixes sweeps warm.
#: Edited-netlist prefixes land in the SAME store under the
#: ``(pack digest, base digest, seed)`` keying of
#: :func:`repro.core.sweep.prefix_for_edit`.
_PREFIXES = _planner.register_cache("pack_prefix", cap=64)

#: greedy decision logs per (pack digest, structural key, seed) — what a
#: later structural edit replays against.  Recorded on every full
#: re-cluster (``repack_with_log``); delta-produced packs do not get a
#: log (an advised replay cannot also record), so a chain of edits
#: re-records at its first full repack.
_REPACK_LOGS = _planner.register_cache("serve_repack_logs", cap=32)

ANALYSES = ("area", "timing", "eval")

_AREA_KEYS = ("alms", "lbs", "area_mwta", "adders", "luts",
              "concurrent_luts")
_TIMING_KEYS = ("arch", "critical_path_ps", "fmax_mhz", "adp")


@dataclass
class FlowRequest:
    """One tenant request: run ``analyses`` of ``net`` under ``arch``.

    ``analyses`` is any subset of ``("area", "timing", "eval")``; area
    and timing ride the same pack+timing job, eval is arch-independent
    and keyed by lane configuration (``n_lane_words`` x ``lanes_seed``,
    or explicit ``pi_lanes``).  ``base_digest`` — the
    :meth:`~repro.core.netlist.Netlist.content_digest` of a previously
    served netlist — opts into the delta fast path.  Higher ``priority``
    drains first when a batch overflows ``max_batch``.
    """

    net: Netlist
    arch: str | ArchParams
    analyses: Sequence[str] = ("area", "timing")
    priority: int = 0
    seed: int = 0
    base_digest: str | None = None
    n_lane_words: int = 4
    lanes_seed: int = 0
    pi_lanes: dict | None = None
    tenant: str = ""

    def __post_init__(self):
        bad = [a for a in self.analyses if a not in ANALYSES]
        if bad:
            raise ValueError(f"unknown analyses {bad!r} "
                             f"(supported: {ANALYSES})")
        if not self.analyses:
            raise ValueError("request with no analyses")


@dataclass
class FlowResult:
    """What a future resolves to: per-analysis records + attribution.

    ``record`` is the full ``timing.analyze``-shaped dict (present when
    area/timing ran); ``analyses`` holds the per-analysis views the
    request asked for (``"eval"`` maps PO name -> ``[bus, lane_words]``
    uint32 lanes).  ``walls`` carries the request's queue/service/total
    latencies plus the shared per-stage walls of its batch; ``batch``
    records how the request was served (batch id, how many requests the
    batch held, how many shared this request's job, cache hits).
    ``delta`` is the delta-path attribution when ``base_digest`` was
    given.  Records may be shared between coalesced requests — treat as
    read-only.
    """

    net: str
    digest: str
    arch: str
    seed: int
    analyses: dict
    record: dict | None
    delta: dict | None
    batch: dict
    walls: dict


@dataclass
class _Pending:
    req: FlowRequest
    future: asyncio.Future
    t_submit: float
    seq: int
    digest: str = ""


@dataclass
class _Job:
    """One deduplicated unit of work: (digest, arch name, seed)."""

    net: Netlist
    arch: ArchParams
    seed: int
    digest: str
    analyses: set = field(default_factory=set)
    entries: list = field(default_factory=list)
    base_digest: str | None = None
    pack_digest: str = ""
    pack: object = None
    ir: object = None
    record: dict | None = None
    delta: dict | None = None
    pack_cached: bool = False
    timing_cached: bool = False
    delta_info: dict | None = None   # repack-path attribution
    verify: dict | None = None       # scoped/full equivalence summary


def _eval_key(req: FlowRequest, digest: str):
    """Dedup key for one eval task; explicit ``pi_lanes`` are keyed by
    object identity (no content claim), generated lanes by config."""
    if req.pi_lanes is not None:
        return (digest, req.n_lane_words, "explicit", id(req.pi_lanes))
    return (digest, req.n_lane_words, "seeded", req.lanes_seed)


class FlowServer:
    """Async batched flow server (see module docstring).

    ``batch_window_s`` is the coalescing window: after the first request
    of a batch arrives the server sleeps this long (yielding the loop)
    so concurrent submitters can join, then drains up to ``max_batch``
    entries by ``(-priority, arrival)``.  ``memoize=False`` disables
    result-cache *reads* (timing/eval records recompute every time —
    what the latency benchmark measures as the honest coalescing win);
    stores and the pack/program caches stay on, as they are
    correctness-neutral reuse, not result memoization.
    """

    def __init__(self, batch_window_s: float = 0.002, max_batch: int = 64,
                 timing_backend: str = "jax", max_buckets: int = 3,
                 max_groups: int = 4, use_pallas: bool = True,
                 memoize: bool = True, eval_mode: str = "auto",
                 eval_warm: bool | str = "auto",
                 verify_deltas: bool = True,
                 pad_timing_shapes: bool = True):
        if timing_backend not in ("jax", "numpy"):
            raise ValueError(f"unknown timing backend {timing_backend!r}")
        self.batch_window_s = batch_window_s
        self.max_batch = max_batch
        self.timing_backend = timing_backend
        self.max_buckets = max_buckets
        self.max_groups = max_groups
        self.use_pallas = use_pallas
        self.memoize = memoize
        self.eval_mode = eval_mode
        self.eval_warm = eval_warm
        #: prove every structurally-delta-served pack: per-cluster
        #: symbolic proof scoped to the touched LBs on the incremental
        #: path, the full-circuit proof on fallbacks
        self.verify_deltas = verify_deltas
        #: quantize batched timing-program shapes to power-of-two
        #: envelopes so rotating batch compositions share jit compiles
        self.pad_timing_shapes = pad_timing_shapes
        self.stats = {"n_requests": 0, "n_batches": 0, "n_jobs": 0,
                      "n_coalesced": 0, "n_pack_hits": 0,
                      "n_timing_hits": 0, "n_eval_hits": 0,
                      "n_delta_requests": 0, "n_delta_pack_reuse": 0,
                      "n_delta_incremental": 0, "n_delta_fallback": 0,
                      "n_verify_scoped": 0, "n_verify_full": 0}
        self._pending: list[_Pending] = []
        self._seq = itertools.count()
        self._batch_ids = itertools.count()
        self._loop = None
        self._wake: asyncio.Event | None = None
        self._task: asyncio.Task | None = None

    # -- client surface ----------------------------------------------------

    def submit_nowait(self, req: FlowRequest) -> asyncio.Future:
        """Enqueue ``req``; returns the request's future immediately.
        Must run inside an event loop (the server's batch task lives on
        it)."""
        loop = asyncio.get_running_loop()
        self._ensure_running(loop)
        entry = _Pending(req=req, future=loop.create_future(),
                         t_submit=time.perf_counter(), seq=next(self._seq),
                         digest=req.net.content_digest())
        self._pending.append(entry)
        self.stats["n_requests"] += 1
        self._wake.set()
        return entry.future

    async def submit(self, req: FlowRequest) -> FlowResult:
        """Enqueue ``req`` and await its result."""
        return await self.submit_nowait(req)

    async def aclose(self) -> None:
        """Stop the batch task; pending (undrained) futures fail."""
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        for entry in self._pending:
            if not entry.future.done():
                entry.future.set_exception(
                    RuntimeError("flow server closed"))
        self._pending.clear()

    def cache_stats(self) -> dict:
        """The shared registry telemetry (all caches, not just serving's:
        the server *is* a tenant of the same bounded layer)."""
        return _planner.cache_stats()

    # -- batch loop --------------------------------------------------------

    def _ensure_running(self, loop) -> None:
        if self._task is not None and not self._task.done() \
                and loop is self._loop:
            return
        self._loop = loop
        self._wake = asyncio.Event()
        self._task = loop.create_task(self._batch_loop())

    def _drain(self) -> list[_Pending]:
        self._pending.sort(key=lambda e: (-e.req.priority, e.seq))
        batch = self._pending[:self.max_batch]
        del self._pending[:self.max_batch]
        return batch

    async def _batch_loop(self) -> None:
        while True:
            await self._wake.wait()
            self._wake.clear()
            # the coalescing window: let concurrent submitters join the
            # batch (0 still yields once, so same-tick submits coalesce)
            await asyncio.sleep(self.batch_window_s)
            while self._pending:
                batch = self._drain()
                try:
                    self._process_batch(batch)
                except BaseException as exc:  # noqa: BLE001 — fail futures
                    for entry in batch:
                        if not entry.future.done():
                            entry.future.set_exception(
                                RuntimeError(
                                    f"flow batch failed: {exc!r}"))

    # -- batch compute (synchronous) ---------------------------------------

    def _process_batch(self, batch: list[_Pending]) -> None:
        t0 = time.perf_counter()
        walls = {"coalesce_s": 0.0, "prefix_s": 0.0, "repack_s": 0.0,
                 "lower_s": 0.0, "verify_s": 0.0, "build_s": 0.0,
                 "timing_s": 0.0, "eval_s": 0.0, "total_s": 0.0}
        batch_id = next(self._batch_ids)

        jobs = self._coalesce(batch, walls)
        pack_jobs = [j for j in jobs.values()
                     if j.analyses & {"area", "timing"}]
        self._pack_stage(pack_jobs, walls)
        self._timing_stage(pack_jobs, walls)
        eval_out = self._eval_stage(batch, jobs, walls)

        t_done = time.perf_counter()
        walls["total_s"] = t_done - t0
        self.stats["n_batches"] += 1
        self.stats["n_jobs"] += len(jobs)
        self.stats["n_coalesced"] += len(batch) - len(jobs)

        for entry in batch:
            req = entry.req
            job = jobs[(entry.digest, _flow._arch(req.arch).name, req.seed)]
            analyses: dict = {}
            if job.record is not None:
                if "area" in req.analyses:
                    analyses["area"] = {k: job.record[k]
                                        for k in _AREA_KEYS}
                if "timing" in req.analyses:
                    analyses["timing"] = {k: job.record[k]
                                          for k in _TIMING_KEYS}
            if "eval" in req.analyses:
                analyses["eval"] = eval_out[_eval_key(req, entry.digest)]
            res = FlowResult(
                net=req.net.name, digest=entry.digest, arch=job.arch.name,
                seed=req.seed, analyses=analyses, record=job.record,
                delta=job.delta,
                batch={"id": batch_id, "n_requests": len(batch),
                       "n_jobs": len(jobs), "n_shared": len(job.entries),
                       "pack_cached": job.pack_cached,
                       "timing_cached": job.timing_cached},
                walls={"queue_s": t0 - entry.t_submit,
                       "service_s": t_done - t0,
                       "total_s": t_done - entry.t_submit,
                       "stages": dict(walls)})
            if not entry.future.done():
                entry.future.set_result(res)

    def _coalesce(self, batch: list[_Pending], walls: dict) -> dict:
        """Collapse the batch into (digest, arch, seed) jobs; union the
        analyses so coalesced requests with different asks share one."""
        t0 = time.perf_counter()
        jobs: dict[tuple, _Job] = {}
        for entry in batch:
            req = entry.req
            arch = _flow._arch(req.arch)
            key = (entry.digest, arch.name, req.seed)
            job = jobs.get(key)
            if job is None:
                job = _Job(net=req.net, arch=arch, seed=req.seed,
                           digest=entry.digest)
                jobs[key] = job
            job.analyses.update(req.analyses)
            job.entries.append(entry)
            if req.base_digest is not None and job.base_digest is None:
                job.base_digest = req.base_digest
        walls["coalesce_s"] += time.perf_counter() - t0
        return jobs

    def _pack_stage(self, pack_jobs: list[_Job], walls: dict) -> None:
        """Resolve each job's pack: pack-digest cache hit (tt-only delta
        or repeat), else the dirty-set structural-delta path when the
        request names a served base, else prefix + full re-cluster.
        Every path is byte-identical to ``pack()``."""
        for job in pack_jobs:
            skey = job.arch.structural_key()
            pd = job.net.pack_digest()
            job.pack_digest = pd
            _DIGESTS.put(job.digest, pd)
            pack = _PACKS.get((pd, skey, job.seed))
            job.pack_cached = pack is not None
            if pack is None and job.base_digest is not None:
                pack = self._delta_pack(job, skey, walls)
            if pack is None:
                prefix = _PREFIXES.get((job.digest, job.seed))
                if prefix is None:
                    t1 = time.perf_counter()
                    prefix = pack_prefix(job.net, seed=job.seed)
                    _PREFIXES.put((job.digest, job.seed), prefix)
                    walls["prefix_s"] += time.perf_counter() - t1
                t1 = time.perf_counter()
                pack, log = repack_with_log(prefix, job.arch)
                walls["repack_s"] += time.perf_counter() - t1
                _PACKS.put((pd, skey, job.seed), pack)
                _REPACK_LOGS.put((pd, skey, job.seed), log)
            elif job.pack_cached:
                self.stats["n_pack_hits"] += 1
            job.pack = pack
            if job.base_digest is not None:
                self._attribute_delta(job, skey)

    def _delta_pack(self, job: _Job, skey, walls: dict):
        """The dirty-set structural-delta path: diff against the served
        base, patch its prefix (``prefix_for_edit`` — hosted in the
        shared store keyed by (pack digest, base digest, seed)), replay
        the base's decision log over the dirty set, patch the cached IR's
        dirty columns, and prove the touched clusters.  Returns the pack
        (byte-identical to a fresh ``pack()``) or ``None`` when any
        eligibility gate fails — the caller then runs the full path, and
        ``job.delta_info`` says why."""
        base_pd = _DIGESTS.get(job.base_digest)
        if base_pd is None:
            return None
        hit = _PREFIXES.get((job.base_digest, job.seed))
        base_prefix = hit
        base_log = _REPACK_LOGS.get((base_pd, skey, job.seed))
        if base_prefix is None or base_log is None:
            job.delta_info = {"mode": "full", "reason": "base_evicted"}
            return None
        diff = netlist_structural_diff(base_prefix.net, job.net)
        if diff is None:
            job.delta_info = {"mode": "full", "reason": "shape"}
            return None
        t1 = time.perf_counter()
        new_prefix, pinfo = prefix_for_edit(base_prefix, job.net,
                                            base_log=base_log,
                                            prefixes=_PREFIXES)
        walls["prefix_s"] += time.perf_counter() - t1
        if new_prefix is None:
            job.delta_info = {"mode": "full",
                              "reason": pinfo.get("reason", "prefix")}
            return None
        t1 = time.perf_counter()
        pack, rinfo = repack_delta(
            new_prefix, base_log, job.arch,
            dirty_atoms=pinfo.get("dirty_atoms", frozenset()))
        walls["repack_s"] += time.perf_counter() - t1
        t1 = time.perf_counter()
        from .circuit_ir import apply_pack_delta

        job.ir = apply_pack_delta(pack, base_prefix.net,
                                  edited_luts=diff["changed_inputs"],
                                  tt_luts=diff["changed_tt"])
        walls["lower_s"] += time.perf_counter() - t1
        job.delta_info = dict(rinfo, prefix_mode=pinfo.get("mode"),
                              prefix_store=pinfo.get("store"))
        self.stats["n_delta_incremental" if rinfo["mode"] == "incremental"
                   else "n_delta_fallback"] += 1
        if self.verify_deltas:
            self._verify_delta(job, pack, diff, rinfo, walls)
            if job.verify is not None and not job.verify["equivalent"]:
                # a failed proof means a packer bug, not a delta bug
                # (every mode is byte-identical by construction) — but
                # never serve an unproven delta: fall back to the full
                # path and surface the failure in the attribution
                job.delta_info = {"mode": "full",
                                  "reason": "verify_failed"}
                return None
        _PACKS.put((job.pack_digest, skey, job.seed), pack)
        return pack

    def _verify_delta(self, job: _Job, pack, diff: dict, rinfo: dict,
                      walls: dict) -> None:
        """Verify-after-repack: on the incremental path a symbolic proof
        scoped to the touched clusters (edited LUTs' LBs + every
        diverged LB); on fallback modes the full-circuit proof — the
        dirty set is no longer a sound touch bound there."""
        from .equiv import reelaborate, symbolic_equivalence_report, \
            verify_clusters

        t1 = time.perf_counter()
        if rinfo["mode"] == "incremental":
            touched = set(rinfo.get("div_lbs", ()))
            for li in set(diff["changed_inputs"]) | set(diff["changed_tt"]):
                site = pack.lut_site.get(li)
                if site is not None:
                    touched.add(int(pack.alm_lb[site]))
            rep = verify_clusters(pack, sorted(touched))
            self.stats["n_verify_scoped"] += 1
        else:
            rep = symbolic_equivalence_report(job.net, reelaborate(pack))
            self.stats["n_verify_full"] += 1
        walls["verify_s"] += time.perf_counter() - t1
        job.verify = {
            "method": rep["method"], "equivalent": rep["equivalent"],
            "lbs": rep.get("lbs"), "proven_luts": rep["proven_luts"],
            "fallback_closures": rep["fallback"]}

    def _attribute_delta(self, job: _Job, skey) -> None:
        self.stats["n_delta_requests"] += 1
        base_pd = _DIGESTS.get(job.base_digest)
        if base_pd is None:
            job.delta = {"mode": "unknown_base",
                         "base_digest": job.base_digest}
            return
        if base_pd == job.pack_digest:
            # tt-only (or no-op) edit: the pack-digest keying already
            # served the base pack and will serve its timing records
            self.stats["n_delta_pack_reuse"] += 1
            job.delta = {"mode": "tt_only", "n_changed": 0,
                         "unchanged_frac": 1.0,
                         "pack_reused": job.pack_cached,
                         "base_digest": job.base_digest}
            return
        base_pack = _PACKS.get((base_pd, skey, job.seed))
        if base_pack is None:
            job.delta = {"mode": "structural_base_evicted",
                         "base_digest": job.base_digest}
            return
        # frozen = same LB signature at the same index, moved = same
        # signature elsewhere, re-clustered = membership changed
        d = cluster_delta(base_pack, job.pack)
        job.delta = dict(d, mode="structural",
                         base_digest=job.base_digest)
        if job.delta_info is not None:
            job.delta["repack"] = job.delta_info
        if job.verify is not None:
            job.delta["verify"] = job.verify

    def _timing_stage(self, pack_jobs: list[_Job], walls: dict) -> None:
        """Batched timing for every job without a (memoized) record:
        grouped by structural class, envelope-clustered, one program per
        group over the class's stacked delay rows."""
        need: list[_Job] = []
        for job in pack_jobs:
            tkey = (job.pack_digest, job.arch.name, job.seed)
            rec = _TIMING.get(tkey) if self.memoize else None
            if rec is not None:
                job.record = rec
                job.timing_cached = True
                self.stats["n_timing_hits"] += 1
            else:
                need.append(job)
        if not need:
            return
        by_class: dict[tuple, list[_Job]] = {}
        for job in need:
            by_class.setdefault(job.arch.structural_key(), []).append(job)
        for skey, class_jobs in by_class.items():
            # distinct IRs (by pack key) and distinct delay rows (by
            # arch name) — two tenants' jobs on the same circuit/arch
            # pair occupy one (row, column) of the batched program
            ir_index: dict[tuple, int] = {}
            irs = []
            arch_index: dict[str, int] = {}
            arch_rows: list[ArchParams] = []
            for job in class_jobs:
                pkey = (job.pack_digest, skey, job.seed)
                if pkey not in ir_index:
                    if job.ir is not None:
                        # the delta path already patched the cached IR's
                        # dirty columns — no re-lowering
                        ir = job.ir
                    else:
                        t1 = time.perf_counter()
                        prefix = _PREFIXES.get((job.digest, job.seed))
                        tpl = (prefix.ir_template if prefix is not None
                               else None)
                        ir = job.pack.lower_ir(template=tpl)
                        if (prefix is not None
                                and prefix.ir_template is None):
                            prefix.ir_template = ir
                        walls["lower_s"] += time.perf_counter() - t1
                    ir_index[pkey] = len(irs)
                    irs.append(ir)
                job.ir = irs[ir_index[pkey]]
                if job.arch.name not in arch_index:
                    arch_index[job.arch.name] = len(arch_rows)
                    arch_rows.append(job.arch)
            tables = np.stack([a.delay_table() for a in arch_rows])
            cps = np.zeros((len(irs), len(arch_rows)))
            if self.timing_backend == "jax":
                t1 = time.perf_counter()
                # members keyed by full (pack digest, skey, seed) — two
                # batches whose IRs differ only in pack seed must not
                # share a program row
                prog_key = (tuple(ir_index), self.max_buckets,
                            self.pad_timing_shapes)
                progs = _PROGRAMS.get(prog_key)
                if progs is None:
                    groups = _planner.group_by_envelope(
                        irs, max_groups=self.max_groups)
                    progs = [(members, build_suite_timing_program(
                        [irs[i] for i in members],
                        max_buckets=self.max_buckets,
                        pad_shapes=self.pad_timing_shapes))
                        for members in groups]
                    _PROGRAMS.put(prog_key, progs)
                walls["build_s"] += time.perf_counter() - t1
                t1 = time.perf_counter()
                for members, prog in progs:
                    gcps = prog.run(tables)
                    for row, gi in enumerate(members):
                        cps[gi] = gcps[row]
                walls["timing_s"] += time.perf_counter() - t1
            else:
                t1 = time.perf_counter()
                for k, arow in enumerate(arch_rows):
                    comps = delay_components(arow.delay_table())
                    for g, ir in enumerate(irs):
                        cps[g, k] = critical_path_numpy(ir, comps)
                walls["timing_s"] += time.perf_counter() - t1
            for job in class_jobs:
                cp = float(cps[ir_index[(job.pack_digest, skey, job.seed)],
                               arch_index[job.arch.name]])
                job.record = metrics_from_cp(job.ir, job.arch, cp)
                _TIMING.put((job.pack_digest, job.arch.name, job.seed),
                            job.record)
        record_timing_wall(
            walls["timing_s"] + walls["build_s"] + walls["lower_s"],
            calls=len(need))

    def _eval_stage(self, batch: list[_Pending], jobs: dict,
                    walls: dict) -> dict:
        """Deduplicated functional eval: one task per (digest, lane
        config), batched through ``evaluate_suite`` per lane count."""
        tasks: dict[tuple, tuple[Netlist, dict]] = {}
        for entry in batch:
            req = entry.req
            if "eval" not in req.analyses:
                continue
            key = _eval_key(req, entry.digest)
            if key not in tasks:
                lanes = (req.pi_lanes if req.pi_lanes is not None else
                         _flow.random_lanes(req.net, req.n_lane_words,
                                            seed=req.lanes_seed))
                tasks[key] = (req.net, lanes)
        out: dict[tuple, dict] = {}
        to_run: dict[int, list[tuple]] = {}
        for key, (net, lanes) in tasks.items():
            memo = _EVAL.get(key) if (self.memoize
                                      and key[2] == "seeded") else None
            if memo is not None:
                out[key] = memo
                self.stats["n_eval_hits"] += 1
            else:
                to_run.setdefault(key[1], []).append(key)
        t1 = time.perf_counter()
        for n_lane_words, keys in to_run.items():
            nets = [tasks[k][0] for k in keys]
            lanes_list = [tasks[k][1] for k in keys]
            vals_list, _stats = _flow.evaluate_suite(
                nets, lanes_list, n_lane_words, use_pallas=self.use_pallas,
                max_groups=self.max_groups, max_buckets=self.max_buckets,
                mode=self.eval_mode, warm=self.eval_warm)
            for key, net, vals in zip(keys, nets, vals_list):
                po = {name: vals[np.asarray(bus, dtype=np.int64)]
                      for name, bus in net.pos.items()}
                out[key] = po
                if key[2] == "seeded":
                    _EVAL.put(key, po)
        walls["eval_s"] += time.perf_counter() - t1
        return out


def serve_requests(requests: Sequence[FlowRequest],
                   **server_kwargs) -> list[FlowResult]:
    """Synchronous front-end: run ``requests`` through one
    :class:`FlowServer` on a fresh event loop, submitting all of them
    concurrently (so they coalesce exactly as live tenants would), and
    return results in request order."""

    async def _main():
        server = FlowServer(**server_kwargs)
        try:
            return list(await asyncio.gather(
                *(server.submit(r) for r in requests)))
        finally:
            await server.aclose()

    return asyncio.run(_main())
