"""Multiplier / reduction synthesis (the paper's §IV CAD enhancements).

Everything is built around *rows*: a row is a bit-vector of signals with a
left shift, representing ``value = sum(bits[j] << (shift + j))``.  Unrolled
(constant-coefficient) multiplication produces one row per set "selector bit"
of the constant; variable multiplication produces one AND-gated row per
multiplier bit.  Reduction of the rows to a single bus is delegated to:

* ``cascade``      — sequential accumulation on carry chains (Fig. 1 left),
* ``binary``       — improved binary adder tree with the strength-heuristic DP
                     (Algorithm 1) and duplicate-chain sharing,
* ``wallace`` / ``dadda`` / ``pw`` — compressor trees (Fig. 1), LUT compressors
                     + one final carry chain,
* ``vtr_baseline`` — unoptimized adjacent-pair binary tree, no zero-row skip,
                     no chain sharing (models stock VTR/Parmys behaviour).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from .netlist import CONST0, Netlist, TT_AND2

ALGOS = ("vtr_baseline", "cascade", "binary", "wallace", "dadda", "pw")


@dataclass(frozen=True)
class Row:
    shift: int
    bits: tuple[int, ...]

    @property
    def start(self) -> int:
        return self.shift

    @property
    def end(self) -> int:  # one past the last bit position
        return self.shift + len(self.bits)

    def bit_at(self, pos: int) -> int:
        j = pos - self.shift
        if 0 <= j < len(self.bits):
            return self.bits[j]
        return CONST0

    def is_zero(self) -> bool:
        return all(b == CONST0 for b in self.bits)

    def trimmed(self) -> "Row":
        bits = list(self.bits)
        shift = self.shift
        while bits and bits[-1] == CONST0:
            bits.pop()
        while bits and bits[0] == CONST0:
            bits.pop(0)
            shift += 1
        if not bits:
            return Row(0, ())
        return Row(shift, tuple(bits))


# ---------------------------------------------------------------------------
# row addition on a carry chain
# ---------------------------------------------------------------------------


def chain_key_for(ra: Row, rb: Row, width_cap: int | None = None):
    """The structural key of the carry chain that would add ``ra + rb``.

    Key is *relative*: positions are taken from the chain start, so two
    row-pairs that are shifted copies of each other produce identical keys —
    this is what lets shifted duplicate chains be shared.
    """
    p0 = max(ra.start, rb.start)
    p1 = max(ra.end, rb.end)
    if width_cap is not None:
        p1 = min(p1, width_cap)
    a = tuple(ra.bit_at(p) for p in range(p0, p1))
    b = tuple(rb.bit_at(p) for p in range(p0, p1))
    return a, b


def add_rows(net: Netlist, ra: Row, rb: Row, width_cap: int | None = None,
             share: bool = True) -> Row:
    """Emit a carry chain computing ``ra + rb`` and return the result row.

    Bits below the overlap pass through untouched (no adders burned on
    them).  With ``share=True`` identical chains are reused via the netlist's
    structural chain cache.
    """
    ra, rb = ra.trimmed(), rb.trimmed()
    if ra.is_zero() and rb.is_zero():
        return Row(0, ())
    if ra.is_zero():
        return rb
    if rb.is_zero():
        return ra
    if ra.start > rb.start:
        ra, rb = rb, ra
    p0 = max(ra.start, rb.start)
    p1 = max(ra.end, rb.end)
    capped = width_cap is not None and p1 > width_cap
    if width_cap is not None:
        p1 = min(p1, width_cap)
    if p1 <= p0:  # no overlap at all: concatenation
        lo = ra
        bits = list(lo.bits) + [CONST0] * (rb.start - lo.end) + list(rb.bits)
        return Row(lo.shift, tuple(bits)).trimmed()
    a = [ra.bit_at(p) for p in range(p0, p1)]
    b = [rb.bit_at(p) for p in range(p0, p1)]
    if share:
        sums, cout = net.add_chain(a, b, want_cout=not capped)
    else:
        sums, cout = _add_chain_fresh(net, a, b, want_cout=not capped)
    low = [ra.bit_at(p) for p in range(ra.start, p0)]
    bits = low + list(sums)
    if cout is not None:
        bits.append(cout)
    return Row(ra.start, tuple(bits)).trimmed()


def add_rows_naive(net: Netlist, ra: Row, rb: Row,
                   width_cap: int | None = None) -> Row:
    """Stock-VTR row addition: a fresh full-width ripple chain.

    No low-bit passthrough, no constant propagation, no chain sharing — each
    add instantiates adders across the union of both rows' spans, exactly the
    redundant behaviour the paper measures against (§IV: baseline VTR uses
    2.85x more full adders on a ``01010101`` constant).
    """
    p0 = min(ra.start, rb.start)
    p1 = max(ra.end, rb.end)
    if width_cap is not None:
        p1 = min(p1, width_cap)
    capped = width_cap is not None and max(ra.end, rb.end) + 1 > width_cap
    if p1 <= p0:
        return Row(0, ())
    a = [ra.bit_at(p) for p in range(p0, p1)]
    b = [rb.bit_at(p) for p in range(p0, p1)]
    sums, cout = _add_chain_fresh(net, a, b, want_cout=not capped)
    bits = list(sums)
    if cout is not None:
        bits.append(cout)
    return Row(p0, tuple(bits))


def _add_chain_fresh(net: Netlist, a, b, want_cout: bool):
    """A chain that bypasses structural hashing (models stock VTR)."""
    sums = [net.new_sig() for _ in a]
    cout = net.new_sig() if want_cout else None
    from .netlist import Chain

    ci = len(net.chains)
    net.chains.append(Chain(a=list(a), b=list(b), sums=sums, cin=CONST0, cout=cout))
    for bi, s in enumerate(sums):
        net.driver[s] = ("chain", ci, bi)
    if cout is not None:
        net.driver[cout] = ("cout", ci)
    return sums, cout


# ---------------------------------------------------------------------------
# partial-product generation
# ---------------------------------------------------------------------------


def const_mult_rows(net: Netlist, x_bus: Sequence[int], const: int, n_const_bits: int,
                    signed: bool = False, out_width: int | None = None,
                    skip_zero: bool = True) -> list[Row]:
    """Rows of an unrolled multiplication ``x * const``.

    Each set bit *i* of ``const`` (the "selector bit", §IV) contributes the
    multiplicand shifted by *i*.  With ``signed=True`` the multiplicand rows
    are sign-extended to ``out_width`` (arithmetic is mod 2**out_width).
    """
    m = len(x_bus)
    W = out_width if out_width is not None else m + n_const_bits
    const &= (1 << n_const_bits) - 1
    if not skip_zero and const == 0:
        # even stock VTR's frontend (Yosys) folds an all-zero multiplier
        return []
    n_sel_bits = n_const_bits
    if signed:
        # sign-extend the constant to the output width: x*c (mod 2^W) is then
        # a plain sum of selector rows even for negative constants.
        if (const >> (n_const_bits - 1)) & 1:
            const |= ((1 << W) - 1) ^ ((1 << n_const_bits) - 1)
        n_sel_bits = W
    rows: list[Row] = []
    for i in range(n_sel_bits):
        sel = (const >> i) & 1
        if skip_zero and not sel:
            continue
        if not sel:
            rows.append(Row(i, tuple([CONST0] * m)))
            continue
        bits = list(x_bus)
        if signed:
            # sign-extend up to W
            while i + len(bits) < W:
                bits.append(x_bus[-1])
        bits = bits[: max(0, W - i)]
        if bits:
            rows.append(Row(i, tuple(bits)))
    return rows


def var_mult_rows(net: Netlist, x_bus: Sequence[int], y_bus: Sequence[int],
                  signed: bool = False, out_width: int | None = None) -> list[Row]:
    """Rows of a variable multiplication: row i = AND(x, y_i) << i.

    With ``signed=True`` both operands are two's complement.  The most
    significant multiplier bit has weight ``-2^(n-1)``, so its row is negated
    Baugh-Wooley style: emit the bitwise complement of the full-width row plus
    a ``+1`` correction row (``-V = ~V + 1`` mod ``2^W``).
    """
    from .netlist import CONST1, tt_from_fn

    TT_NAND2 = tt_from_fn(lambda a, b: 1 - (a & b), 2)
    m, n = len(x_bus), len(y_bus)
    W = out_width if out_width is not None else m + n
    rows: list[Row] = []
    for i in range(n):
        neg = signed and i == n - 1
        tt = TT_NAND2 if neg else TT_AND2
        bits = [net.add_lut((xb, y_bus[i]), tt) for xb in x_bus]
        if signed:
            # sign-extend with (possibly complemented) x sign AND y_i
            while i + len(bits) < W:
                bits.append(bits[m - 1])
        bits = bits[: max(0, W - i)]
        if not bits:
            continue
        if neg:
            # complement covers [i, W); positions [0, i) complement to 1s,
            # then the +1 correction completes the two's complement negation.
            full = [CONST1] * i + bits
            rows.append(Row(0, tuple(full)))
            rows.append(Row(0, (CONST1,)))
        else:
            rows.append(Row(i, tuple(bits)))
    return rows


# ---------------------------------------------------------------------------
# top-level synthesis entry points
# ---------------------------------------------------------------------------


def reduce_rows(net: Netlist, rows: list[Row], algo: str,
                width_cap: int | None = None) -> Row:
    from . import adder_tree, compressor

    if algo == "vtr_baseline":
        # stock VTR: no zero-row pruning, adjacent pairing, fresh full chains
        if not rows:
            return Row(0, ())
        if len(rows) == 1:
            return rows[0]
        return adder_tree.reduce_binary(net, rows, width_cap=width_cap,
                                        use_dp=False, share=False)
    rows = [r.trimmed() for r in rows if not r.trimmed().is_zero()]
    if not rows:
        return Row(0, ())
    if len(rows) == 1:
        return rows[0]
    if algo == "cascade":
        acc = rows[0]
        for r in rows[1:]:
            acc = add_rows(net, acc, r, width_cap=width_cap, share=True)
        return acc
    if algo == "binary":
        return adder_tree.reduce_binary(net, rows, width_cap=width_cap,
                                        use_dp=True, share=True)
    if algo in ("wallace", "dadda", "pw"):
        return compressor.reduce_compressor(net, rows, algo=algo,
                                            width_cap=width_cap)
    raise ValueError(f"unknown reduction algo {algo!r}")


def synth_const_mult(net: Netlist, x_bus: Sequence[int], const: int,
                     n_const_bits: int, algo: str = "wallace",
                     signed: bool = False, out_width: int | None = None) -> list[int]:
    W = out_width if out_width is not None else len(x_bus) + n_const_bits
    skip = algo != "vtr_baseline"
    rows = const_mult_rows(net, x_bus, const, n_const_bits, signed=signed,
                           out_width=W, skip_zero=skip)
    res = reduce_rows(net, rows, algo, width_cap=W)
    return row_to_bus(res, W)


def synth_var_mult(net: Netlist, x_bus: Sequence[int], y_bus: Sequence[int],
                   algo: str = "wallace", signed: bool = False,
                   out_width: int | None = None) -> list[int]:
    W = out_width if out_width is not None else len(x_bus) + len(y_bus)
    rows = var_mult_rows(net, x_bus, y_bus, signed=signed, out_width=W)
    res = reduce_rows(net, rows, algo, width_cap=W)
    return row_to_bus(res, W)


def synth_dot_const(net: Netlist, x_buses: Sequence[Sequence[int]],
                    weights: Sequence[int], n_const_bits: int,
                    algo: str = "wallace", signed: bool = False,
                    out_width: int | None = None,
                    style: str = "per_mult") -> list[int]:
    """Dot product with compile-time constant weights (unrolled DNN MAC).

    ``style="per_mult"`` (paper/Kratos-faithful): each multiplier is reduced
    with ``algo`` (compressor tree / improved adder tree), and the resulting
    products are summed on an explicit binary adder-chain tree — this is why
    Kratos circuits are adder-dominated (Table III: 61.4 %).

    ``style="fused"`` merges *all* partial-product rows of the dot product
    into a single reduction — a beyond-paper variant that trades adder chains
    for LUT compressors.
    """
    assert len(x_buses) == len(weights)
    m = max((len(b) for b in x_buses), default=1)
    import math

    acc_bits = m + n_const_bits + max(1, math.ceil(math.log2(max(1, len(weights)))))
    W = out_width if out_width is not None else acc_bits
    skip = algo != "vtr_baseline"
    if style == "fused":
        rows: list[Row] = []
        for bus, w in zip(x_buses, weights):
            rows.extend(const_mult_rows(net, bus, w, n_const_bits,
                                        signed=signed, out_width=W,
                                        skip_zero=skip))
        res = reduce_rows(net, rows, algo, width_cap=W)
        return row_to_bus(res, W)
    # per-multiplier reduction, then an explicit adder-chain tree
    prods: list[Row] = []
    for bus, w in zip(x_buses, weights):
        rows = const_mult_rows(net, bus, w, n_const_bits, signed=signed,
                               out_width=W, skip_zero=skip)
        if not rows:
            continue
        prods.append(reduce_rows(net, rows, algo, width_cap=W))
    tree_algo = "vtr_baseline" if algo == "vtr_baseline" else (
        "cascade" if algo == "cascade" else "binary")
    res = reduce_rows(net, prods, tree_algo, width_cap=W)
    return row_to_bus(res, W)


def row_to_bus(row: Row, width: int) -> list[int]:
    return [row.bit_at(p) for p in range(width)]
