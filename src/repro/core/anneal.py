"""Batched simulated-annealing placement refinement over the analytic seed.

The analytic placer (:mod:`repro.core.place`) is legalization-limited:
the damped Laplacian relaxation finds a good *relative* ordering, but the
stable-sort snap into grid columns scrambles local structure — on the
benchmark suites the legalized wirelength sits well above what the slot
assignment could achieve.  This module closes that gap with a
fully-vectorized simulated annealer:

* **Bulk move proposal.**  Every temperature step proposes ``moves``
  independent moves at once: a random LB and a random target slot inside
  a cooling-range window around its current position (VPR-style range
  limiting — wide exploratory hops at high T, local shuffles near the
  end).  An occupied target is a *swap*, an empty one a *relocate*.
* **Vectorized HPWL deltas.**  Each move's wirelength delta is computed
  independently from the LB-level adjacency CSR (built once from the
  IR's fanin CSR) with flattened gather/scatter arithmetic —
  ``np.repeat`` ragged gathers of every proposed LB's incident edges,
  partner-corrected neighbour coordinates, one ``np.add.at`` reduction
  per batch.  No Python loop touches an edge.
* **Bulk conflict-free acceptance.**  Metropolis-accepted moves are
  applied together when they touch disjoint resources (the moved LB,
  the swap partner, both slots): a scatter-``min`` claim table keeps,
  per resource, only the first accepted claimer, and a move commits iff
  it won every resource it touches.  Interactions *through shared nets*
  between two committed moves are deliberately tolerated (classic
  parallel-annealing approximation) because the true cost is recomputed
  exactly — one O(E) gather — after every bulk apply.
* **Exact best-snapshot.**  The returned placement is the best exact
  cost ever observed, *including the analytic seed itself*, so
  refinement can never return something worse than its seed — the
  ``wirelength(refined) <= wirelength(seed)`` gate holds by
  construction, not by luck.

Timing-driven weighting
-----------------------
``mode="anneal_timing"`` weights every routed edge by its timing
criticality so near-critical nets pull harder than bulk nets.  The
weights derive from the vectorized static-timing substrate
(:mod:`repro.core.timing_vec`): a forward arrival pass at **zero wire
delay** (the class-canonical timing — placement must not depend on the
wire tiers it is about to decide, or the place-once-per-key cache
contract dies), then a levelized backward required-time pass over the
fanin CSR gives per-edge slack; ``crit = clip(1 - slack / cp, 0, 1)``
and ``w = 1 + timing_weight * crit**crit_exp`` (VPR's criticality
exponent).  Chain carry ripple is absorbed into the sum bits' node
delays, so chain-operand criticality is a documented mild
underestimate.  Weights are cached in the :mod:`repro.core.plan`
registry (``"criticality"``) keyed by (netlist digest, structural key,
non-wire delay signature) — the delay row matters (fan-in moves the
Z-pin mux delay), the wire tiers never do.

Backends
--------
``backend="numpy"`` (canonical, bit-deterministic) runs one chain;
``backend="jax"`` runs a ``chains``-wide ensemble of independently
seeded chains as one vmapped ``lax.scan`` program (dense degree-padded
adjacency, scatter-min conflict claims, in-scan best tracking) and keeps
the candidate with the lowest *exact* (numpy-recomputed) wirelength,
seed included — so legality and the never-worse guarantee are backend-
independent even though the chains explore different trajectories.

Determinism: every random stream is a ``blake2b`` of
``("anneal", digest, placement_key, seed[, chain])`` — same inputs, same
refined placement, bit for bit (the contract
:func:`repro.core.place.placement_for` caches under).
"""
from __future__ import annotations

import hashlib

import numpy as np

from . import plan as _planner
from .alm import DELAY_FIELDS, ArchParams
from .circuit_ir import CircuitIR

#: instrumentation: refinement solves / criticality solves vs cache hits
ANNEAL_COUNTS = {"anneal": 0, "crit_solve": 0, "crit_hit": 0}
#: wall seconds spent inside refinement — the sweep/search ledgers read
#: the delta around their placement phase to attribute annealing cost
ANNEAL_WALL = {"s": 0.0, "calls": 0}

#: criticality weight vectors per (digest, structural key, delay sig)
_CRIT_CACHE = _planner.register_cache("criticality", cap=256)

#: delay-table fields that must NOT steer placement weighting (the
#: placement cache key promises one placement per wire-delay family)
_WIRE_FIELDS = ("t_wire_hop1", "t_wire_hop2", "t_wire_long")

_DEF_T_FINAL = 0.05
_DEF_TIMING_WEIGHT = 4.0
_DEF_CRIT_EXP = 2.0

REFINE_MODES = ("anneal", "anneal_timing")


def read_anneal_wall() -> dict:
    return dict(ANNEAL_WALL)


def _record_wall(seconds: float) -> None:
    ANNEAL_WALL["s"] += seconds
    ANNEAL_WALL["calls"] += 1


def _rng(digest: str, placement_key: tuple, seed: int, chain: int = 0):
    """Deterministic move stream, distinct from the analytic scatter's
    stream (tagged) and per chain."""
    h = hashlib.blake2b(
        repr(("anneal", digest, placement_key, seed, chain)).encode(),
        digest_size=8)
    return np.random.default_rng(int.from_bytes(h.digest(), "big"))


def delay_signature(arch: ArchParams) -> tuple:
    """The delay-table row minus the wire-tier fields — the only delay
    inputs criticality weighting is allowed to read."""
    return tuple(float(getattr(arch, f)) for f in DELAY_FIELDS
                 if f not in _WIRE_FIELDS)


# ---------------------------------------------------------------------------
# criticality weights (timing-driven mode)
# ---------------------------------------------------------------------------


def edge_criticality(ir: CircuitIR, arch: ArchParams) -> np.ndarray:
    """Per-fanin-CSR-edge timing criticality in ``[0, 1]`` at zero wire
    delay.

    Forward: oracle-order arrival times (:func:`timing_vec.
    arrival_times_numpy`) with the wire-tier components zeroed.
    Backward: required times by a levelized scatter-min over the CSR —
    for an edge ``u -> v``, the required arrival at ``u`` through that
    edge is ``required[v] - node_delay[v] - edge_delay(u, v)`` where
    ``node_delay[v] = arrival[v] - max_in_t[v]`` (which absorbs chain
    carry ripple for sum bits — chain-operand criticality is therefore a
    mild underestimate).  ``crit = clip(1 - slack / cp, 0, 1)``.
    """
    from .timing_vec import arrival_times_numpy, delay_components

    idx = {f: i for i, f in enumerate(DELAY_FIELDS)}
    table = arch.delay_table()
    for f in _WIRE_FIELDS:
        table[idx[f]] = 0.0
    comps = delay_components(table)
    arr = arrival_times_numpy(ir, comps)
    cp = float(arr[ir.po_sig].max()) if ir.po_sig.size else 0.0
    cp = max(cp, 1.0)

    E = ir.fanin_sig.size
    if not E:
        return np.zeros(0, dtype=np.float64)
    dst = np.repeat(np.arange(ir.n_signals, dtype=np.int32),
                    np.diff(ir.fanin_ptr))
    ec = comps["edge"][ir.fanin_cls]              # [E, 3] route/pin/path
    d_e = ec[:, 0] + ec[:, 1] + ec[:, 2]
    in_t = arr[ir.fanin_sig] + d_e
    tin = np.full(ir.n_signals, -np.inf)
    np.maximum.at(tin, dst, in_t)
    node_delay = np.where(np.isfinite(tin), arr - tin, 0.0)
    node_delay = np.maximum(node_delay, 0.0)

    req = np.full(ir.n_signals, np.inf)
    req[ir.po_sig] = cp
    dst_level = ir.sig_level[dst]
    for lv in range(int(dst_level.max(initial=0)), 0, -1):
        m = dst_level == lv
        if not m.any():
            continue
        cand = req[dst[m]] - node_delay[dst[m]] - d_e[m]
        np.minimum.at(req, ir.fanin_sig[m], cand)

    slack = (req[dst] - node_delay[dst] - d_e) - arr[ir.fanin_sig]
    crit = 1.0 - slack / cp
    return np.clip(np.where(np.isfinite(slack), crit, 0.0), 0.0, 1.0)


def criticality_weights(ir: CircuitIR, arch: ArchParams, *,
                        timing_weight: float = _DEF_TIMING_WEIGHT,
                        crit_exp: float = _DEF_CRIT_EXP,
                        cache: bool = True) -> np.ndarray:
    """Registry-cached per-*routed*-edge annealing weights ``1 +
    timing_weight * crit**crit_exp`` (aligned with
    :func:`repro.core.place._routed_edges` order)."""
    key = (ir.net_digest, arch.structural_key(), delay_signature(arch),
           float(timing_weight), float(crit_exp))
    if cache:
        hit = _CRIT_CACHE.get(key)
        if hit is not None:
            ANNEAL_COUNTS["crit_hit"] += 1
            return hit
    ANNEAL_COUNTS["crit_solve"] += 1
    crit = edge_criticality(ir, arch)
    dst = np.repeat(np.arange(ir.n_signals, dtype=np.int32),
                    np.diff(ir.fanin_ptr))
    src_lb = ir.sig_lb[ir.fanin_sig]
    dst_lb = ir.sig_lb[dst]
    m = (src_lb >= 0) & (dst_lb >= 0) & (src_lb != dst_lb)
    w = 1.0 + timing_weight * crit[m] ** crit_exp
    if cache:
        _CRIT_CACHE.put(key, w)
    return w


# ---------------------------------------------------------------------------
# shared geometry
# ---------------------------------------------------------------------------


def _adjacency(L: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray):
    """Undirected LB adjacency CSR (both directions of every routed
    edge; parallel edges kept — their weights simply add per move)."""
    a = np.concatenate([src, dst])
    b = np.concatenate([dst, src])
    ww = np.concatenate([w, w])
    order = np.argsort(a, kind="stable")
    a, b, ww = a[order], b[order], ww[order]
    ptr = np.zeros(L + 1, dtype=np.int64)
    np.add.at(ptr, a + 1, 1)
    ptr = np.cumsum(ptr)
    return ptr, b.astype(np.int32), ww.astype(np.float64)


def _schedules(W: int, H: int, t0: float, t_final: float, steps: int):
    """Geometric temperature and range-window schedules, precomputed so
    the numpy and jax chains run the identical annealing plan."""
    span0 = max(W, H, 2)
    temps = np.empty(steps)
    wins = np.empty(steps, dtype=np.int64)
    for k in range(steps):
        frac = k / max(steps - 1, 1)
        temps[k] = t0 * (t_final / t0) ** frac
        wins[k] = max(1, int(round(span0 ** (1.0 - frac))))
    return temps, wins


def _default_steps(L: int) -> int:
    return 96


def _default_moves(L: int) -> int:
    return int(max(32, min(2 * L, 2048)))


# ---------------------------------------------------------------------------
# numpy chain (canonical)
# ---------------------------------------------------------------------------


def _incident_delta(ptr, nbr, wts, px, py, ent, nx, ny,
                    partner, pnx, pny) -> np.ndarray:
    """Per-move incident-cost delta of moving ``ent`` from its current
    slot to ``(nx, ny)`` while ``partner`` (or -1) simultaneously moves
    to ``(pnx, pny)`` — one ragged gather over every proposed LB's
    adjacency, one scatter-add back to moves."""
    P = ent.size
    deg = (ptr[ent + 1] - ptr[ent]).astype(np.int64)
    total = int(deg.sum())
    out = np.zeros(P, dtype=np.float64)
    if not total:
        return out
    mid = np.repeat(np.arange(P), deg)
    offs = np.arange(total, dtype=np.int64) \
        - np.repeat(np.cumsum(deg) - deg, deg)
    fl = ptr[ent][mid] + offs
    n = nbr[fl]
    w = wts[fl]
    is_p = n == partner[mid]
    cnx = np.where(is_p, pnx[mid], px[n])
    cny = np.where(is_p, pny[mid], py[n])
    old = np.abs(px[ent][mid] - px[n]) + np.abs(py[ent][mid] - py[n])
    new = np.abs(nx[mid] - cnx) + np.abs(ny[mid] - cny)
    np.add.at(out, mid, w * (new - old))
    return out


def _probe_t0(ptr, nbr, wts, x, y, occ, W, H, rng, n: int = 256) -> float:
    """Initial temperature from a probe batch: ~60 % of median-magnitude
    uphill moves accepted at step 0."""
    L = x.size
    a = rng.integers(0, L, n).astype(np.int32)
    tx = rng.integers(0, W, n).astype(np.int32)
    ty = rng.integers(0, H, n).astype(np.int32)
    b = occ[tx * H + ty]
    sx, sy = x[a], y[a]
    d_a = _incident_delta(ptr, nbr, wts, x, y, a, tx, ty, b, sx, sy)
    bb = np.where(b >= 0, b, 0).astype(np.int32)
    d_b = _incident_delta(ptr, nbr, wts, x, y, bb, sx, sy, a, tx, ty)
    d = d_a + np.where(b >= 0, d_b, 0.0)
    d = d[b != a]
    mag = float(np.abs(d).mean()) if d.size else 1.0
    return max(1.0, 2.0 * mag)


def _anneal_chain_numpy(ptr, nbr, wts, edge_src, edge_dst, edge_w,
                        x0, y0, W, H, rng, steps, moves, t_final):
    """One annealing chain.  Returns ``(best_cost, best_x, best_y)`` —
    the exact-cost best snapshot, seeded with the input placement."""
    L = x0.size
    x, y = x0.astype(np.int64).copy(), y0.astype(np.int64).copy()
    occ = np.full(W * H, -1, dtype=np.int32)
    occ[x * H + y] = np.arange(L, dtype=np.int32)

    def cost_of(px, py):
        return float((edge_w * (np.abs(px[edge_src] - px[edge_dst])
                                + np.abs(py[edge_src] - py[edge_dst])
                                )).sum())

    cost = cost_of(x, y)
    best_cost, best_x, best_y = cost, x.copy(), y.copy()
    t0 = _probe_t0(ptr, nbr, wts, x, y, occ, W, H, rng)
    temps, wins = _schedules(W, H, t0, t_final, steps)
    idx = np.arange(moves)
    for k in range(steps):
        T, win = float(temps[k]), int(wins[k])
        a = rng.integers(0, L, moves).astype(np.int32)
        dx = rng.integers(-win, win + 1, moves)
        dy = rng.integers(-win, win + 1, moves)
        u = rng.random(moves)
        tx = np.clip(x[a] + dx, 0, W - 1).astype(np.int64)
        ty = np.clip(y[a] + dy, 0, H - 1).astype(np.int64)
        tslot = tx * H + ty
        b = occ[tslot]
        self_move = b == a
        sx, sy = x[a], y[a]
        sslot = sx * H + sy
        d_a = _incident_delta(ptr, nbr, wts, x, y, a, tx, ty, b, sx, sy)
        bb = np.where(b >= 0, b, 0).astype(np.int32)
        d_b = _incident_delta(ptr, nbr, wts, x, y, bb, sx, sy, a, tx, ty)
        delta = d_a + np.where(b >= 0, d_b, 0.0)
        accept = ~self_move & (
            (delta <= 0.0)
            | (u < np.exp(-np.maximum(delta, 0.0) / max(T, 1e-9))))
        if not accept.any():
            continue
        # conflict-free commit: first accepted claimer per resource wins
        res = np.stack([a.astype(np.int64), np.where(b >= 0, b, -1),
                        L + sslot, L + tslot], axis=1)
        claim = np.full(L + W * H, moves, dtype=np.int64)
        acc = np.flatnonzero(accept)
        r = res[acc]
        valid = r >= 0
        np.minimum.at(claim, r[valid],
                      np.repeat(acc, valid.sum(axis=1)))
        ok = accept.copy()
        for c in range(4):
            col = res[:, c]
            v = col >= 0
            ok &= ~v | (claim[np.clip(col, 0, None)] == idx)
        kept = np.flatnonzero(ok)
        if not kept.size:
            continue
        ka, kb = a[kept], b[kept]
        x[ka], y[ka] = tx[kept], ty[kept]
        occ[tslot[kept]] = ka
        hasb = kb >= 0
        occ[sslot[kept]] = np.where(hasb, kb, -1).astype(np.int32)
        x[kb[hasb]] = sx[kept][hasb]
        y[kb[hasb]] = sy[kept][hasb]
        cost = cost_of(x, y)
        if cost < best_cost:
            best_cost, best_x, best_y = cost, x.copy(), y.copy()
    return best_cost, best_x, best_y


# ---------------------------------------------------------------------------
# jax multi-chain ensemble
# ---------------------------------------------------------------------------


def _anneal_chains_jax(ptr, nbr, wts, edge_src, edge_dst, edge_w,
                       x0, y0, W, H, digest, pkey, seed,
                       steps, moves, t_final, chains):
    """``chains`` independently-seeded annealing trajectories as one
    vmapped ``lax.scan`` program.  Move streams are pregenerated with
    the same blake2b-derived numpy generators the canonical backend
    uses (chain index in the seed), so the program is pure data flow;
    adjacency is degree-padded dense (pad neighbour 0 with weight 0).
    Returns per-chain ``(cost, x, y)`` best snapshots as numpy."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    L = x0.size
    WH = W * H
    deg = np.diff(ptr).astype(np.int64)
    D = max(int(deg.max(initial=0)), 1)
    nbr_pad = np.zeros((L, D), dtype=np.int32)
    w_pad = np.zeros((L, D), dtype=np.float64)
    for i in range(L):
        s, e = int(ptr[i]), int(ptr[i + 1])
        nbr_pad[i, : e - s] = nbr[s:e]
        w_pad[i, : e - s] = wts[s:e]

    # per-chain pregenerated streams (identical draw order per chain)
    occ0 = np.full(WH, -1, dtype=np.int32)
    occ0[x0.astype(np.int64) * H + y0.astype(np.int64)] = \
        np.arange(L, dtype=np.int32)
    A = np.empty((chains, steps, moves), dtype=np.int32)
    DX = np.empty((chains, steps, moves), dtype=np.int64)
    DY = np.empty((chains, steps, moves), dtype=np.int64)
    U = np.empty((chains, steps, moves), dtype=np.float64)
    temps = np.empty((chains, steps))
    wins = np.empty((chains, steps), dtype=np.int64)
    for ch in range(chains):
        rng = _rng(digest, pkey, seed, chain=ch)
        t0 = _probe_t0(ptr, nbr, wts, x0.astype(np.int64),
                       y0.astype(np.int64), occ0, W, H, rng)
        temps[ch], wins[ch] = _schedules(W, H, t0, t_final, steps)
        for k in range(steps):
            win = int(wins[ch, k])
            A[ch, k] = rng.integers(0, L, moves)
            DX[ch, k] = rng.integers(-win, win + 1, moves)
            DY[ch, k] = rng.integers(-win, win + 1, moves)
            U[ch, k] = rng.random(moves)

    ids = jnp.arange(moves)

    def step(carry, xs):
        x, y, occ, cost, best_cost, best_x, best_y = carry
        a, dx, dy, u, T = xs
        tx = jnp.clip(x[a] + dx, 0, W - 1)
        ty = jnp.clip(y[a] + dy, 0, H - 1)
        tslot = tx * H + ty
        b = occ[tslot]
        self_move = b == a
        sx, sy = x[a], y[a]
        sslot = sx * H + sy

        def incident(ent, nx, ny, partner, pnx, pny):
            n = nbr_pad[ent]                     # [P, D]
            w = w_pad[ent]
            is_p = n == partner[:, None]
            cnx = jnp.where(is_p, pnx[:, None], x[n])
            cny = jnp.where(is_p, pny[:, None], y[n])
            old = jnp.abs(x[ent][:, None] - x[n]) \
                + jnp.abs(y[ent][:, None] - y[n])
            new = jnp.abs(nx[:, None] - cnx) + jnp.abs(ny[:, None] - cny)
            return (w * (new - old)).sum(axis=1)

        d_a = incident(a, tx, ty, b, sx, sy)
        bb = jnp.where(b >= 0, b, 0)
        d_b = incident(bb, sx, sy, a, tx, ty)
        delta = d_a + jnp.where(b >= 0, d_b, 0.0)
        accept = (~self_move) & (
            (delta <= 0.0)
            | (u < jnp.exp(-jnp.maximum(delta, 0.0)
                           / jnp.maximum(T, 1e-9))))
        dummy = L + WH
        res = jnp.stack([a, jnp.where(b >= 0, b, dummy),
                         L + sslot, L + tslot], axis=1)
        res_sel = jnp.where(accept[:, None], res, dummy)
        claim = jnp.full(L + WH + 1, moves).at[res_sel].min(
            jnp.broadcast_to(ids[:, None], res_sel.shape))
        ok = accept & (claim[res] == ids[:, None]).all(axis=1) \
            | (accept & (b < 0)
               & (claim[res[:, 0]] == ids) & (claim[res[:, 2]] == ids)
               & (claim[res[:, 3]] == ids))
        kept = ok
        # commit via dummy-row redirection (pad row L / slot WH)
        ia = jnp.where(kept, a, L)
        x = jnp.concatenate([x, jnp.zeros(1, x.dtype)]) \
            .at[ia].set(tx).at[jnp.where(kept & (b >= 0), bb, L)] \
            .set(sx)[:L]
        y = jnp.concatenate([y, jnp.zeros(1, y.dtype)]) \
            .at[ia].set(ty).at[jnp.where(kept & (b >= 0), bb, L)] \
            .set(sy)[:L]
        occ = jnp.concatenate([occ, jnp.zeros(1, occ.dtype)]) \
            .at[jnp.where(kept, tslot, WH)].set(a) \
            .at[jnp.where(kept, sslot, WH)] \
            .set(jnp.where(b >= 0, b, -1).astype(occ.dtype))[:WH]
        cost = (edge_w * (jnp.abs(x[edge_src] - x[edge_dst])
                          + jnp.abs(y[edge_src] - y[edge_dst]))).sum()
        better = cost < best_cost
        best_cost = jnp.where(better, cost, best_cost)
        best_x = jnp.where(better, x, best_x)
        best_y = jnp.where(better, y, best_y)
        return (x, y, occ, cost, best_cost, best_x, best_y), None

    def run_chain(a, dx, dy, u, temps_c):
        x = jnp.asarray(x0, dtype=jnp.int64)
        y = jnp.asarray(y0, dtype=jnp.int64)
        occ = jnp.asarray(occ0)
        cost0 = (edge_w * (jnp.abs(x[edge_src] - x[edge_dst])
                           + jnp.abs(y[edge_src] - y[edge_dst]))).sum()
        carry = (x, y, occ, cost0, cost0, x, y)
        carry, _ = jax.lax.scan(step, carry, (a, dx, dy, u, temps_c))
        _, _, _, _, bc, bx, by = carry
        return bc, bx, by

    with enable_x64():
        edge_src = jnp.asarray(edge_src)
        edge_dst = jnp.asarray(edge_dst)
        edge_w = jnp.asarray(edge_w)
        nbr_pad = jnp.asarray(nbr_pad)
        w_pad = jnp.asarray(w_pad)
        bc, bx, by = jax.jit(jax.vmap(run_chain))(
            jnp.asarray(A), jnp.asarray(DX), jnp.asarray(DY),
            jnp.asarray(U), jnp.asarray(temps))
        return (np.asarray(jax.device_get(bc), dtype=np.float64),
                np.asarray(jax.device_get(bx), dtype=np.int64),
                np.asarray(jax.device_get(by), dtype=np.int64))


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def refine_placement(ir: CircuitIR, arch: ArchParams, seed_pl, *,
                     seed: int = 0, mode: str = "anneal",
                     backend: str = "numpy", chains: int = 4,
                     steps: int | None = None, moves: int | None = None,
                     t_final: float = _DEF_T_FINAL,
                     timing_weight: float = _DEF_TIMING_WEIGHT,
                     crit_exp: float = _DEF_CRIT_EXP):
    """Anneal-refine the analytic seed placement ``seed_pl`` of ``ir``.

    Returns a :class:`repro.core.place.GridPlacement` on the same grid
    that is (a) legal (one LB per slot — moves only permute/relocate
    within the grid), (b) bit-deterministic per ``(digest,
    placement_key, seed, mode)``, and (c) never worse than the seed
    under the annealing objective — for ``mode="anneal"`` (uniform
    weights) that objective *is* the wirelength
    :meth:`~repro.core.place.GridPlacement.wirelength` reports, so
    ``wirelength(refined) <= wirelength(seed)`` always holds.
    ``mode="anneal_timing"`` weights edges by slack-derived criticality
    (near-critical nets contract harder); the guarantee then applies to
    the weighted cost and the *best-weighted* snapshot is returned.
    """
    import time

    from .place import GridPlacement, _routed_edges

    if mode not in REFINE_MODES:
        raise ValueError(
            f"unknown refine mode {mode!r} (choose from {REFINE_MODES})")
    t_start = time.perf_counter()
    L = seed_pl.n_lbs
    src, dst = _routed_edges(ir)
    if L <= 1 or not src.size:
        _record_wall(time.perf_counter() - t_start)
        return seed_pl
    if mode == "anneal_timing":
        edge_w = criticality_weights(ir, arch, timing_weight=timing_weight,
                                     crit_exp=crit_exp)
    else:
        edge_w = np.ones(src.size, dtype=np.float64)
    ANNEAL_COUNTS["anneal"] += 1
    W, H = seed_pl.grid_w, seed_pl.grid_h
    ptr, nbr, wts = _adjacency(L, src, dst, edge_w)
    steps = _default_steps(L) if steps is None else int(steps)
    moves = _default_moves(L) if moves is None else int(moves)
    pkey = seed_pl.placement_key
    x0 = seed_pl.lb_x.astype(np.int64)
    y0 = seed_pl.lb_y.astype(np.int64)

    def seed_cost():
        return float((edge_w * (np.abs(x0[src] - x0[dst])
                                + np.abs(y0[src] - y0[dst]))).sum())

    if backend == "jax":
        bc, bx, by = _anneal_chains_jax(
            ptr, nbr, wts, src, dst, edge_w, x0, y0, W, H,
            seed_pl.net_digest, pkey, seed, steps, moves, t_final,
            max(1, chains))
        # exact numpy re-score (jit arithmetic is exact int/f64 already,
        # but the seed must stay in the candidate pool either way)
        cands = [(seed_cost(), x0, y0)]
        for ch in range(bc.shape[0]):
            c = float((edge_w * (np.abs(bx[ch][src] - bx[ch][dst])
                                 + np.abs(by[ch][src] - by[ch][dst]))).sum())
            cands.append((c, bx[ch], by[ch]))
        _, best_x, best_y = min(cands, key=lambda t: t[0])
    elif backend == "numpy":
        rng = _rng(seed_pl.net_digest, pkey, seed)
        _, best_x, best_y = _anneal_chain_numpy(
            ptr, nbr, wts, src, dst, edge_w, x0, y0, W, H, rng,
            steps, moves, t_final)
    else:
        raise ValueError(f"unknown anneal backend {backend!r}")
    out = GridPlacement(W, H, best_x.astype(np.int32),
                        best_y.astype(np.int32), seed_pl.seed,
                        seed_pl.net_digest, pkey, refine=mode)
    _record_wall(time.perf_counter() - t_start)
    return out
