"""Batched analytic grid placement over packed :class:`CircuitIR`.

The pack stage assigns ALMs to logic blocks but says nothing about
*where* those LBs sit on the fabric, so every inter-LB edge used to time
as if routing were free.  This module places each packed circuit's LBs
onto a ``grid_w x grid_h`` grid of slots and feeds the resulting
Manhattan hop distances back into the IR's wire-tier columns
(:func:`repro.core.circuit_ir.apply_placement`), where the tiered-fabric
delay model (tile-local / 1-hop / 2-hop / long wires, same hierarchy as
the apicula fabric notes in SNIPPETS.md) prices them.

Algorithm — classic two-phase analytic placement, fully vectorized:

1. **Quadratic relaxation.**  Build the LB-level connectivity matrix
   ``A`` from the IR's fanin CSR (:func:`lb_connectivity`), scatter LBs
   at deterministic random coordinates in the unit square, then run a
   fixed number of damped Laplacian-smoothing sweeps
   ``pos <- (A @ pos + alpha * pos) / (deg + alpha)`` — each LB moves to
   the weighted centroid of its neighbours, the discrete minimizer step
   of the quadratic wirelength model.  After every sweep the coordinates
   are min-max rescaled back to the unit square: the rescale is the
   overlap-spreading force that stops the classic quadratic collapse to
   a point.
2. **Deterministic legalization.**  Sort LBs by relaxed x into
   ``grid_w`` columns of ``grid_h`` slots, then by relaxed y within each
   column (stable sorts, index tie-break), yielding one legal slot per
   LB — capacity 1, no overlap, reproducible bit-for-bit from
   ``(netlist digest, structural key, seed)``.

The relaxation is plain array arithmetic, so it runs either as numpy
(the canonical, bit-deterministic default) or as a jax program
(``backend="jax"``) vmapped over an ensemble of starting scatters with
the best final wirelength kept — the batched axis the sweep engine uses
when placing circuits x archs.  Legalization is always numpy: downstream
bit-identity gates compare vectorized timing against the placed oracle
*on whatever placement was produced*, so the backend choice never
touches the timing contract.

Refinement: ``place_ir(refine="anneal")`` hands the analytic result to
the batched simulated annealer in :mod:`repro.core.anneal` (uniform
weights; ``refine="anneal_timing"`` adds slack-derived criticality
weights).  The refined placement lives on the same grid, stays legal,
and is never worse than its seed under the refinement objective — see
the anneal module docstring for the guarantees.

Caching: placements register in the :mod:`repro.core.plan` registry
(``"placement"``) keyed ``(netlist digest, arch placement key, seed)``,
extended with the refine mode when refinement is requested (and, for
the timing-driven mode, the arch's non-wire delay signature — the only
refine mode whose result reads the delay row).
:meth:`~repro.core.alm.ArchParams.placement_key` is the *structural* key
plus grid aspect — wire-tier delays and channel width are deliberately
absent, so one placement serves every delay row of a structural class
(place once, re-time many; the reuse the warm-sweep gate measures) and
:func:`repro.core.plan.clear_caches` drops placements along with every
other lowering cache.  Tuning knobs (backend, ensembles, anneal steps /
moves / chains) are deliberately *not* part of the key: like the
analytic ``backend``, they pick an algorithm for producing a placement
that satisfies the same contract, and the first call wins.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from . import plan as _planner
from .alm import ArchParams
from .circuit_ir import CircuitIR, apply_placement

# instrumentation: how many placements were solved analytically vs served
# from the registry cache (tests assert reuse across structural classes)
PLACE_COUNTS = {"analytic": 0, "cache_hit": 0}

_PLACE_CACHE = _planner.register_cache("placement", cap=256)

_SMOOTH_ITERS = 32
_ALPHA = 0.5  # damping: weight of a LB's own position vs its neighbours


def grid_shape(n_lbs: int, aspect: float = 1.0) -> tuple[int, int]:
    """Smallest ``(grid_w, grid_h)`` grid of LB slots holding ``n_lbs``
    at the requested width/height aspect ratio (``aspect = W/H``).

    Degenerate inputs clamp explicitly rather than by rounding
    accident: ``w`` never exceeds ``n_lbs`` (an extreme aspect on a tiny
    circuit would otherwise mint empty columns wider than the design —
    e.g. 1 LB at aspect 16 rounds to a 4-wide grid), so a 1-LB circuit
    always lands on a 1x1 grid and ``w * h >= n_lbs`` always holds with
    every column except possibly the last one full."""
    if n_lbs <= 0:
        return (0, 0)
    if not aspect > 0:
        raise ValueError(f"grid aspect must be positive, got {aspect!r}")
    w = max(1, int(round(np.sqrt(n_lbs * aspect))))
    w = min(w, n_lbs)
    h = -(-n_lbs // w)  # ceil
    return (w, h)


@dataclass(frozen=True)
class GridPlacement:
    """One legal placement of a pack's LBs onto the fabric grid."""

    grid_w: int
    grid_h: int
    lb_x: np.ndarray  # [n_lbs] int32 column of each LB
    lb_y: np.ndarray  # [n_lbs] int32 row of each LB
    seed: int
    net_digest: str
    placement_key: tuple  # arch structural key + grid aspect
    refine: str | None = None  # annealer mode that refined this, if any

    @property
    def n_lbs(self) -> int:
        return int(self.lb_x.shape[0])

    def wirelength(self, ir: CircuitIR) -> int:
        """Total Manhattan wirelength of ``ir``'s inter-LB edges under
        this placement (the quantity the relaxation minimizes)."""
        src, dst = _routed_edges(ir)
        if not src.size:
            return 0
        d = (np.abs(self.lb_x[src] - self.lb_x[dst])
             + np.abs(self.lb_y[src] - self.lb_y[dst]))
        return int(d.sum())


def _routed_edges(ir: CircuitIR) -> tuple[np.ndarray, np.ndarray]:
    """``(src_lb, dst_lb)`` per fanin-CSR edge whose endpoints sit in two
    *different* LBs — the only edges that touch the routing fabric."""
    dst_sig = np.repeat(np.arange(ir.n_signals, dtype=np.int32),
                        np.diff(ir.fanin_ptr))
    src_lb = ir.sig_lb[ir.fanin_sig]
    dst_lb = ir.sig_lb[dst_sig]
    m = (src_lb >= 0) & (dst_lb >= 0) & (src_lb != dst_lb)
    return src_lb[m], dst_lb[m]


def lb_connectivity(ir: CircuitIR) -> np.ndarray:
    """Symmetric ``[n_lbs, n_lbs]`` float64 edge-count matrix between
    LBs, accumulated from the fanin CSR (intra-LB edges excluded)."""
    L = ir.n_lbs
    A = np.zeros((L, L), dtype=np.float64)
    src, dst = _routed_edges(ir)
    np.add.at(A, (src, dst), 1.0)
    return A + A.T


def _seed_rng(digest: str, placement_key: tuple, seed: int):
    """Deterministic per-(circuit, arch class, seed) generator.  Python's
    ``hash`` is process-salted, so derive the seed from a stable blake2b
    of the cache key instead."""
    h = hashlib.blake2b(repr((digest, placement_key, seed)).encode(),
                        digest_size=8)
    return np.random.default_rng(int.from_bytes(h.digest(), "big"))


def _smooth_numpy(A: np.ndarray, pos: np.ndarray,
                  iters: int = _SMOOTH_ITERS) -> np.ndarray:
    deg = A.sum(axis=1, keepdims=True)
    for _ in range(iters):
        pos = (A @ pos + _ALPHA * pos) / (deg + _ALPHA)
        lo = pos.min(axis=0, keepdims=True)
        span = pos.max(axis=0, keepdims=True) - lo
        pos = (pos - lo) / np.where(span > 0, span, 1.0)
    return pos


def _smooth_jax(A: np.ndarray, pos0: np.ndarray,
                iters: int = _SMOOTH_ITERS) -> np.ndarray:
    """Ensemble-batched relaxation as one jax program: ``pos0`` is
    ``[E, L, 2]``, smoothed by ``lax.fori_loop`` under ``vmap`` over the
    ensemble axis.  Returns numpy ``[E, L, 2]``."""
    import jax
    import jax.numpy as jnp

    Aj = jnp.asarray(A)
    deg = Aj.sum(axis=1, keepdims=True)

    def step(_, p):
        p = (Aj @ p + _ALPHA * p) / (deg + _ALPHA)
        lo = p.min(axis=0, keepdims=True)
        span = p.max(axis=0, keepdims=True) - lo
        return (p - lo) / jnp.where(span > 0, span, 1.0)

    def run(p0):
        return jax.lax.fori_loop(0, iters, step, p0)

    out = jax.jit(jax.vmap(run))(jnp.asarray(pos0))
    return np.asarray(jax.device_get(out), dtype=np.float64)


def _legalize(pos: np.ndarray, grid_w: int, grid_h: int
              ) -> tuple[np.ndarray, np.ndarray]:
    """Snap relaxed coordinates to distinct grid slots: stable-sort by x
    into ``grid_w`` columns of ``grid_h``, then by y within a column."""
    L = pos.shape[0]
    if grid_w * grid_h < L:
        raise ValueError(
            f"grid {grid_w}x{grid_h} cannot hold {L} LBs")
    lb_x = np.empty(L, dtype=np.int32)
    lb_y = np.empty(L, dtype=np.int32)
    by_x = np.argsort(pos[:, 0], kind="stable")
    for c in range(grid_w):
        col = by_x[c * grid_h:(c + 1) * grid_h]
        order = col[np.argsort(pos[col, 1], kind="stable")]
        lb_x[order] = c
        lb_y[order] = np.arange(order.size, dtype=np.int32)
    return lb_x, lb_y


def place_ir(ir: CircuitIR, arch: ArchParams, seed: int = 0, *,
             backend: str = "numpy", ensembles: int = 4,
             refine: str | None = None, anneal_steps: int | None = None,
             anneal_moves: int | None = None,
             anneal_chains: int = 4) -> GridPlacement:
    """Solve one analytic placement of ``ir``'s LBs on ``arch``'s grid.

    ``backend="numpy"`` (canonical) relaxes a single deterministic
    scatter; ``backend="jax"`` relaxes an ``ensembles``-wide batch of
    scatters in one vmapped program and keeps the legalized candidate
    with the lowest total wirelength (first-index tie-break, so the
    choice is still deterministic for a fixed backend).

    ``refine="anneal"`` (or ``"anneal_timing"``) hands the analytic
    result to :func:`repro.core.anneal.refine_placement`; the backend
    choice carries over (jax refinement runs an ``anneal_chains``-wide
    multi-chain ensemble).  ``anneal_steps`` / ``anneal_moves`` bound
    the annealing schedule (None = size-scaled defaults).
    """
    if ir.arch_name is None:
        raise ValueError(f"{ir.name}: cannot place a functional IR")
    if ir.structural_key is not None \
            and ir.structural_key != arch.structural_key():
        raise ValueError(
            f"{ir.name}: IR was lowered for structural class "
            f"{ir.structural_key} but placement was requested for "
            f"{arch.structural_key()} — re-pack for this arch first")
    pkey = arch.placement_key()
    L = ir.n_lbs
    grid_w, grid_h = grid_shape(L, arch.grid_aspect)
    if L == 0:
        z = np.zeros(0, dtype=np.int32)
        return GridPlacement(grid_w, grid_h, z, z, seed,
                             ir.net_digest, pkey)

    PLACE_COUNTS["analytic"] += 1
    A = lb_connectivity(ir)
    rng = _seed_rng(ir.net_digest, pkey, seed)
    if backend == "jax":
        pos0 = rng.random((max(1, ensembles), L, 2))
        relaxed = _smooth_jax(A, pos0)
        best = None
        for e in range(relaxed.shape[0]):
            lb_x, lb_y = _legalize(relaxed[e], grid_w, grid_h)
            cand = GridPlacement(grid_w, grid_h, lb_x, lb_y, seed,
                                 ir.net_digest, pkey)
            wl = cand.wirelength(ir)
            if best is None or wl < best[0]:
                best = (wl, cand)
        base = best[1]
    elif backend == "numpy":
        pos = _smooth_numpy(A, rng.random((L, 2)))
        lb_x, lb_y = _legalize(pos, grid_w, grid_h)
        base = GridPlacement(grid_w, grid_h, lb_x, lb_y, seed,
                             ir.net_digest, pkey)
    else:
        raise ValueError(f"unknown placement backend {backend!r}")
    if refine is None:
        return base
    from .anneal import refine_placement
    return refine_placement(ir, arch, base, seed=seed, mode=refine,
                            backend=backend, chains=anneal_chains,
                            steps=anneal_steps, moves=anneal_moves)


def placement_for(ir: CircuitIR, arch: ArchParams, seed: int = 0, *,
                  cache: bool = True, backend: str = "numpy",
                  refine: str | None = None,
                  **refine_kw) -> GridPlacement:
    """Registry-cached :func:`place_ir`.  The key deliberately omits
    wire-tier delays and channel width (they don't steer the placer), so
    all delay rows of a structural class x grid aspect share one
    placement — the reuse that makes placed arch-grid sweeps cheap.

    With ``refine`` set the key grows the refine mode; the timing-driven
    mode additionally keys on the arch's *non-wire* delay signature
    (criticality reads the delay row, but never the wire tiers — so the
    one-placement-per-wire-family reuse survives refinement)."""
    key = (ir.net_digest, arch.placement_key(), seed)
    if refine is not None:
        key = key + (refine,)
        if refine == "anneal_timing":
            from .anneal import delay_signature
            key = key + (delay_signature(arch),)
    if cache:
        hit = _PLACE_CACHE.get(key)
        if hit is not None:
            PLACE_COUNTS["cache_hit"] += 1
            return hit
    pl = place_ir(ir, arch, seed, backend=backend, refine=refine,
                  **refine_kw)
    if cache:
        _PLACE_CACHE.put(key, pl)
    return pl


def place_and_apply(ir: CircuitIR, arch: ArchParams, seed: int = 0, *,
                    cache: bool = True, backend: str = "numpy",
                    refine: str | None = None, **refine_kw) -> CircuitIR:
    """Place ``ir`` and return the placed IR (wire-tier columns filled)."""
    return apply_placement(
        ir, placement_for(ir, arch, seed, cache=cache, backend=backend,
                          refine=refine, **refine_kw))


# ---------------------------------------------------------------------------
# placement-derived channel congestion (Fig-8's routed replacement)
# ---------------------------------------------------------------------------


def _rect_demand(x0, x1, y0, y1, w, nx: int, ny: int) -> np.ndarray:
    """Weighted sum of axis-aligned rectangles ``[x0..x1] x [y0..y1]``
    (inclusive) over an ``[nx, ny]`` grid — 2-D difference array +
    double cumsum; ``w`` is each rectangle's per-cell contribution."""
    d = np.zeros((nx + 1, ny + 1), dtype=np.float64)
    np.add.at(d, (x0, y0), w)
    np.add.at(d, (x1 + 1, y0), -w)
    np.add.at(d, (x0, y1 + 1), -w)
    np.add.at(d, (x1 + 1, y1 + 1), w)
    return np.cumsum(np.cumsum(d, axis=0), axis=1)[:nx, :ny]


def channel_congestion(ir: CircuitIR, channel_width: int | None = None,
                       arch: ArchParams | None = None) -> dict:
    """Per-channel-segment routing demand of a *placed* IR.

    Each signal with consumers outside its producing LB claims its
    bounding box over the producing and consuming slots, RUDY-style
    (Spindler & Johannes): the net's horizontal track demand ``x1 - x0``
    is spread uniformly over its box's rows, loading every vertical
    channel *segment* ``(v, y)`` — the edge between tiles ``(v, y)`` and
    ``(v+1, y)`` — with ``1 / (y1 - y0 + 1)`` expected tracks for
    ``x0 <= v < x1``, ``y0 <= y <= y1`` (and symmetrically for the
    horizontal segments).  A one-track-per-segment count would bill a
    multi-fanout net for its whole box area; the RUDY weight bills it
    exactly its HPWL.  Demand is accumulated for all nets at once with
    2-D difference arrays.  ``utilization`` divides peak segment demand
    by the arch's per-edge ``channel_width`` (400-track default kept so
    recorded fig8 numbers stay reproducible).
    """
    if not ir.placed:
        raise ValueError(f"{ir.name}: channel congestion needs a placed IR")
    if channel_width is None:
        channel_width = arch.channel_width if arch is not None else 400
    W, H = ir.grid_w, ir.grid_h
    dst_sig = np.repeat(np.arange(ir.n_signals, dtype=np.int32),
                        np.diff(ir.fanin_ptr))
    src = ir.fanin_sig
    m = (ir.sig_lb[src] >= 0) & (ir.sig_lb[dst_sig] >= 0) \
        & (ir.sig_lb[src] != ir.sig_lb[dst_sig])
    src, dst_sig = src[m], dst_sig[m]

    x0 = ir.sig_x.astype(np.int64).copy()
    x1 = ir.sig_x.astype(np.int64).copy()
    y0 = ir.sig_y.astype(np.int64).copy()
    y1 = ir.sig_y.astype(np.int64).copy()
    np.minimum.at(x0, src, ir.sig_x[dst_sig])
    np.maximum.at(x1, src, ir.sig_x[dst_sig])
    np.minimum.at(y0, src, ir.sig_y[dst_sig])
    np.maximum.at(y1, src, ir.sig_y[dst_sig])
    nets = np.unique(src)

    # vertical segment (v, y) is loaded iff x0 <= v < x1 and y0 <= y <= y1
    # (a zero-width box crosses no vertical boundary), and symmetrically
    vm = nets[x1[nets] > x0[nets]] if nets.size else nets
    if vm.size and W > 1:
        vertical = _rect_demand(x0[vm], x1[vm] - 1, y0[vm], y1[vm],
                                1.0 / (y1[vm] - y0[vm] + 1), W - 1, H)
    else:
        vertical = np.zeros((max(W - 1, 0), H), dtype=np.float64)
    hm = nets[y1[nets] > y0[nets]] if nets.size else nets
    if hm.size and H > 1:
        horizontal = _rect_demand(x0[hm], x1[hm], y0[hm], y1[hm] - 1,
                                  1.0 / (x1[hm] - x0[hm] + 1), W, H - 1)
    else:
        horizontal = np.zeros((W, max(H - 1, 0)), dtype=np.float64)

    peak = max(float(vertical.max()) if vertical.size else 0.0,
               float(horizontal.max()) if horizontal.size else 0.0)
    return {
        "grid": (W, H),
        "nets": int(nets.size),
        "vertical": vertical,
        "horizontal": horizontal,
        "peak_demand": peak,
        "channel_width": int(channel_width),
        "utilization": peak / channel_width if channel_width else 0.0,
    }
