"""Incremental repacking: one arch-invariant prefix, many re-clusterings.

:func:`repro.core.packing.pack` is two stages with very different
architecture sensitivity:

* the **prefix** — absorption pre-pass, chain slotting, LUT pairing and
  the cluster plan (atom list, connectivity indexes, placement orders) —
  depends only on the netlist and the placement seed, never on cluster
  geometry (``alms_per_lb``, ``lb_inputs``, ``ext_pin_util``,
  ``z_sources``);
* the **clustering** stage replays the shared atom list under one grid
  point's LB budgets and is the only part that must re-run per
  structural class.

:func:`pack_prefix` computes the first once per (circuit, seed);
:func:`repack` replays the second against any :class:`ArchParams` row.
``pack(net, arch, seed)`` is now literally ``repack(pack_prefix(net,
seed), arch)``, so both paths are byte-identical by construction — the
structural-grid oracle-parity tests (``tests/core/test_repack.py``) and
the pinned Fig-5/Table-III numbers hold it there.

A sweep over the cluster-geometry axes therefore costs::

    prefixes:    n_circuits                  (once, the expensive part)
    reclusters:  n_circuits x n_classes      (cheap greedy replay)

instead of ``n_circuits x n_classes`` full packs, and the lowering side
pairs with it: :meth:`PackedCircuit.lower_ir` accepts a ``template``
CircuitIR from any sibling class and patches only the columns clustering
can change (sites, LBs, edge delay classes, ALM modes) instead of
re-levelizing the whole netlist (see
:func:`repro.core.circuit_ir.lower_pack_ir_incremental`; since PR 5 the
fresh path shares the same patch over the content-cached functional IR,
so fresh and template lowering are identical by construction).
"""
from __future__ import annotations

from dataclasses import dataclass

from . import plan as _planner
from .alm import ArchParams
from .netlist import CONST1, Netlist
from .packing import (ALM, LAST_PACK_DEBUG, ClusterPlan, Half, PackedCircuit,
                      _build_cluster_plan, _cluster, _fanout_counts,
                      _pair_luts)

#: first fully-lowered CircuitIR per (netlist digest, seed) — the template
#: sibling structural classes patch instead of re-lowering.  Lives in the
#: shared registry (not on the prefix object) so one
#: :func:`repro.core.plan.clear_caches` provably forces re-lowering and a
#: prefix at another seed can never serve a stale template.
_TEMPLATE_CACHE = _planner.register_cache("ir_template", cap=256)


@dataclass
class PackPrefix:
    """The arch-invariant prefix of a pack: chain-slotted ALM skeleton,
    absorbed-LUT assignment, LUT pairing and the cluster plan.  Immutable
    by convention — :func:`repack` copies every structure clustering
    mutates, so one prefix serves any number of re-clusterings."""

    net: Netlist
    seed: int
    alms: list[ALM]                      # chain-slotted arith skeleton
    chain_site: dict[tuple[int, int], int]
    lut_site: dict[int, int]             # absorbed LUTs only, at this stage
    chain_alm_runs: list[list[int]]
    pairs: list[tuple[int, int]]
    singles6: list[int]
    singles5: list[int]
    plan: ClusterPlan

    def _template_key(self) -> tuple:
        key = self.__dict__.get("_tpl_key")
        if key is None:
            key = (self.net.content_digest(), self.seed)
            self.__dict__["_tpl_key"] = key
        return key

    @property
    def ir_template(self):
        """First fully-lowered :class:`~repro.core.circuit_ir.CircuitIR`
        of this prefix (any structural class) — registry-backed, keyed by
        (netlist content digest, seed)."""
        return _TEMPLATE_CACHE.get(self._template_key())

    @ir_template.setter
    def ir_template(self, ir) -> None:
        if ir is None:
            _TEMPLATE_CACHE.pop(self._template_key())
        else:
            _TEMPLATE_CACHE.put(self._template_key(), ir)


def pack_prefix(net: Netlist, seed: int = 0) -> PackPrefix:
    """Steps 1-3 of :func:`repro.core.packing.pack` (absorption, chain
    slotting, LUT pairing) plus the cluster plan — everything that does
    not depend on the architecture."""
    import random

    rng = random.Random(seed)
    fanout = _fanout_counts(net)

    # --- 1. absorption pre-pass -------------------------------------------
    absorbed_of: dict[tuple[int, int], list[int]] = {}
    lut_absorbed: set[int] = set()
    for ci, ch in enumerate(net.chains):
        for bi in range(len(ch.sums)):
            got: list[int] = []
            for s in (ch.a[bi], ch.b[bi]):
                if s <= CONST1:
                    continue
                drv = net.driver.get(s)
                if (drv is not None and drv[0] == "lut"
                        and fanout[s] == 1
                        and len(net.lut_inputs[drv[1]]) <= 4
                        and drv[1] not in lut_absorbed):
                    got.append(drv[1])
                    lut_absorbed.add(drv[1])
            if got:
                absorbed_of[(ci, bi)] = got

    free_luts = [i for i in range(net.n_luts) if i not in lut_absorbed]

    # --- 2. chain slotting --------------------------------------------------
    alms: list[ALM] = []
    chain_site: dict[tuple[int, int], int] = {}
    lut_site: dict[int, int] = {}
    chain_alm_runs: list[list[int]] = []  # per chain, its ALM indices
    for ci, ch in enumerate(net.chains):
        run: list[int] = []
        for lo in range(0, len(ch.sums), 2):
            halves = []
            for bi in (lo, lo + 1):
                if bi < len(ch.sums):
                    ab = absorbed_of.get((ci, bi), [])
                    halves.append(Half(fa=(ci, bi), fa_feed="lut", absorbed=ab))
                else:
                    halves.append(Half())
            alm = ALM(halves=(halves[0], halves[1]), is_arith=True)
            ai = len(alms)
            alms.append(alm)
            run.append(ai)
            for bi in (lo, lo + 1):
                if bi < len(ch.sums):
                    chain_site[(ci, bi)] = ai
                    for li in absorbed_of.get((ci, bi), []):
                        lut_site[li] = ai
        chain_alm_runs.append(run)

    # --- 3. LUT pairing -----------------------------------------------------
    pairs, singles6, singles5 = _pair_luts(net, free_luts, rng)

    # --- cluster plan (atom list, connectivity, placement orders) -----------
    plan = _build_cluster_plan(net, alms, chain_alm_runs, chain_site,
                               pairs, singles6, singles5, rng)

    return PackPrefix(net=net, seed=seed, alms=alms, chain_site=chain_site,
                      lut_site=lut_site, chain_alm_runs=chain_alm_runs,
                      pairs=pairs, singles6=singles6, singles5=singles5,
                      plan=plan)


def _copy_skeleton(alms: list[ALM]) -> list[ALM]:
    """Fresh ALM objects for one re-clustering — clustering mutates
    halves (hosting, Z conversion) and appends logic ALMs, so the
    prefix's skeleton must never be handed out directly."""
    # bypasses the dataclass constructors (keyword plumbing is ~2x the
    # cost of the copy itself on large skeletons); absorbed lists are
    # shared — clustering never mutates them
    new_half, new_alm = Half.__new__, ALM.__new__
    out: list[ALM] = []
    for alm in alms:
        h0, h1 = alm.halves
        c0 = new_half(Half)
        c0.fa, c0.fa_feed = h0.fa, h0.fa_feed
        c0.absorbed, c0.hosted_lut = h0.absorbed, h0.hosted_lut
        c1 = new_half(Half)
        c1.fa, c1.fa_feed = h1.fa, h1.fa_feed
        c1.absorbed, c1.hosted_lut = h1.absorbed, h1.hosted_lut
        a2 = new_alm(ALM)
        a2.halves = (c0, c1)
        a2.lut6 = alm.lut6
        a2.is_arith = alm.is_arith
        out.append(a2)
    return out


def cluster_delta(base: PackedCircuit, new: PackedCircuit) -> dict:
    """Per-cluster membership diff between two packs of the *same arch*
    — the flow server's delta-path attribution (how much of a
    ``base_digest`` request's packing actually changed).

    An LB is *changed* when its multiset of ALM occupancies differs —
    ALM identity is taken structurally (the FA bits and hosted/absorbed
    LUT indices of each half, plus arith/lut6 flags), so two packs of
    netlists that share atom numbering (the delta-request contract)
    compare meaningfully.  Returns ``{"n_lbs_base", "n_lbs_new",
    "n_changed", "unchanged_frac"}``; byte-identical packs report 0
    changed clusters."""

    def alm_sig(pack: PackedCircuit, ai: int) -> tuple:
        alm = pack.alms[ai]
        return tuple((h.fa, h.fa_feed, tuple(h.absorbed), h.hosted_lut)
                     for h in alm.halves) + (alm.is_arith, alm.lut6)

    def lb_sigs(pack: PackedCircuit) -> list[tuple]:
        # sort by repr: signature fields mix None with tuples/ints, which
        # have no direct ordering — only a canonical multiset order is
        # needed, not a meaningful one
        return [tuple(sorted((alm_sig(pack, ai) for ai in lb.alms),
                             key=repr))
                for lb in pack.lbs]

    base_sigs = lb_sigs(base)
    new_sigs = lb_sigs(new)
    # greedy signature matching: clusters that survive verbatim cancel
    # out, position-independently (re-clustering may renumber LBs)
    from collections import Counter

    surviving = Counter(base_sigs) & Counter(new_sigs)
    n_same = sum(surviving.values())
    n_changed = max(len(base_sigs), len(new_sigs)) - n_same
    return {
        "n_lbs_base": len(base_sigs),
        "n_lbs_new": len(new_sigs),
        "n_changed": int(n_changed),
        "unchanged_frac": n_same / max(len(new_sigs), 1),
    }


def repack(prefix: PackPrefix, arch: ArchParams,
           allow_unrelated: bool = True, strict_phases: tuple = (False,),
           pull_runs: bool = False) -> PackedCircuit:
    """Replay the clustering stage of ``pack()`` under ``arch``'s LB
    budgets.  Byte-identical to ``pack(prefix.net, arch, prefix.seed)``
    by construction, at the cost of one skeleton copy instead of the
    whole prefix."""
    LAST_PACK_DEBUG.clear()
    return _cluster(prefix.net, arch, _copy_skeleton(prefix.alms),
                    prefix.chain_alm_runs, prefix.plan,
                    dict(prefix.chain_site), dict(prefix.lut_site),
                    allow_unrelated=allow_unrelated,
                    strict_phases=strict_phases, pull_runs=pull_runs)
