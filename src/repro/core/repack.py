"""Incremental repacking: one arch-invariant prefix, many re-clusterings.

:func:`repro.core.packing.pack` is two stages with very different
architecture sensitivity:

* the **prefix** — absorption pre-pass, chain slotting, LUT pairing and
  the cluster plan (atom list, connectivity indexes, placement orders) —
  depends only on the netlist and the placement seed, never on cluster
  geometry (``alms_per_lb``, ``lb_inputs``, ``ext_pin_util``,
  ``z_sources``);
* the **clustering** stage replays the shared atom list under one grid
  point's LB budgets and is the only part that must re-run per
  structural class.

:func:`pack_prefix` computes the first once per (circuit, seed);
:func:`repack` replays the second against any :class:`ArchParams` row.
``pack(net, arch, seed)`` is now literally ``repack(pack_prefix(net,
seed), arch)``, so both paths are byte-identical by construction — the
structural-grid oracle-parity tests (``tests/core/test_repack.py``) and
the pinned Fig-5/Table-III numbers hold it there.

A sweep over the cluster-geometry axes therefore costs::

    prefixes:    n_circuits                  (once, the expensive part)
    reclusters:  n_circuits x n_classes      (cheap greedy replay)

instead of ``n_circuits x n_classes`` full packs, and the lowering side
pairs with it: :meth:`PackedCircuit.lower_ir` accepts a ``template``
CircuitIR from any sibling class and patches only the columns clustering
can change (sites, LBs, edge delay classes, ALM modes) instead of
re-levelizing the whole netlist (see
:func:`repro.core.circuit_ir.lower_pack_ir_incremental`; since PR 5 the
fresh path shares the same patch over the content-cached functional IR,
so fresh and template lowering are identical by construction).
"""
from __future__ import annotations

import bisect
from collections import Counter, defaultdict
from dataclasses import dataclass, field

import numpy as np

from . import plan as _planner
from .alm import ArchParams
from .netlist import CONST1, Netlist
from .packing import (ALM, LAST_PACK_DEBUG, ClusterPlan, Half, PackedCircuit,
                      _atom_sigs_of, _build_cluster_plan, _cluster,
                      _fanout_counts, _pair_luts)

#: first fully-lowered CircuitIR per (netlist digest, seed) — the template
#: sibling structural classes patch instead of re-lowering.  Lives in the
#: shared registry (not on the prefix object) so one
#: :func:`repro.core.plan.clear_caches` provably forces re-lowering and a
#: prefix at another seed can never serve a stale template.
_TEMPLATE_CACHE = _planner.register_cache("ir_template", cap=256)


@dataclass
class PackPrefix:
    """The arch-invariant prefix of a pack: chain-slotted ALM skeleton,
    absorbed-LUT assignment, LUT pairing and the cluster plan.  Immutable
    by convention — :func:`repack` copies every structure clustering
    mutates, so one prefix serves any number of re-clusterings."""

    net: Netlist
    seed: int
    alms: list[ALM]                      # chain-slotted arith skeleton
    chain_site: dict[tuple[int, int], int]
    lut_site: dict[int, int]             # absorbed LUTs only, at this stage
    chain_alm_runs: list[list[int]]
    pairs: list[tuple[int, int]]
    singles6: list[int]
    singles5: list[int]
    plan: ClusterPlan

    def _template_key(self) -> tuple:
        key = self.__dict__.get("_tpl_key")
        if key is None:
            key = (self.net.content_digest(), self.seed)
            self.__dict__["_tpl_key"] = key
        return key

    @property
    def ir_template(self):
        """First fully-lowered :class:`~repro.core.circuit_ir.CircuitIR`
        of this prefix (any structural class) — registry-backed, keyed by
        (netlist content digest, seed)."""
        return _TEMPLATE_CACHE.get(self._template_key())

    @ir_template.setter
    def ir_template(self, ir) -> None:
        if ir is None:
            _TEMPLATE_CACHE.pop(self._template_key())
        else:
            _TEMPLATE_CACHE.put(self._template_key(), ir)


def pack_prefix(net: Netlist, seed: int = 0) -> PackPrefix:
    """Steps 1-3 of :func:`repro.core.packing.pack` (absorption, chain
    slotting, LUT pairing) plus the cluster plan — everything that does
    not depend on the architecture."""
    import random

    rng = random.Random(seed)
    fanout = _fanout_counts(net)

    # --- 1. absorption pre-pass -------------------------------------------
    absorbed_of: dict[tuple[int, int], list[int]] = {}
    lut_absorbed: set[int] = set()
    for ci, ch in enumerate(net.chains):
        for bi in range(len(ch.sums)):
            got: list[int] = []
            for s in (ch.a[bi], ch.b[bi]):
                if s <= CONST1:
                    continue
                drv = net.driver.get(s)
                if (drv is not None and drv[0] == "lut"
                        and fanout[s] == 1
                        and len(net.lut_inputs[drv[1]]) <= 4
                        and drv[1] not in lut_absorbed):
                    got.append(drv[1])
                    lut_absorbed.add(drv[1])
            if got:
                absorbed_of[(ci, bi)] = got

    free_luts = [i for i in range(net.n_luts) if i not in lut_absorbed]

    # --- 2. chain slotting --------------------------------------------------
    alms: list[ALM] = []
    chain_site: dict[tuple[int, int], int] = {}
    lut_site: dict[int, int] = {}
    chain_alm_runs: list[list[int]] = []  # per chain, its ALM indices
    for ci, ch in enumerate(net.chains):
        run: list[int] = []
        for lo in range(0, len(ch.sums), 2):
            halves = []
            for bi in (lo, lo + 1):
                if bi < len(ch.sums):
                    ab = absorbed_of.get((ci, bi), [])
                    halves.append(Half(fa=(ci, bi), fa_feed="lut", absorbed=ab))
                else:
                    halves.append(Half())
            alm = ALM(halves=(halves[0], halves[1]), is_arith=True)
            ai = len(alms)
            alms.append(alm)
            run.append(ai)
            for bi in (lo, lo + 1):
                if bi < len(ch.sums):
                    chain_site[(ci, bi)] = ai
                    for li in absorbed_of.get((ci, bi), []):
                        lut_site[li] = ai
        chain_alm_runs.append(run)

    # --- 3. LUT pairing -----------------------------------------------------
    pairs, singles6, singles5 = _pair_luts(net, free_luts, rng)

    # --- cluster plan (atom list, connectivity, placement orders) -----------
    plan = _build_cluster_plan(net, alms, chain_alm_runs, chain_site,
                               pairs, singles6, singles5, rng)

    return PackPrefix(net=net, seed=seed, alms=alms, chain_site=chain_site,
                      lut_site=lut_site, chain_alm_runs=chain_alm_runs,
                      pairs=pairs, singles6=singles6, singles5=singles5,
                      plan=plan)


def _copy_skeleton(alms: list[ALM]) -> list[ALM]:
    """Fresh ALM objects for one re-clustering — clustering mutates
    halves (hosting, Z conversion) and appends logic ALMs, so the
    prefix's skeleton must never be handed out directly."""
    # bypasses the dataclass constructors (keyword plumbing is ~2x the
    # cost of the copy itself on large skeletons); absorbed lists are
    # shared — clustering never mutates them
    new_half, new_alm = Half.__new__, ALM.__new__
    out: list[ALM] = []
    for alm in alms:
        h0, h1 = alm.halves
        c0 = new_half(Half)
        c0.fa, c0.fa_feed = h0.fa, h0.fa_feed
        c0.absorbed, c0.hosted_lut = h0.absorbed, h0.hosted_lut
        c1 = new_half(Half)
        c1.fa, c1.fa_feed = h1.fa, h1.fa_feed
        c1.absorbed, c1.hosted_lut = h1.absorbed, h1.hosted_lut
        a2 = new_alm(ALM)
        a2.halves = (c0, c1)
        a2.lut6 = alm.lut6
        a2.is_arith = alm.is_arith
        out.append(a2)
    return out


def cluster_delta(base: PackedCircuit, new: PackedCircuit) -> dict:
    """Per-cluster membership diff between two packs of the *same arch*
    — the flow server's delta-path attribution (how much of a
    ``base_digest`` request's packing actually changed).

    An LB is *changed* when its multiset of ALM occupancies differs —
    ALM identity is taken structurally (the FA bits and hosted/absorbed
    LUT indices of each half, plus arith/lut6 flags), so two packs of
    netlists that share atom numbering (the delta-request contract)
    compare meaningfully.  Surviving clusters split into *frozen* (same
    signature at the same LB index) and *moved* (same signature at a
    different index — a pure renumbering); the remainder is
    *re-clustered* (membership actually changed).  Returns
    ``{"n_lbs_base", "n_lbs_new", "n_changed", "unchanged_frac",
    "n_frozen", "n_moved", "n_reclustered"}`` with ``n_reclustered ==
    n_changed`` (kept under both names for the serve delta contract);
    byte-identical packs report 0 changed clusters."""

    def alm_sig(pack: PackedCircuit, ai: int) -> tuple:
        alm = pack.alms[ai]
        return tuple((h.fa, h.fa_feed, tuple(h.absorbed), h.hosted_lut)
                     for h in alm.halves) + (alm.is_arith, alm.lut6)

    def lb_sigs(pack: PackedCircuit) -> list[tuple]:
        # sort by repr: signature fields mix None with tuples/ints, which
        # have no direct ordering — only a canonical multiset order is
        # needed, not a meaningful one
        return [tuple(sorted((alm_sig(pack, ai) for ai in lb.alms),
                             key=repr))
                for lb in pack.lbs]

    base_sigs = lb_sigs(base)
    new_sigs = lb_sigs(new)
    # greedy signature matching: clusters that survive verbatim cancel
    # out, position-independently (re-clustering may renumber LBs)
    surviving = Counter(base_sigs) & Counter(new_sigs)
    n_same = sum(surviving.values())
    n_changed = max(len(base_sigs), len(new_sigs)) - n_same
    # positional matches are always a valid subset of the Counter
    # matching (each consumes one base and one new copy of the same
    # signature), so frozen + moved partitions the survivors exactly
    n_frozen = sum(1 for b, n in zip(base_sigs, new_sigs) if b == n)
    return {
        "n_lbs_base": len(base_sigs),
        "n_lbs_new": len(new_sigs),
        "n_changed": int(n_changed),
        "unchanged_frac": n_same / max(len(new_sigs), 1),
        "n_frozen": int(n_frozen),
        "n_moved": int(n_same - n_frozen),
        "n_reclustered": int(n_changed),
    }


def repack(prefix: PackPrefix, arch: ArchParams,
           allow_unrelated: bool = True, strict_phases: tuple = (False,),
           pull_runs: bool = False) -> PackedCircuit:
    """Replay the clustering stage of ``pack()`` under ``arch``'s LB
    budgets.  Byte-identical to ``pack(prefix.net, arch, prefix.seed)``
    by construction, at the cost of one skeleton copy instead of the
    whole prefix."""
    LAST_PACK_DEBUG.clear()
    return _cluster(prefix.net, arch, _copy_skeleton(prefix.alms),
                    prefix.chain_alm_runs, prefix.plan,
                    dict(prefix.chain_site), dict(prefix.lut_site),
                    allow_unrelated=allow_unrelated,
                    strict_phases=strict_phases, pull_runs=pull_runs)


# =========================================================================
# Cluster-local incremental repack
# =========================================================================
#
# The greedy clusterer is a long sequence of *decisions* (per atom: which
# LBs were probed, which rejected, which accepted) over state that is
# almost entirely LB-local.  ``RepackLog`` records one real re-clustering
# at decision granularity; ``ReplayAdvisor`` replays a later
# re-clustering of an *edited* netlist against that log, skipping every
# probe whose verdict provably transfers (same atom sequence, same
# consult order, LB untouched by any divergence so far) and applying the
# recorded scan side effects (hostable prunes/reinserts, capacity-set
# discards) verbatim.  Everything else — every consult of a diverged LB,
# every dirty atom, every accept — runs the real code, so the result is
# byte-identical to a fresh ``pack()`` of the edited netlist by
# construction: the advisor only ever *verifies* that base state mirrors
# fresh state, it never steers a decision.  Any detected divergence
# demotes the involved LBs to the dirty set (always real-scanned from
# then on); the dirty-set growth bound and the order/LB-count checks are
# the escape hatches that degrade advice to a plain full re-cluster.


class RepackLog:
    """Decision log of one real re-clustering (record mode).

    Hook API consumed by :func:`repro.core.packing._cluster` via its
    ``replay`` parameter: ``start_atom`` opens a step,
    ``open_consult``/``close_consult`` bracket one LB scan, ``ev_*``
    capture the scan's state side effects, ``note_atom`` seals the step
    with its outcome.  Recording is observation-only — a logged
    re-clustering is byte-identical to an unlogged one.

    Storage is **per LB**, not per step: ``hist[lb]`` is the ordered
    stream of operations that touched that LB — reject scans (with
    their pruning events), accepting scans, and whole-ALM commits (run
    bits, materializations).  An LB's state is a pure function of its
    op stream plus the acting atoms' data, which is what lets
    :class:`ReplayAdvisor` transfer verdicts *order-tolerantly*: the
    greedy loop of an edited netlist may visit atoms in a different
    global order (frontier scores shift), but any LB whose op stream
    still matches entry-for-entry is provably in the same state."""

    #: hist entry kinds
    REJ, ACC, COMMIT = 0, 1, 2
    #: event codes inside one consult, in firing order
    EV_POP, EV_INS, EV_CAPD = 0, 1, 2

    def __init__(self, arch: ArchParams, allow_unrelated: bool,
                 strict_phases: tuple, pull_runs: bool):
        self.arch = arch
        self.allow_unrelated = allow_unrelated
        self.strict_phases = tuple(strict_phases)
        self.pull_runs = pull_runs
        #: per-LB op stream: list of (kind, aidx, evs-tuple-or-None)
        self.hist: list[list[tuple]] = []
        #: per-atom outcome + consult footprint (ownership columns)
        self.atom_touched: dict[int, tuple] = {}
        self.atom_consults: dict[int, tuple] = {}
        self._aidx = -1
        self._step_lbs: list[int] = []
        self._fired: list | None = None
        self._open: int | None = None

    def _row(self, lb: int) -> list:
        hist = self.hist
        while len(hist) <= lb:
            hist.append([])
        return hist[lb]

    # -- record hooks ----------------------------------------------------
    def start_atom(self, aidx: int):
        self._aidx = aidx
        self._step_lbs = []
        self._fired = None
        self._open = None
        return None

    def open_consult(self, lb: int) -> None:
        self._step_lbs.append(lb)
        self._fired = None
        self._open = lb

    def ev_pop(self, lb: int, ai: int) -> None:
        f = self._fired
        if f is None:
            f = self._fired = []
        f.append((self.EV_POP, ai))

    def ev_ins(self, lb: int, ai: int) -> None:
        f = self._fired
        if f is None:
            f = self._fired = []
        f.append((self.EV_INS, ai))

    def ev_capd(self, lb: int) -> None:
        f = self._fired
        if f is None:
            f = self._fired = []
        f.append((self.EV_CAPD, -1))

    def close_consult(self, lb: int) -> None:
        self._row(lb).append(
            (self.REJ, self._aidx, tuple(self._fired) if self._fired
             else None))
        self._fired = None
        self._open = None

    def note_atom(self, aidx: int, touched: tuple, ret: int | None,
                  n_lbs: int) -> None:
        if self._open is not None:
            # host-accept exit: the only path reaching note_atom with an
            # unclosed consult
            self._row(self._open).append(
                (self.ACC, aidx, tuple(self._fired) if self._fired
                 else None))
        else:
            # run bits / materialization: one whole-ALM commit per
            # touched LB, in placement order
            for lb in touched:
                self._row(lb).append((self.COMMIT, aidx, None))
        self.atom_touched[aidx] = touched
        self.atom_consults[aidx] = tuple(self._step_lbs)
        self._fired = None
        self._open = None

    # -- queries ---------------------------------------------------------
    def n_ops(self) -> int:
        return sum(len(r) for r in self.hist)

    def ownership(self) -> tuple[np.ndarray, list]:
        """Per-atom owner LB (the last LB the step committed into; -1
        for never-committed steps) and per-atom consulted-LB dependency
        lists — the ClusterPlan ownership columns of a delta plan."""
        n = max(self.atom_touched, default=-1) + 1
        owner = np.full(n, -1, np.int64)
        deps: list = [()] * n
        for aidx, t in self.atom_touched.items():
            if t:
                owner[aidx] = t[-1]
            deps[aidx] = self.atom_consults.get(aidx, ())
        return owner, deps


class ReplayAdvisor:
    """Advise mode: replay an edited re-clustering against a base
    :class:`RepackLog`, skipping provably-transferable reject scans.

    Soundness discipline — per-LB verified sync.  The advisor keeps a
    pointer ``hp[lb]`` into each LB's logged op stream.  A reject scan
    of a clean LB is skipped only when the stream's next entry is a
    reject *by the same atom* (same atom + same LB state ⇒ same verdict
    and same pruning side effects, which are applied verbatim); every
    real scan of a clean LB is verified against the stream (same fired
    events advance the pointer, anything else — unexpected events, an
    accept where base rejected or vice versa, a commit by a different
    atom — demotes the LB to ``div``: diverged, never skipped again).
    Eventless reject scans are state-neutral and never break sync.
    Atom order may diverge freely: sync is per LB, not global.

    Escape hatches: dirty atoms (edited data) are never skipped and any
    LB they commit into diverges; ``len(div) > max_div`` turns advice
    off entirely (``fallback`` — the rest of the run is a plain full
    re-cluster); ``unsound`` flags a recorded event that failed to
    apply (the sync invariant was broken), after which callers must
    discard the result and re-cluster fully."""

    def __init__(self, log: RepackLog, dirty_atoms, max_div: int = 32):
        self.log = log
        self.dirty = frozenset(dirty_atoms)
        self.max_div = max_div
        self.active = True
        self.fallback = False
        self.unsound = False
        self.off_reason: str | None = None
        self.div: set[int] = set()
        self.n_skipped = 0
        self.n_scanned = 0
        self._hist = log.hist
        self._nhist = len(log.hist)
        self._hp = [0] * self._nhist
        self._aidx = -1
        self._adirty = False
        self._open: int | None = None
        self._mpos = -1
        self._fired: list | None = None

    # -- hooks -----------------------------------------------------------
    def start_atom(self, aidx: int):
        if not self.active:
            return None
        self._aidx = aidx
        self._adirty = aidx in self.dirty
        self._open = None
        self._fired = None
        return None if self._adirty else self

    def try_skip(self, cand: int, lbs_state, host_capacity_lbs) -> bool:
        """One call per enumerated candidate: skip iff the LB is clean
        and its logged stream's next op is this atom's reject; applying
        the recorded pruning events keeps the LB's live state marching
        in step with the log."""
        if not self.active or cand >= self._nhist or cand in self.div:
            return False
        row = self._hist[cand]
        p = self._hp[cand]
        if p >= len(row):
            return False
        kind, aidx, evs = row[p]
        if kind != 0 or aidx != self._aidx:
            return False
        self._hp[cand] = p + 1
        self.n_skipped += 1
        if evs:
            st = lbs_state[cand]
            hostable = st.hostable
            for k, ai in evs:
                if k == 0:        # EV_POP
                    try:
                        hostable.remove(ai)
                    except ValueError:
                        self.unsound = True
                        self._deactivate("event")
                elif k == 1:      # EV_INS — _unhost's positional insert
                    if ai in hostable:
                        self.unsound = True
                        self._deactivate("event")
                    else:
                        pos = st.alm_pos[ai]
                        idx = 0
                        while (idx < len(hostable)
                               and st.alm_pos[hostable[idx]] < pos):
                            idx += 1
                        hostable.insert(idx, ai)
                else:             # EV_CAPD
                    host_capacity_lbs.discard(cand)
        return True

    def open_consult(self, cand: int) -> None:
        if not self.active:
            return
        self.n_scanned += 1
        self._open = cand
        self._fired = None
        self._mpos = -1
        if cand in self.div or cand >= self._nhist:
            return
        self._mpos = self._hp[cand]

    def ev_pop(self, lb: int, ai: int) -> None:
        f = self._fired
        if f is None:
            f = self._fired = []
        f.append((0, ai))

    def ev_ins(self, lb: int, ai: int) -> None:
        f = self._fired
        if f is None:
            f = self._fired = []
        f.append((1, ai))

    def ev_capd(self, lb: int) -> None:
        f = self._fired
        if f is None:
            f = self._fired = []
        f.append((2, -1))

    def close_consult(self, cand: int) -> None:
        if not self.active:
            return
        fired = self._fired
        mpos = self._mpos
        self._open = None
        self._fired = None
        self._mpos = -1
        if mpos < 0:
            # diverged LB: its real scans run unverified (and unskipped)
            return
        row = self._hist[cand]
        if mpos < len(row):
            kind, aidx, evs = row[mpos]
            if kind == 0 and aidx == self._aidx                     and (tuple(fired) if fired else None) == evs:
                self._hp[cand] = mpos + 1   # verified: still in step
                return
        if fired:
            # this scan pruned the LB in a way the log never recorded
            # (or recorded differently): its state now diverges
            self._mark_div(cand)
        # eventless mismatches are state-neutral — sync holds as-is

    def note_atom(self, aidx: int, touched: tuple, ret: int | None,
                  n_lbs: int) -> None:
        if not self.active:
            return
        if self._open is not None:
            # host-accept: a commit into the consulted LB
            cand = self._open
            fired = self._fired
            mpos = self._mpos
            self._open = None
            self._fired = None
            self._mpos = -1
            if cand in self.div:
                pass
            elif self._adirty:
                # edited atom data committed into this LB
                self._mark_div(cand)
            elif mpos >= 0 and cand < self._nhist:
                row = self._hist[cand]
                ok = False
                if mpos < len(row):
                    kind, a2, evs = row[mpos]
                    ok = (kind == 1 and a2 == aidx
                          and (tuple(fired) if fired else None) == evs)
                if ok:
                    self._hp[cand] = mpos + 1
                else:
                    self._mark_div(cand)
            else:
                self._mark_div(cand)
        else:
            # run bits / materialization commits
            for lb in touched:
                if lb in self.div:
                    continue
                if self._adirty or lb >= self._nhist:
                    self._mark_div(lb)
                    continue
                row = self._hist[lb]
                p = self._hp[lb]
                if p < len(row) and row[p][0] == 2 and row[p][1] == aidx:
                    self._hp[lb] = p + 1
                else:
                    self._mark_div(lb)
        if len(self.div) > self.max_div and self.active:
            self._deactivate("growth")
            self.fallback = True

    def _mark_div(self, lb: int) -> None:
        self.div.add(lb)

    def _deactivate(self, reason: str) -> None:
        if self.active:
            self.active = False
            self.off_reason = reason


def repack_with_log(prefix: PackPrefix, arch: ArchParams,
                    allow_unrelated: bool = True,
                    strict_phases: tuple = (False,),
                    pull_runs: bool = False
                    ) -> tuple[PackedCircuit, RepackLog]:
    """:func:`repack` with decision recording — same pack, plus the
    :class:`RepackLog` a later :func:`repack_delta` replays against."""
    log = RepackLog(arch, allow_unrelated, strict_phases, pull_runs)
    LAST_PACK_DEBUG.clear()
    pack = _cluster(prefix.net, arch, _copy_skeleton(prefix.alms),
                    prefix.chain_alm_runs, prefix.plan,
                    dict(prefix.chain_site), dict(prefix.lut_site),
                    allow_unrelated=allow_unrelated,
                    strict_phases=strict_phases, pull_runs=pull_runs,
                    replay=log)
    return pack, log


def netlist_structural_diff(base: Netlist, new: Netlist) -> dict | None:
    """Index-stable structural diff of two netlists, or ``None`` when
    the edit is outside the dirty-set contract (changed shape, edited
    chains, renamed outputs) and the caller must fall back to a full
    :func:`pack_prefix`.  ``changed_inputs`` lists LUTs whose fanin
    tuple changed (the pack-relevant edits); ``changed_tt`` lists
    truth-table-only edits (pack-irrelevant — zero dirty atoms)."""
    if (base.n_signals != new.n_signals or base.n_luts != new.n_luts
            or len(base.chains) != len(new.chains)
            or base.pis != new.pis or base.pos != new.pos):
        return None
    for c0, c1 in zip(base.chains, new.chains):
        if (list(c0.a) != list(c1.a) or list(c0.b) != list(c1.b)
                or list(c0.sums) != list(c1.sums)
                or c0.cin != c1.cin or c0.cout != c1.cout):
            return None
    if list(base.lut_out) != list(new.lut_out):
        return None
    changed_inputs = [li for li in range(base.n_luts)
                      if base.lut_inputs[li] != new.lut_inputs[li]]
    changed_tt = [li for li in range(base.n_luts)
                  if base.lut_tt[li] != new.lut_tt[li]]
    return {"changed_inputs": changed_inputs, "changed_tt": changed_tt}


def _plan_scaffold(prefix: PackPrefix) -> dict:
    """Connectivity scaffolding of a prefix's plan — the indexes
    ``_build_cluster_plan`` discards (atom signal sets, signal->atoms,
    signal->consumers, fanout counts, LUT->atom map) rebuilt once and
    cached on the prefix, so a stream of edits against the same base
    amortizes the O(edges) passes."""
    sc = prefix.__dict__.get("_scaffold")
    if sc is not None:
        return sc
    net, plan = prefix.net, prefix.plan
    atoms = plan.atoms
    atom_sigs = [_atom_sigs_of(net, a) for a in atoms]
    sig2atoms: dict[int, list[int]] = defaultdict(list)
    for idx in range(len(atoms)):
        for s in atom_sigs[idx]:
            sig2atoms[s].append(idx)
    sig_consumers: dict[int, list[tuple]] = defaultdict(list)
    for li in range(net.n_luts):
        for s in net.lut_inputs[li]:
            if s > CONST1:
                sig_consumers[s].append(("lut", li))
    for ci, ch in enumerate(net.chains):
        for bi in range(len(ch.sums)):
            for s in (ch.a[bi], ch.b[bi]):
                if s > CONST1:
                    sig_consumers[s].append(("chain", ci, bi))
    atom_of_lut: dict[int, int] = {}
    for idx, atom in enumerate(atoms):
        if atom[0] != "run":
            for li in atom[1:]:
                if isinstance(li, int):
                    atom_of_lut[li] = idx
    sc = {
        "atom_sigs": atom_sigs,
        "sig2atoms": dict(sig2atoms),
        "sig_consumers": dict(sig_consumers),
        "atom_of_lut": atom_of_lut,
        "fanout": Counter(_fanout_counts(net)),
    }
    prefix.__dict__["_scaffold"] = sc
    return sc


def _splice_csr(base_ptr: np.ndarray, base_arrs: tuple, changed: dict
                ) -> tuple:
    """Row-splice a CSR image: replace ``changed``'s rows (``{row:
    (col0_values, col1_values, ...)}``), keep every other row's slice —
    byte-identical to rebuilding the CSR from the patched row lists."""
    n = base_ptr.size - 1
    lens = np.diff(base_ptr)
    for r, vals in changed.items():
        lens[r] = len(vals[0])
    new_ptr = np.zeros(n + 1, base_ptr.dtype)
    np.cumsum(lens, out=new_ptr[1:])
    segs: list[list] = [[] for _ in base_arrs]
    prev = 0
    for r in sorted(changed):
        if prev < r:
            lo, hi = base_ptr[prev], base_ptr[r]
            for k, arr in enumerate(base_arrs):
                segs[k].append(arr[lo:hi])
        vals = changed[r]
        for k, arr in enumerate(base_arrs):
            segs[k].append(np.asarray(vals[k], arr.dtype))
        prev = r + 1
    if prev < n:
        lo, hi = base_ptr[prev], base_ptr[n]
        for k, arr in enumerate(base_arrs):
            segs[k].append(arr[lo:hi])
    new_arrs = tuple(
        np.concatenate(segs[k]) if segs[k] else base_arrs[k][:0]
        for k in range(len(base_arrs)))
    return (new_ptr,) + new_arrs


def pack_prefix_delta(base: PackPrefix, new_net: Netlist,
                      base_log: RepackLog | None = None,
                      diff: dict | None = None
                      ) -> tuple[PackPrefix | None, dict]:
    """Diff an edited netlist against a base prefix and build the edited
    prefix by splicing only the dirty rows of the base
    :class:`ClusterPlan` — byte-identical to ``pack_prefix(new_net,
    base.seed)`` whenever it returns a prefix.

    Eligibility gates (each one falls back to ``(None, {"reason":
    ...})`` and the caller runs the full prefix build): index-stable
    shape diff, no chain edits, no edits to absorbed LUTs, unchanged
    absorption decisions, unchanged LUT pairing.  The returned info dict
    names the ``dirty_atoms`` the re-clustering must treat as edited."""
    if diff is None:
        diff = netlist_structural_diff(base.net, new_net)
    if diff is None:
        return None, {"reason": "shape"}
    edited = diff["changed_inputs"]
    plan = base.plan
    if not edited:
        # tt-only edit: the prefix is pack-identical — share everything
        # (repack copies every structure clustering mutates)
        new_prefix = PackPrefix(
            net=new_net, seed=base.seed, alms=base.alms,
            chain_site=base.chain_site, lut_site=base.lut_site,
            chain_alm_runs=base.chain_alm_runs, pairs=base.pairs,
            singles6=base.singles6, singles5=base.singles5, plan=plan)
        if "_scaffold" in base.__dict__:
            new_prefix.__dict__["_scaffold"] = base.__dict__["_scaffold"]
        return new_prefix, {"mode": "tt_only", "dirty_atoms": frozenset(),
                            "changed_tt": diff["changed_tt"]}
    edited_set = set(edited)
    if any(li in base.lut_site for li in edited):
        # prefix-stage lut_site holds exactly the absorbed LUTs; editing
        # one rewrites skeleton ALM IO — full rebuild territory
        return None, {"reason": "absorbed_edit"}
    sc = _plan_scaffold(base)
    fanout = sc["fanout"]
    sig_consumers = sc["sig_consumers"]

    # --- absorption gate: the pre-pass must make identical decisions ----
    # Its predicate per chain operand reads only the operand's fanout and
    # its driver LUT's arity, so only operands touched by a changed
    # fanout count or a changed driver arity need rechecking.
    delta_fan: Counter = Counter()
    for li in edited:
        for s in base.net.lut_inputs[li]:
            delta_fan[s] -= 1
        for s in new_net.lut_inputs[li]:
            delta_fan[s] += 1
    new_fanout = fanout.copy()
    new_fanout.update(delta_fan)
    recheck = {s for s, d in delta_fan.items() if d and s > CONST1}
    recheck.update(new_net.lut_out[li] for li in edited)
    for s in recheck:
        for cons in sig_consumers.get(s, ()):
            if cons[0] != "chain":
                continue
            drv = new_net.driver.get(s)
            if drv is None or drv[0] != "lut":
                continue
            li2 = drv[1]
            was = li2 in base.lut_site
            now = (new_fanout[s] == 1
                   and len(new_net.lut_inputs[li2]) <= 4
                   and s > CONST1)
            if was != now:
                return None, {"reason": "absorption"}

    # --- pairing gate ---------------------------------------------------
    free_luts = [i for i in range(new_net.n_luts) if i not in base.lut_site]
    pairs, singles6, singles5 = _pair_luts(new_net, free_luts, None)
    if (pairs != base.pairs or singles6 != base.singles6
            or singles5 != base.singles5):
        return None, {"reason": "pairing"}

    # --- dirty rows -----------------------------------------------------
    atom_of_lut = sc["atom_of_lut"]
    dirty_atoms = sorted({atom_of_lut[li] for li in edited})
    atoms = plan.atoms
    old_sigs = sc["atom_sigs"]
    new_dirty_sigs = {d: _atom_sigs_of(new_net, atoms[d])
                      for d in dirty_atoms}
    changed_sigs: set[int] = set()
    for d in dirty_atoms:
        changed_sigs |= old_sigs[d] ^ new_dirty_sigs[d]

    # signal -> atoms rows touched by membership changes
    sig2atoms = sc["sig2atoms"]
    patched_s2a: dict[int, list[int]] = {}
    dirty_set = set(dirty_atoms)
    for s in changed_sigs:
        row = [a for a in sig2atoms.get(s, ()) if a not in dirty_set]
        for d in dirty_atoms:
            if s in new_dirty_sigs[d]:
                bisect.insort(row, d)
        patched_s2a[s] = row

    # signal -> consumers rows touched by occurrence changes
    changed_cons: set[int] = set()
    per_lut_delta: dict[int, tuple[Counter, Counter]] = {}
    for li in edited:
        oldc = Counter(s for s in base.net.lut_inputs[li] if s > CONST1)
        newc = Counter(s for s in new_net.lut_inputs[li] if s > CONST1)
        per_lut_delta[li] = (oldc, newc)
        for s in set(oldc) | set(newc):
            if oldc[s] != newc[s]:
                changed_cons.add(s)
    patched_cons: dict[int, list[tuple]] = {}
    for s in changed_cons:
        row = sig_consumers.get(s, ())
        lut_entries = [e for e in row
                       if e[0] == "lut" and e[1] not in edited_set]
        chain_entries = [e for e in row if e[0] == "chain"]
        lis = sorted(set(e[1] for e in lut_entries)
                     | {li for li in edited
                        if per_lut_delta[li][1].get(s, 0)})
        cnt_of = {e[1]: 0 for e in lut_entries}
        for e in lut_entries:
            cnt_of[e[1]] += 1
        merged: list[tuple] = []
        for li in lis:
            n = (per_lut_delta[li][1].get(s, 0) if li in edited_set
                 else cnt_of[li])
            merged.extend([("lut", li)] * n)
        patched_cons[s] = merged + chain_entries

    def s2a(s):
        r = patched_s2a.get(s)
        return r if r is not None else sig2atoms.get(s, ())

    def consumers(s):
        r = patched_cons.get(s)
        return r if r is not None else sig_consumers.get(s, ())

    # --- neighbor rows (frontier counts): dirty atoms + every sharer of
    # a membership-changed signal.  Reused signal sets iterate in the
    # exact order a fresh build would construct them (same insertion
    # sequence), so row entry order — which is semantic: frontier ties
    # break by first-seen — is preserved.
    nbr_rows = set(dirty_atoms)
    for s in changed_sigs:
        nbr_rows.update(sig2atoms.get(s, ()))
        nbr_rows.update(a for a in patched_s2a[s])
    new_neighbors = list(plan.atom_neighbors)
    nbr_changed_csr: dict[int, tuple] = {}
    for j in sorted(nbr_rows):
        sigs_j = new_dirty_sigs.get(j) or old_sigs[j]
        agg: dict[int, int] = {}
        for s in sigs_j:
            for k in s2a(s):
                agg[k] = agg.get(k, 0) + 1
        row = list(agg.items())
        new_neighbors[j] = row
        nbr_changed_csr[j] = ([k for k, _ in row], [c for _, c in row])

    # --- candidate-probe rows: dirty atoms + producers of signals whose
    # consumer multiset changed (their out-consumer probe entries moved)
    cand_rows = set(dirty_atoms)
    for s in changed_cons:
        drv = new_net.driver.get(s)
        if drv is not None and drv[0] == "lut":
            a = atom_of_lut.get(drv[1])
            if a is not None:
                cand_rows.add(a)
    new_cand_ops = list(plan.atom_cand_ops)
    cand_changed_csr: dict[int, tuple] = {}
    for j in sorted(cand_rows):
        ops: list[tuple[int, int]] = []
        for li in atoms[j][1:]:
            if isinstance(li, int):
                for s in new_net.lut_inputs[li]:
                    ops.append((0, s))
                for cons in consumers(new_net.lut_out[li]):
                    if cons[0] == "chain":
                        ops.append((1, base.chain_site[(cons[1], cons[2])]))
                    else:
                        ops.append((2, cons[1]))
        new_cand_ops[j] = ops
        cand_changed_csr[j] = ([op for op, _ in ops], [p for _, p in ops])

    # --- per-dirty-atom IO rows -----------------------------------------
    new_atom_io = list(plan.atom_io)
    new_ah_arr = list(plan.atom_ah_arr) if plan.atom_ah_arr is not None \
        else None
    for d in dirty_atoms:
        ah: set[int] = set()
        prod: set[int] = set()
        for li in atoms[d][1:]:
            if isinstance(li, int):
                ah.update(s for s in new_net.lut_inputs[li] if s > CONST1)
                prod.add(new_net.lut_out[li])
        new_atom_io[d] = (ah, set(), prod)
        if new_ah_arr is not None:
            new_ah_arr[d] = np.array(sorted(ah), np.int32)

    # --- CSR splices ----------------------------------------------------
    if plan.cand_ptr is not None:
        nbr_ptr, nbr_j, nbr_cnt = _splice_csr(
            plan.nbr_ptr, (plan.nbr_j, plan.nbr_cnt), nbr_changed_csr)
        cand_ptr, cand_code, cand_payload = _splice_csr(
            plan.cand_ptr, (plan.cand_code, plan.cand_payload),
            cand_changed_csr)
    else:
        nbr_ptr = nbr_j = nbr_cnt = None
        cand_ptr = cand_code = cand_payload = None

    owner, deps = (base_log.ownership() if base_log is not None
                   else (None, None))
    new_plan = ClusterPlan(
        atoms=atoms, run_order=plan.run_order, lut_order=plan.lut_order,
        skeleton_io=plan.skeleton_io, atom_io=new_atom_io,
        atom_neighbors=new_neighbors, bit_live=plan.bit_live,
        atom_cand_ops=new_cand_ops, cand_ptr=cand_ptr,
        cand_code=cand_code, cand_payload=cand_payload, nbr_ptr=nbr_ptr,
        nbr_j=nbr_j, nbr_cnt=nbr_cnt, atom_ah_arr=new_ah_arr,
        skel_fh=plan.skel_fh, skel_need=plan.skel_need,
        skel_moved=plan.skel_moved, skel_ah_len=plan.skel_ah_len,
        skel_ah_pad=plan.skel_ah_pad, atom_owner_lb=owner,
        atom_dep_lbs=deps)
    new_prefix = PackPrefix(
        net=new_net, seed=base.seed, alms=base.alms,
        chain_site=base.chain_site, lut_site=base.lut_site,
        chain_alm_runs=base.chain_alm_runs, pairs=pairs,
        singles6=singles6, singles5=singles5, plan=new_plan)

    # patched scaffold so an edit *stream* diffs against this prefix at
    # patch cost, not O(edges)
    new_sc = {
        "atom_sigs": [new_dirty_sigs.get(i, s)
                      for i, s in enumerate(old_sigs)],
        "sig2atoms": {**sig2atoms, **patched_s2a},
        "sig_consumers": {**sig_consumers, **patched_cons},
        "atom_of_lut": atom_of_lut,
        "fanout": new_fanout,
    }
    new_prefix.__dict__["_scaffold"] = new_sc
    return new_prefix, {
        "mode": "incremental",
        "dirty_atoms": frozenset(dirty_atoms),
        "changed_sigs": changed_sigs,
        "changed_tt": diff["changed_tt"],
        "n_plan_rows_patched": len(nbr_rows | cand_rows),
    }


def repack_delta(new_prefix: PackPrefix, base_log: RepackLog | None,
                 arch: ArchParams, dirty_atoms=frozenset(),
                 max_div: int = 32, allow_unrelated: bool = True
                 ) -> tuple[PackedCircuit, dict]:
    """Re-cluster an edited prefix with the base decision log as advice:
    only dirty members (and anything their divergence reaches) re-run
    the real scans; surviving LBs are frozen as placed obstacles whose
    recorded decisions replay without scanning.  Byte-identical to
    ``repack(new_prefix, arch)`` — i.e. to a fresh ``pack()`` of the
    edited netlist — in every mode, including the escape hatches."""
    if (base_log is None or base_log.arch != arch
            or base_log.strict_phases != (False,) or base_log.pull_runs
            or base_log.allow_unrelated != allow_unrelated):
        pack = repack(new_prefix, arch, allow_unrelated=allow_unrelated)
        return pack, {"mode": "full", "reason": "no_log"}
    adv = ReplayAdvisor(base_log, dirty_atoms, max_div=max_div)
    LAST_PACK_DEBUG.clear()
    pack = _cluster(new_prefix.net, arch, _copy_skeleton(new_prefix.alms),
                    new_prefix.chain_alm_runs, new_prefix.plan,
                    dict(new_prefix.chain_site), dict(new_prefix.lut_site),
                    allow_unrelated=allow_unrelated,
                    strict_phases=(False,), pull_runs=False, replay=adv)
    if adv.unsound:
        # a recorded event failed to apply: an earlier skip may have run
        # on diverged state — discard and re-cluster fully
        pack = repack(new_prefix, arch, allow_unrelated=allow_unrelated)
        return pack, {"mode": "fallback", "reason": "unsound",
                      "n_skipped": adv.n_skipped,
                      "n_scanned": adv.n_scanned}
    info = {
        "mode": ("fallback" if adv.fallback else "incremental"),
        "n_skipped": adv.n_skipped,
        "n_scanned": adv.n_scanned,
        "n_div_lbs": len(adv.div),
        "n_frozen_lbs": max(len(pack.lbs) - len(adv.div), 0),
        "div_lbs": sorted(adv.div),
        "advice_off_reason": adv.off_reason,
    }
    return pack, info
