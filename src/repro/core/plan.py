"""Shared lowering/planning substrate: cache registry, width-bucket
segmentation and envelope grouping.

Every batched executor in the CAD stack — the fused netlist evaluator
(:mod:`repro.core.eval_jax`), the vectorized static-timing program
(:mod:`repro.core.timing_vec`) and the arch design-space sweep
(:mod:`repro.core.sweep`) — faces the same two planning problems over the
same levelized :class:`~repro.core.circuit_ir.CircuitIR` substrate:

* **width-bucket segmentation** (:func:`segment_levels`): partition a
  level sequence into a few contiguous runs, each padded only to its own
  envelope, minimizing total padded volume by a small DP;
* **envelope grouping** (:func:`group_by_envelope`): cluster many
  circuits into a handful of compatible-envelope groups so a whole suite
  compiles to a few vmapped programs instead of one per circuit.

Both used to live inside ``eval_jax`` and were duplicated (``timing_vec``
imported the DP, ``sweep`` wrapped the grouping behind an adapter shim).
They are jax-free and consume only ``(m, c, b)`` level-width profiles or
objects exposing ``.envelope`` / ``.n_signals`` — which both
:class:`~repro.core.eval_jax.FusedPlan` and
:class:`~repro.core.circuit_ir.CircuitIR` do.

Cache registry
--------------
All content-digest-keyed caches of the lowering/planning stack register
here (:func:`register_cache`) and are cleared together by ONE
:func:`clear_caches`:

* ``netlist_ir`` — functional :class:`CircuitIR` per netlist content
  digest (:func:`repro.core.circuit_ir.lower_netlist_ir`);
* ``eval_plans`` / ``eval_groups`` — the fused evaluator's
  :class:`FusedPlan` and stacked group tensors;
* ``ir_template`` — the sweep engine's per-(circuit digest, seed)
  template IR that sibling structural classes patch
  (:attr:`repro.core.repack.PackPrefix.ir_template`);
* ``placement`` — grid placements per (circuit digest, arch placement
  key, seed) (:func:`repro.core.place.placement_for`) — shared by every
  wire-delay row of a structural class x grid aspect.

Invalidation rule: every key starts with a netlist *content digest*
(:meth:`~repro.core.netlist.Netlist.content_digest`), so structural edits
miss naturally; :func:`clear_caches` exists for tests and for reclaiming
memory, and — unlike the old per-module ``clear_plan_caches()`` — it also
drops the sweep's IR templates, so a cleared registry provably forces
re-lowering (no stale template survives).
"""
from __future__ import annotations


# ---------------------------------------------------------------------------
# cache registry
# ---------------------------------------------------------------------------


class Cache:
    """Bounded LRU mapping with hit/miss/eviction telemetry.

    Eviction is a perf tradeoff, never a correctness one: every consumer
    rebuilds on a miss (re-lowering / re-planning), so a sweep over more
    distinct circuits than a cache's cap still computes correct results —
    it just stops amortizing.  The functional-IR and template caches are
    sized (256) well above the benchmark suites; raise the caps
    (:func:`set_cache_cap`) if a workload legitimately holds more
    circuits warm at once.

    Multi-tenant serving (:mod:`repro.core.serve_flow`) shares these
    caches across requests, so two properties matter beyond the old FIFO
    dict:

    * **LRU order** — a ``get`` hit (or ``[]`` access) refreshes the
      entry, so the steady-state working set of a request mix survives
      one-off circuits streaming through;
    * **counters** — ``hits`` / ``misses`` / ``evictions`` accumulate per
      cache and surface through :func:`cache_stats`; the flow server's
      telemetry and the warm-path cost model both read them.
      ``__contains__`` is a *probe* and deliberately does not count (or
      refresh) — cost models may ask "would this hit?" without skewing
      the stats they are about to report.
    """

    def __init__(self, name: str, cap: int):
        self.name = name
        self.cap = cap
        self._d: dict = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def _touch(self, key) -> None:
        # dicts preserve insertion order; re-inserting moves to the end,
        # which is all LRU needs (the first key is the eviction victim)
        self._d[key] = self._d.pop(key)

    def get(self, key, default=None):
        if key in self._d:
            self.hits += 1
            self._touch(key)
            return self._d[key]
        self.misses += 1
        return default

    def put(self, key, value) -> None:
        if key in self._d:
            self._d.pop(key)
        elif len(self._d) >= self.cap:
            self._d.pop(next(iter(self._d)))
            self.evictions += 1
        self._d[key] = value

    def pop(self, key, default=None):
        return self._d.pop(key, default)

    def clear(self) -> None:
        """Drop all entries.  Lifetime counters survive — a clear is an
        invalidation event, not a telemetry reset
        (:func:`reset_cache_stats` does that)."""
        self._d.clear()

    def resize(self, cap: int) -> None:
        """Change the capacity, evicting LRU entries if shrinking."""
        if cap < 1:
            raise ValueError(f"cache {self.name!r}: cap must be >= 1")
        self.cap = cap
        while len(self._d) > cap:
            self._d.pop(next(iter(self._d)))
            self.evictions += 1

    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {"size": len(self._d), "cap": self.cap, "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "hit_rate": (self.hits / lookups) if lookups else 0.0}

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d

    # dict-compatible views/operators so call sites that historically
    # took a plain dict (e.g. sweep_suite's caller-provided ``prefixes``)
    # accept a registry cache interchangeably
    def __getitem__(self, key):
        if key not in self._d:
            self.misses += 1
            raise KeyError(key)
        self.hits += 1
        self._touch(key)
        return self._d[key]

    def __setitem__(self, key, value) -> None:
        self.put(key, value)

    def __iter__(self):
        return iter(self._d)

    def keys(self):
        return self._d.keys()

    def values(self):
        return self._d.values()

    def items(self):
        return self._d.items()


_REGISTRY: dict[str, Cache] = {}


def register_cache(name: str, cap: int = 64) -> Cache:
    """Create (or fetch) the registry cache ``name``.  Idempotent — module
    reloads and repeated imports share one instance per name."""
    cache = _REGISTRY.get(name)
    if cache is None:
        cache = Cache(name, cap)
        _REGISTRY[name] = cache
    return cache


def clear_caches() -> None:
    """Drop every registered lowering/planning cache at once — functional
    IRs, eval plans, grouped tensors and sweep IR templates.  The single
    invalidation point the per-module ``clear_plan_caches()`` used to
    only partially cover.  Counters survive (a clear is an invalidation,
    not a telemetry reset — see :func:`reset_cache_stats`)."""
    for cache in _REGISTRY.values():
        cache.clear()


def reset_cache_stats() -> None:
    """Zero every registered cache's hit/miss/eviction counters (e.g. at
    flow-server start, so telemetry windows are comparable)."""
    for cache in _REGISTRY.values():
        cache.reset_stats()


def set_cache_cap(name: str, cap: int) -> Cache:
    """Resize the registered cache ``name`` (evicting LRU entries when
    shrinking) — the knob a multi-tenant deployment tunes per cache."""
    cache = _REGISTRY.get(name)
    if cache is None:
        raise KeyError(f"no registered cache named {name!r} "
                       f"(registered: {sorted(_REGISTRY)})")
    cache.resize(cap)
    return cache


def cache_stats() -> dict[str, dict]:
    """Per-cache telemetry: ``{name: {size, cap, hits, misses,
    evictions, hit_rate}}`` — the single surface the flow server's stats
    endpoint, the warm-path cost model diagnostics and the cache tests
    all read.  ``hit_rate`` is derived (``hits / (hits + misses)``; 0.0
    before the first lookup)."""
    return {name: c.stats() for name, c in _REGISTRY.items()}


# ---------------------------------------------------------------------------
# width-bucket segmentation
# ---------------------------------------------------------------------------


def segment_levels(m, c, b, max_buckets: int) -> list[tuple[int, int]]:
    """Partition levels into <= ``max_buckets`` contiguous segments.

    ``m/c/b[t]`` are level ``t``'s LUT-row count, chain count and widest
    chain.  Minimizes the padded row volume ``sum_seg len(seg) * (M_seg +
    C_seg * B_seg)`` by dynamic programming; L is tens at most, so the
    O(K L^2) cost is negligible next to levelization.
    """
    L = len(m)
    if L <= 1:
        return [(0, L)] if L else [(0, 0)]
    K = min(max_buckets, L)

    def seg_cost(i, j):  # cost of segment [i, j)
        mm = max(m[i:j])
        cc = max(c[i:j])
        bb = max(b[i:j])
        return (j - i) * (mm + cc * bb)

    INF = float("inf")
    # dp[k][j]: min cost of first j levels using exactly k segments
    dp = [[INF] * (L + 1) for _ in range(K + 1)]
    back = [[0] * (L + 1) for _ in range(K + 1)]
    dp[0][0] = 0
    for k in range(1, K + 1):
        for j in range(k, L + 1):
            for i in range(k - 1, j):
                if dp[k - 1][i] == INF:
                    continue
                cost = dp[k - 1][i] + seg_cost(i, j)
                if cost < dp[k][j]:
                    dp[k][j] = cost
                    back[k][j] = i
    best_k = min(range(1, K + 1), key=lambda k: dp[k][L])
    bounds = []
    j = L
    for k in range(best_k, 0, -1):
        i = back[k][j]
        bounds.append((i, j))
        j = i
    return bounds[::-1]


def bucket_envelopes(m, c, b, bounds) -> list[tuple[int, int, int]]:
    """Per-bucket ``(M, C, B)`` envelopes of a segmented profile."""
    return [(max(m[i:j], default=0), max(c[i:j], default=0),
             max(b[i:j], default=0)) for i, j in bounds]


def combined_profile(profiles, n_levels: int):
    """Merge member ``(m, c, b)`` profiles into a group profile of
    ``n_levels`` levels (per-level max; members shorter than the group
    contribute zeros)."""
    L = max(n_levels, 1)

    def col(t, sel):
        return max((p[sel][t] if t < len(p[sel]) else 0 for p in profiles),
                   default=0)

    m = [col(t, 0) for t in range(L)]
    c = [col(t, 1) for t in range(L)]
    b = [col(t, 2) for t in range(L)]
    return m, c, b


def padded_rows(bounds, envelopes) -> int:
    """Padded row volume of one segmented profile: ``sum_seg len(seg) *
    (M + C * B)`` — the unit every planning cost model works in."""
    return sum(max(j - i, 1) * (M + C * B)
               for (i, j), (M, C, B) in zip(bounds, envelopes))


# ---------------------------------------------------------------------------
# envelope grouping
# ---------------------------------------------------------------------------


def group_by_envelope(items, max_groups: int = 4,
                      signal_weight: float = 1.0) -> list[list[int]]:
    """Cluster ``items`` into <= ``max_groups`` compatible-envelope groups.

    ``items`` need only expose ``.envelope`` — an ``(L, M, C, B)`` tuple —
    and ``.n_signals``; both :class:`~repro.core.eval_jax.FusedPlan` and
    :class:`~repro.core.circuit_ir.CircuitIR` do, so the evaluator and
    the timing sweep share this single implementation.

    Agglomerative: start one group per item, repeatedly merge the pair
    whose combined layout costs least.  Each resulting group compiles to
    exactly one vmapped jit program.

    The merge cost has two terms, both in "rows of N lane words":

    * the padded *plan* volume ``n * L * (M + C * B)`` of the combined
      worst-case envelope (the index tensors every scan step reads);
    * the padded *value-buffer* volume ``n * max(n_signals)`` weighted by
      ``signal_weight`` — every member's value buffer is padded to the
      group's largest circuit, so co-locating one giant circuit with
      small ones used to make the small members pay the giant's buffer
      rows on every call even when the envelopes merged cheaply.
    """
    groups = [[i] for i in range(len(items))]
    envs = [list(p.envelope) for p in items]
    nsig = [p.n_signals for p in items]

    def vol(env, n):
        L, M, C, B = env
        return n * L * (M + C * B)

    def cost_of(env, ns, n):
        return vol(env, n) + signal_weight * n * ns

    def merged(e1, e2):
        return [max(a, b) for a, b in zip(e1, e2)]

    while len(groups) > max(max_groups, 1):
        best = None
        for i in range(len(groups)):
            for j in range(i + 1, len(groups)):
                me = merged(envs[i], envs[j])
                mns = max(nsig[i], nsig[j])
                ni, nj = len(groups[i]), len(groups[j])
                cost = (cost_of(me, mns, ni + nj)
                        - cost_of(envs[i], nsig[i], ni)
                        - cost_of(envs[j], nsig[j], nj))
                if best is None or cost < best[0]:
                    best = (cost, i, j, me, mns)
        _, i, j, me, mns = best
        groups[i] = groups[i] + groups[j]
        envs[i] = me
        nsig[i] = mns
        del groups[j], envs[j], nsig[j]
    return groups
