"""Columnar pack IR: a PackedCircuit lowered to flat numpy arrays.

``pack()`` produces a Python object graph (ALMs, halves, dict site maps)
that is pleasant to mutate during packing and miserable to analyze at
suite scale — the seed timing analyzer re-walked those dicts per signal,
per arch, per seed.  :func:`lower_pack_ir` flattens one pack into a
:class:`PackIR`: dense integer/float columns that three consumers share —

* the vectorized static-timing analyzer (:mod:`repro.core.timing_vec`),
  which turns the levelized node tables into gather/max/scan programs;
* the architecture design-space sweep (:mod:`repro.core.sweep`), which
  re-times one PackIR under many delay tables (grid rows of
  :func:`repro.core.alm.arch_grid`) without touching Python objects;
* the benchmark flow (:mod:`repro.core.flow`), whose ``pack_and_analyze``
  routes every figure driver through the IR.

Column layout
-------------
Per signal (length ``n_signals``):

``sig_site``
    producing ALM index; ``-1`` for PIs/constants, ``-2`` unplaced.
``sig_lb``
    LB of the producing ALM (``-1`` when none) — routing an edge is
    *local* iff producer LB == consumer LB and both are real.
``sig_kind``
    one of :data:`K_CONST` … :data:`K_COUT`.
``sig_level``
    topological level of the producing node (PIs/consts = 0).

Fanin CSR (timing edges, excluding the intra-chain carry recurrence —
that dependency is captured by the chain tables instead):

``fanin_ptr [S+1]`` / ``fanin_sig [E]`` / ``fanin_cls [E]``
    for signal ``s``, its timing fanins are
    ``fanin_sig[fanin_ptr[s]:fanin_ptr[s+1]]`` with per-edge delay
    classes (below).

Per ALM (length ``n_alms``): ``alm_lb``, ``alm_is_arith``,
``alm_feed [A, 2]`` (per half: 0 = no FA, 1 = LUT-path feed, 2 = Z feed),
``alm_hosted [A, 2]`` (hosted LUT index or -1), ``alm_lut6`` (-1 or the
spanned 6-LUT index).

Levelized node tables (the executor's view): ``lut_levels`` /
``chain_levels`` hold, per topological level, exact-size (unpadded) row
arrays; executors pad/stack them as their batching needs dictate.

Edge delay classes
------------------
An edge's delay is the sum of three components — routing
(none / local / global), LB input pin (none / A–H / Z) and adder path
(none / A–H→adder / Z→adder) — encoded as ``route * 9 + pin * 3 + path``
(27 classes).  The per-arch component table is built by
:func:`repro.core.timing_vec.delay_components`; classes are structural
(decided at pack time), components are per delay row, which is exactly
the split that makes arch-grid batching a gather.  Class 0 is the null
edge (constants / padding): all components zero, gathered from signal 0
(CONST0, arrival 0.0), so padded rows are exact no-ops given the model
invariant that all delays are non-negative.

Node delay classes (``NDC_*``): absorbed LUTs add nothing (their delay
is folded into the A–H→adder path); placed LUTs add
``lut_delay(k) + t_alm_out + t_out_mux_extra``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING

import numpy as np

from .netlist import CONST1, Netlist

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (packing lazily
    from .packing import PackedCircuit  # imports this module via lower_ir)

# signal kinds
K_CONST, K_PI, K_LUT, K_LUT_ABS, K_SUM, K_COUT = range(6)

# edge-class components
ROUTE_NULL, ROUTE_LOCAL, ROUTE_GLOBAL = 0, 1, 2
PIN_NULL, PIN_AH, PIN_Z = 0, 1, 2
PATH_NULL, PATH_AH, PATH_Z = 0, 1, 2
N_EDGE_CLASSES = 27

# node delay classes for LUT rows
NDC_ABSORBED, NDC_LUT4, NDC_LUT5, NDC_LUT6 = range(4)
N_NODE_CLASSES = 4


def edge_class(route: int, pin: int, path: int) -> int:
    return route * 9 + pin * 3 + path


@dataclass(frozen=True)
class LutLevelRows:
    """Unpadded LUT rows of one topological level."""

    ins: np.ndarray       # [M, 6] int32 fanin signals (CONST0-padded pins)
    cls: np.ndarray       # [M, 6] int32 edge classes (0 on padded pins)
    ndc: np.ndarray       # [M] int32 node delay class
    out: np.ndarray       # [M] int32 output signal


@dataclass(frozen=True)
class ChainLevelRows:
    """Unpadded chain rows of one topological level (row width = level's
    widest chain; shorter chains pad bits with null ops and ``sums`` -1)."""

    a_sig: np.ndarray     # [C, B] int32
    a_cls: np.ndarray     # [C, B] int32
    b_sig: np.ndarray     # [C, B] int32
    b_cls: np.ndarray     # [C, B] int32
    cin_sig: np.ndarray   # [C] int32
    cin_cls: np.ndarray   # [C] int32
    sums: np.ndarray      # [C, B] int32 (-1 on padded bits)
    cout: np.ndarray      # [C] int32 (-1 when the chain has no cout)
    last: np.ndarray      # [C] int32 index of the last real bit


@dataclass(frozen=True)
class PackIR:
    name: str
    #: content digest of the source netlist — the incremental-lowering
    #: template guard (same-shaped but different circuits must not patch
    #: each other's IRs)
    net_digest: str
    arch_name: str
    structural_key: tuple
    n_signals: int
    # per-signal columns
    sig_site: np.ndarray
    sig_lb: np.ndarray
    sig_kind: np.ndarray
    sig_level: np.ndarray
    # fanin CSR (timing edges)
    fanin_ptr: np.ndarray
    fanin_sig: np.ndarray
    fanin_cls: np.ndarray
    # per-ALM columns
    alm_lb: np.ndarray
    alm_is_arith: np.ndarray
    alm_feed: np.ndarray
    alm_hosted: np.ndarray
    alm_lut6: np.ndarray
    # levelized node tables (index 0 = first computing level)
    lut_levels: tuple[LutLevelRows, ...]
    chain_levels: tuple[ChainLevelRows, ...]
    # primary outputs + scalar stats
    po_sig: np.ndarray
    n_alms: int
    n_lbs: int
    n_luts: int
    n_adders: int
    concurrent_luts: int

    @property
    def n_levels(self) -> int:
        return len(self.lut_levels)

    def level_profile(self):
        """Per-level (lut rows, chain rows, widest chain) — the width
        profile bucketing/batching decisions consume."""
        m = [lv.out.shape[0] for lv in self.lut_levels]
        c = [lv.cout.shape[0] for lv in self.chain_levels]
        b = [lv.a_sig.shape[1] if lv.cout.shape[0] else 0
             for lv in self.chain_levels]
        return m, c, b


def _levelize(net: Netlist):
    """Nodes grouped by topological level (a node's level is one past its
    deepest input).  Mirrors the evaluator's levelization; kept local so
    the timing stack stays importable without jax."""
    sig_level: dict[int, int] = {s: 0 for s in net.pis}
    sig_level[0] = 0
    sig_level[1] = 0
    by_luts: dict[int, list[int]] = {}
    by_chains: dict[int, list[int]] = {}
    for nd in net.topo_order():
        lv = 0
        for s in net.node_inputs(nd):
            lv = max(lv, sig_level.get(s, 0))
        lv += 1
        for s in net.node_outputs(nd):
            sig_level[s] = lv
        if nd[0] == "lut":
            by_luts.setdefault(lv, []).append(nd[1])
        else:
            by_chains.setdefault(lv, []).append(nd[1])
    return by_luts, by_chains, sig_level


def _placement_columns(packed: "PackedCircuit") -> dict:
    """The placement-derived columns both lowering paths share: per-
    signal site/LB, the per-ALM mode columns, and the chain-bit feed
    views (the `(ci, bi) -> (feed, absorbed)` map, the absorbed-LUT set
    and the per-sum-signal Z-feed flags).  Single source of truth —
    :func:`lower_pack_ir_incremental` must patch exactly what this
    builds."""
    net = packed.net
    S = net.n_signals

    sig_site = np.full(S, -1, dtype=np.int32)
    for li, out in enumerate(net.lut_out):
        sig_site[out] = packed.lut_site.get(li, -2)
    for ci, ch in enumerate(net.chains):
        for bi, s in enumerate(ch.sums):
            sig_site[s] = packed.chain_site.get((ci, bi), -2)
        if ch.cout is not None:
            sig_site[ch.cout] = packed.chain_site.get((ci, len(ch.sums) - 1),
                                                      -2)

    alm_lb_arr = np.asarray(packed.alm_lb, dtype=np.int32) \
        if packed.alm_lb else np.zeros(0, dtype=np.int32)
    sig_lb = np.full(S, -1, dtype=np.int32)
    placed = sig_site >= 0
    sig_lb[placed] = alm_lb_arr[sig_site[placed]]

    A = len(packed.alms)
    alm_is_arith = np.zeros(A, dtype=bool)
    alm_feed = np.zeros((A, 2), dtype=np.int32)
    alm_hosted = np.full((A, 2), -1, dtype=np.int32)
    alm_lut6 = np.full(A, -1, dtype=np.int32)
    feed: dict[tuple[int, int], tuple[str, list[int]]] = {}
    absorbed_all: set[int] = set()
    z_of_sum = np.zeros(S, dtype=bool)
    for ai, alm in enumerate(packed.alms):
        alm_is_arith[ai] = alm.is_arith
        if alm.lut6 is not None:
            alm_lut6[ai] = alm.lut6
        for hi, h in enumerate(alm.halves):
            if h.fa is not None:
                alm_feed[ai, hi] = 2 if h.fa_feed == "z" else 1
                feed[h.fa] = (h.fa_feed, h.absorbed)
                absorbed_all.update(h.absorbed)
                if h.fa_feed == "z":
                    ci, bi = h.fa
                    z_of_sum[net.chains[ci].sums[bi]] = True
            if h.hosted_lut is not None:
                alm_hosted[ai, hi] = h.hosted_lut

    return {"sig_site": sig_site, "sig_lb": sig_lb, "alm_lb": alm_lb_arr,
            "alm_is_arith": alm_is_arith, "alm_feed": alm_feed,
            "alm_hosted": alm_hosted, "alm_lut6": alm_lut6,
            "feed": feed, "absorbed_all": absorbed_all,
            "z_of_sum": z_of_sum}


def lower_pack_ir(packed: "PackedCircuit") -> PackIR:
    """Flatten a :class:`~repro.core.packing.PackedCircuit` into columns."""
    net = packed.net
    arch = packed.arch
    S = net.n_signals

    cols = _placement_columns(packed)
    sig_site, sig_lb, alm_lb_arr = (cols["sig_site"], cols["sig_lb"],
                                    cols["alm_lb"])
    feed, absorbed_all = cols["feed"], cols["absorbed_all"]

    sig_kind = np.full(S, K_PI, dtype=np.int32)
    sig_kind[: min(2, S)] = K_CONST
    for out in net.lut_out:
        sig_kind[out] = K_LUT
    for ch in net.chains:
        for s in ch.sums:
            sig_kind[s] = K_SUM
        if ch.cout is not None:
            sig_kind[ch.cout] = K_COUT
    for li in absorbed_all:
        sig_kind[net.lut_out[li]] = K_LUT_ABS

    def lb_of_site(ai: int) -> int:
        return int(alm_lb_arr[ai]) if ai >= 0 else -1

    def route_cls(s: int, dst_lb: int) -> int:
        src_lb = lb_of_site(int(sig_site[s]))
        if src_lb == dst_lb and src_lb >= 0:
            return ROUTE_LOCAL
        return ROUTE_GLOBAL

    by_luts, by_chains, sig_level_map = _levelize(net)
    sig_level = np.zeros(S, dtype=np.int32)
    for s, lv in sig_level_map.items():
        sig_level[s] = lv
    levels = sorted(set(by_luts) | set(by_chains))
    level_index = {lv: i for i, lv in enumerate(levels)}
    L = len(levels)

    # fanin CSR accumulators
    csr_sig: list[list[int]] = [[] for _ in range(S)]
    csr_cls: list[list[int]] = [[] for _ in range(S)]

    lut_levels: list[LutLevelRows] = []
    chain_levels: list[ChainLevelRows] = []
    for _ in range(L):
        lut_levels.append(None)    # type: ignore[arg-type]
        chain_levels.append(None)  # type: ignore[arg-type]

    for lv in levels:
        t = level_index[lv]
        # ---- LUT rows ----
        ids = [i for i in by_luts.get(lv, ())
               if packed.lut_site.get(i) is not None]
        M = len(ids)
        ins = np.zeros((M, 6), dtype=np.int32)
        cls = np.zeros((M, 6), dtype=np.int32)
        ndc = np.zeros(M, dtype=np.int32)
        out = np.zeros(M, dtype=np.int32)
        for r, li in enumerate(ids):
            osig = net.lut_out[li]
            out[r] = osig
            dst_lb = lb_of_site(packed.lut_site[li])
            k = len(net.lut_inputs[li])
            if li in absorbed_all:
                ndc[r] = NDC_ABSORBED
            elif k <= 4:
                ndc[r] = NDC_LUT4
            elif k == 5:
                ndc[r] = NDC_LUT5
            else:
                ndc[r] = NDC_LUT6
            for j, q in enumerate(net.lut_inputs[li]):
                if q <= CONST1:
                    continue
                ins[r, j] = q
                cls[r, j] = edge_class(route_cls(q, dst_lb), PIN_AH,
                                       PATH_NULL)
                csr_sig[osig].append(q)
                csr_cls[osig].append(int(cls[r, j]))
        lut_levels[t] = LutLevelRows(ins=ins, cls=cls, ndc=ndc, out=out)

        # ---- chain rows ----
        cids = by_chains.get(lv, ())
        C = len(cids)
        B = max((len(net.chains[ci].sums) for ci in cids), default=0)
        a_sig = np.zeros((C, max(B, 1)), dtype=np.int32)
        a_cls = np.zeros((C, max(B, 1)), dtype=np.int32)
        b_sig = np.zeros((C, max(B, 1)), dtype=np.int32)
        b_cls = np.zeros((C, max(B, 1)), dtype=np.int32)
        cin_sig = np.zeros(C, dtype=np.int32)
        cin_cls = np.zeros(C, dtype=np.int32)
        sums = np.full((C, max(B, 1)), -1, dtype=np.int32)
        cout = np.full(C, -1, dtype=np.int32)
        last = np.zeros(C, dtype=np.int32)
        for r, ci in enumerate(cids):
            ch = net.chains[ci]
            n = len(ch.sums)
            last[r] = n - 1
            if ch.cin > CONST1:
                ai0 = packed.chain_site.get((ci, 0), -2)
                cin_sig[r] = ch.cin
                cin_cls[r] = edge_class(route_cls(ch.cin, lb_of_site(ai0)),
                                        PIN_AH, PATH_AH)
            for bi in range(n):
                ai = packed.chain_site.get((ci, bi), -2)
                dst_lb = lb_of_site(ai)
                fkind, absorbed = feed.get((ci, bi), ("lut", []))
                absorbed_outs = {net.lut_out[l] for l in absorbed}
                for op_sig, op_cls, s in ((a_sig, a_cls, ch.a[bi]),
                                          (b_sig, b_cls, ch.b[bi])):
                    if s <= CONST1:
                        continue
                    op_sig[r, bi] = s
                    if s in absorbed_outs:
                        # operand computed in the half's own LUTs — no
                        # routing hop, only the folded A-H adder path
                        c = edge_class(ROUTE_NULL, PIN_NULL, PATH_AH)
                    elif fkind == "z":
                        c = edge_class(route_cls(s, dst_lb), PIN_Z, PATH_Z)
                    else:
                        c = edge_class(route_cls(s, dst_lb), PIN_AH, PATH_AH)
                    op_cls[r, bi] = c
                sums[r, bi] = ch.sums[bi]
                edges = [(ch.a[bi], int(a_cls[r, bi])),
                         (ch.b[bi], int(b_cls[r, bi]))]
                if bi == 0 and ch.cin > CONST1:
                    edges.append((ch.cin, int(cin_cls[r])))
                for q, c in edges:
                    if q > CONST1:
                        csr_sig[ch.sums[bi]].append(q)
                        csr_cls[ch.sums[bi]].append(c)
            if ch.cout is not None:
                cout[r] = ch.cout
        chain_levels[t] = ChainLevelRows(
            a_sig=a_sig, a_cls=a_cls, b_sig=b_sig, b_cls=b_cls,
            cin_sig=cin_sig, cin_cls=cin_cls, sums=sums, cout=cout,
            last=last)

    fanin_ptr = np.zeros(S + 1, dtype=np.int32)
    for s in range(S):
        fanin_ptr[s + 1] = fanin_ptr[s] + len(csr_sig[s])
    fanin_sig = np.array([q for lst in csr_sig for q in lst], dtype=np.int32)
    fanin_cls = np.array([c for lst in csr_cls for c in lst], dtype=np.int32)

    po_sig = np.array(sorted({s for bus in net.pos.values() for s in bus}),
                      dtype=np.int32)

    return PackIR(
        name=net.name, net_digest=net.content_digest(),
        arch_name=arch.name,
        structural_key=arch.structural_key(),
        n_signals=S,
        sig_site=sig_site, sig_lb=sig_lb, sig_kind=sig_kind,
        sig_level=sig_level,
        fanin_ptr=fanin_ptr, fanin_sig=fanin_sig, fanin_cls=fanin_cls,
        alm_lb=alm_lb_arr, alm_is_arith=cols["alm_is_arith"],
        alm_feed=cols["alm_feed"], alm_hosted=cols["alm_hosted"],
        alm_lut6=cols["alm_lut6"],
        lut_levels=tuple(lut_levels), chain_levels=tuple(chain_levels),
        po_sig=po_sig,
        n_alms=packed.n_alms, n_lbs=packed.n_lbs, n_luts=net.n_luts,
        n_adders=net.n_adders, concurrent_luts=packed.concurrent_luts,
    )


#: the unique class of an absorbed chain operand (no route, no pin, the
#: folded A-H adder path) — structural, never produced by any other edge
_CLS_ABSORBED = edge_class(ROUTE_NULL, PIN_NULL, PATH_AH)


def lower_pack_ir_incremental(packed: "PackedCircuit",
                              template: PackIR) -> PackIR:
    """Re-lower a pack by patching a sibling class's PackIR.

    ``template`` must be a full lowering of a pack of the *same netlist
    and prefix* (any structural class — typically the first class of a
    sweep).  Clustering can only move atoms between ALMs/LBs and flip
    chain-bit feeds, so the netlist-shaped columns (signal kinds/levels,
    level tables' signals, fanin CSR topology, primary outputs) are
    reused verbatim and only the placement-derived columns are
    recomputed: per-signal site/LB, per-ALM mode columns, and every edge
    delay class (routing locality, A-H vs Z pin, adder path).  The
    result is array-for-array identical to :func:`lower_pack_ir` — the
    parity tests compare every column.
    """
    net = packed.net
    arch = packed.arch
    S = net.n_signals
    if template.net_digest != net.content_digest():
        raise ValueError(
            f"template PackIR {template.name!r} is not a lowering of "
            f"netlist {net.name!r} — incremental patching needs a sibling "
            f"structural class of the same circuit (content digests "
            f"differ)")

    # --- placement-derived columns (shared builder with the full path) -----
    cols = _placement_columns(packed)
    sig_lb = cols["sig_lb"]
    z_of_sum = cols["z_of_sum"]

    # --- patch edge classes level by level ---------------------------------
    cls_lut_local = edge_class(ROUTE_LOCAL, PIN_AH, PATH_NULL)
    cls_lut_global = edge_class(ROUTE_GLOBAL, PIN_AH, PATH_NULL)
    fanin_cls = np.zeros_like(template.fanin_cls)
    ptr = template.fanin_ptr

    def op_route(src_lb: np.ndarray, dst_lb: np.ndarray) -> np.ndarray:
        return np.where((src_lb == dst_lb) & (src_lb >= 0),
                        ROUTE_LOCAL, ROUTE_GLOBAL)

    lut_levels: list[LutLevelRows] = []
    chain_levels: list[ChainLevelRows] = []
    for ll, cl in zip(template.lut_levels, template.chain_levels):
        # ---- LUT rows: route locality is the only class variable ----
        mask = ll.ins > CONST1
        dst = sig_lb[ll.out][:, None]
        local = (sig_lb[ll.ins] == dst) & (sig_lb[ll.ins] >= 0)
        cls = np.where(mask, np.where(local, cls_lut_local, cls_lut_global),
                       0).astype(np.int32)
        lut_levels.append(LutLevelRows(ins=ll.ins, cls=cls, ndc=ll.ndc,
                                       out=ll.out))
        if mask.any():
            offs = np.cumsum(mask, axis=1) - 1
            slots = ptr[ll.out][:, None] + offs
            fanin_cls[slots[mask]] = cls[mask]

        # ---- chain rows: absorbed mask is structural (read from the
        # template), feed kind and routing are placement-derived ----
        C = cl.cout.shape[0]
        if C:
            sums_safe = np.clip(cl.sums, 0, None)
            dst = np.where(cl.sums >= 0, sig_lb[sums_safe], -1)
            feed_z = z_of_sum[sums_safe] & (cl.sums >= 0)

            def patch_ops(op_sig, op_cls_tpl):
                m = op_sig > CONST1
                absorbed = op_cls_tpl == _CLS_ABSORBED
                route = op_route(sig_lb[op_sig], dst)
                c_z = route * 9 + PIN_Z * 3 + PATH_Z
                c_ah = route * 9 + PIN_AH * 3 + PATH_AH
                c = np.where(absorbed, _CLS_ABSORBED,
                             np.where(feed_z, c_z, c_ah))
                return np.where(m, c, 0).astype(np.int32), m

            a_cls, amask = patch_ops(cl.a_sig, cl.a_cls)
            b_cls, bmask = patch_ops(cl.b_sig, cl.b_cls)
            cmask = cl.cin_sig > CONST1
            route0 = op_route(sig_lb[cl.cin_sig], dst[:, 0])
            cin_cls = np.where(cmask, route0 * 9 + PIN_AH * 3 + PATH_AH,
                               0).astype(np.int32)
            # CSR order per sum: a-edge, b-edge, then cin on bit 0
            base = ptr[sums_safe]
            if amask.any():
                fanin_cls[base[amask]] = a_cls[amask]
            slots_b = base + amask.astype(np.int32)
            if bmask.any():
                fanin_cls[slots_b[bmask]] = b_cls[bmask]
            slot_c = base[:, 0] + amask[:, 0].astype(np.int32) \
                + bmask[:, 0].astype(np.int32)
            if cmask.any():
                fanin_cls[slot_c[cmask]] = cin_cls[cmask]
            chain_levels.append(ChainLevelRows(
                a_sig=cl.a_sig, a_cls=a_cls, b_sig=cl.b_sig, b_cls=b_cls,
                cin_sig=cl.cin_sig, cin_cls=cin_cls, sums=cl.sums,
                cout=cl.cout, last=cl.last))
        else:
            chain_levels.append(cl)

    return PackIR(
        name=net.name, net_digest=template.net_digest,
        arch_name=arch.name,
        structural_key=arch.structural_key(),
        n_signals=S,
        sig_site=cols["sig_site"], sig_lb=sig_lb,
        sig_kind=template.sig_kind,
        sig_level=template.sig_level,
        fanin_ptr=template.fanin_ptr, fanin_sig=template.fanin_sig,
        fanin_cls=fanin_cls,
        alm_lb=cols["alm_lb"], alm_is_arith=cols["alm_is_arith"],
        alm_feed=cols["alm_feed"], alm_hosted=cols["alm_hosted"],
        alm_lut6=cols["alm_lut6"],
        lut_levels=tuple(lut_levels), chain_levels=tuple(chain_levels),
        po_sig=template.po_sig,
        n_alms=packed.n_alms, n_lbs=packed.n_lbs, n_luts=net.n_luts,
        n_adders=net.n_adders, concurrent_luts=packed.concurrent_luts,
    )
