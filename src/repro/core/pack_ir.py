"""Columnar pack IR — compatibility facade over the unified CircuitIR.

Historically this module owned the packed lowering (per-signal columns,
fanin CSR with 27 edge delay classes, per-ALM mode columns, levelized
node tables).  PR 5 unified that substrate with the evaluator's level
tensors into :mod:`repro.core.circuit_ir`: one **functional lowering**
per netlist content digest (levelization + truth-table words + CSR
topology, shared by eval, timing and equivalence) plus one vectorized
**placement patch** per (digest, structural class).  ``PackIR`` is now
an alias of :class:`~repro.core.circuit_ir.CircuitIR`; the names below
re-export so existing imports (timing, sweeps, tests) keep working.

See ``repro/core/circuit_ir.py`` for the column layout, the edge/node
delay-class encoding and the cache-registry invalidation rules.
"""
from __future__ import annotations

from .circuit_ir import (  # noqa: F401 — re-exported public surface
    CircuitIR, ChainLevelRows, LutLevelRows,
    K_CONST, K_PI, K_LUT, K_LUT_ABS, K_SUM, K_COUT,
    ROUTE_NULL, ROUTE_LOCAL, ROUTE_GLOBAL,
    PIN_NULL, PIN_AH, PIN_Z,
    PATH_NULL, PATH_AH, PATH_Z,
    N_EDGE_CLASSES,
    NDC_ABSORBED, NDC_LUT4, NDC_LUT5, NDC_LUT6, N_NODE_CLASSES,
    edge_class, lower_pack_ir, lower_pack_ir_incremental,
)

#: the packed lowering's result type — one dataclass serves eval, timing
#: and equivalence now; kept under the old name for its many importers
PackIR = CircuitIR
