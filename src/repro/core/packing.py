"""Packing: netlist -> ALMs -> logic blocks, for baseline / DD5 / DD6.

A deliberately VPR-like greedy flow, held identical across architectures so
the A/B comparison isolates the architectural change (the paper runs VTR's
timing-driven packer; we model its resource behaviour, not its annealing):

1. **Absorption pre-pass** — fan-out-1, <=4-input LUTs driving a chain
   operand are absorbed into that FA's input LUTs (all architectures; this is
   the classical "LUT simplifies logic before addition" usage).
2. **Chain slotting** — a carry chain of L FA bits occupies ceil(L/2)
   consecutive ALM halves-pairs; chains may span LBs (carry links cross LABs).
3. **LUT pairing** — remaining LUTs are paired into ALM candidates
   (two <=4-LUTs with <=8 distinct inputs, two 5-LUTs sharing >=2 inputs, or a
   single 6-LUT).
4. **Greedy connectivity clustering** into LBs under input/output budgets.
5. **Concurrent co-packing (DD only)** — LUT pairs / singles are placed into
   free or Z-convertible halves of arithmetic ALMs in the same LB before a
   new logic ALM is opened; FA operands of a converted half move to the Z
   pins, debiting the LB's AddMux-crossbar budget (``z_sources`` distinct
   LB-external signals; in-LB producers ride the direct-link taps for free
   when ``z_local_free``).

The baseline architecture rejects step 5 structurally — that is the paper's
entire premise.

Every pack is *verifiable*: :mod:`repro.core.equiv` re-elaborates a
:class:`PackedCircuit` back into the physical netlist its ALMs implement
(absorbed masks, Z-fed vs A–H-fed operands, hosted LUTs, 6-LUT spans) and
proves functional equivalence against the source over random vector lanes —
run ``check_pack_equivalence(net, arch)`` before trusting any area number.

Every pack is also *lowerable*: :meth:`PackedCircuit.lower_ir` flattens the
object graph into the unified :class:`~repro.core.circuit_ir.CircuitIR` (per-
signal site/LB/kind columns, fanin CSR with timing edge classes, per-ALM
mode columns, levelized node tables) — the shared substrate of the
vectorized timing analyzer (:mod:`repro.core.timing_vec`), the architecture
design-space sweep engine (:mod:`repro.core.sweep`) and the benchmark flow
(:mod:`repro.core.flow`).  Only ``ArchParams.structural_key()`` fields steer
this module; delay parameters never do, which is what lets a sweep reuse one
pack (and one CircuitIR) across every delay row of a structural class.
"""
from __future__ import annotations

import heapq
from collections import defaultdict
from dataclasses import dataclass, field
from itertools import islice

import numpy as np

from .alm import ArchParams
from .netlist import CONST0, CONST1, Netlist

#: diagnostic counters from the most recent :func:`pack` call
LAST_PACK_DEBUG: dict[str, int] = {}

#: drive the greedy re-cluster replay through the vectorized
#: ClusterPlan columns (numpy candidate-LB gathers, CSR frontier bumps,
#: batched host-feasibility masks).  The scalar path is kept verbatim as
#: the byte-identity reference — ``tests/core/test_repack.py`` proves
#: both flags produce identical packs across the structural grid.
VECTOR_CLUSTER = True

#: sentinel padding value of the per-ALM A-H signal columns
_SENT32 = np.int32(2**31 - 1)
#: per-ALM A-H column capacity.  An ALM whose A-H set overflows the cap
#: is decidable without the exact distinct count: ``|new_ah| >= ah_len -
#: moved_cnt`` and ``moved_cnt <= 4`` (two convertible halves x two live
#: operands), so ``ah_len > 12`` always fails the 8-pin check.
_AH_CAP = 12
#: below this many candidate ALMs the batched numpy mask costs more than
#: the scalar scan; both are exact, so these thresholds are pure perf —
#: profiled break-evens of numpy dispatch vs the tuned Python loops
_MASK_MIN_ALMS = 24
#: mean per-atom probe/neighbor list length above which a plan's replay
#: uses the numpy CSR gathers instead of the scalar list walks
_VEC_MIN_DEGREE = 48


@dataclass(slots=True)
class Half:
    """One ALM half: 1 FA bit + two 4-LUTs (one 5-LUT equivalent)."""

    fa: tuple[int, int] | None = None      # (chain_idx, bit_idx) or None
    fa_feed: str = "none"                  # "lut" (A-H route) | "z" | "none"
    absorbed: list[int] = field(default_factory=list)  # lut indices feeding FA
    hosted_lut: int | None = None          # unrelated LUT index (mode C/logic)


@dataclass(slots=True)
class ALM:
    halves: tuple[Half, Half]
    lut6: int | None = None                # a hosted 6-LUT spans both halves
    is_arith: bool = False

    def input_signals(self, net: Netlist) -> tuple[set[int], set[int]]:
        """Returns (ah_signals, z_signals) consumed by this ALM."""
        ah: set[int] = set()
        z: set[int] = set()
        for h in self.halves:
            if h.fa is not None:
                ci, bi = h.fa
                ch = net.chains[ci]
                ops = [ch.a[bi], ch.b[bi]]
                if h.fa_feed == "z":
                    z.update(s for s in ops if s > CONST1)
                else:
                    if h.absorbed:
                        for li in h.absorbed:
                            ah.update(s for s in net.lut_inputs[li] if s > CONST1)
                        absorbed_outs = {net.lut_out[li] for li in h.absorbed}
                        ah.update(s for s in ops
                                  if s > CONST1 and s not in absorbed_outs)
                    else:
                        ah.update(s for s in ops if s > CONST1)
            if h.hosted_lut is not None:
                ah.update(s for s in net.lut_inputs[h.hosted_lut] if s > CONST1)
        if self.lut6 is not None:
            ah.update(s for s in net.lut_inputs[self.lut6] if s > CONST1)
        return ah, z

    def output_signals(self, net: Netlist) -> set[int]:
        outs: set[int] = set()
        for h in self.halves:
            if h.fa is not None:
                ci, bi = h.fa
                ch = net.chains[ci]
                outs.add(ch.sums[bi])
                if ch.cout is not None and bi == len(ch.sums) - 1:
                    outs.add(ch.cout)
            if h.hosted_lut is not None:
                outs.add(net.lut_out[h.hosted_lut])
        if self.lut6 is not None:
            outs.add(net.lut_out[self.lut6])
        return outs


@dataclass(slots=True)
class LB:
    alms: list[int] = field(default_factory=list)  # indices into packed.alms


@dataclass
class PackedCircuit:
    net: Netlist
    arch: ArchParams
    alms: list[ALM]
    lbs: list[LB]
    lut_site: dict[int, int]       # lut idx -> alm idx (hosted/absorbed)
    chain_site: dict[tuple[int, int], int]  # (chain, bit) -> alm idx
    alm_lb: list[int]              # alm idx -> lb idx
    concurrent_luts: int           # unrelated LUTs co-packed with active FAs

    _ir: object | None = field(default=None, repr=False, compare=False)

    def lower_ir(self, cache: bool = True, template: object | None = None):
        """Lower to the unified :class:`~repro.core.circuit_ir.CircuitIR` (flat
        per-signal / per-ALM / per-level arrays — the substrate the
        vectorized timing analyzer and the arch-sweep engine consume).
        The IR is cached on the packed circuit; it is immutable, so any
        later mutation of ``alms`` must pass ``cache=False``.

        **Incremental mode**: pass ``template`` — a full lowering of a
        sibling structural class of the same circuit/prefix — and only
        the placement-derived columns (sites, LBs, edge delay classes,
        ALM modes) are recomputed; the netlist-shaped columns (levels,
        fanin CSR topology, node tables' signals) are reused.  Identical
        output to a fresh lowering, at a fraction of the cost — this is
        what a cluster-geometry sweep pays per structural class."""
        if self._ir is None or not cache:
            from .circuit_ir import (lower_pack_ir,
                                     lower_pack_ir_incremental)

            ir = (lower_pack_ir_incremental(self, template)
                  if template is not None else lower_pack_ir(self))
            if not cache:
                return ir
            self._ir = ir
        return self._ir

    # -- stats -------------------------------------------------------------
    @property
    def n_alms(self) -> int:
        return len(self.alms)

    @property
    def n_lbs(self) -> int:
        return len(self.lbs)

    @property
    def total_area(self) -> float:
        return self.n_alms * self.arch.alm_area_mwta

    def produced_in_lb(self, lb_idx: int) -> set[int]:
        out: set[int] = set()
        for ai in self.lbs[lb_idx].alms:
            out.update(self.alms[ai].output_signals(self.net))
        return out

    def lb_external_ins(self, lb_idx: int) -> set[int]:
        produced = self.produced_in_lb(lb_idx)
        need: set[int] = set()
        for ai in self.lbs[lb_idx].alms:
            ah, z = self.alms[ai].input_signals(self.net)
            need.update(ah)
            need.update(z)
        return need - produced

    def stats(self) -> dict:
        return {
            "arch": self.arch.name,
            "alms": self.n_alms,
            "lbs": self.n_lbs,
            "area_mwta": self.total_area,
            "adders": self.net.n_adders,
            "luts": self.net.n_luts,
            "concurrent_luts": self.concurrent_luts,
        }


# ---------------------------------------------------------------------------
# packing driver
# ---------------------------------------------------------------------------


def pack(net: Netlist, arch: ArchParams, seed: int = 0,
         allow_unrelated: bool = True, strict_phases: tuple = (False,),
         pull_runs: bool = False) -> PackedCircuit:
    """Full pack = arch-invariant prefix + one re-clustering.

    The prefix (absorption, chain slotting, LUT pairing, cluster plan —
    see :mod:`repro.core.repack`) depends only on the netlist and the
    seed; the clustering stage consumes the structural arch knobs.  A
    design-space sweep over cluster geometry computes the prefix once
    per circuit and replays only the clustering per structural class."""
    from .repack import pack_prefix, repack

    return repack(pack_prefix(net, seed=seed), arch,
                  allow_unrelated=allow_unrelated,
                  strict_phases=strict_phases, pull_runs=pull_runs)


def _fanout_counts(net: Netlist) -> dict[int, int]:
    fanout: dict[int, int] = defaultdict(int)
    for ins in net.lut_inputs:
        for s in ins:
            fanout[s] += 1
    for ch in net.chains:
        for s in list(ch.a) + list(ch.b):
            fanout[s] += 1
        if ch.cin > CONST1:
            fanout[ch.cin] += 1
    for bus in net.pos.values():
        for s in bus:
            fanout[s] += 1
    return fanout


def _pair_luts(net: Netlist, free_luts: list[int], rng):
    """Pair LUTs into ALM-sized groups by shared-input affinity."""
    # per-LUT input sets/arities hoisted out of the greedy loops: can_pair
    # and the affinity score used to rebuild both sets on every probe,
    # which dominated the pass on large circuits.  Decisions (and
    # therefore the output) are unchanged — only the set construction
    # moved.
    in_set: dict[int, frozenset] = {
        li: frozenset(net.lut_inputs[li]) for li in free_luts}
    arity: dict[int, int] = {li: len(in_set[li]) for li in free_luts}
    by_sig: dict[int, list[int]] = defaultdict(list)
    for li in free_luts:
        for s in net.lut_inputs[li]:
            by_sig[s].append(li)
    unpaired = set(free_luts)
    pairs: list[tuple[int, int]] = []
    singles6: list[int] = []
    singles5: list[int] = []

    def can_pair(a: int, b: int) -> bool:
        ia, ib = in_set[a], in_set[b]
        ka, kb = arity[a], arity[b]
        if ka > 5 or kb > 5:
            return False
        shared = len(ia & ib)
        if ka + kb - shared > 8:
            return False
        if ka == 5 and kb == 5 and shared < 2:
            return False
        return True

    order = sorted(free_luts, key=lambda li: -len(net.lut_inputs[li]))
    for li in order:
        if li not in unpaired:
            continue
        k = len(net.lut_inputs[li])
        if k >= 6:
            unpaired.discard(li)
            singles6.append(li)
            continue
        # candidate partners sharing a signal
        best = None
        best_score = -1
        seen = set()
        ia = in_set[li]
        for s in net.lut_inputs[li]:
            for lj in by_sig[s]:
                if lj == li or lj not in unpaired or lj in seen:
                    continue
                seen.add(lj)
                if can_pair(li, lj):
                    score = len(ia & in_set[lj])
                    if score > best_score:
                        best_score, best = score, lj
        if best is None:
            # fall back: any unpaired small LUT
            for lj in unpaired:
                if lj != li and can_pair(li, lj):
                    best = lj
                    break
        if best is not None:
            unpaired.discard(li)
            unpaired.discard(best)
            pairs.append((li, best))
        else:
            unpaired.discard(li)
            singles5.append(li)
    return pairs, singles6, singles5


# ---------------------------------------------------------------------------
# clustering
# ---------------------------------------------------------------------------


class _LBState:
    def __init__(self, arch: ArchParams):
        self.arch = arch
        self.alm_ids: list[int] = []
        self.produced: set[int] = set()
        self.ext_in: set[int] = set()
        self.ext_out_capacity = arch.output_budget
        self.z_ext: set[int] = set()
        # arith ALMs with hostable halves, in placement order (the
        # hosting scans' first-fit order); pruned lazily as halves fill
        self.hostable: list[int] = []
        self.alm_pos: dict[int, int] = {}

    def n_alms(self) -> int:
        return len(self.alm_ids)

    def fits_inputs(self, new_in: set[int], new_z_ext: set[int]) -> bool:
        # membership counting instead of set algebra: add() keeps
        # ext_in ∩ produced = ∅, so |(ext_in ∪ new_in) − produced| is
        # |ext_in| plus the new signals not already external or local
        ext_in, produced = self.ext_in, self.produced
        tot_in = len(ext_in)
        for s in new_in:
            if s not in ext_in and s not in produced:
                tot_in += 1
        if tot_in > self.arch.input_budget:
            return False
        z_ext = self.z_ext
        tot_z = len(z_ext)
        for s in new_z_ext:
            if s not in z_ext:
                tot_z += 1
        if tot_z > self.arch.z_sources:
            return False
        return True

    def add(self, new_in: set[int], new_prod: set[int], new_z_ext: set[int]):
        self.ext_in |= new_in
        self.produced |= new_prod
        self.ext_in -= self.produced
        self.z_ext |= new_z_ext


@dataclass
class ClusterPlan:
    """Arch-invariant clustering inputs, computed once per (net, seed).

    Everything here depends only on the netlist, the chain-slotted ALM
    skeleton and the pairing RNG — never on cluster geometry — so a
    structural-axis sweep builds one plan per circuit and replays
    :func:`_cluster` under each grid point's LB budgets.
    """

    # Atom = ("run", chain_idx) | ("pair", a, b) | ("single6"/"single5", li)
    atoms: list[tuple]
    run_order: list[int]                  # connectivity-greedy chain order
    lut_order: list[int]                  # seeded shuffle of LUT atoms
    #: per skeleton-ALM (ah, z, prod) at placement time — ALMs are only
    #: mutated *after* they are placed, so these are arch-invariant
    skeleton_io: list[tuple[set[int], set[int], set[int]]]
    #: per atom, the (ah, z, prod) of its materialized logic ALM
    #: (``None`` for chain runs)
    atom_io: list[tuple[set[int], set[int], set[int]] | None]
    #: per atom, its frontier-bump targets as (neighbor, shared-signal
    #: count) pairs, ordered by first occurrence in the legacy
    #: signal-set x sig2atoms iteration (ties in the greedy pull are
    #: broken by first-seen order, so the order is semantic)
    atom_neighbors: list[list[tuple[int, int]]]
    #: per (chain, bit), the live (> CONST1) FA operand signals
    bit_live: dict[tuple[int, int], list[int]]
    #: per LUT atom, its candidate-LB probes in legacy order:
    #: (0, sig) — LB producing ``sig``; (1, alm) — LB of the (fixed,
    #: skeleton) ALM of a consuming chain bit; (2, lut) — LB hosting a
    #: consuming LUT (dynamic).  Empty for chain runs.
    atom_cand_ops: list[list[tuple[int, int]]]

    # --- vectorized replay columns (consumed when VECTOR_CLUSTER) --------
    #: CSR image of ``atom_cand_ops`` — one gather resolves a whole probe
    #: sequence instead of a Python loop per op
    cand_ptr: np.ndarray | None = None
    cand_code: np.ndarray | None = None
    cand_payload: np.ndarray | None = None
    #: CSR image of ``atom_neighbors`` for the batched frontier bump
    nbr_ptr: np.ndarray | None = None
    nbr_j: np.ndarray | None = None
    nbr_cnt: np.ndarray | None = None
    #: per LUT atom, its live A-H inputs sorted (int32; ``None`` for runs)
    atom_ah_arr: list | None = None
    #: per skeleton ALM: host-feasibility columns for the batched hosting
    #: prefilter — free-half count, per hosted-LUT-count variant (1 or 2)
    #: the max live-operand count over converted halves and the distinct
    #: moved-signal count, the A-H set size and its sorted padded image.
    #: Arch-invariant for the *unmutated* skeleton; ``_cluster`` copies
    #: them and refreshes single rows as hosting mutates ALMs.
    skel_fh: np.ndarray | None = None
    skel_need: np.ndarray | None = None
    skel_moved: np.ndarray | None = None
    skel_ah_len: np.ndarray | None = None
    skel_ah_pad: np.ndarray | None = None

    # --- incremental-repack ownership columns (delta plans only) ---------
    #: per atom, the LB that owned it in the *base* pack the delta plan
    #: was derived from (-1 for unknown/new atoms), and per atom the LBs
    #: the base greedy consulted while placing it (its decision
    #: dependencies).  Filled by ``repack.pack_prefix_delta`` from the
    #: base decision log; ``None`` on plans built fresh — fresh plans are
    #: shared across archs and ownership is arch-specific.
    atom_owner_lb: np.ndarray | None = None
    atom_dep_lbs: list | None = None


def _fill_host_cols(ai, alm, bit_live, ah_set, col_fh, col_need, col_moved,
                    col_ah_len, col_ah_pad) -> None:
    """(Re)compute one arith ALM's host-feasibility row.

    Shares the half-selection logic of ``_cluster``'s ``free_halves_of``
    (hostable halves, Z-free first, stable) so the columns predict the
    scalar scan's decisions exactly.  A 6-LUT span zeroes the free-half
    count — the scan prunes on that, covering the legacy ``lut6`` pop."""
    fh = []
    for h in alm.halves:
        if h.hosted_lut is not None:
            continue
        if h.fa is None:
            fh.append((h, False))
        elif not h.absorbed:
            fh.append((h, True))
    fh.sort(key=lambda x: x[1])
    col_fh[ai] = 0 if alm.lut6 is not None else len(fh)
    for k in (1, 2):
        conv_need = 0
        moved: set[int] = set()
        for h, needs_z in fh[:k]:
            if needs_z:
                live = bit_live[h.fa]
                if len(live) > conv_need:
                    conv_need = len(live)
                moved.update(live)
        col_need[ai, k - 1] = conv_need
        col_moved[ai, k - 1] = len(moved)
    col_ah_len[ai] = len(ah_set)
    col_ah_pad[ai, :] = _SENT32
    if len(ah_set) <= _AH_CAP:
        srt = sorted(ah_set)
        col_ah_pad[ai, : len(srt)] = srt


def _atom_sigs_of(net, atom) -> set[int]:
    """Live signal set of one atom — the connectivity currency of the
    plan (frontier counts, probe targets).  Insertion order is part of
    the plan contract: neighbor rows inherit it, so the delta-prefix
    path must build rows with exactly this sequence."""
    kind = atom[0]
    sigs: set[int] = set()
    if kind == "run":
        ci = atom[1]
        ch = net.chains[ci]
        for s in list(ch.a) + list(ch.b) + list(ch.sums):
            if s > CONST1:
                sigs.add(s)
    else:
        for li in atom[1:]:
            if isinstance(li, int):
                sigs.update(s for s in net.lut_inputs[li] if s > CONST1)
                sigs.add(net.lut_out[li])
    return sigs


def _build_cluster_plan(net, alms, chain_alm_runs, chain_site, pairs,
                        singles6, singles5, rng) -> ClusterPlan:
    """Build the :class:`ClusterPlan` — the atom list, connectivity
    indexes, placement orders and placement-time IO sets
    :func:`_cluster` consumes.  Must draw from ``rng`` exactly as the
    pre-refactor ``_cluster`` did (one shuffle of the LUT atoms) so
    packs stay byte-stable."""
    atoms: list[tuple] = []
    for ci, run in enumerate(chain_alm_runs):
        if run:
            atoms.append(("run", ci))
    for a, b in pairs:
        atoms.append(("pair", a, b))
    for li in singles6:
        atoms.append(("single6", li))
    for li in singles5:
        atoms.append(("single5", li))

    atom_sigs = [_atom_sigs_of(net, a) for a in atoms]

    # connectivity index
    sig2atoms: dict[int, list[int]] = defaultdict(list)
    for idx in range(len(atoms)):
        for s in atom_sigs[idx]:
            sig2atoms[s].append(idx)

    # consumer index: signal -> consuming sites (chain bits and luts)
    sig_consumers: dict[int, list[tuple]] = defaultdict(list)
    for li in range(net.n_luts):
        for s in net.lut_inputs[li]:
            if s > CONST1:
                sig_consumers[s].append(("lut", li))
    for ci, ch in enumerate(net.chains):
        for bi in range(len(ch.sums)):
            for s in (ch.a[bi], ch.b[bi]):
                if s > CONST1:
                    sig_consumers[s].append(("chain", ci, bi))

    # Chain runs are placed in *connectivity order*: start from the largest
    # run, then repeatedly take the unplaced run sharing the most signals
    # with what is already placed.  Consumer chains land next to their
    # producers, so Z conversions ride the free local/direct-link taps.
    run_idxs = [i for i, a in enumerate(atoms) if a[0] == "run"]
    run_order: list[int] = []
    if run_idxs:
        remaining = set(run_idxs)
        overlap: dict[int, int] = {i: 0 for i in run_idxs}
        sig2runs: dict[int, list[int]] = defaultdict(list)
        for i in run_idxs:
            for s in atom_sigs[i]:
                sig2runs[s].append(i)
        first = max(remaining, key=lambda i: len(chain_alm_runs[atoms[i][1]]))
        run_order.append(first)
        remaining.discard(first)
        for s in atom_sigs[first]:
            for j in sig2runs[s]:
                if j in remaining:
                    overlap[j] += 1
        while remaining:
            nxt = max(remaining,
                      key=lambda i: (overlap[i],
                                     len(chain_alm_runs[atoms[i][1]])))
            run_order.append(nxt)
            remaining.discard(nxt)
            for s in atom_sigs[nxt]:
                for j in sig2runs[s]:
                    if j in remaining:
                        overlap[j] += 1
    lut_order = [i for i, a in enumerate(atoms) if a[0] != "run"]
    rng.shuffle(lut_order)

    # placement-time IO sets: the skeleton ALMs (and the logic ALMs the
    # LUT atoms materialize) are queried by the clusterer only *before*
    # their first mutation, so their (ah, z, prod) never depends on the
    # architecture — computing them here keeps the greedy replay off the
    # ``input_signals`` object walk entirely
    skeleton_io = [(alm.input_signals(net) + (alm.output_signals(net),))
                   for alm in alms]

    def logic_atom_io(atom):
        if atom[0] == "run":
            return None
        ah: set[int] = set()
        prod: set[int] = set()
        for li in atom[1:]:
            ah.update(s for s in net.lut_inputs[li] if s > CONST1)
            prod.add(net.lut_out[li])
        return (ah, set(), prod)

    atom_io = [logic_atom_io(a) for a in atoms]

    # frontier-bump targets aggregated to (neighbor, count), first
    # occurrence following the legacy (signal-set order x sig2atoms
    # order) flattening — a bump is atomic between placements, so one
    # +count increment replays the legacy per-signal +1 sequence exactly
    atom_neighbors: list[list[tuple[int, int]]] = []
    for i in range(len(atoms)):
        agg: dict[int, int] = {}
        for s in atom_sigs[i]:
            for j in sig2atoms[s]:
                agg[j] = agg.get(j, 0) + 1
        atom_neighbors.append(list(agg.items()))

    bit_live = {(ci, bi): [s for s in (ch.a[bi], ch.b[bi]) if s > CONST1]
                for ci, ch in enumerate(net.chains)
                for bi in range(len(ch.sums))}

    # candidate-LB probe sequences: producer lookups and consumer sites
    # flattened per atom in the legacy per-LUT order; chain-bit consumer
    # sites resolve to *fixed* skeleton ALM indices already here
    atom_cand_ops: list[list[tuple[int, int]]] = []
    for atom in atoms:
        ops: list[tuple[int, int]] = []
        if atom[0] != "run":
            for li in atom[1:]:
                if isinstance(li, int):
                    for s in net.lut_inputs[li]:
                        ops.append((0, s))
                    for cons in sig_consumers.get(net.lut_out[li], ()):
                        if cons[0] == "chain":
                            ops.append((1, chain_site[(cons[1], cons[2])]))
                        else:
                            ops.append((2, cons[1]))
        atom_cand_ops.append(ops)

    # vectorized replay columns: CSR images of the probe/neighbor lists,
    # per-atom sorted A-H arrays and the skeleton host-feasibility rows
    n_atoms = len(atoms)
    cand_ptr = np.zeros(n_atoms + 1, np.int64)
    code_l: list[int] = []
    pay_l: list[int] = []
    for i, ops in enumerate(atom_cand_ops):
        cand_ptr[i + 1] = cand_ptr[i] + len(ops)
        for op, payload in ops:
            code_l.append(op)
            pay_l.append(payload)
    nbr_ptr = np.zeros(n_atoms + 1, np.int64)
    nj_l: list[int] = []
    nc_l: list[int] = []
    for i, nbrs in enumerate(atom_neighbors):
        nbr_ptr[i + 1] = nbr_ptr[i] + len(nbrs)
        for j, cnt in nbrs:
            nj_l.append(j)
            nc_l.append(cnt)
    atom_ah_arr = [None if io is None else np.array(sorted(io[0]), np.int32)
                   for io in atom_io]
    n_skel = len(alms)
    skel_fh = np.zeros(n_skel, np.int16)
    skel_need = np.zeros((n_skel, 2), np.int16)
    skel_moved = np.zeros((n_skel, 2), np.int16)
    skel_ah_len = np.zeros(n_skel, np.int32)
    skel_ah_pad = np.full((n_skel, _AH_CAP), _SENT32, np.int32)
    for ai, alm in enumerate(alms):
        _fill_host_cols(ai, alm, bit_live, skeleton_io[ai][0], skel_fh,
                        skel_need, skel_moved, skel_ah_len, skel_ah_pad)

    # atom_sigs / sig2atoms / sig_consumers are construction scaffolding:
    # everything the clusterer replays is baked into the orders, the
    # neighbor counts and the probe sequences, so the retained plan (it
    # lives as long as a sweep's prefix cache) stays slim
    return ClusterPlan(atoms=atoms, run_order=run_order,
                       lut_order=lut_order, skeleton_io=skeleton_io,
                       atom_io=atom_io, atom_neighbors=atom_neighbors,
                       bit_live=bit_live, atom_cand_ops=atom_cand_ops,
                       cand_ptr=cand_ptr,
                       cand_code=np.array(code_l, np.int8),
                       cand_payload=np.array(pay_l, np.int64),
                       nbr_ptr=nbr_ptr, nbr_j=np.array(nj_l, np.int64),
                       nbr_cnt=np.array(nc_l, np.int64),
                       atom_ah_arr=atom_ah_arr, skel_fh=skel_fh,
                       skel_need=skel_need, skel_moved=skel_moved,
                       skel_ah_len=skel_ah_len, skel_ah_pad=skel_ah_pad)


def _cluster(net, arch, alms, chain_alm_runs, plan: ClusterPlan,
             chain_site, lut_site, allow_unrelated=True,
             strict_phases=(True, False), pull_runs=True, replay=None):
    atoms = plan.atoms
    n_atoms = len(atoms)
    vector = VECTOR_CLUSTER and plan.cand_ptr is not None
    # The numpy replay paths each clear a profiled break-even before they
    # replace the tuned scalar loops (numpy dispatch loses below ~50
    # elements): the CSR probe gather and the batched frontier bump
    # engage per plan by mean list degree; the batched host mask engages
    # per probe by candidate count (_MASK_MIN_ALMS).  Every path is exact
    # — the A/B tests prove byte-identity in all four combinations.
    vector_gather = (vector and plan.cand_payload.size
                     >= _VEC_MIN_DEGREE * max(len(plan.lut_order), 1))
    vector_bump = (vector
                   and plan.nbr_j.size >= _VEC_MIN_DEGREE * n_atoms)

    placed = (np.zeros(n_atoms, dtype=bool) if vector_bump
              else [False] * n_atoms)
    lbs_state: list[_LBState] = []
    lb_list: list[LB] = []
    alm_lb: list[int] = [-1] * len(alms)
    concurrent = 0

    if vector:
        # runtime copies of the skeleton host-feasibility rows, refreshed
        # per ALM (lazily) as hosting mutates it — the batched host mask
        # gathers from these
        n_skel = len(plan.skeleton_io)
        col_fh = plan.skel_fh.copy()
        col_need = plan.skel_need.copy()
        col_moved = plan.skel_moved.copy()
        col_ah_len = plan.skel_ah_len.copy()
        col_ah_pad = plan.skel_ah_pad.copy()
    if vector_gather:
        # flat site/LB mirrors so a probe sequence resolves as one gather
        cand_ptr, cand_code = plan.cand_ptr, plan.cand_code
        cand_payload = plan.cand_payload
        lut_site_arr = np.full(net.n_luts, -1, np.int64)
        for _li, _ai in lut_site.items():
            lut_site_arr[_li] = _ai
        # capacity bound: clustering materializes at most one ALM per atom
        alm_lb_arr = np.full(len(alms) + n_atoms + 1, -1, np.int64)

    # host rows invalidated by a mutation, refreshed lazily on the next
    # scan that reads them (mirrors the alm_io/free_halves discipline —
    # an ALM hosted once and never rescanned costs nothing)
    cols_dirty: set[int] = set()

    def _refresh_host_cols(ai: int) -> None:
        cols_dirty.discard(ai)
        _fill_host_cols(ai, alms[ai], plan.bit_live, alm_io(ai)[0], col_fh,
                        col_need, col_moved, col_ah_len, col_ah_pad)

    # (ah, z, prod) per ALM — seeded from the plan's arch-invariant
    # placement-time sets, recomputed lazily after a mutation (hosting,
    # Z conversion) invalidates an entry.  Callers must treat the sets
    # as read-only (they may be shared across re-clusterings).
    alm_io_cache: dict[int, tuple] = dict(enumerate(plan.skeleton_io))
    # hostable halves per arith ALM, same invalidation discipline
    free_halves_cache: dict[int, list] = {}

    def alm_io(ai: int):
        r = alm_io_cache.get(ai)
        if r is None:
            ah, z = alms[ai].input_signals(net)
            prod = alms[ai].output_signals(net)
            r = (ah, z, prod)
            alm_io_cache[ai] = r
        return r

    def open_lb() -> int:
        lbs_state.append(_LBState(arch))
        lb_list.append(LB())
        return len(lbs_state) - 1

    # signal -> producing ALM (or -1); an ndarray when gathering so the
    # probe gather can fancy-index it (scalar reads/writes are identical)
    prod_site = (np.full(net.n_signals, -1, np.int64) if vector_gather
                 else [-1] * net.n_signals)
    host_capacity_lbs: set[int] = set()

    def _has_free_half(alm: ALM) -> bool:
        if not alm.is_arith or alm.lut6 is not None:
            return False
        for h in alm.halves:
            if h.hosted_lut is None and (h.fa is None or not h.absorbed):
                return True
        return False

    def place_alm(ai: int, lb_idx: int):
        st = lbs_state[lb_idx]
        ah, z, prod = alm_io(ai)
        z_ext = z - st.produced if arch.z_local_free else set(z)
        st.add(ah | z, prod, z_ext)
        st.alm_pos[ai] = len(st.alm_ids)
        st.alm_ids.append(ai)
        lb_list[lb_idx].alms.append(ai)
        alm_lb[ai] = lb_idx
        if vector_gather:
            alm_lb_arr[ai] = lb_idx
        for s in prod:
            prod_site[s] = ai
        if _has_free_half(alms[ai]):
            st.hostable.append(ai)
            if arch.concurrent:
                host_capacity_lbs.add(lb_idx)

    def try_fit_alm(ai: int, lb_idx: int) -> bool:
        st = lbs_state[lb_idx]
        if st.n_alms() >= arch.alms_per_lb:
            return False
        ah, z, prod = alm_io(ai)
        z_ext = z - st.produced if arch.z_local_free else set(z)
        return st.fits_inputs((ah | z) - prod, z_ext)

    # --- concurrent hosting helpers (DD only) ------------------------------
    def host_in_arith(lut_list: list[int], lb_idx: int,
                      strict_z: bool = False, ok_mask=None) -> bool:
        """Try to host LUT(s) in free/convertible halves of arith ALMs.

        A pair is first attempted in one ALM (shared A-H pins), then split
        across two ALMs of the same LB.  With ``strict_z`` only placements
        that add no *new* external AddMux-crossbar source are accepted
        (operands local to the LB or already-routed Z signals).
        ``ok_mask`` is the batched ALM-level prefilter and describes the
        *whole* atom — the split replays per-LUT A-H sets after a state
        commit, so it always runs the exact scan.
        """
        if len(lut_list) == 2:
            if _host_in_one_alm(lut_list, lb_idx, strict_z, ok_mask):
                return True
            st = lbs_state[lb_idx]
            # split: both halves must fit or neither (transactional)
            snapshot = (set(st.ext_in), set(st.produced), set(st.z_ext))
            if _host_in_one_alm([lut_list[0]], lb_idx, strict_z):
                if _host_in_one_alm([lut_list[1]], lb_idx, strict_z):
                    return True
                _unhost(lut_list[0], lb_idx, snapshot)
            return False
        return _host_in_one_alm(lut_list, lb_idx, strict_z, ok_mask)

    def _unhost(li: int, lb_idx: int, snapshot):
        nonlocal concurrent
        st = lbs_state[lb_idx]
        ai = lut_site.pop(li)
        alm_io_cache.pop(ai, None)
        free_halves_cache.pop(ai, None)
        for h in alms[ai].halves:
            if h.hosted_lut == li:
                h.hosted_lut = None
                if h.fa is not None and h.fa_feed == "z":
                    h.fa_feed = "lut"
                    concurrent -= 1
        st.ext_in, st.produced, st.z_ext = snapshot
        if vector:
            cols_dirty.add(ai)
        if vector_gather:
            lut_site_arr[li] = -1
        # the ALM regained hostable halves; restore it at its placement-
        # order slot if a scan pruned it while its halves were full
        if ai not in st.hostable:
            pos = st.alm_pos[ai]
            idx = 0
            while (idx < len(st.hostable)
                   and st.alm_pos[st.hostable[idx]] < pos):
                idx += 1
            st.hostable.insert(idx, ai)
            if replay is not None:
                replay.ev_ins(lb_idx, ai)

    def free_halves_of(ai: int) -> list:
        """Hostable halves of an arith ALM (Z-free first) — cached, with
        the same invalidation points as ``alm_io_cache``."""
        fh = free_halves_cache.get(ai)
        if fh is None:
            fh = []
            for h in alms[ai].halves:
                if h.hosted_lut is not None:
                    continue
                if h.fa is None:
                    fh.append((h, False))   # no Z needed
                elif not h.absorbed:
                    fh.append((h, True))    # needs Z conversion
            fh.sort(key=lambda x: x[1])     # prefer Z-free halves
            free_halves_cache[ai] = fh
        return fh

    def _host_mask(ids: list[int], k: int, atom_ah) -> dict:
        """Batched image of the scan's per-ALM rejections (free halves,
        bypass width, 8-pin budget) over every hostable ALM of the probed
        LBs.  Exact: ``|new_ah| = |ah ∪ atom_ah| - |moved|`` because a
        convertible half's live operands are always A-H-routed before
        conversion (``moved ⊆ ah``); rows whose A-H set overflows
        ``_AH_CAP`` reject unconditionally (see the cap's invariant)."""
        if cols_dirty:
            for ai in ids:
                if ai in cols_dirty:
                    _refresh_host_cols(ai)
        cand = np.array(ids, np.int64)
        fh = col_fh[cand]
        need = col_need[cand, k - 1]
        moved = col_moved[cand, k - 1].astype(np.int64)
        lens = col_ah_len[cand].astype(np.int64)
        mat = np.empty((cand.size, _AH_CAP + atom_ah.size), np.int32)
        mat[:, :_AH_CAP] = col_ah_pad[cand]
        if atom_ah.size:
            mat[:, _AH_CAP:] = atom_ah
        mat.sort(axis=1)
        nonpad = mat != _SENT32
        uniq = ((mat[:, 1:] != mat[:, :-1]) & nonpad[:, 1:]).sum(axis=1) \
            + nonpad[:, 0]
        new_ah = np.where(lens <= _AH_CAP, uniq, lens) - moved
        rej = (fh < k) | (need > arch.bypass_inputs) | (new_ah > 8)
        return dict(zip(ids, (~rej).tolist()))

    def _host_in_one_alm(lut_list: list[int], lb_idx: int,
                         strict_z: bool = False, ok_mask=None) -> bool:
        nonlocal concurrent
        if not (arch.concurrent and allow_unrelated):
            return False
        dbg = LAST_PACK_DEBUG
        dbg["host_calls"] = dbg.get("host_calls", 0) + 1
        st = lbs_state[lb_idx]
        hostable = st.hostable
        i = 0
        while i < len(hostable):
            ai = hostable[i]
            alm = alms[ai]
            if alm.lut6 is not None:
                hostable.pop(i)       # 6-LUT span: never hostable again
                if replay is not None:
                    replay.ev_pop(lb_idx, ai)
                continue
            free_halves = free_halves_of(ai)
            if not free_halves:
                hostable.pop(i)       # filled up; prune (order preserved)
                if replay is not None:
                    replay.ev_pop(lb_idx, ai)
                continue
            i += 1
            if ok_mask is not None and not ok_mask.get(ai, True):
                # the batched mask already proved an ALM-level rejection
                # (free halves / bypass width / 8-pin budget) — skip the
                # per-ALM set builds; survivors re-derive them below
                continue
            if len(free_halves) < len(lut_list):
                dbg["rej_nofree"] = dbg.get("rej_nofree", 0) + 1
                continue
            # input budget at ALM level: all residents' A-H pins <= 8
            ah, z, _ = alm_io(ai)
            new_ah = set(ah)
            for li in lut_list:
                new_ah.update(s for s in net.lut_inputs[li] if s > CONST1)
            # halves being converted move their FA operands to Z; a half
            # whose bit has more live operands than the arch has bypass
            # inputs cannot be converted at all
            conv = [fh for fh in free_halves[: len(lut_list)] if fh[1]]
            moved_z: set[int] = set()
            over_bypass = False
            for h, _ in conv:
                live = plan.bit_live[h.fa]
                if len(live) > arch.bypass_inputs:
                    over_bypass = True
                    break
                for s in live:
                    moved_z.add(s)
                    new_ah.discard(s)
            if over_bypass:
                dbg["rej_bypass"] = dbg.get("rej_bypass", 0) + 1
                continue
            if len(new_ah) > 8:
                dbg["rej_pin8"] = dbg.get("rej_pin8", 0) + 1
                continue
            z_ext = (moved_z | z) - st.produced if arch.z_local_free else (moved_z | z)
            if strict_z and (z_ext - st.z_ext):
                dbg["rej_strictz"] = dbg.get("rej_strictz", 0) + 1
                continue
            if len(st.z_ext | z_ext) > arch.z_sources:
                dbg["rej_zbud"] = dbg.get("rej_zbud", 0) + 1
                continue
            new_in = set(new_ah) | moved_z
            if not st.fits_inputs(new_in - st.produced, z_ext):
                dbg["rej_lbin"] = dbg.get("rej_lbin", 0) + 1
                continue
            # commit
            alm_io_cache.pop(ai, None)
            free_halves_cache.pop(ai, None)
            for li, (h, needs_z) in zip(lut_list, free_halves):
                h.hosted_lut = li
                lut_site[li] = ai
                if vector_gather:
                    lut_site_arr[li] = ai
                if needs_z:
                    h.fa_feed = "z"
                if h.fa is not None:
                    concurrent += 1
            new_prod = {net.lut_out[li] for li in lut_list}
            st.add(new_in, new_prod, z_ext)
            if vector:
                cols_dirty.add(ai)
            return True
        if not hostable:
            host_capacity_lbs.discard(lb_idx)
            if replay is not None:
                replay.ev_capd(lb_idx)
        return False

    def host6_in_arith(li: int, lb_idx: int) -> bool:
        nonlocal concurrent
        if not (arch.concurrent_6lut and allow_unrelated):
            return False
        st = lbs_state[lb_idx]
        for ai in st.alm_ids:
            alm = alms[ai]
            if not alm.is_arith or alm.lut6 is not None:
                continue
            if any(h.hosted_lut is not None or h.absorbed for h in alm.halves):
                continue
            moved_z: set[int] = set()
            over_bypass = False
            for h in alm.halves:
                if h.fa is not None:
                    live = plan.bit_live[h.fa]
                    if len(live) > arch.bypass_inputs:
                        over_bypass = True
                        break
                    moved_z.update(live)
            if over_bypass:
                continue
            new_ah = {s for s in net.lut_inputs[li] if s > CONST1}
            if len(new_ah) > 8:
                continue
            z_ext = moved_z - st.produced if arch.z_local_free else set(moved_z)
            if len(st.z_ext | z_ext) > arch.z_sources:
                continue
            new_in = new_ah | moved_z
            if not st.fits_inputs(new_in - st.produced, z_ext):
                continue
            alm_io_cache.pop(ai, None)
            free_halves_cache.pop(ai, None)
            alm.lut6 = li
            lut_site[li] = ai
            if vector_gather:
                lut_site_arr[li] = ai
            for h in alm.halves:
                if h.fa is not None:
                    h.fa_feed = "z"
                    concurrent += 1
            st.add(new_in, {net.lut_out[li]}, z_ext)
            if vector:
                cols_dirty.add(ai)
            return True
        return False

    def materialize_logic_alm(aidx: int) -> int:
        atom = atoms[aidx]
        kind = atom[0]
        if kind == "pair":
            a, b = atom[1], atom[2]
            alm = ALM(halves=(Half(hosted_lut=a), Half(hosted_lut=b)))
            ai = len(alms)
            alms.append(alm)
            alm_lb.append(-1)
            alm_io_cache[ai] = plan.atom_io[aidx]
            lut_site[a] = ai
            lut_site[b] = ai
            if vector_gather:
                lut_site_arr[a] = ai
                lut_site_arr[b] = ai
            return ai
        if kind == "single6":
            alm = ALM(halves=(Half(), Half()), lut6=atom[1])
        else:
            alm = ALM(halves=(Half(hosted_lut=atom[1]), Half()))
        ai = len(alms)
        alms.append(alm)
        alm_lb.append(-1)
        alm_io_cache[ai] = plan.atom_io[aidx]
        lut_site[atom[1]] = ai
        if vector_gather:
            lut_site_arr[atom[1]] = ai
        return ai

    # --- main greedy loop ---------------------------------------------------
    # Atom orders come precomputed from the plan: chain runs in
    # connectivity order, LUT atoms in the seeded shuffle.  The frontier
    # is a lazy max-heap over (score, first-seen order): the legacy dict
    # scan picked the earliest-inserted atom among the max scores, and
    # (-score, seen, atom) heap entries reproduce exactly that winner —
    # stale entries (superseded scores, placed atoms) pop through.
    # Scores/first-seen live in flat lists (atom-indexed) — the bump
    # loop is the hottest spot of a re-clustering.
    frontier_heap: list[tuple[int, int, int]] = []
    n_seen = 0
    eligible = [pull_runs or a[0] != "run" for a in atoms]
    heappush = heapq.heappush

    if vector_bump:
        # batched bump: one CSR slice per placement updates every
        # neighbor's score, assigns first-seen ranks in CSR (= legacy
        # flattening) order, and pushes the eligible survivors.  Scores
        # only ever grow, so each pushed entry carries the neighbor's
        # final score for this bump — exactly the legacy push sequence.
        frontier_scores = np.zeros(n_atoms, np.int64)
        frontier_seen = np.full(n_atoms, -1, np.int64)
        eligible_arr = np.array(eligible, dtype=bool)
        nbr_ptr, nbr_j, nbr_cnt = plan.nbr_ptr, plan.nbr_j, plan.nbr_cnt

        def bump_frontier(src_aidx: int):
            nonlocal n_seen
            lo, hi = nbr_ptr[src_aidx], nbr_ptr[src_aidx + 1]
            if hi == lo:
                return
            js = nbr_j[lo:hi]
            m = ~placed[js]
            if not m.any():
                return
            js = js[m]
            frontier_scores[js] += nbr_cnt[lo:hi][m]
            new = frontier_seen[js] < 0
            if new.any():
                idxs = js[new]
                frontier_seen[idxs] = n_seen + np.arange(idxs.size)
                n_seen += int(idxs.size)
            el = js[eligible_arr[js]]
            for v, seq, j in zip(frontier_scores[el].tolist(),
                                 frontier_seen[el].tolist(), el.tolist()):
                heappush(frontier_heap, (-v, seq, j))
    else:
        frontier_scores = [0] * n_atoms
        frontier_seen = [-1] * n_atoms

        def bump_frontier(src_aidx: int):
            nonlocal n_seen
            for j, cnt in plan.atom_neighbors[src_aidx]:
                if placed[j]:
                    continue
                v = frontier_scores[j] + cnt
                frontier_scores[j] = v
                seq = frontier_seen[j]
                if seq < 0:
                    seq = n_seen
                    frontier_seen[j] = seq
                    n_seen += 1
                if eligible[j]:
                    heappush(frontier_heap, (-v, seq, j))

    def place_atom(aidx: int, lb_idx: int | None) -> int | None:
        """Place atom; returns the (possibly new) current LB index."""
        atom = atoms[aidx]
        kind = atom[0]
        # The replay log shadows the greedy loop without steering it: in
        # record mode start_atom opens a step and adv_skips stays None; in
        # advise mode it returns the base run's consulted-but-rejected LBs
        # for this atom when the step is provably in sync (same atom order,
        # no diverged state touched) — those scans are skipped and their
        # recorded side effects (hostable prunes/reinserts, capacity-set
        # discards) applied verbatim, so every *executed* scan sees exactly
        # the state a fresh pack would.
        adv_skips = replay.start_atom(aidx) if replay is not None else None
        if kind == "run":
            ci = atom[1]
            tgts: list[int] = []
            for ai in chain_alm_runs[ci]:
                tgt = lb_idx
                if tgt is None or not try_fit_alm(ai, tgt):
                    # chains may spill into a fresh LB mid-run
                    tgt = open_lb()
                    if not try_fit_alm(ai, tgt):
                        # pathological (budget smaller than one ALM) — force
                        pass
                place_alm(ai, tgt)
                lb_idx = tgt
                tgts.append(tgt)
            placed[aidx] = True
            bump_frontier(aidx)
            if replay is not None:
                replay.note_atom(aidx, tuple(tgts), lb_idx, len(lbs_state))
            return lb_idx
        # LUT atoms: try concurrent hosting — connectivity-driven first
        # (current LB, then LBs producing this atom's inputs, then LBs
        # consuming its outputs), then VPR-style unrelated clustering over
        # any LB with spare arithmetic halves.  The probe sequence comes
        # precompiled from the plan (chain-bit consumer sites are fixed
        # skeleton ALMs); only the producer/hosting lookups are dynamic.
        cand_lbs: list[int] = []
        if lb_idx is not None:
            cand_lbs.append(lb_idx)
        if vector_gather:
            lo, hi = cand_ptr[aidx], cand_ptr[aidx + 1]
            if hi > lo:
                code = cand_code[lo:hi]
                pay = cand_payload[lo:hi]
                sites = np.empty(hi - lo, np.int64)
                m = code == 0
                sites[m] = prod_site[pay[m]]
                m = code == 1
                sites[m] = pay[m]
                m = code == 2
                sites[m] = lut_site_arr[pay[m]]
                lbs_arr = alm_lb_arr[sites[sites >= 0]]
                cand_lbs.extend(lbs_arr[lbs_arr >= 0].tolist())
        else:
            for op, payload in plan.atom_cand_ops[aidx]:
                if op == 0:
                    site = prod_site[payload]
                elif op == 1:
                    site = payload
                else:
                    site = lut_site.get(payload, -1)
                if site >= 0 and alm_lb[site] >= 0:
                    cand_lbs.append(alm_lb[site])
        n_conn = len(cand_lbs)
        if allow_unrelated and arch.concurrent:
            cand_lbs.extend(islice(host_capacity_lbs, 64))
        # Batched host-feasibility mask for the unrelated-clustering
        # fallback: the connectivity LBs (few, usually fruitful) run the
        # plain scan, but an atom that falls through them probes up to 64
        # spare-capacity LBs — one batched mask over all their hostable
        # ALMs replaces those per-ALM set walks.  Built lazily on the
        # first fallback probe; the state it snapshots cannot change
        # until a commit ends the placement, so it holds across LBs and
        # strict phases.
        ok_mask = None
        mask_built = kind == "single6" or not vector or adv_skips is not None
        for strict in strict_phases:
            seen_lb: set[int] = set()
            for pos, cand in enumerate(cand_lbs):
                if cand in seen_lb:
                    continue
                seen_lb.add(cand)
                if adv_skips is not None and adv_skips.try_skip(
                        cand, lbs_state, host_capacity_lbs):
                    # base run consulted this LB here and rejected it; its
                    # state is untouched by the edit, so the rejection (and
                    # the scan's pruning side effects) transfer verbatim
                    continue
                use_mask = None
                if pos >= n_conn:
                    if not mask_built:
                        mask_built = True
                        ids: list[int] = []
                        mseen: set[int] = set()
                        for lb2 in cand_lbs[n_conn:]:
                            if lb2 not in mseen:
                                mseen.add(lb2)
                                ids.extend(lbs_state[lb2].hostable)
                        if len(ids) >= _MASK_MIN_ALMS:
                            ok_mask = _host_mask(
                                ids, 2 if kind == "pair" else 1,
                                plan.atom_ah_arr[aidx])
                    use_mask = ok_mask
                if replay is not None:
                    replay.open_consult(cand)
                ok = False
                if kind == "pair":
                    ok = host_in_arith([atom[1], atom[2]], cand, strict,
                                       use_mask)
                elif kind == "single5":
                    ok = host_in_arith([atom[1]], cand, strict, use_mask)
                elif kind == "single6":
                    ok = host6_in_arith(atom[1], cand)
                if ok:
                    placed[aidx] = True
                    bump_frontier(aidx)
                    ret = lb_idx if lb_idx is not None else cand
                    if replay is not None:
                        replay.note_atom(aidx, (cand,), ret, len(lbs_state))
                    return ret
                if replay is not None:
                    replay.close_consult(cand)
        ai = materialize_logic_alm(aidx)
        tgt = lb_idx
        if tgt is None or not try_fit_alm(ai, tgt):
            # look for any LB with room before opening a new one
            tgt = None
            for cand in range(len(lbs_state) - 1, max(-1, len(lbs_state) - 9), -1):
                if try_fit_alm(ai, cand):
                    tgt = cand
                    break
            if tgt is None:
                tgt = open_lb()
        place_alm(ai, tgt)
        placed[aidx] = True
        bump_frontier(aidx)
        if replay is not None:
            replay.note_atom(aidx, (tgt,), tgt, len(lbs_state))
        return tgt

    cur_lb: int | None = None
    for aidx in plan.run_order:
        if placed[aidx]:
            continue
        cur_lb = place_atom(aidx, cur_lb)
        # pull in connected atoms (chains and LUTs) while there is room —
        # connectivity-ordered packing keeps chain operands local, which is
        # what lets Z pins ride the free direct-link taps.
        while True:
            cand = None
            while frontier_heap:
                negv, _, j = frontier_heap[0]
                if placed[j] or frontier_scores[j] != -negv:
                    heapq.heappop(frontier_heap)   # stale or already placed
                    continue
                cand = j
                break
            if cand is None or cur_lb is None:
                break
            before = len(lbs_state)
            cur_lb = place_atom(cand, cur_lb)
            if len(lbs_state) != before:
                break  # spilled into a new LB; go back to chain order

    for aidx in plan.lut_order:
        if not placed[aidx]:
            cur_lb = place_atom(aidx, cur_lb)

    # --- Z timing post-pass (DD only) -----------------------------------
    # Any raw-operand FA still fed through the (now slower) LUT path is
    # moved to the direct Z path when the AddMux budget allows: Table II
    # row 3 — Z->adder is 48 % faster than the baseline LUT route.  This is
    # why the paper's stress tests see *better* critical paths on DD5.
    if arch.concurrent:
        for lbi, st in enumerate(lbs_state):
            for ai in st.alm_ids:
                alm = alms[ai]
                if not alm.is_arith:
                    continue
                for h in alm.halves:
                    if (h.fa is None or h.fa_feed != "lut" or h.absorbed
                            or h.hosted_lut is not None):
                        continue
                    live = plan.bit_live[h.fa]
                    # each live operand *pin* needs its own bypass path,
                    # even when both pins carry the same signal
                    if len(live) > arch.bypass_inputs:
                        continue
                    ops = set(live)
                    z_ext = ops - st.produced if arch.z_local_free else ops
                    if len(st.z_ext | z_ext) > arch.z_sources:
                        continue
                    h.fa_feed = "z"
                    st.z_ext |= z_ext

    return PackedCircuit(
        net=net, arch=arch, alms=alms, lbs=lb_list, lut_site=lut_site,
        chain_site=chain_site, alm_lb=alm_lb, concurrent_luts=concurrent,
    )
