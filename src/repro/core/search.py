"""Budget-aware Pareto-front successive halving over the full arch grid.

:func:`repro.core.alm.full_arch_grid` spans ~2000 grid points / ~1200
structural classes; dense-sweeping it means ~1200 greedy re-clusterings
of *every* circuit — the big Koios members dominate and the sweep engine
spends almost all its wall on architectures that were never contenders.
Successive halving inverts that: every grid point is first scored on a
cheap circuit subset (the smallest-by-node slice of the suite), only the
per-rung survivors — the ADP Pareto front plus the top-ADP fill — are
promoted to larger subsets, and only the last few points ever touch the
full suite.

Everything expensive is shared across rungs through the registry caches
(:mod:`repro.core.plan`): packing prefixes (``pack_prefix``), per-class
re-clusterings (``search_packs``) and compiled timing programs
(``search_programs``), so promoting a survivor to a bigger subset never
repeats the work its earlier rungs already did, and
:func:`repro.core.plan.clear_caches` provably drops all of it.

Determinism: the rung schedule, the circuit subsets (sorted by node
count, circuit name breaking ties), survivor selection (``(adp, name)``
tie-breaks) and the bandit threshold are all pure functions of
``(nets, archs, seed, eta, budget)`` — two runs with the same inputs
produce identical survivor sets and identical payloads (modulo walls),
which ``tests/core/test_search.py`` pins.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

from . import plan as _planner
from .alm import ArchParams
from .netlist import Netlist
from .sweep import SweepResult, adp_frontier, sweep_suite

#: per-(digest, structural key, seed) re-clusterings shared by every rung
#: that touches the class — registered so ``clear_caches()`` drops them
_PACK_CACHE = _planner.register_cache("search_packs", cap=8192)
#: compiled batched timing programs (jax backend only)
_PROG_CACHE = _planner.register_cache("search_programs", cap=256)

#: bandit optimism: keep any arch whose rung ADP is within
#: ``_BANDIT_C / sqrt(n_circuits)`` of the rung best — small subsets are
#: noisy estimates of the full-suite geomean, so early rungs keep a wide
#: optimistic band that tightens as subsets grow
_BANDIT_C = 0.25


def net_size(net: Netlist) -> int:
    """Node count used to order circuits cheapest-first."""
    return net.n_luts + net.n_adders


def circuit_schedule(nets, n_rungs: int, min_circuits: int = 3):
    """Nested smallest-first circuit subsets, growing geometrically from
    ``min_circuits`` to the full suite over ``n_rungs`` rungs."""
    ordered = sorted(nets, key=lambda n: (net_size(n), n.name))
    total = len(ordered)
    lo = min(min_circuits, total)
    if n_rungs <= 1:
        return [ordered]
    sizes = []
    for r in range(n_rungs):
        frac = r / (n_rungs - 1)
        sizes.append(max(lo, round(lo * (total / lo) ** frac)))
    sizes[-1] = total
    return [ordered[:s] for s in sizes]


def pareto_front(rows, x: str = "area_mwta",
                 y: str = "critical_path_ps") -> list[dict]:
    """Non-dominated frontier rows (minimize both axes), in ``(adp,
    name)`` order.  Ties on both axes keep the first by name."""
    front = []
    for r in sorted(rows, key=lambda r: (r["adp"], r["arch"])):
        if not any(o[x] <= r[x] and o[y] <= r[y]
                   and (o[x] < r[x] or o[y] < r[y]) for o in rows):
            front.append(r)
    return front


def select_survivors(rows, k: int, allocation: str = "halving",
                     n_circuits: int = 1) -> list[str]:
    """Names of the archs promoted out of a rung.

    ``halving``: the ADP Pareto front, filled to ``k`` with the best
    remaining ADP rows.  ``bandit``: additionally every arch whose rung
    ADP lies within the optimism band ``1 + _BANDIT_C / sqrt(n_circuits)``
    of the rung best (successive-halving's fixed cull can kill a point
    whose small-subset estimate is unluckily bad; the band keeps it alive
    while estimates are noisy), capped at ``2k`` by ADP order.
    """
    if allocation not in ("halving", "bandit"):
        raise ValueError(f"unknown allocation {allocation!r}")
    ordered = sorted(rows, key=lambda r: (r["adp"], r["arch"]))
    names = {r["arch"] for r in pareto_front(rows)}
    if allocation == "bandit" and ordered:
        thresh = ordered[0]["adp"] * (
            1.0 + _BANDIT_C / math.sqrt(max(n_circuits, 1)))
        names |= {r["arch"] for r in ordered if r["adp"] <= thresh}
        cap = max(2 * k, 1)
        if len(names) > cap:
            names = set([r["arch"] for r in ordered
                         if r["arch"] in names][:cap])
    for r in ordered:
        if len(names) >= k:
            break
        names.add(r["arch"])
    return sorted(names)


@dataclass
class SearchResult:
    """Everything a recorded search needs: the rung trajectory, the final
    full-suite frontier, and the budget ledger."""

    archs: list[str]                 # the searched grid, input order
    baseline: str
    rungs: list[dict]                # per-rung records (see payload())
    frontier: list[dict]             # final-rung ADP frontier rows
    pareto: list[dict]               # final-rung Pareto front
    winner: str
    budget: dict
    final: SweepResult | None = None
    walls: dict = field(default_factory=dict)
    verify: dict | None = None       # verify_winners report, when run

    def survivor_trajectory(self) -> list[list[str]]:
        return [r["survivors"] for r in self.rungs]

    def payload(self) -> dict:
        """JSON-able, deterministic record (walls carried separately per
        rung under ``"walls"`` — drop those keys when comparing runs)."""
        return {
            "n_archs": len(self.archs),
            "baseline": self.baseline,
            "winner": self.winner,
            "budget": self.budget,
            "rungs": self.rungs,
            "frontier": self.frontier,
            "pareto": self.pareto,
        }


def _wall_split(sweep_wall: dict, eval_s: float) -> dict:
    """The per-rung pack / lower / place / anneal / time / eval wall
    split.  ``anneal_s`` is the annealing share *inside* ``place_s``
    (refinement runs during the placement phase), billed separately so
    placed-search ledgers show what refinement itself costs per rung."""
    return {
        "pack_s": sweep_wall["pack_s"],
        "prefix_s": sweep_wall["prefix_s"],
        "recluster_s": sweep_wall["recluster_s"],
        "lower_s": sweep_wall["lower_s"],
        "place_s": sweep_wall["place_s"],
        "anneal_s": sweep_wall["anneal_s"],
        "time_s": sweep_wall["build_s"] + sweep_wall["timing_s"],
        "eval_s": eval_s,
    }


def search_archs(nets, archs, seed: int = 0, eta: int = 4,
                 min_survivors: int = 8, min_circuits: int = 3,
                 allocation: str = "halving", budget: int | None = None,
                 baseline: str | None = None, backend: str = "numpy",
                 max_groups: int = 4, place: bool = False,
                 refine: str | None = "anneal",
                 packs=None, programs=None, prefixes=None) -> SearchResult:
    """Pareto-aware successive-halving search over ``archs``.

    The rung schedule divides the grid by ``eta`` per rung until
    ``min_survivors`` remain, while the circuit subset grows from the
    ``min_circuits`` smallest members to the full suite; the final rung
    is always the full suite.  ``budget`` caps the total number of
    (circuit x arch) evaluations — when a rung would overrun it, its
    circuit subset is trimmed (never below ``min_circuits``); if even the
    trimmed rung does not fit, the search stops early and the last
    completed rung's survivors become final.  The baseline row rides
    along every rung (frontier ratios need it) and is never culled.

    ``backend="numpy"`` (default) re-times each rung as vectorized level
    walks — no compile cost, the right trade for wide rungs where every
    structural class would otherwise jit its own program; pass ``"jax"``
    to compile per class (worth it only for narrow grids re-run many
    times).

    ``prefixes`` overrides the shared ``pack_prefix`` store the rungs'
    sweeps read packing prefixes from — the store that also hosts
    edited-netlist prefixes (:func:`repro.core.sweep.prefix_for_edit`,
    keyed by ``(pack digest, base digest, seed)``), so a search run over
    a netlist and its structural edits shares every delta-derived
    prefix with the serving layer.

    ``place=True`` runs every rung placed: each rung's sweep subgroups
    its grid rows by ``placement_key`` (structural class x grid aspect),
    anneal-refines one placement per (circuit, key, seed) through the
    shared registry cache (``refine``, default ``"anneal"``), and times
    the wire tiers — so ``_w{n}`` wire-delay grid rows stop tying
    bit-for-bit and the wire axis becomes searchable design space.
    Promotion never re-places: a survivor's placements are cache hits on
    every later rung (only newly-joined circuits anneal), and the per-
    rung ledger bills the annealing share under ``walls["anneal_s"]``.
    """
    archs = list(archs)
    if not archs:
        raise ValueError("search_archs needs a non-empty arch grid")
    names = [a.name for a in archs]
    if len(set(names)) != len(names):
        raise ValueError("arch names must be unique across the grid")
    by_name = dict(zip(names, archs))
    base_name = baseline if baseline is not None else names[0]
    if base_name not in by_name:
        raise ValueError(
            f"baseline {base_name!r} not in the searched grid")
    if packs is None:
        packs = _PACK_CACHE
    if programs is None:
        programs = _PROG_CACHE

    # rung count from the halving schedule: n, n/eta, ... until the
    # survivor floor (the last rung always runs the full suite)
    n_rungs = 1
    n = len(archs)
    while n > min_survivors:
        n = max(math.ceil(n / eta), min_survivors)
        n_rungs += 1
    subsets = circuit_schedule(nets, n_rungs, min_circuits=min_circuits)

    current = archs
    rungs: list[dict] = []
    budget_used = 0
    frontier: list[dict] = []
    front: list[dict] = []
    final_res: SweepResult | None = None
    agg_walls: dict[str, float] = {}
    stopped_early = False
    for r, subset in enumerate(subsets):
        if budget is not None:
            remaining = budget - budget_used
            max_circ = remaining // max(len(current), 1)
            if max_circ < min(min_circuits, len(subset)):
                stopped_early = True
                break
            subset = subset[:max_circ] if max_circ < len(subset) else subset
        res = sweep_suite(subset, current, seed=seed, backend=backend,
                          max_groups=max_groups, place=place,
                          refine=refine, packs=packs, programs=programs,
                          prefixes=prefixes)
        budget_used += len(subset) * len(current)
        t0 = time.perf_counter()
        subset_names = [nt.name for nt in subset]
        frontier = adp_frontier(res, baseline=base_name,
                                circuits=subset_names)
        front = pareto_front(frontier)
        last = r == len(subsets) - 1
        if last:
            survivors = sorted(r_["arch"] for r_ in frontier)
        else:
            k = max(math.ceil(len(current) / eta), min_survivors)
            survivors = select_survivors(frontier, k, allocation,
                                         n_circuits=len(subset))
        eval_s = time.perf_counter() - t0
        walls = _wall_split(res.wall, eval_s)
        for key, v in walls.items():
            agg_walls[key] = agg_walls.get(key, 0.0) + v
        rungs.append({
            "rung": r,
            "n_archs": len(current),
            "n_classes": res.n_classes,
            "n_circuits": len(subset),
            "circuits": subset_names,
            "survivors": survivors,
            "best": frontier[0]["arch"] if frontier else base_name,
            "walls": walls,
        })
        final_res = res
        if last:
            break
        keep = set(survivors) | {base_name}
        current = [a for a in current if a.name in keep]
    if not rungs:
        raise ValueError(
            f"budget {budget} cannot afford even one "
            f"{min_circuits}-circuit rung over {len(archs)} archs")
    winner = frontier[0]["arch"] if frontier else base_name
    return SearchResult(
        archs=names, baseline=base_name, rungs=rungs, frontier=frontier,
        pareto=front, winner=winner,
        budget={"requested": budget, "used": budget_used,
                "stopped_early": stopped_early},
        final=final_res, walls=agg_walls)


def verify_winners(result: SearchResult, nets, archs, seed: int = 0,
                   n_equiv_circuits: int = 2, winners=None,
                   place: bool = False,
                   refine: str | None = "anneal") -> dict:
    """Prove the promoted winners honest.

    * **oracle parity**: every (final-rung circuit, winner) record is
      re-derived by a fresh ``pack()`` + Python oracle timing walk and
      must match bit-for-bit — this re-checks the prefix/re-cluster/
      template-lowering pipeline end to end at the exact points the
      search promotes.  For a placed search pass ``place=True`` (and the
      search's ``refine``): the reference becomes
      :func:`repro.core.timing.analyze_placed_oracle` under the same
      registry-cached refined placement the rungs consumed, resolved
      through the winner's placement-key representative in ``archs``
      order (the sweep's subgrouping rule);
    * **equivalence**: each winner's pack of the ``n_equiv_circuits``
      smallest circuits is re-elaborated and proven equivalent to the
      source netlist (symbolic + exhaustive closure,
      :func:`repro.core.equiv.check_pack_equivalence`).
    """
    from .equiv import check_pack_equivalence
    from .packing import pack
    from .timing import analyze_oracle, analyze_placed_oracle

    if result.final is None:
        raise ValueError("search result has no final sweep to verify")
    by_name = {a.name: a for a in archs}
    reps: dict[tuple, ArchParams] = {}
    rep_for = {a.name: reps.setdefault(a.placement_key(), a)
               for a in archs}
    if winners is None:
        winners = [r["arch"] for r in result.pareto]
        if result.winner not in winners:
            winners.append(result.winner)
    ordered = sorted(nets, key=lambda n: (net_size(n), n.name))
    final_names = result.rungs[-1]["circuits"]
    check_nets = [n for n in ordered if n.name in final_names]
    parity = True
    equiv_ok = True
    details = []
    for wname in winners:
        arch = by_name[wname]
        recs = result.final.by_arch(wname)
        rec_by_circ = {r["net"]: r for r in recs}
        for net in check_nets:
            p = pack(net, arch, seed=seed)
            if place:
                from .place import placement_for

                pl = placement_for(p.lower_ir(), rep_for[wname], seed,
                                   refine=refine)
                ro = analyze_placed_oracle(p, pl)
            else:
                ro = analyze_oracle(p)
            rec = rec_by_circ[net.name]
            ok = (ro["critical_path_ps"] == rec["critical_path_ps"]
                  and ro["area_mwta"] == rec["area_mwta"])
            parity = parity and ok
            if not ok:
                details.append({"arch": wname, "net": net.name,
                                "kind": "oracle_mismatch"})
        for net in check_nets[:n_equiv_circuits]:
            rep = check_pack_equivalence(net, arch, seed=seed)
            equiv_ok = equiv_ok and bool(rep["equivalent"])
            if not rep["equivalent"]:
                details.append({"arch": wname, "net": net.name,
                                "kind": "not_equivalent"})
    return {"winners": winners, "oracle_match": parity,
            "equivalent": equiv_ok, "mismatches": details}
