"""Static timing + area analysis over a packed circuit.

Levelized longest-path analysis with the Table II path delays.  An edge
is *local* (same LB, through the local feedback + crossbar) or *global*
(fixed inter-LB routing delay); with a grid placement
(:mod:`repro.core.place`) the global leg additionally pays a wire-tier
delay derived from the Manhattan hop distance between the two LB slots
(1-hop / 2-hop / long wires, zero by default so placement-free numbers
are unchanged).  This is deliberately coarser than VPR's timing-driven
router, but it is applied identically to baseline/DD5/DD6 so the
architectural deltas (Z-path vs LUT-path adder feeds, DD6 output-mux
penalty) dominate the comparison, as in the paper.

Two implementations share this recurrence:

* :func:`analyze_oracle` — the original per-signal Python walk, kept
  verbatim as the ground truth; :func:`analyze_placed_oracle` is the
  same walk with the placement-derived wire term, the ground truth for
  placed timing;
* the **vectorized analyzer** (:mod:`repro.core.timing_vec`) — the pack is
  lowered once to the columnar :class:`~repro.core.circuit_ir.CircuitIR`
  and the arrival recurrence runs as levelized array programs (numpy per
  circuit, or a ``lax.scan``/``vmap`` batched jit across circuits x
  architectures for design-space sweeps).  It is bit-identical to the
  oracle — float64, same addition association order, exact max — which
  tests assert for both the unplaced and the placed paths.

:func:`analyze` dispatches (``method="vector"`` default, ``"oracle"`` for
the reference, optional ``placement=``) and accounts every call's wall
time in :data:`TIMING_WALL` so benchmark drivers can report how much of
a figure was spent in static timing.
"""
from __future__ import annotations

import time

from .alm import ArchParams
from .netlist import CONST0, CONST1, Netlist
from .packing import PackedCircuit

#: cumulative static-timing wall clock (seconds) + call count, accounted by
#: :func:`analyze` and by the sweep engine; benchmark sections report the
#: per-section delta (see ``benchmarks/run.py``)
TIMING_WALL = {"s": 0.0, "calls": 0}

#: open :func:`timing_section` scopes (innermost last) — while any scope is
#: open, recordings land in it instead of the global counter, so nested
#: accounting sites (``sweep_suite`` inside a flow wrapper that itself
#: accounts, ``analyze`` inside either) report **once**, not once per layer
_SCOPE_STACK: list[dict] = []


def reset_timing_wall() -> None:
    TIMING_WALL["s"] = 0.0
    TIMING_WALL["calls"] = 0


def read_timing_wall() -> dict:
    return dict(TIMING_WALL)


def record_timing_wall(seconds: float, calls: int = 1) -> None:
    """Account ``seconds`` of static-timing wall clock.

    Scope-aware: inside an open :func:`timing_section` the amount is
    credited to that section (whose eventual single commit already spans
    it) instead of the global counter — the fix for flow paths that
    drive ``sweep_suite`` *and* call :func:`analyze` under one
    accounted region double-counting the shared span."""
    if _SCOPE_STACK:
        _SCOPE_STACK[-1]["s"] += seconds
        _SCOPE_STACK[-1]["calls"] += calls
    else:
        TIMING_WALL["s"] += seconds
        TIMING_WALL["calls"] += calls


class timing_section:
    """Context manager marking one accounted static-timing region.

    ``measure=True`` (default) commits the section's *elapsed wall
    clock* on exit — any ``record_timing_wall`` issued inside (directly
    or by nested sections) is subsumed by that span rather than added on
    top.  ``measure=False`` commits only the amounts explicitly recorded
    inside (for engines like ``sweep_suite`` that account sub-phases and
    exclude packing).  Either way a nested section contributes to its
    parent, and exactly one commit reaches :data:`TIMING_WALL` per
    outermost section — per-section deltas in ``benchmarks/run.py`` are
    therefore non-overlapping by construction (asserted there against
    each section's real elapsed time).
    """

    def __init__(self, calls: int = 0, measure: bool = True):
        self._calls = calls
        self._measure = measure

    def __enter__(self) -> dict:
        self._scope = {"s": 0.0, "calls": self._calls}
        _SCOPE_STACK.append(self._scope)
        self._t0 = time.perf_counter()
        return self._scope

    def __exit__(self, *exc) -> None:
        _SCOPE_STACK.pop()
        dt = time.perf_counter() - self._t0
        s = dt if self._measure else self._scope["s"]
        record_timing_wall(s, self._scope["calls"])


def analyze(packed: PackedCircuit, method: str = "vector",
            placement=None) -> dict:
    """Timing + area record for one packed circuit.

    ``method="vector"`` lowers to CircuitIR and runs the numpy vectorized
    analyzer (bit-identical to the oracle, no per-signal Python walk);
    ``method="oracle"`` runs the original reference implementation.
    With ``placement`` (a :class:`repro.core.place.GridPlacement` of this
    pack) the inter-LB wire-tier term is included on either path.
    """
    with timing_section(calls=1):
        if method == "oracle":
            rec = (analyze_oracle(packed) if placement is None
                   else analyze_placed_oracle(packed, placement))
        elif method == "vector":
            from .circuit_ir import apply_placement
            from .timing_vec import analyze_ir

            ir = packed.lower_ir()
            if placement is not None:
                ir = apply_placement(ir, placement)
            rec = analyze_ir(ir, packed.arch)
        else:
            raise ValueError(f"unknown timing method {method!r}")
    return rec


def analyze_placed_oracle(packed: PackedCircuit, placement) -> dict:
    """Ground-truth placed timing: :func:`analyze_oracle`'s walk with the
    placement-derived wire-tier delay on every inter-LB edge.

    Wire delay is added between the route and pin components (the
    vectorized association order ``(((arrival + route) + wire) + pin) +
    path``) and only when both endpoints are placed in *different* LBs —
    PIs, constants and intra-LB / absorbed edges never touch the fabric
    grid.  At all-zero wire-tier delays this is bit-identical to
    :func:`analyze_oracle` (``x + 0.0 == x``), which tests pin.
    """
    if placement.n_lbs != packed.n_lbs:
        raise ValueError(
            f"{packed.net.name}: placement has {placement.n_lbs} LB slots "
            f"but the pack has {packed.n_lbs} LBs")
    return analyze_oracle(packed, placement)


def analyze_oracle(packed: PackedCircuit, placement=None) -> dict:
    net = packed.net
    arch = packed.arch

    # production site (alm index) per signal; PIs -> -1
    site: dict[int, int] = {}
    for s in net.pis:
        site[s] = -1
    for li, out in enumerate(net.lut_out):
        ai = packed.lut_site.get(li, -2)
        site[out] = ai
    for ci, ch in enumerate(net.chains):
        for bi, s in enumerate(ch.sums):
            site[s] = packed.chain_site.get((ci, bi), -2)
        if ch.cout is not None:
            site[ch.cout] = packed.chain_site.get((ci, len(ch.sums) - 1), -2)

    def lb_of(ai: int) -> int:
        if ai < 0:
            return -1
        return packed.alm_lb[ai]

    arr: dict[int, float] = {CONST0: 0.0, CONST1: 0.0}
    for s in net.pis:
        arr[s] = 0.0

    def edge_in(s: int, dst_lb: int, pin: str) -> float:
        """Arrival of signal s at an ALM input pin in LB dst_lb."""
        t = arr[s]
        src_lb = lb_of(site.get(s, -1))
        if s <= CONST1:
            return 0.0
        if src_lb == dst_lb and src_lb >= 0:
            t += arch.t_route_local
        else:
            t += arch.t_route_global
            if placement is not None and src_lb >= 0 and dst_lb >= 0:
                d = (abs(int(placement.lb_x[src_lb])
                         - int(placement.lb_x[dst_lb]))
                     + abs(int(placement.lb_y[src_lb])
                           - int(placement.lb_y[dst_lb])))
                t += (arch.t_wire_hop1 if d <= 1 else
                      arch.t_wire_hop2 if d == 2 else arch.t_wire_long)
        t += arch.t_lbin_to_z if pin == "z" else arch.t_lbin_to_ah
        return t

    # map (chain,bit) -> half for feed info
    feed: dict[tuple[int, int], tuple[str, list[int]]] = {}
    absorbed_all: set[int] = set()
    for alm in packed.alms:
        for h in alm.halves:
            if h.fa is not None:
                feed[h.fa] = (h.fa_feed, h.absorbed)
                absorbed_all.update(h.absorbed)

    out_extra = arch.t_out_mux_extra

    for nd in net.topo_order():
        kind, idx = nd
        if kind == "lut":
            out = net.lut_out[idx]
            ai = packed.lut_site.get(idx)
            if ai is None:
                # absorbed LUT timing handled at chain; skip (arr set there)
                continue
            dst_lb = lb_of(ai)
            k = len(net.lut_inputs[idx])
            t_in = max((edge_in(s, dst_lb, "ah") for s in net.lut_inputs[idx]
                        if s > CONST1), default=0.0)
            # absorbed LUTs have their delay folded into t_ah_to_adder
            if idx in absorbed_all:
                arr[out] = t_in
            else:
                arr[out] = t_in + arch.lut_delay(k) + arch.t_alm_out + out_extra
        else:
            ch = net.chains[idx]
            carry = 0.0
            if ch.cin > CONST1:
                ai0 = packed.chain_site.get((idx, 0), -2)
                carry = edge_in(ch.cin, lb_of(ai0), "ah") + arch.t_ah_to_adder
            for bi in range(len(ch.sums)):
                ai = packed.chain_site.get((idx, bi), -2)
                dst_lb = lb_of(ai)
                fkind, absorbed = feed.get((idx, bi), ("lut", []))
                ops = [ch.a[bi], ch.b[bi]]
                t_op = 0.0
                absorbed_outs = {net.lut_out[li] for li in absorbed}
                for s in ops:
                    if s <= CONST1:
                        continue
                    if s in absorbed_outs:
                        # operand computed in the half's own LUTs
                        li = next(l for l in absorbed if net.lut_out[l] == s)
                        tin = max((edge_in(q, dst_lb, "ah")
                                   for q in net.lut_inputs[li] if q > CONST1),
                                  default=0.0)
                        t_op = max(t_op, tin + arch.t_ah_to_adder)
                    elif fkind == "z":
                        t_op = max(t_op, edge_in(s, dst_lb, "z")
                                   + arch.t_z_to_adder)
                    else:
                        t_op = max(t_op, edge_in(s, dst_lb, "ah")
                                   + arch.t_ah_to_adder)
                t_here = max(t_op, carry)
                arr[ch.sums[bi]] = t_here + arch.t_sum_out + out_extra
                carry = t_here + arch.t_carry
            if ch.cout is not None:
                arr[ch.cout] = carry + arch.t_sum_out + out_extra

    # absorbed luts that never got arr (dangling) -> 0
    cp = 0.0
    for bus in net.pos.values():
        for s in bus:
            cp = max(cp, arr.get(s, 0.0))
    cp = max(cp, 1.0)

    area = packed.total_area
    return {
        "arch": arch.name,
        "critical_path_ps": cp,
        "fmax_mhz": 1e6 / cp,
        "alms": packed.n_alms,
        "lbs": packed.n_lbs,
        "area_mwta": area,
        "adp": area * cp,
        "adders": net.n_adders,
        "luts": net.n_luts,
        "concurrent_luts": packed.concurrent_luts,
    }


def channel_utilization(packed: PackedCircuit,
                        channel_width: int | None = None) -> list[float]:
    """Per-LB routing-demand proxy for the Fig. 8 congestion histogram.

    Utilization of the channels around an LB is approximated by the number of
    distinct signals crossing its boundary (external inputs + consumed-
    elsewhere outputs) against the channel capacity serving one LB span.
    ``channel_width`` defaults to the arch's routing capacity
    (``ArchParams.channel_width``, 400 tracks on every canonical arch so
    recorded fig8 numbers are reproducible); pass a value to override.
    The placement-derived successor is
    :func:`repro.core.place.channel_congestion`.
    """
    if channel_width is None:
        channel_width = packed.arch.channel_width
    net = packed.net
    util = []
    # signals consumed per LB + reverse index signal -> consuming LBs
    lb_consumes: list[set[int]] = [set() for _ in packed.lbs]
    consumers_of: dict[int, set[int]] = {}
    for lbi in range(len(packed.lbs)):
        for ai in packed.lbs[lbi].alms:
            ah, z = packed.alms[ai].input_signals(net)
            lb_consumes[lbi] |= ah | z
        for s in lb_consumes[lbi]:
            consumers_of.setdefault(s, set()).add(lbi)
    po_sigs = {s for bus in net.pos.values() for s in bus}
    for lbi in range(len(packed.lbs)):
        produced = packed.produced_in_lb(lbi)
        ext_in = lb_consumes[lbi] - produced
        ext_out = {s for s in produced
                   if (consumers_of.get(s, set()) - {lbi}) or s in po_sigs}
        util.append((len(ext_in) + len(ext_out)) / channel_width)
    return util
