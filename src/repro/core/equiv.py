"""Physical re-elaboration + equivalence checking for packed circuits.

The paper's area comparison is only meaningful if ``pack()`` produces
*functionally identical* circuits under every architecture — a co-packing or
LUT-absorption bug would silently corrupt every figure while all resource
counters still balance.  This module closes that hole the way the f4pga
repacker does: treat packing as a netlist-to-netlist transform and prove it.

Flow
----
1. :func:`reelaborate` rebuilds a *physical* :class:`Netlist` from a
   :class:`~repro.core.packing.PackedCircuit` by walking every ALM and
   emitting exactly the logic its silicon implements:

   * **absorbed LUTs** — re-composed into the half's adder-side 4-LUT mask
     via :func:`~repro.core.netlist.tt_compose` (the operand path is
     ``wire(LUT(x))``, so the absorbed table is substituted into a buffer);
   * **A–H-fed chain operands** (``fa_feed == "lut"``) — raw operands pass
     through the LUT path as wires, absorbed operands through their
     re-composed masks;
   * **Z-fed chain operands** (``fa_feed == "z"``, DD only) — operands
     bypass the LUTs entirely and connect straight to the adder;
   * **hosted LUTs** and **6-LUT spans** — the concurrent-use masks of
     mode-C halves / whole ALMs.

   Structural illegalities (a Z feed on a baseline ALM, a hosted LUT wider
   than its site, an absorbed LUT that is not 4-input single-fanout…)
   raise :class:`ReElaborationError` — they mean the packer corrupted the
   circuit's structure, not merely its function.

2. :func:`equivalence_report` drives source and physical netlists with the
   same random test-vector lanes (bit-parallel over arbitrary-width python
   ints, or the fused JAX engine for large circuits) and compares every
   primary output — plus every re-elaborated internal signal, so a
   mismatch localizes to the first corrupted node.

3. :func:`check_pack_equivalence` / :func:`verify_all_archs` are the
   one-call gates used by tests and benchmarks: pack, re-elaborate, prove —
   for baseline, DD5 and DD6, so the A/B area comparison is provably
   apples-to-apples.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .alm import ARCHS, ArchParams
from .netlist import (CONST0, CONST1, TT_BUF, Netlist, eval_netlist,
                      tt_compose)
from .packing import PackedCircuit, pack


class ReElaborationError(RuntimeError):
    """The packed structure is physically unrealizable."""


@dataclass
class ReElaboration:
    """A physical netlist plus the source→physical signal correspondence."""

    phys: Netlist
    sig_map: dict[int, int]
    #: source lut idx -> role at its site: "absorbed" | "hosted" | "lut6"
    lut_role: dict[int, str] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# re-elaboration
# ---------------------------------------------------------------------------


def _lut_site_role(packed: PackedCircuit, li: int):
    """Locate LUT ``li`` in the packed fabric: (alm_idx, role, half)."""
    ai = packed.lut_site.get(li)
    if ai is None:
        raise ReElaborationError(f"LUT {li} has no site")
    alm = packed.alms[ai]
    if alm.lut6 == li:
        return ai, "lut6", None
    for h in alm.halves:
        if h.hosted_lut == li:
            return ai, "hosted", h
        if li in h.absorbed:
            return ai, "absorbed", h
    raise ReElaborationError(f"LUT {li} mapped to ALM {ai} but not present")


def reelaborate(packed: PackedCircuit) -> ReElaboration:
    """Rebuild the physical netlist a :class:`PackedCircuit` implements."""
    src = packed.net
    arch = packed.arch
    phys = Netlist(f"{src.name}@{arch.name}")
    sig_map: dict[int, int] = {CONST0: CONST0, CONST1: CONST1}
    lut_role: dict[int, str] = {}

    for name, bus in src.pi_buses.items():
        nbus = phys.add_pi_bus(name, len(bus))
        for s, ns in zip(bus, nbus):
            sig_map[s] = ns

    def mapped(s: int) -> int:
        try:
            return sig_map[s]
        except KeyError:
            raise ReElaborationError(f"signal {s} used before it is driven")

    def emit_lut(li: int, max_k: int, role: str) -> None:
        ins = src.lut_inputs[li]
        if len(ins) > max_k:
            raise ReElaborationError(
                f"{role} LUT {li} has {len(ins)} inputs > {max_k}")
        # the physical mask is wire∘LUT: substitute the source table into
        # a buffer, exactly what the half's LUT mask is programmed with
        comp_ins, comp_tt = tt_compose(
            TT_BUF, (src.lut_out[li],), 0, src.lut_tt[li], ins)
        out = phys.add_lut([mapped(s) for s in comp_ins], comp_tt)
        sig_map[src.lut_out[li]] = out
        lut_role[li] = role

    def emit_chain(ci: int) -> None:
        ch = src.chains[ci]
        a_ops: list[int] = []
        b_ops: list[int] = []
        for bi in range(len(ch.sums)):
            ai = packed.chain_site.get((ci, bi))
            if ai is None:
                raise ReElaborationError(f"chain bit ({ci},{bi}) has no site")
            half = None
            for h in packed.alms[ai].halves:
                if h.fa == (ci, bi):
                    half = h
                    break
            if half is None:
                raise ReElaborationError(
                    f"chain bit ({ci},{bi}) not in its site ALM {ai}")
            if half.fa_feed == "z":
                if not arch.concurrent:
                    raise ReElaborationError(
                        f"Z feed on non-concurrent arch {arch.name}")
                if half.absorbed:
                    raise ReElaborationError(
                        f"chain bit ({ci},{bi}): Z feed cannot carry "
                        f"absorbed LUT outputs")
                ops = [mapped(ch.a[bi]), mapped(ch.b[bi])]
            elif half.fa_feed == "lut":
                if half.hosted_lut is not None:
                    raise ReElaborationError(
                        f"chain bit ({ci},{bi}): LUT-path feed but the "
                        f"half's LUT hosts an unrelated function")
                # absorbed operands were already re-composed as LUT masks
                # (their mapped signal is the re-composed LUT output); raw
                # operands ride the LUT path as wires — both map directly
                ops = [mapped(ch.a[bi]), mapped(ch.b[bi])]
            else:
                raise ReElaborationError(
                    f"chain bit ({ci},{bi}) has feed {half.fa_feed!r}")
            if packed.alms[ai].lut6 is not None and half.fa_feed != "z":
                raise ReElaborationError(
                    f"chain bit ({ci},{bi}): 6-LUT span requires Z feeds")
            a_ops.append(ops[0])
            b_ops.append(ops[1])
        sums, cout = phys.add_chain(a_ops, b_ops, cin=mapped(ch.cin),
                                    want_cout=ch.cout is not None)
        for bi, s in enumerate(ch.sums):
            sig_map[s] = sums[bi]
        if ch.cout is not None:
            sig_map[ch.cout] = cout

    role_max_k = {"absorbed": 4, "hosted": 5, "lut6": 6}
    for nd in src.topo_order():
        kind, idx = nd
        if kind == "lut":
            ai, role, half = _lut_site_role(packed, idx)
            if role == "absorbed":
                if half.fa is None or half.fa_feed != "lut":
                    raise ReElaborationError(
                        f"absorbed LUT {idx}: half does not feed an FA "
                        f"through the LUT path")
            emit_lut(idx, role_max_k[role], role)
        else:
            emit_chain(idx)

    phys.pos = {name: [mapped(s) for s in bus]
                for name, bus in src.pos.items()}
    return ReElaboration(phys=phys, sig_map=sig_map, lut_role=lut_role)


# ---------------------------------------------------------------------------
# equivalence checking
# ---------------------------------------------------------------------------


def equivalence_report(src: Netlist, re_elab: ReElaboration,
                       n_vectors: int = 256, seed: int = 0,
                       use_jax: bool = False) -> dict:
    """Random-vector equivalence proof over ``n_vectors`` lanes.

    Compares every primary output *and* every mapped internal signal, so a
    failure names the first corrupted source signal.  ``use_jax`` routes
    both sides through the fused JAX engine (same lanes, uint32 words);
    otherwise the bit-parallel python oracle runs on arbitrary-width ints.
    """
    import random

    rng = random.Random(seed)
    phys, sig_map = re_elab.phys, re_elab.sig_map
    pi_vals = {s: rng.getrandbits(n_vectors) for s in src.pis}
    phys_pi_vals = {sig_map[s]: v for s, v in pi_vals.items()}

    def mismatch_entry(s: int, diff: int) -> dict:
        vec = (diff & -diff).bit_length() - 1
        return {
            "signal": s, "phys_signal": sig_map[s], "vector": vec,
            "pi_assignment": {p: (pi_vals[p] >> vec) & 1 for p in src.pis},
        }

    mismatched: list[dict] = []
    if use_jax:
        import numpy as np

        from .eval_jax import eval_netlist_jax

        n_words = (n_vectors + 31) // 32

        def lanes(vals):
            return {s: np.array([(v >> (32 * w)) & 0xFFFFFFFF
                                 for w in range(n_words)], dtype=np.uint32)
                    for s, v in vals.items()}

        gv = np.asarray(eval_netlist_jax(src, lanes(pi_vals), n_words))
        pv = np.asarray(eval_netlist_jax(phys, lanes(phys_pi_vals), n_words))
        # vectorized compare of every mapped signal at once; python ints
        # are reconstructed only for the (<= 4 reported) mismatching rows
        idx_src = np.array(sorted(sig_map), dtype=np.int64)
        idx_phys = np.array([sig_map[s] for s in idx_src], dtype=np.int64)
        word_mask = np.full(n_words, 0xFFFFFFFF, dtype=np.uint32)
        rem = n_vectors - 32 * (n_words - 1)
        if rem < 32:
            word_mask[-1] = (1 << rem) - 1
        diff_words = (gv[idx_src] ^ pv[idx_phys]) & word_mask[None, :]
        bad_rows = np.nonzero(diff_words.any(axis=1))[0]
        row_of = {int(s): r for r, s in enumerate(idx_src)}
        for r in bad_rows[:4]:
            diff = sum(int(diff_words[r, w]) << (32 * w)
                       for w in range(n_words))
            mismatched.append(mismatch_entry(int(idx_src[r]), diff))
        po_ok = not any(
            diff_words[row_of[s]].any()
            for bus in src.pos.values() for s in bus)
    else:
        src_val = eval_netlist(src, pi_vals, n_vectors)
        phys_val = eval_netlist(phys, phys_pi_vals, n_vectors)
        for s in sorted(sig_map):
            ps = sig_map[s]
            if s not in src_val or ps not in phys_val:
                continue
            if src_val[s] != phys_val[ps]:
                mismatched.append(
                    mismatch_entry(s, src_val[s] ^ phys_val[ps]))
                if len(mismatched) >= 4:
                    break
        po_ok = all(
            src_val[s] == phys_val[sig_map[s]]
            for bus in src.pos.values() for s in bus)
    return {
        "name": src.name,
        "equivalent": po_ok and not mismatched,
        "n_vectors": n_vectors,
        "pos_checked": sum(len(b) for b in src.pos.values()),
        "signals_checked": len(sig_map),
        "mismatches": mismatched,
    }


def assert_equivalent(src: Netlist, re_elab: ReElaboration,
                      n_vectors: int = 256, seed: int = 0,
                      use_jax: bool = False) -> dict:
    rep = equivalence_report(src, re_elab, n_vectors=n_vectors, seed=seed,
                             use_jax=use_jax)
    if not rep["equivalent"]:
        first = rep["mismatches"][0] if rep["mismatches"] else {}
        raise AssertionError(
            f"{src.name}: packed circuit is NOT equivalent "
            f"(first mismatch: {first})")
    return rep


def check_pack_equivalence(net: Netlist, arch: ArchParams, seed: int = 0,
                           n_vectors: int = 256, use_jax: bool = False,
                           **pack_kwargs) -> dict:
    """Pack ``net`` under ``arch``, re-elaborate, and prove equivalence."""
    packed = pack(net, arch, seed=seed, **pack_kwargs)
    re_elab = reelaborate(packed)
    rep = equivalence_report(net, re_elab, n_vectors=n_vectors, seed=seed,
                             use_jax=use_jax)
    rep["arch"] = arch.name
    rep["alms"] = packed.n_alms
    rep["concurrent_luts"] = packed.concurrent_luts
    rep["z_fed_bits"] = sum(
        1 for alm in packed.alms for h in alm.halves
        if h.fa is not None and h.fa_feed == "z")
    return rep


def verify_all_archs(net: Netlist, seed: int = 0, n_vectors: int = 256,
                     use_jax: bool = False) -> dict[str, dict]:
    """The apples-to-apples gate: prove pack equivalence under every arch."""
    return {name: check_pack_equivalence(net, arch, seed=seed,
                                         n_vectors=n_vectors, use_jax=use_jax)
            for name, arch in ARCHS.items()}
