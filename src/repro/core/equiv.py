"""Physical re-elaboration + equivalence checking for packed circuits.

The paper's area comparison is only meaningful if ``pack()`` produces
*functionally identical* circuits under every architecture — a co-packing or
LUT-absorption bug would silently corrupt every figure while all resource
counters still balance.  This module closes that hole the way the f4pga
repacker does: treat packing as a netlist-to-netlist transform and prove it.

Flow
----
1. :func:`reelaborate` rebuilds a *physical* :class:`Netlist` from a
   :class:`~repro.core.packing.PackedCircuit` by walking every ALM and
   emitting exactly the logic its silicon implements:

   * **absorbed LUTs** — re-composed into the half's adder-side 4-LUT mask
     via :func:`~repro.core.netlist.tt_compose` (the operand path is
     ``wire(LUT(x))``, so the absorbed table is substituted into a buffer);
   * **A–H-fed chain operands** (``fa_feed == "lut"``) — raw operands pass
     through the LUT path as wires, absorbed operands through their
     re-composed masks;
   * **Z-fed chain operands** (``fa_feed == "z"``, DD only) — operands
     bypass the LUTs entirely and connect straight to the adder;
   * **hosted LUTs** and **6-LUT spans** — the concurrent-use masks of
     mode-C halves / whole ALMs.

   Structural illegalities (a Z feed on a baseline ALM, a hosted LUT wider
   than its site, an absorbed LUT that is not 4-input single-fanout…)
   raise :class:`ReElaborationError` — they mean the packer corrupted the
   circuit's structure, not merely its function.

2. :func:`equivalence_report` drives source and physical netlists with the
   same random test-vector lanes (bit-parallel over arbitrary-width python
   ints, or — by default for large circuit pairs, ``use_jax="auto"`` —
   the fused vectorized engine over the unified
   :class:`~repro.core.circuit_ir.CircuitIR` lowering, shared with every
   other consumer) and compares every primary output — plus every
   re-elaborated internal signal, so a mismatch localizes to the first
   corrupted node.

3. :func:`symbolic_equivalence_report` is the **per-ALM symbolic fast
   path**: every re-elaborated LUT mask is compared truth-table-to-truth-
   table against the source function (canonicalized over sorted support),
   and every arithmetic half's operand masks are composed into the half's
   sum and carry functions with :func:`~repro.core.netlist.tt_compose` and
   compared directly.  When every cone stays within 6 inputs this proves
   equivalence without simulating a single vector — and a symbolic
   mismatch *localizes* the corrupted node.  Cones wider than 6 inputs
   fall back to lane simulation.

4. :func:`exhaustive_residue_report` closes symbolic residue cones with
   <= 16 support inputs by **full truth-table enumeration**: support
   signals become free variables with ``tt_var`` bit patterns over
   ``2^W`` lanes and both sides' cones evaluate bit-parallel over one
   python int — an exhaustive proof, not a sample.  Only cones wider
   than 16 inputs (or with unmapped leaves) remain for lane simulation —
   the SAT-shaped open item is now wide cones only.

5. :func:`check_pack_equivalence` / :func:`verify_all_archs` are the
   one-call gates used by tests and benchmarks: pack, re-elaborate, prove —
   for baseline, DD5 and DD6, so the A/B area comparison is provably
   apples-to-apples.  The gates run the symbolic fast path first, then
   the exhaustive residue closure, and only simulate what neither pass
   could close.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .alm import ARCHS, ArchParams
from .netlist import (CONST0, CONST1, TT_BUF, TT_MAJ3, TT_XOR3, Netlist,
                      eval_netlist, tt_compose, tt_eval, tt_reduce)
from .packing import PackedCircuit, pack


class ReElaborationError(RuntimeError):
    """The packed structure is physically unrealizable."""


@dataclass
class ReElaboration:
    """A physical netlist plus the source→physical signal correspondence."""

    phys: Netlist
    sig_map: dict[int, int]
    #: source lut idx -> role at its site: "absorbed" | "hosted" | "lut6"
    lut_role: dict[int, str] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# re-elaboration
# ---------------------------------------------------------------------------


def _lut_site_role(packed: PackedCircuit, li: int):
    """Locate LUT ``li`` in the packed fabric: (alm_idx, role, half)."""
    ai = packed.lut_site.get(li)
    if ai is None:
        raise ReElaborationError(f"LUT {li} has no site")
    alm = packed.alms[ai]
    if alm.lut6 == li:
        return ai, "lut6", None
    for h in alm.halves:
        if h.hosted_lut == li:
            return ai, "hosted", h
        if li in h.absorbed:
            return ai, "absorbed", h
    raise ReElaborationError(f"LUT {li} mapped to ALM {ai} but not present")


def reelaborate(packed: PackedCircuit) -> ReElaboration:
    """Rebuild the physical netlist a :class:`PackedCircuit` implements."""
    src = packed.net
    arch = packed.arch
    phys = Netlist(f"{src.name}@{arch.name}")
    sig_map: dict[int, int] = {CONST0: CONST0, CONST1: CONST1}
    lut_role: dict[int, str] = {}

    for name, bus in src.pi_buses.items():
        nbus = phys.add_pi_bus(name, len(bus))
        for s, ns in zip(bus, nbus):
            sig_map[s] = ns

    def mapped(s: int) -> int:
        try:
            return sig_map[s]
        except KeyError:
            raise ReElaborationError(f"signal {s} used before it is driven")

    def emit_lut(li: int, max_k: int, role: str) -> None:
        ins = src.lut_inputs[li]
        if len(ins) > max_k:
            raise ReElaborationError(
                f"{role} LUT {li} has {len(ins)} inputs > {max_k}")
        # the physical mask is wire∘LUT: substitute the source table into
        # a buffer, exactly what the half's LUT mask is programmed with
        comp_ins, comp_tt = tt_compose(
            TT_BUF, (src.lut_out[li],), 0, src.lut_tt[li], ins)
        out = phys.add_lut([mapped(s) for s in comp_ins], comp_tt)
        sig_map[src.lut_out[li]] = out
        lut_role[li] = role

    def emit_chain(ci: int) -> None:
        ch = src.chains[ci]
        a_ops: list[int] = []
        b_ops: list[int] = []
        for bi in range(len(ch.sums)):
            ai = packed.chain_site.get((ci, bi))
            if ai is None:
                raise ReElaborationError(f"chain bit ({ci},{bi}) has no site")
            half = None
            for h in packed.alms[ai].halves:
                if h.fa == (ci, bi):
                    half = h
                    break
            if half is None:
                raise ReElaborationError(
                    f"chain bit ({ci},{bi}) not in its site ALM {ai}")
            if half.fa_feed == "z":
                if not arch.concurrent:
                    raise ReElaborationError(
                        f"Z feed on non-concurrent arch {arch.name}")
                if half.absorbed:
                    raise ReElaborationError(
                        f"chain bit ({ci},{bi}): Z feed cannot carry "
                        f"absorbed LUT outputs")
                ops = [mapped(ch.a[bi]), mapped(ch.b[bi])]
            elif half.fa_feed == "lut":
                if half.hosted_lut is not None:
                    raise ReElaborationError(
                        f"chain bit ({ci},{bi}): LUT-path feed but the "
                        f"half's LUT hosts an unrelated function")
                # absorbed operands were already re-composed as LUT masks
                # (their mapped signal is the re-composed LUT output); raw
                # operands ride the LUT path as wires — both map directly
                ops = [mapped(ch.a[bi]), mapped(ch.b[bi])]
            else:
                raise ReElaborationError(
                    f"chain bit ({ci},{bi}) has feed {half.fa_feed!r}")
            if packed.alms[ai].lut6 is not None and half.fa_feed != "z":
                raise ReElaborationError(
                    f"chain bit ({ci},{bi}): 6-LUT span requires Z feeds")
            a_ops.append(ops[0])
            b_ops.append(ops[1])
        sums, cout = phys.add_chain(a_ops, b_ops, cin=mapped(ch.cin),
                                    want_cout=ch.cout is not None)
        for bi, s in enumerate(ch.sums):
            sig_map[s] = sums[bi]
        if ch.cout is not None:
            sig_map[ch.cout] = cout

    role_max_k = {"absorbed": 4, "hosted": 5, "lut6": 6}
    for nd in src.topo_order():
        kind, idx = nd
        if kind == "lut":
            ai, role, half = _lut_site_role(packed, idx)
            if role == "absorbed":
                if half.fa is None or half.fa_feed != "lut":
                    raise ReElaborationError(
                        f"absorbed LUT {idx}: half does not feed an FA "
                        f"through the LUT path")
            emit_lut(idx, role_max_k[role], role)
        else:
            emit_chain(idx)

    phys.pos = {name: [mapped(s) for s in bus]
                for name, bus in src.pos.items()}
    return ReElaboration(phys=phys, sig_map=sig_map, lut_role=lut_role)


# ---------------------------------------------------------------------------
# per-ALM symbolic fast path
# ---------------------------------------------------------------------------

# sentinel variable id for the free ripple-carry input of a chain bit;
# signals are >= 0, so negatives never collide
_CARRY_VAR = -1


def _canon(inputs, tt):
    """Canonical (sorted-support, reduced) form of a small boolean cone."""
    inputs, tt = tt_reduce(inputs, tt)
    order = sorted(range(len(inputs)), key=lambda j: inputs[j])
    new_inputs = tuple(inputs[j] for j in order)
    new_tt = 0
    for m in range(1 << len(inputs)):
        asgn = 0
        for nj, oj in enumerate(order):
            if (m >> nj) & 1:
                asgn |= 1 << oj
        if tt_eval(tt, asgn):
            new_tt |= 1 << m
    return new_inputs, new_tt


def _sig_cone(net: Netlist, s: int):
    """A signal as a one-level cone: its driving LUT's (inputs, tt), a
    constant, or itself as a free variable (PIs, chain sums/couts)."""
    if s == CONST0:
        return (), 0
    if s == CONST1:
        return (), 1
    drv = net.driver.get(s)
    if drv is not None and drv[0] == "lut":
        i = drv[1]
        return net.lut_inputs[i], net.lut_tt[i]
    return (s,), TT_BUF


def _compose_half(net: Netlist, a: int, b: int, cin, outer_tt: int):
    """Compose the operand cones of one FA bit into ``outer_tt(a, b, c)``.

    ``cin`` is a signal id for bit 0 or ``_CARRY_VAR`` for the free ripple
    carry.  Raises ValueError when the merged support exceeds 6 inputs —
    the caller falls back to lane simulation for that cone.
    """
    a_ins, a_tt = _sig_cone(net, a)
    b_ins, b_tt = _sig_cone(net, b)
    ins, tt = tt_compose(outer_tt, (-2, -3, cin), 0, a_tt, a_ins)
    pin_b = ins.index(-3)
    ins, tt = tt_compose(tt, ins, pin_b, b_tt, b_ins)
    return _canon(ins, tt)


def _prove_nodes(src: Netlist, re_elab: ReElaboration,
                 lut_scope=None, chain_scope=None):
    """The symbolic per-node proof loop, optionally scoped.

    ``lut_scope`` / ``chain_scope`` restrict the walk to those LUT
    indices / chain indices (``None`` = every node); nodes outside the
    scope are not visited at all.  This is the shared core of the
    full-circuit :func:`symbolic_equivalence_report` and the
    dirty-cluster :func:`verify_clusters` — one proof engine, two
    scopes, so a scoped verdict is by construction the full verdict
    restricted to the scope.  Returns ``(proven_luts, proven_bits,
    fallback, mismatches)``.
    """
    phys, sig_map = re_elab.phys, re_elab.sig_map
    proven_luts = proven_bits = 0
    fallback: list[tuple] = []
    mismatches: list[dict] = []

    def map_support(cone):
        """Re-express a source-space cone in physical signal ids (None when
        some input never got mapped — that cone goes to simulation)."""
        ins, tt = cone
        mapped = []
        for s in ins:
            if s < 0:  # the free carry variable
                mapped.append(s)
            elif s in sig_map:
                mapped.append(sig_map[s])
            else:
                return None
        return _canon(tuple(mapped), tt)

    for nd in src.topo_order():
        kind, idx = nd
        if kind == "lut":
            if lut_scope is not None and idx not in lut_scope:
                continue
            out = src.lut_out[idx]
            p_out = sig_map.get(out)
            want = map_support((src.lut_inputs[idx], src.lut_tt[idx]))
            if p_out is None or want is None:
                fallback.append(nd)
                continue
            # structural hashing may collapse the re-composed mask onto an
            # existing signal or constant — a wire/const `want` proves the
            # node by the mapping itself, no physical LUT to compare
            if (want == ((p_out,), TT_BUF)
                    or (want == ((), 0) and p_out == CONST0)
                    or (want == ((), 1) and p_out == CONST1)
                    or want == _canon(*_sig_cone(phys, p_out))):
                proven_luts += 1
            else:
                mismatches.append({"node": nd, "signal": out,
                                   "phys_signal": p_out, "want": want})
        else:
            if chain_scope is not None and idx not in chain_scope:
                continue
            ch = src.chains[idx]
            p_first = sig_map.get(ch.sums[0])
            drv = phys.driver.get(p_first) if p_first is not None else None
            if drv is None or drv[0] != "chain":
                fallback.append(nd)
                continue
            pch = phys.chains[drv[1]]
            if (len(pch.sums) != len(ch.sums)
                    or any(sig_map.get(s) != ps
                           for s, ps in zip(ch.sums, pch.sums))
                    or (ch.cout is not None
                        and sig_map.get(ch.cout) != pch.cout)):
                fallback.append(nd)
                continue
            for bi in range(len(ch.sums)):
                if bi == 0:
                    cin = sig_map.get(ch.cin, ch.cin)
                    if cin != pch.cin:
                        fallback.append((kind, idx, bi))
                        continue
                else:
                    cin = _CARRY_VAR
                # the half's operands reference the same physical signals on
                # both sides by construction; proving that (the shallow
                # skeleton) plus the per-LUT mask proofs above closes the
                # bit by induction along the carry
                shallow = (sig_map.get(ch.a[bi]) == pch.a[bi]
                           and sig_map.get(ch.b[bi]) == pch.b[bi])
                try:
                    deep = True
                    for outer in (TT_XOR3, TT_MAJ3):
                        want = map_support(_compose_half(
                            src, ch.a[bi], ch.b[bi], ch.cin
                            if bi == 0 else _CARRY_VAR, outer))
                        got = _compose_half(
                            phys, pch.a[bi], pch.b[bi], cin, outer)
                        if want is None:
                            raise ValueError("unmapped cone input")
                        if want != got:
                            deep = False
                            break
                    if deep or shallow:
                        # a deep!=shallow disagreement is a cone-depth
                        # artifact (wire-collapsed operand), not corruption
                        proven_bits += 1
                    else:
                        mismatches.append({
                            "node": (kind, idx, bi),
                            "signal": ch.sums[bi],
                            "phys_signal": pch.sums[bi]})
                except ValueError:  # merged cone support > 6 inputs
                    if shallow:
                        proven_bits += 1
                    else:
                        fallback.append((kind, idx, bi))

    return proven_luts, proven_bits, fallback, mismatches


def _po_ok(src: Netlist, re_elab: ReElaboration) -> bool:
    return all(
        [re_elab.sig_map.get(s) for s in bus] == re_elab.phys.pos.get(name)
        for name, bus in src.pos.items())


def symbolic_equivalence_report(src: Netlist,
                                re_elab: ReElaboration) -> dict:
    """Per-ALM symbolic equivalence: truth tables, not test vectors.

    Walks the source in topo order.  LUT nodes compare their canonical
    cone (inputs mapped into physical ids) against the physical driver's
    cone — this is where re-composed absorption masks are verified bit-for-
    bit.  Chain bits compose both sides' operand masks into the sum
    (``XOR3``) and carry (``MAJ3``) functions with ``tt_compose``; a
    merged support wider than 6 inputs is recorded in ``fallback`` for
    lane simulation instead.  ``equivalent`` is True only when every cone
    was proven and none fell back; a symbolic mismatch names the first
    corrupted source node in ``mismatches``.
    """
    proven_luts, proven_bits, fallback, mismatches = _prove_nodes(
        src, re_elab)
    po_ok = _po_ok(src, re_elab)
    sig_map = re_elab.sig_map
    return {
        "name": src.name,
        "method": "symbolic",
        "proven_luts": proven_luts,
        "proven_chain_bits": proven_bits,
        "fallback": fallback,
        "pos_checked": sum(len(b) for b in src.pos.values()),
        "signals_checked": len(sig_map),
        "mismatches": mismatches,
        "po_ok": po_ok,
        "complete": not fallback and po_ok,
        "equivalent": po_ok and not fallback and not mismatches,
    }


def verify_clusters(packed: PackedCircuit, lb_indices,
                    re_elab: ReElaboration | None = None) -> dict:
    """Verify-after-repack, scoped to the dirty clusters.

    Proves exactly the nodes whose ALMs live in ``lb_indices`` — hosted
    / 6-LUT / absorbed LUT cones and the chain bits sited there —
    through the same symbolic engine as the full-circuit report
    (:func:`_prove_nodes`), so the scoped verdict equals the full
    verdict restricted to the scope.  Re-elaboration itself is global
    (cone supports cross cluster boundaries and the signal map must be
    complete) but is linear and shared: pass ``re_elab`` to amortize it
    across calls, or let the function build it.

    Returns a report shaped like :func:`symbolic_equivalence_report`
    with ``method="symbolic_scoped"`` plus the scope description
    (``lbs``, ``scoped_luts``, ``scoped_chains``).  ``equivalent`` means
    every scoped cone proved with no fallback and the primary outputs
    map cleanly; an incremental repack whose dirty set misses a cluster
    this report would have flagged is exactly the bug class the
    property-fuzz suite hunts (scoped == full restricted to scope).
    """
    src = packed.net
    if re_elab is None:
        re_elab = reelaborate(packed)
    lbs = set(int(x) for x in lb_indices)
    alm_scope = {ai for ai, lb in enumerate(packed.alm_lb) if lb in lbs}
    lut_scope = {li for li, ai in packed.lut_site.items()
                 if ai in alm_scope}
    chain_scope = {ci for (ci, bi), ai in packed.chain_site.items()
                   if ai in alm_scope}
    proven_luts, proven_bits, fallback, mismatches = _prove_nodes(
        src, re_elab, lut_scope=lut_scope, chain_scope=chain_scope)
    po_ok = _po_ok(src, re_elab)
    return {
        "name": src.name,
        "method": "symbolic_scoped",
        "lbs": sorted(lbs),
        "scoped_luts": len(lut_scope),
        "scoped_chains": len(chain_scope),
        "proven_luts": proven_luts,
        "proven_chain_bits": proven_bits,
        "fallback": fallback,
        "mismatches": mismatches,
        "po_ok": po_ok,
        "equivalent": po_ok and not fallback and not mismatches,
    }


# ---------------------------------------------------------------------------
# exhaustive residue closure (cones the symbolic pass cannot close)
# ---------------------------------------------------------------------------

#: widest cone support enumerated exhaustively (2^16 assignments as one
#: bit-parallel python-int evaluation; beyond this, lane simulation remains)
EXHAUSTIVE_MAX_SUPPORT = 16

#: narrowest support for which a residue cone is closed through the
#: vectorized evaluator instead of python-int enumeration: the cone pair
#: is extracted into standalone netlists (support signals -> PIs), lowered
#: through the unified CircuitIR, and evaluated as ``2^W`` packed lanes.
#: Measured on the host backend, python ints win at every width up to
#: :data:`EXHAUSTIVE_MAX_SUPPORT` (0.6s vs 1.4s at W=16) because every
#: cone has a unique shape, so the jit compile never amortizes — the
#: default therefore disables the vector path; pass a lower
#: ``vector_min_support`` where compiles amortize (repeated cone shapes,
#: parallel backends).  The path is parity- and corruption-tested either
#: way (``tests/core/test_circuit_ir.py``).
VECTOR_CONE_MIN_SUPPORT = EXHAUSTIVE_MAX_SUPPORT + 1

#: signal count above which lane simulation routes through the fused JAX
#: evaluator by default (``use_jax="auto"``) — big re-elaborations are
#: where the python-int walk dominates equivalence wall time
VECTOR_SIM_MIN_SIGNALS = 4000


def _eval_cone(net: Netlist, targets, var_pat: dict[int, int], mask: int):
    """Bit-parallel evaluation of the cones of ``targets``, treating
    ``var_pat`` signals as free variables (their patterns enumerate every
    assignment).  Returns ``{target: int}``; raises KeyError when a cone
    leaf is neither a constant, a variable, nor a driven signal — that
    cone cannot be closed from this support."""
    val: dict[int, int] = {CONST0: 0, CONST1: mask}
    val.update(var_pat)

    def ev(s: int) -> int:
        if s in val:
            return val[s]
        drv = net.driver[s]        # KeyError -> unclosable leaf
        if drv[0] == "lut":
            i = drv[1]
            ins = [ev(q) for q in net.lut_inputs[i]]
            tt = net.lut_tt[i]
            out = 0
            for m in range(1 << len(ins)):
                if not tt_eval(tt, m):
                    continue
                term = mask
                for j, sv in enumerate(ins):
                    term &= sv if (m >> j) & 1 else (~sv & mask)
                    if term == 0:
                        break
                out |= term
            val[s] = out
            return out
        if drv[0] in ("chain", "cout"):
            ci = drv[1]
            ch = net.chains[ci]
            # ripple only as deep as the requested signal needs: a per-bit
            # residue entry's support covers bits 0..bi only, and deeper
            # bits' operand cones may leave the support entirely
            hi = drv[2] if drv[0] == "chain" else len(ch.sums) - 1
            c = ev(ch.cin)
            for bi in range(hi + 1):
                av, bv = ev(ch.a[bi]), ev(ch.b[bi])
                out = ch.sums[bi]
                if out in var_pat:
                    # the chosen support is not a cut: an enumerated
                    # variable is also an internal node of this cone, so
                    # a consistent valuation does not exist — unclosable
                    raise KeyError(out)
                val[out] = av ^ bv ^ c
                c = (av & bv) | (c & (av ^ bv))
            if drv[0] == "cout" and ch.cout is not None:
                if ch.cout in var_pat:
                    raise KeyError(ch.cout)
                val[ch.cout] = c
            return val[s]
        raise KeyError(s)          # a PI outside the chosen support

    return {t: ev(t) for t in targets}


def _packed_lanes(value: int, n_words: int):
    """A python int's low ``32 * n_words`` bits as uint32 lane words
    (little-endian 32-bit chunks) — the evaluator's vector layout."""
    import numpy as np

    return np.array([(value >> (32 * w)) & 0xFFFFFFFF
                     for w in range(n_words)], dtype=np.uint32)


def _lane_word_mask(n_bits: int, n_words: int):
    """Per-word mask selecting the low ``n_bits`` of an ``n_words``-word
    lane vector (the final word may be partial)."""
    import numpy as np

    mask = np.full(n_words, 0xFFFFFFFF, dtype=np.uint32)
    rem = n_bits - 32 * (n_words - 1)
    if rem < 32:
        mask[-1] = (1 << rem) - 1
    return mask


def _extract_cone_netlist(net: Netlist, targets, support):
    """Extract the cone of ``targets`` over the cut ``support`` into a
    standalone :class:`Netlist` whose PIs are the support signals.

    Mirrors :func:`_eval_cone`'s closure semantics exactly: raises
    ``KeyError`` when a cone leaf is neither a constant, a support
    variable nor a driven signal, and when the chosen support is not a
    cut (an emitted node writes a support signal).  Returns
    ``(mini, sig_map)`` where ``sig_map`` maps original to mini signals.
    """
    mini = Netlist(f"{net.name}.cone")
    pis = mini.add_pi_bus("cut", max(len(support), 1))
    smap: dict[int, int] = {CONST0: CONST0, CONST1: CONST1}
    for s, p in zip(support, pis):
        smap[s] = p
    support_set = set(support)
    chain_depth: dict[int, int] = {}   # emitted ripple depth per chain

    def emit_chain(ci: int, hi: int) -> None:
        ch = net.chains[ci]
        if hi <= chain_depth.get(ci, -1):
            return
        a = [ev(ch.a[b]) for b in range(hi + 1)]
        b_ = [ev(ch.b[b]) for b in range(hi + 1)]
        cin = ev(ch.cin)
        full = hi == len(ch.sums) - 1
        sums, cout = mini.add_chain(a, b_, cin=cin,
                                    want_cout=full and ch.cout is not None)
        for b in range(hi + 1):
            s = ch.sums[b]
            if s in support_set:
                raise KeyError(s)      # support is not a cut
            smap[s] = sums[b]
        if cout is not None:
            if ch.cout in support_set:
                raise KeyError(ch.cout)
            smap[ch.cout] = cout
        chain_depth[ci] = hi

    def ev(s: int) -> int:
        got = smap.get(s)
        if got is not None:
            return got
        drv = net.driver[s]            # KeyError -> undriven leaf
        if drv[0] == "lut":
            # support LUT outputs are pre-seeded into smap (returned as
            # PIs above), so the cut property holds trivially here — only
            # chain-written support signals can break it (emit_chain)
            i = drv[1]
            ins = tuple(ev(q) for q in net.lut_inputs[i])
            out = mini.add_lut(ins, net.lut_tt[i])
            smap[s] = out
            return out
        if drv[0] in ("chain", "cout"):
            ci = drv[1]
            hi = (drv[2] if drv[0] == "chain"
                  else len(net.chains[ci].sums) - 1)
            emit_chain(ci, hi)
            return smap[s]
        raise KeyError(s)              # a PI outside the chosen support

    # deepest-first: residue targets list a chain's sums in increasing
    # bit order, so evaluating in reverse emits each chain once at its
    # max needed depth instead of re-emitting ever-deeper prefixes
    # (emit_chain's depth guard keeps any order correct, just slower)
    for t in reversed(targets):
        ev(t)
    mapped = [smap[t] for t in targets]
    mini.set_po_bus("cone", mapped)
    return mini, smap


def _vector_close_cone(src: Netlist, re_elab: "ReElaboration",
                       support, outs) -> list:
    """Close one residue cone through the unified vectorized evaluator:
    both sides' cones are extracted into standalone netlists (support
    signals become PIs), lowered via the content-cached CircuitIR, and
    evaluated bit-parallel over all ``2^W`` assignments as packed uint32
    lanes.  Returns the mismatching output signals (source side).

    Raises ``KeyError`` exactly where the python-int enumeration would
    (leaf outside the support / support not a cut) — callers treat that
    as "unclosed" and fall back.
    """
    import numpy as np

    from .eval_jax import eval_netlist_jax
    from .netlist import tt_var

    sig_map, phys = re_elab.sig_map, re_elab.phys
    W = len(support)
    n_words = max(1, (1 << W) // 32)

    def lanes_for(mini):
        return {pi: (_packed_lanes(tt_var(j, W), n_words) if j < W
                     else np.zeros(n_words, dtype=np.uint32))
                for j, pi in enumerate(mini.pis)}

    mini_s, map_s = _extract_cone_netlist(src, outs, support)
    mini_p, map_p = _extract_cone_netlist(
        phys, [sig_map[o] for o in outs], [sig_map[s] for s in support])
    vals_s = np.asarray(eval_netlist_jax(mini_s, lanes_for(mini_s), n_words))
    vals_p = np.asarray(eval_netlist_jax(mini_p, lanes_for(mini_p), n_words))
    mask = _lane_word_mask(1 << W, n_words)
    bad = []
    for o in outs:
        d = (vals_s[map_s[o]] ^ vals_p[map_p[sig_map[o]]]) & mask
        if d.any():
            bad.append(o)
    return bad


def _residue_node_spec(src: Netlist, entry):
    """(support signals, output signals) of one symbolic-fallback entry."""
    if entry[0] == "lut":
        ins = [s for s in src.lut_inputs[entry[1]] if s > CONST1]
        return ins, [src.lut_out[entry[1]]]
    ci = entry[1]
    ch = src.chains[ci]
    hi = entry[2] if len(entry) > 2 else len(ch.sums) - 1
    support: list[int] = []
    for s in ([ch.cin] + [ch.a[b] for b in range(hi + 1)]
              + [ch.b[b] for b in range(hi + 1)]):
        if s > CONST1 and s not in support:
            support.append(s)
    outs = [ch.sums[b] for b in range(hi + 1)]
    if ch.cout is not None and hi == len(ch.sums) - 1:
        outs.append(ch.cout)
    return support, outs


def exhaustive_residue_report(src: Netlist, re_elab: ReElaboration,
                              residue,
                              max_support: int = EXHAUSTIVE_MAX_SUPPORT,
                              vector_min_support: int =
                              VECTOR_CONE_MIN_SUPPORT) -> dict:
    """Close symbolic-fallback cones by full truth-table enumeration.

    Each residue entry (a ``symbolic_equivalence_report`` ``fallback``
    item) is re-checked over *every* assignment of its source-side
    support — an exhaustive proof, not a sample.  Narrow cones evaluate
    bit-parallel over one python int (:func:`_eval_cone`); cones with
    ``>= vector_min_support`` support inputs run through the unified
    vectorized evaluator instead (:func:`_vector_close_cone`: both cones
    extracted into standalone netlists with the support as PIs, lowered
    via the shared CircuitIR, ``2^W`` assignments as packed uint32
    lanes), falling back to python ints if extraction cannot close the
    cone.  Cones wider than ``max_support``, or whose physical cone
    reaches a leaf outside the mapped support, stay open (``unclosed``)
    and fall back to lane simulation exactly as before.
    """
    from .netlist import tt_var

    sig_map, phys = re_elab.sig_map, re_elab.phys
    proven = 0
    vector_cones = 0
    unclosed: list = []
    mismatches: list[dict] = []
    for entry in residue:
        support, outs = _residue_node_spec(src, entry)
        W = len(support)
        if (W > max_support or any(s not in sig_map for s in support)
                or any(o not in sig_map for o in outs)):
            unclosed.append(entry)
            continue
        bad = None
        if W >= vector_min_support:
            try:
                bad = _vector_close_cone(src, re_elab, support, outs)
                vector_cones += 1
            except (KeyError, ImportError):
                # extraction could not close the cone, or no jax on this
                # host — the python-int path handles both
                bad = None
        if bad is None:
            mask = (1 << (1 << W)) - 1
            pats = {s: tt_var(j, W) for j, s in enumerate(support)}
            try:
                want = _eval_cone(src, outs, pats, mask)
                got = _eval_cone(
                    phys, [sig_map[o] for o in outs],
                    {sig_map[s]: p for s, p in pats.items()}, mask)
            except KeyError:
                unclosed.append(entry)
                continue
            bad = [o for o in outs if want[o] != got[sig_map[o]]]
        if bad:
            mismatches.append({"node": entry, "signal": bad[0],
                               "phys_signal": sig_map[bad[0]],
                               "support": W})
        else:
            proven += 1
    return {
        "method": "exhaustive",
        "proven_cones": proven,
        "vector_cones": vector_cones,
        "unclosed": unclosed,
        "mismatches": mismatches,
        "max_support": max_support,
    }


# ---------------------------------------------------------------------------
# equivalence checking
# ---------------------------------------------------------------------------


def _resolve_use_jax(use_jax, src: Netlist, phys: Netlist) -> bool:
    """``use_jax="auto"`` routes lane simulation through the fused
    vectorized evaluator (one CircuitIR lowering per side, shared with
    every other consumer) once the circuit pair is big enough for the
    dispatch/compile overhead to pay off; booleans force either path."""
    if use_jax != "auto":
        return bool(use_jax)
    if src.n_signals + phys.n_signals < VECTOR_SIM_MIN_SIGNALS:
        return False
    try:
        import jax  # noqa: F401
    except ImportError:
        return False
    return True


def equivalence_report(src: Netlist, re_elab: ReElaboration,
                       n_vectors: int = 256, seed: int = 0,
                       use_jax: bool | str = "auto") -> dict:
    """Random-vector equivalence proof over ``n_vectors`` lanes.

    Compares every primary output *and* every mapped internal signal, so a
    failure names the first corrupted source signal.  ``use_jax`` routes
    both sides through the fused JAX engine (same lanes, uint32 words);
    otherwise the bit-parallel python oracle runs on arbitrary-width ints.
    The default ``"auto"`` picks the vectorized engine for large circuit
    pairs (>= :data:`VECTOR_SIM_MIN_SIGNALS` combined signals).
    """
    import random

    rng = random.Random(seed)
    phys, sig_map = re_elab.phys, re_elab.sig_map
    use_jax = _resolve_use_jax(use_jax, src, phys)
    pi_vals = {s: rng.getrandbits(n_vectors) for s in src.pis}
    phys_pi_vals = {sig_map[s]: v for s, v in pi_vals.items()}

    def mismatch_entry(s: int, diff: int) -> dict:
        vec = (diff & -diff).bit_length() - 1
        return {
            "signal": s, "phys_signal": sig_map[s], "vector": vec,
            "pi_assignment": {p: (pi_vals[p] >> vec) & 1 for p in src.pis},
        }

    mismatched: list[dict] = []
    if use_jax:
        import numpy as np

        from .eval_jax import eval_netlist_jax

        n_words = (n_vectors + 31) // 32

        def lanes(vals):
            return {s: _packed_lanes(v, n_words) for s, v in vals.items()}

        gv = np.asarray(eval_netlist_jax(src, lanes(pi_vals), n_words))
        pv = np.asarray(eval_netlist_jax(phys, lanes(phys_pi_vals), n_words))
        # vectorized compare of every mapped signal at once; python ints
        # are reconstructed only for the (<= 4 reported) mismatching rows
        idx_src = np.array(sorted(sig_map), dtype=np.int64)
        idx_phys = np.array([sig_map[s] for s in idx_src], dtype=np.int64)
        word_mask = _lane_word_mask(n_vectors, n_words)
        diff_words = (gv[idx_src] ^ pv[idx_phys]) & word_mask[None, :]
        bad_rows = np.nonzero(diff_words.any(axis=1))[0]
        row_of = {int(s): r for r, s in enumerate(idx_src)}
        for r in bad_rows[:4]:
            diff = sum(int(diff_words[r, w]) << (32 * w)
                       for w in range(n_words))
            mismatched.append(mismatch_entry(int(idx_src[r]), diff))
        po_ok = not any(
            diff_words[row_of[s]].any()
            for bus in src.pos.values() for s in bus)
    else:
        src_val = eval_netlist(src, pi_vals, n_vectors)
        phys_val = eval_netlist(phys, phys_pi_vals, n_vectors)
        for s in sorted(sig_map):
            ps = sig_map[s]
            if s not in src_val or ps not in phys_val:
                continue
            if src_val[s] != phys_val[ps]:
                mismatched.append(
                    mismatch_entry(s, src_val[s] ^ phys_val[ps]))
                if len(mismatched) >= 4:
                    break
        po_ok = all(
            src_val[s] == phys_val[sig_map[s]]
            for bus in src.pos.values() for s in bus)
    return {
        "name": src.name,
        "equivalent": po_ok and not mismatched,
        "n_vectors": n_vectors,
        "pos_checked": sum(len(b) for b in src.pos.values()),
        "signals_checked": len(sig_map),
        "mismatches": mismatched,
    }


def assert_equivalent(src: Netlist, re_elab: ReElaboration,
                      n_vectors: int = 256, seed: int = 0,
                      use_jax: bool | str = "auto") -> dict:
    rep = equivalence_report(src, re_elab, n_vectors=n_vectors, seed=seed,
                             use_jax=use_jax)
    if not rep["equivalent"]:
        first = rep["mismatches"][0] if rep["mismatches"] else {}
        raise AssertionError(
            f"{src.name}: packed circuit is NOT equivalent "
            f"(first mismatch: {first})")
    return rep


def check_pack_equivalence(net: Netlist, arch: ArchParams, seed: int = 0,
                           n_vectors: int = 256,
                           use_jax: bool | str = "auto",
                           method: str = "auto", **pack_kwargs) -> dict:
    """Pack ``net`` under ``arch``, re-elaborate, and prove equivalence.

    ``method``: ``"auto"`` runs the per-ALM symbolic fast path first,
    closes any residue cones with <= :data:`EXHAUSTIVE_MAX_SUPPORT`
    support inputs by full truth-table enumeration
    (:func:`exhaustive_residue_report`), and falls back to lane
    simulation only for cones neither pass could close (wide cones — the
    remaining SAT-shaped gap); ``"simulate"`` forces the random-lane
    proof; ``"symbolic"`` returns the symbolic report as-is
    (``equivalent`` is False when incomplete).
    """
    if method not in ("auto", "symbolic", "simulate"):
        raise ValueError(f"unknown equivalence method {method!r}")
    packed = pack(net, arch, seed=seed, **pack_kwargs)
    re_elab = reelaborate(packed)
    if method in ("auto", "symbolic"):
        rep = symbolic_equivalence_report(net, re_elab)
        if (method == "auto" and not rep["equivalent"] and rep["po_ok"]
                and rep["fallback"] and not rep["mismatches"]):
            ex = exhaustive_residue_report(net, re_elab, rep["fallback"])
            rep["exhaustive_proven"] = ex["proven_cones"]
            if ex["mismatches"]:
                rep["mismatches"] = ex["mismatches"]
            else:
                rep["fallback"] = ex["unclosed"]
                if not ex["unclosed"]:
                    rep["method"] = "symbolic+exhaustive"
                    rep["complete"] = True
                    rep["equivalent"] = True
        if method == "auto" and not rep["equivalent"]:
            # incomplete or suspected corruption: the random-lane proof is
            # the authority; keep the symbolic localization alongside
            srep = rep
            rep = equivalence_report(net, re_elab, n_vectors=n_vectors,
                                     seed=seed, use_jax=use_jax)
            rep["method"] = "simulate"
            if srep["mismatches"]:
                rep["symbolic_mismatches"] = srep["mismatches"]
    else:
        rep = equivalence_report(net, re_elab, n_vectors=n_vectors,
                                 seed=seed, use_jax=use_jax)
        rep["method"] = "simulate"
    rep["arch"] = arch.name
    rep["alms"] = packed.n_alms
    rep["concurrent_luts"] = packed.concurrent_luts
    rep["z_fed_bits"] = sum(
        1 for alm in packed.alms for h in alm.halves
        if h.fa is not None and h.fa_feed == "z")
    return rep


def verify_all_archs(net: Netlist, seed: int = 0, n_vectors: int = 256,
                     use_jax: bool | str = "auto",
                     method: str = "auto") -> dict[str, dict]:
    """The apples-to-apples gate: prove pack equivalence under every arch."""
    return {name: check_pack_equivalence(net, arch, seed=seed,
                                         n_vectors=n_vectors, use_jax=use_jax,
                                         method=method)
            for name, arch in ARCHS.items()}
