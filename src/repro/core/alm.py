"""ALM / logic-block architecture models: baseline Stratix-10-like, DD5, DD6.

Area numbers are the paper's Table I (MWTA = minimum-width transistor areas),
path delays are Table II.  Delays not published (plain LUT logic delay, carry
hop, routing) are free parameters of the model, chosen to land the baseline
suites near the paper's Table III Fmax range and held **identical across
architectures** so relative comparisons are fair.  DD6's extra output-mux
delay models the ~8 % frequency penalty reported in §V-B.

An ALM is modeled as two *halves*; each half owns one 1-bit full adder and
two 4-LUTs (combinable into one 5-LUT).  Modes per half:

* ``R`` (related, all archs) — FA operands arrive through the LUT path; the
  half's LUTs may implement fan-out-1 logic feeding the adder (absorption) or
  act as pass-through wires.  The half's LUT output pins are unusable.
* ``C`` (concurrent, DD only) — FA operands arrive through the Z pins
  (AddMux); the half's LUTs host one *unrelated* <=5-input LUT whose output
  uses the spare output pin (O2/O4).
* logic half — no FA in use; hosts one <=5-input LUT (both archs; a plain
  logic ALM is two such halves, or a single 6-LUT across both halves).

Design-space parameterization
-----------------------------
``ArchParams`` is fully data-driven: the DD features are two integers —
``bypass_inputs`` (Z-path operand inputs per ALM half: 0 = baseline,
2 = DD5/DD6) and ``addmux_fanin`` (the per-Z-pin crossbar mux fan-in;
10/60 inputs = the paper's 17 %-populated AddMux) — plus the
``concurrent_6lut`` flag.  :func:`make_arch` derives everything else
(area model, Z-source budget, delay table) from those knobs, so
``BASELINE``/``DD5``/``DD6`` are literally three rows of an architecture
grid (:func:`arch_grid`) and the DD5-vs-DD6 design-space question
("how many bypass inputs, how much AddMux crossbar") becomes a sweep
axis (see :mod:`repro.core.sweep`).

Two views matter to the rest of the stack:

* :meth:`ArchParams.structural_key` — the pack-affecting fields.  Grid
  points sharing a structural key produce *identical* packs, so a sweep
  packs once per key and re-times many delay rows (delays never affect
  packing).
* :meth:`ArchParams.delay_table` — the Table II + free-parameter delays
  as a flat float64 vector over :data:`DELAY_FIELDS`, the row format the
  vectorized timing analyzer (:mod:`repro.core.timing_vec`) gathers from.
"""
from __future__ import annotations

from dataclasses import dataclass, fields

import numpy as np

#: canonical order of the delay parameters inside a delay-table row
DELAY_FIELDS = (
    "t_lbin_to_ah", "t_lbin_to_z", "t_ah_to_adder", "t_z_to_adder",
    "t_lut4", "t_lut5", "t_lut6", "t_carry", "t_sum_out", "t_alm_out",
    "t_out_mux_extra", "t_route_global", "t_route_local",
    "t_wire_hop1", "t_wire_hop2", "t_wire_long",
)


@dataclass(frozen=True)
class ArchParams:
    name: str
    concurrent: bool              # DD5 / DD6: unrelated LUTs in arith ALMs
    concurrent_6lut: bool         # DD6 only
    # per-ALM *tile* area (ALM + its share of crossbars/routing).  Table I
    # gives ALM-only areas (2167.3 -> 2366.6 MWTA) and calls the increase
    # +3.72 % "tile area"; solving (2366.6-2167.3+77.91)/x = 3.72 % puts the
    # baseline tile at ~7452 MWTA/ALM, which we adopt.
    alm_area_mwta: float
    # DD design-space knobs (see module docstring); the canonical DD5/DD6
    # point is (bypass_inputs=2, addmux_fanin=10)
    bypass_inputs: int = 0        # Z-path FA operand inputs per half
    addmux_fanin: int = 10        # crossbar mux fan-in per Z pin (of 60 ins)
    # cluster geometry / budgets
    alms_per_lb: int = 10
    lb_inputs: int = 60
    ext_pin_util: float = 0.9
    direct_link_inputs: int = 40  # LB-to-LB direct wires usable as extra inputs
    lb_outputs: int = 40
    # The AddMux crossbar is 17 % populated: each of the 40 Z pins is a mux
    # with fan-in 10 drawn from the LB's 60 inputs (10/60 crosspoints).  With
    # spread subsets, bipartite matching succeeds until demand nears the pin
    # count, so the budget is one distinct signal per Z pin; Z sources also
    # debit the ordinary LB input budget.  A sparser crossbar (smaller
    # ``addmux_fanin``) supports proportionally fewer distinct sources —
    # :func:`make_arch` derives ``min(lb_outputs, 4 * addmux_fanin)``.
    z_sources: int = 40
    z_local_free: bool = True     # direct-link taps carry neighbouring outputs
    # Table II path delays (ps)
    t_lbin_to_ah: float = 72.61
    t_lbin_to_z: float = 77.05
    t_ah_to_adder: float = 133.4
    t_z_to_adder: float = 68.77
    # model free parameters (ps) — identical across archs
    t_lut4: float = 150.0
    t_lut5: float = 165.0
    t_lut6: float = 180.0
    t_carry: float = 15.0
    t_sum_out: float = 90.0
    t_alm_out: float = 60.0
    t_out_mux_extra: float = 0.0  # DD6 output-mux penalty
    t_route_global: float = 620.0
    t_route_local: float = 160.0
    # routed-fabric model (see repro.core.place): the LB grid the placer
    # legalizes onto and the tiered wire hierarchy an inter-LB edge rides
    # (tile-local / 1-hop / 2-hop / long wires, apicula-style).  Wire-tier
    # delays default to ZERO so the placement-free timing numbers are
    # reproduced bit-for-bit; a routed-fabric grid point sets them.
    grid_aspect: float = 1.0      # W/H aspect of the LB placement grid
    channel_width: int = 400      # routing tracks per channel (Fig. 8 proxy)
    t_wire_hop1: float = 0.0      # extra ps for a 1-hop inter-LB route
    t_wire_hop2: float = 0.0      # extra ps for a 2-hop route
    t_wire_long: float = 0.0      # extra ps for a long-wire (>2 hop) route

    @property
    def input_budget(self) -> int:
        return int(self.lb_inputs * self.ext_pin_util) + int(
            self.direct_link_inputs * self.ext_pin_util
        )

    @property
    def output_budget(self) -> int:
        return self.lb_outputs

    def lut_delay(self, k: int) -> float:
        if k <= 4:
            return self.t_lut4
        if k == 5:
            return self.t_lut5
        return self.t_lut6

    # -- data-driven views ---------------------------------------------------
    def delay_table(self) -> np.ndarray:
        """All delay parameters as a float64 vector over DELAY_FIELDS —
        one row of the batched delay tensor the vectorized timing
        analyzer gathers from."""
        return np.array([getattr(self, f) for f in DELAY_FIELDS],
                        dtype=np.float64)

    def structural_key(self) -> tuple:
        """The pack-affecting fields.  Two archs with equal structural
        keys produce identical ``pack()`` results (delays never steer the
        packer), which is what lets a design-space sweep pack once per
        key and re-time every delay row of the class in one batch."""
        return (self.concurrent, self.concurrent_6lut, self.bypass_inputs,
                self.alms_per_lb, self.lb_inputs, self.ext_pin_util,
                self.direct_link_inputs, self.lb_outputs, self.z_sources,
                self.z_local_free)

    def placement_key(self) -> tuple:
        """The placement-affecting fields: the structural key (it decides
        the pack, hence the LB graph) plus the grid geometry.  Wire-tier
        delays and ``channel_width`` are deliberately absent — the
        analytic placer minimizes wirelength, not timing, so every delay
        row of a class shares one placement (the sweep engine's
        place-once-retime-many contract)."""
        return self.structural_key() + (self.grid_aspect,)


_FIELD_DEFAULTS = {f.name: f.default for f in fields(ArchParams)}

# -- the area/delay model behind make_arch ----------------------------------
_BASE_TILE = 7452.0
#: Table I: the AddMux crossbar's share of the +3.72 % DD5 tile delta,
#: at the canonical (2 bypass inputs x fan-in 10) point
_XBAR_MWTA = 77.91
#: the remaining ALM-internal share (AddMux drivers + output muxing):
#: 0.0372 * 7452 - 77.91, so the canonical point lands exactly on x1.0372
_ALM_BYPASS_MWTA = 0.0372 * _BASE_TILE - _XBAR_MWTA
#: DD6's extra 6-LUT output muxing (estimated): lands exactly on x1.043
_LUT6_MWTA = (1.043 - 1.0372) * _BASE_TILE
#: ps of extra Z-pin mux delay per crossbar input beyond the canonical 10
_T_Z_FANIN_SLOPE = 0.9


def make_arch(name: str, bypass_inputs: int = 0, addmux_fanin: int = 10,
              lut6: bool = False, z_sources: int | None = None,
              **overrides) -> ArchParams:
    """Build an architecture grid point from the DD design-space knobs.

    Everything the packer and timer need is derived:

    * ``concurrent`` = ``bypass_inputs >= 1`` (an FA operand can bypass
      the LUTs at all), ``concurrent_6lut`` = ``lut6``;
    * area: baseline tile + the ALM-internal bypass cost (scales with
      bypass width) + the AddMux crossbar cost (scales with bypass width
      x fan-in) + the DD6 output-mux cost.  The canonical points
      reproduce Table I exactly: (2, 10) -> x1.0372, +lut6 -> x1.043;
    * ``z_sources`` = ``min(lb_outputs, 4 * addmux_fanin)`` — a sparser
      crossbar resolves fewer distinct sources by bipartite matching;
    * delays: with any bypass the LUT-path adder feed pays the AddMux
      (Table II: 133.4 -> 202.2 ps), and the Z-pin mux slows by
      ``_T_Z_FANIN_SLOPE`` ps per crossbar input beyond fan-in 10.

    ``overrides`` are applied last (escape hatch for ablations).
    """
    if bypass_inputs < 0 or bypass_inputs > 2:
        raise ValueError("bypass_inputs must be 0..2 (2 FA operands/half)")
    if lut6 and bypass_inputs < 2:
        raise ValueError("concurrent 6-LUTs require 2 bypass inputs/half")
    concurrent = bypass_inputs >= 1
    w = bypass_inputs / 2.0
    if bypass_inputs == 2 and addmux_fanin == 10:
        # the published Table I points, verbatim (the additive
        # decomposition below reproduces them only to the last ulp)
        area = _BASE_TILE * (1.043 if lut6 else 1.0372)
    else:
        area = _BASE_TILE + w * _ALM_BYPASS_MWTA \
            + w * _XBAR_MWTA * (addmux_fanin / 10.0)
        if lut6:
            area += _LUT6_MWTA
    lb_outputs = overrides.get("lb_outputs", _FIELD_DEFAULTS["lb_outputs"])
    params = dict(
        name=name,
        concurrent=concurrent,
        concurrent_6lut=lut6,
        alm_area_mwta=area,
        bypass_inputs=bypass_inputs,
        addmux_fanin=addmux_fanin,
        z_sources=(min(lb_outputs, 4 * addmux_fanin) if z_sources is None
                   else z_sources),
        t_ah_to_adder=202.2 if concurrent else 133.4,
        t_lbin_to_z=77.05 + _T_Z_FANIN_SLOPE * (addmux_fanin - 10),
        t_out_mux_extra=60.0 if lut6 else 0.0,
    )
    params.update(overrides)
    return ArchParams(**params)


def arch_grid(bypass_inputs=(0, 2), addmux_fanin=(5, 10, 20),
              lut6=(False, True), alms_per_lb=(10,), lb_inputs=(60,),
              ext_pin_util=(0.9,), direct_link_inputs=(40,),
              wire_delays=((0.0, 0.0, 0.0),)) -> list[ArchParams]:
    """The DD design-space grid: bypass width x crossbar population x
    6-LUT concurrency, crossed with the **structural cluster-geometry
    axes** the paper holds fixed at the Stratix-10-like point —
    ``alms_per_lb`` (LB capacity), ``lb_inputs`` (crossbar input pins)
    and ``ext_pin_util`` (usable-pin fraction) — and with the
    **routed-fabric axis** ``wire_delays``: ``(t_wire_hop1, t_wire_hop2,
    t_wire_long)`` tier triples the placement-aware timing path consumes
    (non-structural: every triple of a class shares one pack AND one
    placement).  All extra axes default to singleton canonical values, so
    the historical 7-point grid is unchanged; widening any of them
    multiplies the grid (the incremental repacker in
    :mod:`repro.core.repack` and the placement cache in
    :mod:`repro.core.place` are what keep that affordable).  Infeasible
    corners (lut6 without full bypass) and redundant baseline fan-in
    points are dropped; the canonical baseline/DD5/DD6 rows appear under
    grid names (``b0``, ``b2_f10``, ``b2_f10_l6``) with identical
    parameters; non-canonical points carry
    ``_a<alms>``/``_i<inputs>``/``_u<util%>``/``_w<hop1>`` suffixes."""
    grid: list[ArchParams] = []
    seen: set[tuple] = set()
    for b in bypass_inputs:
        fanins = addmux_fanin if b else (10,)   # no crossbar without bypass
        for f in fanins:
            for l6 in lut6:
                if l6 and b < 2:
                    continue
                for apl in alms_per_lb:
                    for li in lb_inputs:
                        for u in ext_pin_util:
                            for dli in direct_link_inputs:
                                for wd in wire_delays:
                                    w1, w2, wl = wd
                                    name = (f"b{b}" + (f"_f{f}" if b else "")
                                            + ("_l6" if l6 else "")
                                            + (f"_a{apl}" if apl != 10
                                               else "")
                                            + (f"_i{li}" if li != 60 else "")
                                            + (f"_u{round(u * 100)}"
                                               if u != 0.9 else "")
                                            + (f"_d{dli}" if dli != 40
                                               else "")
                                            + (f"_w{round(w1)}" if any(wd)
                                               else ""))
                                    key = (b, f if b else 10, l6, apl, li,
                                           u, dli, wd)
                                    if key in seen:
                                        continue
                                    seen.add(key)
                                    grid.append(make_arch(
                                        name, bypass_inputs=b,
                                        addmux_fanin=f, lut6=l6,
                                        alms_per_lb=apl, lb_inputs=li,
                                        ext_pin_util=u,
                                        direct_link_inputs=dli,
                                        t_wire_hop1=w1, t_wire_hop2=w2,
                                        t_wire_long=wl))
    return grid


def full_arch_grid(wire_delays=((0.0, 0.0, 0.0),)) -> list[ArchParams]:
    """The *entire* DD design-space cross-product — every axis of
    :func:`arch_grid` widened at once:

    bypass (0/1/2) x AddMux fan-in (5/8/10/14/20) x 6-LUT concurrency x
    ``alms_per_lb`` (6/8/10/12/14) x ``lb_inputs`` (40/48/60) x
    ``ext_pin_util`` (0.7/0.8/0.9/1.0) x ``direct_link_inputs`` (20/40)
    = **1920 grid points over 1200 structural classes**.  Fan-ins
    10/14/20 saturate the ``z_sources`` budget, so they pack identically
    and differ only in delay rows — every point is still a distinct
    delay row (fan-in moves the Z-pin mux delay).

    ``wire_delays`` crosses in the wire-tier axis (``_w{n}``-suffixed
    rows per extra profile).  The default keeps it flat: in an unplaced
    sweep all wire rows time identically, padding the point count
    without adding design space.  A *placed* search
    (``search_archs(place=True)``) passes real profiles here — annealed
    placements price the tiers, so the wire rows stop tying and the
    axis becomes searchable.

    This is the search space :mod:`repro.core.search` halves over —
    dense-sweeping it costs ~1200 re-clusterings per circuit, which is
    exactly what the successive-halving driver avoids.
    """
    return arch_grid(
        bypass_inputs=(0, 1, 2),
        addmux_fanin=(5, 8, 10, 14, 20),
        lut6=(False, True),
        alms_per_lb=(6, 8, 10, 12, 14),
        lb_inputs=(40, 48, 60),
        ext_pin_util=(0.7, 0.8, 0.9, 1.0),
        direct_link_inputs=(20, 40),
        wire_delays=wire_delays)


def subgrid(archs, n: int, must_include=("b0", "b2_f10")) -> list[ArchParams]:
    """A deterministic ``n``-point slice of ``archs`` for dense-vs-search
    cost comparisons: evenly strided over the grid order, with the named
    canonical rows (baseline, DD5) forced in so ratios stay anchored."""
    by_name = {a.name: a for a in archs}
    picked: dict[str, ArchParams] = {}
    for name in must_include:
        if name in by_name:
            picked[name] = by_name[name]
    stride = max(1, len(archs) // max(n, 1))
    for a in archs[::stride]:
        if len(picked) >= n:
            break
        picked.setdefault(a.name, a)
    return list(picked.values())


def group_archs_by_structure(archs) -> list[list[int]]:
    """Indices of ``archs`` grouped by structural key (pack-sharing
    classes), preserving first-seen order."""
    groups: dict[tuple, list[int]] = {}
    for i, a in enumerate(archs):
        groups.setdefault(a.structural_key(), []).append(i)
    return list(groups.values())


# canonical paper rows — three points of the grid (checked by tests to land
# exactly on the Table I ratios the seed hard-coded)
BASELINE = make_arch("baseline", bypass_inputs=0)
DD5 = make_arch("dd5", bypass_inputs=2, addmux_fanin=10)
DD6 = make_arch("dd6", bypass_inputs=2, addmux_fanin=10, lut6=True)

ARCHS = {a.name: a for a in (BASELINE, DD5, DD6)}


def get_arch(name: str) -> ArchParams:
    return ARCHS[name]
