"""ALM / logic-block architecture models: baseline Stratix-10-like, DD5, DD6.

Area numbers are the paper's Table I (MWTA = minimum-width transistor areas),
path delays are Table II.  Delays not published (plain LUT logic delay, carry
hop, routing) are free parameters of the model, chosen to land the baseline
suites near the paper's Table III Fmax range and held **identical across
architectures** so relative comparisons are fair.  DD6's extra output-mux
delay models the ~8 % frequency penalty reported in §V-B.

An ALM is modeled as two *halves*; each half owns one 1-bit full adder and
two 4-LUTs (combinable into one 5-LUT).  Modes per half:

* ``R`` (related, all archs) — FA operands arrive through the LUT path; the
  half's LUTs may implement fan-out-1 logic feeding the adder (absorption) or
  act as pass-through wires.  The half's LUT output pins are unusable.
* ``C`` (concurrent, DD only) — FA operands arrive through the Z pins
  (AddMux); the half's LUTs host one *unrelated* <=5-input LUT whose output
  uses the spare output pin (O2/O4).
* logic half — no FA in use; hosts one <=5-input LUT (both archs; a plain
  logic ALM is two such halves, or a single 6-LUT across both halves).
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ArchParams:
    name: str
    concurrent: bool              # DD5 / DD6: unrelated LUTs in arith ALMs
    concurrent_6lut: bool         # DD6 only
    # per-ALM *tile* area (ALM + its share of crossbars/routing).  Table I
    # gives ALM-only areas (2167.3 -> 2366.6 MWTA) and calls the increase
    # +3.72 % "tile area"; solving (2366.6-2167.3+77.91)/x = 3.72 % puts the
    # baseline tile at ~7452 MWTA/ALM, which we adopt.
    alm_area_mwta: float
    # cluster geometry / budgets
    alms_per_lb: int = 10
    lb_inputs: int = 60
    ext_pin_util: float = 0.9
    direct_link_inputs: int = 40  # LB-to-LB direct wires usable as extra inputs
    lb_outputs: int = 40
    # The AddMux crossbar is 17 % populated: each of the 40 Z pins is a mux
    # with fan-in 10 drawn from the LB's 60 inputs (10/60 crosspoints).  With
    # spread subsets, bipartite matching succeeds until demand nears the pin
    # count, so the budget is one distinct signal per Z pin; Z sources also
    # debit the ordinary LB input budget.
    z_sources: int = 40
    z_local_free: bool = True     # direct-link taps carry neighbouring outputs
    # Table II path delays (ps)
    t_lbin_to_ah: float = 72.61
    t_lbin_to_z: float = 77.05
    t_ah_to_adder: float = 133.4
    t_z_to_adder: float = 68.77
    # model free parameters (ps) — identical across archs
    t_lut4: float = 150.0
    t_lut5: float = 165.0
    t_lut6: float = 180.0
    t_carry: float = 15.0
    t_sum_out: float = 90.0
    t_alm_out: float = 60.0
    t_out_mux_extra: float = 0.0  # DD6 output-mux penalty
    t_route_global: float = 620.0
    t_route_local: float = 160.0

    @property
    def input_budget(self) -> int:
        return int(self.lb_inputs * self.ext_pin_util) + int(
            self.direct_link_inputs * self.ext_pin_util
        )

    @property
    def output_budget(self) -> int:
        return self.lb_outputs

    def lut_delay(self, k: int) -> float:
        if k <= 4:
            return self.t_lut4
        if k == 5:
            return self.t_lut5
        return self.t_lut6


_BASE_TILE = 7452.0

BASELINE = ArchParams(
    name="baseline",
    concurrent=False,
    concurrent_6lut=False,
    alm_area_mwta=_BASE_TILE,
)

DD5 = ArchParams(
    name="dd5",
    concurrent=True,
    concurrent_6lut=False,
    alm_area_mwta=_BASE_TILE * 1.0372,  # +3.72 % tile area (Table I)
    t_ah_to_adder=202.2,                # +51.6 % vs baseline (Table II)
)

DD6 = ArchParams(
    name="dd6",
    concurrent=True,
    concurrent_6lut=True,
    alm_area_mwta=_BASE_TILE * 1.043,   # extra output muxing (estimated)
    t_ah_to_adder=202.2,
    t_out_mux_extra=60.0,               # drives the ~8 % Fmax penalty of §V-B
)

ARCHS = {a.name: a for a in (BASELINE, DD5, DD6)}


def get_arch(name: str) -> ArchParams:
    return ARCHS[name]
