"""Benchmark circuit generators.

The paper evaluates on three suites (Table III).  No Verilog frontend exists
in this container, so each suite is re-generated from its published
structural description, scaled to laptop size (relative area/delay deltas are
the reproduction target — see DESIGN.md §3):

* **Kratos-like** [Dai et al., FPL'24]: unrolled DNN layers — every weight a
  compile-time constant, sparsity = fraction of zero weights, mixed
  precision.  Adder-dominated (paper: 61.4 % average adder fraction).
* **Koios-like** [Arora et al.]: ML accelerators with *runtime* operands —
  var x var multiplier arrays + accumulators + control logic (22.5 % adders).
* **VTR-like** [Rose et al.]: general logic — random control networks,
  comparators, small accumulators (19.5 % adders).
* **SHA-like**: 32-bit modular adds + Ch/Maj/Sigma logic, the filler circuit
  of the paper's end-to-end stress test (Table IV).
"""
from __future__ import annotations

import random

from .netlist import (CONST0, Netlist, TT_AND2, TT_MAJ3, TT_NOT, TT_OR2,
                      TT_XOR2, TT_XOR3, tt_from_fn)
from .synth import synth_dot_const, synth_var_mult, Row, reduce_rows
from .techmap import techmap


def _relu(net: Netlist, bus, sign_bit):
    """out = sign ? 0 : x  (bitwise AND with NOT sign)."""
    tt = tt_from_fn(lambda x, s: x & (1 - s), 2)
    return [net.add_lut((b, sign_bit), tt) for b in bus]


def _rand_weights(rng: random.Random, n: int, bits: int, sparsity: float,
                  signed: bool = True):
    ws = []
    for _ in range(n):
        if rng.random() < sparsity:
            ws.append(0)
        else:
            w = rng.getrandbits(bits)
            while w == 0:
                w = rng.getrandbits(bits)
            ws.append(w)
    return ws


# ---------------------------------------------------------------------------
# Kratos-like (unrolled DNN, constant weights)
# ---------------------------------------------------------------------------


def kratos_conv1d(name="conv1d-fu", in_ch=4, out_ch=8, taps=3, n_pos=4,
                  width=6, sparsity=0.5, algo="wallace", seed=0) -> Netlist:
    rng = random.Random(seed)
    net = Netlist(name)
    xs = {}
    for c in range(in_ch):
        for p in range(n_pos + taps - 1):
            xs[(c, p)] = net.add_pi_bus(f"x{c}_{p}", width)
    for o in range(out_ch):
        w = _rand_weights(rng, in_ch * taps, width, sparsity)
        for p in range(n_pos):
            buses = [xs[(c, p + t)] for c in range(in_ch) for t in range(taps)]
            acc = synth_dot_const(net, buses, w, width, algo=algo, signed=True)
            out = _relu(net, acc, acc[-1])
            net.set_po_bus(f"y{o}_{p}", out)
    return techmap(net.sweep())


def kratos_conv2d(name="conv2d-fu", in_ch=2, out_ch=4, k=3, n_pos=3,
                  width=6, sparsity=0.5, algo="wallace", seed=0) -> Netlist:
    rng = random.Random(seed)
    net = Netlist(name)
    span = n_pos + k - 1
    xs = {}
    for c in range(in_ch):
        for i in range(span):
            for j in range(span):
                xs[(c, i, j)] = net.add_pi_bus(f"x{c}_{i}_{j}", width)
    for o in range(out_ch):
        w = _rand_weights(rng, in_ch * k * k, width, sparsity)
        for pi in range(n_pos):
            for pj in range(n_pos):
                buses = [xs[(c, pi + di, pj + dj)]
                         for c in range(in_ch)
                         for di in range(k) for dj in range(k)]
                acc = synth_dot_const(net, buses, w, width, algo=algo,
                                      signed=True)
                out = _relu(net, acc, acc[-1])
                net.set_po_bus(f"y{o}_{pi}_{pj}", out)
    return techmap(net.sweep())


def kratos_gemm(name="gemm-fu", m=8, n=8, width=6, sparsity=0.5,
                algo="wallace", seed=0) -> Netlist:
    """y = W @ x with constant W (m outputs, n inputs)."""
    rng = random.Random(seed)
    net = Netlist(name)
    xs = [net.add_pi_bus(f"x{j}", width) for j in range(n)]
    for i in range(m):
        w = _rand_weights(rng, n, width, sparsity)
        acc = synth_dot_const(net, xs, w, width, algo=algo, signed=True)
        net.set_po_bus(f"y{i}", acc)
    return techmap(net.sweep())


def kratos_fc(name="fc-fu", m=12, n=12, width=4, sparsity=0.5,
              algo="wallace", seed=0) -> Netlist:
    net = kratos_gemm(name, m=m, n=n, width=width, sparsity=sparsity,
                      algo=algo, seed=seed)
    net.name = name
    return net


def kratos_suite(algo="wallace", scale=1.0, seed=0) -> list[Netlist]:
    s = scale
    return [
        kratos_conv1d("conv1d-fu", in_ch=max(2, int(4 * s)), out_ch=max(4, int(8 * s)),
                      width=6, sparsity=0.5, algo=algo, seed=seed),
        kratos_conv1d("conv1d-pw-fu", in_ch=max(2, int(4 * s)), out_ch=max(4, int(8 * s)),
                      taps=1, width=6, sparsity=0.5, algo=algo, seed=seed + 1),
        kratos_conv2d("conv2d-fu", in_ch=2, out_ch=max(2, int(4 * s)),
                      width=6, sparsity=0.5, algo=algo, seed=seed + 2),
        kratos_gemm("gemms-fu", m=max(4, int(8 * s)), n=max(4, int(8 * s)),
                    width=6, sparsity=0.5, algo=algo, seed=seed + 3),
        kratos_gemm("gemmt-fu", m=max(4, int(10 * s)), n=max(4, int(10 * s)),
                    width=6, sparsity=0.5, algo=algo, seed=seed + 4),
        kratos_fc("fc-fu", m=max(6, int(12 * s)), n=max(6, int(12 * s)),
                  width=4, sparsity=0.5, algo=algo, seed=seed + 5),
        kratos_gemm("gemm-dense-fu", m=max(4, int(8 * s)), n=max(4, int(8 * s)),
                    width=8, sparsity=0.25, algo=algo, seed=seed + 6),
    ]


# ---------------------------------------------------------------------------
# Koios-like (runtime operands: multiplier arrays + control)
# ---------------------------------------------------------------------------


def _random_logic(net: Netlist, rng: random.Random, inputs, n_nodes, k=4):
    pool = list(inputs)
    outs = []
    for _ in range(n_nodes):
        kk = rng.randint(2, k)
        ins = tuple(rng.sample(pool, min(kk, len(pool))))
        tt = rng.getrandbits(1 << len(ins))
        o = net.add_lut(ins, tt)
        pool.append(o)
        outs.append(o)
    return outs


def koios_mac_array(name="dla-like", pes=4, width=6, algo="wallace",
                    seed=0, ctrl_nodes=120, acc_width=28) -> Netlist:
    """ML-accelerator-like: var x var multipliers, a reduction tree, wide
    output accumulators fed by the (registered) reduction result, plus
    control/address logic."""
    rng = random.Random(seed)
    net = Netlist(name)
    outs = []
    for p in range(pes):
        x = net.add_pi_bus(f"x{p}", width)
        wv = net.add_pi_bus(f"w{p}", width)
        prod = synth_var_mult(net, x, wv, algo=algo, signed=True)
        outs.append(prod)
    # reduce products on carry chains
    rows = [Row(0, tuple(b)) for b in outs]
    acc = reduce_rows(net, rows, "binary", width_cap=2 * width + pes)
    from .synth import row_to_bus

    acc_bus = row_to_bus(acc, 2 * width + pes)
    net.set_po_bus("acc", acc_bus)
    # wide output accumulators (acc_reg += dot): operands are internal
    # (registered) buses — classic Koios accumulate stage
    state = net.add_pi_bus("acc_state", acc_width)
    ext = list(acc_bus) + [acc_bus[-1]] * (acc_width - len(acc_bus))
    new_state, _ = net.add_chain(list(state), ext[:acc_width])
    net.set_po_bus("acc_next", new_state)
    # control / address-generation logic
    ctrl_in = net.add_pi_bus("ctrl", 16)
    nodes = _random_logic(net, rng, ctrl_in, ctrl_nodes)
    net.set_po_bus("ctrl_out", nodes[-16:])
    return techmap(net.sweep())


def koios_suite(algo="wallace", scale=1.0, seed=0) -> list[Netlist]:
    s = scale
    return [
        koios_mac_array("dla-like", pes=max(2, int(4 * s)), width=6,
                        algo=algo, seed=seed),
        koios_mac_array("tpu-like", pes=max(2, int(6 * s)), width=8,
                        algo=algo, seed=seed + 1, ctrl_nodes=200),
        koios_mac_array("dnnweaver-like", pes=max(2, int(3 * s)), width=4,
                        algo=algo, seed=seed + 2, ctrl_nodes=300),
        koios_mac_array("conv-like", pes=max(2, int(5 * s)), width=6,
                        algo=algo, seed=seed + 3, ctrl_nodes=80),
        koios_mac_array("lstm-like", pes=max(2, int(4 * s)), width=8,
                        algo=algo, seed=seed + 4, ctrl_nodes=150),
    ]


# ---------------------------------------------------------------------------
# VTR-like (general logic)
# ---------------------------------------------------------------------------


def vtr_mixed(name="or1200-like", n_in=32, logic_nodes=500, adders=2,
              add_width=16, seed=0) -> Netlist:
    """General-logic circuit: a random control network whose internal nodes
    feed datapath adders (as in real cores, where ALU operands come from
    muxed/registered internal logic, not from pins)."""
    rng = random.Random(seed)
    net = Netlist(name)
    ins = net.add_pi_bus("in", n_in)
    nodes = _random_logic(net, rng, ins, logic_nodes)
    po_nodes = nodes[-min(32, len(nodes)):]
    for a in range(adders):
        # operands: mix of internal logic nodes and pins
        if a % 2 == 0 and len(nodes) >= 2 * add_width:
            xa = [rng.choice(nodes) for _ in range(add_width)]
            xb = [rng.choice(nodes) for _ in range(add_width)]
        else:
            xa = net.add_pi_bus(f"a{a}", add_width)
            xb = list(net.add_pi_bus(f"b{a}", add_width))
        sums, _ = net.add_chain(list(xa), list(xb))
        net.set_po_bus(f"sum{a}", sums)
    net.set_po_bus("logic", po_nodes)
    return techmap(net.sweep())


def vtr_suite(scale=1.0, seed=0) -> list[Netlist]:
    s = scale
    return [
        vtr_mixed("or1200-like", logic_nodes=int(500 * s), adders=3,
                  add_width=16, seed=seed),
        vtr_mixed("blob-merge-like", logic_nodes=int(800 * s), adders=4,
                  add_width=12, seed=seed + 1),
        vtr_mixed("arm-core-like", logic_nodes=int(1200 * s), adders=6,
                  add_width=24, seed=seed + 2),
        sha_like("sha-like", rounds=max(1, int(2 * s)), seed=seed + 3),
        vtr_mixed("stereovision-like", logic_nodes=int(600 * s), adders=8,
                  add_width=10, seed=seed + 4),
    ]


# ---------------------------------------------------------------------------
# SHA-like (end-to-end stress filler, Table IV)
# ---------------------------------------------------------------------------


def sha_like(name="sha", rounds=2, width=32, seed=0) -> Netlist:
    net = Netlist(name)
    a = net.add_pi_bus("a", width)
    b = net.add_pi_bus("b", width)
    c = net.add_pi_bus("c", width)
    d = net.add_pi_bus("d", width)
    w = net.add_pi_bus("w", width)
    TT_CH = tt_from_fn(lambda e, f, g: (e & f) | ((1 - e) & g), 3)
    for r in range(rounds):
        # Sigma: xor of rotations
        s0 = [net.add_lut((a[(i + 2) % width], a[(i + 13) % width],
                           a[(i + 22) % width]), TT_XOR3) for i in range(width)]
        maj = [net.add_lut((a[i], b[i], c[i]), TT_MAJ3) for i in range(width)]
        ch = [net.add_lut((b[i], c[i], d[i]), TT_CH) for i in range(width)]
        t1, _ = net.add_chain(ch, w)
        t2, _ = net.add_chain(s0, maj)
        t3, _ = net.add_chain(t1, t2)
        new_a, _ = net.add_chain(t3, d)
        a, b, c, d = new_a, a, b, c
        w = t3
    net.set_po_bus("h0", a)
    net.set_po_bus("h1", b)
    return techmap(net.sweep())
