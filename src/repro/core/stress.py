"""Stress tests: packing stress (Fig. 9) and end-to-end SHA stress (Table IV).

The packing sweep drives pack → analyze through ``repro.core.flow`` (the
same pipeline the figure benchmarks use); the Table IV capacity sweep uses
``pack`` directly for its capacity probes and analyzes only the packs that
fit — probe packs' metrics would be discarded.
"""
from __future__ import annotations

import random

from .alm import ArchParams
from .netlist import Netlist


def merge_netlists(nets: list[Netlist], name: str = "merged") -> Netlist:
    """Disjoint union of netlists (fresh signal ids per instance)."""
    out = Netlist(name)
    for k, net in enumerate(nets):
        remap: dict[int, int] = {0: 0, 1: 1}

        def m(s: int) -> int:
            if s not in remap:
                remap[s] = out.new_sig()
            return remap[s]

        for bus_name, bus in net.pi_buses.items():
            for s in bus:
                ns = m(s)
                out.pis.append(ns)
                out.driver[ns] = ("pi", len(out.pis) - 1)
            out.pi_buses[f"i{k}_{bus_name}"] = [remap[s] for s in bus]
        for i in range(net.n_luts):
            ins = tuple(m(s) for s in net.lut_inputs[i])
            idx = len(out.lut_out)
            o = m(net.lut_out[i])
            out.lut_inputs.append(ins)
            out.lut_tt.append(net.lut_tt[i])
            out.lut_out.append(o)
            out.driver[o] = ("lut", idx)
        for ch in net.chains:
            from .netlist import Chain

            ci = len(out.chains)
            nch = Chain(a=[m(s) for s in ch.a], b=[m(s) for s in ch.b],
                        sums=[m(s) for s in ch.sums], cin=m(ch.cin),
                        cout=m(ch.cout) if ch.cout is not None else None)
            out.chains.append(nch)
            for bi, s in enumerate(nch.sums):
                out.driver[s] = ("chain", ci, bi)
            if nch.cout is not None:
                out.driver[nch.cout] = ("cout", ci)
        for bus_name, bus in net.pos.items():
            out.pos[f"i{k}_{bus_name}"] = [remap[s] for s in bus]
    return out


def packing_stress_circuit(n_adders: int = 500, n_luts: int = 0,
                           chain_len: int = 20, op_pool: int = 600,
                           lut_pool: int = 200, seed: int = 0,
                           depth: int = 1) -> Netlist:
    """Fig. 9 synthetic circuit: ``n_adders`` FA bits in chains plus
    ``n_luts`` unrelated 5-LUTs with moderately shared inputs.

    ``depth > 1`` stacks further layers whose operands are drawn from the
    previous layer's outputs, with node counts shrinking 3x per layer —
    a wide-then-narrow level profile, the shape on which the fused
    evaluator's width-bucketed plan cuts padding waste (layer 1 is always
    the classic single-level Fig. 9 circuit).
    """
    rng = random.Random(seed)
    net = Netlist("stress" if depth == 1 else f"stress-d{depth}")
    ops = net.add_pi_bus("ops", op_pool)
    lin = net.add_pi_bus("lin", lut_pool)
    layer = 0
    la, ll = n_adders, n_luts
    while layer < depth and (la > 0 or ll > 0):
        next_ops: list[int] = []
        n_chains = (la + chain_len - 1) // chain_len
        done = 0
        for c in range(n_chains):
            L = min(chain_len, la - done)
            if L <= 0:
                break
            a = [ops[rng.randrange(len(ops))] for _ in range(L)]
            b = [ops[rng.randrange(len(ops))] for _ in range(L)]
            sums, _ = net.add_chain(a, b)
            net.set_po_bus(f"s{layer}_{c}", sums)
            next_ops.extend(sums)
            done += L
        for i in range(ll):
            ins = tuple(rng.sample(lin, min(5, len(lin))))
            tt = rng.getrandbits(32)
            o = net.add_lut(ins, tt)
            net.set_po_bus(f"l{layer}_{i}", [o])
            next_ops.append(o)
        layer += 1
        la, ll = la // 3, ll // 3
        if next_ops:
            ops = next_ops
            lin = next_ops if len(next_ops) >= 5 else lin
    return net


def run_packing_stress(arch: ArchParams, n_adders: int = 500,
                       lut_counts=None, seed: int = 0) -> list[dict]:
    """Sweep added-LUT count; report area and concurrent 5-LUTs (Fig. 9)."""
    from .flow import pack_and_analyze_one

    if lut_counts is None:
        lut_counts = list(range(0, 501, 50))
    out = []
    for nl in lut_counts:
        net = packing_stress_circuit(n_adders=n_adders, n_luts=nl, seed=seed)
        _, r = pack_and_analyze_one(net, arch, seed=seed)
        out.append({"n_luts": nl, "area_mwta": r["area_mwta"],
                    "alms": r["alms"], "concurrent": r["concurrent_luts"]})
    return out


def run_e2e_stress(base_net: Netlist, sha_net: Netlist, arch_list,
                   capacity_lbs: int | None = None, seed: int = 0,
                   max_instances: int = 64) -> dict:
    """Table IV: fix the FPGA size (LBs) from the baseline pack of the base
    circuit + margin, then count how many SHA instances each architecture
    can additionally fit."""
    from .packing import pack
    from .timing import analyze

    results = {}
    if capacity_lbs is None:
        # capacity probe: the pack's LB count is all we need — analyzing
        # here (or the final over-capacity pack below) would be wasted
        # work on the sweep's largest circuits
        p0 = pack(base_net, arch_list[0], seed=seed)
        capacity_lbs = int(p0.n_lbs * 1.3) + 1  # industry-style margin
    for arch in arch_list:
        best = None
        k = 0
        while k <= max_instances:
            merged = merge_netlists([base_net] + [sha_net] * k)
            p = pack(merged, arch, seed=seed)
            if p.n_lbs > capacity_lbs:
                break
            best = (k, p, analyze(p))
            k += 1
        if best is None:
            results[arch.name] = {"instances": 0}
            continue
        k, p, r = best
        n5 = sum(1 for ins in p.net.lut_inputs if len(ins) <= 5)
        results[arch.name] = {
            "instances": k,
            "adders": r["adders"],
            "luts5": n5,
            "concurrent": r["concurrent_luts"],
            "cpd_ps": r["critical_path_ps"],
            "alms": r["alms"],
            "lbs": r["lbs"],
            "area_mwta": r["area_mwta"],
        }
    results["capacity_lbs"] = capacity_lbs
    return results
