"""Architecture design-space sweep: pack once per structural class,
re-time a whole suite across an N-point arch grid in one batched program.

The paper compares three hand-picked architectures (baseline / DD5 / DD6).
With :func:`repro.core.alm.make_arch` the DD design space is two integers
(bypass width x AddMux crossbar fan-in, plus the 6-LUT flag) — and because
delays never steer the packer, every grid point of a *structural class*
(:meth:`ArchParams.structural_key`) shares one ``pack()`` and one
:class:`~repro.core.circuit_ir.CircuitIR`.  A sweep therefore costs:

    packs:   n_circuits x n_structural_classes      (Python, the slow part)
    timing:  one jit program per class — circuits stacked on one ``vmap``
             axis, the class's delay-table rows on another

instead of ``n_circuits x n_grid_points`` Python timing walks.  This opens
the scenario the paper never measured: ADP frontiers over the
bypass-width x crossbar-population plane (:func:`adp_frontier`).

Results are bit-identical to ``timing.analyze_oracle`` per (circuit, grid
point); ``benchmarks/sweep_frontier.py`` gates its recorded speedups on
that parity and writes ``experiments/perf/timing_sweep.json``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from . import plan as _planner
from .alm import ArchParams, group_archs_by_structure
from .netlist import Netlist
from .packing import PackedCircuit, pack

#: packing prefixes per (circuit digest, seed) — the default store behind
#: ``sweep_suite(prefixes=None)``.  Registry-backed so ONE
#: :func:`repro.core.plan.clear_caches` drops it together with the IR
#: templates the prefixes hand out (the PR-6 placement-cache rule);
#: callers may still pass their own plain dict.
_PREFIX_CACHE = _planner.register_cache("pack_prefix", cap=64)
from .timing import record_timing_wall


def prefix_for_edit(base, new_net: Netlist, base_log=None, prefixes=None):
    """Resolve an *edited* netlist's packing prefix through the shared
    prefix store, deriving it with
    :func:`repro.core.repack.pack_prefix_delta` on a miss.

    Edited prefixes are hosted under ``(edited pack digest, base content
    digest, base seed)``: pack digest because truth tables never steer
    packing (the same keying as the serving pack store), base digest
    because a delta-derived prefix replays the *base's* decisions, so
    derivations from two different bases must never collide.  On a hit
    whose cached ``.net`` is a different tt-variant of the same packing
    structure, the prefix is rebound to ``new_net`` — every other field
    is structure-only, and the IR template is content-keyed so it simply
    misses for the new truth tables.

    Returns ``(prefix | None, info)``; ``info`` is the
    ``pack_prefix_delta`` info dict plus a ``"store"`` key (``"hit"`` /
    ``"miss"``).  ``None`` means the edit is outside the delta-eligible
    class — the caller re-runs :func:`repro.core.repack.pack_prefix`.
    """
    from dataclasses import replace

    from .repack import pack_prefix_delta

    store = _PREFIX_CACHE if prefixes is None else prefixes
    key = (new_net.pack_digest(), base.net.content_digest(), base.seed)
    hit = store.get(key)
    if hit is not None:
        prefix, info = hit
        info = dict(info, store="hit")
        if prefix.net.content_digest() != new_net.content_digest():
            prefix = replace(prefix, net=new_net)
            # the stored changed_tt describes the stored tt-variant;
            # recompute it against the actual request
            info["changed_tt"] = [
                li for li in range(base.net.n_luts)
                if base.net.lut_tt[li] != new_net.lut_tt[li]]
        return prefix, info
    prefix, info = pack_prefix_delta(base, new_net, base_log=base_log)
    if prefix is not None:
        # the info rides with the prefix: a later hit must replay with
        # the SAME dirty set or the advised re-cluster would trust
        # recorded decisions of atoms whose data changed
        store[key] = (prefix, dict(info))
    return prefix, dict(info, store="miss")
from .timing_vec import (build_suite_timing_program, delay_components,
                         critical_path_numpy, metrics_from_cp)


@dataclass
class SweepResult:
    """records[g][k] is the ``timing.analyze``-shaped metric dict of
    circuit ``g`` under arch ``k`` (plus ``net``/``suite`` keys)."""

    circuits: list[str]
    suites: list[str]
    archs: list[str]
    records: list[list[dict]]
    n_classes: int
    wall: dict = field(default_factory=dict)

    def by_arch(self, arch_name: str) -> list[dict]:
        try:
            k = self.archs.index(arch_name)
        except ValueError:
            raise ValueError(
                f"arch {arch_name!r} not in sweep result (swept: "
                f"{self.archs!r})") from None
        return [row[k] for row in self.records]


def _flatten(nets) -> tuple[list[str], list[Netlist]]:
    if isinstance(nets, dict):
        suites, flat = [], []
        for sname, ns in nets.items():
            for n in ns:
                suites.append(sname)
                flat.append(n)
        return suites, flat
    return [""] * len(nets), list(nets)


def _envelope_groups(irs, max_groups: int) -> list[list[int]]:
    """Cluster IRs into <= ``max_groups`` compatible-envelope groups —
    the same shared planner the evaluator uses
    (:func:`repro.core.plan.group_by_envelope`; a :class:`CircuitIR`
    exposes ``.envelope`` / ``.n_signals`` directly, so the old adapter
    shim is gone) — one small circuit must not pad to the suite's widest
    member."""
    from .plan import group_by_envelope

    return group_by_envelope(irs, max_groups=max_groups)


def sweep_suite(nets, archs: Sequence[ArchParams], seed: int = 0,
                max_buckets: int = 3, max_groups: int = 4,
                backend: str = "jax", packs: dict | None = None,
                programs: dict | None = None,
                prefixes: dict | None = None,
                place: bool = False,
                refine: str | None = "anneal") -> SweepResult:
    """Pack + re-time ``nets`` under every arch of the grid.

    ``nets`` is a list of netlists or a ``{suite_name: [netlists]}`` dict.
    The arch-invariant packing prefix (absorption, chain slotting, LUT
    pairing, cluster plan — :func:`repro.core.repack.pack_prefix`) is
    computed once per circuit at ``seed`` and *re-clustered* once per
    structural class, so a grid over pack-affecting knobs (``alms_per_lb``,
    ``lb_inputs``, ``ext_pin_util``, ``z_sources``, bypass width) costs
    ``n_circuits`` prefixes + cheap re-clusterings instead of
    ``n_circuits x n_classes`` full packs.  Lowering is incremental too:
    the first class lowers each circuit fully, sibling classes patch that
    template's placement-derived columns
    (:func:`repro.core.circuit_ir.lower_pack_ir_incremental`; fresh
    lowering shares the same placement patch over the content-cached
    functional IR, so levelization runs once per circuit digest).

    Timing runs as <= ``max_groups`` batched jit programs per class
    (circuits clustered by envelope compatibility so small members do not
    pad to the widest one; ``backend="jax"``) or as per-circuit numpy
    level walks (``backend="numpy"`` — still vectorized, no compile;
    useful for tiny grids).

    Pass ``packs``, ``programs`` and ``prefixes`` (plain dicts,
    caller-owned) to reuse pack results, compiled timing programs and
    packing prefixes across sweeps.  All caches key on the netlists'
    *content digest* (plus structural key / seed / grouping knobs), so a
    cache warmed with one circuit list simply misses — never silently
    serves wrong entries — when reused with a different list.  A warm
    sweep then pays only the batched executions — delay tables are data,
    not shapes.

    ``place=True`` additionally grid-places every circuit and times the
    placed IRs (wire-tier delays included).  Placements are registry-
    cached per ``(circuit digest, arch placement key, seed)`` — the
    placement key is the structural key + grid aspect, *not* the delay
    row — so all wire-delay rows of a class share one placement: a grid
    crossing many wire profiles pays ``n_circuits x n_classes x
    n_aspects`` placements, not one per point (the reuse
    ``benchmarks/place_sweep.py`` gates at >= 2x).  Within a class,
    rows are subgrouped by grid aspect (aspect reshapes the grid, hence
    the hop columns) and each subgroup runs as its own batched program.

    ``refine`` (default ``"anneal"``) anneal-refines every placement
    through :mod:`repro.core.anneal` before timing — transparent to the
    caller, billed separately in ``wall["anneal_s"]`` (a subset of
    ``place_s``).  ``refine=None`` times the raw analytic seeds.  The
    timing-driven mode (``"anneal_timing"``) weights moves by the
    subgroup *representative's* non-wire delay row (the first grid row
    of the class x aspect subgroup) — one placement must still serve
    every wire row of the subgroup, so the wire tiers never steer it.
    """
    from .repack import pack_prefix, repack

    suites, flat = _flatten(nets)
    archs = list(archs)
    classes = group_archs_by_structure(archs)
    records: list[list[dict | None]] = [[None] * len(archs) for _ in flat]
    wall = {"pack_s": 0.0, "prefix_s": 0.0, "recluster_s": 0.0,
            "lower_s": 0.0, "place_s": 0.0, "anneal_s": 0.0,
            "build_s": 0.0, "timing_s": 0.0}
    if packs is None:
        packs = {}
    if programs is None:
        programs = {}
    if prefixes is None:
        prefixes = _PREFIX_CACHE
    digests = [net.content_digest() for net in flat]
    suite_key = tuple(digests)
    class_reps = [archs[idx[0]] for idx in classes]
    skeys = [rep.structural_key() for rep in class_reps]
    # --- phase 1: pack + lower, circuit-outer ---------------------------
    # One prefix per circuit, then its re-clusterings and IR patches for
    # every class back to back: the prefix's plan (and the IR template)
    # stay cache-hot across all classes, which a class-outer loop — one
    # touch per prefix per class, 16 circuits apart — would forfeit.
    all_irs: list[list] = [[] for _ in classes]
    for g, net in enumerate(flat):
        prefix = prefixes.get((digests[g], seed))
        t0 = time.perf_counter()
        circ_packs: list[PackedCircuit] = []
        for c, rep in enumerate(class_reps):
            p = packs.get((digests[g], skeys[c], seed))
            if p is None:
                if prefix is None:
                    t1 = time.perf_counter()
                    prefix = pack_prefix(net, seed=seed)
                    prefixes[(digests[g], seed)] = prefix
                    wall["prefix_s"] += time.perf_counter() - t1
                t1 = time.perf_counter()
                p = repack(prefix, rep)
                wall["recluster_s"] += time.perf_counter() - t1
                packs[(digests[g], skeys[c], seed)] = p
            circ_packs.append(p)
        wall["pack_s"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        for c, p in enumerate(circ_packs):
            tpl = prefix.ir_template if prefix is not None else None
            ir = p.lower_ir(template=tpl)
            if prefix is not None and prefix.ir_template is None:
                prefix.ir_template = ir
            all_irs[c].append(ir)
        wall["lower_s"] += time.perf_counter() - t0
    # --- phase 2: batched timing, class-outer ---------------------------
    # With placement, a class's rows are further subgrouped by grid
    # aspect: aspect reshapes the slot grid (hence every hop column) but
    # wire delays stay pure data, so one placed program per (class,
    # aspect) re-times all of that subgroup's delay rows.
    for c, idx_list in enumerate(classes):
        skey = skeys[c]
        irs = all_irs[c]
        if place:
            by_aspect: dict[float, list[int]] = {}
            for i in idx_list:
                by_aspect.setdefault(archs[i].grid_aspect, []).append(i)
            subgroups = list(by_aspect.values())
        else:
            subgroups = [idx_list]
        for sub_idx in subgroups:
            if place:
                from .anneal import ANNEAL_WALL
                from .circuit_ir import apply_placement
                from .place import placement_for

                rep = archs[sub_idx[0]]
                pkey = rep.placement_key()
                t0 = time.perf_counter()
                a0 = ANNEAL_WALL["s"]
                use_irs = [apply_placement(
                    ir, placement_for(ir, rep, seed, refine=refine))
                    for ir in irs]
                wall["place_s"] += time.perf_counter() - t0
                wall["anneal_s"] += ANNEAL_WALL["s"] - a0
            else:
                pkey = None
                use_irs = irs
            tables = np.stack([archs[i].delay_table() for i in sub_idx])
            if backend == "jax":
                t0 = time.perf_counter()
                # pkey/refine last: positions of the pre-placement key
                # elements (suite, skey, seed, buckets, groups) stay
                # stable for callers/tests that probe grouping knobs by
                # index.  refine is part of the key because the program
                # bakes in the placed hop tensors — a program built from
                # analytic placements must never serve annealed rows.
                prog_key = (suite_key, skey, seed, max_buckets,
                            max_groups, pkey,
                            refine if place else None)
                progs = programs.get(prog_key)
                if progs is None:
                    groups = _envelope_groups(use_irs, max_groups)
                    progs = [(members,
                              build_suite_timing_program(
                                  [use_irs[i] for i in members],
                                  max_buckets=max_buckets))
                             for members in groups]
                    programs[prog_key] = progs
                wall["build_s"] += time.perf_counter() - t0
                t0 = time.perf_counter()
                cps = np.zeros((len(use_irs), len(sub_idx)))
                for members, prog in progs:
                    gcps = prog.run(tables)
                    for row, gi in enumerate(members):
                        cps[gi] = gcps[row]
                wall["timing_s"] += time.perf_counter() - t0
            elif backend == "numpy":
                t0 = time.perf_counter()
                cps = np.zeros((len(use_irs), len(sub_idx)))
                for k in range(len(sub_idx)):
                    comps = delay_components(tables[k])
                    for g, ir in enumerate(use_irs):
                        cps[g, k] = critical_path_numpy(ir, comps)
                wall["timing_s"] += time.perf_counter() - t0
            else:
                raise ValueError(f"unknown sweep backend {backend!r}")
            for g, ir in enumerate(use_irs):
                for k, ai in enumerate(sub_idx):
                    rec = metrics_from_cp(ir, archs[ai], float(cps[g, k]))
                    rec["net"] = flat[g].name
                    rec["suite"] = suites[g]
                    records[g][ai] = rec
    record_timing_wall(wall["timing_s"] + wall["lower_s"] + wall["build_s"],
                       calls=len(flat) * len(archs))
    return SweepResult(
        circuits=[n.name for n in flat], suites=suites,
        archs=[a.name for a in archs], records=records,  # type: ignore
        n_classes=len(classes), wall=wall)


def _geomean(xs):
    xs = [float(x) for x in xs]
    bad = [x for x in xs if not x > 0.0 or not np.isfinite(x)]
    if bad:
        # a non-positive (or NaN/inf) metric ratio is never valid — it
        # means a record upstream is broken; clamping it (the old
        # behaviour) poisoned the whole frontier row by orders of
        # magnitude instead of surfacing the bad record
        raise ValueError(
            f"geomean over metric ratios got non-positive/non-finite "
            f"values {bad[:4]!r} — a sweep record is corrupt")
    return float(np.exp(np.mean(np.log(xs))))


def _circuit_rows(result: SweepResult, circuits) -> list[int]:
    """Record-row indices of ``circuits`` (``None`` = all), with a clear
    error naming any circuit the sweep never evaluated."""
    if circuits is None:
        return list(range(len(result.circuits)))
    idx = []
    for name in circuits:
        try:
            idx.append(result.circuits.index(name))
        except ValueError:
            raise ValueError(
                f"circuit {name!r} not in sweep result (swept: "
                f"{result.circuits!r})") from None
    return idx


def adp_frontier(result: SweepResult, baseline: str | None = None,
                 keys=("area_mwta", "critical_path_ps", "adp"),
                 circuits=None) -> list[dict]:
    """Geomean metric ratios vs the baseline arch, one row per grid point —
    the ADP frontier over the design-space grid (sorted by ADP ratio).

    ``circuits`` restricts the geomean to a named subset — the search
    driver's rung-level frontiers (cheap circuit slice) and the final
    full-suite frontier run through this one code path.  An unknown name
    raises ``ValueError`` instead of surfacing as an opaque KeyError.
    """
    base_name = baseline if baseline is not None else result.archs[0]
    rows_g = _circuit_rows(result, circuits)
    base_all = result.by_arch(base_name)
    base = [base_all[g] for g in rows_g]
    rows = []
    for name in result.archs:
        if name == base_name:
            continue
        recs_all = result.by_arch(name)
        recs = [recs_all[g] for g in rows_g]
        row = {"arch": name}
        for k in keys:
            row[k] = _geomean([r[k] / b[k] for r, b in zip(recs, base)])
        rows.append(row)
    rows.sort(key=lambda r: r.get("adp", 1.0))
    return rows


def oracle_parity(result: SweepResult, nets, archs: Sequence[ArchParams],
                  seed: int = 0, place: bool = False,
                  refine: str | None = "anneal") -> bool:
    """Prove every sweep record's critical path bit-identical to the
    Python oracle (packing under the *actual* arch — structural-class
    pack sharing is part of what this verifies).  With ``place=True``
    the reference is :func:`repro.core.timing.analyze_placed_oracle`
    under the registry-cached placement of each (circuit, placement key)
    — the same placements the sweep consumed (``refine`` must match the
    sweep's), so this also proves the wire-tier gather against the
    per-edge Python walk.  Placements resolve through each grid row's
    *subgroup representative* (the first arch in ``archs`` order sharing
    its placement key), mirroring the sweep's subgrouping — for the
    timing-driven refine mode the representative's delay row is part of
    the placement cache key, so resolving through the row itself would
    anneal a fresh (different) placement and spuriously fail parity."""
    from .timing import analyze_oracle, analyze_placed_oracle

    _, flat = _flatten(nets)
    reps: dict[tuple, ArchParams] = {}
    rep_for = [reps.setdefault(a.placement_key(), a) for a in archs]
    for g, net in enumerate(flat):
        for k, arch in enumerate(archs):
            p = pack(net, arch, seed=seed)
            if place:
                from .place import placement_for

                pl = placement_for(p.lower_ir(), rep_for[k], seed,
                                   refine=refine)
                ro = analyze_placed_oracle(p, pl)
            else:
                ro = analyze_oracle(p)
            if ro["critical_path_ps"] != result.records[g][k][
                    "critical_path_ps"]:
                return False
            if ro["area_mwta"] != result.records[g][k]["area_mwta"]:
                return False
    return True
