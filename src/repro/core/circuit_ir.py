"""Unified columnar circuit IR: one lowering serves eval, timing and
equivalence.

The repro used to carry three independent lowering substrates for the
same packed circuit — the evaluator's padded level tensors
(``eval_jax._level_rows``), the timing stack's signal/edge columns
(``pack_ir.lower_ir``) and the equivalence checker's python-int cone
walks.  Each levelized the netlist again and each kept its own cache.
This module collapses them onto one :class:`CircuitIR` with two lowering
stages:

* **functional lowering** (:func:`lower_netlist_ir`) — once per netlist
  *content digest*: topological levelization, per-level LUT rows with
  64-entry truth-table words (``tt_lo``/``tt_hi``) and chain rows with
  their operand/sum/cout signals, per-signal kind/level columns, the
  fanin CSR topology and the primary-output list.  No architecture, no
  placement.  This is everything the fused evaluator and the
  equivalence lanes need, and it is the shared base of every packed
  lowering of the circuit.  Cached in the registry
  (:mod:`repro.core.plan`, cache ``netlist_ir``).
* **placement patch** (:func:`lower_pack_ir` /
  :func:`lower_pack_ir_incremental`) — once per (digest, structural
  class): a vectorized pass that fills in the placement-derived columns
  — per-signal site/LB, per-ALM mode columns, node delay classes
  (absorption) and every edge delay class (routing locality, A–H vs Z
  pin, adder path).  Both entry points run the *same* patch function
  (:func:`_patch_placement`); they differ only in where the
  netlist-shaped arrays come from (the cached functional IR vs a sibling
  class's :class:`CircuitIR` template), so fresh and
  template-incremental lowering are identical column-for-column **by
  construction**.

Column layout
-------------
Per signal (length ``n_signals``): ``sig_site`` (producing ALM; -1 for
PIs/constants; the -2 "unplaced" sentinel survives in the encoding but
an unplaced LUT *raises* at lowering — the level tables carry every LUT,
so a siteless one would corrupt timing, and the packer must place all of
them), ``sig_lb``, ``sig_kind`` (:data:`K_CONST` … :data:`K_COUT`),
``sig_level``.

Fanin CSR: ``fanin_ptr [S+1]`` / ``fanin_sig [E]`` / ``fanin_cls [E]``
(timing edges, excluding the intra-chain carry recurrence; ``fanin_cls``
is all-zero in functional IRs).

Per ALM (length ``n_alms``; empty in functional IRs): ``alm_lb``,
``alm_is_arith``, ``alm_feed [A, 2]`` (0 = no FA, 1 = LUT-path feed,
2 = Z feed), ``alm_hosted [A, 2]``, ``alm_lut6``.

Levelized node tables: ``lut_levels[t]`` / ``chain_levels[t]`` hold
exact-size (unpadded) row arrays per topological level; executors
pad/stack them as their batching needs dictate (the evaluator via
:func:`repro.core.eval_jax.plan_from_ir`, the timing program via
``timing_vec._pad_levels``).  Constant operands are kept **verbatim** in
the signal columns (``ins`` / ``a_sig`` / ``b_sig`` / ``cin_sig``) with
the null edge class 0: the evaluator must read CONST1's all-ones lane,
and the timing executors gather an arrival of 0.0 through signal 0 *or*
1 with zero delay components either way — bit-identical to the oracle's
"skip constants" reductions.

Edge delay classes
------------------
An edge's delay is the sum of three components — routing
(none / local / global), LB input pin (none / A–H / Z) and adder path
(none / A–H→adder / Z→adder) — encoded as ``route * 9 + pin * 3 + path``
(27 classes).  The per-arch component table is built by
:func:`repro.core.timing_vec.delay_components`; classes are structural
(decided at pack time), components are per delay row, which is exactly
the split that makes arch-grid batching a gather.  Class 0 is the null
edge (constants / padding): all components zero.

Node delay classes (``NDC_*``): absorbed LUTs add nothing (their delay
is folded into the A–H→adder path); placed LUTs add
``lut_delay(k) + t_alm_out + t_out_mux_extra``.

Instrumentation
---------------
:data:`LOWER_COUNTS` counts functional lowerings and placement patches
(full vs template); the no-duplicate-lowering property of the sweep
engine is asserted against it in ``tests/core/test_circuit_ir.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING

import numpy as np

from . import plan as _planner
from .netlist import CONST1, Netlist, tt_words64

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (packing lazily
    from .packing import PackedCircuit  # imports this module via lower_ir)

# signal kinds
K_CONST, K_PI, K_LUT, K_LUT_ABS, K_SUM, K_COUT = range(6)

# edge-class components
ROUTE_NULL, ROUTE_LOCAL, ROUTE_GLOBAL = 0, 1, 2
PIN_NULL, PIN_AH, PIN_Z = 0, 1, 2
PATH_NULL, PATH_AH, PATH_Z = 0, 1, 2
N_EDGE_CLASSES = 27

# wire tiers of a placed inter-LB edge (repro.core.place): tier 0 is the
# null/tile-local wire (zero delay — also every edge of an unplaced IR),
# 1-hop and 2-hop wires carry Manhattan-distance-1/-2 routes, and any
# longer route rides one long wire (the 8-hop spans of the apicula-style
# hierarchy cover the grids the suites legalize onto).
TIER_NONE, TIER_HOP1, TIER_HOP2, TIER_LONG = range(4)
N_WIRE_TIERS = 4

# node delay classes for LUT rows
NDC_ABSORBED, NDC_LUT4, NDC_LUT5, NDC_LUT6 = range(4)
N_NODE_CLASSES = 4


def edge_class(route: int, pin: int, path: int) -> int:
    return route * 9 + pin * 3 + path


#: the unique class of an absorbed chain operand (no route, no pin, the
#: folded A-H adder path) — structural, never produced by any other edge
_CLS_ABSORBED = edge_class(ROUTE_NULL, PIN_NULL, PATH_AH)

#: functional IRs per netlist content digest — the single levelization
_IR_CACHE = _planner.register_cache("netlist_ir", cap=256)

#: packed IRs produced by :func:`apply_pack_delta`, keyed by
#: ``(base_digest, new_digest, structural_key)``.  Invalidation rule:
#: both digests are *content* digests, so an entry can never go stale —
#: a different edit or circuit is a different key — and the cache is
#: eviction-only (LRU) plus the registry-wide ``clear_caches()``.
_PACK_DELTA_CACHE = _planner.register_cache("pack_delta_ir", cap=128)

#: lowering-stage counters (see module docstring); tests assert the
#: one-lowering-per-(circuit, structural class) property against these
LOWER_COUNTS = {"functional": 0, "functional_patch": 0,
                "placement_full": 0, "placement_incremental": 0,
                "placement_delta": 0}


def reset_lower_counts() -> None:
    for k in LOWER_COUNTS:
        LOWER_COUNTS[k] = 0


def read_lower_counts() -> dict[str, int]:
    return dict(LOWER_COUNTS)


@dataclass(frozen=True)
class LutLevelRows:
    """Unpadded LUT rows of one topological level."""

    ins: np.ndarray       # [M, 6] int32 fanin signals (consts kept verbatim,
    #                       padded pins -> CONST0; tt replication makes padded
    #                       pins don't-care for the evaluator)
    tt_lo: np.ndarray     # [M] uint32 64-entry replicated mask, low word
    tt_hi: np.ndarray     # [M] uint32 high word
    cls: np.ndarray       # [M, 6] int32 edge classes (0 on const/padded pins;
    #                       all-zero in functional IRs)
    hop: np.ndarray       # [M, 6] int32 wire tier (0 in unplaced IRs)
    ndc: np.ndarray       # [M] int32 node delay class
    out: np.ndarray       # [M] int32 output signal


@dataclass(frozen=True)
class ChainLevelRows:
    """Unpadded chain rows of one topological level (row width = level's
    widest chain; shorter chains pad bits with null ops and ``sums`` -1)."""

    a_sig: np.ndarray     # [C, B] int32 (consts kept verbatim)
    a_cls: np.ndarray     # [C, B] int32
    a_hop: np.ndarray     # [C, B] int32 wire tier (0 in unplaced IRs)
    b_sig: np.ndarray     # [C, B] int32
    b_cls: np.ndarray     # [C, B] int32
    b_hop: np.ndarray     # [C, B] int32
    cin_sig: np.ndarray   # [C] int32 (the chain's real cin, consts included)
    cin_cls: np.ndarray   # [C] int32
    cin_hop: np.ndarray   # [C] int32
    sums: np.ndarray      # [C, B] int32 (-1 on padded bits)
    cout: np.ndarray      # [C] int32 (-1 when the chain has no cout)
    last: np.ndarray      # [C] int32 index of the last real bit


@dataclass(frozen=True)
class CircuitIR:
    """The unified columnar IR (see module docstring for the layout).

    Functional IRs (from :func:`lower_netlist_ir`) carry
    ``arch_name=None`` / ``structural_key=None``, empty ALM columns and
    all-zero edge/node delay classes; packed IRs (from
    :func:`lower_pack_ir`) fill every column."""

    name: str
    #: content digest of the source netlist — the incremental-lowering
    #: template guard (same-shaped but different circuits must not patch
    #: each other's IRs) and the registry cache key
    net_digest: str
    arch_name: str | None
    structural_key: tuple | None
    n_signals: int
    # per-signal columns
    sig_site: np.ndarray
    sig_lb: np.ndarray
    sig_kind: np.ndarray
    sig_level: np.ndarray
    # per-signal placement columns: grid coordinates of the producing LB
    # (-1 for PIs/constants and in unplaced IRs; see apply_placement)
    sig_x: np.ndarray
    sig_y: np.ndarray
    # fanin CSR (timing edges)
    fanin_ptr: np.ndarray
    fanin_sig: np.ndarray
    fanin_cls: np.ndarray
    fanin_hop: np.ndarray
    # per-ALM columns
    alm_lb: np.ndarray
    alm_is_arith: np.ndarray
    alm_feed: np.ndarray
    alm_hosted: np.ndarray
    alm_lut6: np.ndarray
    # levelized node tables (index 0 = first computing level)
    lut_levels: tuple[LutLevelRows, ...]
    chain_levels: tuple[ChainLevelRows, ...]
    # primary outputs + scalar stats
    po_sig: np.ndarray
    n_alms: int
    n_lbs: int
    n_luts: int
    n_adders: int
    concurrent_luts: int
    # placement metadata (0 / None until apply_placement fills the grid)
    grid_w: int = 0
    grid_h: int = 0
    placement_seed: int | None = None

    @property
    def placed(self) -> bool:
        return self.grid_w > 0

    @property
    def n_levels(self) -> int:
        return len(self.lut_levels)

    def level_profile(self):
        """Per-level (lut rows, chain rows, widest chain) — the width
        profile bucketing/batching decisions consume."""
        m = [lv.out.shape[0] for lv in self.lut_levels]
        c = [lv.cout.shape[0] for lv in self.chain_levels]
        b = [lv.a_sig.shape[1] if lv.cout.shape[0] else 0
             for lv in self.chain_levels]
        return m, c, b

    @property
    def envelope(self) -> tuple[int, int, int, int]:
        """Single worst-case ``(L, M, C, B)`` envelope — the shape the
        shared grouping planner (:func:`repro.core.plan.group_by_envelope`)
        clusters on."""
        m, c, b = self.level_profile()
        return (self.n_levels, max(m, default=0), max(c, default=0),
                max(b, default=0))


def levelize(net: Netlist):
    """Nodes grouped by topological level (a node's level is one past its
    deepest input).  Returns ``(by_luts, by_chains, sig_level)``.  The
    single levelization of the stack — the evaluator, the timing lowering
    and the seed per-level dispatcher all consume this."""
    sig_level: dict[int, int] = {s: 0 for s in net.pis}
    sig_level[0] = 0
    sig_level[1] = 0
    by_luts: dict[int, list[int]] = {}
    by_chains: dict[int, list[int]] = {}
    for nd in net.topo_order():
        lv = 0
        for s in net.node_inputs(nd):
            lv = max(lv, sig_level.get(s, 0))
        lv += 1
        for s in net.node_outputs(nd):
            sig_level[s] = lv
        if nd[0] == "lut":
            by_luts.setdefault(lv, []).append(nd[1])
        else:
            by_chains.setdefault(lv, []).append(nd[1])
    return by_luts, by_chains, sig_level


# ---------------------------------------------------------------------------
# functional lowering (per netlist content digest)
# ---------------------------------------------------------------------------


def lower_netlist_ir(net: Netlist, digest: str | None = None) -> CircuitIR:
    """Functional lowering of a bare netlist — content-cached; see the
    module docstring.  Pass ``digest`` to skip recomputing it."""
    key = digest if digest is not None else net.content_digest()
    hit = _IR_CACHE.get(key)
    if hit is not None:
        return hit
    ir = _lower_functional(net, key)
    _IR_CACHE.put(key, ir)
    return ir


def _lower_functional(net: Netlist, digest: str) -> CircuitIR:
    LOWER_COUNTS["functional"] += 1
    S = net.n_signals

    sig_kind = np.full(S, K_PI, dtype=np.int32)
    sig_kind[: min(2, S)] = K_CONST
    for out in net.lut_out:
        sig_kind[out] = K_LUT
    for ch in net.chains:
        for s in ch.sums:
            sig_kind[s] = K_SUM
        if ch.cout is not None:
            sig_kind[ch.cout] = K_COUT

    by_luts, by_chains, sig_level_map = levelize(net)
    sig_level = np.zeros(S, dtype=np.int32)
    for s, lv in sig_level_map.items():
        sig_level[s] = lv
    levels = sorted(set(by_luts) | set(by_chains))

    # fanin CSR accumulators (append order is the patch-scatter contract:
    # per level, LUT rows' non-const pins in pin order, then chain rows'
    # a/b edges per bit plus cin on bit 0)
    csr_sig: list[list[int]] = [[] for _ in range(S)]

    lut_levels: list[LutLevelRows] = []
    chain_levels: list[ChainLevelRows] = []
    for lv in levels:
        # ---- LUT rows ----
        ids = by_luts.get(lv, ())
        M = len(ids)
        ins = np.zeros((M, 6), dtype=np.int32)
        tt_lo = np.zeros(M, dtype=np.uint32)
        tt_hi = np.zeros(M, dtype=np.uint32)
        ndc = np.zeros(M, dtype=np.int32)
        out = np.zeros(M, dtype=np.int32)
        for r, li in enumerate(ids):
            sig_ins = net.lut_inputs[li]
            k = len(sig_ins)
            ins[r, :k] = sig_ins
            lo, hi = tt_words64(net.lut_tt[li], k)
            tt_lo[r] = lo
            tt_hi[r] = hi
            ndc[r] = (NDC_LUT4 if k <= 4 else
                      NDC_LUT5 if k == 5 else NDC_LUT6)
            osig = net.lut_out[li]
            out[r] = osig
            for q in sig_ins:
                if q > CONST1:
                    csr_sig[osig].append(q)
        lut_levels.append(LutLevelRows(
            ins=ins, tt_lo=tt_lo, tt_hi=tt_hi,
            cls=np.zeros((M, 6), dtype=np.int32),
            hop=np.zeros((M, 6), dtype=np.int32), ndc=ndc, out=out))

        # ---- chain rows ----
        cids = by_chains.get(lv, ())
        C = len(cids)
        B = max((len(net.chains[ci].sums) for ci in cids), default=0)
        a_sig = np.zeros((C, max(B, 1)), dtype=np.int32)
        b_sig = np.zeros((C, max(B, 1)), dtype=np.int32)
        cin_sig = np.zeros(C, dtype=np.int32)
        sums = np.full((C, max(B, 1)), -1, dtype=np.int32)
        cout = np.full(C, -1, dtype=np.int32)
        last = np.zeros(C, dtype=np.int32)
        for r, ci in enumerate(cids):
            ch = net.chains[ci]
            n = len(ch.sums)
            last[r] = n - 1
            a_sig[r, :n] = ch.a
            b_sig[r, :n] = ch.b
            cin_sig[r] = ch.cin
            sums[r, :n] = ch.sums
            if ch.cout is not None:
                cout[r] = ch.cout
            for bi in range(n):
                for q in (ch.a[bi], ch.b[bi]):
                    if q > CONST1:
                        csr_sig[ch.sums[bi]].append(q)
                if bi == 0 and ch.cin > CONST1:
                    csr_sig[ch.sums[0]].append(ch.cin)
        chain_levels.append(ChainLevelRows(
            a_sig=a_sig, a_cls=np.zeros_like(a_sig),
            a_hop=np.zeros_like(a_sig),
            b_sig=b_sig, b_cls=np.zeros_like(b_sig),
            b_hop=np.zeros_like(b_sig),
            cin_sig=cin_sig, cin_cls=np.zeros_like(cin_sig),
            cin_hop=np.zeros_like(cin_sig),
            sums=sums, cout=cout, last=last))

    fanin_ptr = np.zeros(S + 1, dtype=np.int32)
    for s in range(S):
        fanin_ptr[s + 1] = fanin_ptr[s] + len(csr_sig[s])
    fanin_sig = np.array([q for lst in csr_sig for q in lst], dtype=np.int32)

    po_sig = np.array(sorted({s for bus in net.pos.values() for s in bus}),
                      dtype=np.int32)

    empty_i32 = np.zeros(0, dtype=np.int32)
    return CircuitIR(
        name=net.name, net_digest=digest,
        arch_name=None, structural_key=None,
        n_signals=S,
        sig_site=np.full(S, -1, dtype=np.int32),
        sig_lb=np.full(S, -1, dtype=np.int32),
        sig_kind=sig_kind, sig_level=sig_level,
        sig_x=np.full(S, -1, dtype=np.int32),
        sig_y=np.full(S, -1, dtype=np.int32),
        fanin_ptr=fanin_ptr, fanin_sig=fanin_sig,
        fanin_cls=np.zeros_like(fanin_sig),
        fanin_hop=np.zeros_like(fanin_sig),
        alm_lb=empty_i32, alm_is_arith=np.zeros(0, dtype=bool),
        alm_feed=np.zeros((0, 2), dtype=np.int32),
        alm_hosted=np.zeros((0, 2), dtype=np.int32),
        alm_lut6=empty_i32,
        lut_levels=tuple(lut_levels), chain_levels=tuple(chain_levels),
        po_sig=po_sig,
        n_alms=0, n_lbs=0, n_luts=net.n_luts, n_adders=net.n_adders,
        concurrent_luts=0,
    )


# ---------------------------------------------------------------------------
# functional dirty-row patch (per edited-netlist content digest)
# ---------------------------------------------------------------------------


def patch_functional_ir(base: CircuitIR, new_net: Netlist,
                        edited_luts, tt_luts,
                        digest: str | None = None) -> CircuitIR | None:
    """Patch a functional :class:`CircuitIR` for an index-stable LUT
    edit instead of re-levelizing the whole netlist.

    ``base`` is the functional IR of the *base* netlist; ``edited_luts``
    are the LUT indices whose fanin tuples changed and ``tt_luts`` those
    whose truth tables changed (from
    :func:`repro.core.repack.netlist_structural_diff` — the caller has
    already proven the edit index-stable).  Only the touched rows are
    rewritten: the edited LUTs' ``ins``/``tt``/``ndc`` entries inside
    their level tables and their output signals' fanin-CSR rows.

    **Levels-stable gate**: the patch requires every edited LUT's
    topological level to be unchanged under its new fanins (level =
    ``max(input levels) + 1``).  An unchanged output level means no
    downstream level can move either, so the level tables keep exactly
    their base rows.  Returns ``None`` when the gate fails and the
    caller must run the full :func:`lower_netlist_ir`.

    Within-level row *order* is inherited from the base IR (fresh
    lowering orders rows by Kahn-queue pop order, which an edit can
    permute); every consumer — the evaluator, the vectorized timing
    program, the equivalence walks — reduces per signal, so results are
    bit-identical regardless of row order within a level.
    """
    import dataclasses

    if base.arch_name is not None:
        raise ValueError("patch_functional_ir needs a functional IR base")
    sig_level = base.sig_level
    edited_luts = sorted(set(edited_luts))
    for li in edited_luts:
        out = new_net.lut_out[li]
        lv = 0
        for s in new_net.lut_inputs[li]:
            lv = max(lv, int(sig_level[s]))
        if lv + 1 != int(sig_level[out]):
            return None
    LOWER_COUNTS["functional_patch"] += 1

    # locate each touched LUT's (level-table, row) slot by output signal
    def find_row(out_sig: int) -> tuple[int, int]:
        for t, ll in enumerate(base.lut_levels):
            r = np.nonzero(ll.out == out_sig)[0]
            if r.size:
                return t, int(r[0])
        raise ValueError(f"signal {out_sig} has no LUT row")

    touched: dict[int, dict[int, int]] = {}   # table idx -> {row: li}
    for li in set(edited_luts) | set(tt_luts):
        t, r = find_row(new_net.lut_out[li])
        touched.setdefault(t, {})[r] = li

    lut_levels = list(base.lut_levels)
    for t, rows in touched.items():
        ll = lut_levels[t]
        ins = ll.ins.copy()
        tt_lo = ll.tt_lo.copy()
        tt_hi = ll.tt_hi.copy()
        ndc = ll.ndc.copy()
        from .netlist import tt_words64 as _ttw
        for r, li in rows.items():
            sig_ins = new_net.lut_inputs[li]
            k = len(sig_ins)
            ins[r] = 0
            ins[r, :k] = sig_ins
            lo, hi = _ttw(new_net.lut_tt[li], k)
            tt_lo[r] = lo
            tt_hi[r] = hi
            ndc[r] = (NDC_LUT4 if k <= 4 else
                      NDC_LUT5 if k == 5 else NDC_LUT6)
        lut_levels[t] = dataclasses.replace(
            ll, ins=ins, tt_lo=tt_lo, tt_hi=tt_hi, ndc=ndc)

    # fanin-CSR rows of the edited outputs (per-occurrence, consts
    # dropped — mirrors _lower_functional's append rule)
    ptr = base.fanin_ptr
    new_rows = {}
    for li in edited_luts:
        out = new_net.lut_out[li]
        new_rows[out] = [q for q in new_net.lut_inputs[li] if q > CONST1]
    same_len = all(ptr[s + 1] - ptr[s] == len(row)
                   for s, row in new_rows.items())
    if same_len:
        fanin_ptr = ptr
        fanin_sig = base.fanin_sig.copy()
        for s, row in new_rows.items():
            fanin_sig[ptr[s]:ptr[s + 1]] = row
    else:
        S = base.n_signals
        lens = np.diff(ptr).astype(np.int64)
        for s, row in new_rows.items():
            lens[s] = len(row)
        fanin_ptr = np.zeros(S + 1, ptr.dtype)
        np.cumsum(lens, out=fanin_ptr[1:])
        segs: list[np.ndarray] = []
        prev = 0
        for s in sorted(new_rows):
            if prev < s:
                segs.append(base.fanin_sig[ptr[prev]:ptr[s]])
            segs.append(np.asarray(new_rows[s], base.fanin_sig.dtype))
            prev = s + 1
        if prev < S:
            segs.append(base.fanin_sig[ptr[prev]:ptr[S]])
        fanin_sig = np.concatenate(segs) if segs \
            else base.fanin_sig[:0]

    return dataclasses.replace(
        base,
        name=new_net.name,
        net_digest=(digest if digest is not None
                    else new_net.content_digest()),
        fanin_ptr=fanin_ptr, fanin_sig=fanin_sig,
        fanin_cls=np.zeros_like(fanin_sig),
        fanin_hop=np.zeros_like(fanin_sig),
        lut_levels=tuple(lut_levels))


def apply_pack_delta(packed: "PackedCircuit", base_net: Netlist,
                     edited_luts=(), tt_luts=()) -> CircuitIR:
    """Dirty-column lowering of an edited netlist's pack: patch the
    *base* netlist's cached functional IR row-wise
    (:func:`patch_functional_ir`) and restamp the placement columns with
    the same vectorized :func:`_patch_placement` pass every other
    lowering path runs — instead of re-levelizing from scratch.

    The patched functional IR is inserted into the ``netlist_ir``
    registry under the edited netlist's content digest, so any later
    fresh lowering of the same edited netlist (``pack_and_analyze``,
    sweeps) hits it identically; the packed result lands in the
    ``pack_delta_ir`` cache keyed ``(base_digest, new_digest,
    structural_key)`` (content-digest keys — entries cannot go stale;
    see the cache comment).  Falls back to the full functional lowering
    when the levels-stable gate fails, so it is total: every call
    returns the same arrays ``lower_pack_ir`` would produce up to
    within-level row order."""
    new_digest = packed.net.content_digest()
    base_digest = base_net.content_digest()
    key = (base_digest, new_digest, packed.arch.structural_key())
    hit = _PACK_DELTA_CACHE.get(key)
    if hit is not None:
        return hit
    func = _IR_CACHE.get(new_digest)
    if func is None:
        base_func = lower_netlist_ir(base_net, base_digest)
        func = patch_functional_ir(base_func, packed.net, edited_luts,
                                   tt_luts, new_digest)
        if func is None:
            func = lower_netlist_ir(packed.net, new_digest)
        else:
            _IR_CACHE.put(new_digest, func)
    LOWER_COUNTS["placement_delta"] += 1
    ir = _patch_placement(func, packed)
    _PACK_DELTA_CACHE.put(key, ir)
    return ir


# ---------------------------------------------------------------------------
# placement patch (per (digest, structural class))
# ---------------------------------------------------------------------------


def _placement_columns(packed: "PackedCircuit") -> dict:
    """The placement-derived columns every packed lowering needs: per-
    signal site/LB, the per-ALM mode columns, the absorbed-LUT set and
    the per-sum-signal Z-feed flags.  Single source of truth — the patch
    recomputes exactly what this builds."""
    net = packed.net
    S = net.n_signals

    sig_site = np.full(S, -1, dtype=np.int32)
    for li, out in enumerate(net.lut_out):
        sig_site[out] = packed.lut_site.get(li, -2)
    for ci, ch in enumerate(net.chains):
        for bi, s in enumerate(ch.sums):
            sig_site[s] = packed.chain_site.get((ci, bi), -2)
        if ch.cout is not None:
            sig_site[ch.cout] = packed.chain_site.get((ci, len(ch.sums) - 1),
                                                      -2)

    alm_lb_arr = np.asarray(packed.alm_lb, dtype=np.int32) \
        if packed.alm_lb else np.zeros(0, dtype=np.int32)
    sig_lb = np.full(S, -1, dtype=np.int32)
    placed = sig_site >= 0
    sig_lb[placed] = alm_lb_arr[sig_site[placed]]

    A = len(packed.alms)
    alm_is_arith = np.zeros(A, dtype=bool)
    alm_feed = np.zeros((A, 2), dtype=np.int32)
    alm_hosted = np.full((A, 2), -1, dtype=np.int32)
    alm_lut6 = np.full(A, -1, dtype=np.int32)
    absorbed_all: set[int] = set()
    z_of_sum = np.zeros(S, dtype=bool)
    for ai, alm in enumerate(packed.alms):
        alm_is_arith[ai] = alm.is_arith
        if alm.lut6 is not None:
            alm_lut6[ai] = alm.lut6
        for hi, h in enumerate(alm.halves):
            if h.fa is not None:
                alm_feed[ai, hi] = 2 if h.fa_feed == "z" else 1
                absorbed_all.update(h.absorbed)
                if h.fa_feed == "z":
                    ci, bi = h.fa
                    z_of_sum[net.chains[ci].sums[bi]] = True
            if h.hosted_lut is not None:
                alm_hosted[ai, hi] = h.hosted_lut

    return {"sig_site": sig_site, "sig_lb": sig_lb, "alm_lb": alm_lb_arr,
            "alm_is_arith": alm_is_arith, "alm_feed": alm_feed,
            "alm_hosted": alm_hosted, "alm_lut6": alm_lut6,
            "absorbed_all": absorbed_all, "z_of_sum": z_of_sum}


def _patch_placement(base: CircuitIR, packed: "PackedCircuit") -> CircuitIR:
    """Fill the placement-derived columns of ``base`` for ``packed``.

    ``base`` supplies the netlist-shaped arrays (level tables' signals and
    truth tables, fanin CSR topology, signal levels, primary outputs) —
    either the cached functional IR (fresh lowering) or a sibling
    structural class's packed IR (template-incremental lowering).  Both
    produce identical columns because this is the only classifier.

    Absorption is derived from the pack: an absorbed LUT is 4-input,
    single-fanout and consumed exactly at its absorbing half, so a global
    per-signal absorbed mask is equivalent to the per-half operand sets
    the object-graph walk used.  Constant operands keep class 0 (the
    null edge: gathered arrival 0.0, zero components) — bit-identical to
    the oracle's skip-constants reductions.
    """
    net = packed.net
    arch = packed.arch
    S = net.n_signals

    cols = _placement_columns(packed)
    sig_lb = cols["sig_lb"]
    z_of_sum = cols["z_of_sum"]

    if net.n_luts:
        lut_outs = np.asarray(net.lut_out, dtype=np.int64)
        if (cols["sig_site"][lut_outs] == -2).any():
            bad = int(lut_outs[cols["sig_site"][lut_outs] == -2][0])
            raise ValueError(
                f"{net.name}: LUT output signal {bad} has no site — an "
                f"unplaced LUT cannot be lowered (the packer must place "
                f"every LUT)")

    absorbed_sig = np.zeros(S, dtype=bool)
    for li in cols["absorbed_all"]:
        absorbed_sig[net.lut_out[li]] = True
    sig_kind = base.sig_kind.copy()
    sig_kind[absorbed_sig] = K_LUT_ABS

    cls_lut_local = edge_class(ROUTE_LOCAL, PIN_AH, PATH_NULL)
    cls_lut_global = edge_class(ROUTE_GLOBAL, PIN_AH, PATH_NULL)
    fanin_cls = np.zeros_like(base.fanin_cls)
    ptr = base.fanin_ptr

    lut_levels: list[LutLevelRows] = []
    chain_levels: list[ChainLevelRows] = []
    for ll, cl in zip(base.lut_levels, base.chain_levels):
        # ---- LUT rows: route locality is the only class variable ----
        mask = ll.ins > CONST1
        dst = sig_lb[ll.out][:, None]
        local = (sig_lb[ll.ins] == dst) & (sig_lb[ll.ins] >= 0)
        cls = np.where(mask, np.where(local, cls_lut_local, cls_lut_global),
                       0).astype(np.int32)
        ndc = np.where(absorbed_sig[ll.out], NDC_ABSORBED,
                       ll.ndc).astype(np.int32)
        lut_levels.append(LutLevelRows(ins=ll.ins, tt_lo=ll.tt_lo,
                                       tt_hi=ll.tt_hi, cls=cls,
                                       hop=np.zeros_like(cls), ndc=ndc,
                                       out=ll.out))
        if mask.any():
            offs = np.cumsum(mask, axis=1) - 1
            slots = ptr[ll.out][:, None] + offs
            fanin_cls[slots[mask]] = cls[mask]

        # ---- chain rows: absorption and feed kind are placement-derived
        # (via the per-signal absorbed / Z-feed masks), routing locality
        # comes from the LB columns ----
        C = cl.cout.shape[0]
        if C:
            sums_safe = np.clip(cl.sums, 0, None)
            dst = np.where(cl.sums >= 0, sig_lb[sums_safe], -1)
            feed_z = z_of_sum[sums_safe] & (cl.sums >= 0)

            def patch_ops(op_sig):
                m = op_sig > CONST1
                absorbed = absorbed_sig[op_sig] & m
                route = np.where((sig_lb[op_sig] == dst) & (sig_lb[op_sig]
                                                            >= 0),
                                 ROUTE_LOCAL, ROUTE_GLOBAL)
                c_z = route * 9 + PIN_Z * 3 + PATH_Z
                c_ah = route * 9 + PIN_AH * 3 + PATH_AH
                c = np.where(absorbed, _CLS_ABSORBED,
                             np.where(feed_z, c_z, c_ah))
                return np.where(m, c, 0).astype(np.int32), m

            a_cls, amask = patch_ops(cl.a_sig)
            b_cls, bmask = patch_ops(cl.b_sig)
            cmask = cl.cin_sig > CONST1
            route0 = np.where((sig_lb[cl.cin_sig] == dst[:, 0])
                              & (sig_lb[cl.cin_sig] >= 0),
                              ROUTE_LOCAL, ROUTE_GLOBAL)
            cin_cls = np.where(cmask, route0 * 9 + PIN_AH * 3 + PATH_AH,
                               0).astype(np.int32)
            # CSR order per sum: a-edge, b-edge, then cin on bit 0
            base_slots = ptr[sums_safe]
            if amask.any():
                fanin_cls[base_slots[amask]] = a_cls[amask]
            slots_b = base_slots + amask.astype(np.int32)
            if bmask.any():
                fanin_cls[slots_b[bmask]] = b_cls[bmask]
            slot_c = base_slots[:, 0] + amask[:, 0].astype(np.int32) \
                + bmask[:, 0].astype(np.int32)
            if cmask.any():
                fanin_cls[slot_c[cmask]] = cin_cls[cmask]
            chain_levels.append(ChainLevelRows(
                a_sig=cl.a_sig, a_cls=a_cls, a_hop=np.zeros_like(a_cls),
                b_sig=cl.b_sig, b_cls=b_cls, b_hop=np.zeros_like(b_cls),
                cin_sig=cl.cin_sig, cin_cls=cin_cls,
                cin_hop=np.zeros_like(cin_cls), sums=cl.sums,
                cout=cl.cout, last=cl.last))
        else:
            chain_levels.append(ChainLevelRows(
                a_sig=cl.a_sig, a_cls=np.zeros_like(cl.a_cls),
                a_hop=np.zeros_like(cl.a_cls),
                b_sig=cl.b_sig, b_cls=np.zeros_like(cl.b_cls),
                b_hop=np.zeros_like(cl.b_cls),
                cin_sig=cl.cin_sig, cin_cls=np.zeros_like(cl.cin_cls),
                cin_hop=np.zeros_like(cl.cin_cls),
                sums=cl.sums, cout=cl.cout, last=cl.last))

    return CircuitIR(
        name=net.name, net_digest=base.net_digest,
        arch_name=arch.name,
        structural_key=arch.structural_key(),
        n_signals=S,
        sig_site=cols["sig_site"], sig_lb=sig_lb,
        sig_kind=sig_kind, sig_level=base.sig_level,
        sig_x=np.full(S, -1, dtype=np.int32),
        sig_y=np.full(S, -1, dtype=np.int32),
        fanin_ptr=base.fanin_ptr, fanin_sig=base.fanin_sig,
        fanin_cls=fanin_cls,
        fanin_hop=np.zeros_like(fanin_cls),
        alm_lb=cols["alm_lb"], alm_is_arith=cols["alm_is_arith"],
        alm_feed=cols["alm_feed"], alm_hosted=cols["alm_hosted"],
        alm_lut6=cols["alm_lut6"],
        lut_levels=tuple(lut_levels), chain_levels=tuple(chain_levels),
        po_sig=base.po_sig,
        n_alms=packed.n_alms, n_lbs=packed.n_lbs, n_luts=net.n_luts,
        n_adders=net.n_adders, concurrent_luts=packed.concurrent_luts,
    )


def lower_pack_ir(packed: "PackedCircuit") -> CircuitIR:
    """Lower a :class:`~repro.core.packing.PackedCircuit` to a full
    :class:`CircuitIR`: the content-cached functional IR of its netlist
    plus the placement patch.  Levelization therefore runs once per
    netlist digest no matter how many structural classes are lowered."""
    base = lower_netlist_ir(packed.net)
    LOWER_COUNTS["placement_full"] += 1
    return _patch_placement(base, packed)


def lower_pack_ir_incremental(packed: "PackedCircuit",
                              template: CircuitIR) -> CircuitIR:
    """Re-lower a pack by patching a sibling class's :class:`CircuitIR`.

    ``template`` must be a lowering of a pack of the *same netlist* (any
    structural class — typically the first class of a sweep).  Clustering
    can only move atoms between ALMs/LBs and flip chain-bit feeds, so the
    netlist-shaped columns are reused verbatim and only the
    placement-derived columns are recomputed — by the *same*
    :func:`_patch_placement` pass the fresh path runs, so the result is
    array-for-array identical to :func:`lower_pack_ir` by construction
    (the parity tests compare every column anyway).
    """
    if template.net_digest != packed.net.content_digest():
        raise ValueError(
            f"template CircuitIR {template.name!r} is not a lowering of "
            f"netlist {packed.net.name!r} — incremental patching needs a "
            f"sibling structural class of the same circuit (content "
            f"digests differ)")
    LOWER_COUNTS["placement_incremental"] += 1
    return _patch_placement(template, packed)


# ---------------------------------------------------------------------------
# grid-placement patch (per (digest, placement key, seed))
# ---------------------------------------------------------------------------


def apply_placement(ir: CircuitIR, placement) -> CircuitIR:
    """Fill the grid-placement columns of a packed :class:`CircuitIR`.

    ``placement`` is a :class:`repro.core.place.GridPlacement` (anything
    with ``lb_x``/``lb_y``/``grid_w``/``grid_h``/``seed`` works) of the
    same pack — one slot per LB.  A third, orthogonal patch stage on top
    of the functional lowering and the placement-derived edge classes:
    it rewrites only the wire-tier columns (``hop`` per level-table pin,
    ``fanin_hop`` per CSR edge) and the per-signal grid coordinates.

    Wire tiers follow the Manhattan distance between the producing and
    consuming LB slots: same LB (or an absorbed operand, or a PI/constant
    source — nothing to route through the fabric grid) → :data:`TIER_NONE`
    (zero delay), distance 1 → :data:`TIER_HOP1`, distance 2 →
    :data:`TIER_HOP2`, anything farther rides one long wire
    (:data:`TIER_LONG`).  Tier delays are per-arch *data*
    (``t_wire_hop1/2``/``t_wire_long`` rows of the delay table), so every
    delay row of a structural class shares this one placed IR; at the
    all-zero default tier delays the placed timing path is bit-identical
    to the unplaced one.
    """
    import dataclasses

    if ir.arch_name is None:
        raise ValueError(
            f"{ir.name}: cannot place a functional IR — placement needs "
            f"the packed LB columns (lower the pack first)")
    lb_x = np.asarray(placement.lb_x, dtype=np.int32)
    lb_y = np.asarray(placement.lb_y, dtype=np.int32)
    if lb_x.shape[0] != ir.n_lbs:
        raise ValueError(
            f"{ir.name}: placement has {lb_x.shape[0]} LB slots but the "
            f"IR packs {ir.n_lbs} LBs — not a placement of this pack")

    sig_lb = ir.sig_lb
    S = ir.n_signals
    sig_x = np.full(S, -1, dtype=np.int32)
    sig_y = np.full(S, -1, dtype=np.int32)
    placed = sig_lb >= 0
    if lb_x.size:
        sig_x[placed] = lb_x[sig_lb[placed]]
        sig_y[placed] = lb_y[sig_lb[placed]]

    def tiers(op_sig, dst_lb):
        src_lb = sig_lb[op_sig]
        routed = (src_lb >= 0) & (dst_lb >= 0) & (src_lb != dst_lb)
        if not lb_x.size:
            return np.zeros(op_sig.shape, dtype=np.int32)
        sl = np.clip(src_lb, 0, None)
        dl = np.clip(dst_lb, 0, None)
        d = np.abs(lb_x[sl] - lb_x[dl]) + np.abs(lb_y[sl] - lb_y[dl])
        t = np.where(d <= 1, TIER_HOP1,
                     np.where(d == 2, TIER_HOP2, TIER_LONG))
        return np.where(routed, t, TIER_NONE).astype(np.int32)

    fanin_hop = np.zeros_like(ir.fanin_hop)
    ptr = ir.fanin_ptr
    lut_levels: list[LutLevelRows] = []
    chain_levels: list[ChainLevelRows] = []
    for ll, cl in zip(ir.lut_levels, ir.chain_levels):
        mask = ll.ins > CONST1
        hop = np.where(mask, tiers(ll.ins, sig_lb[ll.out][:, None]),
                       0).astype(np.int32)
        lut_levels.append(dataclasses.replace(ll, hop=hop))
        if mask.any():
            offs = np.cumsum(mask, axis=1) - 1
            slots = ptr[ll.out][:, None] + offs
            fanin_hop[slots[mask]] = hop[mask]

        C = cl.cout.shape[0]
        if C:
            sums_safe = np.clip(cl.sums, 0, None)
            dst = np.where(cl.sums >= 0, sig_lb[sums_safe], -1)
            amask = cl.a_sig > CONST1
            bmask = cl.b_sig > CONST1
            cmask = cl.cin_sig > CONST1
            a_hop = np.where(amask, tiers(cl.a_sig, dst), 0).astype(np.int32)
            b_hop = np.where(bmask, tiers(cl.b_sig, dst), 0).astype(np.int32)
            cin_hop = np.where(cmask, tiers(cl.cin_sig, dst[:, 0]),
                               0).astype(np.int32)
            chain_levels.append(dataclasses.replace(
                cl, a_hop=a_hop, b_hop=b_hop, cin_hop=cin_hop))
            # CSR order per sum: a-edge, b-edge, then cin on bit 0
            base_slots = ptr[sums_safe]
            if amask.any():
                fanin_hop[base_slots[amask]] = a_hop[amask]
            slots_b = base_slots + amask.astype(np.int32)
            if bmask.any():
                fanin_hop[slots_b[bmask]] = b_hop[bmask]
            slot_c = base_slots[:, 0] + amask[:, 0].astype(np.int32) \
                + bmask[:, 0].astype(np.int32)
            if cmask.any():
                fanin_hop[slot_c[cmask]] = cin_hop[cmask]
        else:
            chain_levels.append(cl)

    return dataclasses.replace(
        ir, sig_x=sig_x, sig_y=sig_y, fanin_hop=fanin_hop,
        lut_levels=tuple(lut_levels), chain_levels=tuple(chain_levels),
        grid_w=int(placement.grid_w), grid_h=int(placement.grid_h),
        placement_seed=int(placement.seed))
