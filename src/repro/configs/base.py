"""Model / run configuration.

One ``ModelConfig`` describes any of the 10 assigned architectures (plus the
paper's own Kratos-DD workload config).  ``family`` selects the layer body:

* ``dense``  — standard decoder transformer (GQA/MQA, SwiGLU/GeGLU)
* ``moe``    — dense attention + routed-experts FFN (+ shared experts)
* ``ssm``    — Mamba-2 SSD blocks (attention-free)
* ``hybrid`` — parallel attention + SSD heads per layer (Hymba-style)
* ``encdec`` — encoder-decoder (Whisper backbone; conv frontend stubbed)
* ``vlm``    — decoder over mixed patch+token embeddings (LLaVA backbone;
               anyres tiling frontend stubbed)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None     # default d_model // n_heads
    act: str = "swiglu"             # swiglu | geglu
    qkv_bias: bool = False           # qwen1.5
    logit_softcap: float | None = None   # gemma2
    attn_softcap: float | None = None
    # local/global attention pattern: window size for local layers; pattern
    # "lg" = alternate local, global (gemma2); None = all global
    local_window: int | None = None
    layer_pattern: str | None = None
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    rms_eps: float = 1e-6
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0          # leading dense layers (deepseek/kimi)
    capacity_factor: float = 1.25
    moe_group_size: int = 4096
    # --- SSM (mamba2 / hymba) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    conv_kernel: int = 4
    # --- enc-dec ---
    n_encoder_layers: int = 0
    encoder_seq: int = 0
    # --- vlm ---
    n_patches: int = 0
    # --- runtime ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    use_kernels: bool = False        # Pallas path (TPU); False = jnp ref path
    loss_chunk: int = 2048           # sequence-chunked CE for huge vocabs
    # --- perf variants (§Perf hillclimbing) ---
    kv_cache_dtype: str = "bfloat16"   # "int8": quantized KV cache
    unroll_layers: bool = False        # python-loop layers: enables static
    #                                    per-layer windows (chunked SWA)
    chunked_local_attn: bool = False   # block-local attention for SWA layers
    ssd_chunk: int = 0                 # SSD chunked-dual form (0 = serial)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(2, self.n_layers),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 * self.n_kv_heads // max(1, self.n_heads)),
            head_dim=16,
            d_ff=128,
            vocab=256,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            moe_group_size=64,
            loss_chunk=64,
        )
        if self.is_moe:
            kw.update(n_experts=4, n_shared_experts=min(1, self.n_shared_experts),
                      top_k=2, d_ff_expert=32, n_dense_layers=min(1, self.n_dense_layers))
        if self.ssm_state:
            kw.update(ssm_state=8, ssm_heads=4, ssm_head_dim=16)
        if self.family == "encdec":
            kw.update(n_encoder_layers=2, encoder_seq=32)
        if self.family == "vlm":
            kw.update(n_patches=8)
        if self.local_window:
            kw.update(local_window=16)
        return replace(self, **kw)


# registry filled by the per-arch modules
REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not REGISTRY:
        load_all()
    return REGISTRY[name]


def list_configs() -> list[str]:
    if not REGISTRY:
        load_all()
    return sorted(REGISTRY)


def load_all() -> None:
    from . import (deepseek_moe_16b, gemma2_2b, gemma_2b, hymba_1_5b,  # noqa
                   kimi_k2, kratos_dd, llava_next_34b, mamba2_2_7b,
                   qwen1_5_0_5b, tinyllama_1_1b, whisper_small)


# ---------------------------------------------------------------------------
# shapes (assigned input-shape sets)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

#: archs with a sub-quadratic long-context path (run long_500k); all others
#: skip it (see DESIGN.md §4)
LONG_CONTEXT_ARCHS = {"mamba2-2.7b", "hymba-1.5b"}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_ARCHS \
            and not cfg.name.endswith("-smoke"):
        return False, "full-attention arch: no sub-quadratic 500k path"
    return True, ""
