"""Gemma 2B — GeGLU, head_dim=256, MQA [arXiv:2403.08295]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, act="geglu", tie_embeddings=True,
))
