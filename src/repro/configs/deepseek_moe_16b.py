"""DeepSeekMoE 16B — 2 shared + 64 routed top-6, fine-grained experts,
first layer dense [arXiv:2401.06066]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944,            # dense (first) layer FFN
    vocab=102400, act="swiglu", tie_embeddings=False,
    n_experts=64, n_shared_experts=2, top_k=6, d_ff_expert=1408,
    n_dense_layers=1,
))
