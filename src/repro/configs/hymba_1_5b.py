"""Hymba 1.5B — parallel attention + SSM heads per layer; SWA everywhere
except first/middle/last global layers [arXiv:2411.13676]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001, act="swiglu", tie_embeddings=True,
    local_window=1024, ssm_state=16, ssm_heads=25, ssm_head_dim=64,
))
