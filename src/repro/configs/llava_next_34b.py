"""LLaVA-NeXT 34B backbone — anyres tiling frontend STUBBED: input_specs
provides precomputed patch embeddings [hf:llava-hf, per assignment]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000, act="swiglu", tie_embeddings=False,
    rope_theta=5000000.0, n_patches=576,
))
