"""Kimi K2 — trillion-parameter MoE, 384 routed experts top-8
[arXiv:2501.kimi2 per assignment; GQA kv=8 per the assigned config]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=14336,            # dense (first) layer FFN
    vocab=163840, act="swiglu", tie_embeddings=False,
    n_experts=384, n_shared_experts=1, top_k=8, d_ff_expert=2048,
    n_dense_layers=1, moe_group_size=2048,
))
