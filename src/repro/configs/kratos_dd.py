"""The paper's own workload config: a small unrolled-DNN-style LM whose
linear layers run through the Double-Duty bitplane path (repro.quant)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="kratos-dd", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=2048, vocab=32000, act="swiglu", tie_embeddings=True,
))
