"""Mamba-2 2.7B — SSD, attention-free [arXiv:2405.21060].
d_inner = 2*d_model = 5120, P=64 -> 80 SSD heads, state N=128."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50280, tie_embeddings=True,
    ssm_state=128, ssm_heads=80, ssm_head_dim=64, conv_kernel=4,
))
