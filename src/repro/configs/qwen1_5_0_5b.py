"""Qwen1.5 0.5B — QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=2816, vocab=151936, act="swiglu", qkv_bias=True,
    tie_embeddings=True, rope_theta=1000000.0,
))
