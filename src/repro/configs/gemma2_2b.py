"""Gemma-2 2B — local+global alternating attention, logit softcap
[arXiv:2408.00118]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab=256000, act="geglu", tie_embeddings=True,
    logit_softcap=30.0, attn_softcap=50.0,
    local_window=4096, layer_pattern="lg", rope_theta=10000.0,
))
