"""Whisper small backbone — enc-dec; conv frontend STUBBED: input_specs
provides precomputed frame embeddings [arXiv:2212.04356]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab=51865, act="gelu_mlp", tie_embeddings=True,
    n_encoder_layers=12, encoder_seq=1500,
))
