#!/bin/sh
# Fast-tier CI check: CAD-core tests + a 2-point arch-grid sweep + a
# 2-point structural-axis (cluster-geometry) sweep, all gated on
# timing-oracle bit-identity, + the IR-parity step (two circuits lowered
# ONCE each; eval and timing proven against their oracles from the same
# CircuitIR object, lowering counters asserting no duplicates), + the
# 2-circuit placement gate (placed sweep bit-identical to the placed
# oracle, >= 2x placement reuse), + the bounded-iteration anneal gate
# (annealed placements grid-legal, wirelength <= the analytic seed,
# placed-oracle parity, bit-deterministic re-anneal), + the
# 2-rung / 8-point / 2-circuit successive-halving search smoke (winner
# oracle parity + equivalence, dense-vs-search cost ratio >= 1), + the
# flow-serving smoke (8 concurrent clients over 2 circuits x 2 archs,
# every served record bit-identical to serial pack_and_analyze and
# coalesced warm throughput >= the serial min-of-N baseline), + the
# repack-delta smoke (a single-LUT edit on conv2d-fu served via the
# dirty-set incremental path: pack byte-identical to a fresh pack(),
# every touched LB proven equivalent, served record bit-identical to
# pack_and_analyze, delta wall >= 2x faster than full re-cluster).
# Ends with the cache-registry table (per-cache hits/misses/hit_rate).
# Equivalent to `python -m benchmarks.run --smoke`; run the full tier-1
# line (`python -m pytest -x -q`) before shipping.
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    exec python -m benchmarks.run --smoke
