"""Fig. 7 — DD5 vs DD6.

Paper: DD6 gives minor extra area savings on Kratos only, costs ~8 % Fmax,
and loses on ADP — the added 6-LUT concurrency is not worth it.
"""
from __future__ import annotations

from .common import Timer, emit, geomean, pack_metrics, suites


def run(verbose: bool = True):
    out: dict[str, dict] = {}
    for suite_name, nets in suites("wallace").items():
        rows = {"dd5": [], "dd6": []}
        for net in nets:
            b = pack_metrics(net, "baseline")
            for arch in ("dd5", "dd6"):
                m = pack_metrics(net, arch)
                rows[arch].append({
                    "area": m["area_mwta"] / b["area_mwta"],
                    "cpd": m["critical_path_ps"] / b["critical_path_ps"],
                    "adp": m["adp"] / b["adp"],
                })
        out[suite_name] = {
            arch: {
                k: geomean([r[k] for r in rows[arch]])
                for k in ("area", "cpd", "adp")
            }
            for arch in ("dd5", "dd6")
        }
        if verbose:
            for arch in ("dd5", "dd6"):
                v = out[suite_name][arch]
                emit(f"fig7/{suite_name}/{arch}", 0,
                     f"area={v['area']:.3f};cpd={v['cpd']:.3f};adp={v['adp']:.3f}")
    return out


def main():
    with Timer() as t:
        res = run()
    k = res["kratos"]
    emit("fig7_dd6", t.us,
         f"kratos_dd5_adp={k['dd5']['adp']:.3f};kratos_dd6_adp={k['dd6']['adp']:.3f}")
    return res


if __name__ == "__main__":
    main()
