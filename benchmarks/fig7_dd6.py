"""Fig. 7 — DD5 vs DD6.

Paper: DD6 gives minor extra area savings on Kratos only, costs ~8 % Fmax,
and loses on ADP — the added 6-LUT concurrency is not worth it.

Packing, analysis and ratio computation run through the unified
``repro.core.flow`` pipeline; this driver only aggregates and emits.
"""
from __future__ import annotations

from repro.core import flow

from .common import Timer, emit, geomean, suites

RATIO_KEYS = {"area": "area_mwta", "cpd": "critical_path_ps", "adp": "adp"}


def run(verbose: bool = True):
    out: dict[str, dict] = {}
    results = flow.run_suites(suites("wallace"),
                              ("baseline", "dd5", "dd6"))
    for suite_name, rows in results.items():
        per_arch_ratios: dict[str, list[dict]] = {"dd5": [], "dd6": []}
        for row in rows:
            for arch, r in flow.ratios_vs_baseline(row["per_arch"]).items():
                per_arch_ratios[arch].append(
                    {k: r[mk] for k, mk in RATIO_KEYS.items()})
        out[suite_name] = {
            arch: {k: geomean([r[k] for r in rows_])
                   for k in RATIO_KEYS}
            for arch, rows_ in per_arch_ratios.items()
        }
        if verbose:
            for arch in ("dd5", "dd6"):
                v = out[suite_name][arch]
                emit(f"fig7/{suite_name}/{arch}", 0,
                     f"area={v['area']:.3f};cpd={v['cpd']:.3f};adp={v['adp']:.3f}")
    return out


def main():
    from repro.core.timing import read_timing_wall

    w0 = read_timing_wall()
    with Timer() as t:
        res = run()
    w1 = read_timing_wall()
    k = res["kratos"]
    emit("fig7_dd6", t.us,
         f"kratos_dd5_adp={k['dd5']['adp']:.3f};"
         f"kratos_dd6_adp={k['dd6']['adp']:.3f};"
         f"timing_s={w1['s'] - w0['s']:.3f}")
    return res


if __name__ == "__main__":
    main()
