"""Fig. 8 — placement-derived per-channel congestion on the Kratos suite.

Each circuit is grid-placed (:mod:`repro.core.place`) and every net's
bounding box over its producing/consuming LB slots is swept across the
vertical and horizontal routing channels it crosses
(:func:`repro.core.place.channel_congestion`).  The histogram is over
*channels* (demand / ``ArchParams.channel_width``), not the old per-LB
boundary-crossing proxy — congestion now concentrates where the placer
packs connected logic, which the proxy could not see.  Paper claim under
test: DD5 shifts utilization up (denser packing onto a smaller grid),
but everything stays routable (max utilization <= 1).
"""
from __future__ import annotations

import numpy as np

from repro.core.alm import ARCHS
from repro.core.circuits import kratos_suite
from repro.core.packing import pack
from repro.core.place import channel_congestion, place_and_apply

from .common import Timer, emit

BINS = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]


def run(verbose: bool = True):
    out = {}
    for arch in ("baseline", "dd5"):
        ap = ARCHS[arch]
        utils: list[float] = []
        peak = 0.0
        for net in kratos_suite(algo="wallace"):
            ir = place_and_apply(pack(net, ap, seed=0).lower_ir(), ap, seed=0)
            cong = channel_congestion(ir, arch=ap)
            demand = np.concatenate([cong["vertical"].ravel(),
                                     cong["horizontal"].ravel()])
            utils.extend((demand / ap.channel_width).tolist())
            peak = max(peak, cong["utilization"])
        hist = [0] * (len(BINS) - 1)
        for u in utils:
            for i in range(len(BINS) - 1):
                if BINS[i] <= u < BINS[i + 1] or (i == len(BINS) - 2 and u >= 1.0):
                    hist[i] += 1
                    break
        total = max(1, len(utils))
        out[arch] = {
            "hist": [h / total for h in hist],
            "mean": sum(utils) / total,
            "max": peak,
            "channels": len(utils),
        }
        if verbose:
            emit(f"fig8/{arch}", 0,
                 f"mean_util={out[arch]['mean']:.3f};max={out[arch]['max']:.3f};"
                 f"channels={out[arch]['channels']}")
    return out


def main():
    with Timer() as t:
        res = run()
    emit("fig8_congestion", t.us,
         f"base_mean={res['baseline']['mean']:.3f};dd5_mean={res['dd5']['mean']:.3f};"
         f"routable={res['dd5']['max'] <= 1.0}")
    return res


if __name__ == "__main__":
    main()
