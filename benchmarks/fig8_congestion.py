"""Fig. 8 — routing-demand histogram on the Kratos suite.

Placement-free proxy: per-LB boundary-crossing signal count over channel
capacity.  Paper: DD5 shifts utilization up (denser packing), but everything
stays routable.
"""
from __future__ import annotations

from repro.core.circuits import kratos_suite
from repro.core.packing import pack
from repro.core.timing import channel_utilization
from repro.core.alm import ARCHS

from .common import Timer, emit

BINS = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]


def run(verbose: bool = True):
    out = {}
    for arch in ("baseline", "dd5"):
        utils: list[float] = []
        for net in kratos_suite(algo="wallace"):
            utils.extend(channel_utilization(pack(net, ARCHS[arch], seed=0)))
        hist = [0] * (len(BINS) - 1)
        for u in utils:
            for i in range(len(BINS) - 1):
                if BINS[i] <= u < BINS[i + 1] or (i == len(BINS) - 2 and u >= 1.0):
                    hist[i] += 1
                    break
        total = max(1, len(utils))
        out[arch] = {
            "hist": [h / total for h in hist],
            "mean": sum(utils) / total,
            "max": max(utils),
        }
        if verbose:
            emit(f"fig8/{arch}", 0,
                 f"mean_util={out[arch]['mean']:.3f};max={out[arch]['max']:.3f}")
    return out


def main():
    with Timer() as t:
        res = run()
    emit("fig8_congestion", t.us,
         f"base_mean={res['baseline']['mean']:.3f};dd5_mean={res['dd5']['mean']:.3f};"
         f"routable={res['dd5']['max'] <= 1.0}")
    return res


if __name__ == "__main__":
    main()
