"""§Perf hillclimbing driver: run baseline + optimized variants of the three
selected cells, re-lower/re-compile each, and log
hypothesis -> change -> before -> after.

Selected cells (from the §Roofline table):
  1. kimi-k2-1t-a32b x train_4k     — most collective-bound (FSDP gathers)
  2. deepseek-moe-16b x decode_32k  — worst roofline fraction (KV-cache BW)
  3. hymba-1.5b x train_4k          — most paper-representative (two
     concurrent mixer primitives per layer; SWA layers pay full S^2)

Each variant is re-lowered and re-compiled through the same dry-run path
(proving the optimization actually compiles on the production mesh) and the
analytic roofline terms quantify the delta; the compiled HLO collective
inventory is the cross-check.

Cell 4 is the netlist-evaluation engine itself (the paper-side hot path):
fused single-jit evaluator vs the seed per-level dispatcher on the Fig. 9
stress workload, gated on pack/re-elaborate equivalence.

Cell 5 (``suite-eval``) is the suite-scale flow: evaluate the re-elaborated
Kratos + Koios + VTR suites per arch as a handful of envelope-grouped
vmapped jit programs (``core.flow.evaluate_suite``) vs one fused program
per circuit, gated on pack equivalence exactly like cell 4, with every
grouped result proven bit-identical to the Python oracle.  Records land in
``experiments/perf/suite_eval_grouped.json``.

NOTE: the model cells must run in a fresh process (``run_variant`` imports
launch.dryrun, which sets the 512-device XLA flag on first use).  Run
``python -m benchmarks.perf_iterations netlist-eval`` (cell 4) or
``python -m benchmarks.perf_iterations suite-eval`` (cell 5) alone — those
paths never import dryrun, so timings see the real host device.
"""
import dataclasses
import json
import os
import sys

from repro.configs.base import get_config
from repro.train.optimizer import OptConfig
from repro.train.step import TrainConfig

from .common import min_of_n
from .roofline import analytic_terms

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")


def run_variant(tag, arch, shape, cfg=None, tcfg=None, force=False):
    from repro.launch import dryrun  # sets the 512-device XLA flag

    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, f"{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    record, lowered = dryrun.lower_cell(arch, shape, False, cfg=cfg,
                                        tcfg=tcfg)
    record = dryrun.compile_cell(record, lowered)
    t = analytic_terms(arch, shape, "single", record["n_params"],
                       record["n_active_params"],
                       cfg=cfg or get_config(arch),
                       fp8_expert_gather=bool(tcfg and
                                              tcfg.fp8_expert_gather))
    record["terms"] = {k: t[k] for k in ("t_compute", "t_memory",
                                         "t_collective", "flops",
                                         "hbm_bytes", "coll_bytes")}
    record["tag"] = tag
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def show(rec):
    t = rec["terms"]
    dom = max(("t_compute", "t_memory", "t_collective"), key=lambda k: t[k])
    coll = rec.get("collectives", {})
    kinds = {k: v["bytes"] for k, v in coll.items()
             if isinstance(v, dict)}
    print(f"{rec['tag']:34s} compute={t['t_compute']:.3e}s "
          f"memory={t['t_memory']:.3e}s coll={t['t_collective']:.3e}s "
          f"dominant={dom[2:]} | HLO coll/dev: {kinds}", flush=True)
    return t


def run_netlist_eval_cell(force: bool = False) -> dict:
    """Cell 4: hypothesis — the seed evaluator is dispatch-bound (one kernel
    launch per LUT level and one scan per chain); change — fuse all levels
    into a single-jit ``lax.scan`` over padded tensors; before/after —
    recorded below (acceptance gate: fused >= 2x on the Fig. 9 workload,
    with pack equivalence proven so the speed is not bought with wrong
    answers)."""
    from .fig9_stress import run_eval_benchmark

    # the model cells force 512 fake host devices (launch.dryrun sets
    # XLA_FLAGS at import); timings taken under that env are not
    # comparable to real-device runs, so tag the record with the env and
    # never serve a cached record from the other one
    env = _device_env()
    os.makedirs(OUT, exist_ok=True)
    suffix = "" if env == "host" else f"_{env}"
    path = os.path.join(OUT, f"netlist_eval_fused{suffix}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            cached = json.load(f)
        if cached.get("device_env") == env:
            return cached
    rec = {"tag": "netlist_eval_fused", "device_env": env}
    for use_pallas in (True, False):
        r = run_eval_benchmark(use_pallas=use_pallas, verbose=False)
        key = "pallas" if use_pallas else "jnp"
        rec[key] = r
        print(f"netlist_eval[{key:6s}] levels={r['t_levels_s']*1e3:9.1f}ms "
              f"fused={r['t_fused_s']*1e3:7.2f}ms "
              f"speedup={r['speedup']:8.1f}x equiv={r['equiv']}", flush=True)
    rec["speedup_min"] = min(rec["pallas"]["speedup"], rec["jnp"]["speedup"])
    rec["pass_2x_gate"] = rec["speedup_min"] >= 2.0
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _device_env() -> str:
    return ("512dev" if "xla_force_host_platform_device_count"
            in os.environ.get("XLA_FLAGS", "") else "host")


def run_suite_eval_cell(force: bool = False, n_lane_words: int = 4,
                        reps: int = 3) -> dict:
    """Cell 5: hypothesis — per-circuit fused eval leaves suite-scale
    throughput on the table (one compile + one dispatch per circuit, and a
    worst-case [L, M_max, 6] envelope wastes padded rows); change — width-
    bucketed plans + envelope-grouped vmapped evaluation via
    ``core.flow.evaluate_suite``; before/after — recorded below, gated on
    pack equivalence and on grouped-vs-oracle bit-identity."""
    import time

    import jax
    import numpy as np

    from repro.core import flow
    from repro.core.equiv import (equivalence_report, reelaborate,
                                  symbolic_equivalence_report)
    from repro.core.packing import pack as pack_fn
    from repro.core.alm import ARCHS

    from .common import suites
    from .roofline import netlist_eval_terms

    env = _device_env()
    os.makedirs(OUT, exist_ok=True)
    suffix = "" if env == "host" else f"_{env}"
    path = os.path.join(OUT, f"suite_eval_grouped{suffix}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            cached = json.load(f)
        if cached.get("device_env") == env:
            return cached
    nets = [net for nets_ in suites("wallace").values() for net in nets_]
    rec = {"tag": "suite_eval_grouped", "device_env": env,
           "n_lane_words": n_lane_words, "n_circuits": len(nets),
           "archs": {}}
    for arch_name in ("baseline", "dd5"):
        arch = ARCHS[arch_name]
        phys_nets, methods, gate_ok = [], {}, True
        for net in nets:
            re_elab = reelaborate(pack_fn(net, arch, seed=0))
            srep = symbolic_equivalence_report(net, re_elab)
            if srep["equivalent"]:
                methods[net.name] = "symbolic"
            else:
                rep = equivalence_report(net, re_elab, n_vectors=64)
                methods[net.name] = "simulate"
                gate_ok &= rep["equivalent"]
            phys_nets.append(re_elab.phys)
        lanes = [flow.random_lanes(p, n_lane_words, seed=i)
                 for i, p in enumerate(phys_nets)]
        # plans and the grouped suite program are prepared once, outside
        # the timed region, so both sides time evaluation (results are
        # materialized as np arrays — no async dispatch escapes the clock)
        prog = flow.prepare_suite(phys_nets)
        plans = [flow.plan_netlist(p) for p in phys_nets]
        stats = prog.stats

        def grouped():
            return flow.evaluate_suite(phys_nets, lanes, n_lane_words,
                                       program=prog)[0]

        def per_circuit():
            return [flow.evaluate_netlist(p, ln, n_lane_words, plan=pl)
                    for p, ln, pl in zip(phys_nets, lanes, plans)]

        # the backend-aware cost model (ROADMAP item): its warm-path
        # pick is recorded next to both measured warm walls below, so
        # the model is auditable against the walls it predicts
        model = flow.eval_mode_cost_model(phys_nets, plans=plans,
                                          warm=True)
        # suite-per-arch wall time, COLD: one full pass including jit
        # compiles — the number a figure run actually pays.  Grouped
        # compiles <= 4 programs; per-circuit compiles one per circuit.
        jax.clear_caches()
        t0 = time.perf_counter()
        outs_g = grouped()
        t_cold_grouped = time.perf_counter() - t0
        jax.clear_caches()
        t0 = time.perf_counter()
        per_circuit()
        t_cold_single = time.perf_counter() - t0
        # WARM steady-state (compiles cached), min-of-``reps`` via the
        # shared gate timer
        t_grouped, _ = min_of_n(grouped, n=reps)
        t_single, _ = min_of_n(per_circuit, n=reps)
        warm_winner = "grouped" if t_grouped <= t_single else "per_circuit"
        warm_gap = abs(t_grouped - t_single) / max(t_grouped, t_single)
        oracle_ok = all(
            flow.oracle_check(p, ln, vals, n_lane_words)
            for p, ln, vals in zip(phys_nets, lanes, outs_g))
        real = sum(p.n_luts + p.n_adders for p in phys_nets)
        padded_grouped = sum(g["padded_lut_rows"] + g["padded_chain_bits"]
                             for g in stats["groups"])
        terms = [netlist_eval_terms(p, n_lane_words) for p in phys_nets]
        waste_single = float(np.mean(
            [t["padding_waste_single_envelope"] for t in terms]))
        rec["archs"][arch_name] = {
            "equiv_gate_ok": gate_ok,
            "equiv_methods": methods,
            "n_groups": stats["n_groups"],
            "groups": stats["groups"],
            "t_suite_grouped_s": t_cold_grouped,
            "t_suite_per_circuit_s": t_cold_single,
            "suite_speedup": t_cold_single / t_cold_grouped,
            "t_warm_grouped_s": t_grouped,
            "t_warm_per_circuit_s": t_single,
            "warm_speedup": t_single / t_grouped,
            "padding_waste_grouped": 1.0 - real / max(padded_grouped, 1),
            "padding_waste_single_envelope_mean": waste_single,
            "oracle_match": bool(oracle_ok),
            # warm-path grouping heuristic: the model's pick, its cost
            # terms, and whether the measured warm walls agree.  On hosts
            # where the two paths land within the run-to-run noise band
            # (the winner flips between recordings), either pick is
            # correct — "agrees" accounts for that explicitly.
            "cost_model": model,
            "warm_measured_winner": warm_winner,
            "warm_gap_frac": warm_gap,
            "cost_model_agrees_warm": (model["pick"] == warm_winner
                                       or warm_gap < 0.25),
        }
        print(f"suite_eval[{arch_name:8s}] circuits={len(nets)} "
              f"groups={stats['n_groups']} "
              f"suite: grouped={t_cold_grouped:6.2f}s "
              f"per-circuit={t_cold_single:6.2f}s "
              f"({t_cold_single/t_cold_grouped:4.1f}x) "
              f"warm: {t_grouped*1e3:6.1f}ms vs {t_single*1e3:6.1f}ms "
              f"model_pick={model['pick']} "
              f"oracle={oracle_ok} gate={gate_ok}", flush=True)
    rec["suite_speedup_min"] = min(a["suite_speedup"]
                                   for a in rec["archs"].values())
    rec["pass_gate"] = (rec["suite_speedup_min"] > 1.0
                        and all(a["equiv_gate_ok"] and a["oracle_match"]
                                for a in rec["archs"].values())
                        and all(a["n_groups"] <= 4
                                for a in rec["archs"].values()))
    # read-merge: other recorders (grouping-delta) share this file —
    # a forced re-run must not drop their keys
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged.update(rec)
    with open(path, "w") as f:
        json.dump(merged, f, indent=1)
    return merged


def record_grouping_delta(arch_name: str = "baseline") -> dict:
    """Satellite record: the value-buffer padded-row delta from the
    size-aware grouping term in ``group_plans_by_envelope`` (volume-only
    cost vs volume + signal-count cost), on the re-elaborated 17-circuit
    suite.  Appended to ``suite_eval_grouped.json`` under
    ``size_aware_grouping``."""
    from repro.core.alm import ARCHS
    from repro.core.equiv import reelaborate
    from repro.core.eval_jax import (group_plans_by_envelope,
                                     grouping_padded_value_rows,
                                     plan_netlist)
    from repro.core.packing import pack as pack_fn

    from .common import suites

    nets = [net for nets_ in suites("wallace").values() for net in nets_]
    phys = [reelaborate(pack_fn(net, ARCHS[arch_name], seed=0)).phys
            for net in nets]
    plans = [plan_netlist(p) for p in phys]

    def plan_volume(groups):
        tot = 0
        for g in groups:
            env = [0, 0, 0, 0]
            for i in g:
                env = [max(a, b) for a, b in zip(env, plans[i].envelope)]
            L, M, C, B = env
            tot += len(g) * L * (M + C * B)
        return tot

    g_vol = group_plans_by_envelope(plans, signal_weight=0.0)
    g_size = group_plans_by_envelope(plans)
    rows_vol = grouping_padded_value_rows(plans, g_vol)
    rows_size = grouping_padded_value_rows(plans, g_size)
    rec = {
        "arch": arch_name,
        "n_circuits": len(nets),
        "groups_volume_only": g_vol,
        "groups_size_aware": g_size,
        "value_rows_real": rows_vol["real_rows"],
        "value_rows_volume_only": rows_vol["padded_rows"],
        "value_rows_size_aware": rows_size["padded_rows"],
        "value_rows_delta": rows_vol["padded_rows"] - rows_size["padded_rows"],
        "plan_volume_volume_only": plan_volume(g_vol),
        "plan_volume_size_aware": plan_volume(g_size),
    }
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "suite_eval_grouped.json")
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["size_aware_grouping"] = rec
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
    print(f"grouping_delta[{arch_name}] value rows: real="
          f"{rec['value_rows_real']} volume-only="
          f"{rec['value_rows_volume_only']} size-aware="
          f"{rec['value_rows_size_aware']} "
          f"(delta {rec['value_rows_delta']}); plan volume "
          f"{rec['plan_volume_volume_only']} -> "
          f"{rec['plan_volume_size_aware']}", flush=True)
    return rec


def main():
    print("== cell 1: kimi-k2 train_4k (collective-bound) ==")
    base = run_variant("kimi_train_base", "kimi-k2-1t-a32b", "train_4k")
    show(base)
    # iteration 1: fp8 expert-weight FSDP gathers
    t8 = TrainConfig(opt=OptConfig(name="adafactor"), fp8_expert_gather=True)
    v1 = run_variant("kimi_train_fp8gather", "kimi-k2-1t-a32b", "train_4k",
                     tcfg=t8)
    show(v1)

    print("== cell 2: deepseek decode_32k (memory-bound) ==")
    base2 = run_variant("deepseek_decode_base", "deepseek-moe-16b",
                        "decode_32k")
    show(base2)
    cfg_kv8 = dataclasses.replace(get_config("deepseek-moe-16b"),
                                  kv_cache_dtype="int8")
    v2 = run_variant("deepseek_decode_kv8", "deepseek-moe-16b", "decode_32k",
                     cfg=cfg_kv8)
    show(v2)

    print("== cell 3: hymba train_4k (paper-representative) ==")
    base3 = run_variant("hymba_train_base", "hymba-1.5b", "train_4k")
    show(base3)
    # it 3.1 (REFUTED at S=4k): chunked SWA is flops-neutral when 2w == S/2
    cfg_sw = dataclasses.replace(get_config("hymba-1.5b"),
                                 chunked_local_attn=True, unroll_layers=True)
    v3 = run_variant("hymba_train_chunked_swa", "hymba-1.5b", "train_4k",
                     cfg=cfg_sw)
    show(v3)
    # it 3.2: chunked-dual SSD scan — 4096 serial recurrences -> 32 dense
    # chunk steps (MXU-friendly); flops ~equal, serialization /128
    cfg_ssd = dataclasses.replace(get_config("hymba-1.5b"), ssd_chunk=128)
    v3b = run_variant("hymba_train_ssd_chunked", "hymba-1.5b", "train_4k",
                      cfg=cfg_ssd)
    show(v3b)
    cfg_m = dataclasses.replace(get_config("mamba2-2.7b"), ssd_chunk=128)
    v3c = run_variant("mamba2_train_ssd_chunked", "mamba2-2.7b", "train_4k",
                      cfg=cfg_m)
    show(v3c)
    b3c = run_variant("mamba2_train_base", "mamba2-2.7b", "train_4k")
    show(b3c)

    # combined: kv8 + chunked swa also helps gemma2 prefill (bonus check)
    cfg_g2 = dataclasses.replace(get_config("gemma2-2b"),
                                 chunked_local_attn=True, unroll_layers=True)
    v4 = run_variant("gemma2_prefill_chunked", "gemma2-2b", "prefill_32k",
                     cfg=cfg_g2)
    show(v4)
    b4 = run_variant("gemma2_prefill_base", "gemma2-2b", "prefill_32k")
    show(b4)

    print("== cell 4: netlist eval — fused single-jit vs per-level ==")
    run_netlist_eval_cell()

    print("== cell 5: suite eval — envelope-grouped vs per-circuit ==")
    run_suite_eval_cell()


if __name__ == "__main__":
    if "netlist-eval" in sys.argv[1:]:
        run_netlist_eval_cell(force="force" in sys.argv[1:])
    elif "suite-eval" in sys.argv[1:]:
        run_suite_eval_cell(force="force" in sys.argv[1:])
    elif "grouping-delta" in sys.argv[1:]:
        record_grouping_delta()
    else:
        main()
