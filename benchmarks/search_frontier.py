"""Thousand-point design-space search — the successive-halving frontier.

The recorded scenario (``experiments/perf/search_frontier.json``):

* **the search** — :func:`repro.core.search.search_archs` over the full
  :func:`repro.core.alm.full_arch_grid` cross-product (~2000 grid points,
  ~1200 structural classes), rung by rung from the 3 smallest circuits to
  the full Kratos + Koios + VTR suite, with the per-rung pack / lower /
  place / time / eval wall split, the survivor trajectory and the final
  ADP Pareto front;
* **honesty gates** — every promoted winner is re-derived by a fresh
  ``pack()`` + Python oracle walk (bit-identity) and equivalence-proven
  (:func:`repro.core.search.verify_winners`), and the JSON states
  whether the found front contains or dominates the paper's DD5 point;
* **the >= 2x cost gate** — on a 64-point subgrid (the largest slice a
  dense sweep still finishes in reasonable time), min-of-N walls of the
  full dense sweep vs the search, both from cold caches.  The search
  must be >= 2x cheaper while agreeing on the winner;
* **the bandit variant** — the same subgrid searched with the optimistic
  allocation (``allocation="bandit"``), recorded for comparison;
* **the placed wire-axis search** — ``search_archs(place=True)`` over
  the canonical grid crossed with routed wire-tier profiles
  (:data:`benchmarks.place_sweep.WIRE_PROFILES`).  Annealed placements
  (:mod:`repro.core.anneal`) price the wire tiers, so the ``_w{n}``
  rows — bit-identical ties in every unplaced sweep — become searchable
  grid points.  The promoted winner is placed-oracle-parity-gated
  (``verify_winners(place=True)``), the annealing wall is attributed in
  every rung's ledger (``walls["anneal_s"]``), and the min-of-N
  **placement-reuse >= 2x gate** (one anneal per placement key, shared
  across the wire rows of a class, vs a fresh refine at every grid
  point) rides along from :func:`benchmarks.place_sweep.placement_reuse_gate`.

``--smoke`` (also wired into ``scripts/check.sh`` via ``benchmarks.run
--smoke``) runs a 2-rung, 8-point, 2-circuit search gated on oracle
parity of the winner and on a dense-vs-search cost ratio >= 1.
"""
from __future__ import annotations

import json
import os
import time

from repro.core.alm import arch_grid, full_arch_grid, subgrid
from repro.core.packing import pack
from repro.core.plan import clear_caches
from repro.core.search import search_archs, verify_winners
from repro.core.sweep import _flatten, sweep_suite

from .common import Timer, emit, min_of_n, suites
from .place_sweep import WIRE_PROFILES, placement_reuse_gate

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")

#: the paper's DD5 grid row (canonical geometry) — the point the found
#: front must contain or dominate
DD5_NAME = "b2_f10"


def _smoke_nets():
    """Two circuits with a real size gap — the search's whole premise is
    that the small one screens archs before the big one pays."""
    from repro.core.circuits import kratos_gemm, sha_like

    return [kratos_gemm(m=4, n=4, width=4, sparsity=0.5),
            sha_like(rounds=4)]


def _dense_vs_search(nets, grid, seed: int, n_runs: int,
                     search_kwargs: dict) -> dict:
    """Min-of-N cold walls: dense ``sweep_suite`` over the whole grid vs
    the successive-halving search on the same grid.  Each sample starts
    from cleared registries and private caches so neither side rides the
    other's warm state."""

    def dense():
        clear_caches()
        return sweep_suite(nets, grid, seed=seed, backend="numpy",
                           packs={}, programs={}, prefixes={})

    def search():
        clear_caches()
        return search_archs(nets, grid, seed=seed, packs={}, programs={},
                            **search_kwargs)

    t_dense, dense_res = min_of_n(dense, n=n_runs)
    t_search, search_res = min_of_n(search, n=n_runs)
    # both must name the same full-suite optimum for the cost ratio to
    # mean anything; the dense reference ranks by the same ADP frontier
    from repro.core.sweep import adp_frontier

    dense_rows = adp_frontier(dense_res,
                              baseline=search_kwargs.get("baseline"))
    ratio = t_dense / max(t_search, 1e-9)
    return {
        "n_points": len(grid),
        "n_classes": dense_res.n_classes,
        "n_runs": n_runs,
        "t_dense_s": t_dense,
        "t_search_s": t_search,
        "ratio": ratio,
        "dense_winner": dense_rows[0]["arch"] if dense_rows else None,
        "search_winner": search_res.winner,
        "winners_agree": bool(
            dense_rows and dense_rows[0]["arch"] == search_res.winner),
        "search_result": search_res,
    }


def run(smoke: bool = False, verbose: bool = True, seed: int = 0,
        write_json: bool = True) -> dict:
    if smoke:
        nets = _smoke_nets()
        grid = subgrid(full_arch_grid(), 8)
        search_kwargs = dict(eta=4, min_survivors=2, min_circuits=1,
                             baseline="b0", backend="numpy")
        gate = _dense_vs_search(nets, grid, seed, n_runs=2,
                                search_kwargs=search_kwargs)
        res = gate.pop("search_result")
        ver = verify_winners(res, nets, grid, seed=seed,
                             n_equiv_circuits=1, winners=[res.winner])
        ratio_ok = gate["ratio"] >= 1.0
        rec = {
            "tag": "search_frontier", "smoke": True,
            "n_archs": len(grid), "n_rungs": len(res.rungs),
            "winner": res.winner,
            "search": res.payload(),
            "verify": {k: ver[k] for k in
                       ("winners", "oracle_match", "equivalent")},
            "dense_gate": {k: v for k, v in gate.items()},
            "oracle_match": ver["oracle_match"] and ver["equivalent"],
            "pass_gate": (ver["oracle_match"] and ver["equivalent"]
                          and ratio_ok),
        }
        if verbose:
            emit("search/smoke", 0,
                 f"winner={res.winner};rungs={len(res.rungs)};"
                 f"dense={gate['t_dense_s']:.2f}s;"
                 f"search={gate['t_search_s']:.2f}s;"
                 f"ratio={gate['ratio']:.2f}x;"
                 f"oracle_match={ver['oracle_match']};"
                 f"equivalent={ver['equivalent']}")
        return rec

    _, nets = _flatten(suites("wallace"))
    grid = full_arch_grid()
    # generous eval budget — not binding at this schedule, but the ledger
    # (requested vs used) is part of the recorded contract
    budget = 12_000

    clear_caches()
    t0 = time.perf_counter()
    res = search_archs(nets, grid, seed=seed, eta=4, min_survivors=8,
                       min_circuits=3, baseline="b0", backend="numpy",
                       budget=budget)
    t_search = time.perf_counter() - t0

    ver = verify_winners(res, nets, grid, seed=seed, n_equiv_circuits=2)

    # DD5 containment: in the final frontier (compare ADP directly), or
    # dominated by the winner on the full-suite dense reference of the
    # two points
    front_names = [r["arch"] for r in res.pareto]
    by_name = {a.name: a for a in grid}
    dd5_row = next((r for r in res.frontier if r["arch"] == DD5_NAME), None)
    if dd5_row is None:
        # DD5 was culled before the final rung: time it on the full
        # suite next to the winner for an apples-to-apples ADP
        from repro.core.sweep import adp_frontier

        ref = sweep_suite(nets, [by_name["b0"], by_name[DD5_NAME],
                                 by_name[res.winner]], seed=seed,
                          backend="numpy")
        rows = adp_frontier(ref, baseline="b0")
        dd5_adp = next(r["adp"] for r in rows if r["arch"] == DD5_NAME)
        winner_adp = next(r["adp"] for r in rows if r["arch"] == res.winner)
    else:
        dd5_adp = dd5_row["adp"]
        winner_adp = res.frontier[0]["adp"]
    dd5 = {
        "name": DD5_NAME,
        "in_final_rung": dd5_row is not None,
        "in_pareto_front": DD5_NAME in front_names,
        "dd5_adp": dd5_adp,
        "winner_adp": winner_adp,
        "contained_or_dominated": (DD5_NAME in front_names
                                   or winner_adp <= dd5_adp),
    }

    # the >= 2x min-of-N cost gate on the 64-point subgrid
    sub = subgrid(grid, 64)
    gate_kwargs = dict(eta=4, min_survivors=8, min_circuits=3,
                       baseline="b0", backend="numpy")
    gate = _dense_vs_search(nets, sub, seed, n_runs=2,
                            search_kwargs=gate_kwargs)
    gate.pop("search_result")
    gate["pass"] = bool(gate["ratio"] >= 2.0 and gate["winners_agree"])

    # the bandit allocation variant on the same subgrid (recorded, not
    # gated — it trades extra rung-0 survivors for robustness to noisy
    # small-subset estimates)
    clear_caches()
    t0 = time.perf_counter()
    bres = search_archs(nets, sub, seed=seed, allocation="bandit",
                        packs={}, programs={}, **gate_kwargs)
    t_bandit = time.perf_counter() - t0
    bandit = {
        "winner": bres.winner,
        "t_search_s": t_bandit,
        "survivors_per_rung": [len(r["survivors"]) for r in bres.rungs],
        "agrees_with_halving": bres.winner == gate["search_winner"],
    }

    # the placed wire-delay-axis search: canonical grid x wire profiles,
    # annealed placements making the _w{n} rows distinct grid points
    placed_grid = arch_grid(wire_delays=WIRE_PROFILES)
    placed_packs: dict = {}
    clear_caches()
    t0 = time.perf_counter()
    pres = search_archs(nets, placed_grid, seed=seed, eta=4,
                        min_survivors=4, min_circuits=3, baseline="b0",
                        backend="numpy", place=True, packs=placed_packs,
                        programs={})
    t_placed = time.perf_counter() - t0
    pver = verify_winners(pres, nets, placed_grid, seed=seed,
                          n_equiv_circuits=2, winners=[pres.winner],
                          place=True)
    # the search culls, so fill pack coverage for the reuse gate (cheap:
    # most pairs are registry hits from the rungs above)
    digests = [n.content_digest() for n in nets]
    for g, net in enumerate(nets):
        for a in placed_grid:
            key = (digests[g], a.structural_key(), seed)
            if key not in placed_packs:
                placed_packs[key] = pack(net, a, seed=seed)
    preuse = placement_reuse_gate(nets, placed_grid, placed_packs,
                                  seed=seed)
    anneal_wall = sum(r["walls"]["anneal_s"] for r in pres.rungs)
    placed = {
        "n_points": len(placed_grid),
        "wire_profiles": [list(w) for w in WIRE_PROFILES],
        "winner": pres.winner,
        "t_search_s": t_placed,
        "anneal_wall_s": anneal_wall,
        "anneal_wall_attributed": anneal_wall > 0.0,
        "walls_per_rung": [r["walls"] for r in pres.rungs],
        "frontier": pres.frontier,
        "pareto": pres.pareto,
        "wire_rows_in_final_rung": sorted(
            r["arch"] for r in pres.frontier if "_w" in r["arch"]),
        "verify": {k: pver[k] for k in
                   ("winners", "oracle_match", "equivalent")},
        "placement_reuse": preuse,
        "pass": (pver["oracle_match"] and pver["equivalent"]
                 and anneal_wall > 0.0 and preuse["pass_gate"]),
    }

    rec = {
        "tag": "search_frontier",
        "smoke": False,
        "n_archs": len(grid),
        "n_structural_classes": res.rungs[0]["n_classes"],
        "n_circuits": len(nets),
        "t_search_s": t_search,
        "search": res.payload(),
        "walls_total": res.walls,
        "survivor_trajectory": res.survivor_trajectory(),
        "dd5": dd5,
        "verify": {k: ver[k] for k in
                   ("winners", "oracle_match", "equivalent", "mismatches")},
        "dense_gate_64": gate,
        "bandit_64": bandit,
        "placed_search": placed,
        "oracle_match": ver["oracle_match"] and ver["equivalent"],
        "pass_gate": (ver["oracle_match"] and ver["equivalent"]
                      and dd5["contained_or_dominated"] and gate["pass"]
                      and placed["pass"]),
    }
    if write_json:
        os.makedirs(OUT, exist_ok=True)
        with open(os.path.join(OUT, "search_frontier.json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        for row in res.pareto:
            emit(f"search/pareto/{row['arch']}", 0,
                 f"area={row['area_mwta']:.3f};"
                 f"cpd={row['critical_path_ps']:.3f};adp={row['adp']:.3f}")
        for r in res.rungs:
            w = r["walls"]
            emit(f"search/rung{r['rung']}", 0,
                 f"archs={r['n_archs']};classes={r['n_classes']};"
                 f"circuits={r['n_circuits']};best={r['best']};"
                 f"pack={w['pack_s']:.2f}s;lower={w['lower_s']:.2f}s;"
                 f"place={w['place_s']:.2f}s;time={w['time_s']:.2f}s;"
                 f"eval={w['eval_s']:.2f}s")
        emit("search/summary", 0,
             f"archs={len(grid)};classes={rec['n_structural_classes']};"
             f"winner={res.winner};winner_adp={winner_adp:.3f};"
             f"dd5_adp={dd5_adp:.3f};"
             f"dd5_ok={dd5['contained_or_dominated']};"
             f"budget={res.budget['used']}/{res.budget['requested']};"
             f"t={t_search:.1f}s;oracle_match={rec['oracle_match']}")
        emit("search/dense_gate_64", 0,
             f"dense={gate['t_dense_s']:.2f}s;"
             f"search={gate['t_search_s']:.2f}s;ratio={gate['ratio']:.2f}x;"
             f"winners_agree={gate['winners_agree']};gate={gate['pass']}")
        emit("search/placed", 0,
             f"points={len(placed_grid)};winner={pres.winner};"
             f"t={t_placed:.1f}s;anneal={anneal_wall:.2f}s;"
             f"wire_rows={len(placed['wire_rows_in_final_rung'])};"
             f"reuse={preuse['speedup_reuse']:.1f}x;"
             f"oracle_match={pver['oracle_match']};gate={placed['pass']}")
    return rec


def main():
    with Timer() as t:
        rec = run()
    emit("search_frontier", t.us,
         f"archs={rec['n_archs']};classes={rec['n_structural_classes']};"
         f"winner={rec['search']['winner']};"
         f"dd5_ok={rec['dd5']['contained_or_dominated']};"
         f"dense_ratio_64={rec['dense_gate_64']['ratio']:.2f}x;"
         f"pass={rec['pass_gate']}")
    return rec


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        rec = run(smoke=True)
        sys.exit(0 if rec["pass_gate"] else 1)
    main()
