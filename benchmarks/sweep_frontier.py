"""Design-space sweep — ADP frontiers over the DD architecture grid.

Two scenarios the paper never measured:

* **delay-space frontier** — every circuit of the Kratos + Koios + VTR
  suites re-timed across the bypass-width x AddMux-population grid
  (:func:`repro.core.alm.arch_grid`; the canonical baseline/DD5/DD6 are
  three of the rows).  Packing happens once per *structural class*;
  timing runs as one batched ``lax.scan``/``vmap`` jit program per class
  over the class's delay-table rows (:mod:`repro.core.sweep`).
* **cluster-geometry frontier** — the *structural* axes the paper holds
  at the Stratix-10-like point: bypass width x ``alms_per_lb`` x
  ``lb_inputs``.  Every point is its own structural class, so this is
  the incremental repacker's stress test: one packing prefix per
  circuit (:func:`repro.core.repack.pack_prefix`), one cheap
  re-clustering + incremental IR patch per class, against the naive
  full-``pack()``-per-point baseline it must beat by >= 2x.

Both runs are gated on bit-identity against the per-circuit Python
timing oracle and record wall times in
``experiments/perf/timing_sweep.json``:

* ``t_oracle_s``      — per-circuit ``analyze_oracle`` over every
  (circuit, grid point), the seed-style dict walk;
* ``t_vector_cold_s`` — IR lowering + program build + first batched run
  (includes jit compiles);
* ``t_vector_warm_s`` — the same sweep re-run with packs and compile
  caches hot (what an interactive frontier exploration pays per step);
* ``cluster_geometry.*`` — incremental vs full-per-point pack walls,
  the >= 2x gate, and the geometry ADP frontier rows.

Pack time is excluded from the timing comparison (identical work,
shared by construction on the vector side) and measured *as the
subject* in the cluster-geometry section.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

from repro.core.alm import arch_grid
from repro.core.sweep import _flatten, adp_frontier, sweep_suite
from repro.core.timing import analyze_oracle

from .common import Timer, emit, min_of_n, suites

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")


def _smoke_suites():
    from repro.core.circuits import kratos_gemm, sha_like, vtr_mixed

    return {"smoke": [kratos_gemm(m=5, n=5, width=5, sparsity=0.5),
                      sha_like(rounds=1),
                      vtr_mixed(logic_nodes=150, adders=2)]}


def cluster_geometry(nets, seed: int = 0, smoke: bool = False) -> dict:
    """The cluster-geometry ADP frontier: bypass width x ``alms_per_lb``
    x ``lb_inputs``.  Every grid point is a distinct structural class,
    so the sweep exercises the incremental repacking engine end-to-end
    (shared prefixes, per-class re-clustering, incremental IR patching)
    and is measured against the naive full-``pack()``-per-point
    baseline.  Gated on per-point bit-identity against ``analyze_oracle``
    over the *full* per-point packs — which simultaneously proves
    ``repack(prefix, arch) == pack(net, arch)`` for every point."""
    import gc

    from repro.core.packing import pack

    if smoke:
        # the 2-point structural-axis smoke sweep (scripts/check.sh)
        grid = arch_grid(bypass_inputs=(2,), addmux_fanin=(10,),
                         lut6=(False,), alms_per_lb=(8, 10))
    else:
        # 16 structural classes: bypass x LB capacity x LB inputs x pin
        # utilization — every point needs its own (re-)clustering
        grid = arch_grid(bypass_inputs=(0, 2), addmux_fanin=(10,),
                         lut6=(False,), alms_per_lb=(8, 10),
                         lb_inputs=(48, 60), ext_pin_util=(0.8, 0.9))
    # both measured phases run without the cyclic GC: the incremental
    # sweep legitimately retains every class's packs/IRs (that is the
    # engine's warm-path contract), and generational scans over those
    # resident objects would bill the retention to the re-cluster loop
    # while the retention-free baseline loop runs unscanned
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        from repro.core.plan import clear_caches

        # min-of-N on the gated (cheap) side: container noise only ever
        # inflates a sample, and an inflated t_pack_inc is what used to
        # flake the >= 2x gate.  Each wall term takes its own min across
        # the runs (a sum of per-term mins — mixing one run's best pack
        # with another run's noisy lower would re-introduce the flake on
        # the pack-to-IR ratio).  The slow full-per-point baseline below
        # runs once — its noise can only overstate the baseline, which
        # never fails the gate spuriously.
        pack_samples, lower_samples = [], []
        res = None
        for _ in range(1 if smoke else 2):
            # cold semantics per sample: no warm templates / functional
            # IRs from the previous repetition
            clear_caches()
            res = sweep_suite(nets, grid, seed=seed)
            pack_samples.append(res.wall["pack_s"])
            lower_samples.append(res.wall["lower_s"])
        t_pack_inc = min(pack_samples)
        t_lower_inc = min(lower_samples)

        # the naive baseline this engine replaces: one full pack per
        # (circuit, grid point), plus a fresh `lower_ir(cache=False)`
        # per point.  NOTE on the lowering side: since the CircuitIR
        # unification a "fresh" lowering is the placement patch over the
        # content-cached functional IR (levelization once per circuit
        # digest), so t_lower_full_per_point_s measures today's real
        # fresh-lowering cost, not the pre-PR-5 re-levelize-every-point
        # cost — the full and incremental lower walls are expected to
        # converge, and the engine's gate is the pack wall.  Timed,
        # parity-checked against the incremental sweep's record, and
        # dropped (nothing from the per-point baseline is retained)
        _, flat_nets = _flatten(nets)
        t_pack_full = 0.0
        t_lower_full = 0.0
        match = True
        for g, net in enumerate(flat_nets):
            for k, arch in enumerate(grid):
                t0 = time.perf_counter()
                p = pack(net, arch, seed=seed)
                t_pack_full += time.perf_counter() - t0
                t0 = time.perf_counter()
                p.lower_ir(cache=False)
                t_lower_full += time.perf_counter() - t0
                want = analyze_oracle(p)
                got = res.records[g][k]
                if (want["critical_path_ps"] != got["critical_path_ps"]
                        or want["area_mwta"] != got["area_mwta"]):
                    match = False
    finally:
        if gc_was_enabled:
            gc.enable()
        gc.collect()
    frontier = adp_frontier(res, baseline=res.archs[0])
    speedup_pack = t_pack_full / max(t_pack_inc, 1e-9)
    speedup_pipeline = (t_pack_full + t_lower_full) / max(
        t_pack_inc + t_lower_inc, 1e-9)
    return {
        "grid": [{"name": a.name, "bypass_inputs": a.bypass_inputs,
                  "alms_per_lb": a.alms_per_lb, "lb_inputs": a.lb_inputs}
                 for a in grid],
        "n_grid_points": len(grid),
        "n_structural_classes": res.n_classes,
        "t_pack_full_per_point_s": t_pack_full,
        "t_pack_incremental_s": t_pack_inc,
        "t_prefix_s": res.wall["prefix_s"],
        "t_recluster_s": res.wall["recluster_s"],
        "t_lower_full_per_point_s": t_lower_full,
        "t_lower_incremental_s": t_lower_inc,
        "speedup_pack": speedup_pack,
        "speedup_pack_to_ir": speedup_pipeline,
        "oracle_match": bool(match),
        "frontier": frontier,
        "pass_gate": bool(match) and speedup_pack >= 2.0,
    }


def run(smoke: bool = False, verbose: bool = True, seed: int = 0,
        write_json: bool = True) -> dict:
    if smoke:
        nets = _smoke_suites()
        grid = [a for a in arch_grid() if a.name in ("b0", "b2_f10")]
    else:
        nets = suites("wallace")
        grid = arch_grid()

    packs: dict = {}
    programs: dict = {}
    t0 = time.perf_counter()
    res = sweep_suite(nets, grid, seed=seed, packs=packs, programs=programs)
    t_total_cold = time.perf_counter() - t0
    t_cold = t_total_cold - res.wall["pack_s"]
    # warm wall feeds the >= 10x gate: min-of-N perf_counter runs (the
    # shared gate timer), each net of its own pack_s
    t_warm, res_warm = min_of_n(
        lambda: sweep_suite(nets, grid, seed=seed, packs=packs,
                            programs=programs),
        n=3, sample=lambda r, elapsed: elapsed - r.wall["pack_s"])

    # the Python oracle on identical packs: re-tag each structural-class
    # pack with the grid row's delays (delays never change the pack) so
    # only the timing walk is timed.  Packs are keyed by netlist content
    # digest (never list position — a warmed cache must miss, not lie,
    # under a different circuit list).
    _, flat_nets = _flatten(nets)
    digests = [n.content_digest() for n in flat_nets]
    t0 = time.perf_counter()
    oracle_cp = {}
    for g in range(len(res.circuits)):
        for k, arch in enumerate(grid):
            p = packs[(digests[g], arch.structural_key(), seed)]
            rec = analyze_oracle(dataclasses.replace(p, arch=arch))
            oracle_cp[(g, k)] = rec["critical_path_ps"]
    t_oracle = time.perf_counter() - t0

    match = all(
        oracle_cp[(g, k)] == res.records[g][k]["critical_path_ps"]
        and oracle_cp[(g, k)] == res_warm.records[g][k]["critical_path_ps"]
        for g in range(len(res.circuits)) for k in range(len(grid)))
    frontier = adp_frontier(res, baseline="b0")

    from .roofline import timing_program_terms

    terms = timing_program_terms([p.lower_ir() for p in packs.values()])

    rec = {
        "tag": "timing_sweep",
        "smoke": smoke,
        "n_circuits": len(res.circuits),
        "n_grid_points": len(grid),
        "grid": [{"name": a.name, "bypass_inputs": a.bypass_inputs,
                  "addmux_fanin": a.addmux_fanin,
                  "lut6": a.concurrent_6lut} for a in grid],
        "n_structural_classes": res.n_classes,
        "t_pack_s": res.wall["pack_s"],
        "t_oracle_s": t_oracle,
        "t_vector_cold_s": t_cold,
        "t_vector_warm_s": t_warm,
        "speedup_cold": t_oracle / max(t_cold, 1e-9),
        "speedup_warm": t_oracle / max(t_warm, 1e-9),
        "oracle_match": bool(match),
        "wall_cold": res.wall,
        "wall_warm": res_warm.wall,
        "roofline_terms_one_pass": terms,
        "frontier_vs_b0": frontier,
        "cluster_geometry": cluster_geometry(nets, seed=seed, smoke=smoke),
        "pass_gate": bool(match) and (t_oracle / max(t_warm, 1e-9)) >= 10.0,
    }
    rec["oracle_match"] = bool(match) and rec["cluster_geometry"][
        "oracle_match"]
    # the headline gate covers every section's gate (the smoke cluster
    # sweep gates on parity only — 2-point speedups are noise)
    if not smoke:
        rec["pass_gate"] = rec["pass_gate"] and rec["cluster_geometry"][
            "pass_gate"]
    if write_json and not smoke:
        os.makedirs(OUT, exist_ok=True)
        with open(os.path.join(OUT, "timing_sweep.json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        for row in frontier:
            emit(f"sweep/frontier/{row['arch']}", 0,
                 f"area={row['area_mwta']:.3f};"
                 f"cpd={row['critical_path_ps']:.3f};adp={row['adp']:.3f}")
        emit("sweep/timing", 0,
             f"oracle={t_oracle:.2f}s;vector_cold={t_cold:.2f}s;"
             f"vector_warm={t_warm:.2f}s;"
             f"speedup_warm={rec['speedup_warm']:.1f}x;"
             f"classes={res.n_classes};oracle_match={match}")
        cg = rec["cluster_geometry"]
        for row in cg["frontier"]:
            emit(f"sweep/geometry/{row['arch']}", 0,
                 f"area={row['area_mwta']:.3f};"
                 f"cpd={row['critical_path_ps']:.3f};adp={row['adp']:.3f}")
        emit("sweep/geometry_pack", 0,
             f"points={cg['n_grid_points']};"
             f"pack_full={cg['t_pack_full_per_point_s']:.2f}s;"
             f"pack_inc={cg['t_pack_incremental_s']:.2f}s;"
             f"speedup_pack={cg['speedup_pack']:.2f}x;"
             f"speedup_pack_to_ir={cg['speedup_pack_to_ir']:.2f}x;"
             f"oracle_match={cg['oracle_match']};gate={cg['pass_gate']}")
    return rec


def main():
    with Timer() as t:
        rec = run()
    best = rec["frontier_vs_b0"][0] if rec["frontier_vs_b0"] else {}
    cg = rec["cluster_geometry"]
    emit("sweep_frontier", t.us,
         f"grid={rec['n_grid_points']};classes={rec['n_structural_classes']};"
         f"best_adp={best.get('arch', '')}={best.get('adp', 0):.3f};"
         f"speedup_warm={rec['speedup_warm']:.1f}x;"
         f"geometry_pack_speedup={cg['speedup_pack']:.2f}x;"
         f"oracle_match={rec['oracle_match']}")
    return rec


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        rec = run(smoke=True)
        sys.exit(0 if rec["oracle_match"] else 1)
    main()
