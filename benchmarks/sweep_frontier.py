"""Design-space sweep — ADP frontier over bypass width x AddMux population.

The scenario the paper never measured: every circuit of the
Kratos + Koios + VTR suites re-timed across the DD architecture grid
(:func:`repro.core.alm.arch_grid` — bypass inputs x crossbar fan-in x
6-LUT concurrency; the canonical baseline/DD5/DD6 are three of the rows).
Packing happens once per *structural class*; timing runs as one batched
``lax.scan``/``vmap`` jit program per class over the class's delay-table
rows (:mod:`repro.core.sweep`).

The run is gated on bit-identity against the per-circuit Python timing
oracle and records wall times in ``experiments/perf/timing_sweep.json``:

* ``t_oracle_s``      — per-circuit ``analyze_oracle`` over every
  (circuit, grid point), the seed-style dict walk;
* ``t_vector_cold_s`` — IR lowering + program build + first batched run
  (includes jit compiles);
* ``t_vector_warm_s`` — the same sweep re-run with packs and compile
  caches hot (what an interactive frontier exploration pays per step).

Pack time is excluded from both sides (identical work, shared by
construction on the vector side).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

from repro.core.alm import arch_grid
from repro.core.sweep import adp_frontier, sweep_suite
from repro.core.timing import analyze_oracle

from .common import Timer, emit, suites

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")


def _smoke_suites():
    from repro.core.circuits import kratos_gemm, sha_like, vtr_mixed

    return {"smoke": [kratos_gemm(m=5, n=5, width=5, sparsity=0.5),
                      sha_like(rounds=1),
                      vtr_mixed(logic_nodes=150, adders=2)]}


def run(smoke: bool = False, verbose: bool = True, seed: int = 0,
        write_json: bool = True) -> dict:
    if smoke:
        nets = _smoke_suites()
        grid = [a for a in arch_grid() if a.name in ("b0", "b2_f10")]
    else:
        nets = suites("wallace")
        grid = arch_grid()

    packs: dict = {}
    programs: dict = {}
    t0 = time.perf_counter()
    res = sweep_suite(nets, grid, seed=seed, packs=packs, programs=programs)
    t_total_cold = time.perf_counter() - t0
    t_cold = t_total_cold - res.wall["pack_s"]
    t0 = time.perf_counter()
    res_warm = sweep_suite(nets, grid, seed=seed, packs=packs,
                           programs=programs)
    t_warm = time.perf_counter() - t0 - res_warm.wall["pack_s"]

    # the Python oracle on identical packs: re-tag each structural-class
    # pack with the grid row's delays (delays never change the pack) so
    # only the timing walk is timed
    t0 = time.perf_counter()
    oracle_cp = {}
    for g in range(len(res.circuits)):
        for k, arch in enumerate(grid):
            p = packs[(g, arch.structural_key(), seed)]
            rec = analyze_oracle(dataclasses.replace(p, arch=arch))
            oracle_cp[(g, k)] = rec["critical_path_ps"]
    t_oracle = time.perf_counter() - t0

    match = all(
        oracle_cp[(g, k)] == res.records[g][k]["critical_path_ps"]
        and oracle_cp[(g, k)] == res_warm.records[g][k]["critical_path_ps"]
        for g in range(len(res.circuits)) for k in range(len(grid)))
    frontier = adp_frontier(res, baseline="b0")

    from .roofline import timing_program_terms

    terms = timing_program_terms([p.lower_ir() for p in packs.values()])

    rec = {
        "tag": "timing_sweep",
        "smoke": smoke,
        "n_circuits": len(res.circuits),
        "n_grid_points": len(grid),
        "grid": [{"name": a.name, "bypass_inputs": a.bypass_inputs,
                  "addmux_fanin": a.addmux_fanin,
                  "lut6": a.concurrent_6lut} for a in grid],
        "n_structural_classes": res.n_classes,
        "t_pack_s": res.wall["pack_s"],
        "t_oracle_s": t_oracle,
        "t_vector_cold_s": t_cold,
        "t_vector_warm_s": t_warm,
        "speedup_cold": t_oracle / max(t_cold, 1e-9),
        "speedup_warm": t_oracle / max(t_warm, 1e-9),
        "oracle_match": bool(match),
        "wall_cold": res.wall,
        "wall_warm": res_warm.wall,
        "roofline_terms_one_pass": terms,
        "frontier_vs_b0": frontier,
        "pass_gate": bool(match) and (t_oracle / max(t_warm, 1e-9)) >= 10.0,
    }
    if write_json and not smoke:
        os.makedirs(OUT, exist_ok=True)
        with open(os.path.join(OUT, "timing_sweep.json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        for row in frontier:
            emit(f"sweep/frontier/{row['arch']}", 0,
                 f"area={row['area_mwta']:.3f};"
                 f"cpd={row['critical_path_ps']:.3f};adp={row['adp']:.3f}")
        emit("sweep/timing", 0,
             f"oracle={t_oracle:.2f}s;vector_cold={t_cold:.2f}s;"
             f"vector_warm={t_warm:.2f}s;"
             f"speedup_warm={rec['speedup_warm']:.1f}x;"
             f"classes={res.n_classes};oracle_match={match}")
    return rec


def main():
    with Timer() as t:
        rec = run()
    best = rec["frontier_vs_b0"][0] if rec["frontier_vs_b0"] else {}
    emit("sweep_frontier", t.us,
         f"grid={rec['n_grid_points']};classes={rec['n_structural_classes']};"
         f"best_adp={best.get('arch', '')}={best.get('adp', 0):.3f};"
         f"speedup_warm={rec['speedup_warm']:.1f}x;"
         f"oracle_match={rec['oracle_match']}")
    return rec


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        rec = run(smoke=True)
        sys.exit(0 if rec["oracle_match"] else 1)
    main()
