"""Flow-serving latency — p50/p99 and throughput under concurrency.

The flow server (:mod:`repro.core.serve_flow`) applies continuous
batching to CAD requests: N concurrent tenants submitting pack/timing
requests coalesce — within a short batching window — into deduplicated
jobs and envelope-grouped batched timing programs over bounded
multi-tenant caches.  This driver measures what that buys over the
obvious alternative (one synchronous ``flow.pack_and_analyze`` per
request) and records ``experiments/perf/serve_latency.json``:

* **closed-loop clients** at N in {1, 8, 32}: each client task submits
  its next request when its previous one resolves — per-request total
  latency (queue + service) gives p50/p99, the pass wall gives
  throughput;
* **cold vs warm** — cold passes run right after
  :func:`repro.core.plan.clear_caches` (packs, prefixes, IR templates,
  compiled timing programs all rebuilt); warm passes repeat the same
  workload best-of-N with every bounded cache hot;
* **coalesced vs serial** — the serial baseline runs the identical
  request list through ``pack_and_analyze(net, arch, seeds=(seed,))``
  one request at a time, min-of-N
  (:func:`benchmarks.common.min_of_n`) so container noise can only
  *strengthen* the baseline.

Gates (``pass_gate``):

* every served record is **bit-identical** to its single-request
  ``pack_and_analyze`` reference (the serving layer is a throughput
  construct, never a numerics one);
* coalesced warm throughput at the highest client count >= 2x the
  serial min-of-N baseline.

The server runs with ``memoize=False``: timing records recompute every
batch, so the recorded speedup is coalescing + pack/program reuse —
not a result-memo dictionary lookup.
"""
from __future__ import annotations

import asyncio
import json
import os
import time

import numpy as np

from repro.core import plan
from repro.core.flow import _METRIC_KEYS, pack_and_analyze
from repro.core.serve_flow import FlowRequest, FlowServer

from .common import Timer, emit, min_of_n

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")

#: what every benchmark request asks for — matches what the serial
#: ``pack_and_analyze`` baseline computes, so the comparison is honest
ANALYSES = ("area", "timing")


def _pool(smoke: bool):
    """The (netlist, arch) request pool — 2 circuits x 2 archs in smoke,
    6 x 2 in full mode."""
    from repro.core.circuits import kratos_gemm, sha_like, vtr_mixed

    if smoke:
        nets = [kratos_gemm(m=5, n=5, width=5, sparsity=0.5),
                sha_like(rounds=1)]
    else:
        nets = [kratos_gemm(m=5, n=5, width=5, sparsity=0.5),
                kratos_gemm(m=6, n=6, width=6, sparsity=0.5),
                sha_like(rounds=1),
                sha_like(rounds=2),
                vtr_mixed(logic_nodes=150, adders=2),
                vtr_mixed(logic_nodes=300, adders=4)]
    archs = ["baseline", "dd5"]
    return [(net, arch) for net in nets for arch in archs]


def _run_pass(pool, n_clients: int, n_requests: int, seed: int,
              server_kwargs: dict):
    """One closed-loop pass: ``n_clients`` tasks drain ``n_requests``
    round-robin over ``pool`` (client ``c`` owns requests ``c, c+N,
    ...`` — stable batch compositions, so warm program caches can
    actually hit).  Returns ``(wall_s, latencies_s, results, stats)``;
    ``results[j]`` is request ``j``'s FlowResult."""

    async def _main():
        server = FlowServer(**server_kwargs)
        latencies = [0.0] * n_requests
        results: list = [None] * n_requests

        async def client(ci: int):
            for j in range(ci, n_requests, n_clients):
                net, arch = pool[j % len(pool)]
                r = await server.submit(FlowRequest(
                    net, arch, analyses=ANALYSES, seed=seed))
                latencies[j] = r.walls["total_s"]
                results[j] = r

        t0 = time.perf_counter()
        await asyncio.gather(*(client(c) for c in range(n_clients)))
        wall = time.perf_counter() - t0
        stats = dict(server.stats)
        await server.aclose()
        return wall, latencies, results, stats

    return asyncio.run(_main())


def _phase_record(wall: float, latencies, stats, n_requests: int) -> dict:
    lat_ms = np.asarray(latencies) * 1e3
    return {
        "wall_s": wall,
        "throughput_rps": n_requests / max(wall, 1e-9),
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "n_batches": stats["n_batches"],
        "n_jobs": stats["n_jobs"],
        "n_coalesced": stats["n_coalesced"],
        "n_pack_hits": stats["n_pack_hits"],
    }


def _check_parity(results, pool, n_requests: int, seed: int,
                  refs: dict) -> bool:
    """Every served record bit-identical to its single-request
    ``pack_and_analyze`` reference (computed once per pool entry)."""
    ok = True
    for j in range(n_requests):
        net, arch = pool[j % len(pool)]
        key = (net.content_digest(), arch)
        if key not in refs:
            refs[key] = pack_and_analyze(net, arch, seeds=(seed,))
        ref = refs[key]
        rec = results[j].record
        for k in _METRIC_KEYS:
            if rec[k] != ref[k]:
                ok = False
    return ok


def run(smoke: bool = False, verbose: bool = True, seed: int = 0,
        write_json: bool = True, batch_window_s: float = 0.002,
        timing_backend: str = "jax") -> dict:
    pool = _pool(smoke)
    n_requests = 8 if smoke else 64
    client_counts = [8] if smoke else [1, 8, 32]
    warm_n = 2 if smoke else 3
    server_kwargs = {"batch_window_s": batch_window_s,
                     "timing_backend": timing_backend,
                     "memoize": False}

    # serial baseline: the identical request list, one synchronous
    # pack_and_analyze per request, min-of-N (noise can only make the
    # baseline stronger, never fail the gate spuriously)
    def serial_pass():
        for j in range(n_requests):
            net, arch = pool[j % len(pool)]
            pack_and_analyze(net, arch, seeds=(seed,))

    t_serial, _ = min_of_n(serial_pass, n=warm_n)
    serial_rps = n_requests / max(t_serial, 1e-9)

    refs: dict = {}
    parity_ok = True
    clients: dict[str, dict] = {}
    for n_cl in client_counts:
        plan.clear_caches()
        wall, lats, results, stats = _run_pass(
            pool, n_cl, n_requests, seed, server_kwargs)
        cold = _phase_record(wall, lats, stats, n_requests)
        parity_ok &= _check_parity(results, pool, n_requests, seed, refs)
        (wall, lats, results, stats) = min_of_n(
            lambda n=n_cl: _run_pass(pool, n, n_requests, seed,
                                     server_kwargs),
            n=warm_n, sample=lambda r, e: r[0])[1]
        warm = _phase_record(wall, lats, stats, n_requests)
        parity_ok &= _check_parity(results, pool, n_requests, seed, refs)
        clients[str(n_cl)] = {"cold": cold, "warm": warm}

    top = str(max(client_counts))
    speedup = clients[top]["warm"]["throughput_rps"] / serial_rps
    # the smoke gate is coalesced >= serial (two-circuit speedups are
    # noise); the full gate is the >= 2x claim
    need = 1.0 if smoke else 2.0
    rec = {
        "tag": "serve_latency",
        "smoke": smoke,
        "workload": {
            "pool": [(net.name, arch) for net, arch in pool],
            "n_requests": n_requests,
            "analyses": list(ANALYSES),
            "seed": seed,
            "client_counts": client_counts,
        },
        "server": dict(server_kwargs, max_batch=64),
        "serial": {"t_best_s": t_serial, "throughput_rps": serial_rps,
                   "n_samples": warm_n},
        "clients": clients,
        "cache_stats": {k: v for k, v in plan.cache_stats().items()
                        if k.startswith("serve") or k == "pack_prefix"},
        "parity_ok": bool(parity_ok),
        "speedup_warm_vs_serial": speedup,
        "pass_gate": bool(parity_ok) and speedup >= need,
    }
    if write_json and not smoke:
        os.makedirs(OUT, exist_ok=True)
        with open(os.path.join(OUT, "serve_latency.json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        emit("serve/serial", t_serial * 1e6 / n_requests,
             f"rps={serial_rps:.1f}")
        for n_cl, phases in clients.items():
            for phase in ("cold", "warm"):
                p = phases[phase]
                emit(f"serve/clients{n_cl}/{phase}", 0,
                     f"rps={p['throughput_rps']:.1f};"
                     f"p50={p['p50_ms']:.2f}ms;p99={p['p99_ms']:.2f}ms;"
                     f"batches={p['n_batches']};"
                     f"coalesced={p['n_coalesced']}")
        emit("serve/gate", 0,
             f"speedup_warm_vs_serial={speedup:.2f}x;"
             f"parity={parity_ok};gate={rec['pass_gate']}")
    return rec


def main():
    with Timer() as t:
        rec = run()
    emit("serve_latency", t.us,
         f"speedup={rec['speedup_warm_vs_serial']:.2f}x;"
         f"parity={rec['parity_ok']};gate={rec['pass_gate']}")
    return rec


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        rec = run(smoke=True)
        sys.exit(0 if rec["pass_gate"] else 1)
    main()
