"""Flow-serving latency — p50/p99 and throughput under concurrency.

The flow server (:mod:`repro.core.serve_flow`) applies continuous
batching to CAD requests: N concurrent tenants submitting pack/timing
requests coalesce — within a short batching window — into deduplicated
jobs and envelope-grouped batched timing programs over bounded
multi-tenant caches.  This driver measures what that buys over the
obvious alternative (one synchronous ``flow.pack_and_analyze`` per
request) and records ``experiments/perf/serve_latency.json``:

* **closed-loop clients** at N in {1, 8, 32}: each client task submits
  its next request when its previous one resolves — per-request total
  latency (queue + service) gives p50/p99, the pass wall gives
  throughput;
* **cold vs warm** — cold passes run right after
  :func:`repro.core.plan.clear_caches` (packs, prefixes, IR templates,
  compiled timing programs all rebuilt); warm passes repeat the same
  workload best-of-N with every bounded cache hot;
* **coalesced vs serial** — the serial baseline runs the identical
  request list through ``pack_and_analyze(net, arch, seeds=(seed,))``
  one request at a time, min-of-N
  (:func:`benchmarks.common.min_of_n`) so container noise can only
  *strengthen* the baseline.

Gates (``pass_gate``):

* every served record is **bit-identical** to its single-request
  ``pack_and_analyze`` reference (the serving layer is a throughput
  construct, never a numerics one);
* coalesced warm throughput at the highest client count >= 2x the
  serial min-of-N baseline.

The server runs with ``memoize=False``: timing records recompute every
batch, so the recorded speedup is coalescing + pack/program reuse —
not a result-memo dictionary lookup.

Two further scenarios ride the same driver:

* **edit stream** — one tenant serves a base circuit, then a stream of
  single-LUT edited variants of it with ``base_digest`` set, exercising
  the structural-delta path end to end (dirty-set repack + dirty-column
  IR patch + scoped per-cluster verify).  Per-edit latency, delta mode,
  and the frozen/moved/re-clustered attribution are recorded; the gate
  requires every edited record bit-identical to ``pack_and_analyze``
  and at least one edit actually served incrementally.
* **compile counts** — :func:`repro.core.timing_vec.read_compile_counts`
  is snapshotted around every pass; the recorded deltas show the
  shape-padded timing programs (``pad_timing_shapes``) re-using jit
  executables across batch compositions instead of recompiling per
  program (``jit_reused`` grows while ``jit_built`` stays flat once
  warm).
"""
from __future__ import annotations

import asyncio
import json
import os
import random
import time

import numpy as np

from repro.core import plan
from repro.core.flow import _METRIC_KEYS, pack_and_analyze
from repro.core.serve_flow import FlowRequest, FlowServer

from .common import Timer, emit, min_of_n

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")

#: what every benchmark request asks for — matches what the serial
#: ``pack_and_analyze`` baseline computes, so the comparison is honest
ANALYSES = ("area", "timing")


def _pool(smoke: bool):
    """The (netlist, arch) request pool — 2 circuits x 2 archs in smoke,
    6 x 2 in full mode."""
    from repro.core.circuits import kratos_gemm, sha_like, vtr_mixed

    if smoke:
        nets = [kratos_gemm(m=5, n=5, width=5, sparsity=0.5),
                sha_like(rounds=1)]
    else:
        nets = [kratos_gemm(m=5, n=5, width=5, sparsity=0.5),
                kratos_gemm(m=6, n=6, width=6, sparsity=0.5),
                sha_like(rounds=1),
                sha_like(rounds=2),
                vtr_mixed(logic_nodes=150, adders=2),
                vtr_mixed(logic_nodes=300, adders=4)]
    archs = ["baseline", "dd5"]
    return [(net, arch) for net in nets for arch in archs]


def _run_pass(pool, n_clients: int, n_requests: int, seed: int,
              server_kwargs: dict):
    """One closed-loop pass: ``n_clients`` tasks drain ``n_requests``
    round-robin over ``pool`` (client ``c`` owns requests ``c, c+N,
    ...`` — stable batch compositions, so warm program caches can
    actually hit).  Returns ``(wall_s, latencies_s, results, stats)``;
    ``results[j]`` is request ``j``'s FlowResult."""

    async def _main():
        server = FlowServer(**server_kwargs)
        latencies = [0.0] * n_requests
        results: list = [None] * n_requests

        async def client(ci: int):
            for j in range(ci, n_requests, n_clients):
                net, arch = pool[j % len(pool)]
                r = await server.submit(FlowRequest(
                    net, arch, analyses=ANALYSES, seed=seed))
                latencies[j] = r.walls["total_s"]
                results[j] = r

        t0 = time.perf_counter()
        await asyncio.gather(*(client(c) for c in range(n_clients)))
        wall = time.perf_counter() - t0
        stats = dict(server.stats)
        await server.aclose()
        return wall, latencies, results, stats

    return asyncio.run(_main())


def _phase_record(wall: float, latencies, stats, n_requests: int) -> dict:
    lat_ms = np.asarray(latencies) * 1e3
    return {
        "wall_s": wall,
        "throughput_rps": n_requests / max(wall, 1e-9),
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "n_batches": stats["n_batches"],
        "n_jobs": stats["n_jobs"],
        "n_coalesced": stats["n_coalesced"],
        "n_pack_hits": stats["n_pack_hits"],
    }


def _edit_stream(base_net, n_edits: int, seed: int):
    """``n_edits`` single-LUT variants of ``base_net`` — mostly fanin
    rewires (structural, exercise the dirty-set repack) with every third
    a truth-table edit (pack-irrelevant, exercises the tt-only delta)."""
    from repro.core.edits import (clone_netlist, edit_lut_tt,
                                  edit_rewire_fanin, safe_rewire_sources)

    rng = random.Random(seed + 1)
    edits = []
    while len(edits) < n_edits:
        li = rng.randrange(base_net.n_luts)
        new_net = clone_netlist(base_net)
        if len(edits) % 3 == 2:
            tt = rng.getrandbits(1 << len(base_net.lut_inputs[li]))
            if tt == base_net.lut_tt[li]:
                continue
            edit_lut_tt(new_net, li, tt)
            kind = "lut_tt"
        else:
            srcs = safe_rewire_sources(base_net, li)
            if not srcs:
                continue
            src = rng.choice(srcs)
            pin = rng.randrange(len(base_net.lut_inputs[li]))
            if base_net.lut_inputs[li][pin] == src:
                continue
            edit_rewire_fanin(new_net, li, pin, src)
            kind = "rewire_fanin"
        edits.append((new_net, kind))
    return edits


def _run_edit_stream(base_net, arch: str, n_edits: int, seed: int,
                     server_kwargs: dict):
    """Serve the base, then its edit stream with ``base_digest`` set.
    Returns ``(records, stats)``; each record carries the edit's
    latency, delta attribution, and parity vs ``pack_and_analyze``."""
    edits = _edit_stream(base_net, n_edits, seed)

    async def _main():
        server = FlowServer(**server_kwargs)
        base = await server.submit(FlowRequest(
            base_net, arch, analyses=ANALYSES, seed=seed))
        recs = []
        for new_net, kind in edits:
            r = await server.submit(FlowRequest(
                new_net, arch, analyses=ANALYSES, seed=seed,
                base_digest=base.digest))
            ref = pack_and_analyze(new_net, arch, seeds=(seed,))
            d = r.delta or {}
            recs.append({
                "kind": kind,
                "latency_ms": r.walls["total_s"] * 1e3,
                "delta_mode": d.get("mode"),
                "repack_mode": (d.get("repack") or {}).get("mode"),
                "n_frozen": d.get("n_frozen"),
                "n_moved": d.get("n_moved"),
                "n_reclustered": d.get("n_reclustered"),
                "verify_method": (d.get("verify") or {}).get("method"),
                "verify_ok": (d.get("verify") or {}).get("equivalent"),
                "parity": all(r.record[k] == ref[k] for k in _METRIC_KEYS),
            })
        stats = dict(server.stats)
        await server.aclose()
        return recs, stats

    return asyncio.run(_main())


def _check_parity(results, pool, n_requests: int, seed: int,
                  refs: dict) -> bool:
    """Every served record bit-identical to its single-request
    ``pack_and_analyze`` reference (computed once per pool entry)."""
    ok = True
    for j in range(n_requests):
        net, arch = pool[j % len(pool)]
        key = (net.content_digest(), arch)
        if key not in refs:
            refs[key] = pack_and_analyze(net, arch, seeds=(seed,))
        ref = refs[key]
        rec = results[j].record
        for k in _METRIC_KEYS:
            if rec[k] != ref[k]:
                ok = False
    return ok


def run(smoke: bool = False, verbose: bool = True, seed: int = 0,
        write_json: bool = True, batch_window_s: float = 0.002,
        timing_backend: str = "jax") -> dict:
    pool = _pool(smoke)
    n_requests = 8 if smoke else 64
    client_counts = [8] if smoke else [1, 8, 32]
    warm_n = 2 if smoke else 3
    server_kwargs = {"batch_window_s": batch_window_s,
                     "timing_backend": timing_backend,
                     "memoize": False}

    # serial baseline: the identical request list, one synchronous
    # pack_and_analyze per request, min-of-N (noise can only make the
    # baseline stronger, never fail the gate spuriously)
    def serial_pass():
        for j in range(n_requests):
            net, arch = pool[j % len(pool)]
            pack_and_analyze(net, arch, seeds=(seed,))

    t_serial, _ = min_of_n(serial_pass, n=warm_n)
    serial_rps = n_requests / max(t_serial, 1e-9)

    from repro.core.timing_vec import read_compile_counts

    def _cc_delta(before: dict) -> dict:
        after = read_compile_counts()
        return {k: after[k] - before[k] for k in after}

    refs: dict = {}
    parity_ok = True
    clients: dict[str, dict] = {}
    compile_counts: dict[str, dict] = {}
    for n_cl in client_counts:
        plan.clear_caches()
        cc0 = read_compile_counts()
        wall, lats, results, stats = _run_pass(
            pool, n_cl, n_requests, seed, server_kwargs)
        cold = _phase_record(wall, lats, stats, n_requests)
        compile_counts[f"clients{n_cl}/cold"] = _cc_delta(cc0)
        parity_ok &= _check_parity(results, pool, n_requests, seed, refs)
        cc0 = read_compile_counts()
        (wall, lats, results, stats) = min_of_n(
            lambda n=n_cl: _run_pass(pool, n, n_requests, seed,
                                     server_kwargs),
            n=warm_n, sample=lambda r, e: r[0])[1]
        warm = _phase_record(wall, lats, stats, n_requests)
        compile_counts[f"clients{n_cl}/warm"] = _cc_delta(cc0)
        parity_ok &= _check_parity(results, pool, n_requests, seed, refs)
        clients[str(n_cl)] = {"cold": cold, "warm": warm}

    # -- edit stream: the structural-delta path under serving ------------
    from repro.core.circuits import kratos_gemm

    edit_net = kratos_gemm(m=5, n=5, width=5, sparsity=0.5) if smoke \
        else kratos_gemm(m=6, n=6, width=6, sparsity=0.5)
    n_edits = 3 if smoke else 6
    plan.clear_caches()
    cc0 = read_compile_counts()
    edit_recs, edit_stats = _run_edit_stream(
        edit_net, "dd5", n_edits, seed, server_kwargs)
    compile_counts["edit_stream"] = _cc_delta(cc0)
    edits_parity = all(r["parity"] for r in edit_recs)
    n_incremental = sum(r["repack_mode"] == "incremental"
                        for r in edit_recs)
    edits_verified = all(r["verify_ok"] is not False for r in edit_recs)
    edits_ok = edits_parity and edits_verified and n_incremental >= 1

    top = str(max(client_counts))
    speedup = clients[top]["warm"]["throughput_rps"] / serial_rps
    # the smoke gate is coalesced >= serial (two-circuit speedups are
    # noise); the full gate is the >= 2x claim
    need = 1.0 if smoke else 2.0
    rec = {
        "tag": "serve_latency",
        "smoke": smoke,
        "workload": {
            "pool": [(net.name, arch) for net, arch in pool],
            "n_requests": n_requests,
            "analyses": list(ANALYSES),
            "seed": seed,
            "client_counts": client_counts,
        },
        "server": dict(server_kwargs, max_batch=64),
        "serial": {"t_best_s": t_serial, "throughput_rps": serial_rps,
                   "n_samples": warm_n},
        "clients": clients,
        "edit_stream": {
            "circuit": edit_net.name,
            "arch": "dd5",
            "n_edits": n_edits,
            "edits": edit_recs,
            "n_incremental": n_incremental,
            "n_delta_incremental": edit_stats["n_delta_incremental"],
            "n_delta_fallback": edit_stats["n_delta_fallback"],
            "n_verify_scoped": edit_stats["n_verify_scoped"],
            "n_verify_full": edit_stats["n_verify_full"],
            "parity_ok": bool(edits_parity),
            "verified_ok": bool(edits_verified),
        },
        "compile_counts": compile_counts,
        "cache_stats": {k: v for k, v in plan.cache_stats().items()
                        if k.startswith("serve") or k == "pack_prefix"},
        "parity_ok": bool(parity_ok),
        "speedup_warm_vs_serial": speedup,
        "pass_gate": (bool(parity_ok) and speedup >= need
                      and bool(edits_ok)),
    }
    if write_json and not smoke:
        os.makedirs(OUT, exist_ok=True)
        with open(os.path.join(OUT, "serve_latency.json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        emit("serve/serial", t_serial * 1e6 / n_requests,
             f"rps={serial_rps:.1f}")
        for n_cl, phases in clients.items():
            for phase in ("cold", "warm"):
                p = phases[phase]
                emit(f"serve/clients{n_cl}/{phase}", 0,
                     f"rps={p['throughput_rps']:.1f};"
                     f"p50={p['p50_ms']:.2f}ms;p99={p['p99_ms']:.2f}ms;"
                     f"batches={p['n_batches']};"
                     f"coalesced={p['n_coalesced']}")
        for i, r in enumerate(edit_recs):
            emit(f"serve/edit{i}", r["latency_ms"] * 1e3,
                 f"kind={r['kind']};mode={r['delta_mode']};"
                 f"repack={r['repack_mode']};frozen={r['n_frozen']};"
                 f"moved={r['n_moved']};recl={r['n_reclustered']};"
                 f"verify={r['verify_method']};parity={r['parity']}")
        cw = compile_counts.get(f"clients{top}/warm", {})
        emit("serve/compile_counts", 0,
             f"warm_built={cw.get('jit_built')};"
             f"warm_reused={cw.get('jit_reused')};"
             f"edits_ok={edits_ok}")
        emit("serve/gate", 0,
             f"speedup_warm_vs_serial={speedup:.2f}x;"
             f"parity={parity_ok};gate={rec['pass_gate']}")
    return rec


def main():
    with Timer() as t:
        rec = run()
    emit("serve_latency", t.us,
         f"speedup={rec['speedup_warm_vs_serial']:.2f}x;"
         f"parity={rec['parity_ok']};gate={rec['pass_gate']}")
    return rec


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        rec = run(smoke=True)
        sys.exit(0 if rec["pass_gate"] else 1)
    main()
