"""Fig. 6 — DD5 vs baseline architecture across the three suites.

Paper: ALM area −21.6 % (Kratos), −9.3 % (Koios), −8.2 % (VTR); critical
path flat on average; ADP −9.7 % over all circuits.

Packing, analysis and ratio computation run through the unified
``repro.core.flow`` pipeline; this driver only aggregates and emits.
"""
from __future__ import annotations

from repro.core import flow

from .common import Timer, emit, geomean, suites

RATIO_KEYS = {"area": "area_mwta", "cpd": "critical_path_ps", "adp": "adp"}


def run(verbose: bool = True):
    out: dict[str, dict] = {}
    all_ratios: dict[str, list[float]] = {k: [] for k in RATIO_KEYS}

    def progress(suite_name, net, per_arch):
        if verbose:
            r = flow.ratios_vs_baseline(per_arch)["dd5"]
            emit(f"fig6/{suite_name}/{net.name}", 0,
                 f"area={r['area_mwta']:.3f};cpd={r['critical_path_ps']:.3f};"
                 f"adp={r['adp']:.3f};"
                 f"conc={per_arch['dd5']['concurrent_luts']:.0f}")

    results = flow.run_suites(suites("wallace"), ("baseline", "dd5"),
                              per_circuit=progress)
    for suite_name, rows in results.items():
        per_key: dict[str, list[float]] = {k: [] for k in RATIO_KEYS}
        for row in rows:
            r = flow.ratios_vs_baseline(row["per_arch"])["dd5"]
            for k, mk in RATIO_KEYS.items():
                per_key[k].append(r[mk])
                all_ratios[k].append(r[mk])
        out[suite_name] = {k: geomean(v) for k, v in per_key.items()}
    out["overall"] = {k: geomean(v) for k, v in all_ratios.items()}
    return out


def main():
    from repro.core.timing import read_timing_wall

    w0 = read_timing_wall()
    with Timer() as t:
        res = run()
    w1 = read_timing_wall()
    d = ";".join(f"{k}_area={v['area']:.3f}" for k, v in res.items())
    emit("fig6_dd5", t.us, d + f";overall_adp={res['overall']['adp']:.3f};"
         f"timing_s={w1['s'] - w0['s']:.3f}")
    return res


if __name__ == "__main__":
    main()
