"""Fig. 6 — DD5 vs baseline architecture across the three suites.

Paper: ALM area −21.6 % (Kratos), −9.3 % (Koios), −8.2 % (VTR); critical
path flat on average; ADP −9.7 % over all circuits.
"""
from __future__ import annotations

from .common import Timer, emit, geomean, pack_metrics, suites


def run(verbose: bool = True):
    out: dict[str, dict] = {}
    all_adp_ratios = []
    all_area_ratios = []
    all_cpd_ratios = []
    for suite_name, nets in suites("wallace").items():
        area_r, cpd_r, adp_r, conc = [], [], [], []
        for net in nets:
            b = pack_metrics(net, "baseline")
            d = pack_metrics(net, "dd5")
            area_r.append(d["area_mwta"] / b["area_mwta"])
            cpd_r.append(d["critical_path_ps"] / b["critical_path_ps"])
            adp_r.append(d["adp"] / b["adp"])
            conc.append(d["concurrent_luts"])
            if verbose:
                emit(f"fig6/{suite_name}/{net.name}", 0,
                     f"area={area_r[-1]:.3f};cpd={cpd_r[-1]:.3f};"
                     f"adp={adp_r[-1]:.3f};conc={conc[-1]:.0f}")
        out[suite_name] = {
            "area": geomean(area_r),
            "cpd": geomean(cpd_r),
            "adp": geomean(adp_r),
        }
        all_adp_ratios.extend(adp_r)
        all_area_ratios.extend(area_r)
        all_cpd_ratios.extend(cpd_r)
    out["overall"] = {
        "area": geomean(all_area_ratios),
        "cpd": geomean(all_cpd_ratios),
        "adp": geomean(all_adp_ratios),
    }
    return out


def main():
    with Timer() as t:
        res = run()
    d = ";".join(f"{k}_area={v['area']:.3f}" for k, v in res.items())
    emit("fig6_dd5", t.us, d + f";overall_adp={res['overall']['adp']:.3f}")
    return res


if __name__ == "__main__":
    main()
