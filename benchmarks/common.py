"""Shared helpers for the paper-figure benchmarks.

Metric computation lives in ``repro.core.flow`` (the unified CAD flow
pipeline); this module keeps only the benchmark-side conveniences: suite
construction, geomean, CSV emission and timing.
"""
from __future__ import annotations

import math
import time

from repro.core.circuits import kratos_suite, koios_suite, vtr_suite
from repro.core.flow import DEFAULT_SEEDS as SEEDS
from repro.core.flow import pack_and_analyze


def geomean(xs):
    xs = [max(x, 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def suites(algo: str = "wallace"):
    return {
        "kratos": kratos_suite(algo=algo),
        "koios": koios_suite(algo=algo),
        "vtr": vtr_suite(),
    }


def pack_metrics(net, arch_name: str, seeds=SEEDS) -> dict:
    """Seed-averaged analyze() metrics (thin alias over the flow pipeline)."""
    return pack_and_analyze(net, arch_name, seeds=seeds)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.0f},{derived}")
