"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import math
import time

from repro.core.alm import ARCHS
from repro.core.circuits import kratos_suite, koios_suite, vtr_suite
from repro.core.packing import pack
from repro.core.timing import analyze

SEEDS = (0, 1, 2)  # the paper averages three placement seeds


def geomean(xs):
    xs = [max(x, 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def suites(algo: str = "wallace"):
    return {
        "kratos": kratos_suite(algo=algo),
        "koios": koios_suite(algo=algo),
        "vtr": vtr_suite(),
    }


def pack_metrics(net, arch_name: str, seeds=SEEDS) -> dict:
    """Average analyze() metrics over placement seeds."""
    arch = ARCHS[arch_name]
    acc: dict[str, float] = {}
    for s in seeds:
        r = analyze(pack(net, arch, seed=s))
        for k in ("alms", "area_mwta", "critical_path_ps", "adp",
                  "concurrent_luts", "lbs"):
            acc[k] = acc.get(k, 0.0) + r[k] / len(seeds)
    acc["adders"] = net.n_adders
    acc["luts"] = net.n_luts
    return acc


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.0f},{derived}")
