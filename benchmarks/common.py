"""Shared helpers for the paper-figure benchmarks.

Metric computation lives in ``repro.core.flow`` (the unified CAD flow
pipeline); this module keeps only the benchmark-side conveniences: suite
construction, geomean, CSV emission and timing.
"""
from __future__ import annotations

import math
import time

from repro.core.circuits import kratos_suite, koios_suite, vtr_suite
from repro.core.flow import DEFAULT_SEEDS as SEEDS
from repro.core.flow import pack_and_analyze


def geomean(xs):
    xs = [max(x, 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def suites(algo: str = "wallace"):
    return {
        "kratos": kratos_suite(algo=algo),
        "koios": koios_suite(algo=algo),
        "vtr": vtr_suite(),
    }


def pack_metrics(net, arch_name: str, seeds=SEEDS) -> dict:
    """Seed-averaged analyze() metrics (thin alias over the flow pipeline)."""
    return pack_and_analyze(net, arch_name, seeds=seeds)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


def min_of_n(fn, n: int = 3, warmup: int = 0, sample=None):
    """Best-of-``n`` wall clock of ``fn()`` — the shared timer for every
    >= 2x perf gate.

    Container timing noise is one-sided (preemption and cache evictions
    only ever *inflate* a sample), so a gate comparing single samples
    flakes; the minimum over N ``perf_counter`` runs is the faithful
    estimate of the code's cost.  ``warmup`` extra calls run untimed
    first (jit compiles).  ``sample(result, elapsed)`` overrides the
    measured quantity — e.g. to subtract an inner phase a run reports
    about itself — otherwise the wall clock of the call is used.
    Returns ``(best_seconds, best_result)`` — the result of the run that
    produced the best sample, so anything the caller records about the
    run (per-phase walls, stats) decomposes the number it sits next to.
    """
    best = float("inf")
    best_result = None
    for _ in range(warmup):
        fn()
    for _ in range(max(n, 1)):
        t0 = time.perf_counter()
        result = fn()
        elapsed = time.perf_counter() - t0
        s = sample(result, elapsed) if sample is not None else elapsed
        if s < best:
            best = s
            best_result = result
    return best, best_result


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.0f},{derived}")
