"""Annealing placement-refinement gates — what refinement actually buys.

The PR-6 analytic placer is legalization-limited: its stable-sort snap
scrambles the relaxation's local structure, leaving a large wirelength
gap that the batched simulated annealer (:mod:`repro.core.anneal`)
exists to close.  This section proves, per suite circuit:

* **legality** — refined placements keep one LB per slot on the same
  grid as the analytic seed;
* **never-worse** — annealed wirelength <= the analytic seed's on
  EVERY circuit (the best-snapshot guarantee, not luck), with a
  **geomean HPWL improvement >= 5%** over the suite;
* **placed-oracle parity** — vectorized placed timing of the annealed
  placement is bit-identical to
  :func:`repro.core.timing.analyze_placed_oracle` at a nonzero
  wire-delay profile (the wire-tier gather is actually exercised);
* **determinism** — a re-anneal from a cleared cache reproduces the
  placement bit for bit.

``--smoke`` (also ``scripts/check.sh --smoke``) runs a bounded-iteration
anneal on 2 circuits; the full run covers all 17 suite members, three
annealing seeds (ensemble variance), and the timing-driven mode's CPD
deltas, and feeds the refinement block of
``experiments/perf/placed_sweep.json`` (via ``benchmarks/place_sweep``).
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core.alm import make_arch
from repro.core.anneal import ANNEAL_WALL
from repro.core.circuit_ir import apply_placement
from repro.core.packing import pack
from repro.core.place import place_ir
from repro.core.timing import analyze_placed_oracle
from repro.core.timing_vec import analyze_ir

from .common import Timer, emit, suites

#: the routed wire profile the parity/CPD legs time under (same tiers as
#: benchmarks/place_sweep.WIRE_PROFILES' nonzero row)
WIRED = make_arch("dd5_wired", bypass_inputs=2, addmux_fanin=10,
                  t_wire_hop1=25.0, t_wire_hop2=40.0, t_wire_long=120.0)

#: suite-wide geomean HPWL improvement the annealer must deliver
GEOMEAN_GATE = 0.05


def _legal(pl) -> bool:
    n = pl.n_lbs
    if not ((pl.lb_x >= 0).all() and (pl.lb_x < pl.grid_w).all()
            and (pl.lb_y >= 0).all() and (pl.lb_y < pl.grid_h).all()):
        return False
    return len(set(zip(pl.lb_x.tolist(), pl.lb_y.tolist()))) == n


def _smoke_nets():
    from repro.core.circuits import kratos_gemm, vtr_mixed

    return [kratos_gemm(m=5, n=5, width=5, sparsity=0.5),
            vtr_mixed(logic_nodes=150, adders=2)]


def wirelength_report(nets, seed: int = 0, steps: int | None = None,
                      seeds=(0, 1, 2), timing_mode: bool = True) -> dict:
    """Per-circuit analytic-vs-annealed comparison under the wired arch.

    For every netlist: the analytic seed and annealed wirelengths (and
    their ratio), the placed CPDs of both placements at the routed wire
    profile, the annealed-wirelength spread over ``seeds`` (the seed-
    ensemble variance a multi-start caller would exploit), and — when
    ``timing_mode`` — the CPD of the criticality-weighted anneal.  The
    dict carries the two acceptance gates: ``all_never_worse`` and the
    suite ``geomean_improvement`` vs :data:`GEOMEAN_GATE`.
    """
    rows = []
    log_ratios = []
    for net in nets:
        packed = pack(net, WIRED, seed=seed)
        ir = packed.lower_ir()
        seed_pl = place_ir(ir, WIRED, seed)
        t0 = time.perf_counter()
        ann = place_ir(ir, WIRED, seed, refine="anneal",
                       anneal_steps=steps)
        t_ann = time.perf_counter() - t0
        wl0, wl1 = seed_pl.wirelength(ir), ann.wirelength(ir)
        cpd0 = analyze_ir(apply_placement(ir, seed_pl),
                          WIRED)["critical_path_ps"]
        cpd1 = analyze_ir(apply_placement(ir, ann),
                          WIRED)["critical_path_ps"]
        wls = [wl1] + [
            place_ir(ir, WIRED, s, refine="anneal",
                     anneal_steps=steps).wirelength(ir)
            for s in seeds if s != seed]
        row = {
            "net": net.name,
            "n_lbs": ir.n_lbs,
            "wirelength_analytic": int(wl0),
            "wirelength_annealed": int(wl1),
            "wl_ratio": wl1 / max(wl0, 1),
            "cpd_analytic_ps": cpd0,
            "cpd_annealed_ps": cpd1,
            "cpd_delta_ps": cpd1 - cpd0,
            "legal": _legal(ann),
            "never_worse": wl1 <= wl0,
            "seed_wl_min": int(min(wls)),
            "seed_wl_max": int(max(wls)),
            "seed_wl_spread": (max(wls) - min(wls)) / max(min(wls), 1),
            "t_anneal_s": t_ann,
        }
        if timing_mode:
            tpl = place_ir(ir, WIRED, seed, refine="anneal_timing",
                           anneal_steps=steps)
            row["cpd_timing_driven_ps"] = analyze_ir(
                apply_placement(ir, tpl), WIRED)["critical_path_ps"]
            row["wirelength_timing_driven"] = int(tpl.wirelength(ir))
        rows.append(row)
        log_ratios.append(math.log(row["wl_ratio"]))
    geo = math.exp(sum(log_ratios) / len(log_ratios)) if log_ratios else 1.0
    return {
        "circuits": rows,
        "geomean_wl_ratio": geo,
        "geomean_improvement": 1.0 - geo,
        "geomean_gate": GEOMEAN_GATE,
        "all_legal": all(r["legal"] for r in rows),
        "all_never_worse": all(r["never_worse"] for r in rows),
        "pass_geomean": (1.0 - geo) >= GEOMEAN_GATE,
    }


def run(smoke: bool = False, verbose: bool = True, seed: int = 0) -> dict:
    if smoke:
        nets = _smoke_nets()
        steps = 24          # bounded-iteration smoke anneal
        seeds = (0,)
    else:
        nets = [n for s in suites("wallace").values() for n in s]
        steps = None        # size-scaled defaults
        seeds = (0, 1, 2)

    a0 = ANNEAL_WALL["s"]
    report = wirelength_report(nets, seed=seed, steps=steps, seeds=seeds,
                               timing_mode=not smoke)
    report["anneal_wall_s"] = ANNEAL_WALL["s"] - a0

    # placed-oracle parity on the ANNEALED placements, nonzero wire tiers
    parity = True
    for net in nets:
        packed = pack(net, WIRED, seed=seed)
        ir = packed.lower_ir()
        ann = place_ir(ir, WIRED, seed, refine="anneal", anneal_steps=steps)
        want = analyze_placed_oracle(packed, ann)
        if analyze_ir(apply_placement(ir, ann), WIRED) != want:
            parity = False

    # determinism: a fresh re-anneal reproduces the placement bit for bit
    net = nets[0]
    ir = pack(net, WIRED, seed=seed).lower_ir()
    a = place_ir(ir, WIRED, seed, refine="anneal", anneal_steps=steps)
    b = place_ir(ir, WIRED, seed, refine="anneal", anneal_steps=steps)
    deterministic = bool(np.array_equal(a.lb_x, b.lb_x)
                         and np.array_equal(a.lb_y, b.lb_y))

    # the smoke tier gates legality/never-worse/parity only; the geomean
    # improvement gate needs the full suite to be meaningful
    gates = [report["all_legal"], report["all_never_worse"], parity,
             deterministic] + ([] if smoke else [report["pass_geomean"]])
    rec = {
        "tag": "anneal_refine",
        "smoke": smoke,
        "n_circuits": len(nets),
        "steps": steps,
        "report": report,
        "oracle_match": parity,
        "deterministic": deterministic,
        "pass_gate": all(gates),
    }
    if verbose:
        for row in report["circuits"]:
            emit(f"anneal/{row['net']}", row["t_anneal_s"] * 1e6,
                 f"lbs={row['n_lbs']};wl={row['wirelength_analytic']}->"
                 f"{row['wirelength_annealed']};"
                 f"ratio={row['wl_ratio']:.3f};"
                 f"cpd_delta={row['cpd_delta_ps']:.0f}ps;"
                 f"spread={row['seed_wl_spread']:.3f}")
        emit("anneal/geomean", 0,
             f"improvement={report['geomean_improvement']:.3f};"
             f"gate>={GEOMEAN_GATE};"
             f"never_worse={report['all_never_worse']};"
             f"legal={report['all_legal']};oracle_match={parity};"
             f"deterministic={deterministic};pass={rec['pass_gate']}")
    return rec


def main():
    with Timer() as t:
        rec = run()
    emit("anneal_refine", t.us,
         f"circuits={rec['n_circuits']};"
         f"improvement={rec['report']['geomean_improvement']:.3f};"
         f"wall={rec['report']['anneal_wall_s']:.2f}s;"
         f"gate={rec['pass_gate']}")
    if not rec["pass_gate"]:
        raise RuntimeError("anneal_refine gates failed")
    return rec


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        sys.exit(0 if run(smoke=True)["pass_gate"] else 1)
    main()
