"""Incremental repack — dirty-set re-cluster + IR patch vs the full path.

The serving layer's structural-delta path (``repack.pack_prefix_delta``
+ ``repack.repack_delta`` + ``circuit_ir.apply_pack_delta``) claims a
single-LUT structural edit on the largest suite circuit re-clusters a
*dirty set* and patches the cached IR instead of re-running the whole
prefix + greedy re-cluster + lowering pipeline.  This driver measures
that claim on ``conv2d-fu`` (the largest Kratos suite member) under DD5
and writes ``experiments/perf/repack_delta.json``.

Workload: one single-LUT fanin rewire, probed so it stays on the
incremental path (edits that flip absorption/pairing decisions or
overrun the divergence bound legitimately fall back — the contract in
``benchmarks/README.md`` — and are not what this gate measures).  Both
paths are timed warm with :func:`benchmarks.common.min_of_n`; the
edited netlist's IR cache rows are evicted per iteration so *both*
paths pay their real lowering cost every sample.

Gates (``pass_gate``):

* **byte-identity** — the delta-path pack equals a fresh ``pack()`` of
  the edited netlist field for field (sites, LB membership, per-ALM
  occupancy), and the delta-patched IR times identically;
* **per-cluster proof** — ``equiv.verify_clusters`` proves every
  touched LB (edited LUT's LB + every diverged LB) equivalent;
* **>= 2x** — delta wall (diff + prefix patch + advised re-cluster +
  IR patch), min-of-N, at least 2x faster than the full re-cluster
  path (prefix + re-cluster + lowering) on the same edit;
* **serve parity** — the edit served through ``FlowServer`` with
  ``base_digest`` produces a record bit-identical to
  ``flow.pack_and_analyze`` on the edited netlist, via the delta path.
"""
from __future__ import annotations

import json
import os
import random

from repro.core import plan
from repro.core.alm import ARCHS
from repro.core.circuit_ir import (_IR_CACHE, _PACK_DELTA_CACHE,
                                   apply_pack_delta)
from repro.core.circuits import kratos_conv2d
from repro.core.edits import (clone_netlist, edit_rewire_fanin,
                              safe_rewire_sources)
from repro.core.equiv import verify_clusters
from repro.core.packing import pack
from repro.core.repack import (netlist_structural_diff, pack_prefix,
                               pack_prefix_delta, repack, repack_delta,
                               repack_with_log)

from .common import Timer, emit, min_of_n

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")

ARCH = "dd5"


def _same_pack(a, b) -> bool:
    """Field-for-field pack identity — the delta contract's byte-identity
    claim over everything downstream lowering reads."""
    if (a.n_alms != b.n_alms or a.n_lbs != b.n_lbs
            or a.concurrent_luts != b.concurrent_luts
            or a.lut_site != b.lut_site or a.chain_site != b.chain_site
            or list(a.alm_lb) != list(b.alm_lb)):
        return False
    for x, y in zip(a.alms, b.alms):
        if (x.is_arith, x.lut6) != (y.is_arith, y.lut6):
            return False
        for hx, hy in zip(x.halves, y.halves):
            if (hx.fa, hx.fa_feed, hx.absorbed, hx.hosted_lut) != (
                    hy.fa, hy.fa_feed, hy.absorbed, hy.hosted_lut):
                return False
    return all(list(x.alms) == list(y.alms) for x, y in zip(a.lbs, b.lbs))


def _pick_edit(net, prefix, log, arch, seed: int, max_probes: int = 50):
    """A single-LUT fanin rewire that stays on the incremental path.
    Probes deterministic random edits; returns ``(new_net, li, n_probed)``
    or raises if the circuit admits none within the probe budget."""
    rng = random.Random(seed)
    cands = [li for li in range(net.n_luts) if li not in prefix.lut_site]
    for probe in range(max_probes):
        li = rng.choice(cands)
        srcs = safe_rewire_sources(net, li)
        if not srcs:
            continue
        src = rng.choice(srcs)
        pin = rng.randrange(len(net.lut_inputs[li]))
        if net.lut_inputs[li][pin] == src:
            continue
        new_net = clone_netlist(net)
        edit_rewire_fanin(new_net, li, pin, src)
        new_prefix, pinfo = pack_prefix_delta(prefix, new_net, base_log=log)
        if new_prefix is None or pinfo["mode"] != "incremental":
            continue
        _, rinfo = repack_delta(new_prefix, log, arch,
                                dirty_atoms=pinfo["dirty_atoms"])
        if rinfo["mode"] == "incremental":
            return new_net, li, probe + 1
    raise RuntimeError(
        f"no incremental-path edit found in {max_probes} probes")


def run(smoke: bool = False, verbose: bool = True, seed: int = 0,
        write_json: bool = True) -> dict:
    plan.clear_caches()
    arch = ARCHS[ARCH]
    net = kratos_conv2d()                 # conv2d-fu, largest suite member
    n = 2 if smoke else 3

    with Timer() as t_base:
        prefix = pack_prefix(net, seed=seed)
        base_pack, log = repack_with_log(prefix, arch)
        base_pack.lower_ir()              # warm the base functional IR
    new_net, li, n_probed = _pick_edit(net, prefix, log, arch, seed)
    new_digest = new_net.content_digest()
    delta_key = (net.content_digest(), new_digest, arch.structural_key())

    def full_path():
        # what serving pays without the delta path: full prefix + full
        # greedy re-cluster + lowering of the edited netlist
        _IR_CACHE.pop(new_digest)
        p = pack_prefix(new_net, seed=seed)
        pk = repack(p, arch)
        return pk, pk.lower_ir()

    def delta_path():
        # the dirty-set path; both lowering caches evicted so the IR
        # patch recomputes every sample (a repeat edit would hit them)
        _IR_CACHE.pop(new_digest)
        _PACK_DELTA_CACHE.pop(delta_key)
        diff = netlist_structural_diff(net, new_net)
        np_, pinfo = pack_prefix_delta(prefix, new_net, base_log=log,
                                       diff=diff)
        pk, rinfo = repack_delta(np_, log, arch,
                                 dirty_atoms=pinfo["dirty_atoms"])
        ir = apply_pack_delta(pk, net, edited_luts=diff["changed_inputs"],
                              tt_luts=diff["changed_tt"])
        return pk, ir, rinfo

    t_full, (full_pack, full_ir) = min_of_n(full_path, n=n)
    t_delta, (dpack, dir_, rinfo) = min_of_n(delta_path, n=n)
    speedup = t_full / max(t_delta, 1e-9)

    # -- byte-identity vs a completely fresh pack of the edited netlist --
    fresh = pack(new_net, arch, seed=seed)
    same = _same_pack(dpack, fresh) and _same_pack(full_pack, fresh)
    from repro.core.timing import analyze_oracle
    from repro.core.timing_vec import analyze_ir
    cp_delta = analyze_ir(dir_, arch)["critical_path_ps"]
    cp_full = analyze_ir(full_ir, arch)["critical_path_ps"]
    cp_ref = analyze_oracle(fresh)["critical_path_ps"]
    timing_same = cp_delta == cp_full == cp_ref

    # -- per-cluster proof over every touched LB ------------------------
    touched = set(rinfo["div_lbs"])
    site = dpack.lut_site.get(li)
    if site is not None:
        touched.add(int(dpack.alm_lb[site]))
    vrep = verify_clusters(dpack, sorted(touched))

    # -- serve parity: the edit through the FlowServer delta path -------
    from repro.core.flow import _METRIC_KEYS, pack_and_analyze
    from repro.core.serve_flow import FlowRequest, serve_requests

    plan.clear_caches()
    res = serve_requests([FlowRequest(net, ARCH, seed=seed)])
    res_d = serve_requests(
        [FlowRequest(new_net, ARCH, seed=seed,
                     base_digest=res[0].digest)])
    ref = pack_and_analyze(new_net, ARCH, seeds=(seed,))
    serve_delta = res_d[0].delta or {}
    serve_parity = all(res_d[0].record[k] == ref[k] for k in _METRIC_KEYS)
    served_incremental = (
        serve_delta.get("repack", {}).get("mode") == "incremental")

    rec = {
        "tag": "repack_delta",
        "smoke": smoke,
        "circuit": net.name,
        "arch": ARCH,
        "seed": seed,
        "edit": {"lut": li, "kind": "rewire_fanin", "n_probed": n_probed},
        "base_build_s": t_base.us / 1e6,
        "t_full_s": t_full,
        "t_delta_s": t_delta,
        "speedup": speedup,
        "n_samples": n,
        "repack": {k: rinfo[k] for k in
                   ("mode", "n_skipped", "n_scanned", "n_div_lbs",
                    "n_frozen_lbs")},
        "verify": {"method": vrep["method"], "lbs": vrep["lbs"],
                   "scoped_luts": vrep["scoped_luts"],
                   "equivalent": vrep["equivalent"]},
        "serve": {"delta_mode": serve_delta.get("mode"),
                  "repack_mode": serve_delta.get("repack", {}).get("mode"),
                  "n_frozen": serve_delta.get("n_frozen"),
                  "n_moved": serve_delta.get("n_moved"),
                  "n_reclustered": serve_delta.get("n_reclustered"),
                  "parity": serve_parity},
        "pack_identical": bool(same),
        "timing_identical": bool(timing_same),
        "pass_gate": bool(same and timing_same and vrep["equivalent"]
                          and serve_parity and served_incremental
                          and speedup >= 2.0),
    }
    if write_json and not smoke:
        os.makedirs(OUT, exist_ok=True)
        with open(os.path.join(OUT, "repack_delta.json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        emit("repack_delta/full", t_full * 1e6, f"n={n}")
        emit("repack_delta/delta", t_delta * 1e6,
             f"skip={rinfo['n_skipped']};scan={rinfo['n_scanned']};"
             f"div={rinfo['n_div_lbs']}")
        emit("repack_delta/gate", 0,
             f"speedup={speedup:.2f}x;identical={same};"
             f"verified_lbs={len(vrep['lbs'])};"
             f"equivalent={vrep['equivalent']};serve_parity={serve_parity};"
             f"gate={rec['pass_gate']}")
    return rec


def main():
    with Timer() as t:
        rec = run()
    emit("repack_delta", t.us,
         f"speedup={rec['speedup']:.2f}x;gate={rec['pass_gate']}")
    return rec


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        rec = run(smoke=True)
        sys.exit(0 if rec["pass_gate"] else 1)
    main()
