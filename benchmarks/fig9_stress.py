"""Fig. 9 — packing stress test, plus the fused-evaluator perf workload.

500 adders + 0..500 unrelated 5-LUTs.  Paper: DD5 area stays flat until the
ALMs saturate; concurrently packed 5-LUTs saturate at ~375 (75 %).

The *layered* saturated stress circuit (500 adders + 500 LUTs feeding two
3x-smaller layers — a wide-then-narrow level profile) doubles as the
standard workload for the netlist-evaluation engine: ``run_eval_benchmark``
times the width-bucketed fused evaluator against the seed per-level
dispatcher on it, proves pack/re-elaborate equivalence through the
``core.flow`` pipeline, and reports the engine's roofline terms — including
the per-bucket padding waste next to the old single-envelope waste.
"""
from __future__ import annotations

from repro.core import flow
from repro.core.alm import BASELINE, DD5
from repro.core.stress import run_packing_stress, packing_stress_circuit

from .common import Timer, emit, min_of_n

LUT_COUNTS = [0, 50, 100, 150, 200, 250, 300, 350, 400, 450, 500]


def run(verbose: bool = True):
    out = {}
    for arch in (BASELINE, DD5):
        res = run_packing_stress(arch, n_adders=500, lut_counts=LUT_COUNTS)
        out[arch.name] = res
        if verbose:
            for r in res:
                emit(f"fig9/{arch.name}/luts{r['n_luts']}", 0,
                     f"alms={r['alms']};area={r['area_mwta']:.0f};"
                     f"conc={r['concurrent']}")
    return out


def eval_workload(n_adders: int = 500, n_luts: int = 500, seed: int = 0,
                  depth: int = 3):
    """The canonical evaluation workload: the saturated Fig. 9 circuit,
    stacked ``depth`` layers deep (each 3x smaller) so the level-width
    profile exercises the evaluator's width buckets."""
    return packing_stress_circuit(n_adders=n_adders, n_luts=n_luts,
                                  seed=seed, depth=depth)


def run_eval_benchmark(n_lane_words: int = 8, use_pallas: bool = True,
                       reps: int = 3, check_equiv: bool = True,
                       verbose: bool = True) -> dict:
    """Time fused vs per-level evaluation of the stress workload.

    Returns a record with best-of-``reps`` wall times (post-warmup, so the
    fused number excludes its one-time compile), the speedup, the fused
    engine's analytic roofline terms (bucketed and single-envelope padding
    waste side by side), and — when ``check_equiv`` — the pack/
    re-elaborate equivalence verdicts for baseline and DD5.
    """
    import jax

    from repro.core.equiv import check_pack_equivalence
    from repro.core.eval_jax import eval_netlist_jax_levels, plan_netlist
    from .roofline import netlist_eval_terms

    net = eval_workload()
    lanes = flow.random_lanes(net, n_lane_words, seed=0)
    plan = plan_netlist(net)

    def bench(fn):
        # min-of-N perf_counter (shared gate timer): one untimed warmup
        # drains the jit compile, then the best of ``reps`` runs
        best, _ = min_of_n(lambda: jax.block_until_ready(fn()),
                           n=reps, warmup=1)
        return best

    t_levels = bench(lambda: eval_netlist_jax_levels(
        net, lanes, n_lane_words, use_pallas=use_pallas))
    t_fused = bench(lambda: flow.evaluate_netlist(
        net, lanes, n_lane_words, use_pallas=use_pallas, plan=plan))
    rec = {
        "workload": f"fig9_stress({net.name}: 500+ adders, 500+ luts, "
                    f"layered)",
        "n_lane_words": n_lane_words,
        "n_vectors": n_lane_words * 32,
        "use_pallas": use_pallas,
        "t_levels_s": t_levels,
        "t_fused_s": t_fused,
        "speedup": t_levels / t_fused,
        "roofline": netlist_eval_terms(net, n_lane_words, plan=plan),
    }
    if check_equiv:
        rec["equiv"] = {
            arch.name: check_pack_equivalence(net, arch, n_vectors=64)
            ["equivalent"] for arch in (BASELINE, DD5)
        }
    if verbose:
        emit("fig9_eval/levels", t_levels * 1e6, "seed per-level dispatcher")
        emit("fig9_eval/fused", t_fused * 1e6,
             f"speedup={rec['speedup']:.1f}x;"
             f"equiv={rec.get('equiv', 'skipped')}")
    return rec


def main():
    with Timer() as t:
        res = run()
    sat = res["dd5"][-1]["concurrent"]
    emit("fig9_stress", t.us,
         f"saturation_luts={sat};saturation_frac={sat/500:.2f}")
    res["eval_benchmark"] = run_eval_benchmark()
    return res


if __name__ == "__main__":
    main()
