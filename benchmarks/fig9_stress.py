"""Fig. 9 — packing stress test.

500 adders + 0..500 unrelated 5-LUTs.  Paper: DD5 area stays flat until the
ALMs saturate; concurrently packed 5-LUTs saturate at ~375 (75 %).
"""
from __future__ import annotations

from repro.core.alm import BASELINE, DD5
from repro.core.stress import run_packing_stress

from .common import Timer, emit

LUT_COUNTS = [0, 50, 100, 150, 200, 250, 300, 350, 400, 450, 500]


def run(verbose: bool = True):
    out = {}
    for arch in (BASELINE, DD5):
        res = run_packing_stress(arch, n_adders=500, lut_counts=LUT_COUNTS)
        out[arch.name] = res
        if verbose:
            for r in res:
                emit(f"fig9/{arch.name}/luts{r['n_luts']}", 0,
                     f"alms={r['alms']};area={r['area_mwta']:.0f};"
                     f"conc={r['concurrent']}")
    return out


def main():
    with Timer() as t:
        res = run()
    sat = res["dd5"][-1]["concurrent"]
    emit("fig9_stress", t.us,
         f"saturation_luts={sat};saturation_frac={sat/500:.2f}")
    return res


if __name__ == "__main__":
    main()
