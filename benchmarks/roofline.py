"""§Roofline: three-term analysis per (arch x shape x mesh).

Terms (seconds, per the assignment's formulas; TPU v5e constants):

    compute    = FLOPs / (chips * 197e12)
    memory     = HBM_bytes / (chips * 819e9)
    collective = collective_bytes / (chips * 50e9)

FLOPs / HBM bytes are **analytic** (derived from the model math and the
sharding strategy): XLA:CPU's ``cost_analysis`` counts ``scan`` bodies once
(trip counts are lost), so the compiled numbers undercount by ~L x — we report
them alongside for transparency, and take the collective *inventory* (which
ops, at what shapes) from the compiled HLO of the dry-run.  HBM modeling
assumes the flash-attention kernel (scores never hit HBM); the dry-run HLO
materializes reference attention instead, which is an XLA-CPU artifact.
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.configs.base import SHAPES, get_config

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def mesh_sizes(mesh_tag: str):
    return {"single": (256, 16, 16, 1), "multi": (512, 32, 16, 2)}[mesh_tag]
    # (chips, dp [pod*data], tp, pods)


def _attn_flops_fwd(cfg, B: int, S: int) -> float:
    """Per-step attention score+value flops, window-aware.

    Full causal layer: 4*B*S^2*H*D*0.5.  With the chunked sliding-window
    path (cfg.chunked_local_attn) a local layer computes S x 2w scores."""
    from repro.models.lm import _layer_windows
    import numpy as np

    if cfg.family == "ssm":
        return 0.0
    L, Hq, Dh = cfg.n_layers, cfg.n_heads, cfg.hd
    windows = np.asarray(_layer_windows(cfg, L))
    total = 0.0
    for w in windows:
        w = int(w)
        if cfg.chunked_local_attn and w * 2 <= S:
            total += 4 * B * S * (2 * w) * Hq * Dh
        else:
            total += 4 * B * (S ** 2) * Hq * Dh * 0.5
    return total


def analytic_terms(arch: str, shape_name: str, mesh_tag: str,
                   n_params: int, n_active: int, cfg=None,
                   fp8_expert_gather: bool = False) -> dict:
    if cfg is None:
        cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips, dp, tp, pods = mesh_sizes(mesh_tag)
    B, S = shape.global_batch, shape.seq_len
    L, d, Hq, Dh = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.hd
    bytes_p = 2  # bf16 params
    kv_elt = 1 if cfg.kv_cache_dtype == "int8" else 2

    n_attn_layers = 0 if cfg.family == "ssm" else L
    if shape.kind == "train":
        T = B * S
        flops = 6 * n_active * T
        flops += 3 * _attn_flops_fwd(cfg, B, S)
        if cfg.ssm_heads:
            flops += 3 * L * B * S * cfg.ssm_heads * cfg.ssm_head_dim \
                * cfg.ssm_state * 6
        # HBM: params fwd+bwd reads + grad w/r + opt rw + remat activations
        opt_bytes = (16 * n_params if not arch.startswith("kimi")
                     else 5 * n_params)   # adamw vs adafactor
        hbm = 4 * n_params * bytes_p + opt_bytes \
            + 4 * L * B * S * d * 2
        # collectives (global): FSDP all-gather fwd+bwd + grad reduce-scatter
        # + 2 TP all-reduces per layer on activations
        # (global bytes: ring collective moves Z*(dp-1) across the fabric)
        if fp8_expert_gather and cfg.is_moe:
            # expert weights cross the data axis at 1 B/elem (fwd + bwd
            # gathers); grad reduce-scatter stays bf16
            p_exp = (cfg.n_layers - cfg.n_dense_layers) * cfg.n_experts \
                * 3 * cfg.d_model * cfg.d_ff_expert
            p_rest = n_params - p_exp
            fsdp = (2 * (p_exp * 1 + p_rest * bytes_p)
                    + n_params * bytes_p) * (dp - 1)
        else:
            fsdp = 3 * n_params * bytes_p * (dp - 1)
        tp_ar = 2 * n_attn_layers * 2 * (B * S * d * 2) * (tp - 1) / tp
        coll = fsdp + tp_ar
        if cfg.is_moe:
            coll += 4 * B * S * d * 2 * cfg.top_k / max(cfg.top_k, 1)
    elif shape.kind == "prefill":
        T = B * S
        flops = 2 * n_active * T
        flops += _attn_flops_fwd(cfg, B, S)
        hbm = n_params * bytes_p + 2 * L * B * S * d * 2 \
            + 2 * L * B * S * cfg.n_kv_heads * Dh * kv_elt
        fsdp = n_params * bytes_p * (dp - 1) / dp
        tp_ar = 2 * n_attn_layers * (B * S * d * 2) * (tp - 1) / tp
        coll = fsdp + tp_ar
    else:  # decode: one token, full cache
        Tctx = S
        flops = 2 * n_active * B
        flops += n_attn_layers * 4 * B * Hq * Dh * Tctx
        kv_bytes = 2 * n_attn_layers * B * Tctx * cfg.n_kv_heads * Dh * kv_elt
        if cfg.family in ("ssm", "hybrid"):
            kv_bytes = 2 * L * B * cfg.ssm_heads * cfg.ssm_head_dim \
                * cfg.ssm_state * 4
            if cfg.family == "hybrid":
                w = cfg.local_window or Tctx
                kv_bytes += 2 * L * B * min(w, Tctx) * cfg.n_kv_heads * Dh \
                    * kv_elt
        hbm = n_params * bytes_p + kv_bytes
        tp_ar = 2 * n_attn_layers * (B * 1 * d * 2) * (tp - 1) / tp
        coll = tp_ar + n_params * bytes_p * 0  # weights resident (no FSDP
        # gather in decode: weights stay sharded TP-style and activations
        # all-reduce)
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "coll_bytes": coll,
        "t_compute": flops / (chips * PEAK_FLOPS),
        "t_memory": hbm / (chips * HBM_BW),
        "t_collective": coll / (chips * LINK_BW),
    }


def netlist_eval_terms(net, n_lane_words: int, plan=None) -> dict:
    """Roofline terms for one fused-evaluator pass over a netlist.

    The fused engine (``repro.core.eval_jax``) is a bitwise workload: count
    uint32 *word ops* instead of FLOPs.  Per LUT row the kernel unrolls 32
    minterms over 5 pins plus the pin-5 select (~``32*7 + 4`` word ops per
    lane word); per chain bit the ripple costs ~7 word ops.  Memory traffic
    is the level gathers/scatters against the value buffer (4 B words).
    The arithmetic intensity (ops/byte) says which side of the machine the
    evaluator saturates — on every real circuit it is compute-bound, which
    is why fusing away the per-level dispatch dominated the wall clock.

    Ops/bytes and ``padding_waste`` are summed per width bucket of the
    multi-scan plan; ``padding_waste_single_envelope`` is what the same
    circuit would waste under the old one-worst-case-envelope layout, so
    the bucketing win is visible in the CSV output.
    """
    from repro.core.eval_jax import plan_netlist

    if plan is None:
        plan = plan_netlist(net)
    N = n_lane_words
    word_ops = 0
    hbm = 0
    per_bucket = []
    for bk in plan.buckets:
        l, M, C, B = bk.shape
        M = M if bk.has_luts else 0
        C = C if bk.has_chains else 0
        B = B if bk.has_chains else 0
        lut_ops = l * M * N * (32 * 7 + 4)
        chain_ops = l * C * B * N * 7
        lut_bytes = l * (M * 6 * N * 4 + M * N * 4 + M * (4 * 2 + 24))
        chain_bytes = l * C * ((2 * B + 2) * N * 4 + (B + 1) * N * 4
                               + 4 * B * 2)
        word_ops += lut_ops + chain_ops
        hbm += lut_bytes + chain_bytes
        per_bucket.append({
            "levels": l, "M": M, "C": C, "B": B,
            "padded_lut_rows": l * M,
            "padded_chain_bits": l * C * B,
        })
    padded = plan.padded_lut_rows + plan.padded_chain_bits
    L, M, C, B = plan.envelope
    padded_single = L * M + L * C * B
    real = net.n_luts + net.n_adders
    return {
        "word_ops": word_ops,
        "hbm_bytes": hbm,
        "intensity_ops_per_byte": word_ops / max(hbm, 1),
        "t_memory": hbm / HBM_BW,
        "levels": plan.n_levels,
        "n_buckets": len(plan.buckets),
        "buckets": per_bucket,
        "padded_lut_rows": plan.padded_lut_rows,
        "padded_chain_bits": plan.padded_chain_bits,
        "real_luts": net.n_luts,
        "real_chain_bits": net.n_adders,
        "padding_waste": 1.0 - real / max(padded, 1),
        "padding_waste_single_envelope": 1.0 - real / max(padded_single, 1),
    }


def timing_program_terms(irs, n_archs: int = 1) -> dict:
    """Roofline terms for one batched static-timing pass over lowered
    PackIRs (``repro.core.pack_ir``), at ``n_archs`` delay rows.

    The vectorized analyzer is a float64 gather/add/max workload: per LUT
    row it gathers 6 arrivals + 6x3 edge components (3 adds each), a
    6-way max and 3 node adds; per chain bit two 3-add operand edges, a
    3-way max and the carry add.  Bytes count the arrival-buffer gathers/
    scatters (8 B doubles) — intensity is low, so unlike the bitwise
    evaluator the timing pass is memory-bound, and batching arch rows
    amortizes the index traffic rather than the flops."""
    flops = 0
    bytes_ = 0
    levels = 0
    for ir in irs:
        m, c, b = ir.level_profile()
        levels = max(levels, ir.n_levels)
        for M, C, B in zip(m, c, b):
            flops += M * (6 * 3 + 5 + 3) + C * B * (2 * 3 + 2 + 1) + C * 3
            bytes_ += M * (6 * 8 + 6 * 4 * 2 + 8) \
                + C * B * (2 * 8 + 2 * 4 * 2 + 8) + C * (8 + 4 + 8)
    flops *= n_archs
    bytes_ *= n_archs
    return {
        "flops": flops,
        "hbm_bytes": bytes_,
        "intensity_flops_per_byte": flops / max(bytes_, 1),
        "t_memory": bytes_ / HBM_BW,
        "levels": levels,
        "n_circuits": len(irs),
        "n_archs": n_archs,
    }


def load_cells(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def build_table(dryrun_dir: str = DRYRUN_DIR, mesh: str = "single"):
    rows = []
    for rec in load_cells(dryrun_dir):
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": "skipped", "reason": rec.get("reason")})
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": rec.get("status"),
                         "reason": rec.get("error", "")[:80]})
            continue
        t = analytic_terms(rec["arch"], rec["shape"], rec["mesh"],
                           rec["n_params"], rec["n_active_params"])
        terms = {"compute": t["t_compute"], "memory": t["t_memory"],
                 "collective": t["t_collective"]}
        dominant = max(terms, key=terms.get)
        bound = max(terms.values())
        frac = terms["compute"] / bound if bound > 0 else 0.0
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "t_compute": t["t_compute"], "t_memory": t["t_memory"],
            "t_collective": t["t_collective"],
            "dominant": dominant,
            "roofline_frac": frac,
            "model_flops": rec.get("model_flops", 0),
            "analytic_flops": t["flops"],
            "useful_ratio": (rec.get("model_flops", 0) / t["flops"]
                             if t["flops"] else 0),
            "hlo_flops_per_dev": rec.get("cost", {}).get("flops", 0),
            "hlo_coll_bytes_per_dev": rec.get("collectives", {}).get(
                "total_bytes", 0),
            "compile_s": rec.get("compile_s"),
        })
    return rows


def to_markdown(rows: list[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | frac-of-roofline | useful FLOP ratio |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} ({r.get('reason','')}) | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} | "
            f"{r['t_memory']:.3e} | {r['t_collective']:.3e} | "
            f"{r['dominant']} | {r['roofline_frac']:.2f} | "
            f"{r['useful_ratio']:.2f} |")
    return "\n".join(out)


def main():
    from .common import emit

    for mesh in ("single", "multi"):
        rows = build_table(mesh=mesh)
        ok = [r for r in rows if r.get("status") == "ok"]
        if not ok:
            emit("roofline", 0, "no dry-run artifacts; run launch.dryrun")
            return
        worst = min(ok, key=lambda r: r["roofline_frac"])
        coll_bound = [r for r in ok if r["dominant"] == "collective"]
        emit("roofline/summary", 0,
             f"cells={len(ok)};worst={worst['arch']}/{worst['shape']}"
             f"({worst['roofline_frac']:.2f});collective_bound="
             f"{len(coll_bound)}")
        csv_path = os.path.join(DRYRUN_DIR, "..", f"roofline_{mesh}.csv")
        with open(csv_path, "w") as f:
            keys = ["arch", "shape", "status", "t_compute", "t_memory",
                    "t_collective", "dominant", "roofline_frac",
                    "useful_ratio"]
            f.write(",".join(keys) + "\n")
            for r in rows:
                f.write(",".join(str(r.get(k, "")) for k in keys) + "\n")
        emit("roofline/csv", 0, os.path.abspath(csv_path))


if __name__ == "__main__":
    main()
