"""Placement-aware design-space sweep — the routed ADP frontier.

The packing-only frontier (``benchmarks/sweep_frontier.py``) asks what
the DD grid looks like when routing is free; this driver re-places and
re-times the full Kratos + Koios + VTR suite across the arch grid with
the wire-tier fabric model on (:mod:`repro.core.place`): every circuit
is grid-placed once per *placement key* (structural class x grid
aspect) — analytic seed then annealing refinement
(:mod:`repro.core.anneal`, ``refine="anneal"``, the ``sweep_suite``
default) — and every grid point's delay row, including the wire-tier
profile, is then pure data for the batched timing programs.  The
question the paper never measured: does DD5's density survive real wire
delay?

Two gates, both green in ``scripts/check.sh --smoke``:

* **placed oracle parity** — every (circuit, grid point) record is
  bit-identical to :func:`repro.core.timing.analyze_placed_oracle`, the
  per-signal Python walk with the same *annealed* placement;
* **placement reuse >= 2x** — supplying the grid's placements from the
  registry cache (one anneal per placement key, shared by every
  wire-delay row of the class) must beat refining a fresh placement at
  every grid point by >= 2x wall clock (min-of-N on the gated side,
  ``benchmarks/common.min_of_n``).

Records ``experiments/perf/placed_sweep.json`` — the placement-aware
frontier that supersedes the packing-only one for routing-pressure
questions (the packing-only file remains the placement-free reference).
The record's ``refinement`` block (``anneal_refine.wirelength_report``)
carries per-circuit analytic-vs-annealed wirelength, placed CPD deltas
at the routed wire profile, and the annealed-wirelength spread over an
annealing-seed ensemble.
"""
from __future__ import annotations

import json
import os
import time

from repro.core.alm import arch_grid
from repro.core.anneal import ANNEAL_COUNTS
from repro.core.packing import pack
from repro.core.place import PLACE_COUNTS, place_ir, placement_for
from repro.core.sweep import _flatten, adp_frontier, sweep_suite
from repro.core.timing import analyze_placed_oracle

from .common import Timer, emit, min_of_n, suites

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")

#: wire-tier delay profiles (ps): the zero row keeps every placement-free
#: pin reproducible; the routed row is an apicula-like hierarchy — a
#: 2-hop wire is cheaper than two 1-hop wires (no intermediate switch),
#: long wires span the grid at a fixed cost
WIRE_PROFILES = ((0.0, 0.0, 0.0), (25.0, 40.0, 120.0))


def _smoke_suites():
    from repro.core.circuits import kratos_gemm, vtr_mixed

    return {"smoke": [kratos_gemm(m=5, n=5, width=5, sparsity=0.5),
                      vtr_mixed(logic_nodes=150, adders=2)]}


def _grid(smoke: bool):
    if smoke:
        # 2 structural classes x 2 wire profiles = 4 points, 2 placement
        # keys — the smallest grid where reuse vs per-point is a real 2x
        return [a for a in arch_grid(wire_delays=WIRE_PROFILES)
                if a.name in ("b0", "b0_w25", "b2_f10", "b2_f10_w25")]
    return arch_grid(wire_delays=WIRE_PROFILES)


def placement_reuse_gate(nets, grid, packs, seed: int = 0,
                         smoke: bool = False) -> dict:
    """The >= 2x warm gate: registry-cached annealed placements (one
    anneal per circuit x placement key) vs a fresh analytic-solve +
    anneal at every (circuit, grid point).

    The cached side is what ``sweep_suite(place=True)`` actually pays
    per warm sweep; min-of-N because container noise only inflates it.
    The per-point baseline runs once — its noise can only overstate the
    baseline, never flake the gate.
    """
    _, flat = _flatten(nets)
    digests = [n.content_digest() for n in flat]
    irs = {}
    for g in range(len(flat)):
        for arch in grid:
            key = (g, arch.structural_key())
            if key not in irs:
                irs[key] = packs[(digests[g], arch.structural_key(),
                                  seed)].lower_ir()

    def reuse_pass():
        for g in range(len(flat)):
            for arch in grid:
                placement_for(irs[(g, arch.structural_key())], arch, seed,
                              refine="anneal")

    # warm the registry cache (the cold anneals were already paid by the
    # placed sweep; this makes the measurement independent of call order)
    reuse_pass()
    solved0 = PLACE_COUNTS["analytic"]
    anneals0 = ANNEAL_COUNTS["anneal"]
    t_reuse, _ = min_of_n(reuse_pass, n=3)
    assert PLACE_COUNTS["analytic"] == solved0, \
        "reuse pass must be pure cache hits"
    assert ANNEAL_COUNTS["anneal"] == anneals0, \
        "reuse pass must not re-anneal"

    t0 = time.perf_counter()
    n_per_point = 0
    for g in range(len(flat)):
        for arch in grid:
            place_ir(irs[(g, arch.structural_key())], arch, seed,
                     refine="anneal")
            n_per_point += 1
    t_per_point = time.perf_counter() - t0

    n_keys = len({(g, a.placement_key()) for g in range(len(flat))
                  for a in grid})
    speedup = t_per_point / max(t_reuse, 1e-9)
    return {
        "n_placements_per_point": n_per_point,
        "n_placements_reused": n_keys,
        "t_place_per_point_s": t_per_point,
        "t_place_reuse_s": t_reuse,
        "speedup_reuse": speedup,
        "pass_gate": speedup >= 2.0,
    }


def run(smoke: bool = False, verbose: bool = True, seed: int = 0,
        write_json: bool = True) -> dict:
    nets = _smoke_suites() if smoke else suites("wallace")
    grid = _grid(smoke)

    packs: dict = {}
    programs: dict = {}
    t0 = time.perf_counter()
    res = sweep_suite(nets, grid, seed=seed, packs=packs, programs=programs,
                      place=True)
    t_cold = time.perf_counter() - t0
    t_warm, res_warm = min_of_n(
        lambda: sweep_suite(nets, grid, seed=seed, packs=packs,
                            programs=programs, place=True),
        n=3, sample=lambda r, elapsed: elapsed - r.wall["pack_s"])

    # gate (a): every grid point bit-identical to the placed Python
    # oracle under the same registry-cached placement
    _, flat = _flatten(nets)
    digests = [n.content_digest() for n in flat]
    t0 = time.perf_counter()
    match = True
    for g in range(len(flat)):
        for k, arch in enumerate(grid):
            p = pack(flat[g], arch, seed=seed)
            pl = placement_for(p.lower_ir(), arch, seed, refine="anneal")
            want = analyze_placed_oracle(p, pl)
            for r in (res, res_warm):
                got = r.records[g][k]
                if (want["critical_path_ps"] != got["critical_path_ps"]
                        or want["area_mwta"] != got["area_mwta"]):
                    match = False
    t_oracle = time.perf_counter() - t0

    # gate (b): placement reuse across wire-delay rows of a class
    reuse = placement_reuse_gate(nets, grid, packs, seed=seed, smoke=smoke)

    # refinement report: analytic-vs-annealed wirelength, CPD deltas at
    # the routed wire profile, and the annealing-seed-ensemble spread
    from .anneal_refine import wirelength_report

    refinement = wirelength_report(
        flat, seed=seed, steps=24 if smoke else None,
        seeds=(0,) if smoke else (0, 1, 2), timing_mode=not smoke)

    frontier = adp_frontier(res, baseline="b0")
    # wire-delay sensitivity: same structural point with/without the
    # routed-wire profile (the question the packing-only frontier can't ask)
    by_name = {row["arch"]: row for row in frontier}
    wire_cost = {
        name: by_name[f"{name}_w25"]["critical_path_ps"]
        / by_name[name]["critical_path_ps"]
        for name in ("b2_f5", "b2_f10", "b2_f20", "b2_f10_l6")
        if name in by_name and f"{name}_w25" in by_name
    }

    rec = {
        "tag": "placed_sweep",
        "smoke": smoke,
        "n_circuits": len(flat),
        "n_grid_points": len(grid),
        "grid": [{"name": a.name, "bypass_inputs": a.bypass_inputs,
                  "addmux_fanin": a.addmux_fanin, "lut6": a.concurrent_6lut,
                  "wire_delays": (a.t_wire_hop1, a.t_wire_hop2,
                                  a.t_wire_long)} for a in grid],
        "wire_profiles": [list(w) for w in WIRE_PROFILES],
        "n_structural_classes": res.n_classes,
        "t_placed_cold_s": t_cold,
        "t_placed_warm_s": t_warm,
        "t_oracle_s": t_oracle,
        "wall_cold": res.wall,
        "wall_warm": res_warm.wall,
        "oracle_match": bool(match),
        "placement_reuse": reuse,
        "refinement": refinement,
        "frontier_vs_b0": frontier,
        "wire_cpd_ratio": wire_cost,
        "pass_gate": (bool(match) and reuse["pass_gate"]
                      and refinement["all_never_worse"]
                      and refinement["all_legal"]),
    }
    if write_json and not smoke:
        os.makedirs(OUT, exist_ok=True)
        with open(os.path.join(OUT, "placed_sweep.json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        for row in frontier:
            emit(f"place/frontier/{row['arch']}", 0,
                 f"area={row['area_mwta']:.3f};"
                 f"cpd={row['critical_path_ps']:.3f};adp={row['adp']:.3f}")
        emit("place/sweep", 0,
             f"points={len(grid)};classes={res.n_classes};"
             f"cold={t_cold:.2f}s;warm={t_warm:.2f}s;"
             f"oracle_match={match}")
        emit("place/reuse", 0,
             f"per_point={reuse['t_place_per_point_s']:.3f}s;"
             f"reused={reuse['t_place_reuse_s']:.3f}s;"
             f"speedup={reuse['speedup_reuse']:.1f}x;"
             f"gate={reuse['pass_gate']}")
        emit("place/refine", 0,
             f"geomean_improvement="
             f"{refinement['geomean_improvement']:.3f};"
             f"never_worse={refinement['all_never_worse']};"
             f"legal={refinement['all_legal']}")
    return rec


def main():
    with Timer() as t:
        rec = run()
    best = rec["frontier_vs_b0"][0] if rec["frontier_vs_b0"] else {}
    emit("place_sweep", t.us,
         f"points={rec['n_grid_points']};"
         f"classes={rec['n_structural_classes']};"
         f"best_adp={best.get('arch', '')}={best.get('adp', 0):.3f};"
         f"reuse={rec['placement_reuse']['speedup_reuse']:.1f}x;"
         f"oracle_match={rec['oracle_match']};gate={rec['pass_gate']}")
    return rec


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        rec = run(smoke=True)
        sys.exit(0 if rec["pass_gate"] else 1)
    main()
