"""Beyond-paper ablations: DD5 advantage vs weight sparsity and precision.

The paper motivates Double-Duty with sparse, mixed-precision unrolled DNNs
(Kratos) but evaluates one sparsity/width point per suite.  This sweep maps
*where* the architecture pays off: concurrency-driven area savings as a
function of (a) weight sparsity and (b) operand width — the two Kratos
knobs.  Expectation from the mechanism: higher sparsity → fewer multipliers
→ adder-dominated residue (higher savings until LUT supply runs out);
wider operands → larger compressor clouds per chain (more co-packing fuel).
"""
from __future__ import annotations

from repro.core import flow
from repro.core.circuits import kratos_gemm

from .common import Timer, emit


def run(verbose: bool = True):
    out = {"sparsity": [], "width": []}
    for sp in (0.0, 0.25, 0.5, 0.75):
        net = kratos_gemm("sweep", m=8, n=8, width=6, sparsity=sp, seed=1)
        pa = flow.run_circuit(net, ("baseline", "dd5"), seeds=(0,))
        b, d = pa["baseline"], pa["dd5"]
        rec = {"sparsity": sp, "area_ratio": d["area_mwta"] / b["area_mwta"],
               "conc": d["concurrent_luts"], "alms_base": b["alms"]}
        out["sparsity"].append(rec)
        if verbose:
            emit(f"beyond/sparsity{sp}", 0,
                 f"area={rec['area_ratio']:.3f};conc={rec['conc']}")
    for wd in (4, 6, 8):
        net = kratos_gemm("sweep", m=8, n=8, width=wd, sparsity=0.5, seed=1)
        pa = flow.run_circuit(net, ("baseline", "dd5"), seeds=(0,))
        b, d = pa["baseline"], pa["dd5"]
        rec = {"width": wd, "area_ratio": d["area_mwta"] / b["area_mwta"],
               "conc": d["concurrent_luts"]}
        out["width"].append(rec)
        if verbose:
            emit(f"beyond/width{wd}", 0,
                 f"area={rec['area_ratio']:.3f};conc={rec['conc']}")
    return out


def main():
    with Timer() as t:
        res = run()
    best = min(res["sparsity"], key=lambda r: r["area_ratio"])
    emit("beyond_paper", t.us,
         f"best_sparsity={best['sparsity']};area={best['area_ratio']:.3f}")
    return res


if __name__ == "__main__":
    main()
