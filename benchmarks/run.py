"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Sections:
  fig5  — CAD-flow validation (stock VTR vs improved synthesis)
  fig6  — DD5 vs baseline across suites (headline result)
  fig7  — DD5 vs DD6
  fig8  — routing-demand histogram
  fig9  — packing stress test
  table4 — end-to-end SHA stress test
  kernels — Pallas kernel microbenchmarks (interpret mode on CPU)
  roofline — reads dry-run artifacts if present (see launch/dryrun.py)
"""
from __future__ import annotations

import sys


def main() -> None:
    print("name,us_per_call,derived")
    from . import fig5_cad, fig6_dd5, fig7_dd6, fig8_congestion, fig9_stress, table4_e2e

    fig5_cad.main()
    fig6_dd5.main()
    fig7_dd6.main()
    fig8_congestion.main()
    fig9_stress.main()
    table4_e2e.main()
    from . import beyond_paper

    beyond_paper.main()
    try:
        from . import kernels as kbench

        kbench.main()
    except Exception as e:  # kernels need jax; report rather than die
        print(f"kernels,,skipped({type(e).__name__}: {e})", file=sys.stderr)
    try:
        from . import roofline as rbench

        rbench.main()
    except Exception as e:
        print(f"roofline,,skipped({type(e).__name__}: {e})", file=sys.stderr)


if __name__ == "__main__":
    main()
