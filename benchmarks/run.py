"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Sections:
  fig5  — CAD-flow validation (stock VTR vs improved synthesis)
  fig6  — DD5 vs baseline across suites (headline result)
  fig7  — DD5 vs DD6
  fig8  — routing-demand histogram
  fig9  — packing stress test
  table4 — end-to-end SHA stress test
  beyond — beyond-paper sparsity/width ablations
  sweep — arch-grid ADP frontier (bypass width x AddMux population),
          batched PackIR timing, oracle-gated
  place — placement-aware ADP frontier (grid placer + annealing
          refinement + wire-tier delays), gated on placed-oracle
          bit-identity and >= 2x placement reuse
  anneal — annealing placement refinement: per-circuit analytic-vs-
          annealed wirelength + CPD deltas, gated on legality,
          never-worse-than-seed, placed-oracle parity on annealed
          placements, and a suite geomean HPWL improvement >= 5%
  search — thousand-point successive-halving design-space search over
          the full arch grid, gated on winner oracle parity +
          equivalence and a >= 2x search-vs-dense cost ratio; plus the
          placed wire-delay-axis search (annealed placements, annealing
          wall in the rung ledger, >= 2x placement-reuse gate)
  serve — async batched flow serving: p50/p99 latency + throughput at
          1/8/32 concurrent clients, gated on serial bit-identity and
          coalesced warm throughput >= 2x the serial min-of-N baseline
  repack — incremental repack: a single-LUT edit on conv2d-fu served
          via dirty-set re-cluster + IR patch, gated on pack
          byte-identity, per-cluster equivalence of every touched LB,
          served-record parity, and a >= 2x delta-vs-full speedup
  kernels — Pallas kernel microbenchmarks (interpret mode on CPU)
  roofline — reads dry-run artifacts if present (see launch/dryrun.py)

Every section is failure-isolated — including its *import*: an exception
anywhere in one figure reports a ``<section>,,failed(...)`` line on stderr
and the run continues, so a CSV run always covers every section it can
(an environment without jax still gets every jax-free section).

After each section the driver emits a ``<section>.timing_analysis`` CSV
row: the static-timing wall time (and call count) that section spent in
``repro.core.timing`` — the figure suites are packing-bound, and this row
is what proves it (the vectorized PackIR analyzer keeps the timing share
in the noise; see ``experiments/perf/timing_sweep.json`` for the
suite-scale sweep numbers).

``--smoke`` is the fast-tier CI entrypoint (also ``scripts/check.sh``):
runs ``pytest -m "not slow"``, a 2-point arch-grid sweep gated on oracle
bit-identity, the IR-parity step, a 2-circuit placement gate (placed
sweep bit-identical to the placed oracle + >= 2x placement reuse), a
2-circuit bounded-iteration anneal gate (grid-legal, wirelength <= the
analytic seed, placed-oracle parity on the annealed placements,
bit-deterministic re-anneal), a
2-rung / 8-point / 2-circuit search smoke (winner oracle parity +
equivalence, dense-vs-search cost ratio >= 1), and a flow-serving smoke
(8 concurrent clients over 2 circuits x 2 archs, every served record
bit-identical to serial ``pack_and_analyze``, coalesced warm throughput
>= the serial baseline), and a repack-delta smoke (a single-LUT edit on
conv2d-fu served via the dirty-set path: pack byte-identical to a fresh
``pack()``, every touched LB proven equivalent, served record
bit-identical to ``pack_and_analyze``), and exits non-zero on any
failure.  The run ends with the cache-registry table — per-cache
size/cap, hits, misses, evictions, and the derived hit rate from
``plan.cache_stats()`` — so a smoke log always shows where the run's
reuse actually came from.
"""
from __future__ import annotations

import importlib
import sys

SECTIONS = [
    ("fig5", "fig5_cad"),
    ("fig6", "fig6_dd5"),
    ("fig7", "fig7_dd6"),
    ("fig8", "fig8_congestion"),
    ("fig9", "fig9_stress"),
    ("table4", "table4_e2e"),
    ("beyond", "beyond_paper"),
    ("sweep", "sweep_frontier"),
    ("place", "place_sweep"),
    ("anneal", "anneal_refine"),
    ("search", "search_frontier"),
    ("serve", "serve_latency"),
    ("repack", "repack_delta"),
    ("kernels", "kernels"),
    ("roofline", "roofline"),
]


def _timing_wall():
    try:
        from repro.core.timing import read_timing_wall

        return read_timing_wall()
    except ImportError:
        return None


def _section(name: str, module: str) -> str:
    import time

    w0 = _timing_wall()
    t0 = time.perf_counter()
    try:
        importlib.import_module(f".{module}", package=__package__).main()
        elapsed = time.perf_counter() - t0
        w1 = _timing_wall()
        if w0 is not None and w1 is not None:
            delta = w1["s"] - w0["s"]
            # Non-overlap invariant: scope-aware accounting (see
            # repro.core.timing.timing_section) guarantees each accounted
            # span commits once, so a section's timing delta can never
            # exceed the wall time the section actually ran for.  A
            # violation means a nested accounting site double-counted.
            # (explicit raise, not assert: must survive `python -O`)
            if delta > elapsed + 1e-6:
                raise RuntimeError(
                    f"{name}: timing_analysis delta {delta:.3f}s exceeds "
                    f"the section's elapsed {elapsed:.3f}s — TIMING_WALL "
                    f"double-counted a nested section")
            print(f"{name}.timing_analysis,{delta * 1e6:.0f},"
                  f"calls={w1['calls'] - w0['calls']}")
        return "ok"
    except ImportError as e:
        # missing optional dependency (e.g. no jax): not a failure — the
        # seed behavior for kernels/roofline, now uniform for all sections
        print(f"{name},,skipped({type(e).__name__}: {e})", file=sys.stderr)
        return "skipped"
    except Exception as e:  # noqa: BLE001 — report uniformly, keep going
        print(f"{name},,failed({type(e).__name__}: {e})", file=sys.stderr)
        return "failed"


def smoke() -> int:
    """Fast-tier check: ``pytest -m "not slow"`` + a 2-point arch-grid
    sweep proven bit-identical to the timing oracle + the IR-parity step
    (two circuits lowered ONCE each; eval and timing both proven against
    their oracles from the same CircuitIR object) + the 2-circuit
    placement gate (placed sweep bit-identical to the placed oracle,
    placement reuse >= 2x vs place-per-point) + the bounded-iteration
    anneal gate (legal, never-worse, placed-oracle parity,
    deterministic) + the 2-rung search smoke
    (winner oracle parity + equivalence, dense-vs-search ratio >= 1) +
    the flow-serving smoke (8 concurrent clients, 2 circuits x 2 archs;
    serial bit-identity + coalesced >= serial throughput) + the
    repack-delta smoke (single-LUT edit served via the dirty-set path,
    parity- and equivalence-gated)."""
    import os
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    print("== smoke: pytest fast tier ==", flush=True)
    tests = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", "-m", "not slow"],
        cwd=root, env=env)
    print("== smoke: 2-point arch-grid sweep ==", flush=True)
    try:
        from .sweep_frontier import run as sweep_run

        rec = sweep_run(smoke=True)
        sweep_ok = rec["oracle_match"]
    except Exception as e:  # noqa: BLE001
        print(f"smoke_sweep,,failed({type(e).__name__}: {e})",
              file=sys.stderr)
        sweep_ok = False
    print("== smoke: IR parity (one lowering serves eval + timing) ==",
          flush=True)
    try:
        from .ir_parity import run as ir_parity_run

        ir_ok = ir_parity_run()["oracle_match"]
    except Exception as e:  # noqa: BLE001
        print(f"smoke_ir_parity,,failed({type(e).__name__}: {e})",
              file=sys.stderr)
        ir_ok = False
    print("== smoke: 2-circuit placement parity + reuse gate ==",
          flush=True)
    try:
        from .place_sweep import run as place_run

        prec = place_run(smoke=True)
        place_ok = prec["pass_gate"]
    except Exception as e:  # noqa: BLE001
        print(f"smoke_place,,failed({type(e).__name__}: {e})",
              file=sys.stderr)
        place_ok = False
    print("== smoke: bounded-iteration anneal gate (2 circuits) ==",
          flush=True)
    try:
        from .anneal_refine import run as anneal_run

        arec = anneal_run(smoke=True)
        anneal_ok = arec["pass_gate"]
    except Exception as e:  # noqa: BLE001
        print(f"smoke_anneal,,failed({type(e).__name__}: {e})",
              file=sys.stderr)
        anneal_ok = False
    print("== smoke: 2-rung successive-halving search gate ==", flush=True)
    try:
        from .search_frontier import run as search_run

        srec = search_run(smoke=True)
        search_ok = srec["pass_gate"]
    except Exception as e:  # noqa: BLE001
        print(f"smoke_search,,failed({type(e).__name__}: {e})",
              file=sys.stderr)
        search_ok = False
    print("== smoke: flow-serving gate (8 clients, 2 circuits x 2 archs) ==",
          flush=True)
    try:
        from .serve_latency import run as serve_run

        vrec = serve_run(smoke=True)
        serve_ok = vrec["pass_gate"]
    except Exception as e:  # noqa: BLE001
        print(f"smoke_serve,,failed({type(e).__name__}: {e})",
              file=sys.stderr)
        serve_ok = False
    print("== smoke: repack-delta gate (single-LUT edit, dirty-set path) ==",
          flush=True)
    try:
        from .repack_delta import run as repack_run

        rrec = repack_run(smoke=True)
        repack_ok = rrec["pass_gate"]
    except Exception as e:  # noqa: BLE001
        print(f"smoke_repack,,failed({type(e).__name__}: {e})",
              file=sys.stderr)
        repack_ok = False
    _print_cache_table()
    ok = (tests.returncode == 0 and sweep_ok and ir_ok and place_ok
          and anneal_ok and search_ok and serve_ok and repack_ok)
    print(f"smoke,,{'ok' if ok else 'failed'}"
          f"(tests={'ok' if tests.returncode == 0 else 'fail'};"
          f"sweep={'ok' if sweep_ok else 'fail'};"
          f"ir_parity={'ok' if ir_ok else 'fail'};"
          f"place={'ok' if place_ok else 'fail'};"
          f"anneal={'ok' if anneal_ok else 'fail'};"
          f"search={'ok' if search_ok else 'fail'};"
          f"serve={'ok' if serve_ok else 'fail'};"
          f"repack={'ok' if repack_ok else 'fail'})")
    return 0 if ok else 1


def _print_cache_table() -> None:
    """The cache-registry table: every registered cache's occupancy and
    hit/miss/eviction counters with the derived hit rate — the smoke
    run's reuse ledger (counters survive ``clear_caches``, so this is
    cumulative over every gate above)."""
    try:
        from repro.core.plan import cache_stats
    except ImportError:
        return
    stats = cache_stats()
    if not stats:
        return
    print("== caches ==", flush=True)
    print(f"{'cache':<20} {'size/cap':>9} {'hits':>7} {'misses':>7} "
          f"{'evict':>6} {'hit_rate':>8}")
    for name in sorted(stats):
        s = stats[name]
        print(f"{name:<20} {s['size']:>4}/{s['cap']:<4} {s['hits']:>7} "
              f"{s['misses']:>7} {s['evictions']:>6} {s['hit_rate']:>8.3f}")


def main() -> int:
    if "--smoke" in sys.argv[1:]:
        return smoke()
    print("name,us_per_call,derived")
    status = {name: _section(name, mod) for name, mod in SECTIONS}
    failed = [name for name, st in status.items() if st == "failed"]
    if failed:
        print(f"sections_failed,,{';'.join(failed)}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
