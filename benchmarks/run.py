"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Sections:
  fig5  — CAD-flow validation (stock VTR vs improved synthesis)
  fig6  — DD5 vs baseline across suites (headline result)
  fig7  — DD5 vs DD6
  fig8  — routing-demand histogram
  fig9  — packing stress test
  table4 — end-to-end SHA stress test
  beyond — beyond-paper sparsity/width ablations
  kernels — Pallas kernel microbenchmarks (interpret mode on CPU)
  roofline — reads dry-run artifacts if present (see launch/dryrun.py)

Every section is failure-isolated — including its *import*: an exception
anywhere in one figure reports a ``<section>,,failed(...)`` line on stderr
and the run continues, so a CSV run always covers every section it can
(previously only kernels/roofline were wrapped and any fig failure killed
the whole run; an environment without jax still gets every jax-free
section).
"""
from __future__ import annotations

import importlib
import sys

SECTIONS = [
    ("fig5", "fig5_cad"),
    ("fig6", "fig6_dd5"),
    ("fig7", "fig7_dd6"),
    ("fig8", "fig8_congestion"),
    ("fig9", "fig9_stress"),
    ("table4", "table4_e2e"),
    ("beyond", "beyond_paper"),
    ("kernels", "kernels"),
    ("roofline", "roofline"),
]


def _section(name: str, module: str) -> str:
    try:
        importlib.import_module(f".{module}", package=__package__).main()
        return "ok"
    except ImportError as e:
        # missing optional dependency (e.g. no jax): not a failure — the
        # seed behavior for kernels/roofline, now uniform for all sections
        print(f"{name},,skipped({type(e).__name__}: {e})", file=sys.stderr)
        return "skipped"
    except Exception as e:  # noqa: BLE001 — report uniformly, keep going
        print(f"{name},,failed({type(e).__name__}: {e})", file=sys.stderr)
        return "failed"


def main() -> int:
    print("name,us_per_call,derived")
    status = {name: _section(name, mod) for name, mod in SECTIONS}
    failed = [name for name, st in status.items() if st == "failed"]
    if failed:
        print(f"sections_failed,,{';'.join(failed)}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
