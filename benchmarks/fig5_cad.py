"""Fig. 5 — CAD improvement validation.

Baseline-VTR synthesis vs our improved Cascade / Wallace / Dadda (and PW),
packed on the baseline Stratix-10-like architecture.  Reports geomean
adders / ALMs / critical path / ADP over the Kratos suite, normalized to the
stock-VTR synthesis.  Paper: improved flow is worth ~37 % ADP; Wallace is the
best overall.
"""
from __future__ import annotations

from repro.core import flow
from repro.core.circuits import kratos_suite

from .common import Timer, emit, geomean

ALGOS = ("vtr_baseline", "cascade", "binary", "wallace", "dadda", "pw")


def run(scale: float = 1.0, verbose: bool = True):
    per_algo: dict[str, dict[str, float]] = {}
    base_metrics: list[dict] | None = None
    for algo in ALGOS:
        nets = kratos_suite(algo=algo, scale=scale)
        ms = [flow.pack_and_analyze(net, "baseline") for net in nets]
        if algo == "vtr_baseline":
            base_metrics = ms
        norm = {
            "adders": geomean([m["adders"] / b["adders"]
                               for m, b in zip(ms, base_metrics)]),
            "alms": geomean([m["alms"] / b["alms"]
                             for m, b in zip(ms, base_metrics)]),
            "cpd": geomean([m["critical_path_ps"] / b["critical_path_ps"]
                            for m, b in zip(ms, base_metrics)]),
            "adp": geomean([m["adp"] / b["adp"]
                            for m, b in zip(ms, base_metrics)]),
        }
        per_algo[algo] = norm
        if verbose:
            emit(f"fig5/{algo}", 0,
                 f"adders={norm['adders']:.3f};alms={norm['alms']:.3f};"
                 f"cpd={norm['cpd']:.3f};adp={norm['adp']:.3f}")
    return per_algo


def main():
    from repro.core.timing import read_timing_wall

    w0 = read_timing_wall()
    with Timer() as t:
        res = run()
    w1 = read_timing_wall()
    wall_adp = res["wallace"]["adp"]
    emit("fig5_cad", t.us, f"wallace_adp_vs_stock_vtr={wall_adp:.3f};"
         f"timing_s={w1['s'] - w0['s']:.3f}")
    return res


if __name__ == "__main__":
    main()
