"""Table IV — end-to-end stress test.

Fix the FPGA size needed for a Kratos base circuit (+ margin), then count how
many extra SHA instances fit.  Paper: +80 % / +66.7 % / +18.2 % instances for
conv1d / conv2d / gemmt, with slightly *better* critical paths on DD5.

The capacity sweep (``core.stress.run_e2e_stress``) packs and analyzes
through the unified ``repro.core.flow`` pipeline.
"""
from __future__ import annotations

from repro.core.alm import BASELINE, DD5
from repro.core.circuits import (kratos_conv1d, kratos_conv2d, kratos_gemm,
                                 sha_like)
from repro.core.stress import run_e2e_stress

from .common import Timer, emit

BASES = {
    "conv1d-mini": lambda: kratos_conv1d(in_ch=2, out_ch=4, width=6,
                                         sparsity=0.5),
    "conv2d-mini": lambda: kratos_conv2d(in_ch=2, out_ch=2, width=6,
                                         sparsity=0.5),
    "gemmt-mini": lambda: kratos_gemm("gemmt-mini", m=8, n=8, width=6,
                                      sparsity=0.5),
}


def run(verbose: bool = True, max_instances: int = 48):
    sha = sha_like(rounds=1)
    out = {}
    for name, mk in BASES.items():
        res = run_e2e_stress(mk(), sha, [BASELINE, DD5],
                             max_instances=max_instances)
        out[name] = res
        if verbose:
            b, d = res["baseline"], res["dd5"]
            gain = (d["instances"] - b["instances"]) / max(1, b["instances"])
            emit(f"table4/{name}", 0,
                 f"base_sha={b['instances']};dd5_sha={d['instances']};"
                 f"gain={gain*100:.1f}%;conc={d['concurrent']};"
                 f"cpd_delta={100*(d['cpd_ps']/b['cpd_ps']-1):.1f}%")
    return out


def main():
    from repro.core.timing import read_timing_wall

    w0 = read_timing_wall()
    with Timer() as t:
        res = run()
    w1 = read_timing_wall()
    gains = []
    for name, r in res.items():
        b, d = r["baseline"]["instances"], r["dd5"]["instances"]
        gains.append((d - b) / max(1, b) * 100)
    emit("table4_e2e", t.us,
         ";".join(f"{n}=+{g:.0f}%" for n, g in zip(res, gains))
         + f";timing_s={w1['s'] - w0['s']:.3f}")
    return res


if __name__ == "__main__":
    main()
