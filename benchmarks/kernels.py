"""Kernel microbenchmarks (interpret mode on CPU — correctness-level timing;
real TPU numbers come from the roofline analysis of the compiled dry-run)."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .common import emit


def _time(fn, *args, iters=3, **kw):
    fn(*args, **kw).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    r = np.random.default_rng(0)

    x = jnp.asarray(r.integers(0, 2**32, (256, 8), dtype=np.uint32))
    w = jnp.asarray(r.integers(0, 2**32, (256, 8), dtype=np.uint32))
    us = _time(ops.popcount_matmul, x, w, mode="and")
    emit("kernel/popcount_matmul_256x256x256b", us, "mode=and")

    ins = jnp.asarray(r.integers(0, 2**32, (2048, 4, 8), dtype=np.uint32))
    tts = jnp.asarray(r.integers(0, 2**16, (2048,), dtype=np.uint32))
    us = _time(ops.lut_eval, ins, tts)
    emit("kernel/lut_eval_2048x4x8", us, "k=4")

    xf = jnp.asarray(r.standard_normal((128, 256)).astype(np.float32))
    planes = jnp.asarray(r.integers(0, 2, (4, 256, 128)).astype(np.float32))
    scale = jnp.ones(128, jnp.float32)
    us = _time(ops.bitplane_matmul, xf, planes, scale)
    emit("kernel/bitplane_matmul_128x256x128_b4", us, "planes=4")

    q = jnp.asarray(r.standard_normal((1, 4, 256, 64)).astype(np.float32))
    k = jnp.asarray(r.standard_normal((1, 2, 256, 64)).astype(np.float32))
    v = jnp.asarray(r.standard_normal((1, 2, 256, 64)).astype(np.float32))
    us = _time(ops.flash_attention, q, k, v)
    emit("kernel/flash_attention_b1h4s256d64", us, "causal_gqa")

    xs = jnp.asarray(r.standard_normal((1, 256, 2, 32)).astype(np.float32))
    dt = jnp.asarray((0.01 + 0.02 * r.random((1, 256, 2))).astype(np.float32))
    A = jnp.asarray(np.full(2, -1.0, np.float32))
    B = jnp.asarray(r.standard_normal((1, 256, 16)).astype(np.float32))
    C = jnp.asarray(r.standard_normal((1, 256, 16)).astype(np.float32))
    us = _time(ops.ssd_scan, xs, dt, A, B, C)
    emit("kernel/ssd_scan_b1l256h2p32", us, "chunked")


if __name__ == "__main__":
    main()
