"""Smoke IR-parity check: ONE CircuitIR lowering serves eval AND timing.

The unified substrate's core contract (``repro/core/circuit_ir.py``): a
packed circuit is lowered exactly once per (content digest, structural
class), and that single object drives both the fused evaluator (via
``eval_jax.plan_from_ir`` — the functional columns) and the vectorized
static-timing analyzer (``timing_vec.analyze_ir`` — the placement
columns).  This check packs two small circuits, lowers each once, and
proves from the *same IR object*:

* evaluation output bit-identical to the pure-python ``eval_netlist``
  oracle on every primary output (``flow.oracle_check``);
* the timing record bit-identical to ``timing.analyze_oracle``;
* the lowering counters show exactly one functional lowering per
  circuit and one placement patch per (circuit, class) — no duplicate
  lowering anywhere on the path.

Run by ``scripts/check.sh`` / ``python -m benchmarks.run --smoke``.
"""
from __future__ import annotations

import numpy as np

from repro.core import flow
from repro.core.alm import ARCHS
from repro.core.circuit_ir import read_lower_counts, reset_lower_counts
from repro.core.circuits import kratos_gemm, sha_like
from repro.core.eval_jax import eval_netlist_jax, plan_from_ir
from repro.core.packing import pack
from repro.core.plan import clear_caches
from repro.core.timing import analyze_oracle
from repro.core.timing_vec import analyze_ir

from .common import emit

N_LANE_WORDS = 2


def run(verbose: bool = True) -> dict:
    nets = [kratos_gemm(m=4, n=4, width=4, sparsity=0.5),
            sha_like(rounds=1)]
    arch = ARCHS["dd5"]
    clear_caches()
    reset_lower_counts()
    eval_ok = timing_ok = True
    for net in nets:
        packed = pack(net, arch, seed=0)
        ir = packed.lower_ir()                      # the ONE lowering
        # eval lane: plan built from the same IR object
        plan = plan_from_ir(ir)
        lanes = flow.random_lanes(net, N_LANE_WORDS, seed=0)
        vals = np.asarray(eval_netlist_jax(net, lanes, N_LANE_WORDS,
                                           plan=plan))
        eval_ok &= flow.oracle_check(net, lanes, vals, N_LANE_WORDS)
        # timing lane: same IR object, vs the python oracle
        rec = analyze_ir(ir, arch)
        want = analyze_oracle(packed)
        timing_ok &= rec["critical_path_ps"] == want["critical_path_ps"]
        timing_ok &= rec["area_mwta"] == want["area_mwta"]
    counts = read_lower_counts()
    single_lowering = (counts["functional"] == len(nets)
                       and counts["placement_full"]
                       + counts["placement_incremental"] == len(nets))
    ok = bool(eval_ok and timing_ok and single_lowering)
    rec = {"oracle_match": ok, "eval_ok": bool(eval_ok),
           "timing_ok": bool(timing_ok),
           "single_lowering": bool(single_lowering),
           "lower_counts": counts, "n_circuits": len(nets)}
    if verbose:
        emit("ir_parity", 0,
             f"eval={eval_ok};timing={timing_ok};"
             f"single_lowering={single_lowering};counts={counts}")
    return rec


def main():
    rec = run()
    if not rec["oracle_match"]:
        raise AssertionError(f"IR parity failed: {rec}")
    return rec


if __name__ == "__main__":
    main()
