"""Multi-device distribution tests (8 host devices via subprocess).

The dry-run proves 256/512-way compile; these tests prove the same code
path *executes* correctly on a small real mesh: sharded train step runs,
metrics are finite, and a checkpoint taken on one mesh restores onto a
different mesh (elastic re-scale)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess + 8-device host mesh

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.data.pipeline import batch_for_step, to_device
from repro.launch.mesh import make_host_mesh
from repro.models.lm import init_params
from repro.parallel.api import sharding_rules
from repro.parallel.sharding import (activation_rules, batch_specs,
                                     opt_specs, param_specs)
from repro.train.step import TrainConfig, make_train_step
from repro.checkpoint import ckpt

arch = sys.argv[1]
mp = int(sys.argv[2])
ckpt_dir = sys.argv[3]

cfg = get_config(arch).smoke()
mesh = make_host_mesh(model_parallel=mp)
params = init_params(jax.random.key(0), cfg)
pshape = jax.eval_shape(lambda: params)
pspecs = param_specs(cfg, mesh, pshape)
params = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                      params, pspecs,
                      is_leaf=lambda x: isinstance(x, jax.Array))
tcfg = TrainConfig()
step_fn, opt_init = make_train_step(cfg, tcfg)
opt = opt_init(params)
losses = []
with mesh, sharding_rules(activation_rules(cfg, mesh)):
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))
    for s in range(3):
        batch = to_device(batch_for_step(cfg, 64, 8, s))
        params, opt, m = jstep(params, opt, batch)
        losses.append(float(m["loss"]))
if ckpt_dir:
    ckpt.save(ckpt_dir, 3, params)
print(json.dumps({"losses": losses,
                  "n_devices": len(jax.devices()),
                  "mesh": dict(mesh.shape)}))
"""

RESTORE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from jax.sharding import NamedSharding
from repro.configs.base import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.lm import init_params
from repro.parallel.sharding import param_specs
from repro.checkpoint import ckpt

arch, mp, ckpt_dir = sys.argv[1], int(sys.argv[2]), sys.argv[3]
cfg = get_config(arch).smoke()
mesh = make_host_mesh(model_parallel=mp)  # DIFFERENT mesh than save time
params = init_params(jax.random.key(0), cfg)
pshape = jax.eval_shape(lambda: params)
pspecs = param_specs(cfg, mesh, pshape)
shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                         is_leaf=lambda x: hasattr(x, "_normalized_spec")
                         or type(x).__name__ == "PartitionSpec")
restored, step = ckpt.restore(ckpt_dir, params, shardings=shardings)
leaf = jax.tree.leaves(restored)[0]
print(json.dumps({"step": step, "ok": bool((leaf == leaf).all())}))
"""


def _run(script, *args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", script, *args],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch,mp", [("qwen1.5-0.5b", 2),
                                     ("deepseek-moe-16b", 2),
                                     ("mamba2-2.7b", 4)])
def test_sharded_train_step_8dev(arch, mp, tmp_path):
    res = _run(SCRIPT, arch, str(mp), "")
    assert res["n_devices"] == 8
    assert all(l > 0 and l == l for l in res["losses"])


def test_elastic_restore_across_meshes(tmp_path):
    ckpt_dir = str(tmp_path / "ck")
    _run(SCRIPT, "qwen1.5-0.5b", "2", ckpt_dir)   # save on (4, 2) mesh
    res = _run(RESTORE_SCRIPT, "qwen1.5-0.5b", "4", ckpt_dir)  # load (2, 4)
    assert res["step"] == 3 and res["ok"]
