"""GPipe pipeline over a mesh axis: exactness vs sequential execution."""
import os
import subprocess
import sys

import json
import pytest

pytestmark = pytest.mark.slow  # subprocess + 8-device host mesh

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_apply, bubble_fraction
from repro.parallel.compat import AXIS_TYPE_AUTO, make_mesh

mesh = make_mesh((4,), ("stage",), axis_types=(AXIS_TYPE_AUTO,))
S, n_mb, mb, d = 4, 8, 2, 16
r = np.random.default_rng(0)
W = jnp.asarray(r.standard_normal((S, d, d)).astype(np.float32) * 0.3)
b = jnp.asarray(r.standard_normal((S, d)).astype(np.float32) * 0.1)
x = jnp.asarray(r.standard_normal((n_mb, mb, d)).astype(np.float32))

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

y = pipeline_apply(stage_fn, {"w": W, "b": b}, x, mesh)
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ W[s] + b[s])
err = float(jnp.abs(y - ref).max())

# transformer-layer stages (2 layers per stage, dense smoke config)
from repro.configs.base import get_config
from repro.models import blocks
from repro.models.lm import init_params

cfg = get_config("tinyllama-1.1b").smoke()
# 8 layers stacked -> 4 stages x 2 layers
import dataclasses
cfg8 = dataclasses.replace(cfg, n_layers=8)
params = init_params(jax.random.key(0), cfg8)
blk = params["blocks"]
stage_params = jax.tree.map(
    lambda a: a.reshape((4, 2) + a.shape[1:]), blk)
B, Sq = mb, 8
xx = jnp.asarray(r.standard_normal((n_mb, B, Sq, cfg8.d_model))
                 .astype(np.float32) * 0.1)
pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))

def tf_stage(p, h):
    for i in range(2):
        pi = jax.tree.map(lambda a: a[i], p)
        a, _ = blocks.attn_block(cfg8, pi, h, pos)
        h = h + a
        h = h + blocks.ffn_block(cfg8, pi, h)
    return h

y2 = pipeline_apply(tf_stage, stage_params, xx, mesh)
ref2 = xx.reshape(n_mb * B, Sq, cfg8.d_model)
for li in range(8):
    pi = jax.tree.map(lambda a: a[li], blk)
    pos2 = jnp.broadcast_to(jnp.arange(Sq)[None], (n_mb * B, Sq))
    a, _ = blocks.attn_block(cfg8, pi, ref2, pos2)
    ref2 = ref2 + a
    ref2 = ref2 + blocks.ffn_block(cfg8, pi, ref2)
ref2 = ref2.reshape(n_mb, B, Sq, cfg8.d_model)
err2 = float(jnp.abs(y2 - ref2).max())
print(json.dumps({"err_mlp": err, "err_tf": err2,
                  "bubble": bubble_fraction(n_mb, S)}))
"""


def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err_mlp"] < 1e-5
    assert res["err_tf"] < 1e-3
    assert abs(res["bubble"] - 3 / 11) < 1e-9


def test_bubble_fraction_shrinks_with_microbatches():
    from repro.parallel.pipeline import bubble_fraction

    assert bubble_fraction(32, 4) < bubble_fraction(8, 4)
    assert bubble_fraction(8, 2) < bubble_fraction(8, 4)
