"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, output shapes + finiteness (assignment requirement)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_configs
from repro.data.pipeline import batch_for_step, to_device
from repro.models.lm import forward, init_params
from repro.train.step import TrainConfig, make_train_step

ARCHS = [a for a in list_configs()]

pytestmark = pytest.mark.slow  # model-substrate tier: minutes of CPU


def _extras(cfg, B):
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jnp.ones((B, cfg.n_patches, cfg.d_model),
                                      jnp.float32) * 0.01
    if cfg.family == "encdec":
        kw["encoder_feats"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                       jnp.float32) * 0.01
    return kw


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).smoke()
    params = init_params(jax.random.key(0), cfg)
    B, S = 2, 32
    toks = jnp.asarray((np.arange(B * S).reshape(B, S) % (cfg.vocab - 1)) + 1)
    logits, aux = forward(cfg, params, toks, **_extras(cfg, B))
    S_out = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).smoke()
    params = init_params(jax.random.key(0), cfg)
    step_fn, opt_init = make_train_step(cfg, TrainConfig())
    opt = opt_init(params)
    batch = to_device(batch_for_step(cfg, 32, 2, step=0))
    params, opt, metrics = jax.jit(step_fn)(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    leaf = jax.tree.leaves(params)[0]
    assert bool(jnp.isfinite(leaf).all())
