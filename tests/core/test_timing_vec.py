"""Vectorized timing (PackIR + timing_vec) vs the Python oracle.

The contract is *bit-identity*, not closeness: float64, the oracle's
addition association order, exact max.  Property tests fuzz random packed
circuits across all three canonical archs; the regression test pins
Fig-5/Table-III-feeding numbers to their pre-refactor values.
"""
import numpy as np
import pytest

from repro.core.alm import ARCHS, DD5, make_arch
from repro.core.circuits import kratos_gemm, sha_like, vtr_mixed
from repro.core.netlist import CONST1
from repro.core.packing import pack
from repro.core.timing import analyze, analyze_oracle
from repro.core.timing_vec import build_suite_timing_program

from _hypothesis_shim import given, settings, st
from test_flow import random_netlist


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=5, deadline=None)
def test_vectorized_timing_matches_oracle(seed):
    """numpy-backend analyze() == analyze_oracle(), bit for bit, on
    random packed circuits under every canonical arch (property)."""
    net = random_netlist(seed)
    for arch in ARCHS.values():
        packed = pack(net, arch, seed=seed % 3)
        want = analyze_oracle(packed)
        got = analyze(packed)
        assert got == want, (net.name, arch.name)


def test_jax_program_matches_oracle_batched():
    """The batched lax.scan/vmap program: several circuits stacked on one
    vmap axis, several delay rows on the other — every (circuit, arch)
    critical path bit-identical to the oracle."""
    nets = [random_netlist(3), random_netlist(11),
            kratos_gemm(m=4, n=4, width=4, sparsity=0.5)]
    # same structural class: dd5 and a fan-in-20 variant (delays differ,
    # packs are identical) — the pack-once-retime-many property
    archs = [DD5, make_arch("dd5_f20", bypass_inputs=2, addmux_fanin=20,
                            z_sources=40)]
    assert archs[0].structural_key() == archs[1].structural_key()
    packs = [pack(n, archs[0], seed=0) for n in nets]
    prog = build_suite_timing_program([p.lower_ir() for p in packs])
    cps = prog.run(np.stack([a.delay_table() for a in archs]))
    assert cps.shape == (len(nets), len(archs))
    for g, net in enumerate(nets):
        for k, arch in enumerate(archs):
            want = analyze_oracle(pack(net, arch, seed=0))
            assert cps[g, k] == want["critical_path_ps"], (net.name,
                                                          arch.name)


# (critical_path_ps, alms, area_mwta, adp) pinned pre-refactor (PR 2 HEAD)
_PINS = {
    ("gemm-fu", "baseline"): (8252.089999999997, 397, 2958444.0,
                              24413346147.95999),
    ("gemm-fu", "dd5"): (7996.330000000003, 283, 2187367.6751999995,
                         17490913762.232018),
    ("gemm-fu", "dd6"): (8536.330000000002, 283, 2199599.388,
                         18776506243.76604),
    ("sha", "baseline"): (3213.03, 64, 476928.0, 1532383971.8400002),
    ("sha", "dd5"): (3161.4500000000003, 64, 494669.72159999993,
                     1563873591.35232),
    ("sha", "dd6"): (3341.4500000000003, 64, 497435.904, 1662157201.4208),
    ("or1200-like", "baseline"): (7916.889999999999, 86, 640872.0,
                                  5073713128.08),
    ("or1200-like", "dd5"): (8316.7, 68, 525586.5791999999,
                             4371145903.232639),
    ("or1200-like", "dd6"): (8396.7, 67, 520753.212, 4372608495.2004),
}


@pytest.mark.parametrize("arch_name", ["baseline", "dd5", "dd6"])
def test_regression_pinned_fig5_table3_numbers(arch_name):
    """The figure-feeding metrics must not move across the PackIR
    refactor: vectorized analyze() reproduces the pre-refactor oracle
    values exactly (seed-0 packs of Fig-5/Table-III representatives)."""
    for mk in (lambda: kratos_gemm(m=6, n=6, width=6, sparsity=0.5),
               lambda: sha_like(rounds=1),
               lambda: vtr_mixed(logic_nodes=200, adders=3)):
        net = mk()
        rec = analyze(pack(net, ARCHS[arch_name], seed=0))
        cp, alms, area, adp = _PINS[(net.name, arch_name)]
        assert rec["critical_path_ps"] == cp
        assert rec["alms"] == alms
        assert rec["area_mwta"] == area
        assert rec["adp"] == adp


def test_pack_ir_columns_consistent():
    """PackIR column sanity: per-signal site/LB columns agree with the
    packed object graph, the fanin CSR covers every LUT input and chain
    operand edge, and level tables place each node once."""
    net = random_netlist(7)
    packed = pack(net, DD5, seed=0)
    ir = packed.lower_ir()
    assert ir.n_signals == net.n_signals
    # sites
    for li, out in enumerate(net.lut_out):
        assert ir.sig_site[out] == packed.lut_site.get(li, -2)
    for ci, ch in enumerate(net.chains):
        for bi, s in enumerate(ch.sums):
            assert ir.sig_site[s] == packed.chain_site.get((ci, bi), -2)
    # LB column derives from the site
    for s in range(ir.n_signals):
        site = int(ir.sig_site[s])
        want_lb = packed.alm_lb[site] if site >= 0 else -1
        assert ir.sig_lb[s] == want_lb
    # CSR: every non-const LUT input appears as a fanin edge of its output
    for li, out in enumerate(net.lut_out):
        lo, hi = int(ir.fanin_ptr[out]), int(ir.fanin_ptr[out + 1])
        edges = set(ir.fanin_sig[lo:hi].tolist())
        want = {s for s in net.lut_inputs[li] if s > CONST1}
        assert edges == want
    for ch in net.chains:
        for bi, s in enumerate(ch.sums):
            lo, hi = int(ir.fanin_ptr[s]), int(ir.fanin_ptr[s + 1])
            edges = set(ir.fanin_sig[lo:hi].tolist())
            want = {q for q in (ch.a[bi], ch.b[bi]) if q > CONST1}
            if bi == 0 and ch.cin > CONST1:
                want.add(ch.cin)
            assert edges == want
    # each placed node appears in exactly one level row
    outs = [o for lv in ir.lut_levels for o in lv.out.tolist()]
    assert len(outs) == len(set(outs))
    n_lut_rows = sum(lv.out.shape[0] for lv in ir.lut_levels)
    placed_luts = sum(1 for li in range(net.n_luts)
                      if packed.lut_site.get(li) is not None)
    assert n_lut_rows == placed_luts
    assert sum(lv.cout.shape[0] for lv in ir.chain_levels) == len(net.chains)


def test_timing_wall_accounting():
    from repro.core import timing

    timing.reset_timing_wall()
    net = random_netlist(1)
    analyze(pack(net, ARCHS["baseline"], seed=0))
    w = timing.read_timing_wall()
    assert w["calls"] == 1 and w["s"] > 0.0
