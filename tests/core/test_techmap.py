"""The ABC-lite mapper must preserve function and reduce LUT count."""
import random

from repro.core.netlist import Netlist, bus_to_ints, eval_netlist
from repro.core.synth import synth_var_mult
from repro.core.techmap import techmap

NV = 16


def test_techmap_preserves_function_and_shrinks():
    rng = random.Random(11)
    net = Netlist()
    x = net.add_pi_bus("x", 6)
    y = net.add_pi_bus("y", 6)
    out = synth_var_mult(net, x, y, algo="wallace", signed=False, out_width=12)
    net.set_po_bus("p", out)
    mapped = techmap(net.sweep())
    assert mapped.n_luts < net.n_luts
    xs = [rng.getrandbits(6) for _ in range(NV)]
    ys = [rng.getrandbits(6) for _ in range(NV)]

    def drive(n):
        vals = {}
        for j, s in enumerate(n.pi_buses.get("x", x)):
            vals[s] = sum(((xs[v] >> j) & 1) << v for v in range(NV))
        for j, s in enumerate(n.pi_buses.get("y", y)):
            vals[s] = sum(((ys[v] >> j) & 1) << v for v in range(NV))
        return vals

    a = bus_to_ints(eval_netlist(net, drive(net), NV), out, NV)
    b = bus_to_ints(eval_netlist(mapped, drive(mapped), NV),
                    mapped.pos["p"], NV)
    assert a == b


def test_techmap_respects_max_k():
    rng = random.Random(5)
    net = Netlist()
    ins = net.add_pi_bus("i", 12)
    prev = list(ins)
    for _ in range(40):
        sel = tuple(rng.sample(prev, 3))
        prev.append(net.add_lut(sel, rng.getrandbits(8)))
    net.set_po_bus("o", prev[-4:])
    mapped = techmap(net.sweep(), max_k=6)
    assert all(len(i) <= 6 for i in mapped.lut_inputs)
    mapped5 = techmap(net.sweep(), max_k=5)
    assert all(len(i) <= 5 for i in mapped5.lut_inputs)
