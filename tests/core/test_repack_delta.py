"""Dirty-set incremental repack: the structural-edit contract.

Three layers of the delta path under test:

* ``cluster_delta`` attribution — frozen / moved / re-clustered must
  partition the surviving clusters correctly, including the pure-swap
  case (same membership, renumbered LBs) that a positional diff would
  misreport as re-clustering;
* byte-identity — for a stream of random structural edits (fanin
  rewires, truth-table flips, LUT adds/removes, chain extensions),
  every edit the delta path accepts must produce a pack identical field
  for field to a fresh ``pack()`` of the edited netlist, whichever mode
  (incremental / fallback / full) the engine picked; shape-changing
  edits must be *rejected* at the prefix gate, never mis-served;
* scoped verification — ``verify_clusters`` over the touched LBs must
  agree with the full-circuit symbolic report on every delta-packed
  result.
"""
import copy
import random

import pytest

from repro.core.alm import ARCHS
from repro.core.circuits import kratos_gemm, sha_like
from repro.core.edits import (clone_netlist, edit_add_lut,
                              edit_extend_chain, edit_lut_tt,
                              edit_remove_lut, edit_rewire_fanin,
                              safe_rewire_sources)
from repro.core.equiv import (reelaborate, symbolic_equivalence_report,
                              verify_clusters)
from repro.core.packing import pack
from repro.core.repack import (cluster_delta, netlist_structural_diff,
                               pack_prefix, pack_prefix_delta,
                               repack_delta, repack_with_log)

from test_repack import _assert_same_pack


def _alm_sig(packed, ai):
    alm = packed.alms[ai]
    return tuple((h.fa, h.fa_feed, tuple(h.absorbed), h.hosted_lut)
                 for h in alm.halves) + (alm.is_arith, alm.lut6)


def _lb_sig(packed, lbi):
    return tuple(sorted((_alm_sig(packed, ai)
                         for ai in packed.lbs[lbi].alms), key=repr))


def test_cluster_delta_identity():
    packed = pack(sha_like(rounds=1), ARCHS["dd5"], seed=0)
    d = cluster_delta(packed, packed)
    assert d["n_changed"] == 0 and d["n_reclustered"] == 0
    assert d["n_frozen"] == d["n_lbs_base"] == d["n_lbs_new"]
    assert d["n_moved"] == 0 and d["unchanged_frac"] == 1.0


def test_cluster_delta_pure_swap_reports_moved_not_reclustered():
    """Renumbering two clusters (identical membership, swapped LB
    indices) is a *move*, never a re-cluster — the distinction the serve
    attribution exposes as ``n_moved`` vs ``n_reclustered``."""
    packed = pack(sha_like(rounds=1), ARCHS["dd5"], seed=0)
    n = len(packed.lbs)
    assert n >= 2
    # first pair of LBs with distinct signatures (a swap of two
    # identical clusters would be invisible, correctly reported frozen)
    i, j = next((i, j) for i in range(n) for j in range(i + 1, n)
                if _lb_sig(packed, i) != _lb_sig(packed, j))
    swapped = copy.copy(packed)
    swapped.lbs = list(packed.lbs)
    swapped.lbs[i], swapped.lbs[j] = packed.lbs[j], packed.lbs[i]
    d = cluster_delta(packed, swapped)
    assert d["n_moved"] == 2
    assert d["n_frozen"] == n - 2
    assert d["n_reclustered"] == 0 and d["n_changed"] == 0
    assert d["unchanged_frac"] == 1.0


def _random_edit(net, rng):
    """One random structural edit on a clone of ``net``; returns
    ``(new_net, kind)``.  Kinds cover every ``edits`` op; add/remove/
    extend change the netlist shape and must be rejected by the prefix
    gate."""
    kind = rng.choice(("rewire", "rewire", "tt", "add", "extend"))
    new_net = clone_netlist(net)
    if kind == "rewire":
        for _ in range(20):
            li = rng.randrange(net.n_luts)
            srcs = safe_rewire_sources(net, li)
            if not srcs:
                continue
            pin = rng.randrange(len(net.lut_inputs[li]))
            src = rng.choice(srcs)
            if net.lut_inputs[li][pin] != src:
                edit_rewire_fanin(new_net, li, pin, src)
                return new_net, kind
        return None, kind
    if kind == "tt":
        li = rng.randrange(net.n_luts)
        tt = rng.getrandbits(1 << len(net.lut_inputs[li]))
        if tt == net.lut_tt[li]:
            tt ^= 1
        edit_lut_tt(new_net, li, tt)
        return new_net, kind
    if kind == "add":
        ins = tuple(rng.sample(net.pis, min(3, len(net.pis))))
        edit_add_lut(new_net, ins, rng.getrandbits(1 << len(ins)))
        return new_net, kind
    # extend: grow the first chain by a PI-fed bit
    if not net.chains:
        return None, kind
    a, b = rng.sample(net.pis, 2)
    edit_extend_chain(new_net, 0, a, b)
    return new_net, kind


@pytest.mark.parametrize("arch_name", ["baseline", "dd5", "dd6"])
def test_edit_stream_byte_identity_and_scoped_verify(arch_name):
    """Property fuzz: random structural edits streamed against one base
    prefix+log.  Every delta-served pack must equal a fresh ``pack()``
    of the edited netlist exactly, whatever mode the engine picked, and
    the scoped per-cluster proof must agree with the full symbolic
    report.  Shape-changing edits must be refused at the prefix gate."""
    arch = ARCHS[arch_name]
    net = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    prefix = pack_prefix(net, seed=0)
    base_pack, log = repack_with_log(prefix, arch)
    _assert_same_pack(base_pack, pack(net, arch, seed=0))

    # str hash is process-randomized — seed from the bytes, not hash()
    rng = random.Random(int.from_bytes(arch_name.encode(), "big"))
    n_checked = 0
    modes = set()
    for _ in range(12):
        new_net, kind = _random_edit(net, rng)
        if new_net is None:
            continue
        diff = netlist_structural_diff(net, new_net)
        new_prefix, pinfo = pack_prefix_delta(prefix, new_net,
                                              base_log=log, diff=diff)
        if kind in ("add", "extend"):
            # shape-changing edits: the structural diff and the prefix
            # gate must both refuse — these go through the full path
            assert diff is None
            assert new_prefix is None and pinfo["reason"] == "shape"
            continue
        if new_prefix is None:
            # absorbed-edit / absorption / pairing gates may legally
            # refuse a rewire; the serve layer then takes the full path
            assert pinfo["reason"] in ("absorbed_edit", "absorption",
                                       "pairing")
            continue
        dpack, rinfo = repack_delta(
            new_prefix, log, arch,
            dirty_atoms=pinfo.get("dirty_atoms", frozenset()))
        modes.add(rinfo["mode"])
        _assert_same_pack(dpack, pack(new_net, arch, seed=0))
        # scoped proof over touched LBs == full-circuit verdict
        touched = set(rinfo.get("div_lbs", ()))
        for li in list(diff["changed_inputs"]) + list(diff["changed_tt"]):
            site = dpack.lut_site.get(li)
            if site is not None:
                touched.add(int(dpack.alm_lb[site]))
        re_elab = reelaborate(dpack)
        scoped = verify_clusters(dpack, sorted(touched), re_elab=re_elab)
        full = symbolic_equivalence_report(new_net, re_elab)
        assert scoped["equivalent"] == full["equivalent"] is True
        n_checked += 1
    assert n_checked >= 3, f"edit stream degenerate: {n_checked} checked"
    assert "incremental" in modes or "fallback" in modes


def test_shape_edit_remove_refused():
    """``edit_remove_lut`` renumbers LUT indices — the diff must report
    a shape change and the prefix gate must refuse."""
    net = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    prefix = pack_prefix(net, seed=0)
    _, log = repack_with_log(prefix, ARCHS["dd5"])
    # a LUT with no consumers anywhere: append a dead one, then drop it
    new_net = clone_netlist(net)
    ins = tuple(net.pis[:2])
    li = edit_add_lut(new_net, ins, 0b0110, po_bus="__dead")
    del new_net.pos["__dead"]
    edit_remove_lut(new_net, li)
    # adding+removing restored the LUT count but burned a signal id
    assert netlist_structural_diff(net, new_net) is None
    got, pinfo = pack_prefix_delta(prefix, new_net, base_log=log)
    assert got is None and pinfo["reason"] == "shape"
