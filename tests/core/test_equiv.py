"""Property-style pack() equivalence: random netlists (LUT clouds + carry
chains) packed under baseline/DD5/DD6 must re-elaborate to functionally
equivalent physical circuits — the gate behind every area figure."""
import random

import pytest

from repro.core.alm import ARCHS, BASELINE, DD5, DD6
from repro.core.circuits import kratos_conv1d, kratos_gemm, sha_like
from repro.core.equiv import (EXHAUSTIVE_MAX_SUPPORT, ReElaborationError,
                              assert_equivalent, check_pack_equivalence,
                              equivalence_report, exhaustive_residue_report,
                              reelaborate, symbolic_equivalence_report,
                              verify_all_archs)
from repro.core.netlist import CONST0, CONST1, Netlist
from repro.core.packing import pack


def random_netlist(seed: int) -> Netlist:
    """LUT cloud + carry chains + post-chain logic, sized for fast packs."""
    rng = random.Random(seed)
    net = Netlist(f"rand{seed}")
    pool = list(net.add_pi_bus("in", rng.randint(8, 16)))
    for _ in range(rng.randint(10, 35)):
        k = rng.randint(1, 6)
        ins = rng.sample(pool, min(k, len(pool)))
        o = net.add_lut(tuple(ins), rng.getrandbits(1 << len(ins)))
        pool.append(o)
    for c in range(rng.randint(1, 4)):
        w = rng.randint(2, 12)
        a = [rng.choice(pool) for _ in range(w)]
        b = [rng.choice(pool) for _ in range(w)]
        cin = rng.choice([CONST0, CONST1, rng.choice(pool)])
        sums, cout = net.add_chain(a, b, cin=cin,
                                   want_cout=rng.random() < 0.5)
        pool.extend(sums)
        net.set_po_bus(f"s{c}", sums)
        if cout is not None:
            net.set_po_bus(f"c{c}", [cout])
    for i in range(rng.randint(5, 15)):
        k = rng.randint(2, 5)
        ins = rng.sample(pool, min(k, len(pool)))
        pool.append(net.add_lut(tuple(ins), rng.getrandbits(1 << len(ins))))
    net.set_po_bus("po", pool[-min(8, len(pool)):])
    return net.sweep()


@pytest.mark.parametrize("arch_name", ["baseline", "dd5", "dd6"])
@pytest.mark.parametrize("seed", range(20))
def test_random_circuits_pack_equivalent(seed, arch_name):
    net = random_netlist(seed)
    rep = check_pack_equivalence(net, ARCHS[arch_name], n_vectors=64,
                                 seed=seed)
    assert rep["equivalent"], rep["mismatches"]


@pytest.mark.parametrize("mk", [
    lambda: kratos_gemm(m=4, n=4, width=5, sparsity=0.5),
    lambda: kratos_conv1d(in_ch=2, out_ch=3, n_pos=2, width=4),
    lambda: sha_like(rounds=1),
])
def test_kratos_style_circuits_equivalent_all_archs(mk):
    net = mk()
    for arch_name, rep in verify_all_archs(net, n_vectors=64).items():
        assert rep["equivalent"], (arch_name, rep["mismatches"])


def test_z_feed_conversion_regression():
    """DD5 must actually convert FA feeds to Z pins (``fa_feed == "z"``) on
    the adder+LUT mix — and stay equivalent through the conversion."""
    net = random_netlist(3)
    packed = pack(net, DD5, seed=0)
    z_bits = sum(1 for alm in packed.alms for h in alm.halves
                 if h.fa is not None and h.fa_feed == "z")
    assert z_bits > 0, "regression: DD5 pack no longer exercises Z feeds"
    assert_equivalent(net, reelaborate(packed), n_vectors=128)
    # baseline must never Z-convert (the paper's structural premise)
    p0 = pack(net, BASELINE, seed=0)
    assert all(h.fa_feed != "z" for alm in p0.alms for h in alm.halves)


def test_absorbed_luts_recomposed():
    """Chains fed by fanout-1 LUTs absorb them; the re-elaboration must
    re-compose those masks (not bypass them) to stay equivalent."""
    net = Netlist("absorb")
    xs = net.add_pi_bus("x", 8)
    ys = net.add_pi_bus("y", 8)
    from repro.core.netlist import TT_AND2, TT_XOR2

    a = [net.add_lut((xs[i], ys[i]), TT_AND2) for i in range(8)]
    b = [net.add_lut((xs[i], ys[(i + 1) % 8]), TT_XOR2) for i in range(8)]
    sums, cout = net.add_chain(a, b, want_cout=True)
    net.set_po_bus("s", sums + [cout])
    for arch in (BASELINE, DD5, DD6):
        packed = pack(net, arch, seed=0)
        absorbed = sum(len(h.absorbed) for alm in packed.alms
                       for h in alm.halves)
        assert absorbed > 0, arch.name
        re_elab = reelaborate(packed)
        assert_equivalent(net, re_elab, n_vectors=256)
        assert "absorbed" in re_elab.lut_role.values()


def test_checker_detects_corruption():
    """The proof must have teeth: a single flipped truth-table bit in the
    physical netlist must be reported as non-equivalent."""
    net = random_netlist(7)
    packed = pack(net, DD5, seed=0)
    re_elab = reelaborate(packed)
    assert equivalence_report(net, re_elab, n_vectors=128)["equivalent"]
    assert re_elab.phys.n_luts > 0
    re_elab.phys.lut_tt[0] ^= 1 << 1
    rep = equivalence_report(net, re_elab, n_vectors=128)
    assert not rep["equivalent"]
    assert rep["mismatches"], "mismatch must localize to a signal"


def test_structural_corruption_raises():
    """Z-feeding a half that carries absorbed LUTs is physically
    unrealizable — re-elaboration must refuse, not paper over it."""
    net = random_netlist(11)
    packed = pack(net, DD5, seed=0)
    for alm in packed.alms:
        for h in alm.halves:
            if h.fa is not None and h.absorbed and h.fa_feed == "lut":
                h.fa_feed = "z"
                with pytest.raises(ReElaborationError):
                    reelaborate(packed)
                return
    pytest.skip("no absorbed half in this pack")


@pytest.mark.parametrize("arch_name", ["baseline", "dd5", "dd6"])
@pytest.mark.parametrize("seed", range(8))
def test_symbolic_fast_path_proves_packs(seed, arch_name):
    """The per-ALM symbolic check must close real packs without
    simulating a single vector — and agree with the lane-simulation
    proof."""
    net = random_netlist(seed)
    packed = pack(net, ARCHS[arch_name], seed=seed)
    re_elab = reelaborate(packed)
    srep = symbolic_equivalence_report(net, re_elab)
    assert srep["equivalent"], (srep["mismatches"], srep["fallback"])
    assert srep["complete"]
    assert srep["proven_luts"] + srep["proven_chain_bits"] > 0
    # cross-check against the simulation oracle
    assert equivalence_report(net, re_elab, n_vectors=64)["equivalent"]


def test_symbolic_localizes_mask_corruption():
    """A flipped truth-table bit must be caught *and named* symbolically,
    with no simulation."""
    net = random_netlist(7)
    re_elab = reelaborate(pack(net, DD5, seed=0))
    assert symbolic_equivalence_report(net, re_elab)["equivalent"]
    assert re_elab.phys.n_luts > 0
    re_elab.phys.lut_tt[0] ^= 1 << 1
    srep = symbolic_equivalence_report(net, re_elab)
    assert not srep["equivalent"]
    assert srep["mismatches"], "corruption must localize to a node"
    # the auto gate falls back to simulation for the authoritative verdict
    # and keeps the symbolic localization
    rep = equivalence_report(net, re_elab, n_vectors=128)
    assert not rep["equivalent"]


def test_check_pack_equivalence_uses_symbolic_fast_path():
    """`method="auto"` must prove healthy packs symbolically (the report
    says so) and `method="simulate"` must still be available."""
    net = random_netlist(4)
    rep = check_pack_equivalence(net, DD5, n_vectors=64)
    assert rep["equivalent"]
    assert rep["method"] == "symbolic"
    rep2 = check_pack_equivalence(net, DD5, n_vectors=64, method="simulate")
    assert rep2["equivalent"]
    assert rep2["method"] == "simulate"


def _wide_chain_netlist(n_bits=4, n_pis=12, seed=0):
    """Chain whose operands are fanout-2 4-LUTs (no absorption), so every
    bit's composed cone support exceeds 6 inputs but stays <= n_pis."""
    rng = random.Random(seed)
    net = Netlist("wide")
    ins = net.add_pi_bus("in", n_pis)
    a_ops, b_ops = [], []
    for i in range(n_bits):
        la = net.add_lut(tuple(rng.sample(ins, 4)), rng.getrandbits(16))
        lb = net.add_lut(tuple(rng.sample(ins, 4)), rng.getrandbits(16))
        a_ops.append(la)
        b_ops.append(lb)
        net.set_po_bus(f"keep{i}", [la, lb])   # fanout > 1 -> no absorption
    sums, cout = net.add_chain(a_ops, b_ops, want_cout=True)
    net.set_po_bus("s", sums)
    net.set_po_bus("c", [cout])
    return net


@pytest.mark.parametrize("arch_name", ["baseline", "dd5"])
def test_exhaustive_residue_closes_all_narrow_cones(arch_name):
    """Full-truth-table closure: every node of a real pack (forced into
    the residue list) is proven over ALL 2^W support assignments — an
    exhaustive proof, where the old path sampled random lanes."""
    net = _wide_chain_netlist()
    re_elab = reelaborate(pack(net, ARCHS[arch_name], seed=0))
    residue = [("lut", i) for i in range(net.n_luts)] \
        + [("chain", i) for i in range(len(net.chains))]
    rep = exhaustive_residue_report(net, re_elab, residue)
    assert rep["proven_cones"] == len(residue)
    assert not rep["unclosed"] and not rep["mismatches"]


def test_exhaustive_residue_closes_per_bit_entries():
    """Per-bit residue entries — the shape symbolic fallback actually
    emits for wide cones — must close too: the cone ripples only as deep
    as the requested bit, so later bits' out-of-support operands don't
    abort the proof (regression)."""
    net = _wide_chain_netlist()
    re_elab = reelaborate(pack(net, DD5, seed=0))
    n_bits = len(net.chains[0].sums)
    residue = [("chain", 0, bi) for bi in range(n_bits)]
    rep = exhaustive_residue_report(net, re_elab, residue)
    assert rep["proven_cones"] == n_bits, (rep["unclosed"],
                                           rep["mismatches"])


def test_exhaustive_residue_detects_corruption():
    net = _wide_chain_netlist(seed=3)
    re_elab = reelaborate(pack(net, DD5, seed=0))
    re_elab.phys.lut_tt[0] ^= 1
    residue = [("lut", i) for i in range(net.n_luts)]
    rep = exhaustive_residue_report(net, re_elab, residue)
    assert rep["mismatches"], "a flipped mask bit must fail exhaustively"
    assert rep["mismatches"][0]["signal"] is not None


def test_exhaustive_residue_leaves_wide_cones_open():
    """Cones wider than EXHAUSTIVE_MAX_SUPPORT stay unclosed (the
    remaining SAT-shaped gap is wide cones only)."""
    net = _wide_chain_netlist(n_bits=10, n_pis=EXHAUSTIVE_MAX_SUPPORT + 8,
                              seed=5)
    re_elab = reelaborate(pack(net, DD5, seed=0))
    rep = exhaustive_residue_report(net, re_elab,
                                    [("chain", 0)], max_support=8)
    assert rep["unclosed"] == [("chain", 0)]
    assert rep["proven_cones"] == 0


def test_auto_gate_closes_residue_exhaustively(monkeypatch):
    """When the symbolic pass leaves narrow residue cones, the auto gate
    must close them by enumeration (method "symbolic+exhaustive"), not
    drop to random-lane simulation."""
    import repro.core.equiv as eq

    net = _wide_chain_netlist(seed=1)
    real_sym = eq.symbolic_equivalence_report

    def leaky(src, re_elab):
        rep = real_sym(src, re_elab)
        rep["fallback"] = rep["fallback"] + [("chain", 0)]
        rep["equivalent"] = False
        rep["complete"] = False
        return rep

    monkeypatch.setattr(eq, "symbolic_equivalence_report", leaky)
    rep = eq.check_pack_equivalence(net, DD5, seed=0)
    assert rep["equivalent"]
    assert rep["method"] == "symbolic+exhaustive"
    assert rep["exhaustive_proven"] == 1


def test_equivalence_via_fused_jax_engine():
    """The checker's JAX path (fused evaluator both sides) must agree with
    the python-oracle path."""
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    for arch_name in ("baseline", "dd5"):
        rep = check_pack_equivalence(net, ARCHS[arch_name], n_vectors=64,
                                     use_jax=True)
        assert rep["equivalent"], (arch_name, rep["mismatches"])
