"""Successive-halving search driver: determinism, budget accounting,
survivor selection, the registry-backed caches, and the subset-aware
frontier satellite.

The driver's contract is *reproducibility*: the rung schedule, circuit
subsets, survivor sets and the recorded payload are pure functions of
``(nets, archs, seed, eta, budget)`` — walls are the only nondeterminism
and live in clearly marked keys.
"""
import copy

import pytest

from repro.core.alm import ARCHS, full_arch_grid, make_arch, subgrid
from repro.core.circuits import kratos_gemm, sha_like, vtr_mixed
from repro.core.plan import cache_stats, clear_caches
from repro.core.search import (circuit_schedule, pareto_front, search_archs,
                               select_survivors, verify_winners)
from repro.core.sweep import adp_frontier, sweep_suite


def _nets():
    return [kratos_gemm(m=4, n=4, width=4, sparsity=0.5),
            sha_like(rounds=1),
            vtr_mixed(logic_nodes=150, adders=2)]


def _grid(n=12):
    return subgrid(full_arch_grid(), n)


def _stable_payload(payload: dict) -> dict:
    """The deterministic part of a search payload (walls dropped)."""
    p = copy.deepcopy(payload)
    for r in p["rungs"]:
        r.pop("walls")
    return p


def test_full_grid_spans_a_thousand_classes():
    from repro.core.alm import group_archs_by_structure

    grid = full_arch_grid()
    names = [a.name for a in grid]
    assert len(names) == len(set(names))
    assert len(grid) >= 1000
    assert len(group_archs_by_structure(grid)) >= 1000
    # the canonical paper rows are grid members under their grid names
    assert {"b0", "b2_f10", "b2_f10_l6"} <= set(names)


def test_circuit_schedule_nested_and_smallest_first():
    nets = _nets()
    subsets = circuit_schedule(nets, n_rungs=3, min_circuits=1)
    assert [len(s) for s in subsets] == [1, 2, 3]
    sizes = [n.n_luts + n.n_adders for n in subsets[-1]]
    assert sizes == sorted(sizes)
    for a, b in zip(subsets, subsets[1:]):       # nested prefixes
        assert [n.name for n in a] == [n.name for n in b][:len(a)]


def test_pareto_front_and_selection():
    rows = [
        {"arch": "a", "area_mwta": 1.0, "critical_path_ps": 1.0,
         "adp": 1.00},
        {"arch": "b", "area_mwta": 0.9, "critical_path_ps": 1.2,
         "adp": 1.08},                            # front: best area
        {"arch": "c", "area_mwta": 1.1, "critical_path_ps": 0.9,
         "adp": 0.99},                            # front: best delay
        {"arch": "d", "area_mwta": 1.2, "critical_path_ps": 1.3,
         "adp": 1.56},                            # dominated by a
    ]
    front = [r["arch"] for r in pareto_front(rows)]
    assert front == ["c", "a", "b"]              # (adp, name) order
    assert "d" not in front
    # halving: the front always survives, fill to k by adp
    assert select_survivors(rows, k=2, allocation="halving") == \
        ["a", "b", "c"]
    # bandit widens by the optimism band but stays deterministic
    s1 = select_survivors(rows, k=2, allocation="bandit", n_circuits=3)
    s2 = select_survivors(rows, k=2, allocation="bandit", n_circuits=3)
    assert s1 == s2 and set(front) <= set(s1)
    with pytest.raises(ValueError, match="allocation"):
        select_survivors(rows, k=2, allocation="ucb")


def test_search_deterministic_payload():
    """Same seed + budget (fresh netlist objects, fresh caches) →
    identical survivor sets and identical payload modulo walls."""
    grid = _grid()
    clear_caches()
    r1 = search_archs(_nets(), grid, seed=0, min_survivors=3,
                      min_circuits=2, baseline="b0", packs={}, programs={})
    clear_caches()
    r2 = search_archs(_nets(), grid, seed=0, min_survivors=3,
                      min_circuits=2, baseline="b0", packs={}, programs={})
    assert r1.survivor_trajectory() == r2.survivor_trajectory()
    assert _stable_payload(r1.payload()) == _stable_payload(r2.payload())
    assert r1.winner == r2.winner
    # every rung reports the full wall split schema
    for rung in r1.rungs:
        assert set(rung["walls"]) == {"pack_s", "prefix_s", "recluster_s",
                                      "lower_s", "place_s", "anneal_s",
                                      "time_s", "eval_s"}


def test_search_budget_ledger():
    """The budget is a hard cap on (circuit x arch) evaluations: rungs
    are trimmed to fit and the ledger records what was spent."""
    grid = _grid()
    nets = _nets()
    free = search_archs(nets, grid, seed=0, min_survivors=3,
                        min_circuits=2, baseline="b0",
                        packs={}, programs={})
    capped = search_archs(nets, grid, seed=0, min_survivors=3,
                          min_circuits=2, baseline="b0", packs={},
                          programs={}, budget=len(grid) * 2)
    assert capped.budget["requested"] == len(grid) * 2
    assert capped.budget["used"] <= capped.budget["requested"]
    assert len(capped.rungs) <= len(free.rungs)
    with pytest.raises(ValueError, match="budget"):
        search_archs(nets, grid, seed=0, min_circuits=2, baseline="b0",
                     packs={}, programs={}, budget=1)


def test_search_winner_verified():
    """The promoted winner is oracle-bit-identical and equivalence-gated
    — the honesty gate the recorded frontier rests on."""
    grid = _grid(8)
    nets = _nets()
    res = search_archs(nets, grid, seed=0, min_survivors=2,
                       min_circuits=2, baseline="b0",
                       packs={}, programs={})
    rep = verify_winners(res, nets, grid, seed=0, n_equiv_circuits=1,
                         winners=[res.winner])
    assert rep["oracle_match"] and rep["equivalent"]
    assert rep["mismatches"] == []


def test_search_placed_wire_axis_smoke():
    """`search_archs(place=True)` is a supported mode: a 2-rung search
    over a wire-delay subgrid completes with the promoted winner placed-
    oracle-parity-gated, bills annealing wall into the rung ledger, and
    the ``_w{n}`` wire rows — bit-for-bit ties in an unplaced sweep —
    become distinct grid points under annealed placements."""
    from repro.core.alm import arch_grid

    grid = arch_grid(bypass_inputs=(0, 2), addmux_fanin=(10,),
                     lut6=(False,),
                     wire_delays=((0.0, 0.0, 0.0), (25.0, 40.0, 120.0)))
    assert {"b0", "b0_w25", "b2_f10", "b2_f10_w25"} == \
        {a.name for a in grid}
    nets = _nets()
    clear_caches()
    res = search_archs(nets, grid, seed=0, eta=2, min_survivors=2,
                       min_circuits=2, baseline="b0", place=True,
                       packs={}, programs={})
    assert len(res.rungs) == 2
    # annealing wall is attributed in the ledger (cold first rung must
    # have actually annealed; later rungs may be pure cache hits)
    assert res.rungs[0]["walls"]["anneal_s"] > 0.0
    assert all("anneal_s" in r["walls"] for r in res.rungs)
    rep = verify_winners(res, nets, grid, seed=0, n_equiv_circuits=1,
                         winners=[res.winner], place=True)
    assert rep["oracle_match"] and rep["equivalent"]
    assert rep["mismatches"] == []
    # wire rows tie bit-for-bit unplaced, and stop tying once placed
    flat = sweep_suite(nets, grid, backend="numpy", place=False,
                       packs={}, programs={}, prefixes={})
    placed = sweep_suite(nets, grid, backend="numpy", place=True,
                         packs={}, programs={}, prefixes={})
    for base, wired in (("b0", "b0_w25"), ("b2_f10", "b2_f10_w25")):
        flat_cps = [(a["critical_path_ps"], b["critical_path_ps"])
                    for a, b in zip(flat.by_arch(base),
                                    flat.by_arch(wired))]
        assert all(a == b for a, b in flat_cps)
        placed_cps = [(a["critical_path_ps"], b["critical_path_ps"])
                      for a, b in zip(placed.by_arch(base),
                                      placed.by_arch(wired))]
        assert any(a != b for a, b in placed_cps)
        assert all(a <= b for a, b in placed_cps)  # wire delay only adds


def test_search_baseline_must_be_in_grid():
    with pytest.raises(ValueError, match="baseline"):
        search_archs(_nets(), _grid(8), baseline="nope")


def test_adp_frontier_circuit_subset():
    """Rung-level and full-suite frontiers share one code path: the
    ``circuits`` subset argument; unknown names raise a clear error."""
    nets = _nets()
    grid = [ARCHS["baseline"], ARCHS["dd5"],
            make_arch("dd5_a8", bypass_inputs=2, alms_per_lb=8)]
    res = sweep_suite(nets, grid, backend="numpy",
                      packs={}, programs={}, prefixes={})
    sub_names = [nets[0].name]
    rows_sub = adp_frontier(res, baseline="baseline", circuits=sub_names)
    # equals the frontier of a sweep over only that circuit
    res_only = sweep_suite([nets[0]], grid, backend="numpy",
                           packs={}, programs={}, prefixes={})
    rows_only = adp_frontier(res_only, baseline="baseline")
    assert rows_sub == rows_only
    with pytest.raises(ValueError, match="no_such_circuit"):
        adp_frontier(res, baseline="baseline",
                     circuits=["no_such_circuit"])
    with pytest.raises(ValueError, match="no_such_arch"):
        res.by_arch("no_such_arch")


def test_prefix_and_search_caches_registered():
    """Regression mirroring the PR-6 placement-cache fix: the default
    ``sweep_suite`` prefix store and the search driver's rung caches
    live in the plan registry, so ONE ``clear_caches()`` provably drops
    them — a 'cleared' state must rebuild, never serve a stale prefix or
    pack."""
    clear_caches()
    nets = [kratos_gemm(m=4, n=4, width=4, sparsity=0.5)]
    grid = [ARCHS["baseline"], ARCHS["dd5"]]
    res1 = sweep_suite(nets, grid, backend="numpy")    # default stores
    assert cache_stats()["pack_prefix"]["size"] == 1
    res2 = search_archs(nets, grid, seed=0, min_circuits=1,
                        baseline="baseline")           # default stores
    assert cache_stats()["search_packs"]["size"] >= 2
    clear_caches()
    assert cache_stats()["pack_prefix"]["size"] == 0
    assert cache_stats()["search_packs"]["size"] == 0
    # rebuilt-from-scratch results are identical in value (no stale
    # reuse, no loss either)
    res1b = sweep_suite(nets, grid, backend="numpy")
    for g in range(len(nets)):
        for k in range(len(grid)):
            assert (res1.records[g][k]["critical_path_ps"]
                    == res1b.records[g][k]["critical_path_ps"])
    res2b = search_archs(nets, grid, seed=0, min_circuits=1,
                         baseline="baseline")
    assert _stable_payload(res2.payload()) == _stable_payload(
        res2b.payload())
