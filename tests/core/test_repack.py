"""Incremental repacking engine: prefix/re-cluster equivalence to full
``pack()`` across the structural grid, incremental ``lower_ir`` parity,
and byte-stability pins on the canonical archs.

The contract is *identity*, not closeness: ``repack(pack_prefix(net,
seed), arch)`` must reproduce ``pack(net, arch, seed)`` exactly (same
ALM graph, same sites, same oracle timing record), and the incremental
IR patch must equal a fresh lowering array for array.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.alm import ARCHS, arch_grid, make_arch
from repro.core.circuits import kratos_gemm, sha_like, vtr_mixed
from repro.core.equiv import check_pack_equivalence
from repro.core.pack_ir import (PackIR, lower_pack_ir,
                                lower_pack_ir_incremental)
from repro.core.packing import pack
from repro.core.repack import pack_prefix, repack
from repro.core.timing import analyze_oracle

from test_flow import random_netlist

#: a small grid that exercises every structural axis (bypass width,
#: LB capacity, LB inputs, pin utilization) — each point is its own
#: structural class
STRUCT_GRID = [
    ARCHS["baseline"],
    ARCHS["dd5"],
    ARCHS["dd6"],
    make_arch("dd5_a8", bypass_inputs=2, alms_per_lb=8),
    make_arch("dd5_i48", bypass_inputs=2, lb_inputs=48),
    make_arch("b0_a8_u70", bypass_inputs=0, alms_per_lb=8,
              ext_pin_util=0.7),
]


def _assert_same_pack(a, b):
    """Structural identity of two PackedCircuits (same object graph)."""
    assert a.n_alms == b.n_alms and a.n_lbs == b.n_lbs
    assert a.concurrent_luts == b.concurrent_luts
    assert a.lut_site == b.lut_site
    assert a.chain_site == b.chain_site
    assert a.alm_lb == b.alm_lb
    for x, y in zip(a.alms, b.alms):
        assert x.lut6 == y.lut6 and x.is_arith == y.is_arith
        for hx, hy in zip(x.halves, y.halves):
            assert hx.fa == hy.fa and hx.fa_feed == hy.fa_feed
            assert hx.absorbed == hy.absorbed
            assert hx.hosted_lut == hy.hosted_lut
    assert [lb.alms for lb in a.lbs] == [lb.alms for lb in b.lbs]


def _assert_same_ir(a: PackIR, b: PackIR):
    for f in dataclasses.fields(PackIR):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if f.name in ("lut_levels", "chain_levels"):
            assert len(va) == len(vb)
            for x, y in zip(va, vb):
                for g in dataclasses.fields(type(x)):
                    assert np.array_equal(getattr(x, g.name),
                                          getattr(y, g.name)), \
                        (f.name, g.name)
        elif isinstance(va, np.ndarray):
            assert np.array_equal(va, vb), f.name
        else:
            assert va == vb, f.name


@pytest.mark.parametrize("seed", [0, 1])
def test_repack_equals_full_pack_across_structural_grid(seed):
    """One prefix, re-clustered under every structural grid point, must
    equal a from-scratch ``pack()`` — the invariant the sweep engine's
    prefix sharing rests on.  Every repacked circuit is also
    equivalence-gated against its source netlist."""
    for mk in (lambda: kratos_gemm(m=4, n=4, width=4, sparsity=0.5),
               lambda: sha_like(rounds=1)):
        net = mk()
        prefix = pack_prefix(net, seed=seed)
        for arch in STRUCT_GRID:
            full = pack(net, arch, seed=seed)
            inc = repack(prefix, arch)
            _assert_same_pack(full, inc)
            assert (analyze_oracle(full)["critical_path_ps"]
                    == analyze_oracle(inc)["critical_path_ps"])
        rep = check_pack_equivalence(net, STRUCT_GRID[3], seed=seed)
        assert rep["equivalent"]


def test_repack_prefix_is_reusable():
    """Re-clustering must not leak state into the prefix: repeated
    repacks from one prefix (same and different archs, interleaved) are
    identical to each other and to fresh packs."""
    net = vtr_mixed(logic_nodes=150, adders=2)
    prefix = pack_prefix(net, seed=0)
    first = repack(prefix, ARCHS["dd5"])
    repack(prefix, ARCHS["baseline"])       # interleave another class
    repack(prefix, make_arch("a8", bypass_inputs=2, alms_per_lb=8))
    again = repack(prefix, ARCHS["dd5"])
    _assert_same_pack(first, again)
    _assert_same_pack(again, pack(net, ARCHS["dd5"], seed=0))


@pytest.mark.parametrize("seed", [0, 3])
def test_incremental_lower_ir_matches_fresh(seed):
    """Column-patched lowering (template from a sibling structural
    class) == fresh lowering, every column, every level table."""
    net = random_netlist(seed)
    prefix = pack_prefix(net, seed=0)
    template = None
    for arch in STRUCT_GRID:
        p = repack(prefix, arch)
        fresh = lower_pack_ir(p)
        if template is None:
            template = fresh
            continue
        _assert_same_ir(fresh, lower_pack_ir_incremental(p, template))


def test_incremental_lower_ir_rejects_wrong_template():
    net_a = random_netlist(1)
    net_b = random_netlist(2)
    tpl = pack(net_a, ARCHS["dd5"], seed=0).lower_ir()
    p = pack(net_b, ARCHS["dd5"], seed=0)
    with pytest.raises(ValueError):
        lower_pack_ir_incremental(p, tpl)


def test_lower_ir_template_kwarg():
    """``PackedCircuit.lower_ir(template=...)`` is the incremental mode
    the sweep engine drives; it must agree with the cached full path."""
    net = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    prefix = pack_prefix(net, seed=0)
    tpl = repack(prefix, ARCHS["baseline"]).lower_ir()
    p = repack(prefix, ARCHS["dd5"])
    via_template = p.lower_ir(cache=False, template=tpl)
    _assert_same_ir(p.lower_ir(), via_template)


@pytest.mark.parametrize("arch_name", ["baseline", "dd5", "dd6"])
def test_repack_reproduces_pinned_table3_numbers(arch_name):
    """The pre-refactor Fig-5/Table-III pins (single source of truth in
    test_timing_vec._PINS), re-asserted through the prefix+repack path
    so the refactored pack() stays byte-stable."""
    from test_timing_vec import _PINS

    net = sha_like(rounds=1)
    rec = analyze_oracle(repack(pack_prefix(net, seed=0), ARCHS[arch_name]))
    cp, alms, area, adp = _PINS[(net.name, arch_name)]
    assert rec["critical_path_ps"] == cp
    assert rec["alms"] == alms
    assert rec["area_mwta"] == area
    assert rec["adp"] == adp


def test_structural_axes_change_packs():
    """The geometry axes really are pack-affecting: shrinking the LB
    capacity produces more LBs; the structural key separates the
    classes; the grid dedups and names them distinctly."""
    net = kratos_gemm(m=5, n=5, width=5, sparsity=0.5)
    p10 = pack(net, ARCHS["dd5"], seed=0)
    a8 = make_arch("dd5_a8", bypass_inputs=2, alms_per_lb=8)
    p8 = pack(net, a8, seed=0)
    assert p8.n_lbs > p10.n_lbs
    assert a8.structural_key() != ARCHS["dd5"].structural_key()
    grid = arch_grid(alms_per_lb=(8, 10), lb_inputs=(48, 60))
    assert len(grid) == 4 * 7            # geometry axes multiply the grid
    assert len({a.name for a in grid}) == len(grid)
    assert len({a.structural_key() for a in grid}) == 4 * 5


#: >= 3 cluster-geometry points for the vectorized-recluster A/B — each
#: a distinct structural class stressing a different budget axis
VEC_GEOMETRY = [
    make_arch("v_a6_i40_u70", bypass_inputs=2, alms_per_lb=6,
              lb_inputs=40, ext_pin_util=0.7),
    make_arch("v_a8", bypass_inputs=2, alms_per_lb=8),
    make_arch("v_a12_u80", bypass_inputs=2, alms_per_lb=12,
              ext_pin_util=0.8),
    make_arch("v_b0_a8", bypass_inputs=0, alms_per_lb=8),
]


def test_vectorized_recluster_byte_identical_to_pack(monkeypatch):
    """The vectorized clustering replay (``VECTOR_CLUSTER`` + the
    density-gated gather/bump/mask paths) must be byte-identical to the
    legacy scalar reference AND to a from-scratch ``pack()`` across
    geometry points — both at the profiled default gates and with every
    vector path forced on (gates zeroed, mask always built)."""
    import repro.core.packing as P

    for mk in (lambda: kratos_gemm(m=5, n=5, width=5, sparsity=0.5),
               lambda: sha_like(rounds=2)):
        net = mk()
        prefix = pack_prefix(net, seed=0)
        for arch in VEC_GEOMETRY:
            monkeypatch.setattr(P, "VECTOR_CLUSTER", False)
            ref = repack(prefix, arch)
            _assert_same_pack(ref, pack(net, arch, seed=0))
            monkeypatch.setattr(P, "VECTOR_CLUSTER", True)
            monkeypatch.setattr(P, "_VEC_MIN_DEGREE", 48)
            monkeypatch.setattr(P, "_MASK_MIN_ALMS", 24)
            _assert_same_pack(ref, repack(prefix, arch))
            monkeypatch.setattr(P, "_VEC_MIN_DEGREE", 0)
            monkeypatch.setattr(P, "_MASK_MIN_ALMS", 1)
            _assert_same_pack(ref, repack(prefix, arch))
