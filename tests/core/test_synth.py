"""Property tests: every synthesis algorithm must produce functionally
correct multipliers (netlist evaluation == integer arithmetic)."""
import random

import pytest
from _hypothesis_shim import given, settings, st

from repro.core.netlist import Netlist, bus_to_ints, eval_netlist
from repro.core.synth import (ALGOS, synth_const_mult, synth_dot_const,
                              synth_var_mult)

NV = 16


def _bitpack(vals, width):
    return [sum(((vals[v] >> j) & 1) << v for v in range(len(vals)))
            for j in range(width)]


def _drive(net, bus, vals):
    return dict(zip(bus, _bitpack(vals, len(bus))))


def _signed(v, bits):
    return v - (1 << bits) if (v >> (bits - 1)) & 1 else v


@pytest.mark.parametrize("algo", ALGOS)
@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_const_mult_correct(algo, data):
    m = data.draw(st.integers(2, 9), label="m")
    nb = data.draw(st.integers(2, 9), label="const_bits")
    const = data.draw(st.integers(0, (1 << nb) - 1), label="const")
    signed = data.draw(st.booleans(), label="signed")
    W = m + nb
    net = Netlist()
    x = net.add_pi_bus("x", m)
    out = synth_const_mult(net, x, const, nb, algo=algo, signed=signed,
                           out_width=W)
    rng = random.Random(data.draw(st.integers(0, 2**16), label="seed"))
    xs = [rng.getrandbits(m) for _ in range(NV)]
    got = bus_to_ints(eval_netlist(net, _drive(net, x, xs), NV), out, NV)
    for v in range(NV):
        xv = _signed(xs[v], m) if signed else xs[v]
        cv = _signed(const, nb) if signed else const
        assert got[v] == (xv * cv) % (1 << W)


@pytest.mark.parametrize("algo", ALGOS)
@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_var_mult_correct(algo, data):
    m = data.draw(st.integers(2, 8), label="m")
    n = data.draw(st.integers(2, 8), label="n")
    signed = data.draw(st.booleans(), label="signed")
    W = m + n
    net = Netlist()
    x = net.add_pi_bus("x", m)
    y = net.add_pi_bus("y", n)
    out = synth_var_mult(net, x, y, algo=algo, signed=signed, out_width=W)
    rng = random.Random(data.draw(st.integers(0, 2**16), label="seed"))
    xs = [rng.getrandbits(m) for _ in range(NV)]
    ys = [rng.getrandbits(n) for _ in range(NV)]
    vals = _drive(net, x, xs)
    vals.update(_drive(net, y, ys))
    got = bus_to_ints(eval_netlist(net, vals, NV), out, NV)
    for v in range(NV):
        xv = _signed(xs[v], m) if signed else xs[v]
        yv = _signed(ys[v], n) if signed else ys[v]
        assert got[v] == (xv * yv) % (1 << W)


@pytest.mark.parametrize("algo", ["wallace", "binary", "cascade"])
@pytest.mark.parametrize("style", ["per_mult", "fused"])
def test_dot_product_correct(algo, style):
    rng = random.Random(7)
    n, m, nb = 6, 5, 4
    W = m + nb + 3
    net = Netlist()
    xs = [net.add_pi_bus(f"x{i}", m) for i in range(n)]
    ws = [rng.getrandbits(nb) for _ in range(n)]
    out = synth_dot_const(net, xs, ws, nb, algo=algo, signed=True,
                          out_width=W, style=style)
    vals = {}
    xvals = []
    for bus in xs:
        vs = [rng.getrandbits(m) for _ in range(NV)]
        xvals.append(vs)
        vals.update(_drive(net, bus, vs))
    got = bus_to_ints(eval_netlist(net, vals, NV), out, NV)
    for v in range(NV):
        exp = sum(_signed(xvals[i][v], m) * _signed(ws[i], nb)
                  for i in range(n)) % (1 << W)
        assert got[v] == exp


def test_duplicate_chain_dedup_ratio():
    """§IV: stock VTR burns ~2.85x more FAs on x * 01010101 than the
    chain-sharing synthesis.  Our model brackets that ratio."""
    net_opt = Netlist()
    x = net_opt.add_pi_bus("x", 8)
    synth_const_mult(net_opt, x, 0b01010101, 8, algo="binary", out_width=16)
    net_base = Netlist()
    x = net_base.add_pi_bus("x", 8)
    synth_const_mult(net_base, x, 0b01010101, 8, algo="vtr_baseline",
                     out_width=16)
    ratio = net_base.n_adders / net_opt.n_adders
    assert 2.0 <= ratio <= 5.0, ratio


def test_dedup_shares_shifted_chains():
    """Two row-pairs that are shifted copies must share one chain."""
    net = Netlist()
    x = net.add_pi_bus("x", 8)
    synth_const_mult(net, x, 0b01010101, 8, algo="binary", out_width=16)
    # stage 1 of the reduction has a single unique chain (0+2 == 4+6 shifted)
    assert len(net.chains) == 2  # one shared stage-1 chain + one final chain


def test_sparsity_drops_rows():
    net = Netlist()
    x = net.add_pi_bus("x", 8)
    out_z = synth_const_mult(net, x, 0, 8, algo="wallace", out_width=16)
    assert net.n_adders == 0 and net.n_luts == 0
    assert all(s == 0 for s in out_z)
