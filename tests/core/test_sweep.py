"""Design-space sweep engine: data-driven archs, structural-class pack
sharing, batched re-timing, frontier reduction."""
import numpy as np
import pytest

from repro.core import flow
from repro.core.alm import (ARCHS, BASELINE, DD5, DD6, arch_grid,
                            group_archs_by_structure, make_arch)
from repro.core.circuits import kratos_gemm, sha_like
from repro.core.equiv import check_pack_equivalence
from repro.core.packing import pack
from repro.core.sweep import adp_frontier, oracle_parity, sweep_suite
from repro.core.timing import analyze_oracle

from test_flow import random_netlist


def test_canonical_archs_are_grid_rows():
    """baseline/DD5/DD6 are reproduced exactly by the make_arch factory
    (Table I area ratios land verbatim, Table II delays intact)."""
    b = make_arch("baseline", bypass_inputs=0)
    d5 = make_arch("dd5", bypass_inputs=2, addmux_fanin=10)
    d6 = make_arch("dd6", bypass_inputs=2, addmux_fanin=10, lut6=True)
    for want, got in ((BASELINE, b), (DD5, d5), (DD6, d6)):
        assert want == got
    assert abs(DD5.alm_area_mwta / BASELINE.alm_area_mwta - 1.0372) < 1e-9
    assert DD5.t_ah_to_adder == 202.2 and BASELINE.t_ah_to_adder == 133.4
    grid = arch_grid()
    by_knobs = {(a.bypass_inputs, a.addmux_fanin, a.concurrent_6lut): a
                for a in grid}
    assert by_knobs[(0, 10, False)].alm_area_mwta == BASELINE.alm_area_mwta
    assert by_knobs[(2, 10, False)].alm_area_mwta == DD5.alm_area_mwta
    assert by_knobs[(2, 10, True)].alm_area_mwta == DD6.alm_area_mwta


def test_structural_classes():
    """Delay-only variants share a structural key; structural knobs split
    classes; delay tables order matches DELAY_FIELDS."""
    d5 = ARCHS["dd5"]
    f20 = make_arch("f20", bypass_inputs=2, addmux_fanin=20, z_sources=40)
    assert d5.structural_key() == f20.structural_key()
    assert d5.delay_table()[1] != f20.delay_table()[1]  # t_lbin_to_z moved
    f5 = make_arch("f5", bypass_inputs=2, addmux_fanin=5)
    assert f5.structural_key() != d5.structural_key()   # z_sources shrank
    groups = group_archs_by_structure([d5, f20, f5, ARCHS["baseline"]])
    assert sorted(len(g) for g in groups) == [1, 1, 2]


def test_sweep_matches_oracle_exactly():
    """A real (small) sweep is bit-identical to per-circuit analyze_oracle
    under every grid point — including points that share a pack."""
    nets = {"a": [random_netlist(5)],
            "b": [kratos_gemm(m=4, n=4, width=4, sparsity=0.5)]}
    grid = [ARCHS["baseline"], ARCHS["dd5"],
            make_arch("dd5_f20", bypass_inputs=2, addmux_fanin=20,
                      z_sources=40)]
    res = sweep_suite(nets, grid, backend="jax")
    assert res.n_classes == 2           # baseline | {dd5, dd5_f20}
    assert oracle_parity(res, nets, grid)
    res_np = sweep_suite(nets, grid, backend="numpy")
    for g in range(len(res.circuits)):
        for k in range(len(grid)):
            assert (res.records[g][k]["critical_path_ps"]
                    == res_np.records[g][k]["critical_path_ps"])
            assert res.records[g][k]["suite"] in ("a", "b")


def test_sweep_program_cache_reused():
    """Warm sweeps reuse packs and compiled programs: second run does no
    packing and rebuilds nothing."""
    nets = [random_netlist(2)]
    grid = [ARCHS["baseline"], ARCHS["dd5"]]
    packs, programs = {}, {}
    sweep_suite(nets, grid, packs=packs, programs=programs)
    n_packs, n_progs = len(packs), len(programs)
    res2 = sweep_suite(nets, grid, packs=packs, programs=programs)
    assert len(packs) == n_packs and len(programs) == n_progs
    assert res2.wall["pack_s"] < res2.wall["timing_s"] + 1.0  # packs cached


def test_pack_cache_is_seed_keyed():
    """Reusing a packs dict across sweeps at different seeds must not
    serve stale-seed packs (regression: the cache key once dropped the
    seed and seed-1 sweeps returned seed-0 timing)."""
    nets = [kratos_gemm(m=4, n=4, width=4, sparsity=0.5)]
    grid = [ARCHS["dd5"]]
    pk: dict = {}
    sweep_suite(nets, grid, seed=0, backend="numpy", packs=pk)
    res1 = sweep_suite(nets, grid, seed=1, backend="numpy", packs=pk)
    fresh = sweep_suite(nets, grid, seed=1, backend="numpy")
    assert (res1.records[0][0]["critical_path_ps"]
            == fresh.records[0][0]["critical_path_ps"])


def test_make_arch_z_sources_respects_lb_outputs_override():
    a = make_arch("x", bypass_inputs=2, addmux_fanin=20, lb_outputs=20)
    assert a.z_sources == 20


def test_adp_frontier_rows():
    nets = [kratos_gemm(m=5, n=5, width=5, sparsity=0.5)]
    grid = [ARCHS["baseline"], ARCHS["dd5"], ARCHS["dd6"]]
    res = sweep_suite(nets, grid, backend="numpy")
    rows = adp_frontier(res, baseline="baseline")
    assert [r["arch"] for r in rows] != []
    assert all(set(r) >= {"arch", "area_mwta", "critical_path_ps", "adp"}
               for r in rows)
    # frontier is sorted by ADP ratio
    adps = [r["adp"] for r in rows]
    assert adps == sorted(adps)
    # paper direction: dd5 saves area vs baseline on an adder circuit
    dd5 = next(r for r in rows if r["arch"] == "dd5")
    assert dd5["area_mwta"] < 1.0


def test_flow_sweep_wrapper():
    nets = [random_netlist(4)]
    res = flow.sweep_architectures(nets, archs=[ARCHS["baseline"],
                                                ARCHS["dd5"]],
                                   backend="numpy")
    rows = flow.sweep_frontier(res, baseline="baseline")
    assert len(rows) == 1 and rows[0]["arch"] == "dd5"


def test_bypass_width_one_packs_and_verifies():
    """bypass_inputs=1 (a half-populated bypass): only FA bits with a
    single live operand may convert to Z; the pack must stay provably
    equivalent and never out-convert the full DD5 bypass."""
    b1 = make_arch("b1_f10", bypass_inputs=1, addmux_fanin=10)
    assert b1.concurrent and b1.bypass_inputs == 1
    net = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    rep = check_pack_equivalence(net, b1, seed=0)
    assert rep["equivalent"]
    p1 = pack(net, b1, seed=0)
    p2 = pack(net, ARCHS["dd5"], seed=0)
    z1 = sum(1 for alm in p1.alms for h in alm.halves if h.fa_feed == "z")
    z2 = sum(1 for alm in p2.alms for h in alm.halves if h.fa_feed == "z")
    assert z1 <= z2
    # every converted bit respects the bypass width
    for alm in p1.alms:
        for h in alm.halves:
            if h.fa is not None and h.fa_feed == "z":
                ci, bi = h.fa
                ch = p1.net.chains[ci]
                live = sum(1 for s in (ch.a[bi], ch.b[bi]) if s > 1)
                assert live <= 1
    r1 = analyze_oracle(p1)
    assert r1["critical_path_ps"] > 0


def test_grid_infeasible_corners_rejected():
    with pytest.raises(ValueError):
        make_arch("bad", bypass_inputs=1, lut6=True)
    with pytest.raises(ValueError):
        make_arch("bad", bypass_inputs=3)
    names = [a.name for a in arch_grid()]
    assert len(names) == len(set(names))
