"""Design-space sweep engine: data-driven archs, structural-class pack
sharing, batched re-timing, frontier reduction."""
import numpy as np
import pytest

from repro.core import flow
from repro.core.alm import (ARCHS, BASELINE, DD5, DD6, arch_grid,
                            group_archs_by_structure, make_arch)
from repro.core.circuits import kratos_gemm, sha_like
from repro.core.equiv import check_pack_equivalence
from repro.core.packing import pack
from repro.core.sweep import adp_frontier, oracle_parity, sweep_suite
from repro.core.timing import analyze_oracle

from test_flow import random_netlist


def test_canonical_archs_are_grid_rows():
    """baseline/DD5/DD6 are reproduced exactly by the make_arch factory
    (Table I area ratios land verbatim, Table II delays intact)."""
    b = make_arch("baseline", bypass_inputs=0)
    d5 = make_arch("dd5", bypass_inputs=2, addmux_fanin=10)
    d6 = make_arch("dd6", bypass_inputs=2, addmux_fanin=10, lut6=True)
    for want, got in ((BASELINE, b), (DD5, d5), (DD6, d6)):
        assert want == got
    assert abs(DD5.alm_area_mwta / BASELINE.alm_area_mwta - 1.0372) < 1e-9
    assert DD5.t_ah_to_adder == 202.2 and BASELINE.t_ah_to_adder == 133.4
    grid = arch_grid()
    by_knobs = {(a.bypass_inputs, a.addmux_fanin, a.concurrent_6lut): a
                for a in grid}
    assert by_knobs[(0, 10, False)].alm_area_mwta == BASELINE.alm_area_mwta
    assert by_knobs[(2, 10, False)].alm_area_mwta == DD5.alm_area_mwta
    assert by_knobs[(2, 10, True)].alm_area_mwta == DD6.alm_area_mwta


def test_structural_classes():
    """Delay-only variants share a structural key; structural knobs split
    classes; delay tables order matches DELAY_FIELDS."""
    d5 = ARCHS["dd5"]
    f20 = make_arch("f20", bypass_inputs=2, addmux_fanin=20, z_sources=40)
    assert d5.structural_key() == f20.structural_key()
    assert d5.delay_table()[1] != f20.delay_table()[1]  # t_lbin_to_z moved
    f5 = make_arch("f5", bypass_inputs=2, addmux_fanin=5)
    assert f5.structural_key() != d5.structural_key()   # z_sources shrank
    groups = group_archs_by_structure([d5, f20, f5, ARCHS["baseline"]])
    assert sorted(len(g) for g in groups) == [1, 1, 2]


def test_sweep_matches_oracle_exactly():
    """A real (small) sweep is bit-identical to per-circuit analyze_oracle
    under every grid point — including points that share a pack."""
    nets = {"a": [random_netlist(5)],
            "b": [kratos_gemm(m=4, n=4, width=4, sparsity=0.5)]}
    grid = [ARCHS["baseline"], ARCHS["dd5"],
            make_arch("dd5_f20", bypass_inputs=2, addmux_fanin=20,
                      z_sources=40)]
    res = sweep_suite(nets, grid, backend="jax")
    assert res.n_classes == 2           # baseline | {dd5, dd5_f20}
    assert oracle_parity(res, nets, grid)
    res_np = sweep_suite(nets, grid, backend="numpy")
    for g in range(len(res.circuits)):
        for k in range(len(grid)):
            assert (res.records[g][k]["critical_path_ps"]
                    == res_np.records[g][k]["critical_path_ps"])
            assert res.records[g][k]["suite"] in ("a", "b")


def test_sweep_program_cache_reused():
    """Warm sweeps reuse packs and compiled programs: second run does no
    packing and rebuilds nothing."""
    nets = [random_netlist(2)]
    grid = [ARCHS["baseline"], ARCHS["dd5"]]
    packs, programs = {}, {}
    sweep_suite(nets, grid, packs=packs, programs=programs)
    n_packs, n_progs = len(packs), len(programs)
    res2 = sweep_suite(nets, grid, packs=packs, programs=programs)
    assert len(packs) == n_packs and len(programs) == n_progs
    assert res2.wall["pack_s"] < res2.wall["timing_s"] + 1.0  # packs cached


def test_pack_cache_is_seed_keyed():
    """Reusing a packs dict across sweeps at different seeds must not
    serve stale-seed packs (regression: the cache key once dropped the
    seed and seed-1 sweeps returned seed-0 timing)."""
    nets = [kratos_gemm(m=4, n=4, width=4, sparsity=0.5)]
    grid = [ARCHS["dd5"]]
    pk: dict = {}
    sweep_suite(nets, grid, seed=0, backend="numpy", packs=pk)
    res1 = sweep_suite(nets, grid, seed=1, backend="numpy", packs=pk)
    fresh = sweep_suite(nets, grid, seed=1, backend="numpy")
    assert (res1.records[0][0]["critical_path_ps"]
            == fresh.records[0][0]["critical_path_ps"])


def test_pack_cache_is_content_keyed():
    """Regression (the old keys were list positions): a packs cache
    warmed with one circuit list, passed to a sweep over a *different*
    list, must miss and repack — never silently reuse the other
    circuit's pack and report its metrics."""
    net_a = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    net_b = sha_like(rounds=1)
    grid = [ARCHS["dd5"]]
    pk: dict = {}
    sweep_suite([net_a], grid, seed=0, backend="numpy", packs=pk)
    warmed = dict(pk)
    res_b = sweep_suite([net_b], grid, seed=0, backend="numpy", packs=pk)
    fresh_b = sweep_suite([net_b], grid, seed=0, backend="numpy")
    assert (res_b.records[0][0]["critical_path_ps"]
            == fresh_b.records[0][0]["critical_path_ps"])
    assert (res_b.records[0][0]["area_mwta"]
            == fresh_b.records[0][0]["area_mwta"])
    # and the warmed entries were misses, not hits: new keys were added
    assert len(pk) > len(warmed)
    # keys are content digests — independent of list position
    res_both = sweep_suite([net_b, net_a], grid, seed=0, backend="numpy",
                           packs=pk)   # b now at index 0, a at index 1
    assert (res_both.records[0][0]["critical_path_ps"]
            == fresh_b.records[0][0]["critical_path_ps"])


def test_program_cache_is_suite_keyed():
    """The compiled-program cache must also key on the circuit list's
    content: reusing it with a different suite rebuilds instead of
    running another suite's (wrong-shaped) program."""
    net_a = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    net_b = sha_like(rounds=1)
    grid = [ARCHS["dd5"]]
    progs: dict = {}
    sweep_suite([net_a], grid, programs=progs)
    n = len(progs)
    res_b = sweep_suite([net_b], grid, programs=progs)
    assert len(progs) == 2 * n
    fresh_b = sweep_suite([net_b], grid)
    assert (res_b.records[0][0]["critical_path_ps"]
            == fresh_b.records[0][0]["critical_path_ps"])


def test_sweep_prefix_sharing_structural_axes():
    """A cluster-geometry sweep (every point its own structural class)
    shares one prefix per circuit and stays bit-identical to per-point
    ``analyze_oracle`` on from-scratch packs."""
    nets = [kratos_gemm(m=4, n=4, width=4, sparsity=0.5),
            random_netlist(6)]
    grid = [make_arch("g_a8", bypass_inputs=2, alms_per_lb=8),
            make_arch("g_a10", bypass_inputs=2, alms_per_lb=10),
            make_arch("g_i48", bypass_inputs=2, lb_inputs=48),
            make_arch("g_b0a8", bypass_inputs=0, alms_per_lb=8)]
    prefixes: dict = {}
    res = sweep_suite(nets, grid, backend="numpy", prefixes=prefixes)
    assert res.n_classes == len(grid)
    assert len(prefixes) == len(nets)      # one prefix per circuit
    assert oracle_parity(res, nets, grid)


def test_geomean_raises_on_nonpositive_ratio():
    """Regression: a non-positive metric ratio used to be clamped to
    1e-12 and silently poisoned the frontier row; it must raise."""
    from repro.core.sweep import _geomean

    assert _geomean([1.0, 2.0, 0.5]) == pytest.approx(1.0)
    with pytest.raises(ValueError):
        _geomean([1.0, 0.0, 2.0])
    with pytest.raises(ValueError):
        _geomean([1.0, -3.0])
    with pytest.raises(ValueError):
        _geomean([float("nan")])
    # end to end: a corrupted sweep record surfaces instead of skewing
    nets = [kratos_gemm(m=4, n=4, width=4, sparsity=0.5)]
    grid = [ARCHS["baseline"], ARCHS["dd5"]]
    res = sweep_suite(nets, grid, backend="numpy")
    res.records[0][1]["adp"] = 0.0
    with pytest.raises(ValueError):
        adp_frontier(res, baseline="baseline")


def test_timing_wall_scope_reports_once():
    """Regression: nested accounting (an outer accounted region driving
    ``analyze``/``sweep_suite``, which record themselves) used to add
    both layers to TIMING_WALL; scoped accounting commits exactly one
    outermost span."""
    from repro.core.timing import (analyze, read_timing_wall,
                                   record_timing_wall, reset_timing_wall,
                                   timing_section)

    packed = pack(kratos_gemm(m=4, n=4, width=4, sparsity=0.5),
                  ARCHS["dd5"], seed=0)
    reset_timing_wall()
    with timing_section():
        analyze(packed)                   # would add its own span pre-fix
        record_timing_wall(1e6, calls=3)  # simulated nested section
    w = read_timing_wall()
    # the nested gigasecond never reaches the global counter — only the
    # outer section's measured span commits (calls still aggregate)
    assert w["s"] < 1.0
    assert w["calls"] == 4
    # un-scoped behaviour unchanged
    reset_timing_wall()
    analyze(packed)
    analyze(packed)
    assert read_timing_wall()["calls"] == 2
    # measure=False sections commit their recorded sub-phases once
    reset_timing_wall()
    with timing_section(measure=False):
        record_timing_wall(2.0, calls=1)
        with timing_section(measure=False):
            record_timing_wall(3.0, calls=1)
    assert read_timing_wall() == {"s": 5.0, "calls": 2}


def test_make_arch_z_sources_respects_lb_outputs_override():
    a = make_arch("x", bypass_inputs=2, addmux_fanin=20, lb_outputs=20)
    assert a.z_sources == 20


def test_adp_frontier_rows():
    nets = [kratos_gemm(m=5, n=5, width=5, sparsity=0.5)]
    grid = [ARCHS["baseline"], ARCHS["dd5"], ARCHS["dd6"]]
    res = sweep_suite(nets, grid, backend="numpy")
    rows = adp_frontier(res, baseline="baseline")
    assert [r["arch"] for r in rows] != []
    assert all(set(r) >= {"arch", "area_mwta", "critical_path_ps", "adp"}
               for r in rows)
    # frontier is sorted by ADP ratio
    adps = [r["adp"] for r in rows]
    assert adps == sorted(adps)
    # paper direction: dd5 saves area vs baseline on an adder circuit
    dd5 = next(r for r in rows if r["arch"] == "dd5")
    assert dd5["area_mwta"] < 1.0


def test_flow_sweep_wrapper():
    nets = [random_netlist(4)]
    res = flow.sweep_architectures(nets, archs=[ARCHS["baseline"],
                                                ARCHS["dd5"]],
                                   backend="numpy")
    rows = flow.sweep_frontier(res, baseline="baseline")
    assert len(rows) == 1 and rows[0]["arch"] == "dd5"


def test_flow_sweep_forwards_max_groups():
    """Regression: ``flow.sweep_architectures`` used to drop
    ``max_groups``, so flow callers could neither match a direct
    ``sweep_suite`` configuration nor hit a programs cache warmed with a
    non-default grouping."""
    nets = [random_netlist(4), random_netlist(9)]
    grid = [ARCHS["baseline"], ARCHS["dd5"]]
    progs: dict = {}
    direct = sweep_suite(nets, grid, max_groups=1, programs=progs)
    n = len(progs)
    assert n and all(k[4] == 1 for k in progs)   # grouping knob in key
    via_flow = flow.sweep_architectures(nets, archs=grid, max_groups=1,
                                        programs=progs)
    assert len(progs) == n                       # warmed cache was hit
    for g in range(len(nets)):
        for k in range(len(grid)):
            assert (direct.records[g][k]["critical_path_ps"]
                    == via_flow.records[g][k]["critical_path_ps"])


def test_flow_sweep_grid_axes():
    """The flow wrapper can grow the structural grid directly."""
    nets = [random_netlist(4)]
    res = flow.sweep_architectures(
        nets, backend="numpy",
        grid_axes={"bypass_inputs": (2,), "addmux_fanin": (10,),
                   "lut6": (False,), "alms_per_lb": (8, 10)})
    assert res.archs == ["b2_f10_a8", "b2_f10"]
    assert res.n_classes == 2
    with pytest.raises(ValueError):
        flow.sweep_architectures(nets, archs=[ARCHS["dd5"]],
                                 grid_axes={"alms_per_lb": (8,)})


def test_bypass_width_one_packs_and_verifies():
    """bypass_inputs=1 (a half-populated bypass): only FA bits with a
    single live operand may convert to Z; the pack must stay provably
    equivalent and never out-convert the full DD5 bypass."""
    b1 = make_arch("b1_f10", bypass_inputs=1, addmux_fanin=10)
    assert b1.concurrent and b1.bypass_inputs == 1
    net = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    rep = check_pack_equivalence(net, b1, seed=0)
    assert rep["equivalent"]
    p1 = pack(net, b1, seed=0)
    p2 = pack(net, ARCHS["dd5"], seed=0)
    z1 = sum(1 for alm in p1.alms for h in alm.halves if h.fa_feed == "z")
    z2 = sum(1 for alm in p2.alms for h in alm.halves if h.fa_feed == "z")
    assert z1 <= z2
    # every converted bit respects the bypass width
    for alm in p1.alms:
        for h in alm.halves:
            if h.fa is not None and h.fa_feed == "z":
                ci, bi = h.fa
                ch = p1.net.chains[ci]
                live = sum(1 for s in (ch.a[bi], ch.b[bi]) if s > 1)
                assert live <= 1
    r1 = analyze_oracle(p1)
    assert r1["critical_path_ps"] > 0


def test_grid_infeasible_corners_rejected():
    with pytest.raises(ValueError):
        make_arch("bad", bypass_inputs=1, lut6=True)
    with pytest.raises(ValueError):
        make_arch("bad", bypass_inputs=3)
    names = [a.name for a in arch_grid()]
    assert len(names) == len(set(names))
