"""Minimal stand-in for ``hypothesis`` in offline environments.

The container that runs tier-1 has no ``hypothesis`` wheel; importing it at
module scope used to abort *collection* of the whole file.  This shim
re-exports the real library when present (``pip install -r
requirements-dev.txt``) and otherwise provides the tiny subset the test
suite uses — ``given``, ``settings`` and the ``integers`` / ``booleans`` /
``data`` strategies — backed by deterministic seeded random sampling.

The shim does no shrinking and no example database; it is a property-style
fuzz loop, not a hypothesis replacement.  Tests written against it must
stick to the subset above.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample_fn):
            self._sample_fn = sample_fn

        def sample(self, rng: random.Random):
            return self._sample_fn(rng)

    class _DataObject:
        """Interactive draws (``st.data()`` style)."""

        def __init__(self, rng: random.Random):
            self._rng = rng

        def draw(self, strategy: _Strategy, label: str | None = None):
            return strategy.sample(self._rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def data() -> _Strategy:
            return _Strategy(_DataObject)

    st = _StrategiesModule()

    def settings(max_examples: int = 100, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(*pos_strategies, **kw_strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            # hypothesis right-aligns positional strategies onto parameters
            n = len(pos_strategies)
            pos_names = names[len(names) - n:] if n else []
            supplied = set(pos_names) | set(kw_strategies)
            max_examples = getattr(fn, "_shim_max_examples", 25)
            seed0 = zlib.adler32(fn.__qualname__.encode())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for example in range(max_examples):
                    rng = random.Random(seed0 * 100003 + example)
                    drawn = {nm: s.sample(rng)
                             for nm, s in zip(pos_names, pos_strategies)}
                    for nm, s in kw_strategies.items():
                        drawn[nm] = s.sample(rng)
                    fn(*args, **kwargs, **drawn)

            # hide the strategy-supplied parameters from pytest's fixture
            # resolution (hypothesis does the same via its own wrapper)
            wrapper.__signature__ = sig.replace(parameters=[
                p for nm, p in sig.parameters.items() if nm not in supplied
            ])
            try:
                del wrapper.__wrapped__
            except AttributeError:
                pass
            return wrapper

        return deco
