"""Grid placement + routed-wire timing: determinism, legality, cache
semantics and bit-identity of the placed timing path.

The contract under test: placements are deterministic per (netlist
content digest, arch placement key, seed) and legal (one LB per slot);
the placed vectorized timing path — numpy and the batched jax program —
is bit-identical to :func:`repro.core.timing.analyze_placed_oracle`
across baseline/DD5/DD6; at all-zero wire-tier delays the placed path
reproduces the placement-free timing bit for bit (so every Fig-5 /
Table-III pin in ``test_timing_vec`` keeps gating this PR's refactor);
and the placement cache lives in the unified :mod:`repro.core.plan`
registry (the PR-5 stale-template regression, re-pinned for placements).
"""
import numpy as np

from repro.core.alm import ARCHS, make_arch
from repro.core.circuit_ir import (TIER_HOP1, TIER_LONG, TIER_NONE,
                                   apply_placement)
from repro.core.circuits import kratos_gemm
from repro.core.packing import pack
from repro.core.place import (PLACE_COUNTS, GridPlacement, channel_congestion,
                              grid_shape, lb_connectivity, place_and_apply,
                              place_ir, placement_for)
from repro.core.plan import cache_stats, clear_caches
from repro.core.sweep import oracle_parity, sweep_suite
from repro.core.timing import (analyze, analyze_oracle, analyze_placed_oracle)
from repro.core.timing_vec import analyze_ir, build_suite_timing_program

from test_flow import random_netlist


def _wired(arch, w1=25.0, w2=40.0, wl=120.0, **kw):
    """Same structural class as ``arch``, nonzero wire-tier delays."""
    return make_arch(arch.name + "_wired", bypass_inputs=arch.bypass_inputs,
                     addmux_fanin=arch.addmux_fanin,
                     lut6=arch.concurrent_6lut,
                     t_wire_hop1=w1, t_wire_hop2=w2, t_wire_long=wl, **kw)


CANONICAL = ("baseline", "dd5", "dd6")


def test_placer_deterministic_per_digest_key_seed():
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    arch = ARCHS["dd5"]
    ir = pack(net, arch).lower_ir()
    a = place_ir(ir, arch, seed=3)
    b = place_ir(ir, arch, seed=3)
    assert np.array_equal(a.lb_x, b.lb_x)
    assert np.array_equal(a.lb_y, b.lb_y)
    assert a.placement_key == arch.placement_key()
    # a different seed starts a different scatter (distinct rng stream)
    c = place_ir(ir, arch, seed=4)
    assert not (np.array_equal(a.lb_x, c.lb_x)
                and np.array_equal(a.lb_y, c.lb_y))


def test_legalized_placements_respect_grid_capacity():
    nets = [kratos_gemm(m=4, n=4, width=5, sparsity=0.5), random_netlist(7)]
    for net in nets:
        for aname in CANONICAL:
            arch = ARCHS[aname]
            ir = pack(net, arch).lower_ir()
            for backend in ("numpy", "jax"):
                pl = place_ir(ir, arch, seed=0, backend=backend)
                assert pl.grid_w * pl.grid_h >= ir.n_lbs
                assert (pl.lb_x >= 0).all() and (pl.lb_x < pl.grid_w).all()
                assert (pl.lb_y >= 0).all() and (pl.lb_y < pl.grid_h).all()
                slots = set(zip(pl.lb_x.tolist(), pl.lb_y.tolist()))
                assert len(slots) == ir.n_lbs, \
                    f"{net.name}@{aname}/{backend}: overlapping LB slots"


def test_grid_shape_aspect():
    w, h = grid_shape(12, aspect=1.0)
    assert w * h >= 12
    w2, h2 = grid_shape(12, aspect=4.0)
    assert w2 > h2 and w2 * h2 >= 12
    assert grid_shape(0) == (0, 0)


def test_grid_shape_degenerate_inputs_clamp_explicitly():
    """Regression for the degenerate-grid satellite: the width clamp is
    explicit, not incidental rounding — a 1-LB circuit lands on a 1x1
    grid at ANY aspect (round(sqrt(16)) = 4 used to mint a 4-wide grid
    of empty columns), extreme aspects never exceed n_lbs columns, and
    capacity always covers the circuit."""
    import pytest

    for aspect in (1 / 16, 0.5, 1.0, 4.0, 16.0, 1000.0):
        assert grid_shape(1, aspect) == (1, 1)
    for n in (1, 2, 3, 5, 7, 12, 97):
        for aspect in (1 / 16, 0.5, 1.0, 4.0, 16.0):
            w, h = grid_shape(n, aspect)
            assert 1 <= w <= n
            assert w * h >= n
            assert w * (h - 1) < n      # h is minimal for this w
    with pytest.raises(ValueError, match="aspect"):
        grid_shape(4, 0.0)
    with pytest.raises(ValueError, match="aspect"):
        grid_shape(4, -1.0)


def test_extreme_aspect_placement_stays_legal_end_to_end():
    """A 1-LB circuit and an extreme-aspect arch both place, refine and
    time without tripping the legalizer's capacity check."""
    from repro.core.circuits import vtr_mixed

    tiny = vtr_mixed(logic_nodes=8, adders=1)
    wide = make_arch("dd5_wide", bypass_inputs=2, addmux_fanin=10,
                     grid_aspect=16.0)
    for net in (tiny, kratos_gemm(m=4, n=4, width=4, sparsity=0.5)):
        packed = pack(net, wide)
        ir = packed.lower_ir()
        for refine in (None, "anneal"):
            pl = place_ir(ir, wide, seed=0, refine=refine)
            assert pl.grid_w * pl.grid_h >= ir.n_lbs
            assert pl.grid_w <= max(ir.n_lbs, 1)
            slots = set(zip(pl.lb_x.tolist(), pl.lb_y.tolist()))
            assert len(slots) == ir.n_lbs
            assert analyze(packed, placement=pl) \
                == analyze_placed_oracle(packed, pl)


def test_lb_connectivity_symmetric_no_self_edges():
    net = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    ir = pack(net, ARCHS["baseline"]).lower_ir()
    A = lb_connectivity(ir)
    assert A.shape == (ir.n_lbs, ir.n_lbs)
    assert np.array_equal(A, A.T)
    assert np.trace(A) == 0.0


def test_placed_timing_bit_identical_to_placed_oracle():
    """Vectorized placed timing == placed Python oracle, bit for bit
    (==, not allclose), across the canonical archs, both backends."""
    nets = [kratos_gemm(m=4, n=4, width=5, sparsity=0.5), random_netlist(3)]
    for net in nets:
        for aname in CANONICAL:
            arch = _wired(ARCHS[aname])
            packed = pack(net, arch)
            ir = packed.lower_ir()
            pl = placement_for(ir, arch, seed=0)
            want = analyze_placed_oracle(packed, pl)
            pir = apply_placement(ir, pl)
            got = analyze_ir(pir, arch)
            assert got == want, f"{net.name}@{aname} numpy"
            prog = build_suite_timing_program([pir])
            cp = float(prog.run(arch.delay_table()[None, :])[0, 0])
            assert cp == want["critical_path_ps"], f"{net.name}@{aname} jax"
            # wire delay can only lengthen paths
            assert want["critical_path_ps"] >= \
                analyze_oracle(packed)["critical_path_ps"]


def test_zero_wire_delay_reproduces_unplaced_timing_bitwise():
    """The refactor's regression contract: with all-zero wire-tier
    delays (every canonical arch), the placed path returns today's
    numbers bit for bit — which is what keeps the Fig-5/Table-III pins
    of ``test_timing_vec`` green through this PR."""
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    for aname in CANONICAL:
        arch = ARCHS[aname]
        assert (arch.t_wire_hop1, arch.t_wire_hop2, arch.t_wire_long) \
            == (0.0, 0.0, 0.0)
        packed = pack(net, arch)
        pl = placement_for(packed.lower_ir(), arch, seed=0)
        base = analyze_oracle(packed)
        assert analyze_placed_oracle(packed, pl) == base
        assert analyze(packed, placement=pl) == base


def test_apply_placement_fills_hop_columns_consistently():
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    arch = ARCHS["dd5"]
    ir = pack(net, arch).lower_ir()
    assert not ir.placed
    assert not ir.fanin_hop.any()
    pir = place_and_apply(ir, arch, seed=0)
    assert pir.placed and pir.grid_w > 0 and pir.placement_seed == 0
    assert pir.fanin_hop.any(), "a multi-LB circuit must route some edge"
    assert pir.fanin_hop.max() <= TIER_LONG
    # per-signal coords match the placement of the producing LB
    pl = placement_for(ir, arch, seed=0)
    placed = ir.sig_lb >= 0
    assert np.array_equal(pir.sig_x[placed], pl.lb_x[ir.sig_lb[placed]])
    assert np.array_equal(pir.sig_y[placed], pl.lb_y[ir.sig_lb[placed]])
    assert (pir.sig_x[~placed] == -1).all()
    # level-table hops agree with a direct recomputation from coords
    for ll in pir.lut_levels:
        if not ll.out.size:
            continue
        src_lb = ir.sig_lb[ll.ins]
        dst_lb = ir.sig_lb[ll.out][:, None]
        routed = (src_lb >= 0) & (dst_lb >= 0) & (src_lb != dst_lb)
        d = (np.abs(pl.lb_x[np.clip(src_lb, 0, None)]
                    - pl.lb_x[np.clip(dst_lb, 0, None)])
             + np.abs(pl.lb_y[np.clip(src_lb, 0, None)]
                      - pl.lb_y[np.clip(dst_lb, 0, None)]))
        assert (ll.hop[~routed] == TIER_NONE).all()
        assert (ll.hop[routed & (d == 1)] == TIER_HOP1).all()
        assert (ll.hop[routed] >= TIER_HOP1).all()


def test_placement_cache_in_registry_cleared_with_everything_else():
    """Regression mirroring the PR-5 stale-sweep-template bug: the
    placement cache must live in the unified registry so the single
    ``clear_caches()`` provably drops placements too — a 'cleared' state
    must re-solve, not serve a stale placement object."""
    clear_caches()
    n0 = PLACE_COUNTS["analytic"]
    net = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    arch = ARCHS["dd5"]
    ir = pack(net, arch).lower_ir()
    a = placement_for(ir, arch, seed=0)
    assert PLACE_COUNTS["analytic"] == n0 + 1
    assert cache_stats()["placement"]["size"] == 1
    # warm hit: same object, no new solve
    assert placement_for(ir, arch, seed=0) is a
    assert PLACE_COUNTS["analytic"] == n0 + 1
    clear_caches()
    assert cache_stats()["placement"]["size"] == 0
    b = placement_for(ir, arch, seed=0)
    assert b is not a                      # re-solved, not stale
    assert PLACE_COUNTS["analytic"] == n0 + 2
    # determinism makes the re-solve identical in value
    assert np.array_equal(a.lb_x, b.lb_x)
    assert np.array_equal(a.lb_y, b.lb_y)


def test_placement_key_shared_across_wire_delay_rows():
    """Wire-tier delays are data, not placement inputs: all delay rows
    of a structural class x grid aspect share ONE cached placement (the
    reuse the >= 2x sweep gate measures), while a different grid aspect
    is a different key."""
    clear_caches()
    net = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    arch = ARCHS["dd5"]
    wired = _wired(arch)
    assert arch.placement_key() == wired.placement_key()
    ir = pack(net, arch).lower_ir()
    a = placement_for(ir, arch, seed=0)
    hits0 = PLACE_COUNTS["cache_hit"]
    assert placement_for(ir, wired, seed=0) is a
    assert PLACE_COUNTS["cache_hit"] == hits0 + 1
    wide = _wired(arch, grid_aspect=2.0)
    assert wide.placement_key() != arch.placement_key()
    b = placement_for(ir, wide, seed=0)
    assert b is not a and b.grid_w != a.grid_w


def test_sweep_place_matches_placed_oracle_and_frontier():
    """``sweep_suite(place=True)`` over a grid crossing structural
    classes x wire profiles: every record bit-identical to the placed
    oracle under the shared registry placements; zero-wire rows equal
    the unplaced sweep bit for bit."""
    clear_caches()
    nets = [kratos_gemm(m=4, n=4, width=4, sparsity=0.5)]
    grid = [ARCHS["baseline"], _wired(ARCHS["baseline"]),
            ARCHS["dd5"], _wired(ARCHS["dd5"])]
    res = sweep_suite(nets, grid, backend="numpy", place=True)
    assert oracle_parity(res, nets, grid, place=True)
    res0 = sweep_suite(nets, grid, backend="numpy", place=False)
    for k, arch in enumerate(grid):
        placed_cp = res.records[0][k]["critical_path_ps"]
        flat_cp = res0.records[0][k]["critical_path_ps"]
        if (arch.t_wire_hop1, arch.t_wire_hop2, arch.t_wire_long) \
                == (0.0, 0.0, 0.0):
            assert placed_cp == flat_cp
        else:
            assert placed_cp >= flat_cp


def test_mismatched_placement_is_rejected():
    import pytest

    net = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    arch = ARCHS["dd5"]
    packed = pack(net, arch)
    ir = packed.lower_ir()
    bad = GridPlacement(1, 1, np.zeros(1, np.int32), np.zeros(1, np.int32),
                        0, ir.net_digest, arch.placement_key())
    if ir.n_lbs != 1:
        with pytest.raises(ValueError):
            apply_placement(ir, bad)
        with pytest.raises(ValueError):
            analyze_placed_oracle(packed, bad)
    other = ARCHS["baseline"]
    if other.structural_key() != arch.structural_key():
        with pytest.raises(ValueError):
            place_ir(ir, other, seed=0)


def test_channel_congestion_totals_match_hpwl():
    """RUDY invariant: every net's summed channel demand equals its
    HPWL (vertical demand sums to horizontal span, and vice versa)."""
    net = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    arch = ARCHS["dd5"]
    pir = place_and_apply(pack(net, arch).lower_ir(), arch, seed=0)
    cong = channel_congestion(pir, arch=arch)
    assert cong["channel_width"] == arch.channel_width == 400
    # recompute total HPWL over distinct routed nets from the IR
    dst = np.repeat(np.arange(pir.n_signals), np.diff(pir.fanin_ptr))
    src = pir.fanin_sig
    m = (pir.sig_lb[src] >= 0) & (pir.sig_lb[dst] >= 0) \
        & (pir.sig_lb[src] != pir.sig_lb[dst])
    hx0 = {}
    for s, d in zip(src[m], dst[m]):
        xs = (pir.sig_x[s], pir.sig_x[d])
        ys = (pir.sig_y[s], pir.sig_y[d])
        if s in hx0:
            x0, x1, y0, y1 = hx0[s]
            hx0[s] = (min(x0, *xs), max(x1, *xs), min(y0, *ys), max(y1, *ys))
        else:
            hx0[s] = (min(xs), max(xs), min(ys), max(ys))
    want_v = float(sum(x1 - x0 for x0, x1, _, _ in hx0.values()))
    want_h = float(sum(y1 - y0 for _, _, y0, y1 in hx0.values()))
    assert np.isclose(cong["vertical"].sum(), want_v)
    assert np.isclose(cong["horizontal"].sum(), want_h)
